//! `coded-opt` launcher binary.
//!
//! Subcommands:
//! - `run --config exp.toml [--workers N --k K --scheme S --iters T
//!   --step A --lambda L --policy static|adaptive[:opts]]` —
//!   run one experiment through the [`coded_opt::driver::Experiment`]
//!   API (overrides apply on top of the config file; all flags optional,
//!   defaults from [`coded_opt::config::ExperimentConfig`]). Every
//!   algorithm is supported: gd / lbfgs / prox / bcd / async_gd /
//!   async_bcd; `--policy adaptive` retunes wait-for-k between rounds
//!   (sync solvers only, any engine).
//! - `spectrum [--scheme paley --n 128 --workers 16 --beta 2 --k 12]` —
//!   print the subsampled-Gram eigenvalue summary (Figures 5–6 style).
//! - `bench [--json] [--out BENCH_hotpath.json]
//!   [--compare bench/baseline.json --tolerance 0.25] [--threads N]
//!   [--fast]` — time the compute hot paths (structured encode, blocked
//!   parallel gram/matmul/matvec_t, worker gradient) against the naive
//!   reference kernels kept in `linalg::mat::reference`, emit the
//!   `coded-opt/bench-v1` JSON report, and optionally gate on a
//!   checked-in baseline: only *speedup ratios* are compared (fast vs
//!   reference timed in the same process), because absolute seconds are
//!   machine-dependent. The report's `features` field records the
//!   detected CPU vector features plus the active SIMD / precision
//!   configuration; `simd_*` pairs time the AVX2 kernels against the
//!   forced-scalar path in the same process, `f32_*` pairs time
//!   f32-storage kernels against f64.
//! - `scenario [--schemes hadamard,uncoded --algorithms gd,lbfgs|all
//!   --scenarios crash-rejoin,rack-correlated | --scenario-file sc.toml]
//!   [--n N --p P --workers M --k K --beta B --iters T --seed S
//!   --policy static|adaptive[:opts] --out dir
//!   --json-out FILE --epsilon E] [--list]` — sweep a Scheme × Solver ×
//!   Scenario grid on the deterministic SimCluster and print per-cell
//!   results (`--out` also writes per-cell trace CSVs and canonical
//!   bit-exact traces; `--json-out` writes the `coded-opt/grid-v1`
//!   per-cell metrics report; `--policy` selects the wait-for-k runtime
//!   controller, see [`coded_opt::control`]).
//! - `pareto [--schemes hadamard,uncoded --betas 1,2
//!   --policies static,adaptive --scenarios crash-rejoin,rack-correlated
//!   --n N --p P --workers M --k K0 --iters T --seed S --lambda L
//!   --epsilon E --out FILE]` — sweep the (β, k-policy, scheme) ×
//!   scenario grid, report per-point time-to-ε / round-latency /
//!   erasure-robustness metrics, mark the per-scenario non-dominated
//!   points, and (with `--out`) write the `coded-opt/pareto-v1` report
//!   ([`coded_opt::control::pareto`]). Byte-deterministic for a pinned
//!   seed — CI's `pareto-smoke` job runs the sweep twice and
//!   byte-compares the two reports.
//! - `shard --out DIR [--dataset gaussian|sparse --n N --p P --sigma S
//!   --seed S --shard-rows R --nnz K --dtype f64|f32]` — generate a
//!   synthetic dataset straight into the out-of-core shard format
//!   (`manifest.json` + `shard-*.bin`, schema `coded-opt/shard-v1`).
//!   The gaussian ensemble streams shard-by-shard and never
//!   materializes the full matrix. `--dtype f32` stores the design
//!   matrix at half width (targets stay f64); readers transparently
//!   widen back to f64.
//! - `encode --source DIR --out DIR [--scheme S --workers M --beta B
//!   --seed S]` — apply an encoding scheme to a sharded dataset
//!   block-by-block (FWHT / CSR fast paths included) and write the
//!   Parseval-normalized worker partitions `(S̄_iX, S̄_iy)` as one shard
//!   dataset per worker.
//! - `run --source DIR …` — run an experiment whose worker shards are
//!   encoded from the sharded dataset instead of an in-memory matrix
//!   (gd / lbfgs / prox / async_gd). Bit-identity to the in-memory run
//!   holds for the *pipeline* (same solver + step ⇒ same trace, pinned
//!   by `rust/tests/shard_pipeline.rs`); CLI *default* steps are
//!   derived from a streamed spectral-norm estimate whose last bits
//!   differ from the in-memory estimate, so pass an explicit `--step`
//!   when diffing CLI traces bit-for-bit (prox also reports no F1
//!   metric — there is no known `w*` on the sharded path).
//! - `run … [--cluster sim|threads|socket] [--worker-addrs A,B,…]
//!   [--replay-tape FILE] [--trace-out FILE]` — engine selection and
//!   cross-engine diffing. `--cluster socket` runs the round gather
//!   over TCP against `coded-opt worker` processes (one address per
//!   encoded partition, in worker order); `--replay-tape` replays a
//!   recorded delay tape (text format: one line per round, one f64 per
//!   worker, `inf` = crash) instead of sampling delays; `--trace-out`
//!   writes the canonical bit-exact trace, so
//!   `cmp sim.trace socket.trace` is the cross-engine conformance
//!   check (see `.github/workflows/ci.yml` `socket-smoke`).
//! - `worker --partition DIR [--listen ADDR] [--once]` — serve one
//!   encoded partition (a `worker-NNN` directory written by
//!   `coded-opt encode`) to a socket-engine master. Prints
//!   `worker listening on HOST:PORT …` once bound (`--listen` defaults
//!   to `127.0.0.1:0`, an OS-assigned port); `--once` exits after one
//!   master session (used by CI).
//! - `lint [--root DIR] [--format human|json|github] [--out FILE]
//!   [--graph-out FILE]` — run the determinism-contract static
//!   analysis (see [`coded_opt::analysis`]) over the source tree
//!   (default root: `rust/src`, falling back to `src`): the line
//!   rules plus the module-graph architecture rules (`layer-order`,
//!   `zone-containment`, `eager-buffer`). `--format github` emits
//!   `::error` annotation lines so CI findings render inline on the
//!   PR diff (`--json` is an alias for `--format json`); `--out`
//!   writes the `coded-opt/lint-v1` JSON report to a file;
//!   `--graph-out` writes the extracted `coded-opt/modgraph-v1`
//!   module DAG (committed as `module-graph.json` at the repo root
//!   and drift-gated by the CI `lint` job). Exit codes: 0 clean,
//!   1 findings, 2 IO/usage errors.
//! - `info` — build / artifact info.

use anyhow::{bail, Result};
use coded_opt::bench::{banner, run_bench, BenchReport};
use coded_opt::cli::Args;
use coded_opt::cluster::WorkerServer;
use coded_opt::config::{Algorithm, ExperimentConfig, Scheme};
use coded_opt::control::pareto::{pareto_json, pareto_table, run_pareto, ParetoSpec};
use coded_opt::control::KPolicy;
use coded_opt::data::shard::{
    shard_dataset_dtype, BlockSource, Dtype, MatSource, ShardedSource,
};
use coded_opt::data::synth::{gaussian_linear, gaussian_linear_shard_to_dtype, sparse_recovery};
use coded_opt::driver::{
    AsyncBcd, AsyncGd, Bcd, DataSource, Engine, Experiment, Gd, Lbfgs, Problem, Prox, RunOutput,
};
use coded_opt::encoding::{stream, EncodingOp, FastPath, SubsetSpectrum};
use coded_opt::linalg::{dot, mat::reference, par, simd, Mat, MatF32};
use coded_opt::metrics::{TableWriter, Trace};
use coded_opt::objectives::{LassoProblem, QuadObjective, RidgeProblem};
use coded_opt::rng::Pcg64;
use coded_opt::runtime::ArtifactIndex;
use coded_opt::scenario::{
    canonical_trace, grid_json, read_tape_file, run_grid, summarize_cell, summary_table, GridCell,
    GridSpec, Scenario,
};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("spectrum") => cmd_spectrum(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("pareto") => cmd_pareto(&args),
        Some("shard") => cmd_shard(&args),
        Some("encode") => cmd_encode(&args),
        Some("worker") => cmd_worker(&args),
        Some("bench") => cmd_bench(&args),
        Some("lint") => lint_entry(&args),
        Some("info") | None => cmd_info(),
        Some(other) => bail!(
            "unknown subcommand '{other}' \
             (try: run, spectrum, scenario, pareto, shard, encode, worker, bench, lint, info)"
        ),
    }
}

fn cmd_info() -> Result<()> {
    println!("coded-opt {}", env!("CARGO_PKG_VERSION"));
    println!("encoded distributed optimization (Karakus, Sun, Diggavi, Yin — 2018)");
    let idx = ArtifactIndex::default_location()?;
    if idx.is_empty() {
        println!("artifacts: none (run `make artifacts` for the PJRT fast path)");
    } else {
        println!("artifacts ({}):", idx.len());
        for a in idx.all() {
            println!("  {:<24} {:<14} {}x{}", a.name, a.kind, a.rows, a.cols);
        }
    }
    println!(
        "subcommands: run, spectrum, scenario, pareto, shard, encode, worker, bench, lint, info"
    );
    Ok(())
}

/// Exit-code discipline for `lint`: 0 clean, 1 findings, 2 IO/usage
/// errors — so CI and scripts can tell "the contract is violated"
/// from "the tool could not run".
fn lint_entry(args: &Args) -> Result<()> {
    match cmd_lint(args) {
        Ok(true) => Ok(()),
        Ok(false) => std::process::exit(1),
        Err(e) => {
            eprintln!("lint error: {e:#}");
            std::process::exit(2);
        }
    }
}

/// Determinism-contract static analysis over the source tree (line
/// rules + module-graph architecture rules). Returns whether the tree
/// is clean; report/graph artifacts are written regardless, so a
/// failing CI run still uploads them.
fn cmd_lint(args: &Args) -> Result<bool> {
    let format = match args.get("format") {
        Some(f) => f.to_string(),
        None if args.has_flag("json") => "json".to_string(),
        None => "human".to_string(),
    };
    if !matches!(format.as_str(), "human" | "json" | "github") {
        bail!("lint: unknown --format '{format}' (expected human, json or github)");
    }
    let root = match args.get("root") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => ["rust/src", "src"]
            .into_iter()
            .map(std::path::PathBuf::from)
            .find(|p| p.is_dir())
            .ok_or_else(|| {
                anyhow::anyhow!("lint: no rust/src or src here; pass --root DIR")
            })?,
    };
    let report = coded_opt::analysis::lint_path(&root)?;
    if let Some(path) = args.get("out") {
        std::fs::write(path, report.to_json())?;
    }
    if let Some(path) = args.get("graph-out") {
        std::fs::write(path, report.graph.to_json())?;
    }
    match format.as_str() {
        "json" => println!("{}", report.to_json()),
        "github" => print!("{}", report.render_github(&root.to_string_lossy())),
        _ => {
            println!("lint root: {}", root.display());
            print!("{}", report.render_human());
        }
    }
    Ok(report.is_clean())
}

/// Generate a synthetic dataset straight into the shard-v1 format.
fn cmd_shard(args: &Args) -> Result<()> {
    let Some(out) = args.get("out") else { bail!("shard: --out DIR is required") };
    let n = args.get_usize("n")?.unwrap_or(4096);
    let p = args.get_usize("p")?.unwrap_or(64);
    let sigma = args.get_f64("sigma")?.unwrap_or(0.5);
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    let shard_rows = args.get_usize("shard-rows")?.unwrap_or(1024);
    let dtype_arg = args.get("dtype").unwrap_or("f64");
    let dtype = Dtype::parse(dtype_arg)
        .ok_or_else(|| anyhow::anyhow!("shard: unknown --dtype '{dtype_arg}' (f64, f32)"))?;
    let dataset = args.get("dataset").unwrap_or("gaussian");
    let manifest = match dataset {
        "gaussian" => {
            // fully streaming: the full X never exists in this process
            let (manifest, _w_star) =
                gaussian_linear_shard_to_dtype(out, n, p, sigma, seed, shard_rows, dtype)?;
            manifest
        }
        "sparse" => {
            // the sparse-recovery ensemble draws w* support before the
            // noise, so it is generated in memory and then sharded
            let nnz = args.get_usize("nnz")?.unwrap_or(p / 12 + 1);
            let (x, y, _) = sparse_recovery(n, p, nnz, sigma, seed);
            shard_dataset_dtype(&x, Some(&y), out, shard_rows, dtype)?
        }
        other => bail!("shard: unknown --dataset '{other}' (gaussian, sparse)"),
    };
    println!(
        "sharded '{dataset}' dataset: n={} p={} → {} shard(s) of ≤{} rows in {}",
        manifest.rows,
        manifest.cols,
        manifest.shards.len(),
        manifest.shard_rows,
        out
    );
    println!("manifest: {}/manifest.json (schema coded-opt/shard-v1)", out);
    Ok(())
}

/// Apply an encoding to a sharded dataset block-by-block and write the
/// Parseval-normalized worker partitions, each as its own shard dataset.
fn cmd_encode(args: &Args) -> Result<()> {
    let Some(source) = args.get("source") else { bail!("encode: --source DIR is required") };
    let Some(out) = args.get("out") else { bail!("encode: --out DIR is required") };
    let scheme = Scheme::parse(args.get("scheme").unwrap_or("hadamard"))?;
    let m = args.get_usize("workers")?.unwrap_or(8);
    let beta = args.get_f64("beta")?.unwrap_or(2.0);
    let seed = args.get_usize("seed")?.unwrap_or(42) as u64;
    if scheme == Scheme::Replication {
        bail!(
            "encode: replication is a partitioning strategy, not an encoding \
             (duplication happens at the cluster layer); use `run --source` \
             with --scheme replication instead"
        );
    }
    let src = ShardedSource::open(source)?;
    let n = src.rows();
    let enc = EncodingOp::build(scheme, n, m, beta, seed)?;
    let fast = enc.fast_path();
    let fast_name = fast.name();
    println!(
        "encoding {} rows × {} cols with {} (β={:.3}, fast path: {fast_name}) for {m} workers",
        n,
        src.cols(),
        scheme.name(),
        enc.beta
    );
    // Honest memory expectations per path (see write_encoded_partitions):
    if fast == FastPath::Fwht {
        println!(
            "memory: the FWHT panel encoder completes output columns across all \
             workers at once, so all {m} encoded partitions are resident until \
             write-out (column-chunked incremental writer is a ROADMAP item)"
        );
    } else {
        println!(
            "memory: partitions stream to disk shard-by-shard — resident output \
             is one shard plus one regenerated generator row-range"
        );
    }
    let out_dir = std::path::Path::new(out);
    // one normalization + write path, shared with the test suite (see
    // encoding::stream::write_encoded_partitions)
    let manifests = stream::write_encoded_partitions(&enc, &src, out_dir)?;
    let has_targets = src.has_targets();
    let worker_dirs: Vec<String> =
        (0..manifests.len()).map(|w| format!("worker-{w:03}")).collect();
    // top-level metadata tying the partitions back to the encoding
    let mut meta = String::from("{\n");
    meta.push_str("  \"schema\": \"coded-opt/encode-v1\",\n");
    meta.push_str(&format!("  \"scheme\": \"{}\",\n", scheme.name()));
    meta.push_str(&format!("  \"beta\": {:.6},\n", enc.beta));
    meta.push_str(&format!("  \"n\": {n},\n"));
    meta.push_str(&format!("  \"p\": {},\n", src.cols()));
    meta.push_str(&format!("  \"workers\": {m},\n"));
    meta.push_str(&format!("  \"seed\": {seed},\n"));
    meta.push_str("  \"normalized\": true,\n");
    meta.push_str(&format!(
        "  \"partitions\": [{}]\n",
        worker_dirs.iter().map(|d| format!("\"{d}\"")).collect::<Vec<_>>().join(", ")
    ));
    meta.push_str("}\n");
    std::fs::write(out_dir.join("encoding.json"), meta)?;
    println!(
        "wrote {m} normalized worker partition(s) (S̄_iX{}) under {out} + encoding.json",
        if has_targets { ", S̄_iy" } else { "" }
    );
    Ok(())
}

/// `coded-opt worker`: serve one encoded partition over TCP to a
/// socket-engine master (see [`coded_opt::cluster::socket`]).
fn cmd_worker(args: &Args) -> Result<()> {
    let Some(partition) = args.get("partition") else {
        bail!(
            "worker: --partition DIR is required (a worker-NNN directory \
             written by `coded-opt encode`)"
        )
    };
    let listen = args.get("listen").unwrap_or("127.0.0.1:0");
    let mut server = WorkerServer::bind(listen, std::path::Path::new(partition))?;
    let (rows, cols) = server.shape();
    // Scraped by the conformance suite and quickstart scripts; stdout is
    // line-buffered, so the line flushes before the accept loop blocks.
    println!(
        "worker listening on {} — partition {partition} ({rows}×{cols})",
        server.local_addr()?
    );
    let sessions = if args.has_flag("once") { Some(1) } else { None };
    server.serve(sessions)
}

/// Hot-path kernel benchmarks with a machine-readable report and an
/// optional speedup-ratio regression gate (see `.github/workflows/ci.yml`
/// for the refresh procedure).
fn cmd_bench(args: &Args) -> Result<()> {
    if let Some(t) = args.get_usize("threads")? {
        par::set_threads(t);
    }
    let quick = args.has_flag("fast");
    let (warmup, iters) = if quick { (2, 8) } else { (5, 30) };
    banner(
        "hotpath",
        "fast kernels vs the naive pre-blocking reference (linalg::mat::reference)",
    );
    println!("threads: {}", par::threads());
    // Recorded in the report's `features` field so cross-runner baseline
    // diffs are explainable (informational — never gated).
    let features = format!(
        "cpu={}; simd={}; precision=f64",
        simd::cpu_features(),
        if simd::active() { "on" } else { "off" }
    );
    println!("features: {features}\n");
    let mut report = BenchReport::new(par::threads()).with_features(&features);
    let mut rng = Pcg64::new(1);

    // ---- structured Hadamard encode: 1024×512 generator applied to a
    //      512×128 data matrix (FWHT path vs dense per-block products)
    {
        let x = Mat::from_fn(512, 128, |_, _| rng.next_f64() - 0.5);
        let enc = EncodingOp::build(Scheme::Hadamard, 512, 16, 2.0, 3)?;
        let dense_blocks: Vec<Mat> =
            (0..enc.workers()).map(|i| enc.row_block(i).to_dense()).collect();
        let fast = run_bench("encode hadamard 1024x512 (fwht)", warmup, iters, || {
            std::hint::black_box(enc.encode_data(&x));
        });
        let naive = run_bench("encode hadamard 1024x512 (dense)", warmup, iters, || {
            for b in &dense_blocks {
                std::hint::black_box(reference::matmul(b, &x));
            }
        });
        report.push_pair("encode_hadamard_1024x512", &fast, &naive);
    }

    // ---- streamed shard encode (the out-of-core hot path): the FWHT
    //      column-panel encoder vs the dense block-accumulation fallback
    //      over the SAME block stream — dimensionless, like every gated
    //      pair. Same workload naming as the in-memory pair above: the
    //      1024×512 generator S applied to a 512×128 data matrix, here
    //      streamed as 8 row blocks of 64 (a miniature shard layout;
    //      the kernels only ever see one block at a time).
    {
        let x = Mat::from_fn(512, 128, |_, _| rng.next_f64() - 0.5);
        let enc = EncodingOp::build(Scheme::Hadamard, 512, 16, 2.0, 3)?;
        // dense referee blocks materialized OUTSIDE the timed region, so
        // the pair times the folds, not the block generation
        let dense_blocks: Vec<Mat> =
            (0..enc.workers()).map(|i| enc.row_block(i).to_dense()).collect();
        let src = MatSource::new(&x, None, 64);
        let fast = run_bench("shard encode 1024x512 (fwht stream)", warmup, iters, || {
            std::hint::black_box(stream::encode_data_streamed(&enc, &src).unwrap());
        });
        let naive = run_bench("shard encode 1024x512 (dense stream)", warmup, iters, || {
            std::hint::black_box(
                stream::encode_data_streamed_with_dense_blocks(&dense_blocks, &src).unwrap(),
            );
        });
        report.push_pair("shard_encode_hadamard_1024x512", &fast, &naive);
    }

    // ---- gram (the BRIP spectrum-analysis inner loop)
    {
        let a = Mat::from_fn(512, 512, |_, _| rng.next_f64() - 0.5);
        let fast = run_bench("gram 512x512 (blocked+par)", warmup, iters, || {
            std::hint::black_box(a.gram());
        });
        let naive = run_bench("gram 512x512 (naive)", warmup, iters, || {
            std::hint::black_box(reference::gram(&a));
        });
        report.push_pair("gram_512x512", &fast, &naive);
    }

    // ---- SIMD vs forced-scalar (the same kernels behind the
    //      CODED_OPT_SIMD toggle — outputs are bit-identical by the
    //      determinism contract, so the pair measures pure speed). The
    //      matvec pair is in the gate baseline; skipped entirely when
    //      SIMD is unavailable so a scalar-only machine does not report
    //      a meaningless 1.0x (the gate then fails loudly on the
    //      missing entry, which is the honest outcome).
    if simd::active() {
        let a = Mat::from_fn(1024, 512, |_, _| rng.next_f64() - 0.5);
        let v: Vec<f64> = (0..512).map(|_| rng.next_f64() - 0.5).collect();
        let fast = run_bench("matvec 1024x512 (simd)", warmup, iters * 4, || {
            std::hint::black_box(a.matvec(&v));
        });
        simd::set_forced(Some(false));
        let naive = run_bench("matvec 1024x512 (forced scalar)", warmup, iters * 4, || {
            std::hint::black_box(a.matvec(&v));
        });
        simd::set_forced(None);
        report.push_pair("simd_matvec_1024x512", &fast, &naive);

        let g = Mat::from_fn(512, 384, |_, _| rng.next_f64() - 0.5);
        let fast = run_bench("gram 512x384 (simd)", warmup, iters, || {
            std::hint::black_box(g.gram());
        });
        simd::set_forced(Some(false));
        let naive = run_bench("gram 512x384 (forced scalar)", warmup, iters, || {
            std::hint::black_box(g.gram());
        });
        simd::set_forced(None);
        report.push_pair("simd_gram_512x384", &fast, &naive);
    } else {
        println!("simd inactive (no avx2 or CODED_OPT_SIMD=0): skipping simd_* pairs");
    }

    // ---- f32 storage vs f64 (informational: the f32 kernels widen to
    //      f64 accumulators, so this measures the bandwidth win of
    //      half-width rows, not a precision shortcut)
    {
        let a = Mat::from_fn(1024, 512, |_, _| rng.next_f64() - 0.5);
        let af = MatF32::from_mat(&a);
        let v: Vec<f64> = (0..512).map(|_| rng.next_f64() - 0.5).collect();
        let fast = run_bench("matvec 1024x512 (f32 storage)", warmup, iters * 4, || {
            std::hint::black_box(af.matvec(&v));
        });
        let naive = run_bench("matvec 1024x512 (f64 storage)", warmup, iters * 4, || {
            std::hint::black_box(a.matvec(&v));
        });
        report.push_pair("f32_matvec_1024x512", &fast, &naive);
    }

    // ---- matmul and matvec_t (informational pairs; not in the gate
    //      baseline because small parallel margins are machine-noisy)
    {
        let a = Mat::from_fn(384, 384, |_, _| rng.next_f64() - 0.5);
        let b = Mat::from_fn(384, 384, |_, _| rng.next_f64() - 0.5);
        let fast = run_bench("matmul 384^3 (blocked+par)", warmup, iters, || {
            std::hint::black_box(a.matmul(&b));
        });
        let naive = run_bench("matmul 384^3 (naive ikj)", warmup, iters, || {
            std::hint::black_box(reference::matmul(&a, &b));
        });
        report.push_pair("matmul_384", &fast, &naive);

        let big = Mat::from_fn(4096, 512, |_, _| rng.next_f64() - 0.5);
        let xt: Vec<f64> = (0..4096).map(|_| rng.next_f64() - 0.5).collect();
        let fast = run_bench("matvec_t 4096x512 (stripe-par)", warmup, iters, || {
            std::hint::black_box(big.matvec_t(&xt));
        });
        let naive = run_bench("matvec_t 4096x512 (naive axpy)", warmup, iters, || {
            std::hint::black_box(reference::matvec_t(&big, &xt));
        });
        report.push_pair("matvec_t_4096x512", &fast, &naive);
    }

    // ---- worker gradient kernel at a shipped shard shape
    {
        let sx = Mat::from_fn(512, 128, |_, _| rng.next_f64() - 0.5);
        let sy: Vec<f64> = (0..512).map(|_| rng.next_f64() - 0.5).collect();
        let w: Vec<f64> = (0..128).map(|_| rng.next_f64() - 0.5).collect();
        let mut resid = vec![0.0; 512];
        let fast = run_bench("quad_grad 512x128 (fused)", warmup, iters * 4, || {
            sx.matvec_sub(&w, &sy, &mut resid);
            std::hint::black_box(sx.matvec_t(&resid));
        });
        let naive = run_bench("quad_grad 512x128 (naive)", warmup, iters * 4, || {
            let mut r = reference::matvec(&sx, &w);
            for (ri, yi) in r.iter_mut().zip(&sy) {
                *ri -= yi;
            }
            std::hint::black_box(reference::matvec_t(&sx, &r));
        });
        report.push_pair("quad_grad_512x128", &fast, &naive);
    }

    // ---- FWHT throughput (informational single)
    {
        let mut buf: Vec<f64> = (0..8192).map(|i| (i as f64 * 0.37).sin()).collect();
        let s = run_bench("FWHT n=8192", warmup, iters * 4, || {
            coded_opt::linalg::fwht(&mut buf);
        });
        report.push(&s);
    }

    println!();
    for e in &report.entries {
        if let Some(s) = e.speedup() {
            println!("{:<28} speedup {:.2}x", e.name, s);
        }
    }

    if let Some(path) = args.get("out") {
        std::fs::write(path, report.to_json())?;
        println!("\nwrote {path}");
    } else if args.has_flag("json") {
        println!("\n{}", report.to_json());
    }

    if let Some(baseline_path) = args.get("compare") {
        let tolerance = args.get_f64("tolerance")?.unwrap_or(0.25);
        let text = std::fs::read_to_string(baseline_path)
            .map_err(|e| anyhow::anyhow!("reading baseline {baseline_path}: {e}"))?;
        let baseline = BenchReport::parse_json(&text)?;
        let regressions = report.compare(&baseline, tolerance);
        if regressions.is_empty() {
            let gated = baseline.entries.iter().filter(|e| e.speedup().is_some()).count();
            println!("perf gate: ok ({gated} gated speedup(s), tolerance {tolerance})");
        } else {
            for r in &regressions {
                eprintln!("perf regression: {r}");
            }
            bail!(
                "perf gate failed: {} kernel(s) regressed >{:.0}%",
                regressions.len(),
                tolerance * 100.0
            );
        }
    }
    Ok(())
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(v) = args.get_usize("workers")? {
        cfg.workers = v;
    }
    if let Some(v) = args.get_usize("k")? {
        cfg.k = v;
    }
    if let Some(v) = args.get_usize("iters")? {
        cfg.iterations = v;
    }
    if let Some(v) = args.get("scheme") {
        cfg.scheme = Scheme::parse(v)?;
    }
    if let Some(v) = args.get("algorithm") {
        cfg.algorithm = Algorithm::parse(v)?;
    }
    if let Some(v) = args.get_f64("beta")? {
        cfg.beta = v;
    }
    if let Some(v) = args.get_f64("step")? {
        cfg.step_size = v;
    }
    if let Some(v) = args.get_f64("lambda")? {
        cfg.lambda = v;
    }
    if let Some(v) = args.get_usize("seed")? {
        cfg.seed = v as u64;
    }
    if args.has_flag("pjrt") {
        cfg.use_pjrt = true;
    }
    if let Some(v) = args.get("policy") {
        cfg.k_policy = KPolicy::parse(v)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// One wired pipeline for every algorithm AND every data source: the
/// Experiment owns the encoding, cluster, delays, and (optionally) the
/// PJRT runtime. The in-memory (`cmd_run`) and sharded
/// (`cmd_run_sharded`) paths both go through here, so a new config knob
/// can never apply to one and silently skip the other.
fn base_source<'a>(
    cfg: &ExperimentConfig,
    source: DataSource<'a>,
    idx: Option<&'a ArtifactIndex>,
    engine: Option<&Engine>,
) -> Experiment<'a> {
    let mut exp = Experiment::data_source(source)
        .scheme(cfg.scheme)
        .workers(cfg.workers)
        .wait_for(cfg.k)
        .redundancy(cfg.beta)
        .seed(cfg.seed)
        .controller(cfg.k_policy.clone())
        .label(&cfg.name);
    exp = match &cfg.scenario {
        Some(sc) => exp.scenario(sc),
        None => exp.delay_spec(cfg.delay.clone(), cfg.seed),
    };
    if let Some(engine) = engine {
        exp = exp.engine(engine.clone());
    }
    if let Some(idx) = idx {
        exp = exp.runtime(idx);
    }
    exp
}

/// [`base_source`] over a borrowed in-memory `(X, y)`.
fn base_experiment<'a>(
    cfg: &ExperimentConfig,
    x: &'a coded_opt::linalg::Mat,
    y: &'a [f64],
    idx: Option<&'a ArtifactIndex>,
    engine: Option<&Engine>,
) -> Experiment<'a> {
    base_source(cfg, DataSource::InMemory(Problem::least_squares(x, y)), idx, engine)
}

/// Engine selection from `--cluster` / `--worker-addrs`
/// (`None` = the library default, [`Engine::Sim`]).
fn cli_engine(args: &Args) -> Result<Option<Engine>> {
    let engine = match args.get("cluster") {
        None | Some("sim") => {
            if args.get("worker-addrs").is_some() {
                bail!("--worker-addrs only applies to --cluster socket");
            }
            return Ok(None);
        }
        Some("threads") => Engine::Threads {
            delay_scale: args.get_f64("delay-scale")?.unwrap_or(1e-3),
        },
        Some("socket") => {
            let Some(addrs) = args.get("worker-addrs") else {
                bail!(
                    "--cluster socket needs --worker-addrs HOST:PORT,HOST:PORT,… \
                     (one per encoded partition, in worker order)"
                )
            };
            let addrs: Vec<String> = csv_list(addrs).into_iter().map(String::from).collect();
            if addrs.is_empty() {
                bail!("--worker-addrs is empty");
            }
            Engine::Socket { addrs }
        }
        Some(other) => bail!("unknown --cluster '{other}' (sim, threads, socket)"),
    };
    Ok(Some(engine))
}

/// `--trace-out FILE`: write the canonical bit-exact trace
/// ([`canonical_trace`]) so two engines' runs can be diffed with `cmp`
/// (the CI `socket-smoke` job compares sim vs socket this way).
fn write_trace_out(args: &Args, cfg: &ExperimentConfig, out: &RunOutput) -> Result<()> {
    let Some(path) = args.get("trace-out") else { return Ok(()) };
    let cell = GridCell {
        scheme: cfg.scheme,
        algorithm: cfg.algorithm,
        scenario: cfg
            .scenario
            .as_ref()
            .map_or_else(|| "none".to_string(), |sc| sc.name.clone()),
        out: out.clone(),
    };
    std::fs::write(path, canonical_trace(&cell))?;
    println!("wrote canonical trace to {path}");
    Ok(())
}

/// One-line controller report for adaptive runs: where the online
/// policy actually moved k. Static runs stay silent so legacy output
/// is unchanged.
fn print_controller(out: &RunOutput) {
    if out.controller == "static" || out.rounds.is_empty() {
        return;
    }
    let lo = out.rounds.iter().map(|r| r.k_effective).min().unwrap_or(0);
    let hi = out.rounds.iter().map(|r| r.k_effective).max().unwrap_or(0);
    println!(
        "controller '{}': {} rounds, effective k ranged {lo}..{hi}",
        out.controller,
        out.rounds.len()
    );
}

/// Print a convergence trace the way `coded-opt run` reports it.
fn print_trace(trace: &Trace) {
    println!("\n{:>6} {:>16} {:>12} {:>10}", "iter", "objective", "metric", "time(s)");
    let stride = (trace.len() / 12).max(1);
    for r in trace.records.iter().step_by(stride) {
        println!("{:>6} {:>16.8} {:>12.4} {:>10.2}", r.iter, r.objective, r.test_metric, r.time);
    }
    println!(
        "\nfinal: objective {:.8}, metric {:.4}, total simulated time {:.2}s",
        trace.final_objective(),
        trace.final_test_metric(),
        trace.total_time()
    );
}

/// `coded-opt run --source DIR`: the experiment's worker shards are
/// encoded block-by-block from the sharded dataset; the full matrix is
/// never materialized in this process. Objectives are evaluated by
/// streaming passes over the shards.
fn cmd_run_sharded(
    mut cfg: ExperimentConfig,
    dir: &str,
    args: &Args,
    engine: Option<&Engine>,
) -> Result<()> {
    let src = ShardedSource::open(dir)?;
    cfg.n = src.rows();
    cfg.p = src.cols();
    cfg.validate()?;
    println!(
        "experiment '{}' from sharded source {dir}: {:?} / {} — n={} p={} ({} shards) \
         m={} k={} β={} iters={}",
        cfg.name,
        cfg.algorithm,
        cfg.scheme.name(),
        cfg.n,
        cfg.p,
        src.manifest().shards.len(),
        cfg.workers,
        cfg.k,
        cfg.beta,
        cfg.iterations
    );
    if !cfg.brip_feasible() {
        println!(
            "note: η·β = {:.2} < 1 — below the strict BRIP threshold (Def. 1); \
             expect a looser approximation band.",
            cfg.eta() * cfg.beta
        );
    }
    let idx = if cfg.use_pjrt { Some(ArtifactIndex::default_location()?) } else { None };
    let n = cfg.n as f64;
    let lambda = cfg.lambda;
    let eval_src = src.clone();
    let out = match cfg.algorithm {
        Algorithm::Gd | Algorithm::Lbfgs | Algorithm::AsyncGd => {
            // ridge objective, streamed: 1/(2n)·‖Xw−y‖² + λ/2·‖w‖²
            let eval = move |w: &[f64]| -> (f64, f64) {
                // loud: mid-run shard corruption must abort the run, not
                // degrade into a silent NaN objective column
                let mse = eval_src
                    .half_mse(w)
                    .unwrap_or_else(|e| panic!("sharded eval failed mid-run: {e}"));
                (mse + 0.5 * lambda * dot(w, w), 0.0)
            };
            // The default-step smoothness estimate costs 60 streaming
            // passes over the shards — only pay for it when a default
            // step is actually needed (Lbfgs line-searches; --step
            // overrides it for gd/async_gd).
            let smoothness = || -> Result<f64> {
                Ok(src.gram_spectral_norm(60, 0x5e)? / n + lambda)
            };
            match cfg.algorithm {
                Algorithm::Gd => {
                    let step = if cfg.step_size > 0.0 {
                        cfg.step_size
                    } else {
                        1.0 / smoothness()?
                    };
                    base_source(&cfg, DataSource::Sharded(src.clone()), idx.as_ref(), engine)
                        .eval(eval)
                        .run(Gd::with_step(step).lambda(lambda).iters(cfg.iterations))?
                }
                Algorithm::Lbfgs => {
                    base_source(&cfg, DataSource::Sharded(src.clone()), idx.as_ref(), engine)
                        .eval(eval)
                        .run(
                            Lbfgs::new()
                                .iters(cfg.iterations)
                                .lambda(lambda)
                                .memory(cfg.lbfgs_memory),
                        )?
                }
                _ => {
                    let step = if cfg.step_size > 0.0 {
                        cfg.step_size
                    } else {
                        0.3 / smoothness()?
                    };
                    let updates = cfg.iterations * cfg.k;
                    base_source(&cfg, DataSource::Sharded(src.clone()), idx.as_ref(), engine)
                        .eval(eval)
                        .run(
                            AsyncGd::with_step(step)
                                .lambda(lambda)
                                .updates(updates)
                                .record_every((updates / 50).max(1)),
                        )?
                }
            }
        }
        Algorithm::ProxGradient => {
            // LASSO objective, streamed: 1/(2n)·‖Xw−y‖² + λ·‖w‖₁
            let eval = move |w: &[f64]| -> (f64, f64) {
                // loud on mid-run shard corruption (see the ridge eval)
                let mse = eval_src
                    .half_mse(w)
                    .unwrap_or_else(|e| panic!("sharded eval failed mid-run: {e}"));
                (mse + lambda * w.iter().map(|v| v.abs()).sum::<f64>(), 0.0)
            };
            let step = if cfg.step_size > 0.0 {
                cfg.step_size
            } else {
                // same expression shape as LassoProblem::default_step
                1.0 / (src.gram_spectral_norm(60, 0x1a)? / n).max(1e-12)
            };
            base_source(&cfg, DataSource::Sharded(src.clone()), idx.as_ref(), engine)
                .eval(eval)
                .run(Prox::with_step(step).lambda(lambda).iters(cfg.iterations))?
        }
        Algorithm::Bcd | Algorithm::AsyncBcd => bail!(
            "{:?} runs model-parallel (column access) and cannot read a sharded \
             (row-streamed) source; load the dataset in memory instead",
            cfg.algorithm
        ),
    };
    if cfg.use_pjrt {
        println!("PJRT-backed workers: {}/{}", out.pjrt_attached, cfg.workers);
    }
    write_trace_out(args, &cfg, &out)?;
    print_controller(&out);
    print_trace(&out.trace);
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    if let Some(path) = args.get("replay-tape") {
        // replace the delay model (or scenario) with the recorded tape;
        // the scenario name lands in the canonical-trace header, so both
        // sides of a cross-engine diff must use the same tape path
        let tape = read_tape_file(path)?;
        cfg.scenario = Some(Scenario::new(&format!("replay:{path}")).replay(tape));
    }
    let engine = cli_engine(args)?;
    let engine = engine.as_ref();
    if let Some(dir) = args.get("source") {
        return cmd_run_sharded(cfg, dir, args, engine);
    }
    println!(
        "experiment '{}': {:?} / {} — n={} p={} m={} k={} β={} iters={}",
        cfg.name,
        cfg.algorithm,
        cfg.scheme.name(),
        cfg.n,
        cfg.p,
        cfg.workers,
        cfg.k,
        cfg.beta,
        cfg.iterations
    );
    if let Some(sc) = &cfg.scenario {
        println!(
            "scenario '{}': {} transform(s), seed {}",
            sc.name,
            sc.transforms.len(),
            sc.seed
        );
    }
    if !cfg.brip_feasible() {
        println!(
            "note: η·β = {:.2} < 1 — below the strict BRIP threshold (Def. 1); \
             expect a looser approximation band.",
            cfg.eta() * cfg.beta
        );
    }
    let idx = if cfg.use_pjrt { Some(ArtifactIndex::default_location()?) } else { None };
    if cfg.use_pjrt
        && matches!(cfg.algorithm, Algorithm::Bcd | Algorithm::AsyncGd | Algorithm::AsyncBcd)
    {
        println!(
            "note: --pjrt has no effect for {:?} (only the data-parallel gradient \
             kernel has an AOT artifact); running native kernels.",
            cfg.algorithm
        );
    }

    let (x, y, w_star) = match cfg.algorithm {
        Algorithm::ProxGradient => sparse_recovery(cfg.n, cfg.p, cfg.p / 12 + 1, 0.5, cfg.seed),
        _ => gaussian_linear(cfg.n, cfg.p, 0.5, cfg.seed),
    };

    let out = match cfg.algorithm {
        Algorithm::Gd => {
            let prob = RidgeProblem::new(x.clone(), y.clone(), cfg.lambda);
            let step = if cfg.step_size > 0.0 { cfg.step_size } else { 1.0 / prob.smoothness() };
            base_experiment(&cfg, &x, &y, idx.as_ref(), engine)
                .eval(|w| (prob.objective(w), 0.0))
                .run(Gd::with_step(step).lambda(cfg.lambda).iters(cfg.iterations))?
        }
        Algorithm::Lbfgs => {
            let prob = RidgeProblem::new(x.clone(), y.clone(), cfg.lambda);
            base_experiment(&cfg, &x, &y, idx.as_ref(), engine)
                .eval(|w| (prob.objective(w), 0.0))
                .run(
                    Lbfgs::new()
                        .iters(cfg.iterations)
                        .lambda(cfg.lambda)
                        .memory(cfg.lbfgs_memory),
                )?
        }
        Algorithm::ProxGradient => {
            let prob = LassoProblem::new(x.clone(), y.clone(), cfg.lambda);
            let step = if cfg.step_size > 0.0 { cfg.step_size } else { prob.default_step() };
            let ws = w_star.clone();
            base_experiment(&cfg, &x, &y, idx.as_ref(), engine)
                .eval(move |w| {
                    let (_, _, f1) = coded_opt::metrics::f1_support(&ws, w, 1e-2);
                    (prob.objective(w), f1)
                })
                .run(Prox::with_step(step).lambda(cfg.lambda).iters(cfg.iterations))?
        }
        Algorithm::Bcd => {
            // Same reporting convention as every other arm: the
            // λ-regularized ridge objective. (BCD internally regularizes
            // the lifted blocks with λ‖v‖², so this tracks, not exactly
            // equals, what the updates minimize.)
            let prob = RidgeProblem::new(x.clone(), y.clone(), cfg.lambda);
            let step = if cfg.step_size > 0.0 {
                cfg.step_size
            } else {
                0.8 * cfg.n as f64 / x.gram_spectral_norm(60, cfg.seed)
            };
            base_experiment(&cfg, &x, &y, idx.as_ref(), engine)
                .eval(|w| (prob.objective(w), 0.0))
                .run(Bcd::with_step(step).lambda(cfg.lambda).iters(cfg.iterations))?
        }
        Algorithm::AsyncGd => {
            let prob = RidgeProblem::new(x.clone(), y.clone(), cfg.lambda);
            let step = if cfg.step_size > 0.0 {
                cfg.step_size
            } else {
                0.3 / prob.smoothness()
            };
            let updates = cfg.iterations * cfg.k;
            base_experiment(&cfg, &x, &y, idx.as_ref(), engine)
                .eval(|w| (prob.objective(w), 0.0))
                .run(
                    AsyncGd::with_step(step)
                        .lambda(cfg.lambda)
                        .updates(updates)
                        .record_every((updates / 50).max(1)),
                )?
        }
        Algorithm::AsyncBcd => {
            // Report the regularized objective so the column is comparable
            // to the other arms. (Async BCD's internal penalty is λ‖w‖² —
            // 2× the ridge convention's λ/2‖w‖² — so this tracks, not
            // exactly equals, what the updates minimize.)
            let prob = RidgeProblem::new(x.clone(), y.clone(), cfg.lambda);
            let step = if cfg.step_size > 0.0 {
                cfg.step_size
            } else {
                0.5 * cfg.n as f64 / x.gram_spectral_norm(60, cfg.seed)
            };
            let updates = cfg.iterations * cfg.k;
            base_experiment(&cfg, &x, &y, idx.as_ref(), engine)
                .eval(|w| (prob.objective(w), 0.0))
                .run(
                    AsyncBcd::with_step(step)
                        .lambda(cfg.lambda)
                        .updates(updates)
                        .record_every((updates / 50).max(1)),
                )?
        }
    };
    if cfg.use_pjrt {
        println!("PJRT-backed workers: {}/{}", out.pjrt_attached, cfg.workers);
    }
    write_trace_out(args, &cfg, &out)?;
    print_controller(&out);
    print_trace(&out.trace);
    Ok(())
}

fn cmd_spectrum(args: &Args) -> Result<()> {
    let n = args.get_usize("n")?.unwrap_or(120);
    let m = args.get_usize("workers")?.unwrap_or(16);
    let beta = args.get_f64("beta")?.unwrap_or(2.0);
    let k = args.get_usize("k")?.unwrap_or(3 * m / 4);
    let subsets = args.get_usize("subsets")?.unwrap_or(12);
    let schemes: Vec<Scheme> = match args.get("scheme") {
        Some(s) => vec![Scheme::parse(s)?],
        None => vec![
            Scheme::Gaussian,
            Scheme::Paley,
            Scheme::Hadamard,
            Scheme::Steiner,
            Scheme::Haar,
        ],
    };
    let mut table = TableWriter::new(&["scheme", "n", "k/m", "β", "λmin", "λmax", "ε", "bulk@1"]);
    for scheme in schemes {
        let enc = EncodingOp::build(scheme, n, m, beta, 5)?;
        let mut an = SubsetSpectrum::new(&enc, 11);
        let stats = an.analyze(k, subsets);
        table.row(&stats.summary_row());
    }
    table.print();
    Ok(())
}

fn csv_list(s: &str) -> Vec<&str> {
    s.split(',').map(|t| t.trim()).filter(|t| !t.is_empty()).collect()
}

/// Sweep a Scheme × Solver × Scenario grid on the deterministic
/// SimCluster and print per-cell results.
fn cmd_scenario(args: &Args) -> Result<()> {
    if args.has_flag("list") {
        println!("built-in scenarios:");
        for name in Scenario::builtin_names() {
            let sc = Scenario::builtin(name).unwrap();
            println!("  {:<16} {} transform(s)", name, sc.transforms.len());
        }
        return Ok(());
    }
    let mut spec = GridSpec::small();
    if let Some(s) = args.get("schemes") {
        spec.schemes =
            csv_list(s).into_iter().map(Scheme::parse).collect::<Result<Vec<_>>>()?;
    }
    if let Some(s) = args.get("algorithms") {
        spec.algorithms = if s == "all" {
            Algorithm::synchronous().to_vec()
        } else {
            csv_list(s).into_iter().map(Algorithm::parse).collect::<Result<Vec<_>>>()?
        };
    }
    // --scenarios (builtin names) and --scenario-file (TOML) REPLACE the
    // default scenario set; given together they combine.
    let mut scenarios = Vec::new();
    if let Some(s) = args.get("scenarios") {
        for name in csv_list(s) {
            scenarios.push(Scenario::builtin(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown scenario '{name}' (builtins: {}; or use --scenario-file)",
                    Scenario::builtin_names().join(", ")
                )
            })?);
        }
    }
    if let Some(path) = args.get("scenario-file") {
        scenarios.push(Scenario::from_file(path)?);
    }
    if !scenarios.is_empty() {
        spec.scenarios = scenarios;
    }
    if let Some(v) = args.get_usize("n")? {
        spec.n = v;
    }
    if let Some(v) = args.get_usize("p")? {
        spec.p = v;
    }
    if let Some(v) = args.get_usize("workers")? {
        spec.m = v;
    }
    if let Some(v) = args.get_usize("k")? {
        spec.k = v;
    }
    if let Some(v) = args.get_f64("beta")? {
        spec.beta = v;
    }
    if let Some(v) = args.get_usize("iters")? {
        spec.iters = v;
    }
    if let Some(v) = args.get_usize("seed")? {
        spec.seed = v as u64;
    }
    if let Some(v) = args.get("policy") {
        spec.policy = KPolicy::parse(v)?;
    }
    println!(
        "scenario grid: {} scheme(s) × {} solver(s) × {} scenario(s) = {} cells \
         (n={} p={} m={} k={} β={} iters={} seed={} policy={})",
        spec.schemes.len(),
        spec.algorithms.len(),
        spec.scenarios.len(),
        spec.cells(),
        spec.n,
        spec.p,
        spec.m,
        spec.k,
        spec.beta,
        spec.iters,
        spec.seed,
        spec.policy.name()
    );
    let cells = run_grid(&spec)?;
    summary_table(&cells).print();
    if let Some(dir) = args.get("out") {
        let dir = std::path::Path::new(dir);
        std::fs::create_dir_all(dir)?;
        for cell in &cells {
            let stem = cell.stem();
            coded_opt::metrics::write_csv(
                &dir.join(format!("{stem}.csv")),
                &[&cell.out.trace],
            )?;
            std::fs::write(dir.join(format!("{stem}.trace")), canonical_trace(cell))?;
        }
        println!("wrote {} trace pairs to {}", cells.len(), dir.display());
    }
    if let Some(path) = args.get("json-out") {
        let epsilon = args.get_f64("epsilon")?.unwrap_or(0.5);
        let rows: Vec<_> = cells.iter().map(|c| summarize_cell(c, epsilon)).collect();
        std::fs::write(path, grid_json(&spec, epsilon, &rows))?;
        println!("wrote coded-opt/grid-v1 report ({} cells) to {path}", rows.len());
    }
    Ok(())
}

/// Sweep the (β, k-policy, scheme) × scenario grid and report the
/// redundancy/latency pareto frontier (`coded-opt/pareto-v1`).
fn cmd_pareto(args: &Args) -> Result<()> {
    let mut spec = ParetoSpec::small();
    if let Some(s) = args.get("schemes") {
        spec.schemes =
            csv_list(s).into_iter().map(Scheme::parse).collect::<Result<Vec<_>>>()?;
    }
    if let Some(s) = args.get("betas") {
        let mut betas = Vec::new();
        for t in csv_list(s) {
            match t.parse::<f64>() {
                Ok(b) => betas.push(b),
                Err(e) => bail!("bad --betas entry '{t}': {e}"),
            }
        }
        spec.betas = betas;
    }
    if let Some(s) = args.get("policies") {
        spec.policies =
            csv_list(s).into_iter().map(KPolicy::parse).collect::<Result<Vec<_>>>()?;
    }
    if let Some(s) = args.get("scenarios") {
        spec.scenarios = csv_list(s).into_iter().map(String::from).collect();
    }
    if let Some(v) = args.get_usize("n")? {
        spec.n = v;
    }
    if let Some(v) = args.get_usize("p")? {
        spec.p = v;
    }
    if let Some(v) = args.get_usize("workers")? {
        spec.m = v;
    }
    if let Some(v) = args.get_usize("k")? {
        spec.k0 = v;
    }
    if let Some(v) = args.get_usize("iters")? {
        spec.iters = v;
    }
    if let Some(v) = args.get_usize("seed")? {
        spec.seed = v as u64;
    }
    if let Some(v) = args.get_f64("lambda")? {
        spec.lambda = v;
    }
    if let Some(v) = args.get_f64("epsilon")? {
        spec.epsilon = v;
    }
    println!(
        "pareto sweep: {} scheme(s) × {} β × {} polic{} × {} scenario(s) = {} points \
         (n={} p={} m={} k0={} iters={} seed={} ε={})",
        spec.schemes.len(),
        spec.betas.len(),
        spec.policies.len(),
        if spec.policies.len() == 1 { "y" } else { "ies" },
        spec.scenarios.len(),
        spec.points(),
        spec.n,
        spec.p,
        spec.m,
        spec.k0,
        spec.iters,
        spec.seed,
        spec.epsilon
    );
    let points = run_pareto(&spec)?;
    pareto_table(&points).print();
    let on = points.iter().filter(|p| p.on_frontier).count();
    println!("{on} of {} points on the per-scenario frontier", points.len());
    if let Some(path) = args.get("out") {
        std::fs::write(path, pareto_json(&spec, &points))?;
        println!("wrote coded-opt/pareto-v1 report to {path}");
    }
    Ok(())
}
