//! `coded-opt` launcher binary.
//!
//! Subcommands:
//! - `run --config exp.toml [--workers N --k K --scheme S --iters T]` —
//!   run one data-parallel experiment (overrides apply on top of the
//!   config file; all flags optional, defaults from
//!   [`coded_opt::config::ExperimentConfig`]).
//! - `spectrum [--scheme paley --n 128 --workers 16 --beta 2 --k 12]` —
//!   print the subsampled-Gram eigenvalue summary (Figures 5–6 style).
//! - `info` — build / artifact info.

use anyhow::{bail, Result};
use coded_opt::cli::Args;
use coded_opt::cluster::SimCluster;
use coded_opt::config::{Algorithm, ExperimentConfig, Scheme};
use coded_opt::coordinator::{
    build_data_parallel_with_runtime, run_gd, run_lbfgs, run_prox, GdConfig, LbfgsConfig,
    ProxConfig,
};
use coded_opt::data::synth::{gaussian_linear, sparse_recovery};
use coded_opt::encoding::{Encoding, SubsetSpectrum};
use coded_opt::metrics::TableWriter;
use coded_opt::objectives::{LassoProblem, QuadObjective, RidgeProblem};
use coded_opt::runtime::ArtifactIndex;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("spectrum") => cmd_spectrum(&args),
        Some("info") | None => cmd_info(),
        Some(other) => bail!("unknown subcommand '{other}' (try: run, spectrum, info)"),
    }
}

fn cmd_info() -> Result<()> {
    println!("coded-opt {}", env!("CARGO_PKG_VERSION"));
    println!("encoded distributed optimization (Karakus, Sun, Diggavi, Yin — 2018)");
    let idx = ArtifactIndex::default_location()?;
    if idx.is_empty() {
        println!("artifacts: none (run `make artifacts` for the PJRT fast path)");
    } else {
        println!("artifacts ({}):", idx.len());
        for a in idx.all() {
            println!("  {:<24} {:<14} {}x{}", a.name, a.kind, a.rows, a.cols);
        }
    }
    println!("subcommands: run, spectrum, info");
    Ok(())
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(v) = args.get_usize("workers")? {
        cfg.workers = v;
    }
    if let Some(v) = args.get_usize("k")? {
        cfg.k = v;
    }
    if let Some(v) = args.get_usize("iters")? {
        cfg.iterations = v;
    }
    if let Some(v) = args.get("scheme") {
        cfg.scheme = Scheme::parse(v)?;
    }
    if let Some(v) = args.get("algorithm") {
        cfg.algorithm = Algorithm::parse(v)?;
    }
    if let Some(v) = args.get_f64("beta")? {
        cfg.beta = v;
    }
    if let Some(v) = args.get_usize("seed")? {
        cfg.seed = v as u64;
    }
    if args.has_flag("pjrt") {
        cfg.use_pjrt = true;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    println!(
        "experiment '{}': {:?} / {} — n={} p={} m={} k={} β={} iters={}",
        cfg.name,
        cfg.algorithm,
        cfg.scheme.name(),
        cfg.n,
        cfg.p,
        cfg.workers,
        cfg.k,
        cfg.beta,
        cfg.iterations
    );
    if !cfg.brip_feasible() {
        println!("note: η·β = {:.2} < 1 — below the strict BRIP threshold (Def. 1); \
                  expect a looser approximation band.", cfg.eta() * cfg.beta);
    }
    let idx = if cfg.use_pjrt { Some(ArtifactIndex::default_location()?) } else { None };

    let (x, y, w_star) = match cfg.algorithm {
        Algorithm::ProxGradient => sparse_recovery(cfg.n, cfg.p, cfg.p / 12 + 1, 0.5, cfg.seed),
        _ => gaussian_linear(cfg.n, cfg.p, 0.5, cfg.seed),
    };
    let dp = build_data_parallel_with_runtime(
        &x,
        &y,
        cfg.scheme,
        cfg.workers,
        cfg.beta,
        cfg.seed,
        idx.as_ref(),
    )?;
    if cfg.use_pjrt {
        println!("PJRT-backed workers: {}/{}", dp.pjrt_attached, cfg.workers);
    }
    let asm = dp.assembler.clone();
    let delay = coded_opt::delay::from_spec(&cfg.delay, cfg.workers, cfg.seed);
    let mut cluster = SimCluster::new(dp.workers, delay);

    let trace = match cfg.algorithm {
        Algorithm::Gd => {
            let prob = RidgeProblem::new(x.clone(), y.clone(), cfg.lambda);
            let step = if cfg.step_size > 0.0 { cfg.step_size } else { 1.0 / prob.smoothness() };
            let gd = GdConfig {
                k: cfg.k,
                step,
                iters: cfg.iterations,
                lambda: cfg.lambda,
                w0: None,
            };
            run_gd(&mut cluster, &asm, &gd, &cfg.name, &|w| (prob.objective(w), 0.0)).trace
        }
        Algorithm::Lbfgs => {
            let prob = RidgeProblem::new(x.clone(), y.clone(), cfg.lambda);
            let lb = LbfgsConfig {
                k: cfg.k,
                iters: cfg.iterations,
                lambda: cfg.lambda,
                memory: cfg.lbfgs_memory,
                rho: 0.9,
                w0: None,
            };
            run_lbfgs(&mut cluster, &asm, &lb, &cfg.name, &|w| (prob.objective(w), 0.0)).trace
        }
        Algorithm::ProxGradient => {
            let prob = LassoProblem::new(x.clone(), y.clone(), cfg.lambda);
            let step = if cfg.step_size > 0.0 { cfg.step_size } else { prob.default_step() };
            let px = ProxConfig {
                k: cfg.k,
                step,
                iters: cfg.iterations,
                lambda: cfg.lambda,
                w0: None,
            };
            let ws = w_star.clone();
            run_prox(&mut cluster, &asm, &px, &cfg.name, &|w| {
                let (_, _, f1) = coded_opt::metrics::f1_support(&ws, w, 1e-2);
                (prob.objective(w), f1)
            })
            .trace
        }
        Algorithm::Bcd => {
            bail!("model-parallel BCD runs live in examples/logistic_bcd.rs and benches/fig10*");
        }
    };
    println!("\n{:>6} {:>16} {:>12} {:>10}", "iter", "objective", "metric", "time(s)");
    let stride = (trace.len() / 12).max(1);
    for r in trace.records.iter().step_by(stride) {
        println!("{:>6} {:>16.8} {:>12.4} {:>10.2}", r.iter, r.objective, r.test_metric, r.time);
    }
    println!(
        "\nfinal: objective {:.8}, metric {:.4}, total simulated time {:.2}s",
        trace.final_objective(),
        trace.final_test_metric(),
        trace.total_time()
    );
    Ok(())
}

fn cmd_spectrum(args: &Args) -> Result<()> {
    let n = args.get_usize("n")?.unwrap_or(120);
    let m = args.get_usize("workers")?.unwrap_or(16);
    let beta = args.get_f64("beta")?.unwrap_or(2.0);
    let k = args.get_usize("k")?.unwrap_or(3 * m / 4);
    let subsets = args.get_usize("subsets")?.unwrap_or(12);
    let schemes: Vec<Scheme> = match args.get("scheme") {
        Some(s) => vec![Scheme::parse(s)?],
        None => vec![
            Scheme::Gaussian,
            Scheme::Paley,
            Scheme::Hadamard,
            Scheme::Steiner,
            Scheme::Haar,
        ],
    };
    let mut table = TableWriter::new(&["scheme", "n", "k/m", "β", "λmin", "λmax", "ε", "bulk@1"]);
    for scheme in schemes {
        let enc = Encoding::build(scheme, n, m, beta, 5)?;
        let mut an = SubsetSpectrum::new(&enc, 11);
        let stats = an.analyze(k, subsets);
        table.row(&stats.summary_row());
    }
    table.print();
    Ok(())
}
