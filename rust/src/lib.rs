//! # coded-opt — encoded distributed optimization
//!
//! Production-quality reproduction of *"Redundancy Techniques for Straggler
//! Mitigation in Distributed Optimization and Learning"* (Karakus, Sun,
//! Diggavi, Yin — 2018).
//!
//! The dataset is linearly encoded with a tall matrix `S ∈ R^{βn×n}`
//! (redundancy factor `β ≥ 1`), partitioned across `m` workers; each
//! iteration the master waits only for the fastest `k ≤ m` updates and
//! treats stragglers as erasures. The code redundancy compensates for the
//! lost updates, yielding *deterministic* convergence guarantees that hold
//! for arbitrary (even adversarial) straggler patterns.
//!
//! ## Entry point: the [`driver`] module
//!
//! Every solver — encoded GD, L-BFGS, proximal gradient, BCD, and the
//! asynchronous baselines — runs through one composable builder that owns
//! the problem → encoding → cluster → solve → evaluate wiring:
//!
//! ```no_run
//! use coded_opt::config::Scheme;
//! use coded_opt::data::synth::gaussian_linear;
//! use coded_opt::delay::MixtureDelay;
//! use coded_opt::driver::{Experiment, Lbfgs, Problem};
//! use coded_opt::objectives::{QuadObjective, RidgeProblem};
//!
//! # fn main() -> anyhow::Result<()> {
//! let (x, y, _) = gaussian_linear(1024, 256, 0.5, 99);
//! let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
//! let out = Experiment::new(Problem::least_squares(&x, &y))
//!     .scheme(Scheme::Hadamard)       // paper §4 encoding
//!     .workers(32)                    // m
//!     .wait_for(12)                   // k: fastest-k gather, rest erased
//!     .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 17)))
//!     .eval(|w| (prob.objective(w), 0.0))
//!     .run(Lbfgs::new().lambda(0.05).iters(50))?;
//! println!("final objective {:.6} after {:.1} simulated seconds",
//!          out.trace.final_objective(), out.trace.total_time());
//! # Ok(())
//! # }
//! ```
//!
//! The driver's docs also state the normalization convention
//! (`S̄ᵀS̄ = I` Parseval shards, `m/k` partial-sum rescaling) every layer
//! below relies on.
//!
//! ## Straggler scenarios
//!
//! The paper's guarantees are *sample-path*: they hold "for arbitrary
//! sequences of delay patterns or distributions on the nodes". The
//! [`scenario`] module makes such sequences first-class: a
//! [`scenario::Scenario`] is a named, seedable description — a base
//! delay spec plus composable transforms (time-varying phases,
//! rack-correlated slowdowns, crash/rejoin windows, per-worker delay
//! scaling) and a per-worker compute-speed profile — pluggable into any
//! experiment:
//!
//! ```no_run
//! use coded_opt::config::DelaySpec;
//! use coded_opt::data::synth::gaussian_linear;
//! use coded_opt::driver::{Experiment, Gd, Problem};
//! use coded_opt::scenario::{Scenario, WorkerSet};
//!
//! # fn main() -> anyhow::Result<()> {
//! let (x, y, _) = gaussian_linear(512, 64, 0.5, 42);
//! // a quarter of the fleet crashes for rounds [5, 15) and rejoins
//! let sc = Scenario::new("crash-rejoin")
//!     .base(DelaySpec::Exponential { mean: 0.005 })
//!     .crash(WorkerSet::Fraction(0.25), 5, 15);
//! let out = Experiment::new(Problem::least_squares(&x, &y))
//!     .workers(8)
//!     .wait_for(6)
//!     .scenario(&sc)
//!     .run(Gd::with_step(0.01).iters(100))?;
//! println!("survived the crash window: {:.1}s", out.trace.total_time());
//! # Ok(())
//! # }
//! ```
//!
//! Crash/rejoin maps directly onto the paper's erasure model: a crash is
//! an *unbounded delay* over a round window, so the crashed node simply
//! never makes the fastest-`k` set `A_t` while the window is open — no
//! new coordinator logic, and Theorem 2's arbitrary-`A_t` guarantee
//! covers it. Scenarios are also constructible from TOML (schema in the
//! [`scenario`] docs, via the `[scenario.*]` sections of an experiment
//! config) and runnable as a Scheme × Solver × Scenario grid with the
//! `coded-opt scenario` subcommand; `rust/tests/golden_traces.rs` pins
//! the grid's traces bit-for-bit against checked-in fixtures.
//!
//! ## Adaptive wait-for-k control and the redundancy/latency frontier
//!
//! The wait-for-`k` knob need not be static: the [`control`] module
//! adds an online controller that retunes `k` *between* rounds from the
//! recorded arrival pattern of the previous round. The contract
//! ([`control::Controller`]) has three clauses, stated in the module
//! docs and enforced by tests:
//!
//! 1. decisions derive **only** from recorded arrival times
//!    ([`metrics::RoundStats`]), so a controller run replays
//!    bit-identically from a delay tape on any engine;
//! 2. `k` stays within hard bounds — never below the erasure-tolerance
//!    floor [`control::erasure_floor`]`(m, β) = ⌈m/β⌉` (below it the
//!    code cannot cover the erasures), never above `m`, and held to the
//!    live-worker count under crash windows;
//! 3. exactly one `observe` per gather round, in round order.
//!
//! Select a policy with `Experiment::controller(KPolicy::parse(
//! "adaptive")?)` or `coded-opt scenario --policy adaptive:widen=2`;
//! static runs keep the strict legacy gather path and their golden
//! traces byte-for-byte. Per-round arrivals and the controller's
//! k-decision sequence are surfaced in [`driver::RunOutput`] (`rounds`,
//! `controller`) and in the canonical trace (`--trace-out`).
//!
//! On top of the controller sits the `coded-opt pareto` sweep
//! ([`control::pareto`]): a (β, k-policy, scheme) × scenario grid where
//! every cell reports time-to-ε, rounds-to-ε, erasure-robustness
//! `(m − ⌈m/β⌉)/m`, and mean/p99 round latency; per-scenario
//! non-dominated points form the redundancy/latency frontier the paper
//! trades along. Reports are hand-written JSON in the `bench-v1`
//! family: `coded-opt scenario --json-out` emits per-cell metrics as
//! `coded-opt/grid-v1` ([`scenario::GRID_SCHEMA`]) and `coded-opt
//! pareto --out` emits the point set + frontier as `coded-opt/pareto-v1`
//! ([`control::pareto::PARETO_SCHEMA`], field reference in the module
//! docs). Both are byte-deterministic for a pinned seed — CI's
//! `pareto-smoke` job runs the same pinned-seed sweep twice and
//! byte-compares the two reports.
//!
//! ## The compute data plane: deterministic parallel kernels
//!
//! The [`linalg`] kernels (`matvec` / `matvec_t` / `matmul` / `gram`,
//! dense and CSR) are cache-blocked and run on a dependency-free chunked
//! thread pool ([`linalg::par`]) with one hard contract: **results are
//! bit-identical at any thread count**. Chunk geometry and the
//! fixed-chunk tree-reduction shape depend only on problem size, never
//! on scheduling, so the golden-trace fixtures cannot move when the
//! thread knob does (CI re-runs the suite at 1 and 8 threads to prove
//! it). Set the knob with `Experiment::threads(n)`,
//! [`linalg::par::set_threads`], or the `CODED_OPT_THREADS` environment
//! variable; it only trades wall-clock for cores.
//!
//! The same contract extends to SIMD ([`linalg::simd`]): on x86_64 with
//! AVX2 the dense/CSR/FWHT inner loops run explicit `std::arch`
//! kernels, but every kernel vectorizes across **independent outputs**
//! — four output rows or four independent axpy/butterfly element
//! positions per vector — and runs each output's accumulation chain in
//! the exact scalar order (multiply then add, never FMA, never a
//! horizontal reduction). SIMD results are therefore **bit-identical to
//! scalar by construction**, and the `CODED_OPT_SIMD` environment
//! variable (`0` = force scalar, `1`/unset = auto-detect) is a
//! pure-speed knob that cannot move a golden trace; CI runs the kernel
//! and golden suites under both settings to prove it.
//!
//! Orthogonally, worker shards can be stored at f32
//! ([`linalg::Precision::F32`], via `Experiment::precision` or
//! `coded-opt shard --dtype f32`): storage is f32 (half the bytes/
//! bandwidth) while every accumulation stays f64 (widening is exact).
//! Unlike the SIMD and thread knobs, f32 storage is **not** bit-pinned
//! against f64 — the contract is a documented ≤1e-5 relative tolerance
//! against the f64 referee (`rust/tests/kernel_equivalence.rs`), and
//! golden traces are recorded under f64 only.
//!
//! ## Operator-first encoding: `SchemeSpec` → `EncodingOp`
//!
//! The paper's schemes are *operators*, not matrices (§4.2 "efficient
//! mechanisms for encoding large-scale data"), and the API mirrors
//! that. An [`encoding::SchemeSpec`] is a pure descriptor — scheme,
//! `n`, `m`, β, seed — that [`lower`](encoding::SchemeSpec::lower)s to
//! a lazy [`encoding::EncodingOp`] exposing the [`encoding::Encoder`]
//! trait (`apply` = `S·x`, `apply_t` = `Sᵀ·x`) plus on-demand
//! [`row_block(i)`](encoding::EncodingOp::row_block). **No dense row
//! block of `S` is stored anywhere**, so encoding state scales with
//! `O(n)`, not `N×n`:
//!
//! - *Structured schemes* — Hadamard applies through FWHT in
//!   `O(N log N)`; Steiner / Haar / identity sweep one CSR in
//!   `O(nnz)`. These never materialize a dense block on any encode
//!   path, a claim made executable by the [`encoding::probe`]
//!   block-generation counters (`rust/tests/lazy_encoding.rs`).
//! - *Dense ensembles* — Gaussian regenerates any block bit-identically
//!   from the seed by jumping the PCG stream
//!   ([`rng::Pcg64::advance`]); Paley rebuilds its size-guarded frame.
//!   Blocks exist only *while in use* and are dropped after — per-use
//!   generation, never a resident `N×n` matrix.
//!
//! `EncodingOp::encode_data` / `encode_vec`, the data-parallel worker
//! build, BCD's per-iteration `w = S̄ᵀv` reconstruction, and the
//! streamed encoders all route through the operator. Dense views exist
//! only where analysis explicitly asks for them
//! ([`stack`](encoding::EncodingOp::stack) for spectrum analysis,
//! `sbar_blocks` for debugging) — those calls ARE the materialization,
//! and the probe counts them.
//!
//! ## Out-of-core data: shards and the streaming encoder
//!
//! Datasets that do not fit one memory image live on disk as a *shard
//! directory* ([`data::shard`]): `manifest.json` (schema
//! `coded-opt/shard-v1` — global shape, targets flag, one entry per
//! shard file with starting row, row count, and payload checksum) plus
//! `shard-NNNNN.bin` files holding consecutive row blocks of `X` (and
//! `y`) as little-endian f64 — or, with `--dtype f32`, `X` at f32
//! (targets stay f64; readers widen transparently, manifest `dtype`
//! records the width). The [`data::shard::BlockSource`] trait is
//! the streaming contract: blocks arrive in ascending row order, are
//! bounded by the shard size, and a source can be re-iterated.
//!
//! [`encoding::stream`] applies any [`encoding::EncodingOp`]
//! shard-by-shard — FWHT via column panels, CSR and per-use regenerated
//! dense generators by continuing the exact per-element accumulation
//! order of the in-memory kernels across block boundaries — so the
//! streamed encode is **bit-identical** to
//! `EncodingOp::encode_data` on the equivalent matrix, and a sharded
//! experiment's trace is bit-identical to its in-memory twin
//! (`rust/tests/shard_pipeline.rs` pins both). Wire a sharded dataset
//! into the driver with `Experiment::sharded(ShardedSource::open(dir)?)`
//! (or [`driver::DataSource`] explicitly); the data-parallel solvers
//! (`Gd` / `Lbfgs` / `Prox`) and `AsyncGd` stream it, while the
//! model-parallel solvers (`Bcd` / `AsyncBcd`) need column access and
//! reject it loudly. On the command line:
//!
//! ```text
//! coded-opt shard  --out shards/ --n 1000000 --p 64 --shard-rows 8192
//! coded-opt encode --source shards/ --out encoded/ --scheme hadamard --workers 16
//! coded-opt run    --source shards/ --algorithm gd --workers 16 --k 12
//! ```
//!
//! The `shard` generator streams (the full matrix never exists in the
//! process); `encode` writes the Parseval-normalized worker partitions
//! `(S̄_iX, S̄_iy)` as one shard dataset per worker plus an
//! `encoding.json` (schema `coded-opt/encode-v1`).
//!
//! Scope of the memory claim: neither the **input** `X` (shard-bounded
//! blocks plus `O(n)` column-panel/target buffers only) nor the
//! **generator** `S` (lazy operator, see above) is ever whole in
//! memory on the sharded path. The encoded worker partitions are the
//! *product*: `coded-opt encode` streams CSR/dense partitions to disk
//! shard-by-shard (resident output = one shard), while the FWHT panel
//! path still assembles all partitions before write-out — an honest
//! exception the CLI prints, since the panel encoder completes output
//! columns across every worker at once (column-chunked writer: see
//! ROADMAP). Driver runs keep all partitions resident by design — they
//! *are* the simulated workers' shards.
//!
//! ## Cluster engines: one round contract, three substrates
//!
//! Every solver talks to its workers through the [`cluster::Gather`]
//! round contract (dispatch tasks, collect the fastest `k`, interrupt
//! the rest). Three engines implement it:
//!
//! | Engine | Processes | Clock | Use it for |
//! |---|---|---|---|
//! | [`cluster::SimCluster`] | one | virtual (delay-model arrivals) | experiments, grids, golden traces |
//! | [`cluster::ThreadCluster`] | one (worker threads) | virtual, real thread preemption | exercising real concurrency |
//! | [`cluster::SocketCluster`] | one master + `m` workers over TCP | virtual; wall clock only for fault detection | multi-host deployment, conformance |
//!
//! The socket engine keeps the virtual clock: the **master** samples the
//! delay model for all `m` workers each round and ranks arrivals exactly
//! like `SimCluster` — TCP only moves the payload bytes (exact
//! little-endian f64 bits, framed per the [`cluster::wire`] spec:
//! length-prefixed, versioned, checksummed). A disconnect, torn frame,
//! stale echo, or timeout is mapped to a *crash-erasure* (arrival `∞`),
//! which the paper's arbitrary-`A_t` guarantee already covers — so a
//! recorded delay tape replayed through real processes produces a trace
//! **bit-identical** to `SimCluster` on the same tape
//! (`rust/tests/socket_cluster.rs` pins it). Two terminals:
//!
//! ```text
//! # terminal 1..m — serve one encoded partition each
//! coded-opt worker --partition encoded/worker-000 --listen 127.0.0.1:7101
//!
//! # terminal 0 — drive the round loop over TCP
//! coded-opt run --source shards/ --scheme hadamard --workers 2 --k 1 \
//!     --algorithm gd --iters 20 --cluster socket \
//!     --worker-addrs 127.0.0.1:7101,127.0.0.1:7102
//! ```
//!
//! Record a tape with [`scenario::DelayRecorder`], ship it as text
//! (`scenario::write_tape_file`), and replay it on any engine with
//! `coded-opt run … --replay-tape tape.txt`; `--trace-out` writes the
//! canonical trace for `cmp`-style cross-engine diffing.
//!
//! ## Benchmarks and the perf gate
//!
//! `coded-opt bench` times the hot paths against the preserved naive
//! kernels (`linalg::mat::reference`) and emits a machine-readable
//! report (`BENCH_hotpath.json`, schema `coded-opt/bench-v1` — see
//! [`bench`] for the field reference). CI's `perf` job fails when any
//! gated kernel's *speedup ratio* drops >25% below the checked-in
//! `bench/baseline.json`; extend that schema, don't invent a new one.
//! Reports carry a `features` field (detected CPU vector features +
//! active SIMD/precision mode) and paired `simd_*` / `f32_*` entries
//! timing the AVX2 kernels against forced-scalar and f32 storage
//! against f64 in the same process. Refresh the baseline from the CI
//! runner class via the `baseline-refresh` workflow_dispatch job.
//!
//! ## Determinism contract
//!
//! Because the guarantees are sample-path results, the repo's real
//! cross-engine contract is *bit-exact golden traces* — and that only
//! holds if the source obeys a handful of invariants. They are
//! mechanized as a built-in static-analysis pass, `coded-opt lint`
//! (blocking in CI), implemented in [`analysis`]:
//!
//! - **`float-total-order`** — float orderings in sort/max/min
//!   positions use `f64::total_cmp`, never `partial_cmp` (which panics
//!   or goes order-unstable on NaN; cf. [`delay::sanitize_delay`]).
//! - **`wall-clock-zone`** — `Instant::now` / `SystemTime` only in the
//!   declared wall-clock modules (`cluster/threads.rs`,
//!   `cluster/socket.rs`, `cluster/wire.rs`, `bench.rs`; the socket
//!   engine reads wall time for connect/IO fault detection only, never
//!   for the trace). Anywhere else — `SimCluster`, solvers, encoding,
//!   scenarios — a wall-clock read breaks replay determinism.
//! - **`ordered-iteration`** — no `HashMap`/`HashSet` in
//!   trace-producing modules; hash-iteration order leaks into output.
//!   Use `BTreeMap`/`BTreeSet` or a sorted collection.
//! - **`safety-comment`** — `unsafe` only under `runtime/` (the PJRT
//!   FFI boundary) and in `linalg/simd.rs` (the `std::arch` kernels),
//!   and always with an adjacent `// SAFETY:` comment.
//! - **`no-silent-nan`** — no `NAN` literals or `.unwrap()` on partial
//!   orders in library (non-test) code; NaN is sanitized at the delay
//!   boundary, not smuggled through.
//!
//! On top of the line rules, the lint extracts the crate's module
//! dependency graph ([`analysis::graph`], from `use`/`mod`/qualified
//! paths — comments, strings and `#[cfg(test)]` regions contribute no
//! edges) and checks three architecture rules on it:
//!
//! - **`layer-order`** — imports must point down the layering DAG:
//!
//!   | layer | modules |
//!   |-------|---------|
//!   | 0 | `linalg` |
//!   | 1 | `encoding`, `data` |
//!   | 2 | `coordinator`, `cluster`, `scenario` |
//!   | 3 | `control` |
//!   | 4 | `driver` |
//!   | 5 | `cli`, `main` |
//!
//!   An import from a lower-numbered layer into a higher one is a
//!   finding. `analysis` sits outside the table: it may import
//!   *nothing* from the crate, so the lint can never depend on what it
//!   checks. Unlisted modules (`rng`, `metrics`, `objectives`, …) are
//!   shared leaves, unconstrained.
//! - **`zone-containment`** — the wall-clock zone (the declared
//!   wall-clock modules above) and the unsafe zone (`runtime`,
//!   `linalg::simd`) must
//!   stay leaves: a trace-affecting module importing one is a finding,
//!   exempting only a zone file's direct parent (that is how
//!   `linalg/mod.rs` dispatches into the SIMD kernel). The same rule
//!   pins `std::arch` / `core::arch` references to `linalg/simd.rs` at
//!   the line level.
//! - **`eager-buffer`** — the streaming modules (`encoding/stream.rs`,
//!   `data/shard.rs`, `coordinator/mod.rs`) must not call dense
//!   full-matrix constructors (`Mat::zeros`, `stack(`, `load_dense`);
//!   out-of-core paths build per block or stream through
//!   [`data::BlockSource`].
//!
//! Run it with `coded-opt lint` (`--format json` for the
//! machine-readable `coded-opt/lint-v1` report, `--format github` for
//! workflow error annotations on the PR diff, `--root DIR` to point it
//! elsewhere). Exit codes are part of the contract: 0 clean, 1
//! findings, 2 broken invocation (bad flag / unreadable root).
//! Justified exceptions are inline: `// lint:allow(<rule>) — <why>` on
//! (or directly above) the flagged line. The justification is
//! mandatory — a bare allow is itself reported — and every suppression
//! is counted in the report. The extracted graph is itself an
//! artifact: `coded-opt lint --graph-out FILE` writes the
//! `coded-opt/modgraph-v1` module DAG (sorted, line-number-free, so it
//! only changes on real architectural drift). CI regenerates it and
//! diffs against the committed `module-graph.json` at the repo root —
//! an architecture change must update that file in the same PR. What
//! the scanner cannot see, CI's sanitizer jobs cover: ThreadSanitizer
//! runs the thread-pool/cluster suites and Miri runs the `runtime`,
//! `shard`, and `fwht` unit tests on the nightly toolchain.
//!
//! ## Layout
//!
//! - [`driver`] — the `Experiment` builder and the `Solver` trait with
//!   its six implementations; the public API everything else goes
//!   through.
//! - [`linalg`] — dense/sparse linear algebra, FWHT, Cholesky, eigensolver.
//! - [`rng`] — PCG64 PRNG and the distributions used by data generation and
//!   straggler delay models.
//! - [`encoding`] — the paper's encoding schemes as lazy operators
//!   (`SchemeSpec` → `EncodingOp`; Paley / Hadamard / Steiner ETFs,
//!   subsampled Haar, Gaussian) and spectrum analysis.
//! - [`delay`] — straggler delay models (bimodal mixture, power-law
//!   background tasks, exponential, adversarial, trace replay).
//! - [`scenario`] — the scenario engine: composable delay transforms,
//!   record/replay, the TOML scenario DSL, and the Scheme × Solver ×
//!   Scenario grid runner behind `coded-opt scenario`.
//! - [`cluster`] — the master/worker distributed substrate with
//!   wait-for-`k` gather and interrupts: virtual-time [`cluster::sim`],
//!   thread-backed [`cluster::threads`], and multi-process TCP
//!   [`cluster::socket`] over the [`cluster::wire`] frame codec.
//! - [`control`] — the online wait-for-`k` runtime controllers
//!   (static / adaptive arrival-histogram policies behind
//!   [`control::Controller`]) and the `coded-opt pareto`
//!   redundancy/latency frontier sweep ([`control::pareto`]).
//! - [`coordinator`] — the algorithm master loops and worker state
//!   machines the driver dispatches to ([`driver::Experiment`] is the
//!   sole entry point; the old `run_*` shims are gone).
//! - [`objectives`] — ridge, LASSO, logistic regression, matrix
//!   factorization.
//! - [`data`] — synthetic workload generators mirroring the paper's
//!   datasets, plus the out-of-core shard format ([`data::shard`]).
//! - [`runtime`] — PJRT client that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them on the hot path.
//! - [`metrics`] — timers, traces, histograms, writers.
//! - [`analysis`] — the determinism-contract lint behind `coded-opt
//!   lint`: std-only source scanner, line rules, module-graph
//!   extraction + architecture rules ([`analysis::graph`]), and
//!   `lint:allow` handling. Depends on no other module in this list.
//! - [`config`] / [`cli`] — experiment configuration and launcher parsing.
//! - [`testutil`] — a small property-testing framework (offline
//!   environment: no external proptest) and the scripted
//!   [`testutil::MisbehavingPeer`] for socket fault-injection tests.
//! - [`bench`] — measurement harness used by `rust/benches/*`.

// Test code pins bit-exact values on purpose (golden traces, kernel
// equivalence), so exact float comparison is the point there; library
// code stays under the workspace-level `clippy::float_cmp` deny.
#![cfg_attr(test, allow(clippy::float_cmp))]

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod control;
pub mod coordinator;
pub mod data;
pub mod delay;
pub mod driver;
pub mod encoding;
pub mod linalg;
pub mod metrics;
pub mod objectives;
pub mod rng;
pub mod runtime;
pub mod scenario;
pub mod testutil;
