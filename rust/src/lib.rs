//! # coded-opt — encoded distributed optimization
//!
//! Production-quality reproduction of *"Redundancy Techniques for Straggler
//! Mitigation in Distributed Optimization and Learning"* (Karakus, Sun,
//! Diggavi, Yin — 2018).
//!
//! The dataset is linearly encoded with a tall matrix `S ∈ R^{βn×n}`
//! (redundancy factor `β ≥ 1`), partitioned across `m` workers; each
//! iteration the master waits only for the fastest `k ≤ m` updates and
//! treats stragglers as erasures. The code redundancy compensates for the
//! lost updates, yielding *deterministic* convergence guarantees that hold
//! for arbitrary (even adversarial) straggler patterns.
//!
//! ## Layout
//!
//! - [`linalg`] — dense/sparse linear algebra, FWHT, Cholesky, eigensolver.
//! - [`rng`] — PCG64 PRNG and the distributions used by data generation and
//!   straggler delay models.
//! - [`encoding`] — the paper's encoding matrices (Paley / Hadamard /
//!   Steiner ETFs, subsampled Haar, Gaussian) and spectrum analysis.
//! - [`delay`] — straggler delay models (bimodal mixture, power-law
//!   background tasks, exponential, adversarial, trace replay).
//! - [`cluster`] — the simulated master/worker distributed substrate with
//!   wait-for-`k` gather and interrupts.
//! - [`coordinator`] — encoded gradient descent, L-BFGS, proximal gradient,
//!   block coordinate descent, plus uncoded / replication / asynchronous
//!   baselines.
//! - [`objectives`] — ridge, LASSO, logistic regression, matrix
//!   factorization.
//! - [`data`] — synthetic workload generators mirroring the paper's
//!   datasets.
//! - [`runtime`] — PJRT client that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them on the hot path.
//! - [`metrics`] — timers, traces, histograms, writers.
//! - [`config`] / [`cli`] — experiment configuration and launcher parsing.
//! - [`testutil`] — a small property-testing framework (offline
//!   environment: no external proptest).
//! - [`bench`] — measurement harness used by `rust/benches/*`.

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod delay;
pub mod encoding;
pub mod linalg;
pub mod metrics;
pub mod objectives;
pub mod rng;
pub mod runtime;
pub mod testutil;
