//! The paper's four evaluation objectives (§5): ridge regression, LASSO,
//! logistic regression, and matrix factorization.
//!
//! Each module owns the *original* (uncoded) objective — used both to
//! generate the distributed problem and to report convergence in terms of
//! the original f(w), exactly as the paper's theorems do.

pub mod lasso;
pub mod logistic;
pub mod matfac;
pub mod ridge;

pub use lasso::LassoProblem;
pub use logistic::LogisticProblem;
pub use matfac::MatFacProblem;
pub use ridge::RidgeProblem;

/// A smooth data-parallel objective of the paper's form
/// `f(w) = 1/(2n)·‖Xw − y‖² + λ·h(w)` evaluated on the ORIGINAL data.
pub trait QuadObjective {
    /// f(w) on the original problem.
    fn objective(&self, w: &[f64]) -> f64;
    /// ∇f(w) on the original problem (smooth part + smooth regularizer).
    fn gradient(&self, w: &[f64]) -> Vec<f64>;
    /// Problem dimension p.
    fn dim(&self) -> usize;
    /// Number of data rows n.
    fn rows(&self) -> usize;
}
