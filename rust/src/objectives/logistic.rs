//! ℓ₂-regularized logistic regression (paper §5.3):
//! `f(w) = 1/n·Σᵢ log(1 + exp(−zᵢᵀw)) + λ‖w‖²`, where `zᵢ = yᵢxᵢ`.
//!
//! Under model parallelism this is `φ(Zw) + λ‖w‖²` with
//! `φ(u) = 1/n·Σ log(1+e^{−uᵢ})` — the form used by encoded block
//! coordinate descent (the feature dimension is partitioned).

use crate::linalg::{dot, Csr};

/// Numerically stable `log(1 + e^{−u})`.
#[inline]
pub fn log1p_exp_neg(u: f64) -> f64 {
    if u > 0.0 {
        (-u).exp().ln_1p()
    } else {
        -u + u.exp().ln_1p()
    }
}

/// Stable logistic sigmoid σ(u) = 1/(1+e^{−u}).
#[inline]
pub fn sigmoid(u: f64) -> f64 {
    if u >= 0.0 {
        1.0 / (1.0 + (-u).exp())
    } else {
        let e = u.exp();
        e / (1.0 + e)
    }
}

/// Logistic regression problem. `z` holds the label-scaled samples
/// `zᵢ = yᵢ·xᵢ` as rows (sparse, tf-idf-like).
#[derive(Clone, Debug)]
pub struct LogisticProblem {
    pub z: Csr,
    pub lambda: f64,
}

impl LogisticProblem {
    pub fn new(z: Csr, lambda: f64) -> Self {
        assert!(lambda >= 0.0);
        LogisticProblem { z, lambda }
    }

    pub fn rows(&self) -> usize {
        self.z.rows()
    }

    pub fn dim(&self) -> usize {
        self.z.cols()
    }

    /// f(w) = φ(Zw) + λ‖w‖².
    pub fn objective(&self, w: &[f64]) -> f64 {
        let u = self.z.matvec(w);
        self.phi(&u) + self.lambda * dot(w, w)
    }

    /// φ(u) = 1/n Σ log(1+e^{−uᵢ}).
    pub fn phi(&self, u: &[f64]) -> f64 {
        u.iter().map(|&ui| log1p_exp_neg(ui)).sum::<f64>() / u.len() as f64
    }

    /// ∇φ(u): elementwise `−σ(−uᵢ)/n`.
    pub fn grad_phi(&self, u: &[f64]) -> Vec<f64> {
        let n = u.len() as f64;
        u.iter().map(|&ui| -sigmoid(-ui) / n).collect()
    }

    /// Full gradient ∇f(w) = Zᵀ∇φ(Zw) + 2λw.
    pub fn gradient(&self, w: &[f64]) -> Vec<f64> {
        let u = self.z.matvec(w);
        let gphi = self.grad_phi(&u);
        let mut g = self.z.matvec_t(&gphi);
        crate::linalg::axpy(2.0 * self.lambda, w, &mut g);
        g
    }

    /// Smoothness constant of φ∘Z: `λ_max(ZᵀZ)/(4n) + 2λ`.
    pub fn smoothness(&self) -> f64 {
        // power iteration on ZᵀZ without densifying
        let mut v = vec![1.0; self.dim()];
        let mut lam = 0.0;
        for _ in 0..50 {
            let zv = self.z.matvec(&v);
            let mut ztzv = self.z.matvec_t(&zv);
            let nrm = crate::linalg::norm2(&ztzv);
            if nrm == 0.0 {
                break;
            }
            crate::linalg::scale(1.0 / nrm, &mut ztzv);
            v = ztzv;
            lam = nrm;
        }
        lam / (4.0 * self.rows() as f64) + 2.0 * self.lambda
    }

    /// Classification error rate of w on label-scaled test rows
    /// (an example is correct iff zᵢᵀw > 0).
    pub fn error_rate(&self, w: &[f64], z_test: &Csr) -> f64 {
        let u = z_test.matvec(w);
        u.iter().filter(|&&ui| ui <= 0.0).count() as f64 / u.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rcv1like::generate;

    #[test]
    fn stable_helpers() {
        assert!((log1p_exp_neg(0.0) - (2.0f64).ln()).abs() < 1e-12);
        assert!(log1p_exp_neg(800.0) < 1e-300); // no overflow
        assert!((log1p_exp_neg(-800.0) - 800.0).abs() < 1e-9);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(-800.0) >= 0.0);
        assert!((sigmoid(800.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let ds = generate(40, 12, 4, 0.4, 3);
        let p = LogisticProblem::new(ds.train, 0.01);
        let w: Vec<f64> = (0..12).map(|i| 0.05 * (i as f64) - 0.3).collect();
        let g = p.gradient(&w);
        let eps = 1e-6;
        for i in 0..12 {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let fd = (p.objective(&wp) - p.objective(&wm)) / (2.0 * eps);
            assert!((fd - g[i]).abs() < 1e-5, "coord {i}: {fd} vs {}", g[i]);
        }
    }

    #[test]
    fn objective_convex_along_segment() {
        let ds = generate(30, 8, 3, 0.4, 5);
        let p = LogisticProblem::new(ds.train, 0.1);
        let w0 = vec![0.0; 8];
        let w1 = vec![0.5; 8];
        let mid: Vec<f64> = w0.iter().zip(&w1).map(|(a, b)| 0.5 * (a + b)).collect();
        assert!(p.objective(&mid) <= 0.5 * p.objective(&w0) + 0.5 * p.objective(&w1) + 1e-12);
    }

    #[test]
    fn gradient_descent_reduces_error() {
        let ds = generate(200, 20, 6, 0.05, 7);
        let p = LogisticProblem::new(ds.train, 1e-4);
        let mut w = vec![0.0; 20];
        let step = 1.0 / p.smoothness();
        let initial_err = p.error_rate(&w, &ds.test);
        for _ in 0..200 {
            let g = p.gradient(&w);
            for i in 0..w.len() {
                w[i] -= step * g[i];
            }
        }
        let err = p.error_rate(&w, &ds.test);
        assert!(err < initial_err.min(0.35), "err={err}, initial={initial_err}");
    }

    #[test]
    fn smoothness_positive() {
        let ds = generate(20, 6, 2, 0.4, 9);
        let p = LogisticProblem::new(ds.train, 0.01);
        assert!(p.smoothness() > 0.0);
    }
}
