//! Ridge regression (paper §5.1):
//! `f(w) = 1/(2n)·‖Xw − y‖² + (λ/2)·‖w‖²`.

use super::QuadObjective;
use crate::linalg::{axpy, cholesky_solve, dot, sub, Mat};

/// Ridge regression problem on the original (uncoded) data.
#[derive(Clone, Debug)]
pub struct RidgeProblem {
    pub x: Mat,
    pub y: Vec<f64>,
    pub lambda: f64,
}

impl RidgeProblem {
    pub fn new(x: Mat, y: Vec<f64>, lambda: f64) -> Self {
        assert_eq!(x.rows(), y.len(), "X/y row mismatch");
        assert!(lambda >= 0.0);
        RidgeProblem { x, y, lambda }
    }

    /// Closed-form solution via normal equations
    /// `(XᵀX/n + λI)·w = Xᵀy/n` — ground truth for tests and for the
    /// suboptimality axes of the Figure-7 bench.
    pub fn solve_exact(&self) -> Vec<f64> {
        let n = self.x.rows() as f64;
        let mut g = self.x.gram();
        g.scale_inplace(1.0 / n);
        for i in 0..g.rows() {
            g[(i, i)] += self.lambda;
        }
        let mut aty = self.x.matvec_t(&self.y);
        crate::linalg::scale(1.0 / n, &mut aty);
        cholesky_solve(&g, &aty).expect("ridge normal equations SPD")
    }

    /// Smoothness constant `M/n + λ` of the gradient (M = λ_max(XᵀX)).
    pub fn smoothness(&self) -> f64 {
        self.x.gram_spectral_norm(60, 0x5e) / self.x.rows() as f64 + self.lambda
    }

    /// Mean squared prediction error on held-out data.
    pub fn test_mse(&self, w: &[f64], x_test: &Mat, y_test: &[f64]) -> f64 {
        let r = sub(&x_test.matvec(w), y_test);
        dot(&r, &r) / y_test.len() as f64
    }
}

impl QuadObjective for RidgeProblem {
    fn objective(&self, w: &[f64]) -> f64 {
        let r = sub(&self.x.matvec(w), &self.y);
        dot(&r, &r) / (2.0 * self.x.rows() as f64) + 0.5 * self.lambda * dot(w, w)
    }

    fn gradient(&self, w: &[f64]) -> Vec<f64> {
        let r = sub(&self.x.matvec(w), &self.y);
        let mut g = self.x.matvec_t(&r);
        crate::linalg::scale(1.0 / self.x.rows() as f64, &mut g);
        axpy(self.lambda, w, &mut g);
        g
    }

    fn dim(&self) -> usize {
        self.x.cols()
    }

    fn rows(&self) -> usize {
        self.x.rows()
    }
}

/// Relative suboptimality `(f(w) − f*)/f*` — the y-axis of Figure 7.
pub fn rel_subopt(f_w: f64, f_star: f64) -> f64 {
    (f_w - f_star) / f_star.max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_linear;
    use crate::linalg::norm2;

    fn small_problem() -> RidgeProblem {
        let (x, y, _) = gaussian_linear(40, 8, 0.1, 7);
        RidgeProblem::new(x, y, 0.05)
    }

    #[test]
    fn gradient_vanishes_at_exact_solution() {
        let p = small_problem();
        let w = p.solve_exact();
        let g = p.gradient(&w);
        assert!(norm2(&g) < 1e-10, "‖∇f(w*)‖ = {}", norm2(&g));
    }

    #[test]
    fn exact_solution_minimizes() {
        let p = small_problem();
        let w_star = p.solve_exact();
        let f_star = p.objective(&w_star);
        let mut rng = crate::rng::Pcg64::new(3);
        for _ in 0..20 {
            let w: Vec<f64> = (0..8).map(|_| rng.next_f64() - 0.5).collect();
            assert!(p.objective(&w) >= f_star - 1e-12);
        }
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = small_problem();
        let w: Vec<f64> = (0..8).map(|i| 0.1 * i as f64 - 0.3).collect();
        let g = p.gradient(&w);
        let eps = 1e-6;
        for i in 0..8 {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let fd = (p.objective(&wp) - p.objective(&wm)) / (2.0 * eps);
            assert!((fd - g[i]).abs() < 1e-5, "coord {i}: fd={fd} g={}", g[i]);
        }
    }

    #[test]
    fn smoothness_upper_bounds_gradient_lipschitz() {
        let p = small_problem();
        let m = p.smoothness();
        let mut rng = crate::rng::Pcg64::new(11);
        for _ in 0..10 {
            let w1: Vec<f64> = (0..8).map(|_| rng.next_f64() - 0.5).collect();
            let w2: Vec<f64> = (0..8).map(|_| rng.next_f64() - 0.5).collect();
            let dg = sub(&p.gradient(&w1), &p.gradient(&w2));
            let dw = sub(&w1, &w2);
            assert!(norm2(&dg) <= m * norm2(&dw) * (1.0 + 1e-6));
        }
    }

    #[test]
    fn test_mse_zero_on_clean_fit() {
        // noiseless data: exact solve with tiny λ recovers predictions
        let (x, y, _) = gaussian_linear(60, 5, 0.0, 13);
        let p = RidgeProblem::new(x.clone(), y.clone(), 1e-10);
        let w = p.solve_exact();
        assert!(p.test_mse(&w, &x, &y) < 1e-10);
    }
}
