//! Matrix factorization for recommendation (paper §5.2, MovieLens task):
//!
//! `min Σ_{(i,j) observed} (R_ij − u_i − v_j − x_iᵀy_j − b)² +
//!      λ(Σ‖x_i‖² + ‖u‖² + Σ‖y_j‖² + ‖v‖²)`
//!
//! solved by alternating minimization: fixing movies, each user's
//! `(x_i, u_i)` is an independent regularized least-squares problem
//! (eq. 13) — and vice versa. Each subproblem is handed to a pluggable
//! solver: small instances go to the local Cholesky solver (the paper
//! uses `numpy.linalg.solve` under n = 500), large ones to distributed
//! encoded L-BFGS.

use crate::linalg::{chol::ridge_solve, Mat};
use crate::rng::{Normal, Pcg64};
use crate::rng::dist::Distribution;

/// One observed rating.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Rating {
    pub user: usize,
    pub movie: usize,
    pub value: f64,
}

/// A regularized least-squares subproblem `min ‖A·w − b‖² + λ‖w‖²`
/// extracted from one row/column update.
pub struct Subproblem {
    pub a: Mat,
    pub b: Vec<f64>,
    pub lambda: f64,
}

/// Pluggable subproblem solver (local Cholesky or distributed L-BFGS).
pub trait SubSolver {
    fn solve(&mut self, sub: &Subproblem) -> Vec<f64>;
}

/// The paper's local path: exact solve via normal equations.
pub struct LocalCholesky;

impl SubSolver for LocalCholesky {
    fn solve(&mut self, sub: &Subproblem) -> Vec<f64> {
        ridge_solve(&sub.a, &sub.b, sub.lambda)
    }
}

/// Matrix-factorization model state + ALS driver.
pub struct MatFacProblem {
    pub n_users: usize,
    pub n_movies: usize,
    /// Embedding dimension p.
    pub dim: usize,
    pub lambda: f64,
    /// Global bias b (fixed, as in the paper: b = 3).
    pub bias: f64,
    /// User embeddings (n_users × p) and biases.
    pub x: Mat,
    pub u: Vec<f64>,
    /// Movie embeddings (n_movies × p) and biases.
    pub y: Mat,
    pub v: Vec<f64>,
    /// Observed ratings grouped per user and per movie.
    by_user: Vec<Vec<(usize, f64)>>,
    by_movie: Vec<Vec<(usize, f64)>>,
}

impl MatFacProblem {
    pub fn new(
        ratings: &[Rating],
        n_users: usize,
        n_movies: usize,
        dim: usize,
        lambda: f64,
        bias: f64,
        seed: u64,
    ) -> Self {
        let mut by_user = vec![Vec::new(); n_users];
        let mut by_movie = vec![Vec::new(); n_movies];
        for r in ratings {
            assert!(r.user < n_users && r.movie < n_movies);
            by_user[r.user].push((r.movie, r.value));
            by_movie[r.movie].push((r.user, r.value));
        }
        let mut rng = Pcg64::with_stream(seed, 0x3af);
        let init = Normal::new(0.0, 0.1);
        let x = Mat::from_fn(n_users, dim, |_, _| init.sample(&mut rng));
        let y = Mat::from_fn(n_movies, dim, |_, _| init.sample(&mut rng));
        MatFacProblem {
            n_users,
            n_movies,
            dim,
            lambda,
            bias,
            x,
            u: vec![0.0; n_users],
            y,
            v: vec![0.0; n_movies],
            by_user,
            by_movie,
        }
    }

    /// Predicted rating for (user, movie).
    pub fn predict(&self, user: usize, movie: usize) -> f64 {
        crate::linalg::dot(self.x.row(user), self.y.row(movie))
            + self.u[user]
            + self.v[movie]
            + self.bias
    }

    /// RMSE over a rating set.
    pub fn rmse(&self, ratings: &[Rating]) -> f64 {
        if ratings.is_empty() {
            return 0.0;
        }
        let sse: f64 = ratings
            .iter()
            .map(|r| {
                let e = self.predict(r.user, r.movie) - r.value;
                e * e
            })
            .sum();
        (sse / ratings.len() as f64).sqrt()
    }

    /// The user-side subproblem (eq. 13): design `[y_{I_i} | 1]`, target
    /// `R_{i,I_i} − v_{I_i} − b`. Returns `None` if the user has no
    /// observed ratings.
    pub fn user_subproblem(&self, user: usize) -> Option<Subproblem> {
        let obs = &self.by_user[user];
        if obs.is_empty() {
            return None;
        }
        let rows = obs.len();
        let mut a = Mat::zeros(rows, self.dim + 1);
        let mut b = Vec::with_capacity(rows);
        for (r, &(movie, value)) in obs.iter().enumerate() {
            let arow = a.row_mut(r);
            arow[..self.dim].copy_from_slice(self.y.row(movie));
            arow[self.dim] = 1.0;
            b.push(value - self.v[movie] - self.bias);
        }
        Some(Subproblem { a, b, lambda: self.lambda })
    }

    /// The movie-side subproblem: design `[x_{J_j} | 1]`, target
    /// `R_{J_j,j} − u_{J_j} − b`.
    pub fn movie_subproblem(&self, movie: usize) -> Option<Subproblem> {
        let obs = &self.by_movie[movie];
        if obs.is_empty() {
            return None;
        }
        let rows = obs.len();
        let mut a = Mat::zeros(rows, self.dim + 1);
        let mut b = Vec::with_capacity(rows);
        for (r, &(user, value)) in obs.iter().enumerate() {
            let arow = a.row_mut(r);
            arow[..self.dim].copy_from_slice(self.x.row(user));
            arow[self.dim] = 1.0;
            b.push(value - self.u[user] - self.bias);
        }
        Some(Subproblem { a, b, lambda: self.lambda })
    }

    /// Apply a solved user update.
    pub fn set_user(&mut self, user: usize, w: &[f64]) {
        assert_eq!(w.len(), self.dim + 1);
        self.x.row_mut(user).copy_from_slice(&w[..self.dim]);
        self.u[user] = w[self.dim];
    }

    /// Apply a solved movie update.
    pub fn set_movie(&mut self, movie: usize, w: &[f64]) {
        assert_eq!(w.len(), self.dim + 1);
        self.y.row_mut(movie).copy_from_slice(&w[..self.dim]);
        self.v[movie] = w[self.dim];
    }

    /// One full ALS epoch (users then movies) with the given solver.
    /// Returns the number of subproblems solved.
    pub fn als_epoch(&mut self, solver: &mut dyn SubSolver) -> usize {
        let mut solved = 0;
        for user in 0..self.n_users {
            if let Some(sub) = self.user_subproblem(user) {
                let w = solver.solve(&sub);
                self.set_user(user, &w);
                solved += 1;
            }
        }
        for movie in 0..self.n_movies {
            if let Some(sub) = self.movie_subproblem(movie) {
                let w = solver.solve(&sub);
                self.set_movie(movie, &w);
                solved += 1;
            }
        }
        solved
    }

    /// Regularized training objective (eq. 12).
    pub fn objective(&self, train: &[Rating]) -> f64 {
        let sse: f64 = train
            .iter()
            .map(|r| {
                let e = self.predict(r.user, r.movie) - r.value;
                e * e
            })
            .sum();
        let reg = self.x.fro_norm().powi(2)
            + self.y.fro_norm().powi(2)
            + crate::linalg::dot(&self.u, &self.u)
            + crate::linalg::dot(&self.v, &self.v);
        sse + self.lambda * reg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::movielens::generate;

    #[test]
    fn als_monotonically_decreases_objective() {
        let ds = generate(30, 20, 5, 8, 0.2, 3);
        let mut mf = MatFacProblem::new(&ds.train, 30, 20, 5, 1.0, ds.global_mean, 7);
        let mut solver = LocalCholesky;
        let mut prev = mf.objective(&ds.train);
        for _ in 0..5 {
            mf.als_epoch(&mut solver);
            let cur = mf.objective(&ds.train);
            assert!(cur <= prev + 1e-8, "ALS must descend: {cur} > {prev}");
            prev = cur;
        }
    }

    #[test]
    fn als_improves_test_rmse() {
        let ds = generate(60, 40, 4, 12, 0.1, 5);
        let mut mf = MatFacProblem::new(&ds.train, 60, 40, 4, 0.5, ds.global_mean, 9);
        let before = mf.rmse(&ds.test);
        let mut solver = LocalCholesky;
        for _ in 0..6 {
            mf.als_epoch(&mut solver);
        }
        let after = mf.rmse(&ds.test);
        assert!(after < before, "test RMSE {after} !< {before}");
        assert!(after < 0.8 * before, "expected a solid improvement, got {before}→{after}");
    }

    #[test]
    fn subproblem_shapes() {
        let ratings = vec![
            Rating { user: 0, movie: 0, value: 4.0 },
            Rating { user: 0, movie: 1, value: 2.0 },
            Rating { user: 1, movie: 1, value: 5.0 },
        ];
        let mf = MatFacProblem::new(&ratings, 2, 2, 3, 0.1, 3.0, 1);
        let sub = mf.user_subproblem(0).unwrap();
        assert_eq!(sub.a.rows(), 2);
        assert_eq!(sub.a.cols(), 4); // p + bias column
        assert_eq!(sub.b.len(), 2);
        let sub_m = mf.movie_subproblem(1).unwrap();
        assert_eq!(sub_m.a.rows(), 2);
    }

    #[test]
    fn empty_user_returns_none() {
        let ratings = vec![Rating { user: 0, movie: 0, value: 4.0 }];
        let mf = MatFacProblem::new(&ratings, 2, 1, 3, 0.1, 3.0, 1);
        assert!(mf.user_subproblem(1).is_none());
    }

    #[test]
    fn solved_subproblem_reduces_user_residual() {
        let ds = generate(10, 15, 3, 6, 0.1, 11);
        let mf = MatFacProblem::new(&ds.train, 10, 15, 3, 0.5, ds.global_mean, 3);
        let user = 0;
        let sub = mf.user_subproblem(user).unwrap();
        let resid_before = {
            let mut w = mf.x.row(user).to_vec();
            w.push(mf.u[user]);
            let r = crate::linalg::sub(&sub.a.matvec(&w), &sub.b);
            crate::linalg::dot(&r, &r) + sub.lambda * crate::linalg::dot(&w, &w)
        };
        let w = LocalCholesky.solve(&sub);
        let resid_after = {
            let r = crate::linalg::sub(&sub.a.matvec(&w), &sub.b);
            crate::linalg::dot(&r, &r) + sub.lambda * crate::linalg::dot(&w, &w)
        };
        assert!(resid_after <= resid_before + 1e-12);
    }
}
