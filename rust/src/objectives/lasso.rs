//! LASSO (paper §5.4):
//! `f(w) = 1/(2n)·‖Xw − y‖² + λ·‖w‖₁`, solved by encoded proximal
//! gradient (ISTA). Sparsity-recovery quality is measured by the F1
//! score of the recovered support.

use super::QuadObjective;
use crate::linalg::{dot, soft_threshold, sub, Mat};

/// LASSO problem on the original (uncoded) data.
#[derive(Clone, Debug)]
pub struct LassoProblem {
    pub x: Mat,
    pub y: Vec<f64>,
    pub lambda: f64,
}

impl LassoProblem {
    pub fn new(x: Mat, y: Vec<f64>, lambda: f64) -> Self {
        assert_eq!(x.rows(), y.len());
        assert!(lambda >= 0.0);
        LassoProblem { x, y, lambda }
    }

    /// Full objective (smooth + ℓ₁).
    pub fn objective(&self, w: &[f64]) -> f64 {
        let r = sub(&self.x.matvec(w), &self.y);
        dot(&r, &r) / (2.0 * self.x.rows() as f64)
            + self.lambda * w.iter().map(|v| v.abs()).sum::<f64>()
    }

    /// Gradient of the smooth part only.
    pub fn smooth_gradient(&self, w: &[f64]) -> Vec<f64> {
        let r = sub(&self.x.matvec(w), &self.y);
        let mut g = self.x.matvec_t(&r);
        crate::linalg::scale(1.0 / self.x.rows() as f64, &mut g);
        g
    }

    /// Proximal step: `prox_{αλ‖·‖₁}(w − α·g)` (soft-thresholding).
    pub fn prox_step(&self, w: &[f64], g: &[f64], alpha: f64) -> Vec<f64> {
        w.iter()
            .zip(g)
            .map(|(wi, gi)| soft_threshold(wi - alpha * gi, alpha * self.lambda))
            .collect()
    }

    /// A safe ISTA step size 1/M with M = λ_max(XᵀX)/n.
    pub fn default_step(&self) -> f64 {
        let m = self.x.gram_spectral_norm(60, 0x1a) / self.x.rows() as f64;
        1.0 / m.max(1e-12)
    }

    /// Reference ISTA solution on the uncoded problem (tests / baselines).
    pub fn solve_ista(&self, iters: usize) -> Vec<f64> {
        let alpha = self.default_step();
        let mut w = vec![0.0; self.x.cols()];
        for _ in 0..iters {
            let g = self.smooth_gradient(&w);
            w = self.prox_step(&w, &g, alpha);
        }
        w
    }
}

impl QuadObjective for LassoProblem {
    fn objective(&self, w: &[f64]) -> f64 {
        LassoProblem::objective(self, w)
    }

    fn gradient(&self, w: &[f64]) -> Vec<f64> {
        // smooth part only; the ℓ₁ term is handled by prox.
        self.smooth_gradient(w)
    }

    fn dim(&self) -> usize {
        self.x.cols()
    }

    fn rows(&self) -> usize {
        self.x.rows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::sparse_recovery;
    use crate::metrics::f1_support;

    #[test]
    fn prox_step_soft_thresholds() {
        let p = LassoProblem::new(Mat::eye(2), vec![0.0, 0.0], 1.0);
        let w = vec![2.0, -0.5];
        let g = vec![0.0, 0.0];
        let out = p.prox_step(&w, &g, 0.5); // threshold 0.5
        assert_eq!(out, vec![1.5, 0.0]);
    }

    #[test]
    fn ista_monotone_descent() {
        let (x, y, _) = sparse_recovery(60, 30, 5, 0.5, 3);
        let p = LassoProblem::new(x, y, 0.1);
        let alpha = p.default_step();
        let mut w = vec![0.0; 30];
        let mut prev = p.objective(&w);
        for _ in 0..50 {
            let g = p.smooth_gradient(&w);
            w = p.prox_step(&w, &g, alpha);
            let cur = p.objective(&w);
            assert!(cur <= prev + 1e-12, "ISTA must descend: {cur} > {prev}");
            prev = cur;
        }
    }

    #[test]
    fn ista_recovers_support_in_easy_regime() {
        // well-conditioned, low-noise: support recovery should be near
        // perfect with a suitable λ.
        let (x, y, w_star) = sparse_recovery(200, 50, 5, 0.05, 7);
        let p = LassoProblem::new(x, y, 0.05);
        let w = p.solve_ista(300);
        let (_, _, f1) = f1_support(&w_star, &w, 1e-2);
        assert!(f1 > 0.85, "f1={f1}");
    }

    #[test]
    fn lambda_zero_reduces_to_least_squares_grad() {
        let (x, y, _) = sparse_recovery(30, 10, 3, 0.1, 9);
        let p = LassoProblem::new(x.clone(), y.clone(), 0.0);
        let w = vec![0.1; 10];
        let g = p.smooth_gradient(&w);
        // matches ridge gradient with λ=0
        let ridge = crate::objectives::RidgeProblem::new(x, y, 0.0);
        use crate::objectives::QuadObjective;
        let g2 = ridge.gradient(&w);
        crate::testutil::assert_allclose(&g, &g2, 1e-12, "grad");
    }

    #[test]
    fn objective_includes_l1_term() {
        let p = LassoProblem::new(Mat::eye(2), vec![0.0, 0.0], 2.0);
        let w = vec![1.0, -1.0];
        // 1/(2·2)·(1+1) + 2·2 = 0.5 + 4
        assert!((LassoProblem::objective(&p, &w) - 4.5).abs() < 1e-12);
    }
}
