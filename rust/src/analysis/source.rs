//! Lexical preprocessing for the determinism-contract lint.
//!
//! The scanner is deliberately *not* a Rust parser: the invariants it
//! checks are token-shaped (`partial_cmp` in a sort position,
//! `Instant::now` outside a wall-clock zone, a bare `unsafe`), so a
//! line classifier that strips comments and blanks string/char literal
//! *contents* is exactly enough — and it keeps the pass dependency-free
//! and fast. What the classifier must get right:
//!
//! - line (`//`) and nested block (`/* */`) comments, so a token inside
//!   prose never counts as code;
//! - string literals (including raw `r#"…"#` and multi-line strings),
//!   so the scanner can mention its own forbidden tokens in messages
//!   without flagging itself;
//! - char literals vs lifetimes (`'{'` must not leak a brace into the
//!   brace-depth tracking; `'a` must not swallow the rest of the line);
//! - `#[cfg(test)]` regions, tracked by brace depth over the stripped
//!   code, so rules scoped to library code skip test modules.

use std::path::{Path, PathBuf};

/// One source line after classification.
#[derive(Clone, Debug)]
pub struct SourceLine {
    /// 1-based line number.
    pub number: usize,
    /// Code with comments removed and string/char contents blanked.
    pub code: String,
    /// Text of a `//` comment on this line (marker stripped), if any.
    pub comment: String,
    /// The comment was a doc comment (`///` or `//!`). Doc comments are
    /// never parsed for `lint:allow` directives, so docs can quote the
    /// directive syntax freely.
    pub is_doc: bool,
    /// The line sits inside a `#[cfg(test)]` item.
    pub in_test: bool,
}

/// Lexer state carried across lines.
enum St {
    Code,
    /// Nested block comment at the given depth.
    Block(usize),
    /// Inside a normal string literal.
    Str,
    /// Inside a raw string literal closed by `"` + this many `#`.
    RawStr(usize),
}

/// Classify a whole file into [`SourceLine`]s.
pub fn classify(text: &str) -> Vec<SourceLine> {
    let mut st = St::Code;
    let mut out: Vec<SourceLine> = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::new();
        let mut comment = String::new();
        let mut is_doc = false;
        let mut i = 0usize;
        while i < chars.len() {
            match st {
                St::Block(depth) => {
                    if chars[i] == '*' && i + 1 < chars.len() && chars[i + 1] == '/' {
                        st = if depth <= 1 { St::Code } else { St::Block(depth - 1) };
                        i += 2;
                    } else if chars[i] == '/' && i + 1 < chars.len() && chars[i + 1] == '*' {
                        st = St::Block(depth + 1);
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                St::Str => {
                    if chars[i] == '\\' {
                        i += 2; // escape: skip the escaped char (may run past EOL)
                    } else if chars[i] == '"' {
                        code.push('"');
                        st = St::Code;
                        i += 1;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                St::RawStr(hashes) => {
                    if chars[i] == '"' && closes_raw(&chars, i, hashes) {
                        code.push('"');
                        st = St::Code;
                        i += 1 + hashes;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                St::Code => {
                    let c = chars[i];
                    let next = chars.get(i + 1).copied();
                    if c == '/' && next == Some('/') {
                        let rest: String = chars[i..].iter().collect();
                        is_doc = rest.starts_with("///") || rest.starts_with("//!");
                        let skip = if is_doc { 3 } else { 2 };
                        comment = rest.chars().skip(skip).collect::<String>().trim().to_string();
                        break; // rest of the line is comment
                    }
                    if c == '/' && next == Some('*') {
                        st = St::Block(1);
                        i += 2;
                        continue;
                    }
                    if c == '"' {
                        code.push('"');
                        st = St::Str;
                        i += 1;
                        continue;
                    }
                    // raw string start: r"…", r#"…"#, br"…" — only when
                    // the `r` is not the tail of an identifier (`for`).
                    if (c == 'r' || c == 'b') && !prev_is_ident(&code) {
                        if let Some((hashes, consumed)) = raw_string_open(&chars, i) {
                            code.push('"');
                            st = St::RawStr(hashes);
                            i += consumed;
                            continue;
                        }
                    }
                    if c == '\'' {
                        // char literal vs lifetime
                        if next == Some('\\') {
                            // escaped char literal: skip to the closing quote
                            let mut j = i + 3; // past ' \ and the escape head
                            while j < chars.len() && chars[j] != '\'' {
                                j += 1;
                            }
                            code.push(' ');
                            i = j + 1;
                        } else if i + 2 < chars.len() && chars[i + 2] == '\'' {
                            code.push(' '); // plain char literal 'x'
                            i += 3;
                        } else {
                            code.push('\''); // lifetime marker
                            i += 1;
                        }
                        continue;
                    }
                    code.push(c);
                    i += 1;
                }
            }
        }
        out.push(SourceLine { number: idx + 1, code, comment, is_doc, in_test: false });
    }
    mark_test_regions(&mut out);
    out
}

/// Does the `"` at `chars[i]` (inside a raw string) close it, i.e. is it
/// followed by `hashes` consecutive `#`?
fn closes_raw(chars: &[char], i: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| chars.get(i + k) == Some(&'#'))
}

/// If `chars[i..]` opens a raw string (`r"`, `r#"`, `br##"`, …), return
/// `(hash_count, chars_consumed_including_quote)`.
fn raw_string_open(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
        if chars.get(j) != Some(&'r') {
            return None;
        }
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

fn prev_is_ident(code: &str) -> bool {
    code.chars().last().is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Mark lines inside `#[cfg(test)]` items by tracking brace depth over
/// the stripped code (string braces are already blanked).
fn mark_test_regions(lines: &mut [SourceLine]) {
    let mut pending = false;
    let mut in_region = false;
    let mut depth: i64 = 0;
    for line in lines.iter_mut() {
        if in_region {
            line.in_test = true;
            depth += brace_delta(&line.code);
            if depth <= 0 {
                in_region = false;
            }
            continue;
        }
        if line.code.contains("cfg(test") {
            line.in_test = true;
            pending = true;
            continue;
        }
        if pending {
            line.in_test = true;
            let opens = line.code.matches('{').count() as i64;
            if opens > 0 {
                depth = brace_delta(&line.code);
                pending = false;
                in_region = depth > 0;
            } else if line.code.contains(';') {
                pending = false; // brace-less cfg'd item (`mod tests;`, `use …;`)
            }
            // otherwise: still between the attribute and its item header
        }
    }
}

fn brace_delta(code: &str) -> i64 {
    code.matches('{').count() as i64 - code.matches('}').count() as i64
}

/// Word-boundary token search over stripped code. Tokens are ASCII; a
/// match is rejected when butted against identifier characters (so
/// `check_partial_cmp` does not match `partial_cmp`).
pub fn find_token(code: &str, tok: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(tok) {
        let i = start + pos;
        let j = i + tok.len();
        let before_ok = i == 0 || !is_ident_byte(bytes[i - 1]);
        let after_ok = j >= bytes.len() || !is_ident_byte(bytes[j]);
        if before_ok && after_ok {
            return Some(i);
        }
        start = j; // tokens don't self-overlap; j is a char boundary
    }
    None
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// All `.rs` files under `root`, recursively, in a deterministic
/// (sorted-path) order — the lint's own output obeys the
/// ordered-iteration contract it enforces.
pub fn rs_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(text: &str) -> Vec<String> {
        classify(text).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_stripped_and_captured() {
        let lines = classify("let x = 1; // trailing note\n// full line\n");
        assert_eq!(lines[0].code.trim_end(), "let x = 1;");
        assert_eq!(lines[0].comment, "trailing note");
        assert_eq!(lines[1].code, "");
        assert_eq!(lines[1].comment, "full line");
        assert!(!lines[0].is_doc);
    }

    #[test]
    fn doc_comments_flagged() {
        let lines = classify("/// docs here\n//! inner docs\n");
        assert!(lines[0].is_doc && lines[1].is_doc);
        assert_eq!(lines[0].comment, "docs here");
    }

    #[test]
    fn string_contents_blanked() {
        let c = codes("let s = \"Instant::now HashMap\";\n");
        assert!(!c[0].contains("Instant"), "{:?}", c[0]);
        assert!(c[0].contains("let s ="));
    }

    #[test]
    fn escaped_quote_does_not_close_string() {
        let c = codes("let s = \"a\\\"b unsafe\"; let t = 1;\n");
        assert!(!c[0].contains("unsafe"));
        assert!(c[0].contains("let t = 1;"));
    }

    #[test]
    fn multiline_string_spans_lines() {
        let c = codes("let s = \"first\nsecond unsafe\nend\"; let z = 2;\n");
        assert!(!c[1].contains("unsafe"));
        assert!(c[2].contains("let z = 2;"));
    }

    #[test]
    fn raw_strings_blanked() {
        let c = codes("let s = r#\"partial_cmp \"quoted\" inside\"#; let u = 3;\n");
        assert!(!c[0].contains("partial_cmp"), "{:?}", c[0]);
        assert!(c[0].contains("let u = 3;"));
    }

    #[test]
    fn nested_block_comments() {
        let c = codes("a; /* one /* two */ still comment */ b;\n");
        assert!(c[0].contains("a;") && c[0].contains("b;"));
        assert!(!c[0].contains("still"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let c = codes("if c == '{' { x::<'a>(); let q = '\\n'; }\n");
        // the literal brace is blanked; the real braces survive
        assert_eq!(c[0].matches('{').count(), 1, "{:?}", c[0]);
        assert!(c[0].contains("<'a>"));
    }

    #[test]
    fn cfg_test_region_tracked() {
        let text = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() {\n        x();\n    }\n}\nfn after() {}\n";
        let lines = classify(text);
        assert!(!lines[0].in_test);
        assert!(lines[1].in_test && lines[2].in_test && lines[4].in_test);
        assert!(lines[6].in_test, "closing brace still inside");
        assert!(!lines[7].in_test, "region ends after the brace closes");
    }

    #[test]
    fn cfg_test_braceless_item_does_not_leak() {
        let lines = classify("#[cfg(test)]\nmod tests;\nfn real() {\n}\n");
        assert!(lines[1].in_test);
        assert!(!lines[2].in_test, "next item is library code");
    }

    #[test]
    fn token_word_boundaries() {
        assert!(find_token("a.partial_cmp(b)", "partial_cmp").is_some());
        assert!(find_token("check_partial_cmp(b)", "partial_cmp").is_none());
        assert!(find_token("partial_cmp_all()", "partial_cmp").is_none());
        assert!(find_token("Instant::now()", "Instant::now").is_some());
    }

    #[test]
    fn rs_files_sorted() {
        let dir = std::env::temp_dir().join("coded_opt_lint_walk_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("b")).unwrap();
        std::fs::write(dir.join("b/z.rs"), "").unwrap();
        std::fs::write(dir.join("a.rs"), "").unwrap();
        std::fs::write(dir.join("skip.txt"), "").unwrap();
        let files = rs_files(&dir).unwrap();
        let names: Vec<String> =
            files.iter().map(|p| p.strip_prefix(&dir).unwrap().display().to_string()).collect();
        assert_eq!(names, vec!["a.rs".to_string(), "b/z.rs".to_string()]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
