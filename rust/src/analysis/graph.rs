//! Module-dependency-graph extraction and the graph-aware
//! architecture rules (`layer-order`, `zone-containment`).
//!
//! The line rules in [`super::rules`] catch forbidden *tokens*; this
//! module catches forbidden *edges*. It rebuilds the crate's module
//! DAG from the classified-line representation — `use` statements
//! (with brace expansion), `mod child;` declarations, and qualified
//! expression paths (`crate::…`, `super::…`, or a path whose first
//! segment names a known module) — still std-only, no parser
//! dependency. `#[cfg(test)]` regions contribute no edges, so the
//! graph describes what ships, not what the tests reach for.
//!
//! Resolution is deliberately conservative: a path contributes an edge
//! only when some prefix of it names a module that exists as a file in
//! the scanned tree (deepest such prefix wins). Paths into `std`,
//! external crates, or plain types therefore resolve to nothing. This
//! can *miss* edges (an expression `stream::f()` after
//! `use crate::encoding::stream` resolves through the `use`, not the
//! expression) but does not invent them — the right bias for a gate.
//!
//! The extracted graph is also an artifact: [`ModuleGraph::to_json`]
//! emits schema `coded-opt/modgraph-v1` with line numbers deliberately
//! omitted and edges deduplicated, so the committed `module-graph.json`
//! only changes when the architecture actually changes (see the CI
//! graph-drift gate).

use crate::analysis::rules::{self, Finding};
use crate::analysis::source::SourceLine;
use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;

/// The declared layering DAG, as `(top-level module, rank)`. An edge
/// from a ranked module to a *higher*-ranked one (an upward import) is
/// a `layer-order` finding; same-rank and downward edges are legal.
/// Unlisted modules (rng, metrics, objectives, delay, config, runtime,
/// bench, testutil, …) are shared leaves/utilities and unconstrained —
/// except `analysis`, which must not import any other crate module.
pub const LAYER_RANKS: &[(&str, u8)] = &[
    ("linalg", 0),
    ("encoding", 1),
    ("data", 1),
    ("coordinator", 2),
    ("cluster", 2),
    ("scenario", 2),
    ("control", 3),
    ("driver", 4),
    ("cli", 5),
    ("main", 5),
];

/// One module reference occurrence (an edge plus where it was seen).
#[derive(Clone, Debug)]
pub struct EdgeOcc {
    pub from: String,
    pub to: String,
    /// File (relative, `/`-separated) the reference sits in.
    pub file: String,
    /// Line of the reference (start line for a multi-line `use`).
    pub line: usize,
}

/// The crate's module dependency graph.
#[derive(Clone, Debug, Default)]
pub struct ModuleGraph {
    /// module name → defining file, both `/`-separated relative paths.
    pub modules: BTreeMap<String, String>,
    /// Every reference occurrence, in (sorted-file, line) scan order.
    pub occurrences: Vec<EdgeOcc>,
}

impl ModuleGraph {
    /// Deduplicated edge set, sorted by (from, to).
    pub fn edges(&self) -> BTreeSet<(String, String)> {
        self.occurrences.iter().map(|o| (o.from.clone(), o.to.clone())).collect()
    }

    /// Machine-readable module DAG (schema `coded-opt/modgraph-v1`).
    ///
    /// Line numbers and per-occurrence data are deliberately excluded:
    /// the committed artifact must only drift when an edge or module
    /// appears or disappears, not when code moves within a file.
    pub fn to_json(&self) -> String {
        let edges = self.edges();
        let mut s = String::from("{\n  \"schema\": \"coded-opt/modgraph-v1\",\n");
        let _ = writeln!(s, "  \"module_count\": {},", self.modules.len());
        let _ = writeln!(s, "  \"edge_count\": {},", edges.len());
        s.push_str("  \"modules\": [");
        for (i, (name, file)) in self.modules.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "\n    {{\"name\": \"{name}\", \"file\": \"{file}\"");
            if let Some(rank) = layer_rank(name) {
                let _ = write!(s, ", \"layer\": {rank}");
            }
            if let Some(kind) = zone_of(name) {
                let _ = write!(s, ", \"zone\": \"{kind}\"");
            }
            s.push('}');
        }
        s.push_str(if self.modules.is_empty() { "],\n" } else { "\n  ],\n" });
        s.push_str("  \"edges\": [");
        for (i, (from, to)) in edges.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "\n    {{\"from\": \"{from}\", \"to\": \"{to}\"}}");
        }
        s.push_str(if edges.is_empty() { "]\n" } else { "\n  ]\n" });
        s.push_str("}\n");
        s
    }
}

/// Module a source file defines: `lib.rs` → `crate`, `main.rs` →
/// `main`, `foo/mod.rs` → `foo`, `foo/bar.rs` → `foo::bar`.
pub fn module_of(rel: &str) -> Option<String> {
    let stem = rel.strip_suffix(".rs")?;
    if stem == "lib" {
        return Some("crate".to_string());
    }
    let mut parts: Vec<&str> = stem.split('/').filter(|p| !p.is_empty()).collect();
    if parts.last() == Some(&"mod") {
        parts.pop();
    }
    if parts.is_empty() {
        return Some("crate".to_string());
    }
    Some(parts.join("::"))
}

/// Layer rank of a module, from its top-level segment.
pub fn layer_rank(module: &str) -> Option<u8> {
    let top = module.split("::").next().unwrap_or(module);
    LAYER_RANKS.iter().find(|(m, _)| *m == top).map(|(_, r)| *r)
}

/// Zone kind of a module (`wall-clock` / `unsafe`), derived from the
/// file-level zone lists in [`rules`] so the two views cannot drift.
pub fn zone_of(module: &str) -> Option<&'static str> {
    let hit = |zones: &[&str]| {
        zones.iter().any(|z| {
            let m = z.trim_end_matches(".rs").trim_end_matches('/').replace('/', "::");
            module == m || module.starts_with(&format!("{m}::"))
        })
    };
    if hit(rules::WALL_CLOCK_ZONES) {
        Some("wall-clock")
    } else if hit(rules::UNSAFE_ZONES) {
        Some("unsafe")
    } else {
        None
    }
}

/// Build the module graph over classified files (as produced by
/// [`super::lint_path`]: relative `/`-separated paths, sorted).
pub fn build(files: &[(String, Vec<SourceLine>)]) -> ModuleGraph {
    let mut modules = BTreeMap::new();
    for (rel, _) in files {
        if let Some(m) = module_of(rel) {
            modules.insert(m, rel.clone());
        }
    }
    let known: BTreeSet<String> = modules.keys().cloned().collect();
    let mut occurrences = Vec::new();
    for (rel, lines) in files {
        let Some(cm) = module_of(rel) else { continue };
        extract_file(rel, &cm, lines, &known, &mut occurrences);
    }
    ModuleGraph { modules, occurrences }
}

/// Graph-aware rule pass: `layer-order` and `zone-containment` over the
/// edge occurrences. One finding per (file, offending target), anchored
/// at the first occurrence — repeated references to an already-reported
/// target are the same architectural fact, not new findings.
pub fn check(graph: &ModuleGraph) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut seen_layer: BTreeSet<(String, String)> = BTreeSet::new();
    let mut seen_zone: BTreeSet<(String, String)> = BTreeSet::new();
    for occ in &graph.occurrences {
        let top_from = occ.from.split("::").next().unwrap_or(&occ.from).to_string();
        let top_to = occ.to.split("::").next().unwrap_or(&occ.to).to_string();

        // layer-order: analysis isolation, then upward rank edges.
        if top_from != top_to {
            let msg = if top_from == "analysis" {
                Some(format!(
                    "`{}` imports `{}`; analysis/ must not depend on any other crate module",
                    occ.from, occ.to
                ))
            } else {
                match (layer_rank(&occ.from), layer_rank(&occ.to)) {
                    (Some(rf), Some(rt)) if rf < rt => Some(format!(
                        "`{}` (layer {rf}) imports `{}` (layer {rt}); the layering DAG \
                         forbids upward imports",
                        occ.from, occ.to
                    )),
                    _ => None,
                }
            };
            if let Some(message) = msg {
                if seen_layer.insert((occ.file.clone(), top_to.clone())) {
                    out.push(Finding {
                        file: occ.file.clone(),
                        line: occ.line,
                        rule: "layer-order".to_string(),
                        message,
                    });
                }
            }
        }

        // zone-containment: trace-affecting module importing a zone.
        if let Some(kind) = zone_of(&occ.to) {
            let src_in_zone = rules::is_zone(&occ.file, rules::WALL_CLOCK_ZONES)
                || rules::in_prefix(&occ.file, rules::UNSAFE_ZONES);
            let src_traces = rules::in_prefix(&occ.file, rules::TRACE_MODULES);
            if src_traces && !src_in_zone && !is_parent(&occ.from, &occ.to) {
                if seen_zone.insert((occ.file.clone(), occ.to.clone())) {
                    out.push(Finding {
                        file: occ.file.clone(),
                        line: occ.line,
                        rule: "zone-containment".to_string(),
                        message: format!(
                            "trace-affecting `{}` imports {kind} zone `{}`; zones must \
                             stay leaf-contained",
                            occ.from, occ.to
                        ),
                    });
                }
            }
        }
    }
    out
}

/// Is `from` the direct parent module of `to`? A parent declaring
/// (`mod x;`) or re-exporting its own zone submodule is containment,
/// not a leak.
fn is_parent(from: &str, to: &str) -> bool {
    if from == "crate" {
        return !to.contains("::");
    }
    to.strip_prefix(from)
        .and_then(|r| r.strip_prefix("::"))
        .is_some_and(|r| !r.contains("::"))
}

fn extract_file(
    rel: &str,
    cm: &str,
    lines: &[SourceLine],
    known: &BTreeSet<String>,
    out: &mut Vec<EdgeOcc>,
) {
    let mut pending_use: Option<(usize, String)> = None;
    for line in lines {
        if line.in_test {
            continue;
        }
        let code = line.code.trim();
        if code.is_empty() {
            continue;
        }
        if let Some((start, mut buf)) = pending_use.take() {
            buf.push(' ');
            buf.push_str(code);
            if code.contains(';') {
                use_edges(rel, cm, start, &buf, known, out);
            } else {
                pending_use = Some((start, buf));
            }
            continue;
        }
        let decl = strip_visibility(code);
        if decl == "use" || decl.starts_with("use ") || decl.starts_with("use{") {
            if decl.contains(';') {
                use_edges(rel, cm, line.number, decl, known, out);
            } else {
                pending_use = Some((line.number, decl.to_string()));
            }
            continue;
        }
        if let Some(child) = mod_decl(decl) {
            let target =
                if cm == "crate" { child.to_string() } else { format!("{cm}::{child}") };
            if known.contains(&target) && target != cm {
                out.push(EdgeOcc {
                    from: cm.to_string(),
                    to: target,
                    file: rel.to_string(),
                    line: line.number,
                });
            }
            continue;
        }
        for segs in path_chains(code) {
            if let Some(to) = resolve(cm, &segs, known, false) {
                if to != cm {
                    out.push(EdgeOcc {
                        from: cm.to_string(),
                        to,
                        file: rel.to_string(),
                        line: line.number,
                    });
                }
            }
        }
    }
}

/// Strip a leading `pub` / `pub(crate)` / `pub(in …)` visibility.
fn strip_visibility(code: &str) -> &str {
    let Some(rest) = code.strip_prefix("pub") else { return code };
    if !rest.starts_with([' ', '\t', '(']) {
        return code; // an identifier that merely starts with `pub`
    }
    let rest = rest.trim_start();
    if let Some(inner) = rest.strip_prefix('(') {
        match inner.find(')') {
            Some(close) => inner[close + 1..].trim_start(),
            None => code,
        }
    } else {
        rest
    }
}

/// Parse a `mod child;` declaration (inline `mod child {` bodies are
/// walked as ordinary lines; a child without its own file is unknown
/// and contributes nothing).
fn mod_decl(decl: &str) -> Option<&str> {
    let rest = decl.strip_prefix("mod ")?;
    let end = rest.find(';')?;
    let name = rest[..end].trim();
    let ident = !name.is_empty()
        && name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_')
        && !name.as_bytes()[0].is_ascii_digit();
    ident.then_some(name)
}

/// Expand one complete `use …;` statement into edges.
fn use_edges(
    rel: &str,
    cm: &str,
    line: usize,
    stmt: &str,
    known: &BTreeSet<String>,
    out: &mut Vec<EdgeOcc>,
) {
    let body = stmt
        .trim_start_matches("use")
        .trim()
        .split(';')
        .next()
        .unwrap_or("")
        .trim();
    let mut paths = Vec::new();
    expand_use_tree(body, &mut paths);
    let mut seen = BTreeSet::new();
    for path in paths {
        let segs: Vec<String> =
            path.split("::").map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
        if segs.is_empty() {
            continue;
        }
        if let Some(to) = resolve(cm, &segs, known, true) {
            if to != cm && seen.insert(to.clone()) {
                out.push(EdgeOcc {
                    from: cm.to_string(),
                    to,
                    file: rel.to_string(),
                    line,
                });
            }
        }
    }
}

/// Recursively expand a use-tree (`a::{b, c::{d}, self}`) into plain
/// paths. `self` and `*` leaves resolve to the prefix; ` as` renames
/// are dropped.
fn expand_use_tree(tree: &str, out: &mut Vec<String>) {
    let tree = tree.trim();
    if let Some(open) = tree.find('{') {
        let prefix = tree[..open].trim().trim_end_matches("::").trim();
        let close = tree.rfind('}').unwrap_or(tree.len());
        let inner = &tree[open + 1..close];
        for item in split_top_commas(inner) {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            if item.contains('{') {
                let joined = if prefix.is_empty() {
                    item.to_string()
                } else {
                    format!("{prefix}::{item}")
                };
                expand_use_tree(&joined, out);
            } else {
                push_leaf(prefix, item, out);
            }
        }
    } else {
        push_leaf("", tree, out);
    }
}

fn push_leaf(prefix: &str, item: &str, out: &mut Vec<String>) {
    let base = item.split(" as ").next().unwrap_or(item).trim();
    let base = base.trim_end_matches('*').trim_end_matches("::").trim();
    let path = if base.is_empty() || base == "self" {
        prefix.to_string()
    } else if prefix.is_empty() {
        base.to_string()
    } else {
        format!("{prefix}::{base}")
    };
    let path = path.trim_end_matches("::self").trim_end_matches("::").trim();
    if !path.is_empty() {
        out.push(path.to_string());
    }
}

/// Split on commas at brace depth 0.
fn split_top_commas(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let (mut depth, mut start) = (0usize, 0usize);
    for (i, b) in s.bytes().enumerate() {
        match b {
            b'{' => depth += 1,
            b'}' => depth = depth.saturating_sub(1),
            b',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

/// All `ident(::ident)+` chains in a code line, left to right.
fn path_chains(code: &str) -> Vec<Vec<String>> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        if ident_start(b[i]) && (i == 0 || !ident_byte(b[i - 1])) {
            let mut segs = Vec::new();
            let mut j = i;
            loop {
                let s = j;
                while j < b.len() && ident_byte(b[j]) {
                    j += 1;
                }
                segs.push(code[s..j].to_string());
                if j + 2 < b.len() && b[j] == b':' && b[j + 1] == b':' && ident_start(b[j + 2]) {
                    j += 2;
                } else {
                    break;
                }
            }
            if segs.len() >= 2 {
                out.push(segs);
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

fn ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Resolve a path to the deepest known module it references, from
/// module `cm`. `is_use` follows Rust-2018 `use` semantics (an
/// unanchored first segment is current-module-relative only); in
/// expressions an unanchored first segment may also name a top-level
/// module brought into scope.
fn resolve(cm: &str, segs: &[String], known: &BTreeSet<String>, is_use: bool) -> Option<String> {
    let cm_parts: Vec<&str> =
        if cm == "crate" { Vec::new() } else { cm.split("::").collect() };
    match segs[0].as_str() {
        "crate" | "coded_opt" => {
            let rest: Vec<&str> = segs[1..].iter().map(String::as_str).collect();
            deepest(&rest, 1, known)
        }
        "self" => {
            let mut parts = cm_parts;
            parts.extend(segs[1..].iter().map(String::as_str));
            deepest(&parts, 1, known)
        }
        "super" => {
            let mut parts = cm_parts;
            let mut k = 0;
            while k < segs.len() && segs[k] == "super" {
                if parts.pop().is_none() {
                    return None; // `super` above the crate root
                }
                k += 1;
            }
            parts.extend(segs[k..].iter().map(String::as_str));
            if parts.is_empty() {
                return None;
            }
            deepest(&parts, 1, known)
        }
        _ => {
            // Current-module-relative (uniform path)…
            let mut parts = cm_parts.clone();
            parts.extend(segs.iter().map(String::as_str));
            if let Some(hit) = deepest(&parts, cm_parts.len() + 1, known) {
                return Some(hit);
            }
            // …else, in expressions, a top-level module in scope.
            if !is_use {
                let parts: Vec<&str> = segs.iter().map(String::as_str).collect();
                return deepest(&parts, 1, known);
            }
            None
        }
    }
}

/// Longest known-module prefix of `parts` with at least `min_len`
/// segments.
fn deepest(parts: &[&str], min_len: usize, known: &BTreeSet<String>) -> Option<String> {
    for len in (min_len..=parts.len()).rev() {
        let name = parts[..len].join("::");
        if known.contains(&name) {
            return Some(name);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::source::classify;

    fn graph_of(files: &[(&str, &str)]) -> ModuleGraph {
        let classified: Vec<(String, Vec<_>)> =
            files.iter().map(|(rel, text)| (rel.to_string(), classify(text))).collect();
        build(&classified)
    }

    #[test]
    fn module_identity_from_paths() {
        assert_eq!(module_of("lib.rs").as_deref(), Some("crate"));
        assert_eq!(module_of("main.rs").as_deref(), Some("main"));
        assert_eq!(module_of("bench.rs").as_deref(), Some("bench"));
        assert_eq!(module_of("cluster/mod.rs").as_deref(), Some("cluster"));
        assert_eq!(module_of("cluster/socket.rs").as_deref(), Some("cluster::socket"));
        assert_eq!(module_of("notes.txt"), None);
    }

    #[test]
    fn use_statements_make_edges_with_brace_expansion() {
        let g = graph_of(&[
            ("driver/mod.rs", "use crate::cluster::{sim::SimCluster, wire};\n"),
            ("cluster/mod.rs", "pub mod sim;\npub mod wire;\n"),
            ("cluster/sim.rs", ""),
            ("cluster/wire.rs", ""),
        ]);
        let e = g.edges();
        assert!(e.contains(&("driver".into(), "cluster::sim".into())), "{e:?}");
        assert!(e.contains(&("driver".into(), "cluster::wire".into())), "{e:?}");
        assert!(e.contains(&("cluster".into(), "cluster::sim".into())), "{e:?}");
    }

    #[test]
    fn uniform_path_use_resolves_to_sibling_child() {
        let g = graph_of(&[
            ("cluster/mod.rs", "pub use sim::SimCluster;\n"),
            ("cluster/sim.rs", ""),
        ]);
        assert!(g.edges().contains(&("cluster".into(), "cluster::sim".into())));
    }

    #[test]
    fn qualified_expression_paths_resolve() {
        let g = graph_of(&[
            ("linalg/mod.rs", ""),
            ("linalg/simd.rs", ""),
            ("linalg/fwht.rs", "fn f(x: &mut [f64]) { crate::linalg::simd::butterfly(x); }\n"),
            ("coordinator/mod.rs", "fn g() { let _ = super::runtime::thing(); }\n"),
            ("runtime/mod.rs", ""),
        ]);
        let e = g.edges();
        assert!(e.contains(&("linalg::fwht".into(), "linalg::simd".into())), "{e:?}");
        assert!(e.contains(&("coordinator".into(), "runtime".into())), "{e:?}");
    }

    #[test]
    fn std_and_unknown_paths_make_no_edges() {
        let g = graph_of(&[(
            "metrics/mod.rs",
            "use std::collections::BTreeMap;\nfn f() { let _ = f64::NAN.is_nan(); }\n",
        )]);
        assert!(g.edges().is_empty(), "{:?}", g.edges());
    }

    #[test]
    fn test_regions_contribute_no_edges() {
        let g = graph_of(&[
            ("encoding/mod.rs", "#[cfg(test)]\nmod tests {\n    use crate::driver::Gd;\n}\n"),
            ("driver/mod.rs", ""),
        ]);
        assert!(g.edges().is_empty(), "{:?}", g.edges());
    }

    #[test]
    fn multi_line_use_anchors_at_start_line() {
        let g = graph_of(&[
            ("driver/mod.rs", "use crate::coordinator::{\n    Round,\n    State,\n};\n"),
            ("coordinator/mod.rs", ""),
        ]);
        assert_eq!(g.occurrences.len(), 1);
        assert_eq!(g.occurrences[0].line, 1);
        assert_eq!(g.occurrences[0].to, "coordinator");
    }

    #[test]
    fn layer_order_flags_upward_imports_once_per_file() {
        let g = graph_of(&[
            ("coordinator/mf.rs", "use crate::driver::Experiment;\nfn f() { crate::driver::go(); }\n"),
            ("coordinator/mod.rs", "mod mf;\n"),
            ("driver/mod.rs", "use crate::coordinator::Round;\n"),
        ]);
        let f = check(&g);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "layer-order");
        assert_eq!((f[0].file.as_str(), f[0].line), ("coordinator/mf.rs", 1));
    }

    #[test]
    fn analysis_must_import_nothing() {
        let g = graph_of(&[
            ("analysis/mod.rs", "use crate::linalg::Mat;\n"),
            ("linalg/mod.rs", ""),
        ]);
        let f = check(&g);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "layer-order");
        assert!(f[0].message.contains("analysis/"), "{f:?}");
    }

    #[test]
    fn zone_containment_flags_trace_imports_but_exempts_parents() {
        let g = graph_of(&[
            ("coordinator/mod.rs", "use crate::runtime::GradExecutor;\n"),
            ("cluster/mod.rs", "pub mod socket;\npub use socket::SocketCluster;\n"),
            ("cluster/socket.rs", "use crate::cluster::wire::Frame;\n"),
            ("cluster/wire.rs", ""),
            ("runtime/mod.rs", ""),
            ("main.rs", "use coded_opt::runtime::ArtifactIndex;\n"),
        ]);
        let f = check(&g);
        // coordinator→runtime is the only finding: cluster (parent) may
        // declare/re-export its zone children, socket.rs is itself a
        // zone, and main is not trace-affecting.
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "zone-containment");
        assert_eq!(f[0].file, "coordinator/mod.rs");
        assert!(f[0].message.contains("`runtime`"), "{f:?}");
    }

    #[test]
    fn modgraph_json_is_sorted_and_line_free() {
        let g = graph_of(&[
            ("data/mod.rs", "use crate::linalg::Mat;\nuse crate::linalg::Mat;\n"),
            ("linalg/mod.rs", ""),
        ]);
        let j = g.to_json();
        assert!(j.contains("\"schema\": \"coded-opt/modgraph-v1\""));
        assert!(j.contains("\"edge_count\": 1"), "dedup: {j}");
        assert!(j.contains("{\"name\": \"data\", \"file\": \"data/mod.rs\", \"layer\": 1}"));
        assert!(!j.contains("\"line\""));
    }
}
