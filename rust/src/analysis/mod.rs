//! Determinism-contract static analysis (`coded-opt lint`).
//!
//! The paper's convergence guarantees are deterministic sample-path
//! results, so this repo pins bit-exact golden traces across thread
//! counts and engines. This module mechanizes the source-level side of
//! that contract: a dependency-free, std-only scanner over the
//! workspace's `.rs` files that fails CI when code re-introduces the
//! bug classes the contract forbids (NaN-partial float orders,
//! wall-clock reads in simulated paths, hash-iteration order leaking
//! into traces, unaudited `unsafe`). See [`rules::RULES`] for the rule
//! set and [`rules`] for the `lint:allow` escape hatch.
//!
//! Beyond the line rules, the analyzer is architecture-aware: [`graph`]
//! rebuilds the crate's module dependency DAG from `use`/`mod`/
//! qualified-path references and checks the layering contract
//! (`layer-order`), zone leaf-containment (`zone-containment`) and
//! streaming-path eagerness (`eager-buffer`) over it. The graph itself
//! is an emitted artifact (`coded-opt/modgraph-v1`, committed as
//! `module-graph.json` and drift-gated in CI).
//!
//! Design note: the scanner is line/token-level, not a parser — see
//! [`source`] for what it does and does not understand. It scans its
//! own source too; the rule tokens it searches for live in string
//! literals, which the lexer blanks, so the tool is clean under itself.

pub mod graph;
pub mod rules;
pub mod source;

pub use rules::{Finding, RuleInfo, Suppressed, BARE_ALLOW, RULES};

use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// Outcome of linting a tree.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    /// Surviving violations, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Violations consumed by `lint:allow` directives.
    pub suppressed: Vec<Suppressed>,
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// The module dependency graph the architecture rules ran over.
    pub graph: graph::ModuleGraph,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Machine-readable report (schema `coded-opt/lint-v1`).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str("  \"schema\": \"coded-opt/lint-v1\",\n");
        let _ = writeln!(s, "  \"files_scanned\": {},", self.files);
        let _ = writeln!(s, "  \"finding_count\": {},", self.findings.len());
        let _ = writeln!(s, "  \"suppressed_count\": {},", self.suppressed.len());
        s.push_str("  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
                json_escape(&f.file),
                f.line,
                json_escape(&f.rule),
                json_escape(&f.message)
            );
        }
        s.push_str(if self.findings.is_empty() { "],\n" } else { "\n  ],\n" });
        s.push_str("  \"suppressed\": [");
        for (i, sp) in self.suppressed.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \
                 \"justification\": \"{}\"}}",
                json_escape(&sp.file),
                sp.line,
                json_escape(&sp.rule),
                json_escape(&sp.justification)
            );
        }
        s.push_str(if self.suppressed.is_empty() { "]\n" } else { "\n  ]\n" });
        s.push_str("}\n");
        s
    }

    /// Human-readable report.
    pub fn render_human(&self) -> String {
        let mut s = String::new();
        for f in &self.findings {
            let _ = writeln!(s, "{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
        }
        if !self.findings.is_empty() {
            s.push('\n');
        }
        for sp in &self.suppressed {
            let why =
                if sp.justification.is_empty() { "(no justification)" } else { &sp.justification };
            let _ = writeln!(s, "allowed {}:{}: [{}] {}", sp.file, sp.line, sp.rule, why);
        }
        if !self.suppressed.is_empty() {
            s.push('\n');
        }
        let _ = writeln!(
            s,
            "{} finding(s), {} allowlisted, {} file(s) scanned",
            self.findings.len(),
            self.suppressed.len(),
            self.files
        );
        s
    }

    /// GitHub Actions annotation lines (`--format github`): one
    /// `::error` per finding, so a failing CI lint job renders its
    /// findings inline on the PR diff. `root` prefixes file paths so
    /// annotations resolve from the repository root.
    pub fn render_github(&self, root: &str) -> String {
        let prefix = root.trim_end_matches('/');
        let mut s = String::new();
        for f in &self.findings {
            let path =
                if prefix.is_empty() { f.file.clone() } else { format!("{prefix}/{}", f.file) };
            let _ = writeln!(
                s,
                "::error file={path},line={},title={}::{}",
                f.line,
                f.rule,
                gh_escape(&f.message)
            );
        }
        let _ = writeln!(
            s,
            "{} finding(s), {} allowlisted, {} file(s) scanned",
            self.findings.len(),
            self.suppressed.len(),
            self.files
        );
        s
    }
}

/// Lint every `.rs` file under `root` (recursively, deterministic
/// order). Paths in the report are relative to `root`.
///
/// Two phases: every file is classified once, the module graph is
/// built over the whole tree, and then each file's line-rule findings
/// and graph-rule findings go through that file's `lint:allow`
/// directives together — so an allow can suppress an architecture
/// finding exactly like a line finding, and an unused allow is still
/// detected.
pub fn lint_path(root: &Path) -> Result<LintReport> {
    let files = source::rs_files(root)
        .with_context(|| format!("walking {}", root.display()))?;
    let mut classified = Vec::with_capacity(files.len());
    for path in &files {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        classified.push((rel, source::classify(&text)));
    }
    let module_graph = graph::build(&classified);
    let mut graph_findings: std::collections::BTreeMap<String, Vec<Finding>> =
        std::collections::BTreeMap::new();
    for f in graph::check(&module_graph) {
        graph_findings.entry(f.file.clone()).or_default().push(f);
    }
    let mut report =
        LintReport { files: classified.len(), graph: module_graph, ..Default::default() };
    for (rel, lines) in &classified {
        let mut findings = rules::scan(rel, lines);
        if let Some(extra) = graph_findings.remove(rel.as_str()) {
            findings.extend(extra);
        }
        let mut suppressed = Vec::new();
        rules::apply_allows(rel, lines, &mut findings, &mut suppressed);
        findings.sort_by(|a, b| (a.line, &a.rule).cmp(&(b.line, &b.rule)));
        suppressed.sort_by(|a, b| (a.line, &a.rule).cmp(&(b.line, &b.rule)));
        report.findings.extend(findings);
        report.suppressed.extend(suppressed);
    }
    Ok(report)
}

fn gh_escape(s: &str) -> String {
    s.replace('%', "%25").replace('\r', "%0D").replace('\n', "%0A")
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(rel: &str, text: &str) -> LintReport {
        let (findings, suppressed) = rules::lint_file(rel, text);
        LintReport { findings, suppressed, files: 1, ..Default::default() }
    }

    #[test]
    fn json_shape_and_escaping() {
        let r = report("metrics/x.rs", "let a = f64::NAN;\n");
        let j = r.to_json();
        assert!(j.contains("\"schema\": \"coded-opt/lint-v1\""));
        assert!(j.contains("\"finding_count\": 1"));
        assert!(j.contains("\"rule\": \"no-silent-nan\""));
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn empty_report_is_valid_json_arrays() {
        let r = LintReport { files: 3, ..Default::default() };
        let j = r.to_json();
        assert!(j.contains("\"findings\": []"));
        assert!(j.contains("\"suppressed\": []"));
        assert!(r.is_clean());
    }

    #[test]
    fn human_render_mentions_counts() {
        let r = report("metrics/x.rs", "let a = f64::NAN;\n");
        let h = r.render_human();
        assert!(h.contains("metrics/x.rs:1:"));
        assert!(h.contains("1 finding(s), 0 allowlisted, 1 file(s) scanned"));
    }

    #[test]
    fn github_render_emits_error_annotations() {
        let r = report("metrics/x.rs", "let a = f64::NAN;\n");
        let g = r.render_github("rust/src");
        assert!(
            g.contains("::error file=rust/src/metrics/x.rs,line=1,title=no-silent-nan::"),
            "{g}"
        );
        assert!(g.contains("1 finding(s)"));
        assert_eq!(gh_escape("a%b\nc"), "a%25b%0Ac");
    }
}
