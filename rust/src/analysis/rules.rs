//! The determinism-contract rules and the per-file scan.
//!
//! Each rule encodes an invariant the repo's bit-exact golden traces
//! depend on (see the "Determinism contract" section in the crate
//! docs). Rules are heuristic and token-level by design; the escape
//! hatch for a justified exception is an inline allow directive:
//!
//! ```text
//! // lint:allow(no-silent-nan) — documented empty-trace sentinel
//! ```
//!
//! written either as a standalone comment on the line *above* the
//! flagged code or as a trailing comment on the flagged line itself.
//! A directive **must** carry a justification after the closing paren;
//! a bare `lint:allow(rule)` still suppresses the target finding (so
//! fixtures stay deterministic) but is itself reported under the meta
//! rule `bare-allow` — you cannot silence the tool without saying why.
//! Doc comments (`///`, `//!`) are never parsed as directives, so docs
//! may quote the syntax freely.

use crate::analysis::source::{classify, find_token, SourceLine};
use std::path::Path;

/// A lint rule's identity and one-line contract.
#[derive(Clone, Copy, Debug)]
pub struct RuleInfo {
    pub id: &'static str,
    pub summary: &'static str,
}

/// The determinism-contract rules: five line-level rules plus the
/// graph-aware architecture rules (whose edge analysis lives in
/// [`crate::analysis::graph`]; `zone-containment` also has a
/// line-level half here for CPU-dispatch intrinsics).
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "float-total-order",
        summary: "float comparisons in sort/max/min positions must use total_cmp",
    },
    RuleInfo {
        id: "wall-clock-zone",
        summary: "wall-clock reads only in cluster/threads.rs, cluster/socket.rs, \
                  cluster/wire.rs and bench.rs",
    },
    RuleInfo {
        id: "ordered-iteration",
        summary: "no HashMap/HashSet in trace-producing modules (use BTreeMap)",
    },
    RuleInfo {
        id: "safety-comment",
        summary: "unsafe only under runtime/ and in linalg/simd.rs, and always \
                  with a SAFETY: comment",
    },
    RuleInfo {
        id: "no-silent-nan",
        summary: "no NAN literals or partial-order unwraps in library code",
    },
    RuleInfo {
        id: "layer-order",
        summary: "imports must follow the layering DAG (linalg → encoding/data → \
                  coordinator/cluster/scenario → control → driver → cli/main); \
                  analysis imports nothing",
    },
    RuleInfo {
        id: "zone-containment",
        summary: "wall-clock/unsafe zones must not be imported by trace-affecting \
                  modules; std::arch only in linalg/simd.rs",
    },
    RuleInfo {
        id: "eager-buffer",
        summary: "no dense full-matrix constructors (Mat::zeros, stack(, load_dense) \
                  in streaming modules",
    },
];

/// Meta rule id for allow directives that are malformed, name an
/// unknown rule, or carry no justification.
pub const BARE_ALLOW: &str = "bare-allow";

/// One contract violation.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub message: String,
}

/// A finding consumed by a `lint:allow` directive.
#[derive(Clone, Debug)]
pub struct Suppressed {
    pub file: String,
    pub line: usize,
    pub rule: String,
    /// Empty when the directive was bare (which is itself a finding).
    pub justification: String,
}

/// Comparator-taking methods: a float `partial_cmp` within reach of one
/// of these is an ordering that panics or goes unstable on NaN.
const SORT_TOKENS: &[&str] =
    &["sort_by", "sort_unstable_by", "max_by", "min_by", "binary_search_by"];

/// How many preceding lines of context count as "the same call" when
/// looking for a sort token (closures often split across lines).
const SORT_WINDOW: usize = 2;

/// Modules whose iteration order leaks into traces or user-visible
/// output (matched as `/`-separated path prefixes relative to `src`).
/// `analysis/` is in the list because the lint's own report ordering
/// is part of its contract (deterministic output, byte-stable graph
/// artifact); `cluster/socket.rs` and `cluster/wire.rs` are covered by
/// the `cluster/` prefix — their wall-clock allowance never extended
/// to iteration order.
pub(crate) const TRACE_MODULES: &[&str] = &[
    "analysis/",
    "cluster/",
    "control/",
    "coordinator/",
    "data/",
    "delay/",
    "driver/",
    "encoding/",
    "linalg/",
    "metrics/",
    "objectives/",
    "scenario/",
];

/// Modules allowed to read the wall clock (path-component suffixes).
/// The socket engine's zone covers connect-retry deadlines and I/O
/// timeouts only — fault *detection*; its traces run on a virtual
/// clock, which the cross-engine conformance suite pins bit-for-bit.
pub(crate) const WALL_CLOCK_ZONES: &[&str] =
    &["cluster/threads.rs", "cluster/socket.rs", "cluster/wire.rs", "bench.rs"];

/// Modules where `unsafe` is permitted (with a SAFETY: comment):
/// the PJRT FFI boundary and the std::arch SIMD kernels. The SIMD zone
/// is the single file, not `linalg/` — the rest of linalg stays
/// unsafe-free.
pub(crate) const UNSAFE_ZONES: &[&str] = &["runtime/", "linalg/simd.rs"];

/// Streaming modules where a dense full-matrix constructor defeats the
/// point: these paths exist so the input never has to fit in memory.
/// (`coordinator/mod.rs` holds the streamed partition builders.)
const EAGER_ZONES: &[&str] = &["encoding/stream.rs", "data/shard.rs", "coordinator/mod.rs"];

/// Call-position tokens that materialize a full dense matrix. Matched
/// word-boundary and only when followed by `(`, so `vstack(` or a
/// `stack` variable never fire; a token directly after `fn` is the
/// definition, not a call.
const EAGER_TOKENS: &[&str] = &["Mat::zeros", "stack", "load_dense"];

/// A parsed `lint:allow` directive.
struct Allow {
    /// Rule name as written (may be unknown).
    rule: String,
    /// Justification text; empty for a bare directive.
    justification: String,
    /// Line the directive itself sits on.
    line: usize,
    /// Line whose findings it suppresses.
    target: usize,
}

pub(crate) fn is_zone(rel: &str, suffixes: &[&str]) -> bool {
    // Component-wise suffix match: `bench.rs` matches `bench.rs` but
    // not `microbench.rs`.
    suffixes.iter().any(|s| Path::new(rel).ends_with(s))
}

pub(crate) fn in_prefix(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p))
}

/// Scan one file with the line-level rules only. Returns surviving
/// findings and suppressed findings, both sorted by (line, rule).
/// The graph-aware passes need the whole tree — [`super::lint_path`]
/// runs them and feeds their findings through the same allow machinery.
pub fn lint_file(rel: &str, text: &str) -> (Vec<Finding>, Vec<Suppressed>) {
    let lines = classify(text);
    let mut findings = scan(rel, &lines);
    let mut suppressed = Vec::new();
    apply_allows(rel, &lines, &mut findings, &mut suppressed);
    findings.sort_by(|a, b| (a.line, &a.rule).cmp(&(b.line, &b.rule)));
    suppressed.sort_by(|a, b| (a.line, &a.rule).cmp(&(b.line, &b.rule)));
    (findings, suppressed)
}

pub(crate) fn scan(rel: &str, lines: &[SourceLine]) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        let code = line.code.as_str();
        if code.trim().is_empty() {
            continue;
        }
        let mut total_order_hit = false;

        // float-total-order: partial_cmp with a comparator-taking call
        // in the same statement window.
        if find_token(code, "partial_cmp").is_some() {
            let lo = i.saturating_sub(SORT_WINDOW);
            let in_sort = lines[lo..=i]
                .iter()
                .any(|l| SORT_TOKENS.iter().any(|t| find_token(&l.code, t).is_some()));
            if in_sort {
                total_order_hit = true;
                out.push(mk(rel, line, "float-total-order",
                    "partial_cmp in a sort/max/min position; use total_cmp for a NaN-total order"));
            }
        }

        // wall-clock-zone
        if !is_zone(rel, WALL_CLOCK_ZONES)
            && (find_token(code, "Instant::now").is_some()
                || find_token(code, "SystemTime").is_some())
        {
            out.push(mk(rel, line, "wall-clock-zone",
                "wall-clock read outside the declared zones (cluster/threads.rs, \
                 cluster/socket.rs, cluster/wire.rs, bench.rs)"));
        }

        // ordered-iteration
        if in_prefix(rel, TRACE_MODULES)
            && (find_token(code, "HashMap").is_some() || find_token(code, "HashSet").is_some())
        {
            out.push(mk(rel, line, "ordered-iteration",
                "hash collection in a trace-producing module; use BTreeMap/BTreeSet"));
        }

        // safety-comment
        if find_token(code, "unsafe").is_some() {
            if !in_prefix(rel, UNSAFE_ZONES) {
                out.push(mk(rel, line, "safety-comment",
                    "unsafe outside the allowlisted modules (runtime/, linalg/simd.rs)"));
            } else if !has_safety_comment(lines, i) {
                out.push(mk(rel, line, "safety-comment",
                    "unsafe without an adjacent SAFETY: comment"));
            }
        }

        // zone-containment, line-level half: CPU-dispatch intrinsics
        // stay in the SIMD kernel file (the module-graph half runs in
        // crate::analysis::graph::check).
        if !is_zone(rel, &["linalg/simd.rs"])
            && (find_token(code, "std::arch").is_some()
                || find_token(code, "core::arch").is_some()
                || find_token(code, "is_x86_64_feature_detected").is_some())
        {
            out.push(mk(rel, line, "zone-containment",
                "std::arch/core::arch reference outside linalg/simd.rs"));
        }

        // eager-buffer (streaming zones, library code only)
        if !line.in_test && is_zone(rel, EAGER_ZONES) {
            for tok in EAGER_TOKENS {
                if let Some(pos) = find_token(code, tok) {
                    let is_call = code[pos + tok.len()..].trim_start().starts_with('(');
                    let is_def = code[..pos].trim_end().ends_with("fn");
                    if is_call && !is_def {
                        out.push(mk(rel, line, "eager-buffer",
                            "dense full-matrix constructor in a streaming module; \
                             build per block or stream through BlockSource"));
                        break;
                    }
                }
            }
        }

        // no-silent-nan (library code only)
        if !line.in_test {
            if find_token(code, "NAN").is_some() {
                out.push(mk(rel, line, "no-silent-nan",
                    "NAN literal in library code; sanitize at the boundary or justify"));
            }
            let unwrapped_cmp = find_token(code, "partial_cmp")
                .is_some_and(|p| code[p..].contains(".unwrap()"));
            if unwrapped_cmp && !total_order_hit {
                out.push(mk(rel, line, "no-silent-nan",
                    "unwrap on a partial-order result panics on NaN; use total_cmp"));
            }
        }
    }
    out
}

fn mk(rel: &str, line: &SourceLine, rule: &str, message: &str) -> Finding {
    Finding {
        file: rel.to_string(),
        line: line.number,
        rule: rule.to_string(),
        message: message.to_string(),
    }
}

/// Is there a SAFETY: marker on line `i` or in the contiguous block of
/// comment/attribute-only lines directly above it?
fn has_safety_comment(lines: &[SourceLine], i: usize) -> bool {
    if lines[i].comment.contains("SAFETY:") {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        let code = l.code.trim();
        if code.is_empty() && !l.comment.is_empty() {
            if l.comment.contains("SAFETY:") {
                return true;
            }
        } else if code.starts_with("#[") {
            continue; // attributes may sit between the comment and the item
        } else {
            break;
        }
    }
    false
}

const ALLOW_PREFIX: &str = "lint:allow";

fn parse_allows(lines: &[SourceLine]) -> Vec<Allow> {
    let mut out = Vec::new();
    for line in lines {
        if line.is_doc || !line.comment.starts_with(ALLOW_PREFIX) {
            continue;
        }
        let target =
            if line.code.trim().is_empty() { line.number + 1 } else { line.number };
        let body = &line.comment[ALLOW_PREFIX.len()..];
        let (rule, justification) = match split_directive(body) {
            Some(pair) => pair,
            None => {
                // Malformed (`lint:allow` with no parenthesized rule):
                // report and suppress nothing.
                out.push(Allow {
                    rule: String::new(),
                    justification: String::new(),
                    line: line.number,
                    target,
                });
                continue;
            }
        };
        out.push(Allow { rule, justification, line: line.number, target });
    }
    out
}

/// Split `"(rule) — why"` into (`rule`, `why`). The justification is
/// whatever follows the closing paren, minus leading separators; it
/// counts only if it contains something alphanumeric.
fn split_directive(body: &str) -> Option<(String, String)> {
    let rest = body.strip_prefix('(')?;
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    if rule.is_empty() {
        return None;
    }
    let tail = rest[close + 1..]
        .trim_start_matches([' ', '\t', '—', '–', '-', ':'])
        .trim()
        .to_string();
    let justified = tail.chars().any(|c| c.is_ascii_alphanumeric());
    Some((rule, if justified { tail } else { String::new() }))
}

pub(crate) fn apply_allows(
    rel: &str,
    lines: &[SourceLine],
    findings: &mut Vec<Finding>,
    suppressed: &mut Vec<Suppressed>,
) {
    for allow in parse_allows(lines) {
        let known = RULES.iter().any(|r| r.id == allow.rule);
        if !known {
            let what = if allow.rule.is_empty() {
                "malformed lint:allow directive (expected a parenthesized rule name)".to_string()
            } else {
                format!("lint:allow names unknown rule `{}`", allow.rule)
            };
            findings.push(Finding {
                file: rel.to_string(),
                line: allow.line,
                rule: BARE_ALLOW.to_string(),
                message: what,
            });
            continue;
        }
        let mut hit = false;
        findings.retain(|f| {
            if f.line == allow.target && f.rule == allow.rule {
                hit = true;
                suppressed.push(Suppressed {
                    file: f.file.clone(),
                    line: f.line,
                    rule: f.rule.clone(),
                    justification: allow.justification.clone(),
                });
                false
            } else {
                true
            }
        });
        if allow.justification.is_empty() {
            findings.push(Finding {
                file: rel.to_string(),
                line: allow.line,
                rule: BARE_ALLOW.to_string(),
                message: "lint:allow without a justification".to_string(),
            });
        } else if !hit {
            findings.push(Finding {
                file: rel.to_string(),
                line: allow.line,
                rule: BARE_ALLOW.to_string(),
                message: format!("unused lint:allow({}) — nothing to suppress", allow.rule),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(rel: &str, text: &str) -> (Vec<Finding>, Vec<Suppressed>) {
        lint_file(rel, text)
    }

    #[test]
    fn partial_cmp_in_sort_is_flagged() {
        let (f, _) =
            lint("linalg/x.rs", "v.sort_by(|a, b| a.partial_cmp(b).expect(\"cmp\"));\n");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "float-total-order");
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn sort_token_in_window_counts() {
        let text = "v.sort_by(|a, b| {\n    a.cost\n        .partial_cmp(&b.cost)\n});\n";
        let (f, _) = lint("linalg/x.rs", text);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn partial_ord_impl_is_not_flagged() {
        let text = "impl PartialOrd for Time {\n    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {\n        Some(self.cmp(o))\n    }\n}\n";
        let (f, _) = lint("coordinator/x.rs", text);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn total_cmp_sort_is_clean() {
        let (f, _) = lint("linalg/x.rs", "v.sort_by(|a, b| a.total_cmp(b));\n");
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn wall_clock_zones_respected() {
        let text = "let t = Instant::now();\n";
        let (f, _) = lint("coordinator/x.rs", text);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "wall-clock-zone");
        let (f, _) = lint("cluster/threads.rs", text);
        assert!(f.is_empty(), "{f:?}");
        // the socket engine's timeout/retry machinery is in the zone…
        let (f, _) = lint("cluster/socket.rs", text);
        assert!(f.is_empty(), "{f:?}");
        let (f, _) = lint("cluster/wire.rs", text);
        assert!(f.is_empty(), "{f:?}");
        // …but the virtual-clock sim engine stays out of it
        let (f, _) = lint("cluster/sim.rs", text);
        assert_eq!(f.len(), 1, "{f:?}");
        let (f, _) = lint("bench.rs", text);
        assert!(f.is_empty(), "{f:?}");
        // component-wise: `microbench.rs` is NOT in the zone
        let (f, _) = lint("microbench.rs", text);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn hash_collections_flagged_in_trace_modules_only() {
        let text = "use std::collections::HashMap;\n";
        let (f, _) = lint("cluster/x.rs", text);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "ordered-iteration");
        // the lint's own report ordering is part of the contract…
        let (f, _) = lint("analysis/x.rs", text);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "ordered-iteration");
        // …and the socket/wire wall-clock zone never waived it
        let (f, _) = lint("cluster/socket.rs", text);
        assert_eq!(f.len(), 1, "{f:?}");
        let (f, _) = lint("testutil/x.rs", text);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn arch_intrinsics_only_in_simd_kernel_file() {
        let text = "use std::arch::x86_64::_mm256_set1_pd;\n";
        let (f, _) = lint("linalg/mat.rs", text);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "zone-containment");
        let (f, _) = lint("linalg/simd.rs", text);
        assert!(f.is_empty(), "{f:?}");
        let (f, _) = lint("linalg/simd.rs", "if is_x86_64_feature_detected!(\"avx2\") {}\n");
        assert!(f.is_empty(), "{f:?}");
        let (f, _) = lint("driver/mod.rs", "if is_x86_64_feature_detected!(\"avx2\") {}\n");
        assert_eq!(f.len(), 1, "{f:?}");
    }

    #[test]
    fn eager_constructors_flagged_in_streaming_zones_only() {
        let text = "let out = Mat::zeros(rows, cols);\n";
        let (f, _) = lint("encoding/stream.rs", text);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "eager-buffer");
        let (f, _) = lint("data/shard.rs", "let (x, y) = src.load_dense()?;\n");
        assert_eq!(f.len(), 1, "{f:?}");
        // same constructor outside the streaming zones is fine
        let (f, _) = lint("linalg/mat.rs", text);
        assert!(f.is_empty(), "{f:?}");
        // definitions don't fire — only call positions do
        let (f, _) = lint("data/shard.rs", "pub fn load_dense(&self) -> Result<Mat> {\n");
        assert!(f.is_empty(), "{f:?}");
        // word boundaries: vstack( and a `stack` variable are not stack(
        let (f, _) = lint("encoding/stream.rs", "let m = Mat::vstack(&blocks);\n");
        assert!(f.is_empty(), "{f:?}");
        let (f, _) = lint("encoding/stream.rs", "let mut stack = Vec::new();\nstack.push(1);\n");
        assert!(f.is_empty(), "{f:?}");
        let (f, _) = lint("coordinator/mod.rs", "let s = enc.stack(&parts);\n");
        assert_eq!(f.len(), 1, "{f:?}");
        // test modules may build dense fixtures freely
        let in_test = "#[cfg(test)]\nmod tests {\n    fn t() { let m = Mat::zeros(4, 4); }\n}\n";
        let (f, _) = lint("encoding/stream.rs", in_test);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn unsafe_needs_zone_and_safety_comment() {
        let bad_zone = "unsafe impl Send for X {}\n";
        let (f, _) = lint("linalg/x.rs", bad_zone);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "safety-comment");

        let (f, _) = lint("runtime/x.rs", "unsafe impl Send for X {}\n");
        assert_eq!(f.len(), 1, "in-zone but uncommented: {f:?}");

        let ok = "// SAFETY: X is plain data.\n// Second comment line.\nunsafe impl Send for X {}\n";
        let (f, _) = lint("runtime/x.rs", ok);
        assert!(f.is_empty(), "{f:?}");

        let multi = "// SAFETY: head line.\n// continuation.\n#[allow(dead_code)]\nunsafe impl Send for X {}\n";
        let (f, _) = lint("runtime/x.rs", multi);
        assert!(f.is_empty(), "walkback crosses attributes: {f:?}");

        // The SIMD kernel file is in the zone (still SAFETY-gated)…
        let (f, _) = lint("linalg/simd.rs", "unsafe { body() }\n");
        assert_eq!(f.len(), 1, "in-zone but uncommented: {f:?}");
        let ok = "// SAFETY: avx2 checked by the dispatcher.\nunsafe { body() }\n";
        let (f, _) = lint("linalg/simd.rs", ok);
        assert!(f.is_empty(), "{f:?}");
        // …and the zone is that one file, not the rest of linalg/.
        let (f, _) = lint("linalg/mat.rs", ok);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("runtime/"), "{f:?}");
    }

    #[test]
    fn nan_literal_flagged_outside_tests_only() {
        let text = "let a = f64::NAN;\n#[cfg(test)]\nmod tests {\n    fn t() { let b = f64::NAN; }\n}\n";
        let (f, _) = lint("metrics/x.rs", text);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 1);
        assert_eq!(f[0].rule, "no-silent-nan");
    }

    #[test]
    fn partial_cmp_unwrap_without_sort_context() {
        let (f, _) = lint("metrics/x.rs", "let o = a.partial_cmp(&b).unwrap();\n");
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "no-silent-nan");
    }

    #[test]
    fn sort_unwrap_fires_once_not_twice() {
        let (f, _) = lint("metrics/x.rs", "v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n");
        assert_eq!(f.len(), 1, "dedup: {f:?}");
        assert_eq!(f[0].rule, "float-total-order");
    }

    #[test]
    fn justified_allow_suppresses_and_is_counted() {
        let text = "// lint:allow(no-silent-nan) — documented sentinel for empty traces\nlet a = f64::NAN;\n";
        let (f, s) = lint("metrics/x.rs", text);
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].rule, "no-silent-nan");
        assert_eq!(s[0].line, 2);
        assert!(s[0].justification.contains("sentinel"));
    }

    #[test]
    fn trailing_allow_targets_its_own_line() {
        let text = "let a = f64::NAN; // lint:allow(no-silent-nan) — sentinel value\n";
        let (f, s) = lint("metrics/x.rs", text);
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn bare_allow_suppresses_but_is_itself_a_finding() {
        let text = "// lint:allow(no-silent-nan)\nlet a = f64::NAN;\n";
        let (f, s) = lint("metrics/x.rs", text);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, BARE_ALLOW);
        assert_eq!(f[0].line, 1);
        assert_eq!(s.len(), 1, "underlying finding still suppressed");
        assert!(s[0].justification.is_empty());
    }

    #[test]
    fn separator_only_justification_is_bare() {
        let text = "let a = f64::NAN; // lint:allow(no-silent-nan) —\n";
        let (f, s) = lint("metrics/x.rs", text);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, BARE_ALLOW);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn unknown_rule_is_a_finding_and_suppresses_nothing() {
        let text = "// lint:allow(no-such-rule) — reason\nlet a = f64::NAN;\n";
        let (f, s) = lint("metrics/x.rs", text);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().any(|x| x.rule == BARE_ALLOW));
        assert!(f.iter().any(|x| x.rule == "no-silent-nan"));
        assert!(s.is_empty());
    }

    #[test]
    fn unused_allow_is_reported() {
        let text = "// lint:allow(no-silent-nan) — stale directive\nlet a = 1.0;\n";
        let (f, _) = lint("metrics/x.rs", text);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, BARE_ALLOW);
        assert!(f[0].message.contains("unused"));
    }

    #[test]
    fn doc_comments_never_parse_as_directives() {
        let text = "/// lint:allow(no-silent-nan) — this is documentation\nlet a = f64::NAN;\n";
        let (f, s) = lint("metrics/x.rs", text);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "no-silent-nan");
        assert!(s.is_empty());
    }

    #[test]
    fn directive_in_string_is_inert() {
        let text = "let s = \"// lint:allow(no-silent-nan) — nope\";\nlet a = f64::NAN;\n";
        let (f, s) = lint("metrics/x.rs", text);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(s.is_empty());
    }
}
