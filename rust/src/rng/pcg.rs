//! PCG64 (PCG-XSL-RR 128/64) pseudo-random generator.
//!
//! Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation" (2014).
//! 128-bit LCG state with an XSL-RR output permutation; period 2^128.

/// Default LCG multiplier for the 128-bit PCG family.
const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// A deterministic, seedable PRNG. Not cryptographic.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream constant fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream selector; distinct
    /// streams from the same seed are statistically independent.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut g = Pcg64 { state: 0, inc };
        g.state = g.state.wrapping_mul(PCG_MULT).wrapping_add(g.inc);
        g.state = g.state.wrapping_add(seed as u128);
        g.state = g.state.wrapping_mul(PCG_MULT).wrapping_add(g.inc);
        g
    }

    /// Next 64 uniformly random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `0..bound` (Lemire-style rejection, unbiased).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be positive");
        let bound = bound as u64;
        // Rejection sampling over the top bits to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % bound) as usize;
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Jump the generator forward by `delta` steps of [`next_u64`] in
    /// `O(log delta)` (Brown, "Random Number Generation with Arbitrary
    /// Strides", 1994 — the standard LCG advance by repeated squaring of
    /// the affine map). `advance(k)` leaves the generator in exactly the
    /// state `k` calls to `next_u64` would: this is what lets a lazily
    /// regenerated Gaussian encoding block start its draw mid-stream and
    /// still be bit-identical to the one-pass eager construction.
    ///
    /// [`next_u64`]: Pcg64::next_u64
    pub fn advance(&mut self, mut delta: u128) {
        let mut acc_mult: u128 = 1;
        let mut acc_plus: u128 = 0;
        let mut cur_mult = PCG_MULT;
        let mut cur_plus = self.inc;
        while delta > 0 {
            if delta & 1 == 1 {
                acc_mult = acc_mult.wrapping_mul(cur_mult);
                acc_plus = acc_plus.wrapping_mul(cur_mult).wrapping_add(cur_plus);
            }
            cur_plus = cur_mult.wrapping_add(1).wrapping_mul(cur_plus);
            cur_mult = cur_mult.wrapping_mul(cur_mult);
            delta >>= 1;
        }
        self.state = acc_mult.wrapping_mul(self.state).wrapping_add(acc_plus);
    }

    /// Fork a child generator; children with different `stream_id`s are
    /// independent of the parent and of each other. Used to hand each
    /// simulated worker its own RNG.
    pub fn fork(&mut self, stream_id: u64) -> Pcg64 {
        Pcg64::with_stream(
            self.next_u64() ^ stream_id.wrapping_mul(0x9e37_79b9_7f4a_7c15),
            stream_id,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut g = Pcg64::new(5);
        for _ in 0..10_000 {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_near_half() {
        let mut g = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| g.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_unbiased_over_small_bound() {
        let mut g = Pcg64::new(13);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[g.gen_range(7)] += 1;
        }
        for &c in &counts {
            let expected = n as f64 / 7.0;
            assert!((c as f64 - expected).abs() < expected * 0.1, "counts={counts:?}");
        }
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut parent = Pcg64::new(99);
        let mut a = parent.fork(1);
        let mut b = parent.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn advance_matches_stepping() {
        for &(seed, stream) in &[(42u64, 0xda3e_39cb_94b9_5bdbu64), (7, 0x6a55), (0, 1)] {
            for &k in &[0u128, 1, 2, 63, 64, 1000, 123_457] {
                let mut stepped = Pcg64::with_stream(seed, stream);
                for _ in 0..k {
                    stepped.next_u64();
                }
                let mut jumped = Pcg64::with_stream(seed, stream);
                jumped.advance(k);
                assert_eq!(
                    jumped.next_u64(),
                    stepped.next_u64(),
                    "advance({k}) != {k} steps (seed={seed})"
                );
            }
        }
    }

    #[test]
    fn advance_composes() {
        let mut a = Pcg64::new(9);
        a.advance(100);
        a.advance(23);
        let mut b = Pcg64::new(9);
        b.advance(123);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_bool_extremes() {
        let mut g = Pcg64::new(21);
        assert!((0..100).all(|_| !g.gen_bool(0.0)));
        assert!((0..100).all(|_| g.gen_bool(1.0)));
    }
}
