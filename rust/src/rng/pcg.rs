//! PCG64 (PCG-XSL-RR 128/64) pseudo-random generator.
//!
//! Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation" (2014).
//! 128-bit LCG state with an XSL-RR output permutation; period 2^128.

/// Default LCG multiplier for the 128-bit PCG family.
const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// A deterministic, seedable PRNG. Not cryptographic.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream constant fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream selector; distinct
    /// streams from the same seed are statistically independent.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut g = Pcg64 { state: 0, inc };
        g.state = g.state.wrapping_mul(PCG_MULT).wrapping_add(g.inc);
        g.state = g.state.wrapping_add(seed as u128);
        g.state = g.state.wrapping_mul(PCG_MULT).wrapping_add(g.inc);
        g
    }

    /// Next 64 uniformly random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `0..bound` (Lemire-style rejection, unbiased).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be positive");
        let bound = bound as u64;
        // Rejection sampling over the top bits to avoid modulo bias.
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % bound) as usize;
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fork a child generator; children with different `stream_id`s are
    /// independent of the parent and of each other. Used to hand each
    /// simulated worker its own RNG.
    pub fn fork(&mut self, stream_id: u64) -> Pcg64 {
        Pcg64::with_stream(
            self.next_u64() ^ stream_id.wrapping_mul(0x9e37_79b9_7f4a_7c15),
            stream_id,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut g = Pcg64::new(5);
        for _ in 0..10_000 {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_near_half() {
        let mut g = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| g.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_unbiased_over_small_bound() {
        let mut g = Pcg64::new(13);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[g.gen_range(7)] += 1;
        }
        for &c in &counts {
            let expected = n as f64 / 7.0;
            assert!((c as f64 - expected).abs() < expected * 0.1, "counts={counts:?}");
        }
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut parent = Pcg64::new(99);
        let mut a = parent.fork(1);
        let mut b = parent.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_bool_extremes() {
        let mut g = Pcg64::new(21);
        assert!((0..100).all(|_| !g.gen_bool(0.0)));
        assert!((0..100).all(|_| g.gen_bool(1.0)));
    }
}
