//! Pseudo-random number generation substrate.
//!
//! The offline build environment has no `rand` crate, so we implement a
//! small, well-tested PRNG stack ourselves: a PCG64 generator ([`Pcg64`])
//! plus the distributions the paper's experiments need ([`dist`]):
//! standard normal, exponential, Pareto (power law), and finite mixtures.

pub mod dist;
pub mod pcg;

pub use dist::{Exponential, GaussianMixture, Normal, Pareto, Uniform};
pub use pcg::Pcg64;

/// Convenience: deterministic generator from a u64 seed.
pub fn seeded(seed: u64) -> Pcg64 {
    Pcg64::new(seed)
}

/// Fisher–Yates shuffle of a slice.
pub fn shuffle<T>(rng: &mut Pcg64, xs: &mut [T]) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    for i in (1..n).rev() {
        let j = rng.gen_range(i + 1);
        xs.swap(i, j);
    }
}

/// Sample `k` distinct indices from `0..n` (uniform without replacement).
///
/// Uses partial Fisher–Yates: O(n) memory, O(k) swaps. Panics if `k > n`.
pub fn sample_without_replacement(rng: &mut Pcg64, n: usize, k: usize) -> Vec<usize> {
    assert!(k <= n, "cannot sample {k} distinct items from {n}");
    let mut pool: Vec<usize> = (0..n).collect();
    for i in 0..k {
        let j = i + rng.gen_range(n - i);
        pool.swap(i, j);
    }
    pool.truncate(k);
    pool
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = seeded(7);
        let mut xs: Vec<usize> = (0..100).collect();
        shuffle(&mut rng, &mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_handles_degenerate_sizes() {
        let mut rng = seeded(1);
        let mut empty: [u8; 0] = [];
        shuffle(&mut rng, &mut empty);
        let mut one = [42];
        shuffle(&mut rng, &mut one);
        assert_eq!(one, [42]);
    }

    #[test]
    fn sample_without_replacement_distinct_and_in_range() {
        let mut rng = seeded(3);
        for _ in 0..50 {
            let s = sample_without_replacement(&mut rng, 20, 7);
            assert_eq!(s.len(), 7);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 7, "indices must be distinct");
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn sample_full_is_permutation() {
        let mut rng = seeded(9);
        let mut s = sample_without_replacement(&mut rng, 10, 10);
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic]
    fn sample_more_than_n_panics() {
        let mut rng = seeded(0);
        let _ = sample_without_replacement(&mut rng, 3, 4);
    }
}
