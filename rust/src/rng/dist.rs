//! Probability distributions over [`Pcg64`].
//!
//! Exactly the set needed by the paper's experiments:
//! - [`Normal`] — data matrices, noise (Box–Muller with caching).
//! - [`Exponential`] — per-task latency (MovieLens experiment, §5.2).
//! - [`Pareto`] — power-law number of background tasks (§5.3).
//! - [`GaussianMixture`] — bimodal / trimodal communication delays
//!   (§5.3, §5.4).
//! - [`Uniform`] — generic ranges.

use super::pcg::Pcg64;

/// Common sampling interface.
pub trait Distribution {
    fn sample(&self, rng: &mut Pcg64) -> f64;
}

/// Normal(μ, σ²) via Box–Muller (both variates used, one cached).
#[derive(Clone, Copy, Debug)]
pub struct Normal {
    pub mean: f64,
    pub std: f64,
}

impl Normal {
    pub fn new(mean: f64, std: f64) -> Self {
        assert!(std >= 0.0, "std must be non-negative");
        Normal { mean, std }
    }

    pub fn standard() -> Self {
        Normal { mean: 0.0, std: 1.0 }
    }

    /// One standard-normal variate.
    #[inline]
    pub fn sample_standard(rng: &mut Pcg64) -> f64 {
        // Box–Muller; u1 bounded away from 0 so ln is finite.
        let u1 = (rng.next_f64()).max(1e-300);
        let u2 = rng.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

impl Distribution for Normal {
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.mean + self.std * Normal::sample_standard(rng)
    }
}

/// Exponential(rate λ); mean 1/λ.
#[derive(Clone, Copy, Debug)]
pub struct Exponential {
    pub rate: f64,
}

impl Exponential {
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0, "rate must be positive");
        Exponential { rate }
    }

    /// Construct from the mean (1/λ), which is how the paper states it
    /// ("Δ ~ exp(10 ms)" means mean 10 ms).
    pub fn with_mean(mean: f64) -> Self {
        Self::new(1.0 / mean)
    }
}

impl Distribution for Exponential {
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        -(1.0 - rng.next_f64()).max(1e-300).ln() / self.rate
    }
}

/// Pareto(x_min, α) — power-law tail P(X > x) = (x_min/x)^α.
#[derive(Clone, Copy, Debug)]
pub struct Pareto {
    pub x_min: f64,
    pub alpha: f64,
}

impl Pareto {
    pub fn new(x_min: f64, alpha: f64) -> Self {
        assert!(x_min > 0.0 && alpha > 0.0);
        Pareto { x_min, alpha }
    }
}

impl Distribution for Pareto {
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        let u = (1.0 - rng.next_f64()).max(1e-300);
        self.x_min / u.powf(1.0 / self.alpha)
    }
}

/// Uniform over [lo, hi).
#[derive(Clone, Copy, Debug)]
pub struct Uniform {
    pub lo: f64,
    pub hi: f64,
}

impl Uniform {
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(hi >= lo);
        Uniform { lo, hi }
    }
}

impl Distribution for Uniform {
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }
}

/// Finite mixture of normals: Σ qᵢ · N(μᵢ, σᵢ²).
///
/// The paper's logistic-regression experiment uses
/// `0.5·N(0.5s, 0.2²) + 0.5·N(20s, 5²)` and the LASSO experiment a
/// trimodal variant.
#[derive(Clone, Debug)]
pub struct GaussianMixture {
    components: Vec<(f64, Normal)>, // (weight, component)
}

impl GaussianMixture {
    /// Components as (weight, mean, std). Weights are normalized.
    pub fn new(spec: &[(f64, f64, f64)]) -> Self {
        assert!(!spec.is_empty());
        let total: f64 = spec.iter().map(|s| s.0).sum();
        assert!(total > 0.0);
        let components = spec
            .iter()
            .map(|&(q, mu, sd)| (q / total, Normal::new(mu, sd)))
            .collect();
        GaussianMixture { components }
    }

    /// The paper's bimodal delay: q·N(μ1,σ1²) + (1−q)·N(μ2,σ2²)
    /// with q=0.5, μ1=0.5 s, μ2=20 s, σ1=0.2 s, σ2=5 s (§5.3).
    pub fn paper_bimodal() -> Self {
        Self::new(&[(0.5, 0.5, 0.2), (0.5, 20.0, 5.0)])
    }

    /// The paper's trimodal LASSO delay (§5.4):
    /// 0.8·N(0.2, 0.1²) + 0.1·N(0.6, 0.2²) + 0.1·N(1.0, 0.4²).
    pub fn paper_trimodal() -> Self {
        Self::new(&[(0.8, 0.2, 0.1), (0.1, 0.6, 0.2), (0.1, 1.0, 0.4)])
    }

    pub fn mean(&self) -> f64 {
        self.components.iter().map(|(q, n)| q * n.mean).sum()
    }
}

impl Distribution for GaussianMixture {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        let mut u = rng.next_f64();
        for (q, comp) in &self.components {
            if u < *q {
                return comp.sample(rng);
            }
            u -= q;
        }
        // Floating-point slack: fall through to the last component.
        self.components.last().unwrap().1.sample(rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(f: impl Fn(&mut Pcg64) -> f64, n: usize, seed: u64) -> (f64, f64) {
        let mut rng = Pcg64::new(seed);
        let xs: Vec<f64> = (0..n).map(|_| f(&mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        (mean, var)
    }

    #[test]
    fn normal_moments() {
        let d = Normal::new(3.0, 2.0);
        let (mean, var) = moments(|r| d.sample(r), 200_000, 17);
        assert!((mean - 3.0).abs() < 0.03, "mean={mean}");
        assert!((var - 4.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn exponential_moments() {
        let d = Exponential::with_mean(0.01); // the MovieLens exp(10ms) delay
        let (mean, var) = moments(|r| d.sample(r), 200_000, 19);
        assert!((mean - 0.01).abs() < 2e-4, "mean={mean}");
        assert!((var - 1e-4).abs() < 1e-5, "var={var}");
    }

    #[test]
    fn exponential_nonnegative() {
        let d = Exponential::new(2.0);
        let mut rng = Pcg64::new(23);
        assert!((0..10_000).all(|_| d.sample(&mut rng) >= 0.0));
    }

    #[test]
    fn pareto_respects_xmin_and_tail() {
        let d = Pareto::new(1.0, 1.5); // the paper's α=1.5 background-task law
        let mut rng = Pcg64::new(29);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        assert!(xs.iter().all(|&x| x >= 1.0));
        // P(X > 4) = 4^{-1.5} = 0.125
        let frac = xs.iter().filter(|&&x| x > 4.0).count() as f64 / n as f64;
        assert!((frac - 0.125).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let d = Uniform::new(-2.0, 6.0);
        let mut rng = Pcg64::new(31);
        let xs: Vec<f64> = (0..50_000).map(|_| d.sample(&mut rng)).collect();
        assert!(xs.iter().all(|&x| (-2.0..6.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 2.0).abs() < 0.05);
    }

    #[test]
    fn mixture_weights_normalize_and_mean_matches() {
        let gm = GaussianMixture::new(&[(2.0, 0.0, 0.1), (2.0, 10.0, 0.1)]);
        assert!((gm.mean() - 5.0).abs() < 1e-12);
        let (mean, _) = moments(|r| gm.sample(r), 100_000, 37);
        assert!((mean - 5.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn paper_bimodal_is_bimodal() {
        let gm = GaussianMixture::paper_bimodal();
        let mut rng = Pcg64::new(41);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| gm.sample(&mut rng)).collect();
        let fast = xs.iter().filter(|&&x| x < 2.0).count() as f64 / n as f64;
        let slow = xs.iter().filter(|&&x| x > 10.0).count() as f64 / n as f64;
        assert!((fast - 0.5).abs() < 0.02, "fast={fast}");
        assert!((slow - 0.48).abs() < 0.04, "slow={slow}");
    }

    #[test]
    fn paper_trimodal_mean() {
        let gm = GaussianMixture::paper_trimodal();
        let expect = 0.8 * 0.2 + 0.1 * 0.6 + 0.1 * 1.0;
        assert!((gm.mean() - expect).abs() < 1e-12);
    }
}
