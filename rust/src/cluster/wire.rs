//! Hand-rolled wire format for the multi-host socket engine.
//!
//! Zero dependencies beyond `std::io` — the same defensive style as the
//! shard format (`data/shard.rs`): a magic tag, an explicit
//! little-endian protocol version, a bounded length header, and an
//! FNV-1a checksum over every frame, with loud `ensure!` errors on any
//! mismatch. A peer that sends garbage is *diagnosed*, never trusted.
//!
//! # Frame layout (all integers little-endian)
//!
//! | offset        | size | field                                       |
//! |--------------:|-----:|---------------------------------------------|
//! | 0             | 4    | magic `b"CWIR"`                             |
//! | 4             | 4    | `u32` protocol version ([`WIRE_VERSION`])   |
//! | 8             | 1    | message kind tag                            |
//! | 9             | 8    | `u64` body length (≤ [`MAX_BODY`])          |
//! | 17            | body | kind-specific body (below)                  |
//! | 17 + body     | 8    | `u64` FNV-1a 64 over kind tag + body        |
//!
//! # Message kinds
//!
//! | tag | message    | body                                             |
//! |----:|------------|--------------------------------------------------|
//! | 0   | `Hello`    | `rows: u64, cols: u64` — worker → master greeting with its partition shape |
//! | 1   | `Task`     | `iter: u64, kind: u32`, then `payload` and `aux` as length-prefixed f64 vectors |
//! | 2   | `Result`   | `iter: u64` echo, then `payload` as a length-prefixed f64 vector |
//! | 3   | `Shutdown` | empty — master → worker session end                |
//!
//! f64 vectors are `count: u64` followed by `count` raw little-endian
//! `f64::to_le_bytes` values — payloads cross the wire **bit-exactly**,
//! which is what lets a multi-process run reproduce a [`SimCluster`]
//! trace bit for bit (see [`super::socket`]).
//!
//! Version negotiation is the frame header itself: a peer speaking a
//! different [`WIRE_VERSION`] is refused at the first frame with an
//! error naming both versions, before any payload is interpreted.
//!
//! [`SimCluster`]: super::SimCluster

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

/// Frame magic, little-endian first on the wire.
pub const WIRE_MAGIC: &[u8; 4] = b"CWIR";

/// Protocol version; bump on any frame- or body-layout change.
pub const WIRE_VERSION: u32 = 1;

/// Upper bound on a frame body. A gradient payload is `p` f64s, so this
/// admits models up to tens of millions of coordinates while making a
/// corrupt (or hostile) length header fail fast instead of attempting a
/// multi-gigabyte allocation.
pub const MAX_BODY: u64 = 1 << 28;

const K_HELLO: u8 = 0;
const K_TASK: u8 = 1;
const K_RESULT: u8 = 2;
const K_SHUTDOWN: u8 = 3;

// FNV-1a 64 (same constants as the shard format's checksum; kept
// private there, so the wire codec carries its own copies).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01b3;

fn fnv1a64(mut acc: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        acc ^= b as u64;
        acc = acc.wrapping_mul(FNV_PRIME);
    }
    acc
}

/// One socket-engine message. See the module docs for the exact wire
/// encoding of each kind.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Worker → master greeting sent immediately after accept: the
    /// shape of the encoded partition the worker loaded from disk.
    /// `rows` drives the master's virtual-arrival cost model (mirrors
    /// `QuadWorker::cost`), `cols` is checked against the problem `p`.
    Hello { rows: u64, cols: u64 },
    /// Master → worker: execute one round task (the wire form of
    /// [`super::Task`]).
    Task { iter: u64, kind: u32, payload: Vec<f64>, aux: Vec<f64> },
    /// Worker → master: the task's result, echoing the iteration it
    /// answers. A mismatched echo is a protocol violation the master
    /// treats as a crash-erasure — stale payloads never reach a later
    /// round's assembler.
    Result { iter: u64, payload: Vec<f64> },
    /// Master → worker: the session is over; return to accepting.
    Shutdown,
}

impl Msg {
    /// Stable human name for error messages (avoids Debug-printing
    /// payload vectors into an error string).
    pub fn kind_name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "Hello",
            Msg::Task { .. } => "Task",
            Msg::Result { .. } => "Result",
            Msg::Shutdown => "Shutdown",
        }
    }

    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => K_HELLO,
            Msg::Task { .. } => K_TASK,
            Msg::Result { .. } => K_RESULT,
            Msg::Shutdown => K_SHUTDOWN,
        }
    }
}

fn push_f64s(body: &mut Vec<u8>, v: &[f64]) {
    body.extend_from_slice(&(v.len() as u64).to_le_bytes());
    for x in v {
        body.extend_from_slice(&x.to_le_bytes());
    }
}

fn encode_body(msg: &Msg) -> Vec<u8> {
    let mut body = Vec::new();
    match msg {
        Msg::Hello { rows, cols } => {
            body.extend_from_slice(&rows.to_le_bytes());
            body.extend_from_slice(&cols.to_le_bytes());
        }
        Msg::Task { iter, kind, payload, aux } => {
            body.extend_from_slice(&iter.to_le_bytes());
            body.extend_from_slice(&kind.to_le_bytes());
            push_f64s(&mut body, payload);
            push_f64s(&mut body, aux);
        }
        Msg::Result { iter, payload } => {
            body.extend_from_slice(&iter.to_le_bytes());
            push_f64s(&mut body, payload);
        }
        Msg::Shutdown => {}
    }
    body
}

/// Serialize one frame. The whole frame is assembled in memory and
/// written with a single `write_all`, so a frame is never interleaved
/// with another writer's bytes on the same stream.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<()> {
    write_msg_with_version(w, msg, WIRE_VERSION)
}

/// [`write_msg`] with an explicit header version — exists so the
/// version-skew handshake path is testable (see `testutil::peer`).
pub(crate) fn write_msg_with_version<W: Write>(
    w: &mut W,
    msg: &Msg,
    version: u32,
) -> Result<()> {
    let kind = msg.tag();
    let body = encode_body(msg);
    let mut frame = Vec::with_capacity(17 + body.len() + 8);
    frame.extend_from_slice(WIRE_MAGIC);
    frame.extend_from_slice(&version.to_le_bytes());
    frame.push(kind);
    frame.extend_from_slice(&(body.len() as u64).to_le_bytes());
    frame.extend_from_slice(&body);
    let sum = fnv1a64(fnv1a64(FNV_OFFSET, &[kind]), &body);
    frame.extend_from_slice(&sum.to_le_bytes());
    w.write_all(&frame).context("write wire frame")?;
    Ok(())
}

fn read_exact<R: Read>(r: &mut R, buf: &mut [u8], what: &str) -> Result<()> {
    r.read_exact(buf).with_context(|| format!("torn frame: truncated {what}"))
}

fn read_u32<R: Read>(r: &mut R, what: &str) -> Result<u32> {
    let mut b = [0u8; 4];
    read_exact(r, &mut b, what)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R, what: &str) -> Result<u64> {
    let mut b = [0u8; 8];
    read_exact(r, &mut b, what)?;
    Ok(u64::from_le_bytes(b))
}

/// Read one frame; a clean EOF *at a frame boundary* (zero bytes before
/// the magic) is `Ok(None)` — the peer ended the session. EOF anywhere
/// inside a frame is a torn-frame error.
pub fn read_msg_or_eof<R: Read>(r: &mut R) -> Result<Option<Msg>> {
    let mut magic = [0u8; 4];
    let mut got = 0usize;
    while got < magic.len() {
        let k = r.read(&mut magic[got..]).context("read wire frame magic")?;
        if k == 0 {
            ensure!(got == 0, "torn frame: EOF inside the magic ({got}/4 bytes)");
            return Ok(None);
        }
        got += k;
    }
    ensure!(
        &magic == WIRE_MAGIC,
        "bad wire magic {magic:02x?} (expected {WIRE_MAGIC:02x?}) — not a coded-opt peer"
    );
    let version = read_u32(r, "version")?;
    ensure!(
        version == WIRE_VERSION,
        "protocol version skew: peer speaks wire v{version}, this build speaks \
         v{WIRE_VERSION}; upgrade the older side"
    );
    let mut kind_b = [0u8; 1];
    read_exact(r, &mut kind_b, "kind tag")?;
    let kind = kind_b[0];
    let len = read_u64(r, "length header")?;
    ensure!(
        len <= MAX_BODY,
        "wire frame length header {len} exceeds the {MAX_BODY}-byte bound \
         (corrupt stream or hostile peer)"
    );
    let mut body = vec![0u8; len as usize];
    read_exact(r, &mut body, "body")?;
    let want = read_u64(r, "checksum")?;
    let got_sum = fnv1a64(fnv1a64(FNV_OFFSET, &[kind]), &body);
    ensure!(
        got_sum == want,
        "wire frame checksum mismatch (kind tag {kind}): computed {got_sum:#018x}, \
         header says {want:#018x} — corrupt frame"
    );
    decode_body(kind, &body).map(Some)
}

/// Read one frame, treating any EOF — even at a frame boundary — as an
/// error ("connection closed by peer"). The master side uses this:
/// mid-round, a vanished worker is a fault, not a session end.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Msg> {
    match read_msg_or_eof(r)? {
        Some(msg) => Ok(msg),
        None => bail!("connection closed by peer"),
    }
}

/// Bounds-checked reader over a frame body.
struct Body<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Body<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        ensure!(
            n <= self.remaining(),
            "wire body underrun reading {what}: need {n} bytes, {} left",
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f64s(&mut self, what: &str) -> Result<Vec<f64>> {
        let count = self.u64(what)? as usize;
        let bytes = count.checked_mul(8).ok_or_else(|| {
            anyhow::anyhow!("wire vector length {count} overflows reading {what}")
        })?;
        let raw = self.take(bytes, what)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn done(self, kind: u8) -> Result<()> {
        ensure!(
            self.remaining() == 0,
            "wire frame (kind tag {kind}) has {} trailing byte(s)",
            self.remaining()
        );
        Ok(())
    }
}

fn decode_body(kind: u8, body: &[u8]) -> Result<Msg> {
    let mut b = Body { buf: body, pos: 0 };
    let msg = match kind {
        K_HELLO => Msg::Hello { rows: b.u64("Hello.rows")?, cols: b.u64("Hello.cols")? },
        K_TASK => Msg::Task {
            iter: b.u64("Task.iter")?,
            kind: b.u32("Task.kind")?,
            payload: b.f64s("Task.payload")?,
            aux: b.f64s("Task.aux")?,
        },
        K_RESULT => {
            Msg::Result { iter: b.u64("Result.iter")?, payload: b.f64s("Result.payload")? }
        }
        K_SHUTDOWN => Msg::Shutdown,
        other => bail!("unknown wire message kind tag {other}"),
    };
    b.done(kind)?;
    Ok(msg)
}

#[cfg(test)]
mod tests {
    // Round-trip comparisons pin exact payload bits on purpose.
    #![allow(clippy::float_cmp)]

    use super::*;

    fn frame(msg: &Msg) -> Vec<u8> {
        let mut buf = Vec::new();
        write_msg(&mut buf, msg).unwrap();
        buf
    }

    fn all_kinds() -> Vec<Msg> {
        vec![
            Msg::Hello { rows: 32, cols: 8 },
            Msg::Task {
                iter: 7,
                kind: 1,
                payload: vec![1.5, -0.0, f64::INFINITY, 3.25e-300],
                aux: vec![42.0],
            },
            Msg::Result { iter: 7, payload: vec![0.1, 0.2, 0.3] },
            Msg::Shutdown,
        ]
    }

    #[test]
    fn round_trip_all_message_kinds() {
        for msg in all_kinds() {
            let buf = frame(&msg);
            let back = read_msg(&mut buf.as_slice())
                .unwrap_or_else(|e| panic!("{}: {e:#}", msg.kind_name()));
            assert_eq!(back, msg, "{} round trip", msg.kind_name());
        }
    }

    #[test]
    fn payload_bits_survive_the_wire_exactly() {
        let vals = vec![0.1 + 0.2, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE, f64::INFINITY];
        let msg = Msg::Result { iter: 0, payload: vals.clone() };
        let Msg::Result { payload, .. } = read_msg(&mut frame(&msg).as_slice()).unwrap()
        else {
            panic!("wrong kind")
        };
        for (a, b) in vals.iter().zip(&payload) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn back_to_back_frames_parse_in_order() {
        let mut buf = Vec::new();
        for msg in all_kinds() {
            write_msg(&mut buf, &msg).unwrap();
        }
        let mut r = buf.as_slice();
        for msg in all_kinds() {
            assert_eq!(read_msg(&mut r).unwrap(), msg);
        }
        assert!(read_msg_or_eof(&mut r).unwrap().is_none(), "clean EOF after last frame");
    }

    #[test]
    fn checksum_mismatch_is_rejected_loudly() {
        let msg = Msg::Result { iter: 3, payload: vec![1.0, 2.0] };
        let mut buf = frame(&msg);
        let body_byte = 17 + 9; // inside the payload, after iter + count
        buf[body_byte] ^= 0x40;
        let err = read_msg(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err:#}");
    }

    #[test]
    fn flipped_kind_tag_also_fails_the_checksum() {
        // the checksum covers the kind tag, not just the body
        let mut buf = frame(&Msg::Shutdown);
        buf[8] = K_HELLO;
        let err = read_msg(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "{err:#}");
    }

    #[test]
    fn oversized_length_header_is_rejected_before_allocating() {
        let mut buf = Vec::new();
        buf.extend_from_slice(WIRE_MAGIC);
        buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        buf.push(K_RESULT);
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        let err = read_msg(&mut buf.as_slice()).unwrap_err();
        let s = err.to_string();
        assert!(s.contains("exceeds") && s.contains("bound"), "{err:#}");
    }

    #[test]
    fn version_skew_is_refused_with_both_versions_named() {
        let mut buf = Vec::new();
        write_msg_with_version(&mut buf, &Msg::Hello { rows: 1, cols: 1 }, WIRE_VERSION + 1)
            .unwrap();
        let err = read_msg(&mut buf.as_slice()).unwrap_err();
        let s = err.to_string();
        assert!(s.contains("version skew"), "{err:#}");
        let theirs = format!("v{}", WIRE_VERSION + 1);
        let ours = format!("v{WIRE_VERSION}");
        assert!(s.contains(&theirs) && s.contains(&ours), "both versions named: {err:#}");
    }

    #[test]
    fn bad_magic_is_rejected() {
        let mut buf = frame(&Msg::Shutdown);
        buf[0] = b'X';
        let err = read_msg(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("bad wire magic"), "{err:#}");
    }

    #[test]
    fn truncation_anywhere_is_a_torn_frame_not_a_hang_or_panic() {
        let full = frame(&Msg::Task {
            iter: 1,
            kind: 0,
            payload: vec![1.0, 2.0, 3.0],
            aux: vec![],
        });
        // cut at every prefix length except 0 (which is a clean EOF)
        for cut in 1..full.len() {
            let err = read_msg(&mut &full[..cut]).unwrap_err();
            assert!(
                err.to_string().contains("torn frame"),
                "cut at {cut}/{}: {err:#}",
                full.len()
            );
        }
        assert!(read_msg_or_eof(&mut &full[..0]).unwrap().is_none());
    }

    #[test]
    fn unknown_kind_tag_is_rejected() {
        // craft a checksum-valid frame with an unassigned kind tag
        let mut buf = Vec::new();
        buf.extend_from_slice(WIRE_MAGIC);
        buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        buf.push(99);
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&fnv1a64(FNV_OFFSET, &[99]).to_le_bytes());
        let err = read_msg(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("unknown wire message kind"), "{err:#}");
    }

    #[test]
    fn inconsistent_inner_vector_length_is_rejected() {
        // a Result whose inner count promises more f64s than the body
        // holds: body-level bounds catch it (defense past the checksum,
        // which an in-protocol attacker could recompute)
        let mut body = Vec::new();
        body.extend_from_slice(&0u64.to_le_bytes()); // iter
        body.extend_from_slice(&1000u64.to_le_bytes()); // count: lies
        body.extend_from_slice(&1.0f64.to_le_bytes()); // only one value
        let mut buf = Vec::new();
        buf.extend_from_slice(WIRE_MAGIC);
        buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        buf.push(K_RESULT);
        buf.extend_from_slice(&(body.len() as u64).to_le_bytes());
        buf.extend_from_slice(&body);
        buf.extend_from_slice(&fnv1a64(fnv1a64(FNV_OFFSET, &[K_RESULT]), &body).to_le_bytes());
        let err = read_msg(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("underrun"), "{err:#}");
    }

    #[test]
    fn trailing_bytes_in_a_body_are_rejected() {
        let mut body = Vec::new();
        body.extend_from_slice(&1u64.to_le_bytes());
        body.extend_from_slice(&2u64.to_le_bytes());
        body.push(0xAB); // one byte too many for a Hello
        let mut buf = Vec::new();
        buf.extend_from_slice(WIRE_MAGIC);
        buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        buf.push(K_HELLO);
        buf.extend_from_slice(&(body.len() as u64).to_le_bytes());
        buf.extend_from_slice(&body);
        buf.extend_from_slice(&fnv1a64(fnv1a64(FNV_OFFSET, &[K_HELLO]), &body).to_le_bytes());
        let err = read_msg(&mut buf.as_slice()).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err:#}");
    }
}
