//! Real-thread cluster: one OS thread per worker, std::mpsc messaging,
//! atomic interrupt lines, wall-clock timing.
//!
//! This is the production coordinator path: worker `process()` does real
//! compute (native kernels or PJRT executions of the AOT artifacts).
//! Injected straggler delays are sampled master-side per round and
//! slept worker-side in small chunks so an interrupt cancels the
//! remainder — mirroring the paper's footnote 1 (master sends an
//! interrupt signal; a listener thread at the worker aborts the
//! computation).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::{Gather, Response, RoundResult, Task, WorkerNode};
use crate::delay::DelayModel;

enum Msg {
    Run(Task, /*injected delay secs*/ f64),
    Shutdown,
}

struct ResultMsg {
    worker: usize,
    iter: usize,
    payload: Vec<f64>,
}

/// Sentinel meaning "no iteration is interrupted".
const NO_ABORT: u64 = u64::MAX;

/// Granularity of interruptible sleep.
const SLEEP_CHUNK: Duration = Duration::from_micros(200);

/// Wall-clock master/worker cluster.
pub struct ThreadCluster {
    task_txs: Vec<Sender<Msg>>,
    results: Receiver<ResultMsg>,
    abort_iter: Vec<Arc<AtomicU64>>,
    handles: Vec<JoinHandle<()>>,
    delay: Box<dyn DelayModel>,
    /// Injected delays are multiplied by this factor (scale the paper's
    /// 20-second stragglers down to test-friendly milliseconds).
    pub delay_scale: f64,
    /// Per-worker compute-speed multiplier (≥ 1 means slower hardware).
    /// Real compute cannot be slowed down, so a worker at speed `s`
    /// sleeps an extra `(s − 1)·cost·compute_unit` seconds per task —
    /// the ms-scale mirror of `SimCluster`'s compute scaling.
    speeds: Vec<f64>,
    /// Per-worker [`WorkerNode::cost`], captured at construction.
    costs: Vec<f64>,
    /// Emulated seconds of compute per unit of cost for the speed
    /// handicap (default 1 ms).
    pub compute_unit: f64,
    started: Instant,
    iter: usize,
}

impl ThreadCluster {
    pub fn new(workers: Vec<Box<dyn WorkerNode>>, delay: Box<dyn DelayModel>) -> Self {
        assert_eq!(workers.len(), delay.workers(), "delay model sized for wrong m");
        let m = workers.len();
        let costs: Vec<f64> = workers.iter().map(|w| w.cost()).collect();
        let (res_tx, res_rx) = channel::<ResultMsg>();
        let mut task_txs = Vec::with_capacity(m);
        let mut abort_iter = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        for (id, mut worker) in workers.into_iter().enumerate() {
            let (tx, rx) = channel::<Msg>();
            let abort = Arc::new(AtomicU64::new(NO_ABORT));
            let res = res_tx.clone();
            let abort_w = Arc::clone(&abort);
            let handle = std::thread::Builder::new()
                .name(format!("coded-opt-worker-{id}"))
                .spawn(move || worker_loop(id, &mut *worker, &rx, &res, &abort_w))
                .expect("spawn worker thread");
            task_txs.push(tx);
            abort_iter.push(abort);
            handles.push(handle);
        }
        ThreadCluster {
            task_txs,
            results: res_rx,
            abort_iter,
            handles,
            delay,
            delay_scale: 1.0,
            speeds: vec![1.0; m],
            costs,
            compute_unit: 1e-3,
            started: Instant::now(),
            iter: 0,
        }
    }

    pub fn with_delay_scale(mut self, scale: f64) -> Self {
        self.delay_scale = scale;
        self
    }

    /// Heterogeneous per-worker compute-speed multipliers (see the
    /// `speeds` field for the sleep-handicap semantics).
    pub fn with_speeds(mut self, speeds: Vec<f64>) -> Self {
        assert_eq!(speeds.len(), self.task_txs.len(), "one speed per worker");
        assert!(
            speeds.iter().all(|s| s.is_finite() && *s > 0.0),
            "speed multipliers must be finite and > 0"
        );
        self.speeds = speeds;
        self
    }

    /// Emulated seconds of compute per unit of cost used by the speed
    /// handicap. Default 1 ms.
    pub fn with_compute_unit(mut self, secs: f64) -> Self {
        assert!(secs.is_finite() && secs >= 0.0);
        self.compute_unit = secs;
        self
    }
}

fn worker_loop(
    id: usize,
    worker: &mut dyn WorkerNode,
    rx: &Receiver<Msg>,
    res: &Sender<ResultMsg>,
    abort: &AtomicU64,
) {
    while let Ok(msg) = rx.recv() {
        let (task, delay) = match msg {
            Msg::Run(task, delay) => (task, delay),
            Msg::Shutdown => break,
        };
        let iter = task.iter as u64;
        // Interruptible sleep simulating the injected straggler latency.
        let deadline = Instant::now() + Duration::from_secs_f64(delay.max(0.0));
        let mut interrupted = false;
        while Instant::now() < deadline {
            if abort.load(Ordering::Acquire) == iter {
                interrupted = true;
                break;
            }
            // saturating: the deadline may pass between the loop check
            // and the subtraction
            std::thread::sleep(SLEEP_CHUNK.min(deadline.saturating_duration_since(Instant::now())));
        }
        if interrupted || abort.load(Ordering::Acquire) == iter {
            continue; // drop the task; master moved on without us
        }
        let payload = worker.process(&task);
        if abort.load(Ordering::Acquire) == iter {
            continue; // interrupted mid-compute: do not send (footnote 1)
        }
        // Master may have dropped the receiver during shutdown.
        let _ = res.send(ResultMsg { worker: id, iter: task.iter, payload });
    }
}

impl ThreadCluster {
    /// Shared round body. `clamp` selects [`Gather::round_clamped`]'s
    /// behavior: hold k down to the live count instead of panicking.
    fn round_impl(
        &mut self,
        k: usize,
        clamp: bool,
        task_for: &mut dyn FnMut(usize) -> Task,
    ) -> RoundResult {
        let m = self.task_txs.len();
        assert!(k >= 1 && k <= m, "k={k} out of range for m={m}");
        let iter = self.iter;
        let round_start = Instant::now();
        // A crashed worker (infinite injected delay) is never dispatched:
        // it cannot respond this round, exactly like a real dead node.
        let mut dispatched = vec![false; m];
        for i in 0..m {
            // sanitize: NaN → crashed, negatives clamped — same boundary
            // rule as SimCluster, so a pathological composition behaves
            // identically on both engines.
            let delay = crate::delay::sanitize_delay(self.delay.sample(i, iter));
            if !delay.is_finite() {
                continue;
            }
            let handicap = (self.speeds[i] - 1.0).max(0.0) * self.costs[i] * self.compute_unit;
            let task = task_for(i);
            debug_assert_eq!(task.iter, iter, "task iter mismatch");
            self.task_txs[i]
                .send(Msg::Run(task, delay * self.delay_scale + handicap))
                .expect("worker alive");
            dispatched[i] = true;
        }
        let live = dispatched.iter().filter(|&&d| d).count();
        let k = if clamp {
            assert!(live >= 1, "round {iter}: no live (non-crashed) workers of m={m}");
            k.min(live)
        } else {
            assert!(
                k <= live,
                "round {iter}: k={k} but only {live} live (non-crashed) workers of m={m}"
            );
            k
        };
        let mut responses: Vec<Response> = Vec::with_capacity(k);
        let mut responded = vec![false; m];
        while responses.len() < k {
            let msg = self.results.recv().expect("workers alive");
            if msg.iter != iter {
                continue; // stale result from an interrupted past round
            }
            responded[msg.worker] = true;
            responses.push(Response {
                worker: msg.worker,
                payload: msg.payload,
                arrival: round_start.elapsed().as_secs_f64(),
            });
        }
        // Interrupt the stragglers (A_tᶜ); crashed workers never got the
        // task, so there is nothing to abort, but they are still erased.
        let mut interrupted = Vec::with_capacity(m - k);
        for i in 0..m {
            if !responded[i] {
                if dispatched[i] {
                    self.abort_iter[i].store(iter as u64, Ordering::Release);
                }
                interrupted.push(i);
            }
        }
        let elapsed = responses.last().map(|r| r.arrival).unwrap_or(0.0);
        self.iter += 1;
        RoundResult { responses, elapsed, interrupted, live }
    }
}

impl Gather for ThreadCluster {
    fn round(&mut self, k: usize, task_for: &mut dyn FnMut(usize) -> Task) -> RoundResult {
        self.round_impl(k, false, task_for)
    }

    fn round_clamped(&mut self, k: usize, task_for: &mut dyn FnMut(usize) -> Task) -> RoundResult {
        self.round_impl(k, true, task_for)
    }

    fn workers(&self) -> usize {
        self.task_txs.len()
    }

    fn clock(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }
}

impl Drop for ThreadCluster {
    fn drop(&mut self) {
        for tx in &self.task_txs {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{AdversarialDelay, NoDelay};

    struct Echo {
        id: usize,
    }

    impl WorkerNode for Echo {
        fn process(&mut self, task: &Task) -> Vec<f64> {
            vec![self.id as f64, task.iter as f64, task.payload.iter().sum()]
        }
    }

    fn mk(m: usize, delay: Box<dyn crate::delay::DelayModel>) -> ThreadCluster {
        let workers: Vec<Box<dyn WorkerNode>> =
            (0..m).map(|id| Box::new(Echo { id }) as Box<dyn WorkerNode>).collect();
        ThreadCluster::new(workers, delay)
    }

    fn task(iter: usize, payload: Vec<f64>) -> Task {
        Task { iter, kind: 0, payload, aux: vec![] }
    }

    #[test]
    fn gathers_k_of_m() {
        let mut c = mk(4, Box::new(NoDelay::new(4)));
        let rr = c.round(3, &mut |_| task(0, vec![1.0, 2.0]));
        assert_eq!(rr.responses.len(), 3);
        assert_eq!(rr.interrupted.len(), 1);
        for r in &rr.responses {
            assert_eq!(r.payload[2], 3.0);
        }
    }

    #[test]
    fn adversarial_stragglers_excluded() {
        // workers 0,1 delayed 50 ms; k=2 of 4 → 2,3 always win.
        let delay = AdversarialDelay::new(4, vec![0, 1], 0.05);
        let mut c = mk(4, Box::new(delay));
        for t in 0..3 {
            let rr = c.round(2, &mut |_| task(t, vec![]));
            assert_eq!(rr.active_set(), vec![2, 3], "iter {t}");
        }
    }

    #[test]
    fn stale_results_discarded_across_rounds() {
        // Round 0 interrupts the slow pair mid-sleep; round 1 must still
        // return exactly k fresh responses with the right iter tag.
        let delay = AdversarialDelay::new(3, vec![2], 0.02);
        let mut c = mk(3, Box::new(delay));
        let r0 = c.round(2, &mut |_| task(0, vec![]));
        assert_eq!(r0.active_set(), vec![0, 1]);
        let r1 = c.round(3, &mut |_| task(1, vec![]));
        for r in &r1.responses {
            assert_eq!(r.payload[1], 1.0, "payload iter tag");
        }
    }

    #[test]
    fn multiple_rounds_advance() {
        let mut c = mk(2, Box::new(NoDelay::new(2)));
        for t in 0..5 {
            let rr = c.round(2, &mut |_| task(t, vec![t as f64]));
            assert_eq!(rr.responses.len(), 2);
            for r in &rr.responses {
                assert_eq!(r.payload[1], t as f64);
            }
        }
        assert!(c.clock() > 0.0);
    }

    #[test]
    fn crashed_worker_is_never_dispatched_and_rejoins() {
        // worker 2 crashed (infinite delay) for round 0 only
        let delay = crate::delay::TraceDelay::new(vec![
            vec![0.0, 0.0, f64::INFINITY],
            vec![0.0, 0.0, 0.0],
        ]);
        let mut c = mk(3, Box::new(delay));
        let r0 = c.round(2, &mut |_| task(0, vec![]));
        assert_eq!(r0.active_set(), vec![0, 1]);
        assert!(r0.interrupted.contains(&2));
        let r1 = c.round(3, &mut |_| task(1, vec![]));
        assert_eq!(r1.active_set(), vec![0, 1, 2], "crashed worker rejoins");
        for r in &r1.responses {
            assert_eq!(r.payload[1], 1.0, "fresh iter tag after rejoin");
        }
    }

    #[test]
    #[should_panic(expected = "live")]
    fn waiting_for_a_crashed_worker_panics() {
        let delay = crate::delay::TraceDelay::new(vec![vec![0.0, f64::INFINITY]]);
        let mut c = mk(2, Box::new(delay));
        c.round(2, &mut |_| task(0, vec![]));
    }

    #[test]
    fn clamped_round_holds_k_to_live() {
        let delay = crate::delay::TraceDelay::new(vec![
            vec![0.0, f64::INFINITY],
            vec![0.0, 0.0],
        ]);
        let mut c = mk(2, Box::new(delay));
        let r0 = c.round_clamped(2, &mut |_| task(0, vec![]));
        assert_eq!(r0.responses.len(), 1);
        assert_eq!(r0.live, 1);
        assert_eq!(r0.active_set(), vec![0]);
        let r1 = c.round_clamped(2, &mut |_| task(1, vec![]));
        assert_eq!(r1.responses.len(), 2);
        assert_eq!(r1.live, 2);
    }

    #[test]
    fn speed_handicap_slows_a_worker() {
        // worker 0 at 100× speed handicap with a 1 ms compute unit →
        // ~0.1 s extra sleep; k=1 of 2 ⇒ worker 1 always wins.
        let mut c = mk(2, Box::new(NoDelay::new(2)))
            .with_speeds(vec![101.0, 1.0])
            .with_compute_unit(1e-3);
        for t in 0..3 {
            let rr = c.round(1, &mut |_| task(t, vec![]));
            assert_eq!(rr.active_set(), vec![1], "iter {t}");
        }
    }

    #[test]
    fn delay_scale_shrinks_waits() {
        let delay = AdversarialDelay::new(2, vec![1], 100.0); // 100 s !
        let mut c = mk(2, Box::new(delay)).with_delay_scale(1e-4); // → 10 ms
        let t0 = Instant::now();
        let rr = c.round(2, &mut |_| task(0, vec![]));
        assert!(t0.elapsed().as_secs_f64() < 5.0);
        assert_eq!(rr.responses.len(), 2);
    }
}
