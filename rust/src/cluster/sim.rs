//! Virtual-clock cluster simulation.
//!
//! Single-threaded and fully deterministic: per round, each worker's
//! hypothetical finish time is `cost·secs_per_unit·speed_i + delay(i, t)`;
//! the k smallest arrivals form A_t, *only those workers actually execute*
//! (stragglers are interrupted before completing, exactly like the
//! paper's Algorithm 1 line 6), and the round advances the virtual clock
//! by the k-th arrival time plus a fixed master overhead.
//!
//! An infinite delay ([`crate::delay::CRASHED`]) marks a worker as
//! crashed for the round: it can never make the fastest-k set, which is
//! exactly the paper's erasure semantics. The round asserts that at
//! least `k` live workers remain.

use super::{Gather, Response, RoundResult, Task, WorkerNode};
use crate::delay::DelayModel;

/// Deterministic virtual-time cluster.
pub struct SimCluster {
    workers: Vec<Box<dyn WorkerNode>>,
    delay: Box<dyn DelayModel>,
    /// Seconds of compute per unit of [`WorkerNode::cost`].
    pub secs_per_unit: f64,
    /// Master-side per-round overhead (broadcast + step computation).
    pub master_overhead: f64,
    /// Per-worker compute-speed multiplier (≥ 1 means slower hardware;
    /// scales the simulated compute time, not the injected delay).
    speed: Vec<f64>,
    clock: f64,
    iter: usize,
}

impl SimCluster {
    pub fn new(workers: Vec<Box<dyn WorkerNode>>, delay: Box<dyn DelayModel>) -> Self {
        assert_eq!(workers.len(), delay.workers(), "delay model sized for wrong m");
        let m = workers.len();
        SimCluster {
            workers,
            delay,
            secs_per_unit: 0.01,
            master_overhead: 0.001,
            speed: vec![1.0; m],
            clock: 0.0,
            iter: 0,
        }
    }

    pub fn with_timing(mut self, secs_per_unit: f64, master_overhead: f64) -> Self {
        self.secs_per_unit = secs_per_unit;
        self.master_overhead = master_overhead;
        self
    }

    /// Heterogeneous per-worker compute-speed multipliers.
    pub fn with_speeds(mut self, speeds: Vec<f64>) -> Self {
        assert_eq!(speeds.len(), self.workers.len(), "one speed per worker");
        assert!(
            speeds.iter().all(|s| s.is_finite() && *s > 0.0),
            "speed multipliers must be finite and > 0"
        );
        self.speed = speeds;
        self
    }

    /// Current iteration counter (rounds completed).
    pub fn iterations(&self) -> usize {
        self.iter
    }

    /// Mutable access to a worker (tests / state inspection).
    pub fn worker_mut(&mut self, i: usize) -> &mut dyn WorkerNode {
        self.workers[i].as_mut()
    }
}

impl SimCluster {
    /// Shared round body. `clamp` selects [`Gather::round_clamped`]'s
    /// behavior: hold k down to the live count instead of panicking.
    fn round_impl(
        &mut self,
        k: usize,
        clamp: bool,
        task_for: &mut dyn FnMut(usize) -> Task,
    ) -> RoundResult {
        let m = self.workers.len();
        assert!(k >= 1 && k <= m, "k={k} out of range for m={m}");
        // Arrival time of each worker if it were allowed to finish.
        // Delays pass through `sanitize_delay` (NaN → crashed, negatives
        // clamped) and the sort uses the total order, so a pathological
        // delay composition can never panic the release-build sort —
        // `sort_by(partial_cmp(..).unwrap())` did, once the debug_assert
        // was compiled out.
        let mut arrivals: Vec<(f64, usize)> = (0..m)
            .map(|i| {
                let d = crate::delay::sanitize_delay(self.delay.sample(i, self.iter));
                (self.workers[i].cost() * self.secs_per_unit * self.speed[i] + d, i)
            })
            .collect();
        arrivals.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        // Crashed workers (infinite delay) can never be waited for.
        let live = arrivals.iter().take_while(|(t, _)| t.is_finite()).count();
        let k = if clamp {
            assert!(live >= 1, "round {}: no live (non-crashed) workers of m={m}", self.iter);
            k.min(live)
        } else {
            assert!(
                k <= live,
                "round {}: k={k} but only {live} live (non-crashed) workers of m={m}",
                self.iter
            );
            k
        };
        let winners = &arrivals[..k];
        let elapsed = winners.last().unwrap().0;
        let mut responses = Vec::with_capacity(k);
        for &(arrival, i) in winners {
            let task = task_for(i);
            debug_assert_eq!(task.iter, self.iter, "task iter mismatch");
            let payload = self.workers[i].process(&task);
            responses.push(Response { worker: i, payload, arrival });
        }
        let interrupted: Vec<usize> = arrivals[k..].iter().map(|&(_, i)| i).collect();
        self.clock += elapsed + self.master_overhead;
        self.iter += 1;
        RoundResult { responses, elapsed, interrupted, live }
    }
}

impl Gather for SimCluster {
    fn round(&mut self, k: usize, task_for: &mut dyn FnMut(usize) -> Task) -> RoundResult {
        self.round_impl(k, false, task_for)
    }

    fn round_clamped(&mut self, k: usize, task_for: &mut dyn FnMut(usize) -> Task) -> RoundResult {
        self.round_impl(k, true, task_for)
    }

    fn workers(&self) -> usize {
        self.workers.len()
    }

    fn clock(&self) -> f64 {
        self.clock
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::{AdversarialDelay, ConstantDelay, NoDelay};

    /// Worker that returns its id and the iter it saw; counts calls.
    struct Echo {
        id: usize,
        calls: usize,
        cost: f64,
    }

    impl WorkerNode for Echo {
        fn process(&mut self, task: &Task) -> Vec<f64> {
            self.calls += 1;
            vec![self.id as f64, task.iter as f64]
        }
        fn cost(&self) -> f64 {
            self.cost
        }
    }

    fn mk_cluster(m: usize, delay: Box<dyn crate::delay::DelayModel>) -> SimCluster {
        let workers: Vec<Box<dyn WorkerNode>> = (0..m)
            .map(|id| Box::new(Echo { id, calls: 0, cost: 1.0 }) as Box<dyn WorkerNode>)
            .collect();
        SimCluster::new(workers, delay)
    }

    fn task(iter: usize) -> Task {
        Task { iter, kind: 0, payload: vec![], aux: vec![] }
    }

    #[test]
    fn waits_for_exactly_k() {
        let mut c = mk_cluster(6, Box::new(NoDelay::new(6)));
        let rr = c.round(4, &mut |_| task(0));
        assert_eq!(rr.responses.len(), 4);
        assert_eq!(rr.interrupted.len(), 2);
        let mut all = rr.active_set();
        all.extend(&rr.interrupted);
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5], "A_t ⊎ A_tᶜ = [m]");
    }

    #[test]
    fn stragglers_never_execute() {
        // workers 1 and 3 adversarially slow: they must never process.
        let delay = AdversarialDelay::new(4, vec![1, 3], 100.0);
        let mut c = mk_cluster(4, Box::new(delay));
        for t in 0..5 {
            let rr = c.round(2, &mut |_| task(t));
            assert_eq!(rr.active_set(), vec![0, 2]);
        }
        // inspect call counts via payloads: run one more round and check
        // worker 0 payload says iter 5 (it ran all 6 rounds)
        let rr = c.round(2, &mut |_| task(5));
        assert_eq!(rr.responses[0].payload[1], 5.0);
    }

    #[test]
    fn clock_advances_by_kth_arrival() {
        let mut c = mk_cluster(4, Box::new(ConstantDelay::new(4, 0.5)))
            .with_timing(0.1, 0.0);
        let rr = c.round(2, &mut |_| task(0));
        // all arrivals = 0.1·1 + 0.5 = 0.6
        assert!((rr.elapsed - 0.6).abs() < 1e-12);
        assert!((c.clock() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn heterogeneous_costs_order_arrivals() {
        let workers: Vec<Box<dyn WorkerNode>> = (0..3)
            .map(|id| Box::new(Echo { id, calls: 0, cost: (id + 1) as f64 }) as Box<dyn WorkerNode>)
            .collect();
        let mut c = SimCluster::new(workers, Box::new(NoDelay::new(3))).with_timing(1.0, 0.0);
        let rr = c.round(2, &mut |_| task(0));
        assert_eq!(rr.arrival_order(), vec![0, 1]);
        assert_eq!(rr.interrupted, vec![2]);
    }

    #[test]
    fn k_equals_m_no_interrupts() {
        let mut c = mk_cluster(3, Box::new(NoDelay::new(3)));
        let rr = c.round(3, &mut |_| task(0));
        assert!(rr.interrupted.is_empty());
        assert_eq!(rr.responses.len(), 3);
    }

    #[test]
    #[should_panic]
    fn k_zero_rejected() {
        let mut c = mk_cluster(3, Box::new(NoDelay::new(3)));
        c.round(0, &mut |_| task(0));
    }

    #[test]
    fn speeds_reorder_arrivals() {
        // equal costs, worker 0 on 10× slower hardware → always last
        let mut c = mk_cluster(3, Box::new(NoDelay::new(3)))
            .with_timing(1.0, 0.0)
            .with_speeds(vec![10.0, 1.0, 1.0]);
        let rr = c.round(2, &mut |_| task(0));
        assert_eq!(rr.interrupted, vec![0]);
        assert!((rr.elapsed - 1.0).abs() < 1e-12);
    }

    #[test]
    fn crashed_workers_are_erased_and_rejoin() {
        // worker 1 crashed (infinite delay) in round 0, back in round 1
        let delay = crate::delay::TraceDelay::new(vec![
            vec![0.0, f64::INFINITY, 0.0],
            vec![0.0, 0.0, 0.0],
        ]);
        let mut c = mk_cluster(3, Box::new(delay));
        let r0 = c.round(2, &mut |_| task(0));
        assert_eq!(r0.active_set(), vec![0, 2]);
        assert!(r0.interrupted.contains(&1));
        assert!(r0.elapsed.is_finite() && c.clock().is_finite());
        let r1 = c.round(3, &mut |_| task(1));
        assert_eq!(r1.active_set(), vec![0, 1, 2], "crashed worker rejoins");
    }

    #[test]
    #[should_panic(expected = "live")]
    fn waiting_for_a_crashed_worker_panics() {
        let delay = crate::delay::TraceDelay::new(vec![vec![0.0, f64::INFINITY]]);
        let mut c = mk_cluster(2, Box::new(delay));
        c.round(2, &mut |_| task(0));
    }

    #[test]
    fn clamped_round_holds_k_to_live() {
        // worker 1 crashed: round_clamped(2) must deliver 1 response
        // instead of panicking, and report live=1.
        let delay = crate::delay::TraceDelay::new(vec![
            vec![0.0, f64::INFINITY],
            vec![0.0, 0.0],
        ]);
        let mut c = mk_cluster(2, Box::new(delay));
        let r0 = c.round_clamped(2, &mut |_| task(0));
        assert_eq!(r0.responses.len(), 1);
        assert_eq!(r0.live, 1);
        assert_eq!(r0.active_set(), vec![0]);
        // next round both live again: full k honored, live reported
        let r1 = c.round_clamped(2, &mut |_| task(1));
        assert_eq!(r1.responses.len(), 2);
        assert_eq!(r1.live, 2);
    }

    #[test]
    #[should_panic(expected = "no live")]
    fn clamped_round_still_panics_with_zero_live() {
        let delay = crate::delay::TraceDelay::new(vec![vec![f64::INFINITY, f64::INFINITY]]);
        let mut c = mk_cluster(2, Box::new(delay));
        c.round_clamped(1, &mut |_| task(0));
    }

    #[test]
    fn nan_delay_is_an_erasure_not_a_panic() {
        // A delay model that leaks NaN (e.g. a hand-edited replay tape,
        // or a transform composing 0·∞) must behave like a crash: the
        // worker is erased for the round, the sort never sees NaN, and
        // the clock stays finite. The old partial_cmp().unwrap() sort
        // panicked here in release builds (the debug_assert guarding it
        // is compiled out).
        struct NanDelay;
        impl crate::delay::DelayModel for NanDelay {
            fn sample(&mut self, worker: usize, _iter: usize) -> f64 {
                if worker == 1 {
                    f64::NAN
                } else {
                    0.0
                }
            }
            fn workers(&self) -> usize {
                3
            }
        }
        let mut c = mk_cluster(3, Box::new(NanDelay));
        let rr = c.round(2, &mut |_| task(0));
        assert_eq!(rr.active_set(), vec![0, 2], "NaN worker erased");
        assert!(rr.interrupted.contains(&1));
        assert!(rr.elapsed.is_finite() && c.clock().is_finite());
    }

    #[test]
    fn negative_delays_clamp_to_zero() {
        let delay = crate::delay::TraceDelay::new(vec![vec![-5.0, 0.0]]);
        let mut c = mk_cluster(2, Box::new(delay)).with_timing(0.1, 0.0);
        let rr = c.round(2, &mut |_| task(0));
        // both arrivals = compute floor 0.1; a negative delay must not
        // let a worker arrive before its compute finishes
        assert!((rr.elapsed - 0.1).abs() < 1e-12, "elapsed {}", rr.elapsed);
        assert!(rr.responses.iter().all(|r| (r.arrival - 0.1).abs() < 1e-12));
    }

    #[test]
    fn iteration_counter_increments() {
        let mut c = mk_cluster(2, Box::new(NoDelay::new(2)));
        for t in 0..4 {
            assert_eq!(c.iterations(), t);
            c.round(1, &mut |_| task(t));
        }
    }
}
