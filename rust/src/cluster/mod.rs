//! Simulated distributed cluster: master/worker substrate with
//! wait-for-k gather and straggler interrupts.
//!
//! Substitutes for the paper's EC2 deployments (see DESIGN.md §5): the
//! paper's own MovieLens experiment already runs on a single machine with
//! injected latencies, and the straggler phenomenology lives entirely in
//! the delay distribution + wait-for-k semantics, both of which are
//! reproduced exactly here.
//!
//! Three engines share the [`WorkerNode`] / round-gather contract:
//! - [`sim::SimCluster`] — virtual-clock, single-threaded, fully
//!   deterministic. Drives all paper-figure benches (time axis =
//!   simulated seconds).
//! - [`threads::ThreadCluster`] — real OS threads, std::mpsc messaging,
//!   `AtomicU64` interrupt lines, wall-clock timing. Drives the examples
//!   and the PJRT-backed end-to-end run.
//! - [`socket::SocketCluster`] — multi-process TCP over the hand-rolled
//!   [`wire`] frame format, workers streaming pre-encoded partitions
//!   from their own disks (`coded-opt worker`). Virtual-clock like
//!   `SimCluster` — injected delays are enforced by the master's winner
//!   selection, not wall clock — so a replayed delay tape reproduces a
//!   `SimCluster` trace bit for bit across real processes.
//!
//! All engines support heterogeneous per-worker compute speeds
//! (`with_speeds`) and crash semantics: an infinite injected delay
//! ([`crate::delay::CRASHED`], produced e.g. by a
//! [`crate::scenario`] crash window) means the worker cannot respond
//! this round — `SimCluster` gives it an infinite arrival time,
//! `ThreadCluster` never dispatches to it, `SocketCluster` additionally
//! maps every transport/protocol fault (disconnect, timeout, torn or
//! stale frame) onto the same erasure — and the wait-for-k gather
//! erases it exactly like any other straggler (the paper's
//! stragglers-as-erasures model; each round asserts ≥ k live workers).

pub mod sim;
pub mod socket;
pub mod threads;
pub mod wire;

pub use sim::SimCluster;
pub use socket::{SocketCluster, WorkerServer};
pub use threads::ThreadCluster;

/// A task broadcast from the master to workers in one round.
#[derive(Clone, Debug)]
pub struct Task {
    /// Iteration index t (workers echo it back; stale results discarded).
    pub iter: usize,
    /// Operation selector, interpreted by the worker implementation
    /// (e.g. 0 = gradient, 1 = line-search matvec, 2 = BCD step).
    pub kind: u32,
    /// Main payload (e.g. the iterate w_t, or the direction d_t).
    pub payload: Vec<f64>,
    /// Auxiliary payload (e.g. BCD's (I_{i,t−1}, z̃_{i,t})).
    pub aux: Vec<f64>,
}

/// One worker's computational endpoint. Implementations own their shard
/// of the encoded data and any local state (e.g. BCD's v_i).
pub trait WorkerNode: Send {
    /// Execute a task, returning the update payload sent to the master.
    fn process(&mut self, task: &Task) -> Vec<f64>;

    /// Relative compute cost of one task (arrival time = cost ·
    /// seconds-per-unit + injected delay). Defaults to 1.
    fn cost(&self) -> f64 {
        1.0
    }
}

/// A single worker response.
#[derive(Clone, Debug)]
pub struct Response {
    pub worker: usize,
    pub payload: Vec<f64>,
    /// Arrival time (seconds since round start).
    pub arrival: f64,
}

/// Result of one wait-for-k round.
#[derive(Clone, Debug)]
pub struct RoundResult {
    /// The k fastest responses, in arrival order — A_t with payloads.
    pub responses: Vec<Response>,
    /// Time the round took (arrival of the k-th response).
    pub elapsed: f64,
    /// Workers that were interrupted (A_tᶜ).
    pub interrupted: Vec<usize>,
    /// Non-crashed workers at dispatch time — the ceiling a clamped
    /// round's effective k was held to (see [`Gather::round_clamped`]).
    pub live: usize,
}

impl RoundResult {
    /// The active set A_t (sorted worker ids).
    pub fn active_set(&self) -> Vec<usize> {
        let mut a: Vec<usize> = self.responses.iter().map(|r| r.worker).collect();
        a.sort_unstable();
        a
    }

    /// Workers in arrival order (fastest first).
    pub fn arrival_order(&self) -> Vec<usize> {
        self.responses.iter().map(|r| r.worker).collect()
    }
}

/// The round-gather contract shared by both engines.
pub trait Gather {
    /// Broadcast one task per worker (built by `task_for`), wait for the
    /// fastest `k` responses, interrupt the rest, return the round.
    /// Panics if fewer than `k` workers are live — a static wait-for-k
    /// run that outlives its erasure tolerance is a configuration error.
    fn round(&mut self, k: usize, task_for: &mut dyn FnMut(usize) -> Task) -> RoundResult;

    /// [`Gather::round`], but `k` is clamped down to the live worker
    /// count instead of panicking when crashes push `live` below `k` —
    /// the entry point the adaptive wait-for-k controller uses, since a
    /// controller's request is made *before* it can observe this round's
    /// crashes. Still panics when no worker at all is live. All three
    /// engines override this; the default delegates to [`Gather::round`]
    /// for exotic implementations that never lose workers.
    fn round_clamped(&mut self, k: usize, task_for: &mut dyn FnMut(usize) -> Task) -> RoundResult {
        self.round(k, task_for)
    }

    /// Worker count m.
    fn workers(&self) -> usize;

    /// Total simulated/wall time elapsed so far (seconds).
    fn clock(&self) -> f64;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_result_active_set_sorted() {
        let rr = RoundResult {
            responses: vec![
                Response { worker: 3, payload: vec![], arrival: 0.1 },
                Response { worker: 0, payload: vec![], arrival: 0.2 },
            ],
            elapsed: 0.2,
            interrupted: vec![1, 2],
            live: 4,
        };
        assert_eq!(rr.active_set(), vec![0, 3]);
        assert_eq!(rr.arrival_order(), vec![3, 0]);
    }
}
