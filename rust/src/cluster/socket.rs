//! Multi-process TCP cluster engine.
//!
//! [`SocketCluster`] is the *placement master* side of a multi-host
//! run: it connects to remote workers (each a `coded-opt worker
//! --listen ADDR --partition DIR` process that streamed its encoded
//! partition from local disk), drives the same wait-for-k
//! [`Gather`] round contract as [`SimCluster`], and maps every network
//! fault onto the paper's stragglers-as-erasures model.
//!
//! # Determinism: master-enforced virtual time
//!
//! The master samples the delay model itself and computes each worker's
//! **virtual** arrival with exactly [`SimCluster`]'s formula
//! (`cost·secs_per_unit·speed_i + sanitize_delay(delay(i, t))`, total
//! order + index tie-break). Injected delays are *enforced by
//! selection* — only the k virtual winners are dispatched over TCP —
//! never by wall-clock sleeps. Task and result payloads cross the wire
//! as exact little-endian `f64` bits, so a recorded delay tape replayed
//! through real processes on localhost produces a trace **bit-identical**
//! to [`SimCluster`] replaying the same tape (pinned by
//! `rust/tests/socket_cluster.rs` and the CI `socket-smoke` job). Wall
//! clock appears only as connect/read *timeouts*, which exist to detect
//! faults and can never influence a fault-free trace.
//!
//! # Faults are erasures
//!
//! Any protocol or transport failure — disconnect, read timeout, torn
//! frame, checksum mismatch, a result echoing the wrong iteration —
//! permanently erases the worker: its connection is dropped and its
//! arrival is `+∞` from that point on, exactly a
//! [`crate::delay::CRASHED`] delay. If a *winner* dies mid-round, the
//! already-sampled arrivals are re-ranked with that worker at `+∞` and
//! the next-fastest live worker is dispatched instead (responses
//! already collected stay valid — erasing a worker only promotes
//! others). The `k ≤ live` assertion holds with [`SimCluster`]'s exact
//! message, and a stale payload can never reach a later round's
//! assembler: the iteration echo is checked before a payload is
//! accepted.
//!
//! [`SimCluster`]: super::SimCluster

use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::wire::{read_msg, read_msg_or_eof, write_msg, Msg};
use super::{Gather, Response, RoundResult, Task, WorkerNode};
use crate::delay::DelayModel;

/// Default per-connection I/O timeout (handshake, task write, result
/// read). Generous: it only bounds fault *detection*, never the trace.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(10);

/// The master side of a multi-process TCP cluster. See the module docs
/// for the determinism and fault model.
pub struct SocketCluster {
    /// `None` = erased (crashed / misbehaved / disconnected).
    conns: Vec<Option<TcpStream>>,
    addrs: Vec<String>,
    /// Partition shape `(rows, cols)` each worker reported in its
    /// `Hello`; `rows` drives the virtual-arrival cost model.
    shapes: Vec<(u64, u64)>,
    delay: Box<dyn DelayModel>,
    /// Seconds of virtual compute per unit of worker cost (a worker's
    /// cost is its partition row count, mirroring `QuadWorker::cost`).
    pub secs_per_unit: f64,
    /// Master-side per-round overhead on the virtual clock.
    pub master_overhead: f64,
    speed: Vec<f64>,
    clock: f64,
    iter: usize,
    io_timeout: Duration,
}

/// Retry `connect` until `deadline`: workers and master are commonly
/// launched concurrently, so the listener may not be up yet.
fn connect_retry(addr: &str, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e).with_context(|| format!("connecting to worker {addr}"));
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

impl SocketCluster {
    /// Connect to one worker per address (index order = partition
    /// order) and complete the `Hello` handshake with each. A peer
    /// speaking a different wire version is refused here, cleanly, with
    /// an error naming both versions.
    pub fn connect(addrs: &[String], delay: Box<dyn DelayModel>) -> Result<Self> {
        Self::connect_with_timeout(addrs, delay, DEFAULT_IO_TIMEOUT)
    }

    /// [`SocketCluster::connect`] with an explicit I/O timeout (connect
    /// retries, task writes, result reads). Fault-injection tests use a
    /// short timeout so a stalled peer is erased quickly.
    pub fn connect_with_timeout(
        addrs: &[String],
        delay: Box<dyn DelayModel>,
        io_timeout: Duration,
    ) -> Result<Self> {
        assert_eq!(addrs.len(), delay.workers(), "delay model sized for wrong m");
        ensure!(!addrs.is_empty(), "socket cluster needs at least one worker address");
        let deadline = Instant::now() + io_timeout;
        let mut conns = Vec::with_capacity(addrs.len());
        let mut shapes = Vec::with_capacity(addrs.len());
        for (i, addr) in addrs.iter().enumerate() {
            let mut stream = connect_retry(addr, deadline)?;
            stream.set_nodelay(true).ok();
            stream.set_read_timeout(Some(io_timeout))?;
            stream.set_write_timeout(Some(io_timeout))?;
            match read_msg(&mut stream)
                .with_context(|| format!("handshake with worker {i} ({addr})"))?
            {
                Msg::Hello { rows, cols } => shapes.push((rows, cols)),
                other => bail!(
                    "worker {i} ({addr}) opened with {} instead of Hello",
                    other.kind_name()
                ),
            }
            conns.push(Some(stream));
        }
        let m = addrs.len();
        Ok(SocketCluster {
            conns,
            addrs: addrs.to_vec(),
            shapes,
            delay,
            // SimCluster's defaults, so a driver-built socket run is
            // bit-identical to the equivalent sim run out of the box.
            secs_per_unit: 0.01,
            master_overhead: 0.001,
            speed: vec![1.0; m],
            clock: 0.0,
            iter: 0,
            io_timeout,
        })
    }

    /// Same builder as [`SimCluster::with_timing`](super::SimCluster::with_timing).
    pub fn with_timing(mut self, secs_per_unit: f64, master_overhead: f64) -> Self {
        self.secs_per_unit = secs_per_unit;
        self.master_overhead = master_overhead;
        self
    }

    /// Heterogeneous per-worker compute-speed multipliers (same
    /// contract as [`SimCluster::with_speeds`](super::SimCluster::with_speeds)).
    pub fn with_speeds(mut self, speeds: Vec<f64>) -> Self {
        assert_eq!(speeds.len(), self.conns.len(), "one speed per worker");
        assert!(
            speeds.iter().all(|s| s.is_finite() && *s > 0.0),
            "speed multipliers must be finite and > 0"
        );
        self.speed = speeds;
        self
    }

    /// Rounds completed.
    pub fn iterations(&self) -> usize {
        self.iter
    }

    /// Partition shape `(rows, cols)` each worker reported at handshake.
    pub fn partition_shapes(&self) -> &[(u64, u64)] {
        &self.shapes
    }

    /// Placement check: every worker must hold the partition its index
    /// implies — row counts from the encoding geometry, `cols = p`. A
    /// mismatch means a worker was pointed at the wrong `worker-NNN`
    /// directory (or the wrong encode entirely); refuse up front rather
    /// than assemble garbage gradients.
    pub fn verify_partitions(&self, expected_rows: &[u64], cols: u64) -> Result<()> {
        ensure!(
            expected_rows.len() == self.shapes.len(),
            "expected {} partition shapes, have {} workers",
            expected_rows.len(),
            self.shapes.len()
        );
        for (i, (&want_rows, &(rows, got_cols))) in
            expected_rows.iter().zip(&self.shapes).enumerate()
        {
            ensure!(
                got_cols == cols,
                "worker {i} ({}) holds a partition with {got_cols} columns, the \
                 problem has p={cols} — wrong dataset?",
                self.addrs[i]
            );
            ensure!(
                rows == want_rows,
                "worker {i} ({}) holds a {rows}-row partition but encoded partition \
                 {i} has {want_rows} rows — check that --worker-addrs order matches \
                 the worker-NNN partition order",
                self.addrs[i]
            );
        }
        Ok(())
    }

    /// Worker cost for the virtual-arrival formula — mirrors
    /// `QuadWorker::cost` (partition rows, min 1) so the socket engine
    /// ranks arrivals exactly like the in-process build of the same
    /// partitions.
    fn cost(&self, i: usize) -> f64 {
        self.shapes[i].0.max(1) as f64
    }

    /// One task→result exchange with worker `i`. Any error (transport,
    /// codec, or a result echoing the wrong iteration) is a fault the
    /// caller turns into an erasure.
    fn exchange(&mut self, i: usize, task: &Task) -> Result<Vec<f64>> {
        let stream = self.conns[i].as_mut().expect("dispatch to a live worker");
        write_msg(
            stream,
            &Msg::Task {
                iter: task.iter as u64,
                kind: task.kind,
                payload: task.payload.clone(),
                aux: task.aux.clone(),
            },
        )?;
        stream.flush()?;
        match read_msg(stream)? {
            Msg::Result { iter, payload } => {
                ensure!(
                    iter == task.iter as u64,
                    "stale result: worker echoed iteration {iter}, round is {} — \
                     protocol violation, payload dropped",
                    task.iter
                );
                Ok(payload)
            }
            other => bail!("expected Result, got {}", other.kind_name()),
        }
    }
}

impl SocketCluster {
    /// Shared round body. `clamp` selects [`Gather::round_clamped`]'s
    /// behavior: hold k down to the live count instead of panicking.
    /// The effective k is re-derived on every dispatch pass — a winner
    /// erased mid-round shrinks `live`, and a clamped round must track
    /// that instead of waiting for a replacement that may not exist.
    fn round_impl(
        &mut self,
        k: usize,
        clamp: bool,
        task_for: &mut dyn FnMut(usize) -> Task,
    ) -> RoundResult {
        let m = self.conns.len();
        assert!(k >= 1 && k <= m, "k={k} out of range for m={m}");
        // Virtual arrivals: SimCluster's exact formula over the same
        // sample order (0..m every round, so stateful delay models see
        // the same stream either engine). An already-erased worker's
        // arrival is forced to +∞ AFTER sampling, preserving that
        // alignment.
        let mut arrivals: Vec<(f64, usize)> = (0..m)
            .map(|i| {
                let d = crate::delay::sanitize_delay(self.delay.sample(i, self.iter));
                let t = self.cost(i) * self.secs_per_unit * self.speed[i] + d;
                if self.conns[i].is_some() {
                    (t, i)
                } else {
                    (f64::INFINITY, i)
                }
            })
            .collect();
        arrivals.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut payloads: Vec<Option<Vec<f64>>> = (0..m).map(|_| None).collect();
        let mut k_eff = k;
        let mut final_live;
        loop {
            let live = arrivals.iter().take_while(|(t, _)| t.is_finite()).count();
            if clamp {
                assert!(
                    live >= 1,
                    "round {}: no live (non-crashed) workers of m={m}",
                    self.iter
                );
                k_eff = k.min(live);
            } else {
                assert!(
                    k <= live,
                    "round {}: k={k} but only {live} live (non-crashed) workers of m={m}",
                    self.iter
                );
            }
            final_live = live;
            // Dispatch the k virtual winners that have not answered
            // yet, in arrival order (the task_for order SimCluster
            // uses); collect each result before the next dispatch.
            let mut faulted: Vec<usize> = Vec::new();
            for &(_, i) in &arrivals[..k_eff] {
                if payloads[i].is_some() {
                    continue;
                }
                let task = task_for(i);
                debug_assert_eq!(task.iter, self.iter, "task iter mismatch");
                match self.exchange(i, &task) {
                    Ok(p) => payloads[i] = Some(p),
                    Err(e) => {
                        eprintln!(
                            "socket: round {}: worker {i} ({}) erased: {e:#}",
                            self.iter, self.addrs[i]
                        );
                        self.conns[i] = None;
                        faulted.push(i);
                    }
                }
            }
            if faulted.is_empty() {
                break;
            }
            // Crash-erasure mid-round: re-rank the SAME sampled
            // arrivals with the faulted workers at +∞ (no re-sampling —
            // a crash is an infinite delay, not a different delay).
            // Previous responders keep their finite arrivals, so they
            // stay winners; only the next-fastest live workers are
            // promoted into the gap.
            for a in arrivals.iter_mut() {
                if faulted.contains(&a.1) {
                    a.0 = f64::INFINITY;
                }
            }
            arrivals.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        }
        let winners = &arrivals[..k_eff];
        let elapsed = winners.last().unwrap().0;
        let mut responses = Vec::with_capacity(k_eff);
        for &(arrival, i) in winners {
            let payload = payloads[i].take().expect("every winner answered");
            responses.push(Response { worker: i, payload, arrival });
        }
        let interrupted: Vec<usize> = arrivals[k_eff..].iter().map(|&(_, i)| i).collect();
        self.clock += elapsed + self.master_overhead;
        self.iter += 1;
        RoundResult { responses, elapsed, interrupted, live: final_live }
    }
}

impl Gather for SocketCluster {
    fn round(&mut self, k: usize, task_for: &mut dyn FnMut(usize) -> Task) -> RoundResult {
        self.round_impl(k, false, task_for)
    }

    fn round_clamped(&mut self, k: usize, task_for: &mut dyn FnMut(usize) -> Task) -> RoundResult {
        self.round_impl(k, true, task_for)
    }

    fn workers(&self) -> usize {
        self.conns.len()
    }

    fn clock(&self) -> f64 {
        self.clock
    }
}

impl Drop for SocketCluster {
    fn drop(&mut self) {
        // Best-effort session end so workers return to accepting; a
        // worker that is gone already is exactly why this is best-effort.
        for conn in self.conns.iter_mut().flatten() {
            let _ = write_msg(conn, &Msg::Shutdown);
        }
    }
}

/// The worker side of the socket engine: load one encoded partition
/// from local disk, listen, and serve master sessions. This is what
/// `coded-opt worker --listen ADDR --partition DIR` runs.
pub struct WorkerServer {
    listener: TcpListener,
    worker: crate::coordinator::QuadWorker,
    rows: u64,
    cols: u64,
}

impl WorkerServer {
    /// Bind `listen` and load the partition (a `worker-NNN` shard
    /// dataset written by `coded-opt encode` — already
    /// Parseval-normalized `(S̄_iX, S̄_iy)`).
    pub fn bind(listen: &str, partition: &Path) -> Result<Self> {
        let (sx, sy) = crate::data::shard::ShardedSource::open(partition)?
            .load_dense()
            .with_context(|| format!("loading partition {}", partition.display()))?;
        let sy = sy.with_context(|| {
            format!(
                "partition {} has no targets S̄y — data-parallel workers need them \
                 (was the source dataset sharded without y?)",
                partition.display()
            )
        })?;
        let (rows, cols) = (sx.rows() as u64, sx.cols() as u64);
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding worker listener on {listen}"))?;
        Ok(WorkerServer {
            listener,
            worker: crate::coordinator::QuadWorker::new(sx, sy),
            rows,
            cols,
        })
    }

    /// The bound address (resolves `--listen 127.0.0.1:0` to the real
    /// port; the CLI prints it for harnesses to scrape).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Partition shape `(rows, cols)` reported in the `Hello`.
    pub fn shape(&self) -> (u64, u64) {
        (self.rows, self.cols)
    }

    /// Accept and serve master sessions, at most `sessions` of them
    /// (`None` = forever). Sessions are sequential — one master drives
    /// a round-based run at a time, then the worker re-accepts (which
    /// is what lets a conformance test run the same master twice
    /// against live workers).
    pub fn serve(&mut self, sessions: Option<usize>) -> Result<()> {
        let mut done = 0usize;
        loop {
            let (stream, peer) = self.listener.accept().context("accept master")?;
            if let Err(e) = self.serve_master(stream) {
                eprintln!("worker: session with {peer} ended with error: {e:#}");
            }
            done += 1;
            if sessions.is_some_and(|s| done >= s) {
                return Ok(());
            }
        }
    }

    /// One master session: `Hello`, then a task→result loop until
    /// `Shutdown` or a clean EOF. Malformed input (bad kind, wrong
    /// payload size) errors out of the session without panicking — the
    /// master's failure must not take the worker down with it.
    fn serve_master(&mut self, mut stream: TcpStream) -> Result<()> {
        stream.set_nodelay(true).ok();
        write_msg(&mut stream, &Msg::Hello { rows: self.rows, cols: self.cols })?;
        loop {
            match read_msg_or_eof(&mut stream)? {
                Some(Msg::Task { iter, kind, payload, aux }) => {
                    ensure!(
                        kind == crate::coordinator::KIND_GRADIENT
                            || kind == crate::coordinator::KIND_LINESEARCH,
                        "unsupported task kind {kind} (socket workers serve the \
                         data-parallel gradient/line-search kernels)"
                    );
                    ensure!(
                        payload.len() as u64 == self.cols,
                        "task payload has {} coordinates, partition has p={}",
                        payload.len(),
                        self.cols
                    );
                    let task = Task { iter: iter as usize, kind, payload, aux };
                    let out = self.worker.process(&task);
                    write_msg(&mut stream, &Msg::Result { iter, payload: out })?;
                    stream.flush()?;
                }
                Some(Msg::Shutdown) | None => return Ok(()),
                Some(other) => bail!("unexpected {} from master", other.kind_name()),
            }
        }
    }
}
