//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` runs `python/compile/aot.py` once at build time,
//! lowering the L2 JAX functions (which call the L1 Pallas kernels) to
//! **HLO text** under `artifacts/`, plus a `manifest.toml` index. This
//! module loads the manifest, compiles each module on a PJRT CPU client
//! (`xla` crate), and serves executions from the worker hot path — Python
//! is never on the request path.
//!
//! Threading: `xla::PjRtClient` is `Rc`-based (not `Send`), so executors
//! are created *lazily inside the thread that first uses them* (see
//! [`GradExecutor`]): a worker is constructed with a [`GradSpec`]
//! (plain data, trivially `Send`) and compiles on first call. The
//! single-threaded [`crate::cluster::SimCluster`] path shares one client
//! per thread via a thread-local.

pub mod artifact;

pub use artifact::{ArtifactIndex, ArtifactMeta};

use crate::linalg::Mat;
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;

thread_local! {
    /// One PJRT CPU client per thread (clients are Rc-based).
    static CLIENT: RefCell<Option<Rc<xla::PjRtClient>>> = const { RefCell::new(None) };
    /// Per-thread cache of compiled executables keyed by artifact path.
    static EXE_CACHE: RefCell<std::collections::BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>> =
        const { RefCell::new(std::collections::BTreeMap::new()) };
}

/// Get (or create) this thread's PJRT CPU client.
pub fn thread_client() -> Result<Rc<xla::PjRtClient>> {
    CLIENT.with(|c| {
        let mut c = c.borrow_mut();
        if c.is_none() {
            *c = Some(Rc::new(xla::PjRtClient::cpu()?));
        }
        Ok(Rc::clone(c.as_ref().unwrap()))
    })
}

/// Compile an HLO-text artifact on this thread's client (cached).
pub fn compile_artifact(path: &Path) -> Result<Rc<xla::PjRtLoadedExecutable>> {
    let key = path.to_string_lossy().to_string();
    let cached = EXE_CACHE.with(|m| m.borrow().get(&key).cloned());
    if let Some(exe) = cached {
        return Ok(exe);
    }
    let client = thread_client()?;
    let proto = xla::HloModuleProto::from_text_file(&key)
        .with_context(|| format!("parsing HLO text {key}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = Rc::new(client.compile(&comp).with_context(|| format!("compiling {key}"))?);
    EXE_CACHE.with(|m| m.borrow_mut().insert(key, Rc::clone(&exe)));
    Ok(exe)
}

/// Plain-data description of a gradient executor: which artifact to run
/// and the worker's shard, in f32. `Send`-safe by construction.
#[derive(Clone, Debug)]
pub struct GradSpec {
    /// HLO text file for the `quad_grad` artifact with matching shape.
    pub hlo_path: PathBuf,
    /// Shard dimensions (encoded rows × model dim).
    pub rows: usize,
    pub cols: usize,
    /// Row-major S̄·X in f32.
    pub sx: Vec<f32>,
    /// S̄·y in f32.
    pub sy: Vec<f32>,
}

enum ExecState {
    /// Not yet compiled on this thread.
    Spec,
    /// Compiled; device buffers for (sx, sy) pre-uploaded.
    Ready {
        exe: Rc<xla::PjRtLoadedExecutable>,
        sx_buf: xla::PjRtBuffer,
        sy_buf: xla::PjRtBuffer,
        /// Thread that compiled (and therefore owns) the Rc'd PJRT
        /// state; `gradient()` asserts it is never entered from any
        /// other thread (the `Send` SAFETY contract, runtime-verified).
        owner: std::thread::ThreadId,
    },
    /// Compilation failed; native fallback forever.
    Failed,
}

/// Executes the AOT `quad_grad` artifact:
/// `r = S̄Xᵀ(S̄X·w − S̄y)` with `(S̄X, S̄y)` resident on device.
pub struct GradExecutor {
    spec: GradSpec,
    state: ExecState,
    /// Number of successful PJRT executions (metrics / tests).
    pub calls: usize,
}

// SAFETY: `GradExecutor` is only `Send` in its `Spec`/`Failed` states,
// which hold plain data. The `Ready` state (holding Rc'd PJRT objects) is
// entered lazily inside `gradient()` and the executor is never moved
// across threads afterwards: `cluster::threads` moves workers exactly
// once, at spawn, before any task runs. The claim is runtime-verified:
// `Ready` records the compiling thread's id and `gradient()`
// debug-asserts every entry happens on that thread (exercised by the
// debug test suites, including the ThreadSanitizer CI job).
unsafe impl Send for GradExecutor {}

impl GradExecutor {
    pub fn new(spec: GradSpec) -> Self {
        GradExecutor { spec, state: ExecState::Spec, calls: 0 }
    }

    /// Build a spec from a shard if the index has a matching artifact.
    pub fn from_index(index: &ArtifactIndex, sx: &Mat, sy: &[f64]) -> Option<Self> {
        let meta = index.find("quad_grad", sx.rows(), sx.cols())?;
        Some(GradExecutor::new(GradSpec {
            hlo_path: index.dir().join(&meta.file),
            rows: sx.rows(),
            cols: sx.cols(),
            sx: sx.as_slice().iter().map(|&v| v as f32).collect(),
            sy: sy.iter().map(|&v| v as f32).collect(),
        }))
    }

    fn ensure_ready(&mut self) -> Result<()> {
        if matches!(self.state, ExecState::Ready { .. }) {
            return Ok(());
        }
        if matches!(self.state, ExecState::Failed) {
            return Err(anyhow!("PJRT compilation previously failed"));
        }
        let built = (|| -> Result<ExecState> {
            let exe = compile_artifact(&self.spec.hlo_path)?;
            let client = thread_client()?;
            let sx_buf = client.buffer_from_host_buffer::<f32>(
                &self.spec.sx,
                &[self.spec.rows, self.spec.cols],
                None,
            )?;
            let sy_buf =
                client.buffer_from_host_buffer::<f32>(&self.spec.sy, &[self.spec.rows], None)?;
            Ok(ExecState::Ready { exe, sx_buf, sy_buf, owner: std::thread::current().id() })
        })();
        match built {
            Ok(state) => {
                self.state = state;
                Ok(())
            }
            Err(e) => {
                self.state = ExecState::Failed;
                Err(e)
            }
        }
    }

    /// Expected model dimension.
    pub fn dim(&self) -> usize {
        self.spec.cols
    }

    /// Run the artifact: returns `r = S̄Xᵀ(S̄X·w − S̄y)` as f64.
    pub fn gradient(&mut self, w: &[f64]) -> Result<Vec<f64>> {
        if w.len() != self.spec.cols {
            return Err(anyhow!("shape mismatch: w has {} != {}", w.len(), self.spec.cols));
        }
        self.ensure_ready()?;
        let ExecState::Ready { exe, sx_buf, sy_buf, owner } = &self.state else {
            unreachable!("ensure_ready succeeded");
        };
        debug_assert_eq!(
            *owner,
            std::thread::current().id(),
            "GradExecutor::gradient entered off the owning thread — violates \
             the `unsafe impl Send` contract (Ready state must not move)"
        );
        let w32: Vec<f32> = w.iter().map(|&v| v as f32).collect();
        let client = thread_client()?;
        let w_buf = client.buffer_from_host_buffer::<f32>(&w32, &[w32.len()], None)?;
        let result = exe.execute_b(&[sx_buf, sy_buf, &w_buf])?;
        let lit = result[0][0].to_literal_sync()?;
        let out = lit.to_tuple1()?;
        let vals: Vec<f32> = out.to_vec()?;
        self.calls += 1;
        Ok(vals.into_iter().map(|v| v as f64).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT execution against real artifacts is covered by
    // rust/tests/pjrt_integration.rs (needs `make artifacts` first).
    // Here: spec plumbing only.

    #[test]
    fn spec_shape_mismatch_is_error_without_compiling() {
        let spec = GradSpec {
            hlo_path: PathBuf::from("/nonexistent.hlo.txt"),
            rows: 4,
            cols: 3,
            sx: vec![0.0; 12],
            sy: vec![0.0; 4],
        };
        let mut exec = GradExecutor::new(spec);
        // wrong w length fails fast before touching PJRT
        assert!(exec.gradient(&[0.0; 5]).is_err());
        assert_eq!(exec.calls, 0);
    }

    #[test]
    fn missing_artifact_fails_then_stays_failed() {
        let spec = GradSpec {
            hlo_path: PathBuf::from("/nonexistent.hlo.txt"),
            rows: 2,
            cols: 2,
            sx: vec![0.0; 4],
            sy: vec![0.0; 2],
        };
        let mut exec = GradExecutor::new(spec);
        assert!(exec.gradient(&[0.0; 2]).is_err());
        assert!(exec.gradient(&[0.0; 2]).is_err()); // Failed state persists
    }
}
