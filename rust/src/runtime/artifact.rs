//! Artifact manifest: the index of AOT-compiled HLO modules produced by
//! `python/compile/aot.py` (`artifacts/manifest.toml`).

use crate::config::TomlDoc;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One compiled artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// Section name (unique id).
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    /// Kind tag, e.g. "quad_grad" / "logistic_grad".
    pub kind: String,
    /// Shard shape this module was lowered for.
    pub rows: usize,
    pub cols: usize,
}

/// Parsed manifest + artifacts directory.
#[derive(Clone, Debug)]
pub struct ArtifactIndex {
    dir: PathBuf,
    artifacts: Vec<ArtifactMeta>,
}

impl ArtifactIndex {
    /// Load `<dir>/manifest.toml`. Missing manifest → empty index (the
    /// framework falls back to native kernels).
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.toml");
        if !path.exists() {
            return Ok(ArtifactIndex { dir: dir.to_path_buf(), artifacts: Vec::new() });
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (each `[section]` is one artifact).
    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut artifacts = Vec::new();
        for name in doc.sections() {
            let file = doc
                .get_str(&name, "file")
                .with_context(|| format!("artifact [{name}] missing 'file'"))?
                .to_string();
            let kind = doc
                .get_str(&name, "kind")
                .with_context(|| format!("artifact [{name}] missing 'kind'"))?
                .to_string();
            let rows = doc.get_i64(&name, "rows").unwrap_or(0) as usize;
            let cols = doc.get_i64(&name, "cols").unwrap_or(0) as usize;
            artifacts.push(ArtifactMeta { name: name.clone(), file, kind, rows, cols });
        }
        Ok(ArtifactIndex { dir: dir.to_path_buf(), artifacts })
    }

    /// Default location: `$CODED_OPT_ARTIFACTS` or `./artifacts`.
    pub fn default_location() -> Result<Self> {
        let dir = std::env::var("CODED_OPT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(Path::new(&dir))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn all(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }

    /// Exact-shape lookup by kind.
    pub fn find(&self, kind: &str, rows: usize, cols: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && a.rows == rows && a.cols == cols)
    }

    /// All artifacts of a kind.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactMeta> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[quad_grad_128x64]
file = "quad_grad_128x64.hlo.txt"
kind = "quad_grad"
rows = 128
cols = 64

[quad_grad_64x32]
file = "quad_grad_64x32.hlo.txt"
kind = "quad_grad"
rows = 64
cols = 32
"#;

    #[test]
    fn parse_and_find() {
        let idx = ArtifactIndex::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(idx.len(), 2);
        let a = idx.find("quad_grad", 128, 64).unwrap();
        assert_eq!(a.file, "quad_grad_128x64.hlo.txt");
        assert!(idx.find("quad_grad", 128, 65).is_none());
        assert!(idx.find("other", 128, 64).is_none());
        assert_eq!(idx.by_kind("quad_grad").len(), 2);
    }

    #[test]
    fn missing_manifest_is_empty_index() {
        let idx = ArtifactIndex::load(Path::new("/definitely/not/here")).unwrap();
        assert!(idx.is_empty());
    }

    #[test]
    fn missing_required_key_errors() {
        let bad = "[a]\nkind = \"quad_grad\"\n";
        assert!(ArtifactIndex::parse(Path::new("/tmp"), bad).is_err());
    }
}
