//! BRIP spectrum analysis (Definition 1, Figures 5–6).
//!
//! Samples random active sets `A ⊂ [m]` of size `k = ηm`, stacks
//! `S_A = [S_i]_{i∈A}`, and reports the eigenvalue distribution of the
//! normalized Gram `(1/(ηβ))·S_AᵀS_A`. The spread of these eigenvalues
//! around 1 is the ε of the `(m, η, ε)`-BRIP condition; the paper's key
//! empirical claim (Prop. 8 and Figs. 5–6) is that ETF constructions keep
//! the *bulk* of the spectrum pinned at exactly 1.

use super::{EncodingOp, FastPath};
use crate::linalg::symmetric_eigenvalues;
use crate::rng::{sample_without_replacement, Pcg64};

/// Eigenvalue statistics pooled over sampled subsets.
#[derive(Clone, Debug)]
pub struct SpectrumStats {
    pub scheme: String,
    pub n: usize,
    pub m: usize,
    pub k: usize,
    pub beta: f64,
    /// Worst extremes over subsets → ε = max(1−λ_min, λ_max−1).
    pub lambda_min: f64,
    pub lambda_max: f64,
    /// Fraction of all pooled eigenvalues within |λ−1| ≤ 0.05 — the
    /// "bulk at 1" measure of Proposition 8.
    pub bulk_at_one: f64,
    /// All pooled (sorted) eigenvalues, for histogram plotting.
    pub eigenvalues: Vec<f64>,
    pub subsets_sampled: usize,
}

impl SpectrumStats {
    /// ε of the empirical BRIP condition over the sampled subsets.
    pub fn epsilon(&self) -> f64 {
        (1.0 - self.lambda_min).max(self.lambda_max - 1.0)
    }

    /// Histogram of eigenvalues with `bins` uniform bins over [lo, hi].
    pub fn histogram(&self, lo: f64, hi: f64, bins: usize) -> Vec<usize> {
        let mut h = vec![0usize; bins];
        for &e in &self.eigenvalues {
            if e < lo || e >= hi {
                continue;
            }
            let b = ((e - lo) / (hi - lo) * bins as f64) as usize;
            h[b.min(bins - 1)] += 1;
        }
        h
    }

    pub fn summary_row(&self) -> Vec<String> {
        vec![
            self.scheme.clone(),
            format!("{}", self.n),
            format!("{}/{}", self.k, self.m),
            format!("{:.3}", self.beta),
            format!("{:.4}", self.lambda_min),
            format!("{:.4}", self.lambda_max),
            format!("{:.4}", self.epsilon()),
            format!("{:.1}%", 100.0 * self.bulk_at_one),
        ]
    }
}

/// Spectrum analyzer over random subsets.
///
/// Spectrum analysis is an *explicitly dense* consumer of the lazy
/// [`EncodingOp`]: it stacks `S_A` per sampled subset. For the dense
/// ensembles (Gaussian, Paley) the analyzer materializes the full frame
/// ONCE at construction and slices it per subset — regenerating per
/// subset would rebuild Paley's eigendecomposition `subsets` times for
/// identical bits. Structured schemes still produce their (sparse /
/// closed-form) blocks on demand per subset.
pub struct SubsetSpectrum<'a> {
    encoding: &'a EncodingOp,
    rng: Pcg64,
    /// The one explicit dense materialization for dense-ensemble
    /// generators (`None` for structured schemes).
    full: Option<crate::linalg::Mat>,
}

impl<'a> SubsetSpectrum<'a> {
    pub fn new(encoding: &'a EncodingOp, seed: u64) -> Self {
        let full = (encoding.fast_path() == FastPath::Dense).then(|| {
            let all: Vec<usize> = (0..encoding.workers()).collect();
            encoding.stack(&all)
        });
        SubsetSpectrum { encoding, rng: Pcg64::with_stream(seed, 0x5bec), full }
    }

    /// `(1/(ηβ))·S_AᵀS_A` for a subset — from the cached full frame when
    /// one exists (bit-identical to [`EncodingOp::gram_normalized`]:
    /// `stack` slices the same regenerated frame at the same bounds).
    fn subset_gram(&self, subset: &[usize]) -> crate::linalg::Mat {
        match &self.full {
            None => self.encoding.gram_normalized(subset),
            Some(full) => {
                let b = self.encoding.block_bounds();
                let blocks: Vec<crate::linalg::Mat> =
                    subset.iter().map(|&i| full.row_block(b[i], b[i + 1])).collect();
                let refs: Vec<&crate::linalg::Mat> = blocks.iter().collect();
                let sa = crate::linalg::Mat::vstack(&refs);
                // the 1/(ηβ) normalization lives on the op — shared with
                // gram_normalized so the two paths cannot drift
                self.encoding.gram_normalized_of(&sa, subset.len())
            }
        }
    }

    /// Pool eigenvalues of `(1/(ηβ))·S_AᵀS_A` over `subsets` random A of
    /// size k.
    ///
    /// ε comes from the Definition-1 normalization `(1/(ηβ))` (unbiased
    /// around 1); the `bulk_at_one` plateau measure uses the
    /// Proposition-8 normalization `(1/β)`, under which ETF plateau
    /// eigenvalues are *exactly* 1. For an η-normalized eigenvalue λ the
    /// β-normalized one is η·λ, so both come from one decomposition.
    pub fn analyze(&mut self, k: usize, subsets: usize) -> SpectrumStats {
        let m = self.encoding.workers();
        assert!(k >= 1 && k <= m, "k must be in [1, m]");
        let eta = k as f64 / m as f64;
        let mut all = Vec::new();
        let mut lmin = f64::INFINITY;
        let mut lmax = f64::NEG_INFINITY;
        for _ in 0..subsets {
            let subset = sample_without_replacement(&mut self.rng, m, k);
            let g = self.subset_gram(&subset);
            let eigs = symmetric_eigenvalues(&g);
            lmin = lmin.min(eigs[0]);
            lmax = lmax.max(*eigs.last().unwrap());
            all.extend(eigs);
        }
        all.sort_by(|a, b| a.total_cmp(b));
        let bulk = all.iter().filter(|&&e| (eta * e - 1.0).abs() <= 0.02).count() as f64
            / all.len() as f64;
        SpectrumStats {
            scheme: self.encoding.scheme.name().to_string(),
            n: self.encoding.n,
            m,
            k,
            beta: self.encoding.beta,
            lambda_min: lmin,
            lambda_max: lmax,
            bulk_at_one: bulk,
            eigenvalues: all,
            subsets_sampled: subsets,
        }
    }
}

/// Proposition 8 check: for an ETF with redundancy β and η ≥ 1 − 1/β, the
/// normalized subset Gram has at least `n(1 − β(1−η))` eigenvalues equal
/// to 1 (up to the (ηβ) normalization — exactly-1 eigenvalues of
/// `(1/β)S_AᵀS_A` map to `1/η` here; this helper counts eigenvalues of
/// the β-normalized Gram at 1).
pub fn prop8_unit_eigen_count(encoding: &EncodingOp, subset: &[usize], tol: f64) -> usize {
    let sa = encoding.stack(subset);
    let mut g = sa.gram();
    g.scale_inplace(1.0 / encoding.beta);
    let eigs = symmetric_eigenvalues(&g);
    eigs.iter().filter(|&&e| (e - 1.0).abs() <= tol).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;
    use crate::encoding::EncodingOp;

    #[test]
    fn full_subset_of_tight_frame_has_flat_spectrum() {
        let enc = EncodingOp::build(Scheme::Hadamard, 16, 4, 2.0, 1).unwrap();
        let mut an = SubsetSpectrum::new(&enc, 2);
        let stats = an.analyze(4, 3); // k = m: S_A = S always
        assert!(stats.epsilon() < 1e-9, "eps={}", stats.epsilon());
        assert!((stats.bulk_at_one - 1.0).abs() < 1e-12);
    }

    #[test]
    fn uncoded_subsets_lose_rank() {
        // identity encoding: any k < m drops rows → zero eigenvalues.
        let enc = EncodingOp::build(Scheme::Uncoded, 12, 4, 1.0, 1).unwrap();
        let mut an = SubsetSpectrum::new(&enc, 3);
        let stats = an.analyze(3, 4);
        assert!(stats.lambda_min.abs() < 1e-12, "λmin={}", stats.lambda_min);
    }

    #[test]
    fn coded_subsets_stay_full_rank() {
        // Hadamard β=2, η=3/4 ≥ 1/β: S_A keeps full column rank — in
        // sharp contrast with the uncoded case where λ_min is exactly 0.
        let enc = EncodingOp::build(Scheme::Hadamard, 32, 8, 2.0, 1).unwrap();
        let mut an = SubsetSpectrum::new(&enc, 4);
        let stats = an.analyze(6, 8);
        assert!(stats.lambda_min > 1e-6, "λmin={}", stats.lambda_min);
        assert!(stats.lambda_max < 3.0, "λmax={}", stats.lambda_max);
    }

    #[test]
    fn prop8_etf_unit_eigen_count() {
        // Steiner ETF v=4: n=6, β=8/3. η=3/4 ⇒ guarantee n(1−β(1−η)) =
        // 6(1 − 8/3·1/4) = 6·(1/3) = 2 eigenvalues at 1.
        let enc = EncodingOp::build(Scheme::Steiner, 6, 4, 2.0, 1).unwrap();
        let count = prop8_unit_eigen_count(&enc, &[0, 1, 2], 1e-9);
        assert!(count >= 2, "count={count}");
    }

    #[test]
    fn histogram_bins_count_all_in_range() {
        let enc = EncodingOp::build(Scheme::Gaussian, 24, 4, 2.0, 5).unwrap();
        let mut an = SubsetSpectrum::new(&enc, 6);
        let stats = an.analyze(3, 4);
        let h = stats.histogram(0.0, 3.0, 30);
        let total: usize = h.iter().sum();
        let in_range = stats.eigenvalues.iter().filter(|&&e| (0.0..3.0).contains(&e)).count();
        assert_eq!(total, in_range);
    }

    #[test]
    fn etf_tighter_than_gaussian() {
        // The paper's Fig. 5/6 claim: ETF spectra concentrate harder than
        // iid Gaussian at the same (n, β, η).
        let n = 28;
        let m = 8;
        let etf = EncodingOp::build(Scheme::Steiner, n, m, 2.0, 1).unwrap();
        let gau = EncodingOp::build(Scheme::Gaussian, n, m, etf.beta, 1).unwrap();
        let e1 = SubsetSpectrum::new(&etf, 9).analyze(6, 6);
        let e2 = SubsetSpectrum::new(&gau, 9).analyze(6, 6);
        assert!(
            e1.epsilon() < e2.epsilon(),
            "steiner ε={} vs gaussian ε={}",
            e1.epsilon(),
            e2.epsilon()
        );
    }
}
