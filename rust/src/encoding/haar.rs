//! Column-subsampled Haar wavelet encoding (paper §4.2.1, "Example: Haar
//! matrix").
//!
//! The orthonormal Haar matrix is defined recursively:
//!
//!   H_{2n} = (1/√2) · [ H_n ⊗ [1  1] ]
//!                     [ I_n ⊗ [1 −1] ] ,   H_1 = [1].
//!
//! Given redundancy β, sample n columns of `H_N` (N = βn rounded to a
//! power of two) and scale by √β so that `SᵀS = β·I` exactly. Haar
//! columns have O(log N) non-zeros, giving the paper's
//! `|B_I_k| ≤ βn·log(n)/m` memory bound.

use super::{partition_bounds, EncodingOp, Generator};
use crate::config::Scheme;
use crate::linalg::Csr;
use crate::rng::{sample_without_replacement, Pcg64};

/// Triplets of the orthonormal Haar matrix of order `n` (power of two).
pub fn haar_triplets(n: usize) -> Vec<(usize, usize, f64)> {
    assert!(n.is_power_of_two(), "Haar order must be a power of two");
    let mut t: Vec<(usize, usize, f64)> = vec![(0, 0, 1.0)];
    let mut size = 1;
    let inv_sqrt2 = std::f64::consts::FRAC_1_SQRT_2;
    while size < n {
        let mut next = Vec::with_capacity(2 * t.len() + 2 * size);
        // Top half: H_size ⊗ [1 1] / √2
        for &(i, j, v) in &t {
            next.push((i, 2 * j, v * inv_sqrt2));
            next.push((i, 2 * j + 1, v * inv_sqrt2));
        }
        // Bottom half: I_size ⊗ [1 −1] / √2
        for i in 0..size {
            next.push((size + i, 2 * i, inv_sqrt2));
            next.push((size + i, 2 * i + 1, -inv_sqrt2));
        }
        t = next;
        size *= 2;
    }
    t
}

/// Sibling-avoiding column sample: choose `n` of `nn` columns such that
/// no two selected columns are a finest-level sibling pair {2i, 2i+1}.
///
/// Rationale: the fine-detail Haar row `i` has its entire mass on
/// columns {2i, 2i+1}. If both survive the subsampling and that row is
/// later erased with a straggling worker, the erased row captures a full
/// coordinate direction and `λ_min(S_AᵀS_A)` collapses to 0. Picking at
/// most one column per sibling pair caps every non-top row's selected
/// mass at ½, so no single erased row can zero out a direction.
/// Requires `n ≤ nn/2`, i.e. β ≥ 2 (rounded up by the power-of-two).
fn sibling_avoiding_sample(rng: &mut Pcg64, nn: usize, n: usize) -> Vec<usize> {
    assert!(n <= nn / 2, "sibling-avoiding Haar sample needs β ≥ 2 (n={n}, N={nn})");
    let pairs = sample_without_replacement(rng, nn / 2, n);
    let mut cols: Vec<usize> = pairs
        .into_iter()
        .map(|p| 2 * p + rng.gen_range(2)) // one side of each chosen pair
        .collect();
    cols.sort_unstable();
    cols
}

/// Lower the subsampled-Haar descriptor for dimension n across m
/// workers: one sparse CSR generator of O(n log n) non-zeros, nothing
/// dense anywhere.
pub(crate) fn lower(n: usize, m: usize, beta: f64, seed: u64) -> EncodingOp {
    let target = ((beta * n as f64).ceil() as usize).max(2 * n);
    let nn = target.next_power_of_two().max(2);
    let mut rng = Pcg64::with_stream(seed, 0x4aa2);
    let cols = sibling_avoiding_sample(&mut rng, nn, n);
    let mut col_map = vec![usize::MAX; nn];
    for (new, &old) in cols.iter().enumerate() {
        col_map[old] = new;
    }
    let scale = (nn as f64 / n as f64).sqrt();
    // Random column signs (FJLT trick, see hadamard.rs): decorrelate the
    // coarse Haar rows from constant data columns.
    let signs: Vec<f64> = (0..n).map(|_| if rng.gen_bool(0.5) { 1.0 } else { -1.0 }).collect();
    let triplets: Vec<(usize, usize, f64)> = haar_triplets(nn)
        .into_iter()
        .filter_map(|(i, j, v)| {
            let nj = col_map[j];
            (nj != usize::MAX).then(|| (i, nj, v * scale * signs[nj]))
        })
        .collect();
    let s = Csr::from_triplets(nn, n, &triplets);
    EncodingOp {
        scheme: Scheme::Haar,
        beta: nn as f64 / n as f64,
        n,
        bounds: partition_bounds(nn, m),
        gen: Generator::Sparse(s),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn haar_dense(n: usize) -> Mat {
        Csr::from_triplets(n, n, &haar_triplets(n)).to_dense()
    }

    #[test]
    fn haar_2_matches_definition() {
        let h = haar_dense(2);
        let s = std::f64::consts::FRAC_1_SQRT_2;
        crate::testutil::assert_allclose(h.as_slice(), &[s, s, s, -s], 1e-15, "H2");
    }

    #[test]
    fn haar_is_orthonormal() {
        for n in [2usize, 4, 8, 16, 64] {
            let h = haar_dense(n);
            let g = h.gram();
            for i in 0..n {
                for j in 0..n {
                    let expect = if i == j { 1.0 } else { 0.0 };
                    assert!((g[(i, j)] - expect).abs() < 1e-12, "n={n} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn haar_nnz_is_n_log_n() {
        let n = 64;
        let t = haar_triplets(n);
        // nnz(N) = N(log2 N)... exact recurrence: nnz(2n)=2nnz(n)+2n
        // → nnz(64) = 64·log2(64)/... compute directly: 448
        assert_eq!(t.len(), 448);
    }

    fn build(n: usize, m: usize, beta: f64, seed: u64) -> EncodingOp {
        lower(n, m, beta, seed)
    }

    #[test]
    fn encoding_is_exact_tight_frame() {
        let enc = build(24, 4, 2.0, 3);
        let s = enc.stack(&[0, 1, 2, 3]);
        let g = s.gram();
        for i in 0..24 {
            for j in 0..24 {
                let expect = if i == j { enc.beta } else { 0.0 };
                assert!((g[(i, j)] - expect).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn blocks_are_sparse() {
        let enc = build(512, 8, 2.0, 5);
        for i in 0..enc.workers() {
            let b = enc.row_block(i);
            assert!(b.density() < 0.1, "density={}", b.density());
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = build(32, 4, 2.0, 7).stack(&[1]);
        let b = build(32, 4, 2.0, 7).stack(&[1]);
        assert_eq!(a.as_slice(), b.as_slice());
    }
}
