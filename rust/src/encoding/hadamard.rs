//! Column-subsampled Hadamard encoding (paper §4.2.2, used for the ridge
//! experiment of Figure 7 with β = 2, encoded via FWHT).
//!
//! Take the Sylvester–Hadamard matrix `H_N` (N = 2^⌈log₂ βn⌉), keep `n`
//! randomly chosen columns, scale by `1/√n`. Column-orthogonality of `H`
//! makes this an *exact* tight frame: `SᵀS = (N/n)·I = β·I`, and rows have
//! exactly unit norm. Encoding a vector is `O(N log N)` via FWHT.
//!
//! The scheme is pure operator: lowering builds only the [`FwhtOp`]
//! (column sample, row permutation, signs — three O(N) vectors), and no
//! dense row of `S` exists on any encode path. [`FwhtOp::dense_rows`]
//! can materialize an explicit dense view (spectrum analysis, test
//! referees) from the closed-form entry `signs[j]·H[perm[i]][cols[j]]/√n`.

use super::{partition_bounds, EncodingOp, Generator};
use crate::config::Scheme;
use crate::linalg::fwht::{fwht, hadamard_entry};
use crate::linalg::Mat;
use crate::rng::{sample_without_replacement, Pcg64};

/// The structured subsampled-Hadamard operator: the full generator
/// `S[i][j] = signs[j]·H[perm[i]][cols[j]]/√n` applied through FWHT in
/// `O(N log N)` instead of the `O(N·n)` dense product — the paper's
/// §4.2.2 efficient-encoding mechanism. Carried by the Hadamard
/// [`EncodingOp`] so [`super::Encoder::apply`] /
/// [`super::Encoder::apply_t`] never touch dense rows.
#[derive(Clone, Debug)]
pub struct FwhtOp {
    cols: Vec<usize>,
    perm: Vec<usize>,
    signs: Vec<f64>,
    nn: usize,
}

impl FwhtOp {
    /// The operator for (n, β, seed).
    pub fn new(n: usize, beta: f64, seed: u64) -> FwhtOp {
        let (cols, nn) = column_sample(n, beta, seed);
        let perm = row_permutation(nn, seed);
        let signs = column_signs(n, seed);
        FwhtOp { cols, perm, signs, nn }
    }

    /// Encoded rows N (a power of two).
    pub fn encoded_rows(&self) -> usize {
        self.nn
    }

    /// Data dimension n.
    pub fn dim(&self) -> usize {
        self.cols.len()
    }

    /// S·x via scatter → FWHT → permuted gather: O(N log N).
    pub fn apply(&self, x: &[f64]) -> Vec<f64> {
        encode_fwht(x, &self.cols, &self.perm, &self.signs, self.nn)
    }

    /// Sᵀ·u. Since the Sylvester–Hadamard matrix is symmetric,
    /// `(Sᵀu)_j = signs[j]/√n · (H·ũ)[cols[j]]` with `ũ[perm[i]] = u_i` —
    /// one permutation scatter, one FWHT, one column gather.
    pub fn apply_t(&self, u: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), self.nn, "apply_t length mismatch");
        let mut padded = vec![0.0; self.nn];
        for (&p, &ui) in self.perm.iter().zip(u) {
            padded[p] = ui;
        }
        fwht(&mut padded);
        let scale = 1.0 / (self.dim() as f64).sqrt();
        self.cols
            .iter()
            .zip(&self.signs)
            .map(|(&c, &s)| s * scale * padded[c])
            .collect()
    }

    /// Explicit dense view of rows `r0..r1` of `S`, from the closed-form
    /// entry — used by spectrum analysis and test referees only; the
    /// encode paths apply through FWHT and never call this. Recorded by
    /// the [`super::probe`] counters like every dense materialization.
    pub fn dense_rows(&self, r0: usize, r1: usize) -> Mat {
        let scale = 1.0 / (self.dim() as f64).sqrt();
        let block = Mat::from_fn(r1 - r0, self.dim(), |i, j| {
            scale * self.signs[j] * hadamard_entry(self.perm[r0 + i], self.cols[j])
        });
        super::probe::record_dense(r1 - r0, self.dim());
        block
    }
}

/// Lower the subsampled-Hadamard descriptor to its lazy operator.
///
/// The achieved β is `2^⌈log₂(βn)⌉ / n` (power-of-two rounding). Two
/// randomizations, both leaving SᵀS = β·I exact:
/// 1. Rows are randomly permuted before blocking: Sylvester–Hadamard
///    is a tensor power (H_N = H_{N/m} ⊗ H_m under bit-split
///    indexing), so *consecutive* row blocks align with tensor factors
///    and dropping two blocks can annihilate a direction (rank loss).
///    The permutation — the matrix analogue of the paper's "insert
///    zero rows at random locations, then FWHT" recipe — destroys
///    that alignment.
/// 2. Random column signs (the FJLT trick): raw Hadamard columns are
///    coherent with constant data columns (H·1 concentrates on one
///    row), so a worker block can see ~zero energy for a bias
///    feature; random signs spread every data direction evenly.
pub(crate) fn lower(n: usize, m: usize, beta: f64, seed: u64) -> EncodingOp {
    let op = FwhtOp::new(n, beta, seed);
    let nn = op.nn;
    EncodingOp {
        scheme: Scheme::Hadamard,
        beta: nn as f64 / n as f64,
        n,
        bounds: partition_bounds(nn, m),
        gen: Generator::Fwht(op),
    }
}

/// The row permutation the lowered operator uses for (nn, seed).
pub fn row_permutation(nn: usize, seed: u64) -> Vec<usize> {
    let mut rng = Pcg64::with_stream(seed, 0x4ad_0001);
    let mut perm: Vec<usize> = (0..nn).collect();
    crate::rng::shuffle(&mut rng, &mut perm);
    perm
}

/// The random ±1 column signs the lowered operator uses for (n, seed).
pub fn column_signs(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::with_stream(seed, 0x4ad_0002);
    (0..n).map(|_| if rng.gen_bool(0.5) { 1.0 } else { -1.0 }).collect()
}

/// Fast encoding of a single column vector by FWHT: computes `S·x` in
/// O(N log N) without materializing S. `cols` and `perm` must be the same
/// column sample / row permutation used to build S ([`column_sample`],
/// [`row_permutation`]).
pub fn encode_fwht(
    x: &[f64],
    cols: &[usize],
    perm: &[usize],
    signs: &[f64],
    nn: usize,
) -> Vec<f64> {
    assert_eq!(x.len(), cols.len());
    let mut padded = vec![0.0; nn];
    for (j, &c) in cols.iter().enumerate() {
        padded[c] = x[j] * signs[j];
    }
    fwht(&mut padded);
    let scale = 1.0 / (x.len() as f64).sqrt();
    let mut out = vec![0.0; nn];
    for (i, &p) in perm.iter().enumerate() {
        out[i] = padded[p] * scale;
    }
    out
}

/// The sorted column sample for (n, β, seed) — exposed so the FWHT fast
/// path and a materialized referee matrix agree.
pub fn column_sample(n: usize, beta: f64, seed: u64) -> (Vec<usize>, usize) {
    let target = (beta * n as f64).ceil() as usize;
    let nn = target.next_power_of_two();
    let mut rng = Pcg64::with_stream(seed, 0x4ad_u64);
    let mut c = sample_without_replacement(&mut rng, nn, n);
    c.sort_unstable();
    (c, nn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::symmetric_eigenvalues;

    fn build(n: usize, m: usize, beta: f64, seed: u64) -> EncodingOp {
        lower(n, m, beta, seed)
    }

    #[test]
    fn exact_tight_frame() {
        let enc = build(24, 4, 2.0, 1);
        // SᵀS = β·I exactly (columns of H are orthogonal).
        let s = enc.stack(&[0, 1, 2, 3]);
        let g = s.gram();
        for i in 0..24 {
            for j in 0..24 {
                let expect = if i == j { enc.beta } else { 0.0 };
                assert!((g[(i, j)] - expect).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn rows_unit_norm() {
        let enc = build(16, 2, 2.0, 3);
        let s = enc.stack(&[0, 1]);
        for i in 0..s.rows() {
            let n2 = crate::linalg::dot(s.row(i), s.row(i));
            assert!((n2 - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn beta_rounds_to_power_of_two() {
        let enc = build(24, 4, 2.0, 1);
        // βn = 48 → next pow2 = 64 → β = 64/24
        assert!((enc.beta - 64.0 / 24.0).abs() < 1e-12);
    }

    #[test]
    fn fwht_fast_path_matches_matrix() {
        let n = 12;
        let (cols, nn) = column_sample(n, 2.0, 9);
        let perm = row_permutation(nn, 9);
        let signs = column_signs(n, 9);
        let enc = build(n, 3, 2.0, 9);
        let s = enc.stack(&[0, 1, 2]);
        let mut rng = Pcg64::new(4);
        let x: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
        let slow = s.matvec(&x);
        let fast = encode_fwht(&x, &cols, &perm, &signs, nn);
        crate::testutil::assert_allclose(&fast, &slow, 1e-10, "fwht encode");
    }

    #[test]
    fn fwht_op_apply_and_apply_t_match_matrix() {
        let n = 12;
        let op = FwhtOp::new(n, 2.0, 9);
        let enc = build(n, 3, 2.0, 9);
        let s = enc.stack(&[0, 1, 2]);
        let mut rng = Pcg64::new(11);
        let x: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
        crate::testutil::assert_allclose(&op.apply(&x), &s.matvec(&x), 1e-10, "op apply");
        let u: Vec<f64> = (0..op.encoded_rows()).map(|_| rng.next_f64() - 0.5).collect();
        crate::testutil::assert_allclose(&op.apply_t(&u), &s.matvec_t(&u), 1e-10, "op apply_t");
    }

    #[test]
    fn dense_rows_match_an_independent_referee() {
        // The referee is built here from the published closed form —
        // NOT through dense_rows or stack (which routes through
        // dense_rows), so a sign/permutation/scale slip in dense_rows
        // cannot cancel out of the comparison.
        let n = 12;
        let enc = build(n, 3, 2.0, 9);
        let super::super::Generator::Fwht(op) = &enc.gen else {
            panic!("hadamard must lower to an FWHT generator");
        };
        let scale = 1.0 / (n as f64).sqrt();
        let referee = Mat::from_fn(op.nn, n, |i, j| {
            scale * op.signs[j] * hadamard_entry(op.perm[i], op.cols[j])
        });
        let rows = op.dense_rows(0, op.encoded_rows());
        assert_eq!(rows.as_slice(), referee.as_slice(), "closed form referee");
        let mid = op.dense_rows(3, 7);
        assert_eq!(mid.as_slice(), referee.row_block(3, 7).as_slice());
        // ...and the FWHT apply (an entirely different computation:
        // scatter → butterfly → gather) agrees with the referee matrix,
        // closing the loop on the closed form itself.
        let mut rng = Pcg64::new(2);
        let x: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
        crate::testutil::assert_allclose(
            &op.apply(&x),
            &referee.matvec(&x),
            1e-10,
            "fwht apply vs closed-form referee",
        );
    }

    #[test]
    fn subset_spectrum_full_rank_with_prop8_plateau() {
        let enc = build(32, 8, 2.0, 5);
        // η = 0.75 > 1/β: the normalized Gram stays full rank…
        let g = enc.gram_normalized(&[0, 1, 2, 3, 4, 5]);
        let eigs = symmetric_eigenvalues(&g);
        assert!(eigs[0] > 0.05, "rank-deficient subset: {eigs:?}");
        // …and Proposition 8 pins n(1−β(1−η)) = 16 eigenvalues of the
        // β-normalized Gram at exactly 1, i.e. at 1/η = 4/3 here.
        let plateau = eigs.iter().filter(|&&e| (e - 1.0 / 0.75).abs() < 1e-9).count();
        assert!(plateau >= 16, "plateau={plateau}, eigs={eigs:?}");
    }
}
