//! i.i.d. Gaussian encoding ensemble.
//!
//! Entries drawn N(0, 1/n) so each row has expected unit norm, matching
//! the paper's eq. (8)–(9) normalization `(1/(βηn))·S_AᵀS_A` with N(0,1)
//! entries: our rows absorb the 1/√n. For large n the subset Grams
//! concentrate in `[(1−√(1/(βη)))², (1+√(1/(βη)))²]`.
//!
//! The ensemble is *lazy*: lowering stores only the seed, and
//! `dense_rows` regenerates any row range on demand by jumping the
//! PCG stream ([`Pcg64::advance`]) to the range's first entry — each
//! standard-normal draw consumes exactly two `next_u64` steps (one
//! Box–Muller pair, cosine variate only), so rows `r0..r1` start
//! `2·r0·n` steps into the stream and the regenerated block is
//! bit-identical to the corresponding slice of a one-pass eager draw.

use super::{partition_bounds, EncodingOp, Generator};
use crate::config::Scheme;
use crate::linalg::Mat;
use crate::rng::{Normal, Pcg64};

/// The Gaussian entry stream selector (fixed so regeneration and the
/// historical eager construction read the same stream).
const STREAM: u64 = 0x6a55;

/// Lower the Gaussian descriptor: `⌈βn⌉ × n` in m row-blocks, no entry
/// generated until a block is used.
pub(crate) fn lower(n: usize, m: usize, beta: f64, seed: u64) -> EncodingOp {
    let total_rows = (beta * n as f64).round() as usize;
    EncodingOp {
        scheme: Scheme::Gaussian,
        beta: total_rows as f64 / n as f64,
        n,
        bounds: partition_bounds(total_rows, m),
        gen: Generator::Gaussian { seed },
    }
}

/// Regenerate rows `r0..r1` of the seeded `N×n` ensemble — bit-identical
/// to the same rows of a single front-to-back draw (each entry costs two
/// PCG steps; [`Pcg64::advance`] jumps the stream in O(log) time).
pub(crate) fn dense_rows(n: usize, seed: u64, r0: usize, r1: usize) -> Mat {
    let mut rng = Pcg64::with_stream(seed, STREAM);
    rng.advance(2 * (r0 as u128) * (n as u128));
    let sigma = 1.0 / (n as f64).sqrt();
    let block = Mat::from_fn(r1 - r0, n, |_, _| sigma * Normal::sample_standard(&mut rng));
    super::probe::record_dense(r1 - r0, n);
    block
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::symmetric_eigenvalues;

    fn build(n: usize, m: usize, beta: f64, seed: u64) -> EncodingOp {
        lower(n, m, beta, seed)
    }

    #[test]
    fn dimensions_and_beta() {
        let enc = build(64, 8, 2.0, 1);
        assert_eq!(enc.total_rows(), 128);
        assert_eq!(enc.n, 64);
        assert_eq!(enc.workers(), 8);
        assert!((enc.beta - 2.0).abs() < 1e-12);
    }

    #[test]
    fn block_regeneration_matches_one_pass_draw() {
        // The lazy per-block regeneration must reproduce the bits of a
        // single front-to-back draw of the full N×n ensemble — the
        // contract that keeps every fixture pinned to the old eager
        // construction.
        let (n, total) = (13, 29);
        let mut rng = Pcg64::with_stream(7, STREAM);
        let sigma = 1.0 / (n as f64).sqrt();
        let eager = Mat::from_fn(total, n, |_, _| sigma * Normal::sample_standard(&mut rng));
        for (r0, r1) in [(0usize, 5usize), (5, 6), (11, 29), (0, 29)] {
            let lazy = dense_rows(n, 7, r0, r1);
            assert_eq!(
                lazy.as_slice(),
                eager.row_block(r0, r1).as_slice(),
                "rows {r0}..{r1} must regenerate bit-identically"
            );
        }
    }

    #[test]
    fn rows_have_near_unit_norm() {
        let enc = build(256, 4, 2.0, 2);
        let s = enc.stack(&[0, 1, 2, 3]);
        let mut mean_norm2 = 0.0;
        for i in 0..s.rows() {
            mean_norm2 += crate::linalg::dot(s.row(i), s.row(i));
        }
        mean_norm2 /= s.rows() as f64;
        assert!((mean_norm2 - 1.0).abs() < 0.05, "mean row norm² = {mean_norm2}");
    }

    #[test]
    fn full_gram_concentrates_near_identity() {
        // With all workers, G = (1/β)·SᵀS should have eigenvalues in a
        // Marchenko–Pastur-ish band around 1.
        let enc = build(96, 6, 3.0, 3);
        let g = enc.gram_normalized(&[0, 1, 2, 3, 4, 5]);
        let eigs = symmetric_eigenvalues(&g);
        // Marchenko–Pastur band for aspect ratio 1/β = 1/3:
        // [(1−√⅓)², (1+√⅓)²] ≈ [0.18, 2.49]; allow finite-n slack.
        let (lo, hi) = (eigs[0], *eigs.last().unwrap());
        assert!(lo > 0.05 && hi < 2.8, "spectrum [{lo:.3}, {hi:.3}] too wide");
    }

    #[test]
    fn deterministic_in_seed() {
        let a = build(32, 4, 2.0, 7);
        let b = build(32, 4, 2.0, 7);
        let (sa, sb) = (a.stack(&[0]), b.stack(&[0]));
        assert_eq!(sa.as_slice(), sb.as_slice());
        let c = build(32, 4, 2.0, 8);
        assert_ne!(a.stack(&[0]).as_slice(), c.stack(&[0]).as_slice());
    }
}
