//! i.i.d. Gaussian encoding ensemble.
//!
//! Entries drawn N(0, 1/n) so each row has expected unit norm, matching
//! the paper's eq. (8)–(9) normalization `(1/(βηn))·S_AᵀS_A` with N(0,1)
//! entries: our rows absorb the 1/√n. For large n the subset Grams
//! concentrate in `[(1−√(1/(βη)))², (1+√(1/(βη)))²]`.

use super::{split_dense, Encoding, FastS};
use crate::config::Scheme;
use crate::linalg::Mat;
use crate::rng::{Normal, Pcg64};

/// Build the Gaussian encoding: `⌈βn⌉ × n`, split into m row-blocks.
pub fn build(n: usize, m: usize, beta: f64, seed: u64) -> Encoding {
    let total_rows = (beta * n as f64).round() as usize;
    let mut rng = Pcg64::with_stream(seed, 0x6a55);
    let sigma = 1.0 / (n as f64).sqrt();
    let s = Mat::from_fn(total_rows, n, |_, _| sigma * Normal::sample_standard(&mut rng));
    Encoding {
        scheme: Scheme::Gaussian,
        beta: total_rows as f64 / n as f64,
        n,
        blocks: split_dense(s, m),
        // i.i.d. ensembles have no exploitable structure: dense fallback.
        fast: FastS::Dense,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::symmetric_eigenvalues;

    #[test]
    fn dimensions_and_beta() {
        let enc = build(64, 8, 2.0, 1);
        assert_eq!(enc.total_rows(), 128);
        assert_eq!(enc.n, 64);
        assert_eq!(enc.workers(), 8);
        assert!((enc.beta - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rows_have_near_unit_norm() {
        let enc = build(256, 4, 2.0, 2);
        let s = enc.stack(&[0, 1, 2, 3]);
        let mut mean_norm2 = 0.0;
        for i in 0..s.rows() {
            mean_norm2 += crate::linalg::dot(s.row(i), s.row(i));
        }
        mean_norm2 /= s.rows() as f64;
        assert!((mean_norm2 - 1.0).abs() < 0.05, "mean row norm² = {mean_norm2}");
    }

    #[test]
    fn full_gram_concentrates_near_identity() {
        // With all workers, G = (1/β)·SᵀS should have eigenvalues in a
        // Marchenko–Pastur-ish band around 1.
        let enc = build(96, 6, 3.0, 3);
        let g = enc.gram_normalized(&[0, 1, 2, 3, 4, 5]);
        let eigs = symmetric_eigenvalues(&g);
        // Marchenko–Pastur band for aspect ratio 1/β = 1/3:
        // [(1−√⅓)², (1+√⅓)²] ≈ [0.18, 2.49]; allow finite-n slack.
        let (lo, hi) = (eigs[0], *eigs.last().unwrap());
        assert!(lo > 0.05 && hi < 2.8, "spectrum [{lo:.3}, {hi:.3}] too wide");
    }

    #[test]
    fn deterministic_in_seed() {
        let a = build(32, 4, 2.0, 7);
        let b = build(32, 4, 2.0, 7);
        let (sa, sb) = (a.stack(&[0]), b.stack(&[0]));
        assert_eq!(sa.as_slice(), sb.as_slice());
        let c = build(32, 4, 2.0, 8);
        assert_ne!(a.stack(&[0]).as_slice(), c.stack(&[0]).as_slice());
    }
}
