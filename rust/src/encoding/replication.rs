//! Replication scheme support.
//!
//! The paper's replication baseline assigns each uncoded partition to
//! `r` distinct workers; the master uses whichever copy of a partition
//! arrives first in an iteration and discards duplicates. This is a
//! *partition map*, not an encoding matrix: [`ReplicationMap`] records
//! which partition each worker holds, and resolves a set of responding
//! workers to the set of distinct partitions recovered.

/// Maps m workers onto `partitions` replicated `r`-fold.
#[derive(Clone, Debug)]
pub struct ReplicationMap {
    /// partition index held by each worker (len m).
    worker_partition: Vec<usize>,
    /// number of distinct partitions.
    partitions: usize,
}

impl ReplicationMap {
    /// m workers, replication factor r (m must be divisible by r).
    /// Partition p is held by workers {p, p + m/r, p + 2m/r, …}, spreading
    /// replicas across the machine range so correlated stragglers (racks)
    /// hit distinct partitions.
    pub fn new(m: usize, r: usize) -> Self {
        assert!(r >= 1 && m % r == 0, "m={m} must be divisible by replication factor r={r}");
        let partitions = m / r;
        let worker_partition = (0..m).map(|w| w % partitions).collect();
        ReplicationMap { worker_partition, partitions }
    }

    pub fn partitions(&self) -> usize {
        self.partitions
    }

    pub fn workers(&self) -> usize {
        self.worker_partition.len()
    }

    /// Partition held by worker w.
    pub fn partition_of(&self, w: usize) -> usize {
        self.worker_partition[w]
    }

    /// Given responding workers, the distinct partitions recovered and,
    /// for each, the first responding worker that supplied it (in the
    /// order given — callers pass workers sorted by arrival time).
    pub fn resolve(&self, responded: &[usize]) -> Vec<(usize, usize)> {
        let mut seen = vec![false; self.partitions];
        let mut out = Vec::new();
        for &w in responded {
            let p = self.worker_partition[w];
            if !seen[p] {
                seen[p] = true;
                out.push((p, w));
            }
        }
        out
    }

    /// Number of distinct partitions covered by a responding set.
    pub fn coverage(&self, responded: &[usize]) -> usize {
        self.resolve(responded).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spread_assignment() {
        let map = ReplicationMap::new(8, 2);
        assert_eq!(map.partitions(), 4);
        // replicas of partition 0 are workers 0 and 4
        assert_eq!(map.partition_of(0), 0);
        assert_eq!(map.partition_of(4), 0);
        assert_eq!(map.partition_of(3), 3);
        assert_eq!(map.partition_of(7), 3);
    }

    #[test]
    fn resolve_dedups_in_arrival_order() {
        let map = ReplicationMap::new(8, 2);
        // worker 4 (partition 0) arrives before worker 0
        let got = map.resolve(&[4, 0, 1, 5]);
        assert_eq!(got, vec![(0, 4), (1, 1)]);
    }

    #[test]
    fn full_response_covers_all() {
        let map = ReplicationMap::new(12, 3);
        let all: Vec<usize> = (0..12).collect();
        assert_eq!(map.coverage(&all), 4);
    }

    #[test]
    fn both_replicas_straggling_loses_partition() {
        let map = ReplicationMap::new(8, 2);
        // partitions of workers {1,2,3,5,6,7}: missing both 0 and 4 → no partition 0
        let got = map.resolve(&[1, 2, 3, 5, 6, 7]);
        assert!(got.iter().all(|&(p, _)| p != 0));
        assert_eq!(map.coverage(&[1, 2, 3, 5, 6, 7]), 3);
    }

    #[test]
    #[should_panic]
    fn indivisible_m_rejected() {
        ReplicationMap::new(7, 2);
    }
}
