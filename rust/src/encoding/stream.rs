//! Streaming block encoder: apply any [`EncodingOp`] to a dataset that
//! arrives as row blocks ([`BlockSource`]) instead of one materialized
//! `Mat` — the out-of-core half of the paper's §4.2 "efficient
//! mechanisms for encoding large-scale data".
//!
//! Column view of the algebra: `S·X = Σ_b S[:, rows_b] · X_b` over the
//! source's row blocks `X_b`. Each fast path consumes that sum without
//! ever holding `X`:
//! - **FWHT** (Hadamard): full encode columns are needed before the
//!   transform, so the encoder makes one pass over the source per
//!   *column panel* ([`PANEL_COLS`] columns), reassembling exact
//!   columns (an `O(n)` buffer) and applying the same
//!   [`FwhtOp::apply`](super::FwhtOp::apply) as the in-memory path.
//! - **CSR** (Steiner / Haar / identity): the one sparse generator is
//!   swept row-range by row-range; each source block contributes the
//!   entries whose column falls inside the block, in the same ascending
//!   order as the in-memory sweep.
//! - **Dense** (Gaussian / Paley): one generator block is regenerated at
//!   a time (worker-outer loop, one source pass per block) and dropped
//!   after its fold — the operator-first memory story: the input is one
//!   shard, the generator is one block, and neither is ever whole.
//!
//! ## Bit-identity contract
//!
//! Every path accumulates each output element in *exactly* the
//! floating-point order of the corresponding in-memory
//! [`EncodingOp::encode_data`] kernel (the FWHT path reassembles exact
//! column bits; the dense/CSR paths continue the same left-to-right
//! fold across block boundaries, and dense blocks regenerate
//! bit-identically from the seed). [`encode_data_streamed`] is therefore
//! **bit-identical** to `enc.encode_data(&x)` for every scheme — the
//! property `rust/tests/shard_pipeline.rs` pins, and the reason a
//! sharded experiment's trace matches its in-memory twin bit-for-bit.
//!
//! Peak resident data: one source block, one `O(n)` column panel /
//! target buffer, at most one regenerated generator block, and the
//! encoded worker partitions themselves when a caller asks for all of
//! them at once ([`write_encoded_partitions`] instead streams CSR/dense
//! partitions out shard-by-shard and never holds more than one output
//! shard).

use super::{EncodingOp, Generator, SMatrix};
use crate::data::shard::{assemble_targets, BlockSource, ShardWriter};
use crate::linalg::{axpy, par, Csr, Mat};
use anyhow::{bail, ensure, Result};

/// Minimum columns reassembled per streaming pass on the FWHT path.
///
/// The FWHT transform needs a *complete* encode column before it can
/// run, so this path fundamentally carries a `Θ(n)` buffer (one column
/// is already `n` floats — that, not the shard size, is the FWHT
/// path's memory floor). The width knob only trades passes for memory
/// above that floor: the panel is `width · n` floats, the source is
/// re-read `⌈p / width⌉` times, and the width grows past this minimum
/// only while the panel stays within the source's one-block budget
/// (`max_block_rows · cols` floats), so wide shards buy fewer passes.
/// At the floor, memory is `PANEL_COLS · n` floats — independent of
/// `p`, but up to `PANEL_COLS×` one shard for very tall datasets.
pub const PANEL_COLS: usize = 32;

/// Resolved FWHT panel width for a source: at least [`PANEL_COLS`]
/// (see its doc for the Θ(n) memory floor), at most `p`, growing with
/// the one-shard memory budget in between.
fn panel_width(src: &dyn BlockSource) -> usize {
    let p = src.cols().max(1);
    let budget = src.max_block_rows().saturating_mul(p);
    // max-then-min (not clamp: PANEL_COLS may exceed p for narrow data)
    (budget / src.rows().max(1)).max(PANEL_COLS).min(p)
}

/// `out += S[:, k0..k0+xb.rows()] · xb`, continuing [`Mat::matmul`]'s
/// per-element ascending-`k` fold (same zero-skip, same `axpy` row
/// update) so that accumulating block-by-block over a full row stream
/// reproduces the in-memory product bit-for-bit.
fn acc_dense_block(s: &Mat, k0: usize, xb: &Mat, out: &mut Mat) {
    debug_assert_eq!(s.rows(), out.rows());
    debug_assert_eq!(xb.cols(), out.cols());
    let p = xb.cols();
    let kblk = xb.rows();
    if p == 0 || kblk == 0 {
        return;
    }
    par::par_chunks_mut(out.as_mut_slice(), par::CHUNK * p, kblk, |ci, cchunk| {
        let i0 = ci * par::CHUNK;
        for (di, crow) in cchunk.chunks_mut(p).enumerate() {
            let srow = &s.row(i0 + di)[k0..k0 + kblk];
            for (off, &aik) in srow.iter().enumerate() {
                // same zero-skip as Mat::matmul (keeps −0.0 bit-stable)
                if aik == 0.0 {
                    continue;
                }
                axpy(aik, xb.row(off), crow);
            }
        }
    });
}

/// `out += S[row0+local, k0..k0+xb.rows()] · xb` for the generator rows
/// `row0..row0+out.rows()` of the one sparse generator: each row's
/// entries whose column lands in the source block's range, in the same
/// ascending-column order as [`SMatrix::encode_mat`]'s sweep (the
/// binary-searched start changes where iteration begins, never the
/// in-range entry order, so bit-identity is untouched — while avoiding
/// an O(nnz) prefix rescan per source block).
fn acc_sparse_rows(s: &Csr, row0: usize, k0: usize, xb: &Mat, out: &mut Mat) {
    let k1 = k0 + xb.rows();
    for local in 0..out.rows() {
        let orow = out.row_mut(local);
        for (j, v) in s.row_iter_from(row0 + local, k0) {
            if j >= k1 {
                // CSR rows are column-sorted: nothing further in range.
                break;
            }
            axpy(v, xb.row(j - k0), orow);
        }
    }
}

/// Apply the full encoding to a streamed data matrix: returns `S_i·X`
/// per worker, bit-identical to [`EncodingOp::encode_data`] on the
/// equivalent in-memory `X` (see the [module docs](self)).
///
/// Pass budget: one source pass per FWHT column panel, one pass total
/// for CSR generators, and one pass per *worker block* for the dense
/// ensembles (the price of holding only one regenerated block at a
/// time; sources are re-iterable by contract).
pub fn encode_data_streamed(enc: &EncodingOp, src: &dyn BlockSource) -> Result<Vec<Mat>> {
    ensure!(
        enc.n == src.rows(),
        "encode dim mismatch: encoding for n={}, source has {} rows",
        enc.n,
        src.rows()
    );
    let p = src.cols();
    match &enc.gen {
        Generator::Fwht(op) => {
            // Encoded OUTPUT partitions (this fn's return value); the input X still
            // streams block-wise. The column-chunked ShardWriter mode that retires
            // this buffer is the ROADMAP's last-eager-buffers item.
            let mut outs: Vec<Mat> = (0..enc.workers())
                // lint:allow(eager-buffer) — output partitions by contract; input streams
                .map(|i| Mat::zeros(enc.block_rows(i), p))
                .collect();
            let n = src.rows();
            let width = panel_width(src);
            let mut j0 = 0;
            while j0 < p {
                let j1 = (j0 + width).min(p);
                let cb = j1 - j0;
                // column-major panel: cols[c·n + row] = X[row, j0+c]
                let mut cols = vec![0.0; cb * n];
                src.for_each_block(&mut |row0, xb, _y| {
                    for r in 0..xb.rows() {
                        let xrow = xb.row(r);
                        for (c, dst) in cols.chunks_mut(n).enumerate() {
                            dst[row0 + r] = xrow[j0 + c];
                        }
                    }
                    Ok(())
                })?;
                for (c, col) in cols.chunks(n).enumerate() {
                    // identical to the in-memory path from here: exact
                    // column bits → same FWHT → same block scatter
                    let enc_col = op.apply(col);
                    let j = j0 + c;
                    let mut r = 0;
                    for out in &mut outs {
                        for local in 0..out.rows() {
                            out[(local, j)] = enc_col[r];
                            r += 1;
                        }
                    }
                }
                j0 = j1;
            }
            Ok(outs)
        }
        Generator::Sparse(s) => {
            let mut outs: Vec<Mat> = (0..enc.workers())
                // lint:allow(eager-buffer) — output partitions by contract; input streams
                .map(|i| Mat::zeros(enc.block_rows(i), p))
                .collect();
            let bounds = enc.block_bounds().to_vec();
            src.for_each_block(&mut |k0, xb, _y| {
                for (i, out) in outs.iter_mut().enumerate() {
                    acc_sparse_rows(s, bounds[i], k0, xb, out);
                }
                Ok(())
            })?;
            Ok(outs)
        }
        Generator::Gaussian { .. } | Generator::Paley => {
            // Worker-outer: regenerate one block, fold the whole source
            // through it, drop it. m source passes, one live block.
            let mut outs: Vec<Mat> = Vec::with_capacity(enc.workers());
            enc.for_each_row_block(&mut |_i, b| {
                let sb = match b {
                    SMatrix::Dense(m) => m,
                    SMatrix::Sparse(_) => unreachable!("dense generator yields dense blocks"),
                };
                // lint:allow(eager-buffer) — one worker block at a time, block_rows × p
                let mut out = Mat::zeros(sb.rows(), p);
                src.for_each_block(&mut |k0, xb, _y| {
                    acc_dense_block(sb, k0, xb, &mut out);
                    Ok(())
                })?;
                outs.push(out);
                Ok(())
            })?;
            Ok(outs)
        }
    }
}

/// Encode generator rows `r0..r1` (global row indices of `S`) against a
/// streamed source: `S[r0..r1, :]·X` — the row-range primitive behind
/// the shard-by-shard partition writer. CSR sweeps the one generator;
/// dense ensembles regenerate exactly these rows from the seed. The
/// FWHT path computes whole columns at once and has no row-range form —
/// callers must use [`encode_data_streamed`] there.
pub fn encode_rows_streamed(
    enc: &EncodingOp,
    src: &dyn BlockSource,
    r0: usize,
    r1: usize,
) -> Result<Mat> {
    ensure!(enc.n == src.rows(), "encode dim mismatch");
    ensure!(r0 <= r1 && r1 <= enc.total_rows(), "row range out of bounds");
    let p = src.cols();
    // lint:allow(eager-buffer) — caller-bounded row range (one shard's worth when streaming)
    let mut out = Mat::zeros(r1 - r0, p);
    match &enc.gen {
        Generator::Fwht(_) => bail!(
            "the FWHT panel encoder completes whole columns across all row blocks \
             at once; a row-range encode has no fast path (column-chunked \
             write-out is a ROADMAP item)"
        ),
        Generator::Sparse(s) => {
            src.for_each_block(&mut |k0, xb, _y| {
                acc_sparse_rows(s, r0, k0, xb, &mut out);
                Ok(())
            })?;
        }
        Generator::Gaussian { seed } => {
            let sb = super::gaussian::dense_rows(enc.n, *seed, r0, r1);
            src.for_each_block(&mut |k0, xb, _y| {
                acc_dense_block(&sb, k0, xb, &mut out);
                Ok(())
            })?;
        }
        Generator::Paley => {
            // transient full frame per range — inherent to the
            // eigendecomposition-derived construction (size-guarded at
            // lower time), dropped before the source pass begins
            let sb = super::paley::paley_etf(enc.n)?.row_block(r0, r1);
            src.for_each_block(&mut |k0, xb, _y| {
                acc_dense_block(&sb, k0, xb, &mut out);
                Ok(())
            })?;
        }
    }
    Ok(out)
}

/// Dense-fold referee: encode a streamed source through explicitly
/// materialized per-worker dense blocks, continuing the [`Mat::matmul`]
/// fold across block boundaries. Used by `coded-opt bench` as the
/// denominator of the FWHT-vs-dense streamed pair (blocks are
/// materialized by the caller, outside the timed region) and by tests
/// as an equivalence referee.
pub fn encode_data_streamed_with_dense_blocks(
    blocks: &[Mat],
    src: &dyn BlockSource,
) -> Result<Vec<Mat>> {
    let p = src.cols();
    // lint:allow(eager-buffer) — outputs sized by the caller's generator blocks; X streams
    let mut outs: Vec<Mat> = blocks.iter().map(|b| Mat::zeros(b.rows(), p)).collect();
    src.for_each_block(&mut |k0, xb, _y| {
        for (b, out) in blocks.iter().zip(&mut outs) {
            acc_dense_block(b, k0, xb, out);
        }
        Ok(())
    })?;
    Ok(outs)
}

/// Encode the streamed target vector: returns `S_i·y` per worker,
/// bit-identical to [`EncodingOp::encode_vec`]. `y` is the one
/// full-length (`O(n)`) buffer the streaming pipeline assembles.
pub fn encode_vec_streamed(enc: &EncodingOp, src: &dyn BlockSource) -> Result<Vec<Vec<f64>>> {
    let y = assemble_targets(src)?;
    ensure!(y.len() == enc.n, "encode_vec dim mismatch");
    Ok(enc.encode_vec(&y))
}

/// Encode a streamed dataset and write the Parseval-normalized worker
/// partitions `(S̄_iX, S̄_iy)`, one shard dataset per worker
/// (`worker-NNN/` under `out_dir`). The normalization is the same
/// `1/√β` scaling the driver's worker build applies to the same
/// streamed encode output, and the round-trip test in this module pins
/// the written bits to it — `coded-opt encode` goes through here, so
/// the on-disk partitions cannot drift from what `run` computes.
///
/// Memory: CSR and dense-generator schemes stream each partition out
/// **shard-by-shard** through a [`ShardWriter`] — resident output is
/// one shard (plus one regenerated generator row-range; Paley keeps its
/// one per-call frame resident for the write, see below), at the cost
/// of one source pass per output shard. The FWHT panel path completes
/// output columns across *all* workers at once, so it still assembles
/// every partition before writing (an honest exception; the
/// column-chunked writer is a ROADMAP item — callers printing memory
/// expectations should branch on [`EncodingOp::fast_path`]).
pub fn write_encoded_partitions(
    enc: &EncodingOp,
    src: &dyn BlockSource,
    out_dir: &std::path::Path,
) -> Result<Vec<crate::data::shard::Manifest>> {
    let norm = 1.0 / enc.beta.sqrt();
    std::fs::create_dir_all(out_dir)?;
    // S̄y per worker: O(N) floats total — assembled up front either way.
    let sy: Option<Vec<Vec<f64>>> =
        if src.has_targets() { Some(encode_vec_streamed(enc, src)?) } else { None };
    let m = enc.workers();
    let mut manifests = Vec::with_capacity(m);
    if let Generator::Fwht(_) = &enc.gen {
        let mut sx = encode_data_streamed(enc, src)?;
        for (w, sxw) in sx.iter_mut().enumerate() {
            sxw.scale_inplace(norm);
            let yw: Option<Vec<f64>> = sy.as_ref().map(|sy| {
                let mut v = sy[w].clone();
                crate::linalg::scale(norm, &mut v);
                v
            });
            let dir = out_dir.join(format!("worker-{w:03}"));
            let rows = sxw.rows().max(1);
            manifests.push(crate::data::shard::shard_dataset(
                &*sxw,
                yw.as_deref(),
                &dir,
                rows.min(src.max_block_rows()),
            )?);
        }
        return Ok(manifests);
    }
    // Paley's row-range generation rebuilds the whole frame (conference
    // matrix + eigendecomposition); per-chunk or per-worker rebuilds of
    // identical bits would swamp the write, so build it ONCE per encode
    // call and slice it — one use, one generation, and the transient
    // peaks at the same full-frame size paley_etf reaches internally
    // anyway. Gaussian stays per-chunk (its PCG-jump regeneration is
    // O(chunk), so per-chunk keeps the smaller chunk×n generator slice).
    let paley_full: Option<Mat> = match &enc.gen {
        Generator::Paley => Some(super::paley::paley_etf(enc.n)?),
        _ => None,
    };
    for w in 0..m {
        let (r0, r1) = (enc.block_bounds()[w], enc.block_bounds()[w + 1]);
        let shard_rows = (r1 - r0).max(1).min(src.max_block_rows());
        let dir = out_dir.join(format!("worker-{w:03}"));
        let mut writer = ShardWriter::create(&dir, src.cols(), shard_rows, sy.is_some())?;
        let mut c0 = r0;
        while c0 < r1 {
            let c1 = (c0 + shard_rows).min(r1);
            let mut chunk = match &paley_full {
                Some(full) => {
                    let sb = full.row_block(c0, c1);
                    // lint:allow(eager-buffer) — one shard-sized chunk between writes
                    let mut out = Mat::zeros(c1 - c0, src.cols());
                    src.for_each_block(&mut |k0, xb, _y| {
                        acc_dense_block(&sb, k0, xb, &mut out);
                        Ok(())
                    })?;
                    out
                }
                None => encode_rows_streamed(enc, src, c0, c1)?,
            };
            chunk.scale_inplace(norm);
            let ychunk: Vec<f64> = match &sy {
                Some(sy) => {
                    let mut v = sy[w][c0 - r0..c1 - r0].to_vec();
                    crate::linalg::scale(norm, &mut v);
                    v
                }
                None => Vec::new(),
            };
            writer.append(&chunk, &ychunk)?;
            c0 = c1;
        }
        manifests.push(writer.finish()?);
    }
    Ok(manifests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;
    use crate::data::shard::MatSource;
    use crate::rng::Pcg64;

    fn random_mat(n: usize, p: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::from_fn(n, p, |_, _| rng.next_f64() - 0.5)
    }

    #[test]
    fn streamed_encode_is_bit_identical_for_every_scheme() {
        let (n, p, m) = (48, 9, 4);
        let x = random_mat(n, p, 5);
        for scheme in [
            Scheme::Uncoded,
            Scheme::Gaussian,
            Scheme::Hadamard,
            Scheme::Paley,
            Scheme::Steiner,
            Scheme::Haar,
        ] {
            let enc = EncodingOp::build(scheme, n, m, 2.0, 7).unwrap();
            let dense = enc.encode_data(&x);
            for block_rows in [1, 7, 16, 48, 100] {
                let src = MatSource::new(&x, None, block_rows);
                let streamed = encode_data_streamed(&enc, &src).unwrap();
                assert_eq!(streamed.len(), dense.len());
                for (sb, db) in streamed.iter().zip(&dense) {
                    assert_eq!(
                        sb.as_slice(),
                        db.as_slice(),
                        "{scheme:?} block_rows={block_rows}: streamed encode must be \
                         bit-identical to the in-memory encode"
                    );
                }
            }
        }
    }

    #[test]
    fn row_range_encode_matches_full_encode() {
        let (n, p, m) = (40, 5, 3);
        let x = random_mat(n, p, 19);
        for scheme in [Scheme::Uncoded, Scheme::Gaussian, Scheme::Steiner, Scheme::Paley] {
            let enc = EncodingOp::build(scheme, n, m, 2.0, 3).unwrap();
            let src = MatSource::new(&x, None, 11);
            let full = encode_data_streamed(&enc, &src).unwrap();
            for w in 0..m {
                let (r0, r1) = (enc.block_bounds()[w], enc.block_bounds()[w + 1]);
                // whole block in one range
                let got = encode_rows_streamed(&enc, &src, r0, r1).unwrap();
                assert_eq!(got.as_slice(), full[w].as_slice(), "{scheme:?} worker {w}");
                // and in two chunks — the writer's shard-by-shard shape
                if r1 - r0 >= 2 {
                    let mid = r0 + (r1 - r0) / 2;
                    let a = encode_rows_streamed(&enc, &src, r0, mid).unwrap();
                    let b = encode_rows_streamed(&enc, &src, mid, r1).unwrap();
                    let stacked = Mat::vstack(&[&a, &b]);
                    assert_eq!(
                        stacked.as_slice(),
                        full[w].as_slice(),
                        "{scheme:?} worker {w}: chunked == whole"
                    );
                }
            }
        }
    }

    #[test]
    fn dense_blocks_referee_matches_fast_paths() {
        let (n, p, m) = (32, 6, 4);
        let x = random_mat(n, p, 23);
        for scheme in [Scheme::Hadamard, Scheme::Haar] {
            let enc = EncodingOp::build(scheme, n, m, 2.0, 5).unwrap();
            let blocks: Vec<Mat> =
                (0..m).map(|i| enc.row_block(i).to_dense()).collect();
            let src = MatSource::new(&x, None, 9);
            let fast = encode_data_streamed(&enc, &src).unwrap();
            let referee = encode_data_streamed_with_dense_blocks(&blocks, &src).unwrap();
            for (f, r) in fast.iter().zip(&referee) {
                crate::testutil::assert_allclose(
                    f.as_slice(),
                    r.as_slice(),
                    1e-12,
                    &format!("{scheme:?} fast vs dense-blocks referee"),
                );
            }
        }
    }

    #[test]
    fn streamed_encode_vec_is_bit_identical() {
        let n = 40;
        let x = random_mat(n, 3, 9);
        let mut rng = Pcg64::new(13);
        let y: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
        for scheme in [Scheme::Hadamard, Scheme::Gaussian, Scheme::Steiner] {
            let enc = EncodingOp::build(scheme, n, 4, 2.0, 3).unwrap();
            let dense = enc.encode_vec(&y);
            let src = MatSource::new(&x, Some(&y), 11);
            let streamed = encode_vec_streamed(&enc, &src).unwrap();
            assert_eq!(streamed, dense, "{scheme:?}");
        }
    }

    #[test]
    fn panel_boundary_column_counts_are_exact() {
        // p > PANEL_COLS forces multiple passes; p not a multiple of the
        // panel width exercises the tail panel.
        let (n, p, m) = (32, PANEL_COLS + 5, 4);
        let x = random_mat(n, p, 17);
        let enc = EncodingOp::build(Scheme::Hadamard, n, m, 2.0, 1).unwrap();
        let dense = enc.encode_data(&x);
        let src = MatSource::new(&x, None, 10);
        let streamed = encode_data_streamed(&enc, &src).unwrap();
        for (sb, db) in streamed.iter().zip(&dense) {
            assert_eq!(sb.as_slice(), db.as_slice());
        }
    }

    #[test]
    fn written_partitions_roundtrip_with_driver_normalization() {
        use crate::data::shard::ShardedSource;
        let n = 24;
        let x = random_mat(n, 5, 21);
        let mut rng = Pcg64::new(23);
        let y: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
        // CSR and FWHT paths both pinned: the incremental shard-by-shard
        // writer and the all-partitions FWHT fallback must write the same
        // bits the driver's worker build computes.
        for scheme in [Scheme::Hadamard, Scheme::Steiner, Scheme::Gaussian] {
            let enc = EncodingOp::build(scheme, n, 3, 2.0, 9).unwrap();
            let src = MatSource::new(&x, Some(&y), 7);
            let dir = std::env::temp_dir().join(format!(
                "coded-opt-stream-parts-{}-{}",
                enc.scheme.name(),
                std::process::id()
            ));
            let _ = std::fs::remove_dir_all(&dir);
            let manifests = write_encoded_partitions(&enc, &src, &dir).unwrap();
            assert_eq!(manifests.len(), 3);
            // expected bits: the streamed encode scaled by 1/√β — exactly
            // what the driver's worker build stores for the same source
            let norm = 1.0 / enc.beta.sqrt();
            let sx = encode_data_streamed(&enc, &src).unwrap();
            let sy = encode_vec_streamed(&enc, &src).unwrap();
            for w in 0..3 {
                let part = ShardedSource::open(dir.join(format!("worker-{w:03}"))).unwrap();
                let (px, py) = part.load_dense().unwrap();
                let mut want_x = sx[w].clone();
                want_x.scale_inplace(norm);
                let mut want_y = sy[w].clone();
                crate::linalg::scale(norm, &mut want_y);
                assert_eq!(px.as_slice(), want_x.as_slice(), "{scheme:?} worker {w} S̄X bits");
                assert_eq!(py.unwrap(), want_y, "{scheme:?} worker {w} S̄y bits");
                if enc.fast_path() != crate::encoding::FastPath::Fwht {
                    // incremental path: the partition really was written in
                    // source-shard-sized shards, not one monolith
                    let expect_shards =
                        enc.block_rows(w).div_ceil(src.max_block_rows());
                    assert_eq!(
                        part.manifest().shards.len(),
                        expect_shards.max(1),
                        "{scheme:?} worker {w}: shard-by-shard flush"
                    );
                }
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn dim_mismatch_is_rejected() {
        let x = random_mat(20, 4, 1);
        let enc = EncodingOp::build(Scheme::Gaussian, 24, 4, 2.0, 1).unwrap();
        let src = MatSource::new(&x, None, 8);
        assert!(encode_data_streamed(&enc, &src).is_err());
        assert!(encode_rows_streamed(&enc, &src, 0, 4).is_err());
    }
}
