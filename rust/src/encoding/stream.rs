//! Streaming block encoder: apply any [`Encoding`] to a dataset that
//! arrives as row blocks ([`BlockSource`]) instead of one materialized
//! `Mat` — the out-of-core half of the paper's §4.2 "efficient
//! mechanisms for encoding large-scale data".
//!
//! Column view of the algebra: `S·X = Σ_b S[:, rows_b] · X_b` over the
//! source's row blocks `X_b`. Each fast path consumes that sum without
//! ever holding `X`:
//! - **FWHT** (Hadamard): full encode columns are needed before the
//!   transform, so the encoder makes one pass over the source per
//!   *column panel* ([`PANEL_COLS`] columns), reassembling exact
//!   columns (an `O(n)` buffer) and applying the same
//!   [`FwhtOp::apply`](super::FwhtOp::apply) as the in-memory path.
//! - **CSR** (Steiner / Haar / identity): each block accumulates the
//!   entries whose column falls inside the block's row range, in the
//!   same ascending order as the in-memory sweep.
//! - **Dense** (Gaussian / Paley): each block continues the per-element
//!   ascending-`k` fold of [`Mat::matmul`].
//!
//! ## Bit-identity contract
//!
//! Every path accumulates each output element in *exactly* the
//! floating-point order of the corresponding in-memory
//! [`Encoding::encode_data`] kernel (the FWHT path reassembles exact
//! column bits; the dense/CSR paths continue the same left-to-right
//! fold across block boundaries). [`encode_data_streamed`] is therefore
//! **bit-identical** to `enc.encode_data(&x)` for every scheme — the
//! property `rust/tests/shard_pipeline.rs` pins, and the reason a
//! sharded experiment's trace matches its in-memory twin bit-for-bit.
//!
//! Peak resident data: one source block, one `O(n)` column panel /
//! target buffer, and the encoded worker partitions themselves (the
//! product being built) — never the `n × p` input.

use super::{Encoding, FastS, SMatrix};
use crate::data::shard::{assemble_targets, BlockSource};
use crate::linalg::{axpy, par, Csr, Mat};
use anyhow::{ensure, Result};

/// Minimum columns reassembled per streaming pass on the FWHT path.
///
/// The FWHT transform needs a *complete* encode column before it can
/// run, so this path fundamentally carries a `Θ(n)` buffer (one column
/// is already `n` floats — that, not the shard size, is the FWHT
/// path's memory floor). The width knob only trades passes for memory
/// above that floor: the panel is `width · n` floats, the source is
/// re-read `⌈p / width⌉` times, and the width grows past this minimum
/// only while the panel stays within the source's one-block budget
/// (`max_block_rows · cols` floats), so wide shards buy fewer passes.
/// At the floor, memory is `PANEL_COLS · n` floats — independent of
/// `p`, but up to `PANEL_COLS×` one shard for very tall datasets.
pub const PANEL_COLS: usize = 32;

/// Resolved FWHT panel width for a source: at least [`PANEL_COLS`]
/// (see its doc for the Θ(n) memory floor), at most `p`, growing with
/// the one-shard memory budget in between.
fn panel_width(src: &dyn BlockSource) -> usize {
    let p = src.cols().max(1);
    let budget = src.max_block_rows().saturating_mul(p);
    // max-then-min (not clamp: PANEL_COLS may exceed p for narrow data)
    (budget / src.rows().max(1)).max(PANEL_COLS).min(p)
}

/// `out += S[:, k0..k0+xb.rows()] · xb`, continuing [`Mat::matmul`]'s
/// per-element ascending-`k` fold (same zero-skip, same `axpy` row
/// update) so that accumulating block-by-block over a full row stream
/// reproduces the in-memory product bit-for-bit.
fn acc_dense_block(s: &Mat, k0: usize, xb: &Mat, out: &mut Mat) {
    debug_assert_eq!(s.rows(), out.rows());
    debug_assert_eq!(xb.cols(), out.cols());
    let p = xb.cols();
    let kblk = xb.rows();
    if p == 0 || kblk == 0 {
        return;
    }
    par::par_chunks_mut(out.as_mut_slice(), par::CHUNK * p, kblk, |ci, cchunk| {
        let i0 = ci * par::CHUNK;
        for (di, crow) in cchunk.chunks_mut(p).enumerate() {
            let srow = &s.row(i0 + di)[k0..k0 + kblk];
            for (off, &aik) in srow.iter().enumerate() {
                // same zero-skip as Mat::matmul (keeps −0.0 bit-stable)
                if aik == 0.0 {
                    continue;
                }
                axpy(aik, xb.row(off), crow);
            }
        }
    });
}

/// `out += S[:, k0..k0+xb.rows()] · xb` for a CSR block: the entries of
/// each row whose column lands in the block's range, in the same
/// ascending-column order as [`SMatrix::encode_mat`]'s sweep (the
/// binary-searched start changes where iteration begins, never the
/// in-range entry order, so bit-identity is untouched — while avoiding
/// an O(nnz) prefix rescan per source block).
fn acc_sparse_block(s: &Csr, k0: usize, xb: &Mat, out: &mut Mat) {
    debug_assert_eq!(s.rows(), out.rows());
    let k1 = k0 + xb.rows();
    for i in 0..s.rows() {
        let orow = out.row_mut(i);
        for (j, v) in s.row_iter_from(i, k0) {
            if j >= k1 {
                // CSR rows are column-sorted: nothing further in range.
                break;
            }
            axpy(v, xb.row(j - k0), orow);
        }
    }
}

/// Apply the full encoding to a streamed data matrix: returns `S_i·X`
/// per worker, bit-identical to [`Encoding::encode_data`] on the
/// equivalent in-memory `X` (see the [module docs](self)).
pub fn encode_data_streamed(enc: &Encoding, src: &dyn BlockSource) -> Result<Vec<Mat>> {
    ensure!(
        enc.n == src.rows(),
        "encode dim mismatch: encoding for n={}, source has {} rows",
        enc.n,
        src.rows()
    );
    let p = src.cols();
    let mut outs: Vec<Mat> = enc.blocks.iter().map(|b| Mat::zeros(b.rows(), p)).collect();
    match &enc.fast {
        FastS::Fwht(op) => {
            let n = src.rows();
            let width = panel_width(src);
            let mut j0 = 0;
            while j0 < p {
                let j1 = (j0 + width).min(p);
                let cb = j1 - j0;
                // column-major panel: cols[c·n + row] = X[row, j0+c]
                let mut cols = vec![0.0; cb * n];
                src.for_each_block(&mut |row0, xb, _y| {
                    for r in 0..xb.rows() {
                        let xrow = xb.row(r);
                        for (c, dst) in cols.chunks_mut(n).enumerate() {
                            dst[row0 + r] = xrow[j0 + c];
                        }
                    }
                    Ok(())
                })?;
                for (c, col) in cols.chunks(n).enumerate() {
                    // identical to the in-memory path from here: exact
                    // column bits → same FWHT → same block scatter
                    let enc_col = op.apply(col);
                    let j = j0 + c;
                    let mut r = 0;
                    for out in &mut outs {
                        for local in 0..out.rows() {
                            out[(local, j)] = enc_col[r];
                            r += 1;
                        }
                    }
                }
                j0 = j1;
            }
        }
        FastS::Sparse(_) | FastS::Dense => {
            src.for_each_block(&mut |row0, xb, _y| {
                for (b, out) in enc.blocks.iter().zip(&mut outs) {
                    match b {
                        SMatrix::Dense(s) => acc_dense_block(s, row0, xb, out),
                        SMatrix::Sparse(s) => acc_sparse_block(s, row0, xb, out),
                    }
                }
                Ok(())
            })?;
        }
    }
    Ok(outs)
}

/// Encode the streamed target vector: returns `S_i·y` per worker,
/// bit-identical to [`Encoding::encode_vec`]. `y` is the one
/// full-length (`O(n)`) buffer the streaming pipeline assembles.
pub fn encode_vec_streamed(enc: &Encoding, src: &dyn BlockSource) -> Result<Vec<Vec<f64>>> {
    let y = assemble_targets(src)?;
    ensure!(y.len() == enc.n, "encode_vec dim mismatch");
    Ok(enc.encode_vec(&y))
}

/// Encode a streamed dataset and write the Parseval-normalized worker
/// partitions `(S̄_iX, S̄_iy)`, one shard dataset per worker
/// (`worker-NNN/` under `out_dir`). The normalization is the same
/// `1/√β` scaling the driver's worker build applies to the same
/// streamed encode output, and the round-trip test in this module pins
/// the written bits to it — `coded-opt encode` goes through here, so
/// the on-disk partitions cannot drift from what `run` computes.
pub fn write_encoded_partitions(
    enc: &Encoding,
    src: &dyn BlockSource,
    out_dir: &std::path::Path,
) -> Result<Vec<crate::data::shard::Manifest>> {
    let norm = 1.0 / enc.beta.sqrt();
    let mut sx = encode_data_streamed(enc, src)?;
    let sy: Option<Vec<Vec<f64>>> =
        if src.has_targets() { Some(encode_vec_streamed(enc, src)?) } else { None };
    std::fs::create_dir_all(out_dir)?;
    let mut manifests = Vec::with_capacity(sx.len());
    for (w, sxw) in sx.iter_mut().enumerate() {
        sxw.scale_inplace(norm);
        let yw: Option<Vec<f64>> = sy.as_ref().map(|sy| {
            let mut v = sy[w].clone();
            crate::linalg::scale(norm, &mut v);
            v
        });
        let dir = out_dir.join(format!("worker-{w:03}"));
        let rows = sxw.rows().max(1);
        manifests.push(crate::data::shard::shard_dataset(
            &*sxw,
            yw.as_deref(),
            &dir,
            rows.min(src.max_block_rows()),
        )?);
    }
    Ok(manifests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheme;
    use crate::data::shard::MatSource;
    use crate::rng::Pcg64;

    fn random_mat(n: usize, p: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::from_fn(n, p, |_, _| rng.next_f64() - 0.5)
    }

    #[test]
    fn streamed_encode_is_bit_identical_for_every_scheme() {
        let (n, p, m) = (48, 9, 4);
        let x = random_mat(n, p, 5);
        for scheme in [
            Scheme::Uncoded,
            Scheme::Gaussian,
            Scheme::Hadamard,
            Scheme::Paley,
            Scheme::Steiner,
            Scheme::Haar,
        ] {
            let enc = Encoding::build(scheme, n, m, 2.0, 7).unwrap();
            let dense = enc.encode_data(&x);
            for block_rows in [1, 7, 16, 48, 100] {
                let src = MatSource::new(&x, None, block_rows);
                let streamed = encode_data_streamed(&enc, &src).unwrap();
                assert_eq!(streamed.len(), dense.len());
                for (sb, db) in streamed.iter().zip(&dense) {
                    assert_eq!(
                        sb.as_slice(),
                        db.as_slice(),
                        "{scheme:?} block_rows={block_rows}: streamed encode must be \
                         bit-identical to the in-memory encode"
                    );
                }
            }
        }
    }

    #[test]
    fn streamed_encode_vec_is_bit_identical() {
        let n = 40;
        let x = random_mat(n, 3, 9);
        let mut rng = Pcg64::new(13);
        let y: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
        for scheme in [Scheme::Hadamard, Scheme::Gaussian, Scheme::Steiner] {
            let enc = Encoding::build(scheme, n, 4, 2.0, 3).unwrap();
            let dense = enc.encode_vec(&y);
            let src = MatSource::new(&x, Some(&y), 11);
            let streamed = encode_vec_streamed(&enc, &src).unwrap();
            assert_eq!(streamed, dense, "{scheme:?}");
        }
    }

    #[test]
    fn panel_boundary_column_counts_are_exact() {
        // p > PANEL_COLS forces multiple passes; p not a multiple of the
        // panel width exercises the tail panel.
        let (n, p, m) = (32, PANEL_COLS + 5, 4);
        let x = random_mat(n, p, 17);
        let enc = Encoding::build(Scheme::Hadamard, n, m, 2.0, 1).unwrap();
        let dense = enc.encode_data(&x);
        let src = MatSource::new(&x, None, 10);
        let streamed = encode_data_streamed(&enc, &src).unwrap();
        for (sb, db) in streamed.iter().zip(&dense) {
            assert_eq!(sb.as_slice(), db.as_slice());
        }
    }

    #[test]
    fn written_partitions_roundtrip_with_driver_normalization() {
        use crate::data::shard::ShardedSource;
        let n = 24;
        let x = random_mat(n, 5, 21);
        let mut rng = Pcg64::new(23);
        let y: Vec<f64> = (0..n).map(|_| rng.next_f64() - 0.5).collect();
        let enc = Encoding::build(Scheme::Hadamard, n, 3, 2.0, 9).unwrap();
        let src = MatSource::new(&x, Some(&y), 7);
        let dir = std::env::temp_dir()
            .join(format!("coded-opt-stream-parts-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let manifests = write_encoded_partitions(&enc, &src, &dir).unwrap();
        assert_eq!(manifests.len(), 3);
        // expected bits: the streamed encode scaled by 1/√β — exactly
        // what the driver's worker build stores for the same source
        let norm = 1.0 / enc.beta.sqrt();
        let sx = encode_data_streamed(&enc, &src).unwrap();
        let sy = encode_vec_streamed(&enc, &src).unwrap();
        for w in 0..3 {
            let part = ShardedSource::open(dir.join(format!("worker-{w:03}"))).unwrap();
            let (px, py) = part.load_dense().unwrap();
            let mut want_x = sx[w].clone();
            want_x.scale_inplace(norm);
            let mut want_y = sy[w].clone();
            crate::linalg::scale(norm, &mut want_y);
            assert_eq!(px.as_slice(), want_x.as_slice(), "worker {w} S̄X bits");
            assert_eq!(py.unwrap(), want_y, "worker {w} S̄y bits");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dim_mismatch_is_rejected() {
        let x = random_mat(20, 4, 1);
        let enc = Encoding::build(Scheme::Gaussian, 24, 4, 2.0, 1).unwrap();
        let src = MatSource::new(&x, None, 8);
        assert!(encode_data_streamed(&enc, &src).is_err());
    }
}
