//! Encoding matrices (paper §4: Code Design).
//!
//! An encoding is a tall matrix `S ∈ R^{N×n}`, `N = βn`, partitioned into
//! `m` row-blocks `S_i`, one per worker. Under data parallelism worker `i`
//! stores `(S_i X, S_i y)`; under model parallelism it stores the column
//! block `X S_iᵀ`. All constructions here produce (exactly or
//! approximately) *tight frames*: `SᵀS = βI`, which preserves the original
//! optimum when all workers respond (paper §4.1), while the block-RIP
//! behaviour of submatrices `S_A` governs robustness when only `k` of `m`
//! respond.
//!
//! Constructions:
//! - [`gaussian`]    — i.i.d. N(0, 1/n) dense ensemble (eq. 8–9 scaling).
//! - [`hadamard`]    — column-subsampled Sylvester–Hadamard (exact tight
//!   frame; FWHT fast path, §4.2.2).
//! - [`paley`]       — Paley conference-matrix ETF (β = 2).
//! - [`steiner`]     — sparse Steiner ETF from (2,2,v)-Steiner systems.
//! - [`haar`]        — column-subsampled Haar wavelet matrix (sparse).
//! - uncoded / replication — identity partitioning, with or without
//!   block duplication ([`replication`]).

pub mod gaussian;
pub mod haar;
pub mod hadamard;
pub mod paley;
pub mod replication;
pub mod spectrum;
pub mod steiner;
pub mod stream;

pub use hadamard::FwhtOp;
pub use replication::ReplicationMap;
pub use spectrum::{SpectrumStats, SubsetSpectrum};

use crate::config::Scheme;
use crate::linalg::{Csr, Mat};
use anyhow::Result;

/// Structured application of an encoding operator: `S·x` / `Sᵀ·x`
/// without materializing the dense generator where structure allows.
///
/// This is the paper's §4.2 "efficient mechanisms for encoding
/// large-scale data" made into an interface: the Hadamard scheme applies
/// through FWHT in `O(N log N)`, the sparse Steiner / Haar / identity
/// schemes through one CSR product in `O(nnz)`, and only the
/// unstructured ensembles (Gaussian, Paley) fall back to the dense
/// per-block product.
pub trait Encoder {
    /// `S·x` — encode a data-dimension vector to `N = βn` encoded rows.
    fn apply(&self, x: &[f64]) -> Vec<f64>;

    /// `Sᵀ·x` — project an encoded-row vector back to data dimension
    /// (the model-parallel reconstruction `w = Sᵀv`).
    fn apply_t(&self, x: &[f64]) -> Vec<f64>;
}

/// The structured form of a full generator `S`, carried alongside the
/// per-worker row blocks. Dense materialization is the *fallback*, not
/// the default: constructions with exploitable structure record it here
/// and the encode hot paths ([`Encoding::encode_data`],
/// [`Encoding::encode_vec`], [`Encoder::apply`], [`Encoder::apply_t`])
/// dispatch on it.
#[derive(Clone, Debug)]
pub enum FastS {
    /// FWHT-able subsampled Hadamard (O(N log N) apply).
    Fwht(FwhtOp),
    /// One CSR for the whole generator (sparse constructions: Steiner,
    /// subsampled Haar, identity/replication partitioning).
    Sparse(Csr),
    /// No exploitable structure — fall back to the dense blocks
    /// (Gaussian, Paley).
    Dense,
}

/// A worker's row-block `S_i`, stored dense or sparse depending on the
/// construction.
#[derive(Clone, Debug)]
pub enum SMatrix {
    Dense(Mat),
    Sparse(Csr),
}

impl SMatrix {
    pub fn rows(&self) -> usize {
        match self {
            SMatrix::Dense(m) => m.rows(),
            SMatrix::Sparse(s) => s.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            SMatrix::Dense(m) => m.cols(),
            SMatrix::Sparse(s) => s.cols(),
        }
    }

    /// y = S_i·x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        match self {
            SMatrix::Dense(m) => m.matvec(x),
            SMatrix::Sparse(s) => s.matvec(x),
        }
    }

    /// y = S_iᵀ·x.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        match self {
            SMatrix::Dense(m) => m.matvec_t(x),
            SMatrix::Sparse(s) => s.matvec_t(x),
        }
    }

    /// Dense copy (tests, spectrum analysis, encoding small shards).
    pub fn to_dense(&self) -> Mat {
        match self {
            SMatrix::Dense(m) => m.clone(),
            SMatrix::Sparse(s) => s.to_dense(),
        }
    }

    /// S_i·X for a dense data matrix X (row-block of the encoded data).
    pub fn encode_mat(&self, x: &Mat) -> Mat {
        assert_eq!(self.cols(), x.rows(), "encode dim mismatch");
        match self {
            SMatrix::Dense(s) => s.matmul(x),
            SMatrix::Sparse(s) => {
                let mut out = Mat::zeros(s.rows(), x.cols());
                for i in 0..s.rows() {
                    for (j, v) in s.row_iter(i) {
                        crate::linalg::axpy(v, x.row(j), out.row_mut(i));
                    }
                }
                out
            }
        }
    }

    /// Fraction of non-zero entries (1.0 for dense).
    pub fn density(&self) -> f64 {
        match self {
            SMatrix::Dense(_) => 1.0,
            SMatrix::Sparse(s) => s.nnz() as f64 / (s.rows() * s.cols()) as f64,
        }
    }
}

/// A full encoding: the row-blocks `S_i`, one per worker, plus the
/// structured form of the full generator for the fast encode paths.
#[derive(Clone, Debug)]
pub struct Encoding {
    pub scheme: Scheme,
    /// Achieved redundancy factor (total rows / n); constructions round
    /// to feasible sizes so this can differ slightly from the request.
    pub beta: f64,
    /// Data dimension n (columns of S).
    pub n: usize,
    /// Per-worker row-blocks.
    pub blocks: Vec<SMatrix>,
    /// Structured full-S operator ([`FastS::Dense`] when the
    /// construction has no exploitable structure).
    pub fast: FastS,
}

impl Encoding {
    /// Build an encoding for scheme / dimension / workers / redundancy.
    ///
    /// `n` is the number of data rows (data parallelism) or model
    /// coordinates (model parallelism). Replication is *not* built here —
    /// it is a partitioning strategy, see [`ReplicationMap`]; requesting
    /// it returns the identity encoding (the duplication happens at the
    /// cluster layer).
    pub fn build(scheme: Scheme, n: usize, m: usize, beta: f64, seed: u64) -> Result<Encoding> {
        anyhow::ensure!(n > 0 && m > 0, "n and m must be positive");
        anyhow::ensure!(beta >= 1.0, "β must be ≥ 1");
        let enc = match scheme {
            Scheme::Uncoded | Scheme::Replication => identity_encoding(n, m),
            Scheme::Gaussian => gaussian::build(n, m, beta, seed),
            Scheme::Hadamard => hadamard::build(n, m, beta, seed),
            Scheme::Paley => paley::build(n, m)?,
            Scheme::Steiner => steiner::build(n, m)?,
            Scheme::Haar => haar::build(n, m, beta, seed),
        };
        debug_assert_eq!(enc.blocks.len(), m);
        Ok(enc)
    }

    /// Number of workers m.
    pub fn workers(&self) -> usize {
        self.blocks.len()
    }

    /// Total encoded rows N = Σᵢ rows(S_i).
    pub fn total_rows(&self) -> usize {
        self.blocks.iter().map(|b| b.rows()).sum()
    }

    /// Stack `S_A = [S_i]_{i∈A}` densely (spectrum analysis / tests).
    pub fn stack(&self, subset: &[usize]) -> Mat {
        let blocks: Vec<Mat> = subset.iter().map(|&i| self.blocks[i].to_dense()).collect();
        let refs: Vec<&Mat> = blocks.iter().collect();
        Mat::vstack(&refs)
    }

    /// Normalized Gram `G_A = (1/(ηβ))·S_Aᵀ S_A`, whose eigenvalue spread
    /// around 1 is the ε of the block-RIP condition (Definition 1).
    pub fn gram_normalized(&self, subset: &[usize]) -> Mat {
        let sa = self.stack(subset);
        let eta = subset.len() as f64 / self.workers() as f64;
        let mut g = sa.gram();
        g.scale_inplace(1.0 / (eta * self.beta));
        g
    }

    /// Apply the full encoding to a data matrix: returns `S_i·X` per
    /// worker.
    ///
    /// Structure-aware: the FWHT path encodes column-by-column in
    /// `O(p·N log N)` instead of the dense `O(p·N·n)` block products
    /// (≤ rounding-level difference from the dense path); sparse
    /// generators already encode block-wise in `O(nnz·p)`. The dense
    /// per-block product is the fallback.
    pub fn encode_data(&self, x: &Mat) -> Vec<Mat> {
        assert_eq!(self.n, x.rows(), "encode dim mismatch");
        match &self.fast {
            FastS::Fwht(op) => {
                let p = x.cols();
                let mut outs: Vec<Mat> =
                    self.blocks.iter().map(|b| Mat::zeros(b.rows(), p)).collect();
                let mut col = vec![0.0; x.rows()];
                for j in 0..p {
                    for (i, c) in col.iter_mut().enumerate() {
                        *c = x[(i, j)];
                    }
                    let enc = op.apply(&col);
                    let mut r = 0;
                    for out in &mut outs {
                        for local in 0..out.rows() {
                            out[(local, j)] = enc[r];
                            r += 1;
                        }
                    }
                }
                outs
            }
            FastS::Sparse(_) | FastS::Dense => {
                self.blocks.iter().map(|s| s.encode_mat(x)).collect()
            }
        }
    }

    /// Apply to a vector: returns `S_i·y` per worker (one structured
    /// full-S apply sliced at the block boundaries where possible).
    pub fn encode_vec(&self, y: &[f64]) -> Vec<Vec<f64>> {
        match &self.fast {
            FastS::Fwht(_) | FastS::Sparse(_) => {
                let full = self.apply(y);
                let mut out = Vec::with_capacity(self.blocks.len());
                let mut r = 0;
                for b in &self.blocks {
                    out.push(full[r..r + b.rows()].to_vec());
                    r += b.rows();
                }
                out
            }
            FastS::Dense => self.blocks.iter().map(|s| s.matvec(y)).collect(),
        }
    }
}

impl Encoder for Encoding {
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n, "apply dim mismatch");
        match &self.fast {
            FastS::Fwht(op) => op.apply(x),
            FastS::Sparse(s) => s.matvec(x),
            FastS::Dense => {
                let mut out = Vec::with_capacity(self.total_rows());
                for b in &self.blocks {
                    out.extend(b.matvec(x));
                }
                out
            }
        }
    }

    fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.total_rows(), "apply_t dim mismatch");
        match &self.fast {
            FastS::Fwht(op) => op.apply_t(x),
            FastS::Sparse(s) => s.matvec_t(x),
            FastS::Dense => {
                let mut out = vec![0.0; self.n];
                let mut r = 0;
                for b in &self.blocks {
                    let part = b.matvec_t(&x[r..r + b.rows()]);
                    crate::linalg::axpy(1.0, &part, &mut out);
                    r += b.rows();
                }
                out
            }
        }
    }
}

impl Encoder for SMatrix {
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        self.matvec(x)
    }

    fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_t(x)
    }
}

/// Identity encoding: S = I split into m near-equal contiguous row blocks
/// (the uncoded baseline).
pub fn identity_encoding(n: usize, m: usize) -> Encoding {
    let triplets: Vec<(usize, usize, f64)> = (0..n).map(|r| (r, r, 1.0)).collect();
    let full = Csr::from_triplets(n, n, &triplets);
    let bounds = partition_bounds(n, m);
    let blocks = bounds
        .windows(2)
        .map(|w| SMatrix::Sparse(full.row_block(w[0], w[1])))
        .collect();
    Encoding { scheme: Scheme::Uncoded, beta: 1.0, n, blocks, fast: FastS::Sparse(full) }
}

/// Boundaries that split `total` items into `m` near-equal contiguous
/// chunks: returns m+1 offsets. Earlier chunks get the remainder.
pub fn partition_bounds(total: usize, m: usize) -> Vec<usize> {
    let base = total / m;
    let rem = total % m;
    let mut bounds = Vec::with_capacity(m + 1);
    let mut acc = 0;
    bounds.push(0);
    for i in 0..m {
        acc += base + usize::from(i < rem);
        bounds.push(acc);
    }
    bounds
}

/// Split a dense matrix `S ∈ R^{N×n}` into m near-equal row-block
/// [`SMatrix::Dense`] chunks.
pub(crate) fn split_dense(s: Mat, m: usize) -> Vec<SMatrix> {
    let bounds = partition_bounds(s.rows(), m);
    bounds
        .windows(2)
        .map(|w| SMatrix::Dense(s.row_block(w[0], w[1])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_bounds_cover_everything() {
        assert_eq!(partition_bounds(10, 3), vec![0, 4, 7, 10]);
        assert_eq!(partition_bounds(9, 3), vec![0, 3, 6, 9]);
        assert_eq!(partition_bounds(2, 4), vec![0, 1, 2, 2, 2]);
    }

    #[test]
    fn identity_encoding_blocks_are_identity_rows() {
        let enc = identity_encoding(7, 3);
        assert_eq!(enc.total_rows(), 7);
        assert_eq!(enc.workers(), 3);
        let x: Vec<f64> = (0..7).map(|i| i as f64).collect();
        let encoded = enc.encode_vec(&x);
        // Blocks are contiguous slices of x.
        assert_eq!(encoded[0], vec![0.0, 1.0, 2.0]);
        assert_eq!(encoded[1], vec![3.0, 4.0]);
        assert_eq!(encoded[2], vec![5.0, 6.0]);
    }

    #[test]
    fn build_rejects_bad_args() {
        assert!(Encoding::build(Scheme::Gaussian, 0, 4, 2.0, 1).is_err());
        assert!(Encoding::build(Scheme::Gaussian, 16, 4, 0.5, 1).is_err());
    }

    #[test]
    fn stack_concatenates_subset_in_order() {
        let enc = identity_encoding(6, 3);
        let sa = enc.stack(&[2, 0]);
        assert_eq!(sa.rows(), 4);
        // first rows come from block 2 (rows 4..6 of I)
        assert_eq!(sa[(0, 4)], 1.0);
        assert_eq!(sa[(2, 0)], 1.0);
    }

    #[test]
    fn identity_fast_ops_are_the_identity() {
        let enc = identity_encoding(7, 3);
        let x: Vec<f64> = (0..7).map(|i| i as f64 - 3.0).collect();
        assert_eq!(enc.apply(&x), x);
        assert_eq!(enc.apply_t(&x), x);
        // encode_vec slices the one structured apply at block bounds
        let encoded = enc.encode_vec(&x);
        assert_eq!(encoded.concat(), x);
    }

    #[test]
    fn fast_encode_data_matches_dense_blocks() {
        let mut rng = crate::rng::Pcg64::new(3);
        let x = Mat::from_fn(24, 5, |_, _| rng.next_f64() - 0.5);
        let enc = Encoding::build(Scheme::Hadamard, 24, 4, 2.0, 7).unwrap();
        let fast = enc.encode_data(&x);
        for (f, b) in fast.iter().zip(&enc.blocks) {
            let dense = b.encode_mat(&x);
            crate::testutil::assert_allclose(f.as_slice(), dense.as_slice(), 1e-10, "encode");
        }
    }

    #[test]
    fn encode_mat_dense_sparse_agree() {
        let mut rng = crate::rng::Pcg64::new(5);
        let x = Mat::from_fn(6, 4, |_, _| rng.next_f64() - 0.5);
        let tri = vec![(0, 1, 2.0), (1, 3, -1.0), (1, 5, 0.5)];
        let sp = Csr::from_triplets(2, 6, &tri);
        let de = sp.to_dense();
        let a = SMatrix::Sparse(sp).encode_mat(&x);
        let b = SMatrix::Dense(de).encode_mat(&x);
        crate::testutil::assert_allclose(a.as_slice(), b.as_slice(), 1e-12, "encode");
    }
}
