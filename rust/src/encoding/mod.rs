//! Encoding operators (paper §4: Code Design).
//!
//! An encoding is a tall matrix `S ∈ R^{N×n}`, `N = βn`, partitioned into
//! `m` row-blocks `S_i`, one per worker. Under data parallelism worker `i`
//! stores `(S_i X, S_i y)`; under model parallelism it stores the column
//! block `X S_iᵀ`. All constructions here produce (exactly or
//! approximately) *tight frames*: `SᵀS = βI`, which preserves the original
//! optimum when all workers respond (paper §4.1), while the block-RIP
//! behaviour of submatrices `S_A` governs robustness when only `k` of `m`
//! respond.
//!
//! ## Operator-first design
//!
//! The paper's schemes are *operators*, not matrices (§4.2 "efficient
//! mechanisms for encoding large-scale data"), and the API mirrors that:
//! a [`SchemeSpec`] is a pure descriptor (scheme, `n`, `m`, β, seed) that
//! [`SchemeSpec::lower`]s to a lazy [`EncodingOp`]. The operator exposes
//! `apply` (`S·x`), `apply_t` (`Sᵀ·x`), and [`EncodingOp::row_block`]
//! (`S_i` on demand); **no dense row block of `S` is stored anywhere**:
//!
//! - Hadamard applies through FWHT in `O(N log N)` and the sparse
//!   Steiner / Haar / identity generators through one CSR product in
//!   `O(nnz)` — these structured schemes never materialize a dense block
//!   on any encode path (asserted by the [`probe`] counters in
//!   `rust/tests/lazy_encoding.rs`).
//! - The unstructured ensembles (Gaussian, Paley) regenerate each dense
//!   block *per use* from the seed — Gaussian by jumping the PCG stream
//!   to the block's first entry ([`crate::rng::Pcg64::advance`]), Paley
//!   by rebuilding its (size-guarded) frame — and the block is dropped
//!   when the use ends. Encoding memory therefore scales with one block
//!   (Gaussian) or one transient frame (Paley), never with a *stored*
//!   `N×n` matrix, and every regeneration is bit-identical to the old
//!   eager one-pass construction.
//!
//! Constructions:
//! - [`gaussian`]    — i.i.d. N(0, 1/n) dense ensemble (eq. 8–9 scaling).
//! - [`hadamard`]    — column-subsampled Sylvester–Hadamard (exact tight
//!   frame; FWHT fast path, §4.2.2).
//! - [`paley`]       — Paley conference-matrix ETF (β = 2).
//! - [`steiner`]     — sparse Steiner ETF from (2,2,v)-Steiner systems.
//! - [`haar`]        — column-subsampled Haar wavelet matrix (sparse).
//! - uncoded / replication — identity partitioning, with or without
//!   block duplication ([`replication`]).

pub mod gaussian;
pub mod haar;
pub mod hadamard;
pub mod paley;
pub mod replication;
pub mod spectrum;
pub mod steiner;
pub mod stream;

pub use hadamard::FwhtOp;
pub use replication::ReplicationMap;
pub use spectrum::{SpectrumStats, SubsetSpectrum};

use crate::config::Scheme;
use crate::linalg::{Csr, Mat, PrecisionMat};
use anyhow::Result;

/// Thread-local accounting of dense generator material — the
/// block-generation probe behind the "structured schemes never allocate
/// a dense S block" acceptance test.
///
/// Every site that materializes dense rows of a generator `S`
/// (per-block Gaussian regeneration, the Paley frame build, an explicit
/// dense view of Hadamard rows for spectrum analysis) records the bytes
/// here. The counter is thread-local so concurrently running tests
/// cannot race each other; reset it, drive an encode path, and read it
/// back on the same thread.
pub mod probe {
    use std::cell::Cell;

    thread_local! {
        static DENSE_BYTES: Cell<u64> = const { Cell::new(0) };
    }

    /// Zero this thread's dense-generation counter.
    pub fn reset() {
        DENSE_BYTES.with(|c| c.set(0));
    }

    /// Dense generator bytes materialized on this thread since the last
    /// [`reset`].
    pub fn dense_bytes() -> u64 {
        DENSE_BYTES.with(|c| c.get())
    }

    /// Record a freshly generated `rows × cols` dense block of `S`.
    pub(crate) fn record_dense(rows: usize, cols: usize) {
        DENSE_BYTES.with(|c| {
            c.set(c.get() + (rows as u64) * (cols as u64) * std::mem::size_of::<f64>() as u64)
        });
    }
}

/// Structured application of an encoding operator: `S·x` / `Sᵀ·x`
/// without materializing the dense generator where structure allows.
///
/// This is the paper's §4.2 "efficient mechanisms for encoding
/// large-scale data" made into an interface: the Hadamard scheme applies
/// through FWHT in `O(N log N)`, the sparse Steiner / Haar / identity
/// schemes through one CSR product in `O(nnz)`, and only the
/// unstructured ensembles (Gaussian, Paley) fall back to per-use
/// regenerated dense blocks.
pub trait Encoder {
    /// `S·x` — encode a data-dimension vector to `N = βn` encoded rows.
    fn apply(&self, x: &[f64]) -> Vec<f64>;

    /// `Sᵀ·x` — project an encoded-row vector back to data dimension
    /// (the model-parallel reconstruction `w = Sᵀv`).
    fn apply_t(&self, x: &[f64]) -> Vec<f64>;
}

/// A worker's row-block `S_i`, produced on demand by
/// [`EncodingOp::row_block`] — dense or sparse depending on the
/// construction.
#[derive(Clone, Debug)]
pub enum SMatrix {
    Dense(Mat),
    Sparse(Csr),
}

impl SMatrix {
    pub fn rows(&self) -> usize {
        match self {
            SMatrix::Dense(m) => m.rows(),
            SMatrix::Sparse(s) => s.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            SMatrix::Dense(m) => m.cols(),
            SMatrix::Sparse(s) => s.cols(),
        }
    }

    /// y = S_i·x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        match self {
            SMatrix::Dense(m) => m.matvec(x),
            SMatrix::Sparse(s) => s.matvec(x),
        }
    }

    /// y = S_iᵀ·x.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        match self {
            SMatrix::Dense(m) => m.matvec_t(x),
            SMatrix::Sparse(s) => s.matvec_t(x),
        }
    }

    /// Dense copy (tests, spectrum analysis, encoding small shards).
    pub fn to_dense(&self) -> Mat {
        match self {
            SMatrix::Dense(m) => m.clone(),
            SMatrix::Sparse(s) => s.to_dense(),
        }
    }

    /// S_i·X for a dense data matrix X (row-block of the encoded data).
    pub fn encode_mat(&self, x: &Mat) -> Mat {
        assert_eq!(self.cols(), x.rows(), "encode dim mismatch");
        match self {
            SMatrix::Dense(s) => s.matmul(x),
            SMatrix::Sparse(s) => {
                let mut out = Mat::zeros(s.rows(), x.cols());
                for i in 0..s.rows() {
                    for (j, v) in s.row_iter(i) {
                        crate::linalg::axpy(v, x.row(j), out.row_mut(i));
                    }
                }
                out
            }
        }
    }

    /// Fraction of non-zero entries (1.0 for dense).
    pub fn density(&self) -> f64 {
        match self {
            SMatrix::Dense(_) => 1.0,
            SMatrix::Sparse(s) => s.nnz() as f64 / (s.rows() * s.cols()) as f64,
        }
    }
}

impl Encoder for SMatrix {
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        self.matvec(x)
    }

    fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_t(x)
    }
}

/// A pure scheme descriptor: everything needed to *name* an encoding
/// without building anything. [`SchemeSpec::lower`] turns it into the
/// lazy [`EncodingOp`]; until then it is a handful of integers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchemeSpec {
    pub scheme: Scheme,
    /// Data dimension n (columns of S): data rows under data
    /// parallelism, model coordinates under model parallelism.
    pub n: usize,
    /// Worker count m (row-block partitions of S).
    pub m: usize,
    /// Requested redundancy β ≥ 1; constructions round to feasible sizes
    /// so the achieved [`EncodingOp::beta`] can differ slightly.
    pub beta: f64,
    /// Construction seed (column sample, row permutation, signs, or the
    /// Gaussian entry stream).
    pub seed: u64,
}

impl SchemeSpec {
    /// Describe an encoding. No validation or construction happens here;
    /// [`lower`](SchemeSpec::lower) validates and resolves sizes.
    pub fn new(scheme: Scheme, n: usize, m: usize, beta: f64, seed: u64) -> SchemeSpec {
        SchemeSpec { scheme, n, m, beta, seed }
    }

    /// Lower the descriptor to a lazy [`EncodingOp`]: validate the
    /// parameters, resolve the achieved β and row-block boundaries, and
    /// build the scheme's *generator* — an `FwhtOp` for Hadamard, one
    /// CSR for the sparse constructions, and only a seed for the dense
    /// ensembles (their blocks are regenerated per use). Replication is
    /// *not* an encoding — it is a partitioning strategy (see
    /// [`ReplicationMap`]); requesting it lowers to the identity
    /// operator and the duplication happens at the cluster layer.
    pub fn lower(&self) -> Result<EncodingOp> {
        anyhow::ensure!(self.n > 0 && self.m > 0, "n and m must be positive");
        anyhow::ensure!(self.beta >= 1.0, "β must be ≥ 1");
        let op = match self.scheme {
            Scheme::Uncoded | Scheme::Replication => EncodingOp::identity(self.n, self.m),
            Scheme::Gaussian => gaussian::lower(self.n, self.m, self.beta, self.seed),
            Scheme::Hadamard => hadamard::lower(self.n, self.m, self.beta, self.seed),
            Scheme::Paley => paley::lower(self.n, self.m)?,
            Scheme::Steiner => steiner::lower(self.n, self.m)?,
            Scheme::Haar => haar::lower(self.n, self.m, self.beta, self.seed),
        };
        debug_assert_eq!(op.workers(), self.m);
        Ok(op)
    }
}

/// The structured fast path an [`EncodingOp`] answers through —
/// compiler-checked dispatch for the callers that branch on it (the
/// CLI's memory notes, the spectrum analyzer's dense-frame cache,
/// tests). Use [`FastPath::name`] for display.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FastPath {
    /// `O(N log N)` FWHT apply (Hadamard).
    Fwht,
    /// One CSR sweep in `O(nnz)` (Steiner / Haar / identity).
    Csr,
    /// Per-use regenerated dense blocks (Gaussian, Paley).
    Dense,
}

impl FastPath {
    /// Display label: `"fwht"` / `"csr"` / `"dense"`.
    pub fn name(self) -> &'static str {
        match self {
            FastPath::Fwht => "fwht",
            FastPath::Csr => "csr",
            FastPath::Dense => "dense",
        }
    }
}

/// How an [`EncodingOp`] produces the entries of `S`. Private on
/// purpose: consumers see `apply`/`apply_t`/`row_block`, not the
/// representation.
#[derive(Clone, Debug)]
pub(crate) enum Generator {
    /// FWHT-able subsampled Hadamard (O(N log N) apply; dense rows only
    /// ever exist as explicit on-demand views for spectrum analysis).
    Fwht(FwhtOp),
    /// One CSR for the whole generator (sparse constructions: Steiner,
    /// subsampled Haar, identity/replication partitioning). Row blocks
    /// are O(nnz) slices, never dense.
    Sparse(Csr),
    /// i.i.d. Gaussian ensemble: blocks are regenerated per use from the
    /// seed (PCG stream jump to the block's first entry), bit-identical
    /// to a one-pass eager draw.
    Gaussian { seed: u64 },
    /// Paley ETF: the frame is rebuilt (conference matrix +
    /// eigendecomposition, size-guarded at lower time) per use and
    /// dropped after. Inherently dense-transient — the construction has
    /// no sub-quadratic representation.
    Paley,
}

/// A lazy encoding operator: scheme metadata, row-block boundaries, and
/// a private generator — never a stored dense `S`.
///
/// Dense blocks of unstructured schemes are produced on demand by
/// [`row_block`](EncodingOp::row_block) /
/// [`for_each_row_block`](EncodingOp::for_each_row_block) and dropped
/// after use; structured schemes answer every encode path through FWHT /
/// CSR without materializing anything dense.
#[derive(Clone, Debug)]
pub struct EncodingOp {
    pub scheme: Scheme,
    /// Achieved redundancy / frame constant (total rows / n for the
    /// subsampled constructions; exactly 2 for Paley). Constructions
    /// round to feasible sizes so this can differ from the request.
    pub beta: f64,
    /// Data dimension n (columns of S).
    pub n: usize,
    /// m+1 row offsets: block i spans rows `bounds[i]..bounds[i+1]`.
    pub(crate) bounds: Vec<usize>,
    pub(crate) gen: Generator,
}

impl EncodingOp {
    /// [`SchemeSpec::new`] + [`SchemeSpec::lower`] in one call — the
    /// idiom for call sites that already hold the five knobs.
    pub fn build(scheme: Scheme, n: usize, m: usize, beta: f64, seed: u64) -> Result<EncodingOp> {
        SchemeSpec::new(scheme, n, m, beta, seed).lower()
    }

    /// Identity operator: S = I split into m near-equal contiguous row
    /// blocks (the uncoded baseline and the replication substrate).
    pub fn identity(n: usize, m: usize) -> EncodingOp {
        let triplets: Vec<(usize, usize, f64)> = (0..n).map(|r| (r, r, 1.0)).collect();
        let full = Csr::from_triplets(n, n, &triplets);
        EncodingOp {
            scheme: Scheme::Uncoded,
            beta: 1.0,
            n,
            bounds: partition_bounds(n, m),
            gen: Generator::Sparse(full),
        }
    }

    /// Number of workers m.
    pub fn workers(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Total encoded rows N = Σᵢ rows(S_i).
    pub fn total_rows(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Rows of worker i's block S_i.
    pub fn block_rows(&self, i: usize) -> usize {
        self.bounds[i + 1] - self.bounds[i]
    }

    /// The m+1 row offsets partitioning `0..total_rows()` into blocks.
    pub fn block_bounds(&self) -> &[usize] {
        &self.bounds
    }

    /// The structured fast path this operator answers through.
    pub fn fast_path(&self) -> FastPath {
        match &self.gen {
            Generator::Fwht(_) => FastPath::Fwht,
            Generator::Sparse(_) => FastPath::Csr,
            Generator::Gaussian { .. } | Generator::Paley => FastPath::Dense,
        }
    }

    /// Worker i's row block `S_i`, produced on demand: an O(nnz) CSR
    /// slice for sparse generators, a regenerated dense block for the
    /// dense ensembles (bit-identical across calls), and an explicit
    /// dense view for Hadamard (spectrum analysis / streaming referees —
    /// the encode paths never call this for FWHT).
    pub fn row_block(&self, i: usize) -> SMatrix {
        let (r0, r1) = (self.bounds[i], self.bounds[i + 1]);
        match &self.gen {
            Generator::Sparse(s) => SMatrix::Sparse(s.row_block(r0, r1)),
            Generator::Fwht(op) => SMatrix::Dense(op.dense_rows(r0, r1)),
            Generator::Gaussian { seed } => {
                SMatrix::Dense(gaussian::dense_rows(self.n, *seed, r0, r1))
            }
            Generator::Paley => SMatrix::Dense(self.dense_full().row_block(r0, r1)),
        }
    }

    /// Visit every row block in worker order, generating each on demand
    /// and dropping it when the callback returns. Paley regenerates its
    /// frame once per visit (not once per block); everything else goes
    /// through [`row_block`](EncodingOp::row_block).
    pub fn for_each_row_block(
        &self,
        f: &mut dyn FnMut(usize, &SMatrix) -> Result<()>,
    ) -> Result<()> {
        if let Generator::Paley = &self.gen {
            let full = self.dense_full();
            for i in 0..self.workers() {
                let b = SMatrix::Dense(full.row_block(self.bounds[i], self.bounds[i + 1]));
                f(i, &b)?;
            }
            return Ok(());
        }
        for i in 0..self.workers() {
            let b = self.row_block(i);
            f(i, &b)?;
        }
        Ok(())
    }

    /// The full dense `S` of an unstructured generator — the transient
    /// the dense ensembles regenerate per use. Panics for structured
    /// generators, which must never take a dense path.
    fn dense_full(&self) -> Mat {
        match &self.gen {
            Generator::Gaussian { seed } => {
                gaussian::dense_rows(self.n, *seed, 0, self.total_rows())
            }
            Generator::Paley => paley::paley_etf(self.n)
                .expect("Paley feasibility was validated when the spec was lowered"),
            _ => unreachable!("structured generators have no dense_full path"),
        }
    }

    /// Stack `S_A = [S_i]_{i∈A}` densely (spectrum analysis / tests);
    /// the materialization is this call's explicit product. Paley
    /// builds its (monolithic) frame once and slices it; every other
    /// generator — including Gaussian, whose stream jump makes a block
    /// regeneration exactly proportional to the block — produces only
    /// the requested blocks.
    pub fn stack(&self, subset: &[usize]) -> Mat {
        let blocks: Vec<Mat> = match &self.gen {
            Generator::Paley => {
                let full = self.dense_full();
                subset
                    .iter()
                    .map(|&i| full.row_block(self.bounds[i], self.bounds[i + 1]))
                    .collect()
            }
            _ => subset.iter().map(|&i| self.row_block(i).to_dense()).collect(),
        };
        let refs: Vec<&Mat> = blocks.iter().collect();
        Mat::vstack(&refs)
    }

    /// Normalized Gram `G_A = (1/(ηβ))·S_Aᵀ S_A`, whose eigenvalue spread
    /// around 1 is the ε of the block-RIP condition (Definition 1).
    pub fn gram_normalized(&self, subset: &[usize]) -> Mat {
        self.gram_normalized_of(&self.stack(subset), subset.len())
    }

    /// [`gram_normalized`](EncodingOp::gram_normalized) from an
    /// already-stacked `S_A` with `|A| = k` — the one place the
    /// `1/(ηβ)` Definition-1 normalization lives, shared with the
    /// spectrum analyzer's cached-frame path so the two cannot drift.
    pub fn gram_normalized_of(&self, sa: &Mat, k: usize) -> Mat {
        let eta = k as f64 / self.workers() as f64;
        let mut g = sa.gram();
        g.scale_inplace(1.0 / (eta * self.beta));
        g
    }

    /// Apply the full encoding to a data matrix: returns `S_i·X` per
    /// worker.
    ///
    /// Structure-aware: the FWHT path encodes column-by-column in
    /// `O(p·N log N)`, the CSR path sweeps the generator's rows in
    /// `O(nnz·p)` — neither materializes a dense block. The dense
    /// ensembles regenerate one block at a time, multiply, and drop it.
    pub fn encode_data(&self, x: &Mat) -> Vec<Mat> {
        assert_eq!(self.n, x.rows(), "encode dim mismatch");
        let p = x.cols();
        match &self.gen {
            Generator::Fwht(op) => {
                let mut outs: Vec<Mat> =
                    (0..self.workers()).map(|i| Mat::zeros(self.block_rows(i), p)).collect();
                let mut col = vec![0.0; x.rows()];
                for j in 0..p {
                    for (i, c) in col.iter_mut().enumerate() {
                        *c = x[(i, j)];
                    }
                    let enc = op.apply(&col);
                    let mut r = 0;
                    for out in &mut outs {
                        for local in 0..out.rows() {
                            out[(local, j)] = enc[r];
                            r += 1;
                        }
                    }
                }
                outs
            }
            Generator::Sparse(s) => {
                let mut outs: Vec<Mat> =
                    (0..self.workers()).map(|i| Mat::zeros(self.block_rows(i), p)).collect();
                for (i, out) in outs.iter_mut().enumerate() {
                    let r0 = self.bounds[i];
                    for local in 0..out.rows() {
                        let orow = out.row_mut(local);
                        for (j, v) in s.row_iter(r0 + local) {
                            crate::linalg::axpy(v, x.row(j), orow);
                        }
                    }
                }
                outs
            }
            Generator::Gaussian { .. } | Generator::Paley => {
                let mut outs = Vec::with_capacity(self.workers());
                self.for_each_row_block(&mut |_i, b| {
                    outs.push(b.encode_mat(x));
                    Ok(())
                })
                .expect("in-memory block visit cannot fail");
                outs
            }
        }
    }

    /// [`encode_data`](EncodingOp::encode_data) at a requested storage
    /// precision: the encode itself always runs in f64 (so the encoded
    /// values are independent of the storage mode), then each worker
    /// block is demoted once. Under [`Precision::F64`] this is exactly
    /// `encode_data`; under [`Precision::F32`] each stored element
    /// rounds to nearest f32 (see [`crate::linalg::precision`] for the
    /// tolerance contract).
    pub fn encode_data_prec(&self, x: &Mat, p: crate::linalg::Precision) -> Vec<PrecisionMat> {
        self.encode_data(x).into_iter().map(|b| PrecisionMat::demote(b, p)).collect()
    }

    /// Apply to a vector: returns `S_i·y` per worker (one structured
    /// full-S apply sliced at the block boundaries where possible).
    pub fn encode_vec(&self, y: &[f64]) -> Vec<Vec<f64>> {
        match &self.gen {
            Generator::Fwht(_) | Generator::Sparse(_) => {
                let full = self.apply(y);
                self.bounds.windows(2).map(|w| full[w[0]..w[1]].to_vec()).collect()
            }
            Generator::Gaussian { .. } | Generator::Paley => {
                let mut out = Vec::with_capacity(self.workers());
                self.for_each_row_block(&mut |_i, b| {
                    out.push(b.matvec(y));
                    Ok(())
                })
                .expect("in-memory block visit cannot fail");
                out
            }
        }
    }
}

impl Encoder for EncodingOp {
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n, "apply dim mismatch");
        match &self.gen {
            Generator::Fwht(op) => op.apply(x),
            Generator::Sparse(s) => s.matvec(x),
            Generator::Gaussian { .. } | Generator::Paley => {
                let mut out = Vec::with_capacity(self.total_rows());
                self.for_each_row_block(&mut |_i, b| {
                    out.extend(b.matvec(x));
                    Ok(())
                })
                .expect("in-memory block visit cannot fail");
                out
            }
        }
    }

    fn apply_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.total_rows(), "apply_t dim mismatch");
        match &self.gen {
            Generator::Fwht(op) => op.apply_t(x),
            Generator::Sparse(s) => s.matvec_t(x),
            Generator::Gaussian { .. } | Generator::Paley => {
                let mut out = vec![0.0; self.n];
                let bounds = &self.bounds;
                self.for_each_row_block(&mut |i, b| {
                    let part = b.matvec_t(&x[bounds[i]..bounds[i + 1]]);
                    crate::linalg::axpy(1.0, &part, &mut out);
                    Ok(())
                })
                .expect("in-memory block visit cannot fail");
                out
            }
        }
    }
}

/// Boundaries that split `total` items into `m` near-equal contiguous
/// chunks: returns m+1 offsets. Earlier chunks get the remainder.
pub fn partition_bounds(total: usize, m: usize) -> Vec<usize> {
    let base = total / m;
    let rem = total % m;
    let mut bounds = Vec::with_capacity(m + 1);
    let mut acc = 0;
    bounds.push(0);
    for i in 0..m {
        acc += base + usize::from(i < rem);
        bounds.push(acc);
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_bounds_cover_everything() {
        assert_eq!(partition_bounds(10, 3), vec![0, 4, 7, 10]);
        assert_eq!(partition_bounds(9, 3), vec![0, 3, 6, 9]);
        assert_eq!(partition_bounds(2, 4), vec![0, 1, 2, 2, 2]);
    }

    #[test]
    fn identity_op_blocks_are_identity_rows() {
        let enc = EncodingOp::identity(7, 3);
        assert_eq!(enc.total_rows(), 7);
        assert_eq!(enc.workers(), 3);
        let x: Vec<f64> = (0..7).map(|i| i as f64).collect();
        let encoded = enc.encode_vec(&x);
        // Blocks are contiguous slices of x.
        assert_eq!(encoded[0], vec![0.0, 1.0, 2.0]);
        assert_eq!(encoded[1], vec![3.0, 4.0]);
        assert_eq!(encoded[2], vec![5.0, 6.0]);
    }

    #[test]
    fn lower_rejects_bad_args() {
        assert!(EncodingOp::build(Scheme::Gaussian, 0, 4, 2.0, 1).is_err());
        assert!(EncodingOp::build(Scheme::Gaussian, 16, 4, 0.5, 1).is_err());
        assert!(SchemeSpec::new(Scheme::Hadamard, 16, 0, 2.0, 1).lower().is_err());
    }

    #[test]
    fn spec_is_a_pure_descriptor() {
        // Constructing and copying a spec generates nothing.
        probe::reset();
        let spec = SchemeSpec::new(Scheme::Gaussian, 64, 4, 2.0, 9);
        let spec2 = spec;
        assert_eq!(spec, spec2);
        let op = spec.lower().unwrap();
        assert_eq!(probe::dense_bytes(), 0, "lowering stores no dense blocks");
        assert_eq!(op.workers(), 4);
        assert_eq!(op.total_rows(), 128);
    }

    #[test]
    fn stack_concatenates_subset_in_order() {
        let enc = EncodingOp::identity(6, 3);
        let sa = enc.stack(&[2, 0]);
        assert_eq!(sa.rows(), 4);
        // first rows come from block 2 (rows 4..6 of I)
        assert_eq!(sa[(0, 4)], 1.0);
        assert_eq!(sa[(2, 0)], 1.0);
    }

    #[test]
    fn identity_fast_ops_are_the_identity() {
        let enc = EncodingOp::identity(7, 3);
        let x: Vec<f64> = (0..7).map(|i| i as f64 - 3.0).collect();
        assert_eq!(enc.apply(&x), x);
        assert_eq!(enc.apply_t(&x), x);
        // encode_vec slices the one structured apply at block bounds
        let encoded = enc.encode_vec(&x);
        assert_eq!(encoded.concat(), x);
    }

    #[test]
    fn fast_encode_data_matches_dense_blocks() {
        let mut rng = crate::rng::Pcg64::new(3);
        let x = Mat::from_fn(24, 5, |_, _| rng.next_f64() - 0.5);
        let enc = EncodingOp::build(Scheme::Hadamard, 24, 4, 2.0, 7).unwrap();
        let fast = enc.encode_data(&x);
        for (i, f) in fast.iter().enumerate() {
            let dense = enc.row_block(i).encode_mat(&x);
            crate::testutil::assert_allclose(f.as_slice(), dense.as_slice(), 1e-10, "encode");
        }
    }

    #[test]
    fn encode_mat_dense_sparse_agree() {
        let mut rng = crate::rng::Pcg64::new(5);
        let x = Mat::from_fn(6, 4, |_, _| rng.next_f64() - 0.5);
        let tri = vec![(0, 1, 2.0), (1, 3, -1.0), (1, 5, 0.5)];
        let sp = Csr::from_triplets(2, 6, &tri);
        let de = sp.to_dense();
        let a = SMatrix::Sparse(sp).encode_mat(&x);
        let b = SMatrix::Dense(de).encode_mat(&x);
        crate::testutil::assert_allclose(a.as_slice(), b.as_slice(), 1e-12, "encode");
    }
}
