//! Steiner equiangular tight frame (Fickus–Mixon–Tremain 2012), from
//! (2,2,v)-Steiner systems — the sparse encoding used in the paper's
//! logistic-regression and LASSO experiments (β = 2v/(v−1) ≈ 2).
//!
//! For `v` a power of two: let `H` be the v×v Sylvester–Hadamard matrix
//! and `V ∈ {0,1}^{v × v(v−1)/2}` the incidence matrix of all 2-element
//! subsets of `[v]` (each column has exactly two ones; each row exactly
//! v−1 ones). Replace the j-th one in each *row* of `V` with the
//! (j+1)-th column of `H` (skipping the all-ones first column) and scale
//! by `1/√(v−1)`. The result is a `v² × v(v−1)/2` matrix with unit-norm
//! rows, `SᵀS = β·I`, and constant coherence — an ETF.
//!
//! Sparsity: each row has v−1 non-zeros out of v(v−1)/2 columns, so the
//! per-worker storage overhead matches the paper's `|B_I_k| ≤ 2n/m` bound.

use super::{partition_bounds, EncodingOp, Generator};
use crate::config::Scheme;
use crate::linalg::fwht::hadamard_entry;
use crate::linalg::Csr;
use anyhow::{ensure, Result};

/// Smallest power-of-two v with v(v−1)/2 ≥ n.
fn steiner_v_for(n: usize) -> usize {
    let mut v = 4usize;
    while v * (v - 1) / 2 < n {
        v *= 2;
    }
    v
}

/// Lower the Steiner descriptor for data dimension n across m workers.
///
/// Chooses the smallest feasible v, constructs the v² × v(v−1)/2 frame
/// as ONE sparse CSR generator (≈ 2·nnz values — there is nothing dense
/// to elide), keeps the first n columns (paper's column-subsampling),
/// and partitions the v row-*blocks* (v rows each) across workers —
/// assigning half-blocks when m does not divide v, following the paper's
/// footnote 3 observation that splitting blocks across machines helps.
pub(crate) fn lower(n: usize, m: usize) -> Result<EncodingOp> {
    let v = steiner_v_for(n);
    ensure!(v >= 2, "steiner needs v ≥ 2");
    let total_rows = v * v;
    // Enumerate 2-subsets {a,b} of [v] in lexicographic order == columns.
    // col_of[a][b] for a<b.
    let ncols_full = v * (v - 1) / 2;
    let keep_cols = n.min(ncols_full);
    let mut pair_of_col = Vec::with_capacity(ncols_full);
    for a in 0..v {
        for b in a + 1..v {
            pair_of_col.push((a, b));
        }
    }
    // For each row-block r (row r of V), the ones sit at columns whose
    // pair contains r; the j-th such one (in column order) is replaced by
    // Hadamard column j+1.
    // Build triplets for the kept columns only.
    let scale = 1.0 / ((v - 1) as f64).sqrt();
    let mut block_col_rank = vec![0usize; v]; // per-block counter of ones seen
    let mut triplets: Vec<(usize, usize, f64)> = Vec::new();
    for (col, &(a, b)) in pair_of_col.iter().enumerate() {
        for &blk in &[a, b] {
            let rank = block_col_rank[blk];
            block_col_rank[blk] += 1;
            if col >= keep_cols {
                continue; // counted for rank bookkeeping, but column dropped
            }
            let hcol = rank + 1; // skip all-ones column 0
            debug_assert!(hcol < v);
            for r in 0..v {
                // Hadamard entry H[r, hcol]
                let val = hadamard_entry(r, hcol) * scale;
                triplets.push((blk * v + r, col, val));
            }
        }
    }
    // Spread rows across machines with a random permutation (the
    // paper's footnote 3: "performance improves when the blocks are
    // broken into multiple machines"). Column {a,b} has support only in
    // Steiner blocks a and b; with machine-aligned blocks, two straggling
    // machines can annihilate that column entirely (λ_min = 0 against a
    // fixed adversary). Spreading each block's v rows over all machines
    // removes that failure mode at the cost of a larger per-worker
    // column support.
    let mut rng = crate::rng::Pcg64::with_stream(0x57e1 ^ (v as u64), 0x57e1);
    let mut perm: Vec<usize> = (0..total_rows).collect();
    crate::rng::shuffle(&mut rng, &mut perm);
    let mut inv = vec![0usize; total_rows];
    for (new, &old) in perm.iter().enumerate() {
        inv[old] = new;
    }
    // Random column signs (FJLT trick, same rationale as hadamard.rs):
    // raw Steiner rows sum to a spike on the Hadamard DC rows, making
    // constant data columns coherent with a few encoded rows. Signs
    // preserve unit rows, SᵀS = β·I, and equiangularity exactly.
    let signs: Vec<f64> =
        (0..keep_cols).map(|_| if rng.gen_bool(0.5) { 1.0 } else { -1.0 }).collect();
    let permuted: Vec<(usize, usize, f64)> =
        triplets.into_iter().map(|(r, c, val)| (inv[r], c, val * signs[c])).collect();
    let s_full = Csr::from_triplets(total_rows, keep_cols, &permuted);
    // β is the FRAME CONSTANT SᵀS = β·I — for Steiner that is
    // 2v/(v−1) = v²/ncols_full, unchanged by column subsampling
    // (sub-blocks of a scaled identity stay scaled identities). The
    // storage redundancy rows/keep_cols can be larger.
    let beta = total_rows as f64 / ncols_full as f64;
    Ok(EncodingOp {
        scheme: Scheme::Steiner,
        beta,
        n: keep_cols,
        bounds: partition_bounds(total_rows, m),
        gen: Generator::Sparse(s_full),
    })
}

/// The natural (v, n) pairs: v power of 2, n = v(v−1)/2 — sizes at which
/// the Steiner frame needs no column subsampling.
pub fn natural_sizes(max_v: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut v = 4;
    while v <= max_v {
        out.push((v, v * (v - 1) / 2));
        v *= 2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;

    #[test]
    fn v_selection() {
        assert_eq!(steiner_v_for(6), 4); // 4·3/2 = 6
        assert_eq!(steiner_v_for(7), 8); // 8·7/2 = 28
        assert_eq!(steiner_v_for(28), 8);
        assert_eq!(steiner_v_for(29), 16);
    }

    #[test]
    fn natural_size_is_tight_frame() {
        // v=4: S is 16×6 with β = 16/6 = 2v/(v−1) = 8/3.
        let enc = lower(6, 4).unwrap();
        assert_eq!(enc.total_rows(), 16);
        assert_eq!(enc.n, 6);
        let s = enc.stack(&[0, 1, 2, 3]);
        let g = s.gram();
        let beta = 16.0 / 6.0;
        for i in 0..6 {
            for j in 0..6 {
                let expect = if i == j { beta } else { 0.0 };
                assert!((g[(i, j)] - expect).abs() < 1e-9, "({i},{j})={}", g[(i, j)]);
            }
        }
    }

    #[test]
    fn rows_unit_norm() {
        let enc = lower(6, 2).unwrap();
        let s = enc.stack(&[0, 1]);
        for i in 0..s.rows() {
            let n2 = dot(s.row(i), s.row(i));
            assert!((n2 - 1.0).abs() < 1e-12, "row {i}: {n2}");
        }
    }

    #[test]
    fn equiangular_at_natural_size() {
        let enc = lower(28, 4).unwrap(); // v=8, no subsampling
        let s = enc.stack(&[0, 1, 2, 3]);
        let beta = s.rows() as f64 / 28.0;
        let welch = ((beta - 1.0) / (beta * 28.0 - 1.0)).sqrt();
        let mut min_ip = f64::INFINITY;
        let mut max_ip: f64 = 0.0;
        for i in 0..s.rows() {
            for j in i + 1..s.rows() {
                let ip = dot(s.row(i), s.row(j)).abs();
                min_ip = min_ip.min(ip);
                max_ip = max_ip.max(ip);
            }
        }
        // Steiner ETFs have inner products in {0, ±ω}? No — true ETFs have
        // |<a_i,a_j>| = ω for ALL pairs. Verify constancy:
        assert!((max_ip - welch).abs() < 1e-9, "max={max_ip} welch={welch}");
        assert!((min_ip - welch).abs() < 1e-9, "min={min_ip} welch={welch}");
    }

    #[test]
    fn sparsity_bound() {
        // per-row nnz = v−1; density = (v−1)/(v(v−1)/2) = 2/v.
        let enc = lower(28, 4).unwrap(); // v=8
        for i in 0..enc.workers() {
            assert!(enc.row_block(i).density() < 2.0 / 8.0 + 1e-9);
        }
    }

    #[test]
    fn subsampled_still_near_tight() {
        let enc = lower(20, 4).unwrap(); // v=8, keep 20 of 28 columns
        assert_eq!(enc.n, 20);
        let s = enc.stack(&[0, 1, 2, 3]);
        let g = s.gram();
        // Column-subsampling an exact tight frame keeps G = β_full·I on
        // the kept coordinates exactly.
        let beta_full = 64.0 / 28.0;
        for i in 0..20 {
            for j in 0..20 {
                let expect = if i == j { beta_full } else { 0.0 };
                assert!((g[(i, j)] - expect).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn natural_sizes_list() {
        assert_eq!(natural_sizes(16), vec![(4, 6), (8, 28), (16, 120)]);
    }
}
