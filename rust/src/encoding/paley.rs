//! Paley equiangular tight frame (β = 2).
//!
//! Construction (Paley 1933; Goethals–Seidel 1967): take a prime
//! `q ≡ 1 (mod 4)` and build the symmetric conference matrix `C` of order
//! `N = q + 1` from the quadratic-residue (Legendre) symbol. `C` satisfies
//! `C = Cᵀ`, `C·Cᵀ = q·I`, zero diagonal, ±1 off-diagonal. Then
//!
//!   P = (I + C/√q) / 2
//!
//! is an orthogonal projection of rank `N/2` with constant off-diagonal
//! magnitude `1/(2√q)`. Factoring `P = V₁V₁ᵀ` through its unit-eigenvalue
//! eigenvectors and scaling by √2 yields `S = √2·V₁ᵀ…` — concretely the
//! `N` columns of `V₁ᵀ` are `N` unit vectors in `R^{N/2}` forming an ETF
//! with redundancy 2 that meets the Welch bound `ω = 1/√(N−1)` with
//! equality (Proposition 7).
//!
//! To hit an arbitrary data dimension `n`, we build the smallest feasible
//! Paley frame with `N/2 ≥ n` and keep `n` coordinates — the paper's
//! "bank of encoding matrices, subsample columns" trick (§5.2).

use super::{partition_bounds, EncodingOp, Generator};
use crate::config::Scheme;
use crate::linalg::{symmetric_eigen, Mat};
use anyhow::{bail, Result};

/// Largest conference-matrix order the dense eigendecomposition-based
/// construction will attempt (the frame build materializes and
/// decomposes an nn×nn matrix).
const MAX_PALEY_ORDER: usize = 1 << 14;

/// Legendre symbol χ(a) over GF(q): 1 if a is a non-zero QR, −1 if
/// non-residue, 0 if a ≡ 0.
fn legendre(a: i64, q: i64) -> i64 {
    let a = a.rem_euclid(q);
    if a == 0 {
        return 0;
    }
    // Euler's criterion: a^((q-1)/2) mod q ∈ {1, q-1}.
    let r = modpow(a, (q - 1) / 2, q);
    if r == 1 {
        1
    } else {
        -1
    }
}

/// `b^e mod m` by square-and-multiply. Intermediates are widened to
/// `i128`: `b, acc < m ≤ i64::MAX < 2^63`, so every product is below
/// `2^126` and cannot overflow — the previous `i64::checked_mul(..)
/// .unwrap()` panicked for any modulus above `√i64::MAX ≈ 3.04e9`
/// (`b·b` overflows i64), which a large Paley prime reaches.
fn modpow(b: i64, mut e: i64, m: i64) -> i64 {
    assert!(m > 0, "modpow modulus must be positive");
    debug_assert!(e >= 0, "modpow exponent must be non-negative");
    let m128 = m as i128;
    let mut acc: i128 = 1;
    let mut b128 = (b as i128).rem_euclid(m128);
    while e > 0 {
        if e & 1 == 1 {
            acc = (acc * b128).rem_euclid(m128);
        }
        b128 = (b128 * b128).rem_euclid(m128);
        e >>= 1;
    }
    acc as i64
}

fn is_prime(n: i64) -> bool {
    if n < 2 {
        return false;
    }
    if n % 2 == 0 {
        return n == 2;
    }
    let mut d = 3;
    // overflow-safe trial division (d·d would overflow i64 near its max)
    while d <= n / d {
        if n % d == 0 {
            return false;
        }
        d += 2;
    }
    true
}

/// Smallest prime q ≡ 1 (mod 4) with (q+1)/2 ≥ n.
fn paley_prime_for(n: usize) -> Result<i64> {
    let mut q = (2 * n).max(5) as i64 - 1;
    // search upward; density of primes ≡ 1 mod 4 makes this fast
    for _ in 0..100_000 {
        if q % 4 == 1 && is_prime(q) {
            return Ok(q);
        }
        q += 1;
    }
    bail!("no Paley prime found near n={n}")
}

/// Shared feasibility guard for the dense eigendecomposition-based
/// construction — called at lower time (loud, early) and again by
/// [`paley_etf`] so a hand-built call cannot bypass it.
fn check_order(nn: usize, n: usize) -> Result<()> {
    anyhow::ensure!(
        nn <= MAX_PALEY_ORDER,
        "Paley frame of order {nn} (n={n}) exceeds the dense eigendecomposition \
         budget; use a structured scheme (hadamard/haar) at this size"
    );
    Ok(())
}

/// Symmetric conference matrix of order q+1 (q prime, q ≡ 1 mod 4).
pub fn conference_matrix(q: i64) -> Mat {
    let n = (q + 1) as usize;
    let mut c = Mat::zeros(n, n);
    for j in 1..n {
        c[(0, j)] = 1.0;
        c[(j, 0)] = 1.0;
    }
    for i in 1..n {
        for j in 1..n {
            if i == j {
                continue;
            }
            c[(i, j)] = legendre(i as i64 - j as i64, q) as f64;
        }
    }
    c
}

/// The full (2n'×n') Paley ETF matrix for the smallest feasible frame,
/// restricted to the first `n` coordinates. Rows are unit-norm frame
/// vectors.
pub fn paley_etf(n: usize) -> Result<Mat> {
    let q = paley_prime_for(n)?;
    let nn = (q + 1) as usize; // number of frame vectors
    // Proper error path instead of an OOM abort: the construction
    // materializes the nn×nn conference matrix and eigendecomposes it.
    check_order(nn, n)?;
    let half = nn / 2; // frame dimension
    let c = conference_matrix(q);
    // P = (I + C/√q)/2 — projection of rank nn/2.
    let sq = (q as f64).sqrt();
    let mut p = Mat::zeros(nn, nn);
    for i in 0..nn {
        for j in 0..nn {
            p[(i, j)] = 0.5 * (if i == j { 1.0 } else { 0.0 } + c[(i, j)] / sq);
        }
    }
    let (eigs, v) = symmetric_eigen(&p);
    // Unit-eigenvalue eigenvectors are the last `half` columns (ascending).
    debug_assert!(eigs[nn - half] > 0.9, "projection eigenvalues not 0/1: {eigs:?}");
    // Frame vector for data coordinate direction: S has rows = frame
    // vectors in R^half. Column j of V₁ᵀ ↔ frame vector j: S[j, :] =
    // √2 · V[j, half..].
    let mut s = Mat::zeros(nn, half);
    for j in 0..nn {
        for (d, col) in (nn - half..nn).enumerate() {
            s[(j, d)] = std::f64::consts::SQRT_2 * v[(j, col)];
        }
    }
    // Keep the first n coordinates (column subsample) if the frame
    // dimension exceeds the requested n.
    let s = if half > n {
        let idx: Vec<usize> = (0..n).collect();
        s.select_cols(&idx)
    } else {
        s
    };
    super::probe::record_dense(s.rows(), s.cols());
    Ok(s)
}

/// Lower the Paley descriptor: validate feasibility (prime search +
/// dense-eigendecomposition size guard) and record the row-block
/// boundaries — the frame itself is regenerated per use by the
/// [`EncodingOp`]'s dense paths and dropped after (the construction has
/// no sub-quadratic representation, so its memory story is "transient",
/// not "structured").
///
/// `beta` is the FRAME CONSTANT (SᵀS = β·I), which stays exactly 2 even
/// after column restriction — a sub-block of 2·I is 2·I. The storage
/// redundancy (rows/n) can be slightly larger due to the prime search.
pub(crate) fn lower(n: usize, m: usize) -> Result<EncodingOp> {
    let q = paley_prime_for(n)?;
    let nn = (q + 1) as usize;
    check_order(nn, n)?;
    Ok(EncodingOp {
        scheme: Scheme::Paley,
        beta: 2.0,
        n,
        bounds: partition_bounds(nn, m),
        gen: Generator::Paley,
    })
}

/// Maximal inner product ω(F) between distinct unit rows — for ETF
/// verification against the Welch bound (Proposition 7).
pub fn max_coherence(s: &Mat) -> f64 {
    let mut w: f64 = 0.0;
    for i in 0..s.rows() {
        for j in i + 1..s.rows() {
            let ip = crate::linalg::dot(s.row(i), s.row(j)).abs();
            w = w.max(ip);
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legendre_symbol_small_cases() {
        // QRs mod 13: {1,3,4,9,10,12}
        assert_eq!(legendre(4, 13), 1);
        assert_eq!(legendre(2, 13), -1);
        assert_eq!(legendre(0, 13), 0);
        assert_eq!(legendre(-1, 13), 1); // 12 is a QR mod 13
    }

    #[test]
    fn modpow_survives_primes_beyond_the_i64_square_root() {
        // Regression: q = 3_037_000_537 is the smallest prime ≡ 1 mod 4
        // above √i64::MAX; the old i64 intermediate overflowed on b·b
        // for any such modulus and panicked. With i128 intermediates the
        // Legendre symbol is well-defined arbitrarily close to i64::MAX.
        let q: i64 = 3_037_000_537;
        // Fermat: a^(q−1) ≡ 1 for a ≢ 0
        assert_eq!(modpow(2, q - 1, q), 1);
        assert_eq!(modpow(q - 1, q - 1, q), 1);
        // Euler's criterion lands in {1, q−1}
        let e = modpow(2, (q - 1) / 2, q);
        assert_eq!(e, 1, "2 is a quadratic residue mod this q");
        // a perfect square is always a residue
        let a: i64 = 123_456_789;
        assert_eq!(legendre(a.wrapping_mul(a).rem_euclid(q), q), 1);
        assert_eq!(legendre(a, q) * legendre(a, q), 1, "χ(a)² = 1 for a ≠ 0");
        // multiplicativity χ(a)·χ(b) = χ(ab) exercises the full range
        let b: i64 = 2_999_999_999;
        let ab = ((a as i128 * b as i128).rem_euclid(q as i128)) as i64;
        assert_eq!(legendre(a, q) * legendre(b, q), legendre(ab, q));
    }

    #[test]
    fn primes() {
        assert!(is_prime(13));
        assert!(is_prime(2));
        assert!(!is_prime(1));
        assert!(!is_prime(15));
        assert_eq!(paley_prime_for(7).unwrap(), 13); // (13+1)/2 = 7
    }

    #[test]
    fn conference_matrix_property() {
        let q = 13;
        let c = conference_matrix(q);
        let cct = c.matmul(&c.transpose());
        for i in 0..14 {
            for j in 0..14 {
                let expect = if i == j { q as f64 } else { 0.0 };
                assert!((cct[(i, j)] - expect).abs() < 1e-9, "({i},{j})={}", cct[(i, j)]);
            }
        }
        // symmetric, zero diagonal
        for i in 0..14 {
            assert_eq!(c[(i, i)], 0.0);
            for j in 0..14 {
                assert_eq!(c[(i, j)], c[(j, i)]);
            }
        }
    }

    #[test]
    fn paley_is_tight_frame() {
        let s = paley_etf(7).unwrap(); // q=13, 14 vectors in R^7
        assert_eq!(s.rows(), 14);
        assert_eq!(s.cols(), 7);
        let g = s.gram();
        for i in 0..7 {
            for j in 0..7 {
                let expect = if i == j { 2.0 } else { 0.0 };
                assert!((g[(i, j)] - expect).abs() < 1e-8, "({i},{j})={}", g[(i, j)]);
            }
        }
    }

    #[test]
    fn paley_rows_unit_norm() {
        let s = paley_etf(7).unwrap();
        for i in 0..s.rows() {
            let n2 = crate::linalg::dot(s.row(i), s.row(i));
            assert!((n2 - 1.0).abs() < 1e-9, "row {i}: {n2}");
        }
    }

    #[test]
    fn paley_meets_welch_bound_with_equality() {
        // Proposition 7: ω(F) = √((β−1)/(βn−1)) iff ETF.
        let s = paley_etf(7).unwrap();
        let beta: f64 = 2.0;
        let n: f64 = 7.0;
        let welch = ((beta - 1.0) / (beta * n - 1.0)).sqrt();
        let w = max_coherence(&s);
        assert!((w - welch).abs() < 1e-9, "ω={w}, welch={welch}");
        // and EVERY pair meets it (equiangular)
        for i in 0..s.rows() {
            for j in i + 1..s.rows() {
                let ip = crate::linalg::dot(s.row(i), s.row(j)).abs();
                assert!((ip - welch).abs() < 1e-8, "pair ({i},{j}): {ip}");
            }
        }
    }

    #[test]
    fn lower_partitions_workers() {
        let enc = lower(7, 7).unwrap();
        assert_eq!(enc.workers(), 7);
        assert_eq!(enc.total_rows(), 14);
        assert!((enc.beta - 2.0).abs() < 1e-12);
        // the lowered bounds agree with the regenerated frame's shape
        let s = paley_etf(7).unwrap();
        assert_eq!(s.rows(), enc.total_rows());
        assert_eq!(s.cols(), enc.n);
    }

    #[test]
    fn column_restricted_frame_still_near_tight() {
        // n=6 forces q=13 frame restricted to 6 of 7 coordinates.
        let s = paley_etf(6).unwrap();
        assert_eq!(s.cols(), 6);
        let g = s.gram();
        // Diagonal ≈ 2, off-diagonal small.
        for i in 0..6 {
            assert!((g[(i, i)] - 2.0).abs() < 1e-8);
        }
    }
}
