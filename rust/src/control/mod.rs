//! Adaptive wait-for-k runtime control.
//!
//! The paper fixes the wait-for-k parameter ahead of time; this module
//! closes the loop at runtime. A [`Controller`] watches the per-round
//! arrival-time record ([`RoundStats`]) and chooses the `k` to request
//! for the *next* round, trading redundancy headroom against straggler
//! latency while the run is in flight.
//!
//! ## Controller contract
//!
//! 1. **Inputs are the recorded arrivals only.** A controller sees the
//!    [`RoundStats`] stream the engines recorded — never wall clocks,
//!    RNGs, thread timing, or ambient state — so a controller-enabled
//!    run replays bit-identically from a delay tape and golden-traces
//!    like any static run.
//! 2. **Hard bounds.** The returned `k` never drops below the scheme's
//!    erasure-tolerance floor ([`erasure_floor`], derived from the
//!    achieved redundancy β) and never exceeds `m`; it is additionally
//!    held to the last observed live-worker count (the engines clamp
//!    the *effective* k to live at dispatch time regardless, via
//!    `Gather::round_clamped`).
//! 3. **Decisions are per-round.** `observe` is called exactly once per
//!    gather round, after the round completes, with that round's stats.
//!
//! The driver threads a controller into the coordinator loops as an
//! opaque `FnMut(&RoundStats) -> usize` closure
//! (`coordinator::RoundCtl::adaptive`), keeping the coordinator layer
//! below `control` in the module DAG.
//!
//! [`RoundStats`]: crate::metrics::RoundStats

use anyhow::{bail, Context, Result};

use crate::metrics::RoundStats;

pub mod pareto;

/// Minimum `k` the encoding can tolerate without biasing the assembled
/// gradient: `ceil(m / β)`, clamped to `[1, m]`.
///
/// With redundancy β every partition's signal is spread over ~β worker
/// blocks, so any `ceil(m/β)` responses carry a full-rank view of the
/// data. For an uncoded run (β = 1) the floor is `m` — shedding any
/// worker silently drops its data block.
pub fn erasure_floor(m: usize, beta: f64) -> usize {
    let b = beta.max(1.0);
    ((m as f64 / b).ceil() as usize).clamp(1, m)
}

/// Online wait-for-k policy: one `observe` call per completed gather
/// round, returning the `k` to request next round. See the module docs
/// for the determinism and bounds contract.
pub trait Controller {
    /// Stable policy name recorded in traces and reports.
    fn name(&self) -> &'static str;

    /// The `k` to request for round 0, before any stats exist.
    fn initial_k(&self) -> usize;

    /// Digest one completed round; return next round's requested `k`.
    fn observe(&mut self, stats: &RoundStats) -> usize;
}

/// The paper's baseline: `k` fixed for the whole run.
#[derive(Clone, Debug)]
pub struct StaticK {
    pub k: usize,
}

impl Controller for StaticK {
    fn name(&self) -> &'static str {
        "static"
    }

    fn initial_k(&self) -> usize {
        self.k
    }

    fn observe(&mut self, _stats: &RoundStats) -> usize {
        self.k
    }
}

/// Tuning knobs for [`AdaptiveK`].
#[derive(Clone, Debug, PartialEq)]
pub struct AdaptiveConfig {
    /// Shrink `k` when the tail gap exceeds `widen ×` the median
    /// inter-arrival gap (the last waited-for worker is a straggler).
    pub widen: f64,
    /// Grow `k` when the tail gap is at most `shrink ×` the median gap
    /// (the marginal response was nearly free).
    pub shrink: f64,
    /// Consecutive same-direction signals required before moving
    /// (hysteresis); 1 moves immediately.
    pub patience: usize,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig { widen: 2.0, shrink: 0.75, patience: 1 }
    }
}

/// Arrival-gap adaptive policy.
///
/// Per round it computes the inter-arrival gaps of the `k_effective`
/// recorded arrivals, compares the tail gap (cost of the last response
/// waited for) against the median of the earlier gaps, and steps `k`
/// by one: down when the tail is `widen ×` the median or worse, up
/// when it is within `shrink ×` the median. Every decision is clamped
/// to `[erasure_floor(m, β), m]` and to the observed live count, per
/// the module contract.
#[derive(Clone, Debug)]
pub struct AdaptiveK {
    cfg: AdaptiveConfig,
    k: usize,
    floor: usize,
    m: usize,
    /// Signed run-length of same-direction signals (hysteresis state).
    streak: i32,
}

impl AdaptiveK {
    /// `k0` is the starting request (clamped into the hard bounds);
    /// `beta` is the ACHIEVED redundancy of the built encoding.
    pub fn new(k0: usize, m: usize, beta: f64, cfg: AdaptiveConfig) -> AdaptiveK {
        assert!(m >= 1, "need at least one worker");
        let floor = erasure_floor(m, beta);
        AdaptiveK {
            cfg: AdaptiveConfig { patience: cfg.patience.max(1), ..cfg },
            k: k0.clamp(floor, m),
            floor,
            m,
            streak: 0,
        }
    }

    /// The erasure-tolerance floor this controller never drops below.
    pub fn floor(&self) -> usize {
        self.floor
    }
}

impl Controller for AdaptiveK {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn initial_k(&self) -> usize {
        self.k
    }

    fn observe(&mut self, stats: &RoundStats) -> usize {
        // Direction signal from the recorded arrival gaps. With fewer
        // than 3 arrivals there is no tail-vs-body comparison: hold.
        let a = &stats.arrivals;
        let mut dir: i32 = 0;
        if a.len() >= 3 {
            let gaps: Vec<f64> = a.windows(2).map(|w| w[1] - w[0]).collect();
            let tail = *gaps.last().unwrap();
            let mut body = gaps[..gaps.len() - 1].to_vec();
            body.sort_by(|x, y| x.total_cmp(y));
            let median = body[(body.len() - 1) / 2];
            if tail > self.cfg.widen * median {
                dir = -1;
            } else if tail <= self.cfg.shrink * median {
                dir = 1;
            }
        }
        if dir == 0 {
            self.streak = 0;
        } else if self.streak != 0 && (dir > 0) == (self.streak > 0) {
            self.streak += dir;
        } else {
            self.streak = dir;
        }
        if dir != 0 && self.streak.unsigned_abs() as usize >= self.cfg.patience {
            self.k = if dir > 0 { self.k + 1 } else { self.k.saturating_sub(1) };
            self.streak = 0;
        }
        // Hard bounds: never below the erasure floor, never above m,
        // and held to the last observed live count (the floor wins if
        // live has dipped below it — the engine clamp covers the gap).
        self.k = self.k.clamp(self.floor, self.m).min(stats.live.max(self.floor));
        self.k
    }
}

/// Parsed k-policy selection, carried by `driver::Experiment` and
/// `scenario::GridSpec`.
///
/// `Static` preserves the legacy strict-gather semantics (a round with
/// `k > live` panics); `Adaptive` routes rounds through the clamped
/// gather and moves `k` between rounds.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum KPolicy {
    #[default]
    Static,
    Adaptive(AdaptiveConfig),
}

impl KPolicy {
    /// Parse `"static"`, `"adaptive"`, or
    /// `"adaptive:widen=2.0,shrink=0.75,patience=1"`.
    pub fn parse(s: &str) -> Result<KPolicy> {
        let (head, opts) = match s.split_once(':') {
            Some((h, o)) => (h, Some(o)),
            None => (s, None),
        };
        match head {
            "static" => {
                if opts.is_some() {
                    bail!("policy 'static' takes no options");
                }
                Ok(KPolicy::Static)
            }
            "adaptive" => {
                let mut cfg = AdaptiveConfig::default();
                for kv in opts.unwrap_or("").split(',').filter(|t| !t.is_empty()) {
                    let (key, val) = kv
                        .split_once('=')
                        .with_context(|| format!("bad policy option '{kv}' (want key=value)"))?;
                    match key {
                        "widen" => cfg.widen = val.parse().context("bad widen")?,
                        "shrink" => cfg.shrink = val.parse().context("bad shrink")?,
                        "patience" => cfg.patience = val.parse().context("bad patience")?,
                        other => bail!("unknown adaptive option '{other}'"),
                    }
                }
                Ok(KPolicy::Adaptive(cfg))
            }
            other => bail!("unknown k-policy '{other}' (try: static, adaptive)"),
        }
    }

    /// Stable name, matching the built controller's `name()`.
    pub fn name(&self) -> &'static str {
        match self {
            KPolicy::Static => "static",
            KPolicy::Adaptive(_) => "adaptive",
        }
    }

    pub fn is_static(&self) -> bool {
        matches!(self, KPolicy::Static)
    }

    /// Instantiate the controller for a run with `m` workers, starting
    /// request `k0`, and achieved redundancy `beta`.
    pub fn build(&self, k0: usize, m: usize, beta: f64) -> Box<dyn Controller> {
        match self {
            KPolicy::Static => Box::new(StaticK { k: k0 }),
            KPolicy::Adaptive(cfg) => Box::new(AdaptiveK::new(k0, m, beta, cfg.clone())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(round: usize, live: usize, arrivals: &[f64]) -> RoundStats {
        RoundStats {
            round,
            k_requested: arrivals.len(),
            k_effective: arrivals.len(),
            live,
            elapsed: arrivals.last().copied().unwrap_or(0.0),
            arrivals: arrivals.to_vec(),
        }
    }

    #[test]
    fn erasure_floor_bounds() {
        assert_eq!(erasure_floor(8, 2.0), 4);
        assert_eq!(erasure_floor(8, 1.0), 8);
        assert_eq!(erasure_floor(8, 3.0), 3);
        assert_eq!(erasure_floor(8, 100.0), 1);
        // β < 1 is treated as uncoded, not a panic.
        assert_eq!(erasure_floor(8, 0.5), 8);
        assert_eq!(erasure_floor(1, 2.0), 1);
    }

    #[test]
    fn static_k_never_moves() {
        let mut c = StaticK { k: 6 };
        assert_eq!(c.initial_k(), 6);
        assert_eq!(c.observe(&stats(0, 8, &[1.0, 2.0, 50.0])), 6);
        assert_eq!(c.observe(&stats(1, 2, &[1.0])), 6);
    }

    #[test]
    fn adaptive_shrinks_on_straggler_tail() {
        let mut c = AdaptiveK::new(6, 8, 2.0, AdaptiveConfig::default());
        // gaps 1,1,1,1,7: tail 7 > 2×median(1) → shed the straggler.
        let k = c.observe(&stats(0, 8, &[1.0, 2.0, 3.0, 4.0, 5.0, 12.0]));
        assert_eq!(k, 5);
        // ...but never below the erasure floor (m/β = 4).
        let k = c.observe(&stats(1, 8, &[1.0, 2.0, 3.0, 4.0, 11.0]));
        assert_eq!(k, 4);
        let k = c.observe(&stats(2, 8, &[1.0, 2.0, 3.0, 10.0]));
        assert_eq!(k, 4, "floor must hold");
        assert_eq!(c.floor(), 4);
    }

    #[test]
    fn adaptive_grows_on_cheap_tail() {
        let mut c = AdaptiveK::new(6, 8, 2.0, AdaptiveConfig::default());
        // gaps 1,1,1,1,0.1: tail ≤ 0.75×median → the marginal response
        // was nearly free, wait for one more.
        let k = c.observe(&stats(0, 8, &[1.0, 2.0, 3.0, 4.0, 5.0, 5.1]));
        assert_eq!(k, 7);
        let k = c.observe(&stats(1, 8, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 6.1]));
        assert_eq!(k, 8);
        let k = c.observe(&stats(2, 8, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 7.05]));
        assert_eq!(k, 8, "ceiling at m");
    }

    #[test]
    fn adaptive_holds_on_balanced_gaps_or_short_rounds() {
        let mut c = AdaptiveK::new(5, 8, 2.0, AdaptiveConfig::default());
        // Equal gaps: tail == median, neither threshold fires.
        assert_eq!(c.observe(&stats(0, 8, &[1.0, 2.0, 3.0, 4.0, 5.0])), 5);
        // Fewer than 3 arrivals: no signal.
        assert_eq!(c.observe(&stats(1, 8, &[1.0, 2.0])), 5);
    }

    #[test]
    fn adaptive_respects_live_ceiling() {
        let mut c = AdaptiveK::new(6, 8, 2.0, AdaptiveConfig::default());
        // Crash round: only 5 live. Even with a grow signal the next
        // request is held to live.
        let k = c.observe(&stats(0, 5, &[1.0, 2.0, 3.0, 4.0, 4.05]));
        assert_eq!(k, 5);
        // live dips below the floor: the floor wins for the REQUEST
        // (the engine clamps the effective k to live at dispatch).
        let k = c.observe(&stats(1, 3, &[1.0, 2.0, 3.0]));
        assert!(k >= c.floor());
    }

    #[test]
    fn patience_defers_moves() {
        let cfg = AdaptiveConfig { patience: 2, ..AdaptiveConfig::default() };
        let mut c = AdaptiveK::new(6, 8, 2.0, cfg);
        let straggly = [1.0, 2.0, 3.0, 4.0, 5.0, 12.0];
        assert_eq!(c.observe(&stats(0, 8, &straggly)), 6, "first signal: hold");
        assert_eq!(c.observe(&stats(1, 8, &straggly)), 5, "second consecutive: move");
    }

    #[test]
    fn controller_replays_deterministically() {
        let rounds: Vec<RoundStats> = (0..6)
            .map(|r| {
                let arr: Vec<f64> =
                    (0..6).map(|i| (i as f64) + ((r * 7 + i) % 3) as f64 * 0.4).collect();
                let mut sorted = arr;
                sorted.sort_by(|x, y| x.total_cmp(y));
                stats(r, 8, &sorted)
            })
            .collect();
        let run = |mut c: AdaptiveK| -> Vec<usize> {
            rounds.iter().map(|s| c.observe(s)).collect()
        };
        let a = run(AdaptiveK::new(6, 8, 2.0, AdaptiveConfig::default()));
        let b = run(AdaptiveK::new(6, 8, 2.0, AdaptiveConfig::default()));
        assert_eq!(a, b, "same stats stream must give the same k sequence");
    }

    #[test]
    fn policy_parse_and_names() {
        assert_eq!(KPolicy::parse("static").unwrap(), KPolicy::Static);
        assert_eq!(KPolicy::parse("adaptive").unwrap(), KPolicy::Adaptive(Default::default()));
        let p = KPolicy::parse("adaptive:widen=3.0,shrink=0.5,patience=2").unwrap();
        assert_eq!(
            p,
            KPolicy::Adaptive(AdaptiveConfig { widen: 3.0, shrink: 0.5, patience: 2 })
        );
        assert_eq!(p.name(), "adaptive");
        assert_eq!(KPolicy::Static.name(), "static");
        assert!(KPolicy::Static.is_static());
        assert!(!p.is_static());
        assert!(KPolicy::parse("banana").is_err());
        assert!(KPolicy::parse("adaptive:bogus=1").is_err());
        assert!(KPolicy::parse("static:widen=2").is_err());
        // build() honors the policy and the bounds.
        let c = KPolicy::Adaptive(Default::default()).build(2, 8, 2.0);
        assert_eq!(c.initial_k(), 4, "k0 below the floor is lifted to it");
        assert_eq!(KPolicy::Static.build(6, 8, 2.0).initial_k(), 6);
    }
}
