//! `coded-opt pareto` — the redundancy/latency frontier sweep.
//!
//! Runs the (β, k-policy, scheme) × scenario grid through the
//! deterministic scenario runner ([`crate::scenario::run_grid`]), maps
//! every cell to a point carrying its convergence-latency metrics (the
//! `grid-v1` [`CellSummary`] row) plus its erasure-robustness
//! coordinate, and marks the points no other point dominates — the
//! operating frontier of the paper's redundancy-vs-latency trade-off.
//!
//! ## `coded-opt/pareto-v1` schema
//!
//! Hand-written JSON in the `bench-v1` / `lint-v1` / `grid-v1` family
//! (parse with [`crate::bench::json`]):
//!
//! ```json
//! {
//!   "schema": "coded-opt/pareto-v1",
//!   "spec": { "n": 64, "workers": 8, "k0": 6, "epsilon": 0.5,
//!             "betas": [1, 2], "policies": ["static", "adaptive"],
//!             "schemes": ["hadamard"], "scenarios": ["crash-rejoin"] },
//!   "points": [
//!     { "scheme": "hadamard", "scenario": "crash-rejoin",
//!       "policy": "adaptive", "beta": 2, "beta_achieved": 2,
//!       "erasure_floor": 4, "erasure_robustness": 0.5,
//!       "time_to_eps": 1.2e0, "iters_to_eps": 9,
//!       "mean_round_secs": 1.3e-1, "p99_round_secs": 6.1e-1,
//!       "k_min": 4, "k_max": 7, "reached": true, "on_frontier": true }
//!   ],
//!   "frontier": [ { "scheme": "…", "scenario": "…", "policy": "…",
//!                   "beta": 2, "time_to_eps": 1.2e0,
//!                   "erasure_robustness": 0.5 } ]
//! }
//! ```
//!
//! The report is a pure function of the [`ParetoSpec`] — every run is a
//! pinned-seed [`SimCluster`](crate::cluster::SimCluster) simulation —
//! so CI byte-compares a committed fixture against a fresh sweep.
//!
//! ## Frontier semantics
//!
//! Dominance is evaluated **within each scenario** (two scenarios are
//! different environments, so comparing their latencies is
//! meaningless): point `p` dominates `q` iff `p` reaches the ε-target
//! no later AND is at least as erasure-robust, strictly better on one
//! axis. Points that never reach the target (`time_to_eps = null`) are
//! never on the frontier.

use anyhow::{ensure, Result};

use super::{erasure_floor, KPolicy};
// lint:allow(zone-containment) — shares bench's dependency-free JSON writer; no timing flows
use crate::bench::json::escape;
use crate::config::{Algorithm, Scheme};
use crate::scenario::{run_grid, summarize_cell, CellSummary, GridSpec, Scenario};

/// Schema tag written into / expected from every pareto report.
pub const PARETO_SCHEMA: &str = "coded-opt/pareto-v1";

/// The sweep to run: the cross product of `betas × policies` becomes
/// one [`GridSpec`] each (sharing `schemes × scenarios` cells and one
/// pinned-seed synthetic problem), always on the deterministic Sim
/// engine with the Gd solver — the paper's Algorithm 1, and the one
/// solver whose round count equals its iteration count.
#[derive(Clone, Debug)]
pub struct ParetoSpec {
    pub schemes: Vec<Scheme>,
    pub betas: Vec<f64>,
    pub policies: Vec<KPolicy>,
    /// Built-in scenario names ([`Scenario::builtin_names`]).
    pub scenarios: Vec<String>,
    pub n: usize,
    pub p: usize,
    pub m: usize,
    /// Starting wait-for-k request (adaptive policies move from here).
    pub k0: usize,
    pub iters: usize,
    pub seed: u64,
    pub lambda: f64,
    /// Convergence target as a fraction of the first recorded
    /// objective (see [`summarize_cell`]).
    pub epsilon: f64,
}

impl ParetoSpec {
    /// The CLI-default sweep: 2 schemes × 2 betas × 2 policies × 2
    /// library scenarios = 16 points, a few seconds of simulation.
    pub fn small() -> Self {
        ParetoSpec {
            schemes: vec![Scheme::Hadamard, Scheme::Uncoded],
            betas: vec![1.0, 2.0],
            policies: vec![KPolicy::Static, KPolicy::Adaptive(Default::default())],
            scenarios: vec!["crash-rejoin".to_string(), "rack-correlated".to_string()],
            n: 64,
            p: 8,
            m: 8,
            k0: 6,
            iters: 15,
            seed: 42,
            lambda: 0.05,
            epsilon: 0.5,
        }
    }

    /// Points the sweep will produce.
    pub fn points(&self) -> usize {
        self.schemes.len() * self.betas.len() * self.policies.len() * self.scenarios.len()
    }
}

/// One (β, policy, scheme, scenario) operating point.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    /// Requested redundancy (the summary carries the achieved β).
    pub beta: f64,
    /// `erasure_floor(m, beta_achieved)` — the k the scheme can shed to.
    pub floor: usize,
    /// `(m − floor) / m`: the fraction of the fleet the run tolerates
    /// losing without biasing the assembled gradient. 0 for uncoded.
    pub erasure_robustness: f64,
    /// The cell's `grid-v1` metrics row.
    pub summary: CellSummary,
    /// Set by [`mark_frontier`].
    pub on_frontier: bool,
}

impl ParetoPoint {
    /// Whether the run reached the ε-target at all.
    pub fn reached(&self) -> bool {
        self.summary.time_to_eps.is_some()
    }
}

/// Run the sweep. Deterministic: same spec ⇒ same points, in a fixed
/// order (β-major, then policy, then [`run_grid`]'s scenario × scheme
/// cell order). The frontier is already marked on return.
pub fn run_pareto(spec: &ParetoSpec) -> Result<Vec<ParetoPoint>> {
    ensure!(!spec.betas.is_empty(), "pareto sweep needs at least one β");
    ensure!(!spec.policies.is_empty(), "pareto sweep needs at least one k-policy");
    ensure!(spec.epsilon > 0.0 && spec.epsilon < 1.0, "epsilon must be in (0, 1)");
    let scenarios: Vec<Scenario> = spec
        .scenarios
        .iter()
        .map(|name| {
            Scenario::builtin(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown scenario '{name}' (builtins: {})",
                    Scenario::builtin_names().join(", ")
                )
            })
        })
        .collect::<Result<_>>()?;
    let mut points = Vec::with_capacity(spec.points());
    for &beta in &spec.betas {
        for policy in &spec.policies {
            let grid = GridSpec {
                schemes: spec.schemes.clone(),
                algorithms: vec![Algorithm::Gd],
                scenarios: scenarios.clone(),
                n: spec.n,
                p: spec.p,
                m: spec.m,
                k: spec.k0,
                beta,
                iters: spec.iters,
                seed: spec.seed,
                lambda: spec.lambda,
                policy: policy.clone(),
            };
            for cell in run_grid(&grid)? {
                let summary = summarize_cell(&cell, spec.epsilon);
                let floor = erasure_floor(spec.m, summary.beta_achieved);
                points.push(ParetoPoint {
                    beta,
                    floor,
                    erasure_robustness: (spec.m - floor) as f64 / spec.m as f64,
                    summary,
                    on_frontier: false,
                });
            }
        }
    }
    mark_frontier(&mut points);
    Ok(points)
}

/// Mark the non-dominated points within each scenario (see the module
/// docs for the dominance rule). Idempotent.
pub fn mark_frontier(points: &mut [ParetoPoint]) {
    for i in 0..points.len() {
        points[i].on_frontier = false;
        let Some(ti) = points[i].summary.time_to_eps else { continue };
        let ri = points[i].erasure_robustness;
        let dominated = points.iter().enumerate().any(|(j, q)| {
            if j == i || q.summary.scenario != points[i].summary.scenario {
                return false;
            }
            let Some(tj) = q.summary.time_to_eps else { return false };
            tj <= ti && q.erasure_robustness >= ri && (tj < ti || q.erasure_robustness > ri)
        });
        points[i].on_frontier = !dominated;
    }
}

fn json_f64_list(vals: &[f64]) -> String {
    let cells: Vec<String> = vals.iter().map(|v| format!("{v:e}")).collect();
    format!("[{}]", cells.join(", "))
}

fn json_str_list(vals: &[String]) -> String {
    let cells: Vec<String> = vals.iter().map(|v| format!("\"{}\"", escape(v))).collect();
    format!("[{}]", cells.join(", "))
}

/// Serialize the sweep to the `coded-opt/pareto-v1` JSON document.
/// Byte-deterministic for a pinned spec — the CI `pareto-smoke` job
/// runs the same pinned-seed sweep twice and `cmp`s the two reports.
pub fn pareto_json(spec: &ParetoSpec, points: &[ParetoPoint]) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"schema\": \"{PARETO_SCHEMA}\",\n"));
    out.push_str("  \"spec\": {");
    out.push_str(&format!("\"n\": {}, ", spec.n));
    out.push_str(&format!("\"p\": {}, ", spec.p));
    out.push_str(&format!("\"workers\": {}, ", spec.m));
    out.push_str(&format!("\"k0\": {}, ", spec.k0));
    out.push_str(&format!("\"iters\": {}, ", spec.iters));
    out.push_str(&format!("\"seed\": {}, ", spec.seed));
    out.push_str(&format!("\"lambda\": {:e}, ", spec.lambda));
    out.push_str(&format!("\"epsilon\": {:e}, ", spec.epsilon));
    let schemes: Vec<String> = spec.schemes.iter().map(|s| s.name().to_string()).collect();
    let policies: Vec<String> = spec.policies.iter().map(|p| p.name().to_string()).collect();
    out.push_str(&format!("\"schemes\": {}, ", json_str_list(&schemes)));
    out.push_str(&format!("\"betas\": {}, ", json_f64_list(&spec.betas)));
    out.push_str(&format!("\"policies\": {}, ", json_str_list(&policies)));
    out.push_str(&format!("\"scenarios\": {}", json_str_list(&spec.scenarios)));
    out.push_str("},\n");
    out.push_str("  \"points\": [\n");
    for (i, pt) in points.iter().enumerate() {
        let s = &pt.summary;
        out.push_str("    {");
        out.push_str(&format!("\"scheme\": \"{}\", ", escape(&s.scheme)));
        out.push_str(&format!("\"scenario\": \"{}\", ", escape(&s.scenario)));
        out.push_str(&format!("\"policy\": \"{}\", ", escape(&s.policy)));
        out.push_str(&format!("\"beta\": {:e}, ", pt.beta));
        out.push_str(&format!("\"beta_achieved\": {:e}, ", s.beta_achieved));
        out.push_str(&format!("\"erasure_floor\": {}, ", pt.floor));
        out.push_str(&format!("\"erasure_robustness\": {:e}, ", pt.erasure_robustness));
        match s.time_to_eps {
            Some(t) => out.push_str(&format!("\"time_to_eps\": {t:e}, ")),
            None => out.push_str("\"time_to_eps\": null, "),
        }
        match s.iters_to_eps {
            Some(n) => out.push_str(&format!("\"iters_to_eps\": {n}, ")),
            None => out.push_str("\"iters_to_eps\": null, "),
        }
        out.push_str(&format!("\"rounds\": {}, ", s.rounds));
        out.push_str(&format!("\"mean_round_secs\": {:e}, ", s.mean_round_secs));
        out.push_str(&format!("\"p99_round_secs\": {:e}, ", s.p99_round_secs));
        out.push_str(&format!("\"k_min\": {}, ", s.k_min));
        out.push_str(&format!("\"k_max\": {}, ", s.k_max));
        out.push_str(&format!("\"final_objective\": {:e}, ", s.final_objective));
        out.push_str(&format!("\"total_time\": {:e}, ", s.total_time));
        out.push_str(&format!("\"reached\": {}, ", pt.reached()));
        out.push_str(&format!("\"on_frontier\": {}", pt.on_frontier));
        out.push('}');
        out.push_str(if i + 1 < points.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n");
    out.push_str("  \"frontier\": [\n");
    let frontier: Vec<&ParetoPoint> = points.iter().filter(|p| p.on_frontier).collect();
    for (i, pt) in frontier.iter().enumerate() {
        let s = &pt.summary;
        out.push_str("    {");
        out.push_str(&format!("\"scheme\": \"{}\", ", escape(&s.scheme)));
        out.push_str(&format!("\"scenario\": \"{}\", ", escape(&s.scenario)));
        out.push_str(&format!("\"policy\": \"{}\", ", escape(&s.policy)));
        out.push_str(&format!("\"beta\": {:e}, ", pt.beta));
        out.push_str(&format!(
            "\"time_to_eps\": {:e}, ",
            s.time_to_eps.expect("frontier points reached the target")
        ));
        out.push_str(&format!("\"erasure_robustness\": {:e}", pt.erasure_robustness));
        out.push('}');
        out.push_str(if i + 1 < frontier.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// Human-readable summary table of the sweep (frontier points starred).
pub fn pareto_table(points: &[ParetoPoint]) -> crate::metrics::TableWriter {
    let mut table = crate::metrics::TableWriter::new(&[
        "scenario", "scheme", "policy", "beta", "robust", "t_eps", "p99 round", "k range", "front",
    ]);
    for pt in points {
        let s = &pt.summary;
        table.row(&[
            s.scenario.clone(),
            s.scheme.clone(),
            s.policy.clone(),
            format!("{:.2}", s.beta_achieved),
            format!("{:.2}", pt.erasure_robustness),
            match s.time_to_eps {
                Some(t) => format!("{t:.3}s"),
                None => "—".to_string(),
            },
            format!("{:.3}s", s.p99_round_secs),
            format!("{}..{}", s.k_min, s.k_max),
            if pt.on_frontier { "*".to_string() } else { String::new() },
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(scenario: &str, time: Option<f64>, robust: f64) -> ParetoPoint {
        ParetoPoint {
            beta: 2.0,
            floor: 4,
            erasure_robustness: robust,
            summary: CellSummary {
                scheme: "hadamard".to_string(),
                algorithm: "gd".to_string(),
                scenario: scenario.to_string(),
                policy: "static".to_string(),
                beta_achieved: 2.0,
                final_objective: 1.0,
                total_time: 2.0,
                rounds: 10,
                mean_round_secs: 0.1,
                p99_round_secs: 0.2,
                k_min: 6,
                k_max: 6,
                time_to_eps: time,
                iters_to_eps: time.map(|_| 5),
                min_participation: 1.0,
            },
            on_frontier: false,
        }
    }

    #[test]
    fn frontier_keeps_non_dominated_points_per_scenario() {
        let mut pts = vec![
            point("a", Some(1.0), 0.5),  // fast and robust: frontier
            point("a", Some(2.0), 0.5),  // slower, equally robust: dominated
            point("a", Some(0.5), 0.0),  // fastest but fragile: frontier
            point("a", None, 0.9),       // never converged: excluded
            point("b", Some(9.0), 0.0),  // other scenario: its own frontier
        ];
        mark_frontier(&mut pts);
        let flags: Vec<bool> = pts.iter().map(|p| p.on_frontier).collect();
        assert_eq!(flags, vec![true, false, true, false, true]);
        // idempotent
        mark_frontier(&mut pts);
        assert_eq!(flags, pts.iter().map(|p| p.on_frontier).collect::<Vec<_>>());
    }

    #[test]
    fn tie_on_both_axes_keeps_both_points() {
        let mut pts = vec![point("a", Some(1.0), 0.5), point("a", Some(1.0), 0.5)];
        mark_frontier(&mut pts);
        assert!(pts[0].on_frontier && pts[1].on_frontier, "equal points co-exist");
    }

    #[test]
    fn sweep_runs_and_serializes_deterministically() {
        // One β × both policies on one scheme/scenario: 2 points, fast.
        let spec = ParetoSpec {
            schemes: vec![Scheme::Hadamard],
            betas: vec![2.0],
            policies: vec![KPolicy::Static, KPolicy::Adaptive(Default::default())],
            scenarios: vec!["crash-rejoin".to_string()],
            n: 32,
            p: 4,
            m: 8,
            k0: 6,
            iters: 10,
            seed: 7,
            lambda: 0.05,
            epsilon: 0.5,
        };
        let points = run_pareto(&spec).unwrap();
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].summary.policy, "static");
        assert_eq!(points[1].summary.policy, "adaptive");
        assert_eq!(points[0].floor, 4, "hadamard β=2 on m=8");
        assert!((points[0].erasure_robustness - 0.5).abs() < 1e-12);
        // every scenario with a reached point has a frontier point
        assert!(points.iter().any(|p| p.on_frontier) || points.iter().all(|p| !p.reached()));
        let text = pareto_json(&spec, &points);
        let root = crate::bench::json::parse(&text).unwrap();
        let obj = root.as_object().unwrap();
        assert_eq!(
            crate::bench::json::get(obj, "schema").unwrap().as_str().unwrap(),
            PARETO_SCHEMA
        );
        let pts_v = crate::bench::json::get(obj, "points").unwrap().as_array().unwrap();
        assert_eq!(pts_v.len(), 2);
        // pinned seed ⇒ byte-identical report
        let again = pareto_json(&spec, &run_pareto(&spec).unwrap());
        assert_eq!(text, again);
        // and the table renders header + separator + one row per point
        assert_eq!(pareto_table(&points).render().lines().count(), 2 + 2);
    }

    #[test]
    fn bad_specs_are_rejected() {
        let mut spec = ParetoSpec::small();
        spec.scenarios = vec!["no-such-scenario".to_string()];
        assert!(run_pareto(&spec).is_err());
        let mut spec = ParetoSpec::small();
        spec.betas.clear();
        assert!(run_pareto(&spec).is_err());
        let mut spec = ParetoSpec::small();
        spec.epsilon = 1.5;
        assert!(run_pareto(&spec).is_err());
    }
}
