//! Scenario engine: named, seedable descriptions of adversarial and
//! time-varying straggler patterns.
//!
//! The paper's central claim is *deterministic, sample-path* convergence
//! of encoded optimization "for arbitrary sequences of delay patterns or
//! distributions on the nodes". A [`Scenario`] makes such a sequence a
//! first-class, reproducible object: a base [`DelaySpec`] plus an ordered
//! stack of [`Transform`]s (time-varying phases, rack-correlated
//! slowdowns, crash/rejoin windows, per-worker delay scaling) and an
//! optional per-worker compute [`SpeedProfile`], all derived
//! deterministically from a seed.
//!
//! Scenarios are
//! - buildable in code via the builder API
//!   (`Scenario::new("x").base(..).crash(..)`),
//! - constructible from TOML ([`Scenario::from_doc`] /
//!   [`Scenario::from_file`], schema below),
//! - pluggable into `driver::Experiment` via `Experiment::scenario`
//!   (both `SimCluster` and `ThreadCluster`),
//! - runnable as a Scheme × Solver × Scenario grid via [`grid`] and the
//!   `coded-opt scenario` CLI subcommand.
//!
//! ## TOML schema
//!
//! One scenario per document; everything lives under `scenario.*`
//! sections (the flat `config::toml` subset — no arrays, index lists are
//! comma-separated strings):
//!
//! ```toml
//! [scenario]
//! name = "crash-then-degrade"
//! seed = 7                      # mixed into the experiment seed
//!
//! [scenario.base]               # any [delay] spec; default: none
//! kind = "exponential"
//! mean = 0.01
//!
//! # transform sections apply in lexicographic section-name order;
//! # prefix them to control ordering.
//! [scenario.t0-crash]
//! transform = "crash"
//! workers = "0,3"               # or: fraction = 0.25 (seed-chosen set)
//! start = 5                     # gather rounds [start, end)
//! end = 15
//!
//! [scenario.t1-degrade]
//! transform = "phase"
//! start = 20
//! end = 1000000
//! factor = 4.0
//! extra_secs = 0.02
//!
//! [scenario.t2-racks]
//! transform = "rack"
//! racks = 4
//! prob = 0.3
//! slow_secs = 0.5
//!
//! [scenario.t3-scale]
//! transform = "scale"
//! fraction = 0.5                # or: workers = "1,2"
//! factor = 3.0
//!
//! [scenario.speeds]             # per-worker COMPUTE speed (cluster layer)
//! kind = "two_tier"             # or "per_worker" with factors = "1,2,1,4"
//! slow_fraction = 0.25
//! factor = 3.0
//! ```
//!
//! ## Crash/rejoin and the paper's erasure model
//!
//! A crash is modeled as an *unbounded delay* ([`crate::delay::CRASHED`]
//! = +∞) over a round window. Because the coordinator already treats
//! every straggler as an erasure — wait for the fastest `k`, interrupt
//! the rest — a crashed node is just a worker that never makes `A_t`
//! while the window is open, and no new coordinator logic is needed; the
//! redundancy `β` covers the lost updates exactly as Theorem 2's
//! arbitrary-`A_t` guarantee promises. The engines only have to ensure
//! `k` live (non-crashed) workers remain, which they assert per round.

pub mod grid;
pub mod record;
pub mod transforms;

pub use grid::{
    canonical_trace, grid_json, run_grid, summarize_cell, summary_table, CellSummary, GridCell,
    GridSpec, GRID_SCHEMA,
};
pub use record::{DelayRecorder, TapeHandle};
pub use transforms::{
    unit_hash, CrashWindowDelay, PhasedDelay, RackCorrelatedDelay, WorkerScaleDelay,
};

use crate::config::{DelaySpec, TomlDoc};
use crate::delay::{from_spec, DelayModel, TraceDelay};
use crate::rng::{sample_without_replacement, Pcg64};
use anyhow::{bail, ensure, Context, Result};

/// A set of workers, either explicit or a seed-resolved fraction of `m`.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkerSet {
    /// Explicit worker indices.
    List(Vec<usize>),
    /// `round(fraction · m)` workers sampled without replacement from the
    /// scenario's seed stream.
    Fraction(f64),
}

impl WorkerSet {
    /// Resolve to concrete indices for `m` workers.
    pub fn resolve(&self, m: usize, rng: &mut Pcg64) -> Result<Vec<usize>> {
        match self {
            WorkerSet::List(ws) => {
                for &w in ws {
                    ensure!(w < m, "worker {w} out of range for m={m}");
                }
                Ok(ws.clone())
            }
            WorkerSet::Fraction(f) => {
                ensure!((0.0..=1.0).contains(f), "worker fraction must be in [0, 1]");
                let k = ((m as f64) * f).round() as usize;
                Ok(sample_without_replacement(rng, m, k.min(m)))
            }
        }
    }
}

/// One delay transform layered over the base model (see [`transforms`]).
#[derive(Clone, Debug, PartialEq)]
pub enum Transform {
    /// Multiply by `factor` and add `extra_secs` inside rounds
    /// `[start, end)`.
    Phase { start: usize, end: usize, factor: f64, extra_secs: f64 },
    /// Rack-correlated slowdown: `racks` contiguous racks, each slow with
    /// probability `prob` per round, adding `slow_secs`.
    Rack { racks: usize, prob: f64, slow_secs: f64 },
    /// Crash the given workers for rounds `[start, end)` (delay = +∞).
    Crash { workers: WorkerSet, start: usize, end: usize },
    /// Multiply the given workers' delays by `factor`.
    Scale { workers: WorkerSet, factor: f64 },
}

/// Per-worker compute-speed multipliers, applied at the cluster layer
/// (`SimCluster` scales simulated compute time; `ThreadCluster` adds a
/// proportional sleep handicap).
#[derive(Clone, Debug, PartialEq, Default)]
pub enum SpeedProfile {
    /// All workers at speed 1.
    #[default]
    Uniform,
    /// Explicit multiplier per worker (≥ 1 means slower).
    PerWorker(Vec<f64>),
    /// A seed-chosen `slow_fraction` of workers runs `factor`× slower.
    TwoTier { slow_fraction: f64, factor: f64 },
}

impl SpeedProfile {
    /// Resolve to one multiplier per worker.
    pub fn resolve(&self, m: usize, seed: u64) -> Result<Vec<f64>> {
        match self {
            SpeedProfile::Uniform => Ok(vec![1.0; m]),
            SpeedProfile::PerWorker(f) => {
                ensure!(f.len() == m, "speed profile sized for {} workers, m={m}", f.len());
                ensure!(
                    f.iter().all(|s| s.is_finite() && *s > 0.0),
                    "speed multipliers must be finite and > 0"
                );
                Ok(f.clone())
            }
            SpeedProfile::TwoTier { slow_fraction, factor } => {
                ensure!(
                    (0.0..=1.0).contains(slow_fraction),
                    "slow_fraction must be in [0, 1]"
                );
                ensure!(factor.is_finite() && *factor > 0.0, "speed factor must be > 0");
                let k = ((m as f64) * slow_fraction).round() as usize;
                let mut rng = Pcg64::with_stream(seed, 0x5eed);
                let slow = sample_without_replacement(&mut rng, m, k.min(m));
                let mut speeds = vec![1.0; m];
                for w in slow {
                    speeds[w] = *factor;
                }
                Ok(speeds)
            }
        }
    }
}

/// A named, seedable straggler scenario. See the [module docs](self).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    /// Mixed into the experiment seed so the same scenario yields
    /// distinct (but reproducible) realizations across scenarios.
    pub seed: u64,
    /// Base delay distribution the transforms layer over.
    pub base: DelaySpec,
    /// A recorded delay tape replayed instead of `base` (builder-only;
    /// see [`record`]).
    pub replay: Option<Vec<Vec<f64>>>,
    /// Transforms, applied in order (each wraps everything before it).
    pub transforms: Vec<Transform>,
    /// Per-worker compute-speed multipliers for the cluster layer.
    pub speeds: SpeedProfile,
}

impl Scenario {
    pub fn new(name: &str) -> Self {
        Scenario {
            name: name.to_string(),
            seed: 0,
            base: DelaySpec::None,
            replay: None,
            transforms: Vec::new(),
            speeds: SpeedProfile::Uniform,
        }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn base(mut self, spec: DelaySpec) -> Self {
        self.base = spec;
        self
    }

    /// Replay a recorded delay tape (`tape[iter][worker]`) instead of the
    /// base spec.
    pub fn replay(mut self, tape: Vec<Vec<f64>>) -> Self {
        self.replay = Some(tape);
        self
    }

    pub fn phase(mut self, start: usize, end: usize, factor: f64, extra_secs: f64) -> Self {
        self.transforms.push(Transform::Phase { start, end, factor, extra_secs });
        self
    }

    pub fn rack_slowdown(mut self, racks: usize, prob: f64, slow_secs: f64) -> Self {
        self.transforms.push(Transform::Rack { racks, prob, slow_secs });
        self
    }

    pub fn crash(mut self, workers: WorkerSet, start: usize, end: usize) -> Self {
        self.transforms.push(Transform::Crash { workers, start, end });
        self
    }

    pub fn scale(mut self, workers: WorkerSet, factor: f64) -> Self {
        self.transforms.push(Transform::Scale { workers, factor });
        self
    }

    pub fn speeds(mut self, profile: SpeedProfile) -> Self {
        self.speeds = profile;
        self
    }

    /// Whether any transform can produce an infinite (crash) delay. The
    /// wait-for-k engines handle crashes; the event-queue async baselines
    /// would starve the crashed worker forever instead.
    pub fn has_crash(&self) -> bool {
        self.transforms.iter().any(|t| matches!(t, Transform::Crash { .. }))
    }

    /// The scenario's effective seed under an experiment seed.
    pub fn mixed_seed(&self, exp_seed: u64) -> u64 {
        exp_seed ^ self.seed.wrapping_mul(0x9e37_79b9_7f4a_7c15)
    }

    /// Check every transform's parameters, returning loud errors instead
    /// of letting bad TOML reach the constructor asserts. Called by
    /// [`Scenario::build_delay`]; also useful right after parsing.
    pub fn validate(&self) -> Result<()> {
        let name = &self.name;
        for (i, t) in self.transforms.iter().enumerate() {
            match t {
                Transform::Phase { start, end, factor, extra_secs } => {
                    ensure!(
                        start < end,
                        "scenario '{name}' transform #{i}: empty phase window [{start}, {end})"
                    );
                    ensure!(
                        factor.is_finite() && *factor >= 0.0,
                        "scenario '{name}' transform #{i}: phase factor must be finite \
                         and ≥ 0 (got {factor})"
                    );
                    ensure!(
                        *extra_secs >= 0.0,
                        "scenario '{name}' transform #{i}: extra_secs must be ≥ 0 \
                         (got {extra_secs})"
                    );
                }
                Transform::Rack { racks, prob, slow_secs } => {
                    ensure!(*racks >= 1, "scenario '{name}' transform #{i}: racks must be ≥ 1");
                    ensure!(
                        (0.0..=1.0).contains(prob),
                        "scenario '{name}' transform #{i}: rack prob must be in [0, 1] \
                         (got {prob})"
                    );
                    ensure!(
                        *slow_secs >= 0.0,
                        "scenario '{name}' transform #{i}: slow_secs must be ≥ 0 \
                         (got {slow_secs})"
                    );
                }
                Transform::Crash { start, end, .. } => {
                    ensure!(
                        start < end,
                        "scenario '{name}' transform #{i}: empty crash window [{start}, {end})"
                    );
                }
                Transform::Scale { factor, .. } => {
                    ensure!(
                        factor.is_finite() && *factor >= 0.0,
                        "scenario '{name}' transform #{i}: scale factor must be finite \
                         and ≥ 0 (got {factor})"
                    );
                }
            }
        }
        Ok(())
    }

    /// Build the delay model for `m` workers under `exp_seed`
    /// (deterministic: same scenario + seed + m ⇒ same model).
    pub fn build_delay(&self, m: usize, exp_seed: u64) -> Result<Box<dyn DelayModel>> {
        ensure!(m >= 1, "scenario needs at least one worker");
        self.validate()?;
        let seed = self.mixed_seed(exp_seed);
        let mut model: Box<dyn DelayModel> = match &self.replay {
            Some(tape) => {
                ensure!(!tape.is_empty(), "scenario '{}': empty replay tape", self.name);
                ensure!(
                    tape[0].len() == m,
                    "scenario '{}': replay tape is for {} workers, experiment has m={m}",
                    self.name,
                    tape[0].len()
                );
                // NaN marks an unsampled hole in a raw recorder snapshot
                // (see record::TapeHandle); replaying one silently would
                // smuggle NaN into delay composition, so reject it here
                // and point at the patching API.
                for (t, row) in tape.iter().enumerate() {
                    ensure!(
                        row.iter().all(|v| !v.is_nan()),
                        "scenario '{}': replay tape has an unsampled NaN hole at \
                         iteration {t}; build the tape with TapeHandle::replay(hole_secs) \
                         or patch the holes before replaying",
                        self.name
                    );
                }
                Box::new(TraceDelay::new(tape.clone()))
            }
            None => from_spec(&self.base, m, seed),
        };
        for (i, t) in self.transforms.iter().enumerate() {
            // Each transform draws from its own stream, keyed by its
            // position in the stack, so no two transforms share draws.
            // (Reordering transforms therefore changes the realization —
            // a scenario is identified by its full ordered stack + seed.)
            let mut rng = Pcg64::with_stream(seed, 0x5ce0_0000 + i as u64);
            model = match t {
                Transform::Phase { start, end, factor, extra_secs } => Box::new(
                    PhasedDelay::new(model, *start, *end, *factor, *extra_secs),
                ),
                Transform::Rack { racks, prob, slow_secs } => Box::new(
                    RackCorrelatedDelay::new(model, (*racks).min(m), *prob, *slow_secs, seed),
                ),
                Transform::Crash { workers, start, end } => {
                    let ws = workers.resolve(m, &mut rng).map_err(|e| {
                        anyhow::anyhow!("scenario '{}' crash set: {e}", self.name)
                    })?;
                    Box::new(CrashWindowDelay::new(model, &ws, *start, *end))
                }
                Transform::Scale { workers, factor } => {
                    let ws = workers.resolve(m, &mut rng).map_err(|e| {
                        anyhow::anyhow!("scenario '{}' scale set: {e}", self.name)
                    })?;
                    let mut factors = vec![1.0; m];
                    for w in ws {
                        factors[w] = *factor;
                    }
                    Box::new(WorkerScaleDelay::new(model, factors))
                }
            };
        }
        Ok(model)
    }

    // ------------------------------------------------------------ TOML

    /// Parse a scenario from a TOML document (schema in the
    /// [module docs](self)).
    pub fn from_doc(doc: &TomlDoc) -> Result<Scenario> {
        ensure!(doc.has_section("scenario"), "missing [scenario] section");
        let mut sc = Scenario::new(doc.get_str("scenario", "name").unwrap_or("unnamed"));
        if let Some(seed) = doc.get_i64("scenario", "seed") {
            sc.seed = seed as u64;
        }
        if doc.has_section("scenario.base") {
            sc.base = DelaySpec::parse(doc, "scenario.base")?;
        }
        if doc.has_section("scenario.speeds") {
            sc.speeds = parse_speeds(doc, "scenario.speeds")?;
        }
        for section in doc.sections() {
            let Some(rest) = section.strip_prefix("scenario.") else {
                continue;
            };
            if rest == "base" || rest == "speeds" {
                continue;
            }
            sc.transforms.push(parse_transform(doc, &section)?);
        }
        Ok(sc)
    }

    pub fn from_file(path: &str) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {path}"))?;
        let doc = TomlDoc::parse(&text)?;
        Self::from_doc(&doc)
    }

    // -------------------------------------------------------- builtins

    /// Names of the built-in scenario library (CLI + golden suite).
    pub fn builtin_names() -> &'static [&'static str] {
        &[
            "baseline",
            "warmup-degrade",
            "rack-correlated",
            "crash-rejoin",
            "hetero-speed",
            "random-half",
        ]
    }

    /// A built-in scenario by name (see [`Scenario::builtin_names`]).
    pub fn builtin(name: &str) -> Option<Scenario> {
        let exp = DelaySpec::Exponential { mean: 0.005 };
        Some(match name {
            // plain i.i.d. exponential latency, no transforms
            "baseline" => Scenario::new("baseline").base(exp),
            // quiet warm-up, then a sustained 4× degradation with a
            // 20 ms floor — the time-varying-distribution case
            "warmup-degrade" => Scenario::new("warmup-degrade")
                .base(exp)
                .phase(0, 10, 0.25, 0.0)
                .phase(10, usize::MAX, 4.0, 0.02),
            // 4 racks, each independently slow 30% of rounds
            "rack-correlated" => Scenario::new("rack-correlated")
                .base(exp)
                .rack_slowdown(4, 0.3, 0.5),
            // a quarter of the fleet crashes for rounds [5, 15) and
            // rejoins — the erasure-window case
            "crash-rejoin" => Scenario::new("crash-rejoin")
                .base(exp)
                .crash(WorkerSet::Fraction(0.25), 5, 15),
            // heterogeneous hardware on both axes: one seed-chosen
            // quarter of the fleet sees 2× the injected latency, and an
            // independently drawn quarter computes 4× slower (the two
            // sets come from unrelated streams and generally differ, so
            // up to half the fleet is degraded on one axis each)
            "hetero-speed" => Scenario::new("hetero-speed")
                .base(exp)
                .scale(WorkerSet::Fraction(0.25), 2.0)
                .speeds(SpeedProfile::TwoTier { slow_fraction: 0.25, factor: 4.0 }),
            // every round an (unpredictable) half of the fleet stalls —
            // one rack per worker makes the rack coin per-worker
            "random-half" => Scenario::new("random-half")
                .base(exp)
                .rack_slowdown(usize::MAX, 0.5, 0.3),
            _ => return None,
        })
    }
}

fn parse_index_list(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|tok| {
            tok.trim()
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad worker index '{tok}'"))
        })
        .collect()
}

fn parse_f64_list(s: &str) -> Result<Vec<f64>> {
    s.split(',')
        .map(|tok| {
            tok.trim()
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("bad factor '{tok}'"))
        })
        .collect()
}

/// Worker set from a section: `workers = "0,3"` or `fraction = 0.25`.
fn parse_worker_set(doc: &TomlDoc, section: &str) -> Result<WorkerSet> {
    if let Some(ws) = doc.get_str(section, "workers") {
        return Ok(WorkerSet::List(parse_index_list(ws)?));
    }
    if let Some(f) = doc.get_f64(section, "fraction") {
        return Ok(WorkerSet::Fraction(f));
    }
    bail!("[{section}] needs either workers = \"i,j,…\" or fraction = x")
}

/// Non-negative integer key with default (negative values error instead
/// of wrapping through an `as usize` cast).
fn get_nonneg(doc: &TomlDoc, section: &str, key: &str, default: usize) -> Result<usize> {
    match doc.get_i64(section, key) {
        None => Ok(default),
        Some(v) if v >= 0 => Ok(v as usize),
        Some(v) => bail!("[{section}] {key} must be ≥ 0 (got {v})"),
    }
}

fn parse_transform(doc: &TomlDoc, section: &str) -> Result<Transform> {
    let kind = doc
        .get_str(section, "transform")
        .ok_or_else(|| anyhow::anyhow!("[{section}] missing 'transform' key"))?;
    Ok(match kind {
        "phase" => Transform::Phase {
            start: get_nonneg(doc, section, "start", 0)?,
            end: get_nonneg(doc, section, "end", usize::MAX)?,
            factor: doc.get_f64(section, "factor").unwrap_or(1.0),
            extra_secs: doc.get_f64(section, "extra_secs").unwrap_or(0.0),
        },
        "rack" => Transform::Rack {
            racks: get_nonneg(doc, section, "racks", 2)?,
            prob: doc.get_f64(section, "prob").unwrap_or(0.25),
            slow_secs: doc.get_f64(section, "slow_secs").unwrap_or(1.0),
        },
        "crash" => Transform::Crash {
            workers: parse_worker_set(doc, section)?,
            start: get_nonneg(doc, section, "start", 0)?,
            end: get_nonneg(doc, section, "end", usize::MAX)?,
        },
        "scale" => Transform::Scale {
            workers: parse_worker_set(doc, section)?,
            factor: doc.get_f64(section, "factor").unwrap_or(2.0),
        },
        other => bail!("[{section}]: unknown transform '{other}'"),
    })
}

// ------------------------------------------------------- tape files
//
// A recorded delay tape as a text file: one line per gather round, one
// whitespace-separated f64 per worker, `#` comments and blank lines
// ignored, `inf` for a crash erasure. Rust's shortest-round-trip float
// formatting guarantees `format_tape` → `parse_tape` preserves every
// delay bit-for-bit, so a tape written by one process and replayed by
// another (`coded-opt run --replay-tape`) reproduces the recorded
// trace exactly.

/// Render a delay tape in the text format [`parse_tape`] reads.
pub fn format_tape(tape: &[Vec<f64>]) -> String {
    let mut s = String::from("# coded-opt delay tape: rows = rounds, cols = workers\n");
    for row in tape {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        s.push_str(&cells.join(" "));
        s.push('\n');
    }
    s
}

/// Parse the text tape format (see [`format_tape`]). Rejects NaN holes,
/// ragged rows, and empty tapes loudly — a malformed tape must never
/// degrade into a silently different delay realization.
pub fn parse_tape(text: &str) -> Result<Vec<Vec<f64>>> {
    let mut tape: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut row = Vec::new();
        for tok in line.split_whitespace() {
            let v: f64 = tok
                .parse()
                .map_err(|e| anyhow::anyhow!("tape line {}: '{tok}': {e}", lineno + 1))?;
            ensure!(
                !v.is_nan(),
                "tape line {}: NaN delay — record holes must be patched \
                 (TapeHandle::replay) before writing a tape file",
                lineno + 1
            );
            row.push(v);
        }
        if let Some(first) = tape.first() {
            ensure!(
                row.len() == first.len(),
                "tape line {}: {} delay(s) but earlier rounds have {} worker(s)",
                lineno + 1,
                row.len(),
                first.len()
            );
        }
        tape.push(row);
    }
    ensure!(!tape.is_empty(), "delay tape has no rounds");
    Ok(tape)
}

/// [`parse_tape`] over a file path.
pub fn read_tape_file(path: &str) -> Result<Vec<Vec<f64>>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading delay tape {path}"))?;
    parse_tape(&text).with_context(|| format!("parsing delay tape {path}"))
}

/// [`format_tape`] to a file path.
pub fn write_tape_file(path: &str, tape: &[Vec<f64>]) -> Result<()> {
    std::fs::write(path, format_tape(tape)).with_context(|| format!("writing delay tape {path}"))
}

fn parse_speeds(doc: &TomlDoc, section: &str) -> Result<SpeedProfile> {
    let kind = doc.get_str(section, "kind").unwrap_or("uniform");
    Ok(match kind {
        "uniform" => SpeedProfile::Uniform,
        "per_worker" => {
            let f = doc
                .get_str(section, "factors")
                .ok_or_else(|| anyhow::anyhow!("[{section}] per_worker needs factors"))?;
            SpeedProfile::PerWorker(parse_f64_list(f)?)
        }
        "two_tier" => SpeedProfile::TwoTier {
            slow_fraction: doc.get_f64(section, "slow_fraction").unwrap_or(0.25),
            factor: doc.get_f64(section, "factor").unwrap_or(2.0),
        },
        other => bail!("[{section}]: unknown speeds kind '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_builds_deterministic_model() {
        let sc = Scenario::new("t")
            .seed(3)
            .base(DelaySpec::Exponential { mean: 0.01 })
            .phase(0, 5, 0.5, 0.0)
            .crash(WorkerSet::List(vec![1]), 2, 4);
        let sample_all = |sc: &Scenario| -> Vec<u64> {
            let mut d = sc.build_delay(4, 42).unwrap();
            let mut out = Vec::new();
            for t in 0..8 {
                for w in 0..4 {
                    out.push(d.sample(w, t).to_bits());
                }
            }
            out
        };
        assert_eq!(sample_all(&sc), sample_all(&sc), "same seed ⇒ bit-identical");
        let mut d = sc.build_delay(4, 42).unwrap();
        assert!(d.sample(1, 2).is_infinite(), "crash window");
        assert!(d.sample(1, 4).is_finite(), "rejoin");
        assert!(sc.has_crash());
    }

    #[test]
    fn scenario_seed_changes_realization() {
        let base = Scenario::new("a").base(DelaySpec::Exponential { mean: 0.01 });
        let mut d0 = base.clone().seed(1).build_delay(4, 42).unwrap();
        let mut d1 = base.seed(2).build_delay(4, 42).unwrap();
        let diff = (0..16).filter(|&i| d0.sample(i % 4, i / 4) != d1.sample(i % 4, i / 4)).count();
        assert!(diff > 8, "seeds must decorrelate realizations");
    }

    #[test]
    fn fraction_crash_resolves_to_rounded_count() {
        let sc = Scenario::new("c").crash(WorkerSet::Fraction(0.25), 0, 10);
        let mut d = sc.build_delay(8, 7).unwrap();
        let crashed = (0..8).filter(|&w| d.sample(w, 0).is_infinite()).count();
        assert_eq!(crashed, 2);
    }

    #[test]
    fn toml_roundtrip() {
        let text = r#"
[scenario]
name = "mixed"
seed = 11

[scenario.base]
kind = "exponential"
mean = 0.02

[scenario.t0-crash]
transform = "crash"
workers = "0,2"
start = 1
end = 3

[scenario.t1-phase]
transform = "phase"
start = 5
end = 9
factor = 2.0
extra_secs = 0.1

[scenario.t2-rack]
transform = "rack"
racks = 2
prob = 0.5
slow_secs = 0.3

[scenario.speeds]
kind = "two_tier"
slow_fraction = 0.5
factor = 3.0
"#;
        let doc = TomlDoc::parse(text).unwrap();
        let sc = Scenario::from_doc(&doc).unwrap();
        assert_eq!(sc.name, "mixed");
        assert_eq!(sc.seed, 11);
        assert_eq!(sc.base, DelaySpec::Exponential { mean: 0.02 });
        assert_eq!(sc.transforms.len(), 3);
        assert_eq!(
            sc.transforms[0],
            Transform::Crash { workers: WorkerSet::List(vec![0, 2]), start: 1, end: 3 }
        );
        assert!(matches!(sc.transforms[1], Transform::Phase { .. }));
        assert!(matches!(sc.transforms[2], Transform::Rack { .. }));
        let speeds = sc.speeds.resolve(4, 9).unwrap();
        assert_eq!(speeds.iter().filter(|&&s| s == 3.0).count(), 2);
        // and the whole thing builds
        let mut d = sc.build_delay(4, 1).unwrap();
        assert!(d.sample(0, 1).is_infinite());
        assert!(d.sample(1, 1).is_finite());
    }

    #[test]
    fn bad_values_error_instead_of_panicking() {
        // empty phase window → build_delay error, not a constructor panic
        let sc = Scenario::new("bad").phase(3, 3, 1.0, 0.0);
        assert!(sc.build_delay(4, 1).is_err());
        // racks = 0 → error
        let sc = Scenario::new("bad").rack_slowdown(0, 0.5, 1.0);
        assert!(sc.build_delay(4, 1).is_err());
        // prob out of range → error
        let sc = Scenario::new("bad").rack_slowdown(2, 1.5, 1.0);
        assert!(sc.build_delay(4, 1).is_err());
        // negative TOML integers → parse error, not a wrapping cast
        let doc = TomlDoc::parse(
            "[scenario]\nname = \"x\"\n[scenario.t]\ntransform = \"phase\"\nstart = -1\n",
        )
        .unwrap();
        assert!(Scenario::from_doc(&doc).is_err());
    }

    #[test]
    fn toml_errors_are_loud() {
        let no_scenario = TomlDoc::parse("[delay]\nkind = \"none\"\n").unwrap();
        assert!(Scenario::from_doc(&no_scenario).is_err());
        let bad = TomlDoc::parse(
            "[scenario]\nname = \"x\"\n[scenario.t]\ntransform = \"nope\"\n",
        )
        .unwrap();
        assert!(Scenario::from_doc(&bad).is_err());
        let missing_set = TomlDoc::parse(
            "[scenario]\nname = \"x\"\n[scenario.t]\ntransform = \"crash\"\n",
        )
        .unwrap();
        assert!(Scenario::from_doc(&missing_set).is_err());
    }

    #[test]
    fn example_scenario_file_parses_and_builds() {
        let path =
            concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/scenarios/crash_degrade.toml");
        let sc = Scenario::from_file(path).unwrap();
        assert_eq!(sc.name, "crash-degrade");
        assert_eq!(sc.seed, 7);
        assert_eq!(sc.transforms.len(), 3);
        assert!(sc.has_crash());
        assert!(matches!(sc.speeds, SpeedProfile::TwoTier { .. }));
        let mut d = sc.build_delay(8, 1).unwrap();
        let crashed_at_6 = (0..8).filter(|&w| d.sample(w, 6).is_infinite()).count();
        assert_eq!(crashed_at_6, 2, "fraction 0.25 of 8 crashes inside the window");
    }

    #[test]
    fn builtins_all_build() {
        for name in Scenario::builtin_names() {
            let sc = Scenario::builtin(name).unwrap_or_else(|| panic!("missing builtin {name}"));
            assert_eq!(&sc.name, name);
            let mut d = sc.build_delay(8, 42).unwrap();
            for t in 0..20 {
                for w in 0..8 {
                    let v = d.sample(w, t);
                    assert!(v >= 0.0, "{name}: negative delay {v}");
                }
            }
            let speeds = sc.speeds.resolve(8, 42).unwrap();
            assert_eq!(speeds.len(), 8);
        }
        assert!(Scenario::builtin("no-such").is_none());
    }

    #[test]
    fn crash_rejoin_keeps_six_of_eight_alive() {
        // The golden grid runs m=8, k=6: the builtin crash window must
        // never take more than 2 workers down.
        let sc = Scenario::builtin("crash-rejoin").unwrap();
        let mut d = sc.build_delay(8, 1234).unwrap();
        for t in 0..25 {
            let live = (0..8).filter(|&w| d.sample(w, t).is_finite()).count();
            assert!(live >= 6, "round {t}: only {live} live");
        }
    }

    #[test]
    fn replay_scenario_reproduces_tape() {
        let tape = vec![vec![0.1, 0.2], vec![0.3, 0.4]];
        let sc = Scenario::new("r").replay(tape.clone());
        let mut d = sc.build_delay(2, 99).unwrap();
        assert_eq!(d.sample(1, 0), 0.2);
        assert_eq!(d.sample(0, 1), 0.3);
        // wrong width is rejected
        assert!(sc.build_delay(3, 99).is_err());
    }

    #[test]
    fn tape_text_round_trip_is_bit_exact() {
        // awkward values on purpose: shortest-round-trip formatting must
        // preserve every bit, including subnormals and infinities
        let tape = vec![
            vec![0.1, 1.0 / 3.0, f64::INFINITY, 5e-324],
            vec![f64::MAX, 0.0, 1e-17, 2.5],
        ];
        let parsed = parse_tape(&format_tape(&tape)).unwrap();
        assert_eq!(parsed.len(), tape.len());
        for (a, b) in tape.iter().zip(&parsed) {
            let (ab, bb): (Vec<u64>, Vec<u64>) = (
                a.iter().map(|v| v.to_bits()).collect(),
                b.iter().map(|v| v.to_bits()).collect(),
            );
            assert_eq!(ab, bb);
        }
    }

    #[test]
    fn tape_parser_rejects_malformed_input_loudly() {
        // comments and blank lines are fine
        let ok = parse_tape("# header\n\n0.1 0.2 # trailing\n0.3 inf\n").unwrap();
        assert_eq!(ok, vec![vec![0.1, 0.2], vec![0.3, f64::INFINITY]]);
        // ragged rows
        let e = parse_tape("0.1 0.2\n0.3\n").unwrap_err().to_string();
        assert!(e.contains("earlier rounds have 2 worker(s)"), "{e}");
        // NaN holes
        let e = parse_tape("0.1 NaN\n").unwrap_err().to_string();
        assert!(e.contains("NaN delay"), "{e}");
        // junk token
        assert!(parse_tape("0.1 zebra\n").is_err());
        // empty
        assert!(parse_tape("# nothing\n").is_err());
    }
}
