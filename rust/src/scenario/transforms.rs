//! Composable delay transforms: wrappers layered over any base
//! [`DelayModel`] to produce time-varying, correlated, or adversarial
//! straggler patterns while staying fully deterministic.
//!
//! Every wrapper samples its inner model *first* and then modifies the
//! result, so the base model's RNG stream advances identically whether or
//! not a transform is active — adding a crash window does not perturb the
//! delays other workers see.

use crate::delay::{DelayModel, CRASHED};

/// Stateless hash of `(seed, a, b)` to a uniform f64 in [0, 1)
/// (splitmix64-style finalizer). Used by transforms whose per-iteration
/// randomness must not depend on the order in which workers are sampled.
pub fn unit_hash(seed: u64, a: u64, b: u64) -> f64 {
    let mut z = seed
        ^ a.wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ b.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Time-varying phase: inside gather rounds `[start, end)` the base delay
/// is multiplied by `factor` and `extra_secs` is added (e.g. a warm-up
/// phase at `factor < 1`, or a degradation phase at `factor > 1`).
pub struct PhasedDelay {
    inner: Box<dyn DelayModel>,
    start: usize,
    end: usize,
    factor: f64,
    extra_secs: f64,
}

impl PhasedDelay {
    pub fn new(
        inner: Box<dyn DelayModel>,
        start: usize,
        end: usize,
        factor: f64,
        extra_secs: f64,
    ) -> Self {
        assert!(start < end, "phase window must be non-empty (start={start}, end={end})");
        assert!(factor >= 0.0 && factor.is_finite(), "phase factor must be finite and ≥ 0");
        assert!(extra_secs >= 0.0, "phase extra_secs must be ≥ 0");
        PhasedDelay { inner, start, end, factor, extra_secs }
    }
}

impl DelayModel for PhasedDelay {
    fn sample(&mut self, worker: usize, iter: usize) -> f64 {
        let d = self.inner.sample(worker, iter);
        // A crash (infinite delay) from an inner transform passes through
        // unchanged: factor 0.0 would otherwise produce inf·0 = NaN.
        if !d.is_finite() {
            return d;
        }
        if iter >= self.start && iter < self.end {
            d * self.factor + self.extra_secs
        } else {
            d
        }
    }
    fn workers(&self) -> usize {
        self.inner.workers()
    }
}

/// Rack-correlated slowdowns: workers are grouped into `racks` contiguous
/// racks; each (iteration, rack) pair independently suffers a shared
/// `slow_secs` hit with probability `prob`. The coin flips come from
/// [`unit_hash`], so they are a pure function of `(seed, iter, rack)` —
/// identical regardless of engine or sampling order.
pub struct RackCorrelatedDelay {
    inner: Box<dyn DelayModel>,
    m: usize,
    racks: usize,
    prob: f64,
    slow_secs: f64,
    seed: u64,
}

impl RackCorrelatedDelay {
    pub fn new(
        inner: Box<dyn DelayModel>,
        racks: usize,
        prob: f64,
        slow_secs: f64,
        seed: u64,
    ) -> Self {
        let m = inner.workers();
        assert!(racks >= 1 && racks <= m, "racks must satisfy 1 ≤ racks ≤ m");
        assert!((0.0..=1.0).contains(&prob), "rack slowdown prob must be in [0, 1]");
        assert!(slow_secs >= 0.0, "rack slow_secs must be ≥ 0");
        RackCorrelatedDelay { inner, m, racks, prob, slow_secs, seed }
    }

    /// Rack of worker `w` (contiguous blocks of near-equal size).
    pub fn rack_of(&self, worker: usize) -> usize {
        worker * self.racks / self.m
    }
}

impl DelayModel for RackCorrelatedDelay {
    fn sample(&mut self, worker: usize, iter: usize) -> f64 {
        let d = self.inner.sample(worker, iter);
        let rack = self.rack_of(worker);
        if unit_hash(self.seed, iter as u64, rack as u64) < self.prob {
            d + self.slow_secs
        } else {
            d
        }
    }
    fn workers(&self) -> usize {
        self.m
    }
}

/// Crash/rejoin window: the given workers are *crashed* (their delay is
/// [`CRASHED`] = +∞) during gather rounds `[start, end)` and behave
/// normally outside it. A crash is just an unbounded delay, so the
/// stragglers-as-erasures coordinator handles it with no extra logic —
/// the crashed worker simply never makes the fastest-k set while the
/// window is open, and rejoins A_t candidates once it closes.
pub struct CrashWindowDelay {
    inner: Box<dyn DelayModel>,
    crashed: Vec<bool>,
    start: usize,
    end: usize,
}

impl CrashWindowDelay {
    pub fn new(inner: Box<dyn DelayModel>, workers: &[usize], start: usize, end: usize) -> Self {
        assert!(start < end, "crash window must be non-empty (start={start}, end={end})");
        let m = inner.workers();
        let mut crashed = vec![false; m];
        for &w in workers {
            assert!(w < m, "crashed worker {w} out of range for m={m}");
            crashed[w] = true;
        }
        CrashWindowDelay { inner, crashed, start, end }
    }
}

impl DelayModel for CrashWindowDelay {
    fn sample(&mut self, worker: usize, iter: usize) -> f64 {
        // Sample first to keep the base RNG stream aligned with the
        // crash-free counterfactual.
        let d = self.inner.sample(worker, iter);
        if self.crashed[worker] && iter >= self.start && iter < self.end {
            CRASHED
        } else {
            d
        }
    }
    fn workers(&self) -> usize {
        self.inner.workers()
    }
}

/// Per-worker multiplicative delay scaling (heterogeneous node quality on
/// the *injected latency* axis; compute-speed heterogeneity lives at the
/// cluster layer, see `SimCluster::with_speeds`).
pub struct WorkerScaleDelay {
    inner: Box<dyn DelayModel>,
    factors: Vec<f64>,
}

impl WorkerScaleDelay {
    pub fn new(inner: Box<dyn DelayModel>, factors: Vec<f64>) -> Self {
        assert_eq!(factors.len(), inner.workers(), "one scale factor per worker");
        assert!(
            factors.iter().all(|f| f.is_finite() && *f >= 0.0),
            "scale factors must be finite and ≥ 0"
        );
        WorkerScaleDelay { inner, factors }
    }
}

impl DelayModel for WorkerScaleDelay {
    fn sample(&mut self, worker: usize, iter: usize) -> f64 {
        let d = self.inner.sample(worker, iter);
        // Crashes pass through unscaled (avoid inf·0 = NaN at factor 0).
        if !d.is_finite() {
            return d;
        }
        d * self.factors[worker]
    }
    fn workers(&self) -> usize {
        self.inner.workers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::ConstantDelay;

    fn base(m: usize, secs: f64) -> Box<dyn DelayModel> {
        Box::new(ConstantDelay::new(m, secs))
    }

    #[test]
    fn unit_hash_is_deterministic_and_uniformish() {
        assert_eq!(unit_hash(1, 2, 3), unit_hash(1, 2, 3));
        assert_ne!(unit_hash(1, 2, 3), unit_hash(1, 2, 4));
        let n = 10_000;
        let mean: f64 =
            (0..n).map(|i| unit_hash(7, i as u64, 0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
        assert!((0..n).all(|i| (0.0..1.0).contains(&unit_hash(9, i as u64, 1))));
    }

    #[test]
    fn phase_applies_only_inside_window() {
        let mut d = PhasedDelay::new(base(2, 1.0), 5, 10, 3.0, 0.5);
        assert_eq!(d.sample(0, 4), 1.0);
        assert_eq!(d.sample(0, 5), 3.5);
        assert_eq!(d.sample(1, 9), 3.5);
        assert_eq!(d.sample(1, 10), 1.0);
        assert_eq!(d.workers(), 2);
    }

    #[test]
    fn rack_groups_are_contiguous_and_move_together() {
        let mut d = RackCorrelatedDelay::new(base(8, 0.0), 4, 0.5, 2.0, 11);
        assert_eq!(d.rack_of(0), 0);
        assert_eq!(d.rack_of(1), 0);
        assert_eq!(d.rack_of(7), 3);
        for t in 0..50 {
            // rack-mates always agree
            assert_eq!(d.sample(0, t), d.sample(1, t), "iter {t}");
            assert_eq!(d.sample(6, t), d.sample(7, t), "iter {t}");
        }
        // some iteration separates rack 0 from rack 3 (correlated ≠ global)
        assert!(
            (0..200).any(|t| d.sample(0, t) != d.sample(7, t)),
            "racks never diverged"
        );
        // roughly prob fraction of (iter, rack) pairs are slow
        let slow = (0..400).filter(|&t| d.sample(0, t) > 0.0).count();
        assert!((120..=280).contains(&slow), "slow={slow}");
    }

    #[test]
    fn crash_window_is_infinite_then_rejoins() {
        let mut d = CrashWindowDelay::new(base(3, 0.1), &[1], 2, 4);
        assert_eq!(d.sample(1, 1), 0.1);
        assert!(d.sample(1, 2).is_infinite());
        assert!(d.sample(1, 3).is_infinite());
        assert_eq!(d.sample(1, 4), 0.1, "worker must rejoin after the window");
        assert_eq!(d.sample(0, 2), 0.1, "others unaffected");
    }

    #[test]
    fn worker_scale_is_per_worker() {
        let mut d = WorkerScaleDelay::new(base(3, 2.0), vec![1.0, 0.5, 3.0]);
        assert_eq!(d.sample(0, 0), 2.0);
        assert_eq!(d.sample(1, 0), 1.0);
        assert_eq!(d.sample(2, 0), 6.0);
    }

    #[test]
    fn crashes_pass_through_multiplicative_transforms_unscathed() {
        // factor 0.0 ("perfectly quiet phase") over a crash window must
        // not turn +inf into inf·0 = NaN.
        let crash = CrashWindowDelay::new(base(2, 0.1), &[0], 0, 10);
        let mut d = PhasedDelay::new(Box::new(crash), 0, 10, 0.0, 0.0);
        assert!(d.sample(0, 3).is_infinite(), "crash preserved, not NaN");
        assert_eq!(d.sample(1, 3), 0.0, "live worker scaled normally");
        let crash = CrashWindowDelay::new(base(2, 0.1), &[0], 0, 10);
        let mut d = WorkerScaleDelay::new(Box::new(crash), vec![0.0, 2.0]);
        assert!(d.sample(0, 3).is_infinite());
        assert_eq!(d.sample(1, 3), 0.2);
    }

    #[test]
    #[should_panic]
    fn empty_phase_window_rejected() {
        let _ = PhasedDelay::new(base(2, 0.0), 5, 5, 1.0, 0.0);
    }
}
