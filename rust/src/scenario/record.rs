//! Delay-trace record/replay.
//!
//! [`DelayRecorder`] wraps any [`DelayModel`] and tapes every sampled
//! `(worker, iter) → delay` onto a shared [`TapeHandle`]. After a run the
//! tape replays through [`crate::delay::TraceDelay`], reproducing the
//! exact same straggler pattern against a different scheme / solver /
//! engine — the "same adversary, different code" comparison the paper's
//! sample-path guarantees are about.

use std::sync::{Arc, Mutex};

use crate::delay::{DelayModel, TraceDelay};

/// Shared handle onto a recorded delay tape (`tape[iter][worker]`).
/// Entries never sampled in an iteration are `NaN`.
#[derive(Clone)]
pub struct TapeHandle {
    tape: Arc<Mutex<Vec<Vec<f64>>>>,
    m: usize,
}

impl TapeHandle {
    /// Copy of the tape recorded so far.
    pub fn snapshot(&self) -> Vec<Vec<f64>> {
        self.tape.lock().unwrap().clone()
    }

    /// Number of iterations recorded so far.
    pub fn len(&self) -> usize {
        self.tape.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Build a replaying [`TraceDelay`] from the tape. Unsampled entries
    /// (workers never asked in an iteration, e.g. because the engine
    /// skipped a crashed worker) are replayed as `hole_secs`.
    pub fn replay(&self, hole_secs: f64) -> TraceDelay {
        let mut tape = self.snapshot();
        assert!(!tape.is_empty(), "cannot replay an empty delay tape");
        for row in tape.iter_mut() {
            for v in row.iter_mut() {
                if v.is_nan() {
                    *v = hole_secs;
                }
            }
        }
        TraceDelay::new(tape)
    }
}

/// Recording wrapper: delegates to the inner model and tapes the result.
pub struct DelayRecorder {
    inner: Box<dyn DelayModel>,
    handle: TapeHandle,
}

impl DelayRecorder {
    /// Wrap `inner`; the returned [`TapeHandle`] stays valid after the
    /// recorder (and the cluster owning it) is dropped.
    pub fn new(inner: Box<dyn DelayModel>) -> (Self, TapeHandle) {
        let m = inner.workers();
        let handle = TapeHandle { tape: Arc::new(Mutex::new(Vec::new())), m };
        (DelayRecorder { inner, handle: handle.clone() }, handle)
    }
}

impl DelayModel for DelayRecorder {
    fn sample(&mut self, worker: usize, iter: usize) -> f64 {
        let d = self.inner.sample(worker, iter);
        let mut tape = self.handle.tape.lock().unwrap();
        while tape.len() <= iter {
            let m = self.handle.m;
            // lint:allow(no-silent-nan) — never-sampled hole marker, patched by replay()
            tape.push(vec![f64::NAN; m]);
        }
        tape[iter][worker] = d;
        d
    }
    fn workers(&self) -> usize {
        self.inner.workers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::ExponentialDelay;

    #[test]
    fn record_then_replay_is_bit_identical() {
        let (mut rec, tape) =
            DelayRecorder::new(Box::new(ExponentialDelay::new(3, 0.01, 5)));
        let mut original = Vec::new();
        for t in 0..4 {
            for w in 0..3 {
                original.push(rec.sample(w, t));
            }
        }
        assert_eq!(tape.len(), 4);
        let mut replay = tape.replay(0.0);
        let mut replayed = Vec::new();
        for t in 0..4 {
            for w in 0..3 {
                replayed.push(replay.sample(w, t));
            }
        }
        assert_eq!(
            original.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            replayed.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn holes_are_patched_on_replay() {
        let (mut rec, tape) =
            DelayRecorder::new(Box::new(ExponentialDelay::new(2, 0.01, 7)));
        rec.sample(0, 0); // worker 1 never sampled at iter 0
        let mut replay = tape.replay(9.0);
        assert_eq!(replay.sample(1, 0), 9.0);
        assert!(replay.sample(0, 0).is_finite());
    }

    #[test]
    #[should_panic(expected = "empty delay tape")]
    fn empty_tape_cannot_replay() {
        let (_rec, tape) = DelayRecorder::new(Box::new(ExponentialDelay::new(2, 0.01, 9)));
        let _ = tape.replay(0.0);
    }
}
