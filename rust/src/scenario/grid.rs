//! Scheme × Solver × Scenario grid runner (the `coded-opt scenario`
//! subcommand and the golden-trace regression suite).
//!
//! Every cell runs on the deterministic virtual-clock [`SimCluster`]
//! through the `driver::Experiment` pipeline, so a grid is a pure
//! function of its [`GridSpec`]: running it twice yields bit-identical
//! [`RunOutput`]s, and [`canonical_trace`] serializes a cell's trace with
//! exact f64 bit patterns for golden-fixture comparison.

use super::Scenario;
use crate::config::{Algorithm, Scheme};
use crate::data::synth::gaussian_linear;
// The grid enumerates Scheme×Solver×Scenario cells and runs each through the driver.
// lint:allow(layer-order) — the sweep is a harness over driver::Experiment by design
use crate::driver::{self, Experiment, Problem, RunOutput};
use crate::objectives::{LassoProblem, QuadObjective, RidgeProblem};
use anyhow::{bail, Result};

/// The grid to sweep. All cells share one synthetic least-squares
/// problem generated from `(n, p, seed)`.
#[derive(Clone, Debug)]
pub struct GridSpec {
    pub schemes: Vec<Scheme>,
    pub algorithms: Vec<Algorithm>,
    pub scenarios: Vec<Scenario>,
    /// Data rows / model dimension.
    pub n: usize,
    pub p: usize,
    /// Workers / wait-for-k / redundancy.
    pub m: usize,
    pub k: usize,
    pub beta: f64,
    /// Outer iterations per cell.
    pub iters: usize,
    pub seed: u64,
    pub lambda: f64,
}

impl GridSpec {
    /// A small, fast default grid (CLI defaults; CI smoke).
    pub fn small() -> Self {
        GridSpec {
            schemes: vec![Scheme::Hadamard, Scheme::Uncoded],
            algorithms: vec![Algorithm::Gd, Algorithm::Lbfgs],
            scenarios: vec![
                Scenario::builtin("crash-rejoin").unwrap(),
                Scenario::builtin("rack-correlated").unwrap(),
            ],
            n: 64,
            p: 8,
            m: 8,
            k: 6,
            beta: 2.0,
            iters: 15,
            seed: 42,
            lambda: 0.05,
        }
    }

    /// Number of cells.
    pub fn cells(&self) -> usize {
        self.schemes.len() * self.algorithms.len() * self.scenarios.len()
    }
}

/// One completed grid cell.
pub struct GridCell {
    pub scheme: Scheme,
    pub algorithm: Algorithm,
    pub scenario: String,
    pub out: RunOutput,
}

impl GridCell {
    /// `scheme__algorithm__scenario` (stable fixture / file stem).
    pub fn stem(&self) -> String {
        format!("{}__{}__{}", self.scheme.name(), self.algorithm.name(), self.scenario)
    }

    /// Smallest per-worker participation fraction — 0% means some worker
    /// was erased in every round (e.g. a permanent straggler), values
    /// below 100% under crash scenarios show the erasure window working.
    pub fn min_participation(&self) -> f64 {
        self.out
            .participation
            .fractions()
            .into_iter()
            .fold(f64::INFINITY, f64::min)
    }
}

/// Aligned summary table of completed cells — one renderer shared by the
/// `coded-opt scenario` subcommand and the `scenario_grid` bench.
pub fn summary_table(cells: &[GridCell]) -> crate::metrics::TableWriter {
    let mut table = crate::metrics::TableWriter::new(&[
        "scheme", "solver", "scenario", "final f", "sim time", "min part",
    ]);
    for cell in cells {
        table.row(&[
            cell.scheme.name().to_string(),
            cell.algorithm.name().to_string(),
            cell.scenario.clone(),
            format!("{:.6e}", cell.out.trace.final_objective()),
            format!("{:.2}s", cell.out.trace.total_time()),
            format!("{:.0}%", 100.0 * cell.min_participation()),
        ]);
    }
    table
}

/// Run the full grid on the deterministic [`SimCluster`] engine.
///
/// Supports the synchronous wait-for-k solvers (gd, lbfgs, prox, bcd);
/// the event-queue async baselines have no round structure for the
/// scenario windows to key on and are rejected.
pub fn run_grid(spec: &GridSpec) -> Result<Vec<GridCell>> {
    anyhow::ensure!(spec.k >= 1 && spec.k <= spec.m, "grid k out of range");
    let (x, y, _) = gaussian_linear(spec.n, spec.p, 0.5, spec.seed);
    let ridge = RidgeProblem::new(x.clone(), y.clone(), spec.lambda);
    let lasso = LassoProblem::new(x.clone(), y.clone(), spec.lambda);
    let bcd_step = 0.5 * spec.n as f64 / x.gram_spectral_norm(60, spec.seed);
    let mut cells = Vec::with_capacity(spec.cells());
    for scenario in &spec.scenarios {
        for &scheme in &spec.schemes {
            for &algorithm in &spec.algorithms {
                let label =
                    format!("{}/{}/{}", scheme.name(), algorithm.name(), scenario.name);
                let exp = Experiment::new(Problem::least_squares(&x, &y))
                    .scheme(scheme)
                    .workers(spec.m)
                    .wait_for(spec.k)
                    .redundancy(spec.beta)
                    .seed(spec.seed)
                    .scenario(scenario)
                    .label(&label);
                let out = match algorithm {
                    Algorithm::Gd => exp
                        .eval(|w| (ridge.objective(w), 0.0))
                        .run(
                            driver::Gd::with_step(1.0 / ridge.smoothness())
                                .lambda(spec.lambda)
                                .iters(spec.iters),
                        )?,
                    Algorithm::Lbfgs => exp
                        .eval(|w| (ridge.objective(w), 0.0))
                        .run(driver::Lbfgs::new().lambda(spec.lambda).iters(spec.iters))?,
                    Algorithm::ProxGradient => exp
                        .eval(|w| (lasso.objective(w), 0.0))
                        .run(
                            driver::Prox::with_step(0.5 * lasso.default_step())
                                .lambda(spec.lambda)
                                .iters(spec.iters),
                        )?,
                    Algorithm::Bcd => exp
                        .eval(|w| (ridge.objective(w), 0.0))
                        .run(driver::Bcd::with_step(bcd_step).iters(spec.iters))?,
                    Algorithm::AsyncGd | Algorithm::AsyncBcd => bail!(
                        "the scenario grid drives the synchronous wait-for-k solvers \
                         (gd, lbfgs, prox, bcd); async baselines have no gather rounds"
                    ),
                };
                cells.push(GridCell {
                    scheme,
                    algorithm,
                    scenario: scenario.name.clone(),
                    out,
                });
            }
        }
    }
    Ok(cells)
}

/// Serialize one cell's run bit-exactly: each trace record's floats as
/// hex `f64::to_bits`, plus a human-readable echo for diff-reading, and
/// the final iterate. Two runs produce the same string iff the traces
/// are bit-identical.
pub fn canonical_trace(cell: &GridCell) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "# scheme={} algorithm={} scenario={} records={}\n",
        cell.scheme.name(),
        cell.algorithm.name(),
        cell.scenario,
        cell.out.trace.len()
    ));
    for r in &cell.out.trace.records {
        s.push_str(&format!(
            "{} {:016x} {:016x} {:016x} {} # t={:.6e} f={:.9e}\n",
            r.iter,
            r.time.to_bits(),
            r.objective.to_bits(),
            r.test_metric.to_bits(),
            r.k_used,
            r.time,
            r.objective
        ));
    }
    s.push_str("w");
    for v in &cell.out.w {
        s.push_str(&format!(" {:016x}", v.to_bits()));
    }
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> GridSpec {
        GridSpec {
            schemes: vec![Scheme::Hadamard],
            algorithms: vec![Algorithm::Gd],
            scenarios: vec![Scenario::builtin("crash-rejoin").unwrap()],
            n: 32,
            p: 4,
            m: 8,
            k: 6,
            beta: 2.0,
            iters: 8,
            seed: 7,
            lambda: 0.05,
        }
    }

    #[test]
    fn grid_runs_and_serializes() {
        let cells = run_grid(&tiny_spec()).unwrap();
        assert_eq!(cells.len(), 1);
        let cell = &cells[0];
        assert_eq!(cell.out.trace.len(), 8);
        assert_eq!(cell.stem(), "hadamard__gd__crash-rejoin");
        let s = canonical_trace(cell);
        assert!(s.starts_with("# scheme=hadamard"));
        assert_eq!(s.lines().count(), 1 + 8 + 1);
    }

    #[test]
    fn async_algorithms_rejected() {
        let mut spec = tiny_spec();
        spec.algorithms = vec![Algorithm::AsyncGd];
        assert!(run_grid(&spec).is_err());
    }
}
