//! Scheme × Solver × Scenario grid runner (the `coded-opt scenario`
//! subcommand and the golden-trace regression suite).
//!
//! Every cell runs on the deterministic virtual-clock [`SimCluster`]
//! through the `driver::Experiment` pipeline, so a grid is a pure
//! function of its [`GridSpec`]: running it twice yields bit-identical
//! [`RunOutput`]s, and [`canonical_trace`] serializes a cell's trace with
//! exact f64 bit patterns for golden-fixture comparison.

use super::Scenario;
// lint:allow(zone-containment) — shares bench's dependency-free JSON writer; no timing flows
use crate::bench::json::escape;
use crate::config::{Algorithm, Scheme};
// lint:allow(layer-order) — grid cells carry the driver-level k-policy selection by design
use crate::control::KPolicy;
use crate::data::synth::gaussian_linear;
// The grid enumerates Scheme×Solver×Scenario cells and runs each through the driver
// (and carries the driver-level k-policy selection for each cell).
// lint:allow(layer-order) — the sweep is a harness over driver::Experiment by design
use crate::driver::{self, Experiment, Problem, RunOutput};
use crate::objectives::{LassoProblem, QuadObjective, RidgeProblem};
use anyhow::{bail, Result};

/// The grid to sweep. All cells share one synthetic least-squares
/// problem generated from `(n, p, seed)`.
#[derive(Clone, Debug)]
pub struct GridSpec {
    pub schemes: Vec<Scheme>,
    pub algorithms: Vec<Algorithm>,
    pub scenarios: Vec<Scenario>,
    /// Data rows / model dimension.
    pub n: usize,
    pub p: usize,
    /// Workers / wait-for-k / redundancy.
    pub m: usize,
    pub k: usize,
    pub beta: f64,
    /// Outer iterations per cell.
    pub iters: usize,
    pub seed: u64,
    pub lambda: f64,
    /// Wait-for-k controller policy applied to every cell
    /// ([`crate::control`]). `KPolicy::Static` reproduces the classic
    /// fixed-k grid bit-for-bit.
    pub policy: KPolicy,
}

impl GridSpec {
    /// A small, fast default grid (CLI defaults; CI smoke).
    pub fn small() -> Self {
        GridSpec {
            schemes: vec![Scheme::Hadamard, Scheme::Uncoded],
            algorithms: vec![Algorithm::Gd, Algorithm::Lbfgs],
            scenarios: vec![
                Scenario::builtin("crash-rejoin").unwrap(),
                Scenario::builtin("rack-correlated").unwrap(),
            ],
            n: 64,
            p: 8,
            m: 8,
            k: 6,
            beta: 2.0,
            iters: 15,
            seed: 42,
            lambda: 0.05,
            policy: KPolicy::Static,
        }
    }

    /// Number of cells.
    pub fn cells(&self) -> usize {
        self.schemes.len() * self.algorithms.len() * self.scenarios.len()
    }
}

/// One completed grid cell.
pub struct GridCell {
    pub scheme: Scheme,
    pub algorithm: Algorithm,
    pub scenario: String,
    pub out: RunOutput,
}

impl GridCell {
    /// `scheme__algorithm__scenario` (stable fixture / file stem).
    pub fn stem(&self) -> String {
        format!("{}__{}__{}", self.scheme.name(), self.algorithm.name(), self.scenario)
    }

    /// Smallest per-worker participation fraction — 0% means some worker
    /// was erased in every round (e.g. a permanent straggler), values
    /// below 100% under crash scenarios show the erasure window working.
    pub fn min_participation(&self) -> f64 {
        self.out
            .participation
            .fractions()
            .into_iter()
            .fold(f64::INFINITY, f64::min)
    }
}

/// Aligned summary table of completed cells — one renderer shared by the
/// `coded-opt scenario` subcommand and the `scenario_grid` bench.
pub fn summary_table(cells: &[GridCell]) -> crate::metrics::TableWriter {
    let mut table = crate::metrics::TableWriter::new(&[
        "scheme", "solver", "scenario", "final f", "sim time", "min part",
    ]);
    for cell in cells {
        table.row(&[
            cell.scheme.name().to_string(),
            cell.algorithm.name().to_string(),
            cell.scenario.clone(),
            format!("{:.6e}", cell.out.trace.final_objective()),
            format!("{:.2}s", cell.out.trace.total_time()),
            format!("{:.0}%", 100.0 * cell.min_participation()),
        ]);
    }
    table
}

/// Run the full grid on the deterministic [`SimCluster`] engine.
///
/// Supports the synchronous wait-for-k solvers (gd, lbfgs, prox, bcd);
/// the event-queue async baselines have no round structure for the
/// scenario windows to key on and are rejected.
pub fn run_grid(spec: &GridSpec) -> Result<Vec<GridCell>> {
    anyhow::ensure!(spec.k >= 1 && spec.k <= spec.m, "grid k out of range");
    let (x, y, _) = gaussian_linear(spec.n, spec.p, 0.5, spec.seed);
    let ridge = RidgeProblem::new(x.clone(), y.clone(), spec.lambda);
    let lasso = LassoProblem::new(x.clone(), y.clone(), spec.lambda);
    let bcd_step = 0.5 * spec.n as f64 / x.gram_spectral_norm(60, spec.seed);
    let mut cells = Vec::with_capacity(spec.cells());
    for scenario in &spec.scenarios {
        for &scheme in &spec.schemes {
            for &algorithm in &spec.algorithms {
                let label =
                    format!("{}/{}/{}", scheme.name(), algorithm.name(), scenario.name);
                let exp = Experiment::new(Problem::least_squares(&x, &y))
                    .scheme(scheme)
                    .workers(spec.m)
                    .wait_for(spec.k)
                    .redundancy(spec.beta)
                    .seed(spec.seed)
                    .scenario(scenario)
                    .controller(spec.policy.clone())
                    .label(&label);
                let out = match algorithm {
                    Algorithm::Gd => exp
                        .eval(|w| (ridge.objective(w), 0.0))
                        .run(
                            driver::Gd::with_step(1.0 / ridge.smoothness())
                                .lambda(spec.lambda)
                                .iters(spec.iters),
                        )?,
                    Algorithm::Lbfgs => exp
                        .eval(|w| (ridge.objective(w), 0.0))
                        .run(driver::Lbfgs::new().lambda(spec.lambda).iters(spec.iters))?,
                    Algorithm::ProxGradient => exp
                        .eval(|w| (lasso.objective(w), 0.0))
                        .run(
                            driver::Prox::with_step(0.5 * lasso.default_step())
                                .lambda(spec.lambda)
                                .iters(spec.iters),
                        )?,
                    Algorithm::Bcd => exp
                        .eval(|w| (ridge.objective(w), 0.0))
                        .run(driver::Bcd::with_step(bcd_step).iters(spec.iters))?,
                    Algorithm::AsyncGd | Algorithm::AsyncBcd => bail!(
                        "the scenario grid drives the synchronous wait-for-k solvers \
                         (gd, lbfgs, prox, bcd); async baselines have no gather rounds"
                    ),
                };
                cells.push(GridCell {
                    scheme,
                    algorithm,
                    scenario: scenario.name.clone(),
                    out,
                });
            }
        }
    }
    Ok(cells)
}

/// Serialize one cell's run bit-exactly: each trace record's floats as
/// hex `f64::to_bits`, plus a human-readable echo for diff-reading, and
/// the final iterate. Two runs produce the same string iff the traces
/// are bit-identical.
pub fn canonical_trace(cell: &GridCell) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "# scheme={} algorithm={} scenario={} records={}\n",
        cell.scheme.name(),
        cell.algorithm.name(),
        cell.scenario,
        cell.out.trace.len()
    ));
    for r in &cell.out.trace.records {
        s.push_str(&format!(
            "{} {:016x} {:016x} {:016x} {} # t={:.6e} f={:.9e}\n",
            r.iter,
            r.time.to_bits(),
            r.objective.to_bits(),
            r.test_metric.to_bits(),
            r.k_used,
            r.time,
            r.objective
        ));
    }
    s.push_str("w");
    for v in &cell.out.w {
        s.push_str(&format!(" {:016x}", v.to_bits()));
    }
    s.push('\n');
    // Controller-steered runs additionally pin the per-round k decisions
    // and the arrival times they were derived from. Static runs emit
    // nothing here, keeping their serialization byte-identical to every
    // pre-controller fixture (and to the socket-vs-sim CI `cmp`).
    if cell.out.controller != "static" {
        s.push_str(&format!(
            "# controller={} rounds={}\n",
            cell.out.controller,
            cell.out.rounds.len()
        ));
        for r in &cell.out.rounds {
            s.push_str(&format!(
                "r{} k={}/{} live={} {:016x}",
                r.round,
                r.k_requested,
                r.k_effective,
                r.live,
                r.elapsed.to_bits()
            ));
            for a in &r.arrivals {
                s.push_str(&format!(" {:016x}", a.to_bits()));
            }
            s.push('\n');
        }
    }
    s
}

/// Schema tag of the machine-readable grid report.
pub const GRID_SCHEMA: &str = "coded-opt/grid-v1";

/// Per-cell metrics row of the `coded-opt/grid-v1` report — also the
/// raw material of the `coded-opt pareto` sweep
/// ([`crate::control::pareto`]), which attaches redundancy-robustness
/// coordinates and prunes these rows to a frontier.
#[derive(Clone, Debug)]
pub struct CellSummary {
    pub scheme: String,
    pub algorithm: String,
    pub scenario: String,
    /// Controller that steered the run (`RunOutput::controller`).
    pub policy: String,
    /// Achieved redundancy β of the built encoding.
    pub beta_achieved: f64,
    pub final_objective: f64,
    /// Simulated seconds to the last trace record.
    pub total_time: f64,
    /// Gather rounds recorded (L-BFGS: two per outer iteration).
    pub rounds: usize,
    pub mean_round_secs: f64,
    pub p99_round_secs: f64,
    /// Range of the effective k over the run's rounds.
    pub k_min: usize,
    pub k_max: usize,
    /// Simulated seconds until the objective first dropped to
    /// `ε × f(w_1)`; `None` if the run never got there.
    pub time_to_eps: Option<f64>,
    /// Trace records consumed to reach the same target.
    pub iters_to_eps: Option<usize>,
    pub min_participation: f64,
}

/// Reduce one completed cell to its `grid-v1` metrics row. `epsilon`
/// sets the convergence target as a fraction of the first recorded
/// objective (`time_to_eps` is the simulated time of the first record
/// at or below `ε × f(w_1)`).
pub fn summarize_cell(cell: &GridCell, epsilon: f64) -> CellSummary {
    let out = &cell.out;
    let mut time_to_eps = None;
    let mut iters_to_eps = None;
    if let Some(first) = out.trace.records.first() {
        let target = epsilon * first.objective;
        for (i, r) in out.trace.records.iter().enumerate() {
            if r.objective <= target {
                time_to_eps = Some(r.time);
                iters_to_eps = Some(i + 1);
                break;
            }
        }
    }
    let mut h = crate::metrics::Histogram::new();
    for r in &out.rounds {
        h.record(r.elapsed);
    }
    let (mean_round, p99_round) =
        if h.is_empty() { (0.0, 0.0) } else { (h.mean(), h.percentile(0.99)) };
    let k_eff: Vec<usize> = out.rounds.iter().map(|r| r.k_effective).collect();
    CellSummary {
        scheme: cell.scheme.name().to_string(),
        algorithm: cell.algorithm.name().to_string(),
        scenario: cell.scenario.clone(),
        policy: out.controller.clone(),
        beta_achieved: out.beta,
        final_objective: out.trace.final_objective(),
        total_time: out.trace.total_time(),
        rounds: out.rounds.len(),
        mean_round_secs: mean_round,
        p99_round_secs: p99_round,
        k_min: k_eff.iter().copied().min().unwrap_or(0),
        k_max: k_eff.iter().copied().max().unwrap_or(0),
        time_to_eps,
        iters_to_eps,
        min_participation: cell.min_participation(),
    }
}

/// Serialize a completed grid to the `coded-opt/grid-v1` JSON document
/// (hand-written like `bench-v1`; parse it back with
/// [`crate::bench::json`]). Deterministic: a pinned-seed grid yields a
/// byte-identical report.
pub fn grid_json(spec: &GridSpec, epsilon: f64, cells: &[CellSummary]) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"schema\": \"{GRID_SCHEMA}\",\n"));
    out.push_str("  \"spec\": {");
    out.push_str(&format!("\"n\": {}, ", spec.n));
    out.push_str(&format!("\"p\": {}, ", spec.p));
    out.push_str(&format!("\"workers\": {}, ", spec.m));
    out.push_str(&format!("\"k\": {}, ", spec.k));
    out.push_str(&format!("\"beta\": {:e}, ", spec.beta));
    out.push_str(&format!("\"iters\": {}, ", spec.iters));
    out.push_str(&format!("\"seed\": {}, ", spec.seed));
    out.push_str(&format!("\"lambda\": {:e}, ", spec.lambda));
    out.push_str(&format!("\"policy\": \"{}\", ", spec.policy.name()));
    out.push_str(&format!("\"epsilon\": {epsilon:e}"));
    out.push_str("},\n");
    out.push_str("  \"cells\": [\n");
    for (i, c) in cells.iter().enumerate() {
        out.push_str("    {");
        out.push_str(&format!("\"scheme\": \"{}\", ", escape(&c.scheme)));
        out.push_str(&format!("\"algorithm\": \"{}\", ", escape(&c.algorithm)));
        out.push_str(&format!("\"scenario\": \"{}\", ", escape(&c.scenario)));
        out.push_str(&format!("\"policy\": \"{}\", ", escape(&c.policy)));
        out.push_str(&format!("\"beta_achieved\": {:e}, ", c.beta_achieved));
        out.push_str(&format!("\"final_objective\": {:e}, ", c.final_objective));
        out.push_str(&format!("\"total_time\": {:e}, ", c.total_time));
        out.push_str(&format!("\"rounds\": {}, ", c.rounds));
        out.push_str(&format!("\"mean_round_secs\": {:e}, ", c.mean_round_secs));
        out.push_str(&format!("\"p99_round_secs\": {:e}, ", c.p99_round_secs));
        out.push_str(&format!("\"k_min\": {}, ", c.k_min));
        out.push_str(&format!("\"k_max\": {}, ", c.k_max));
        match c.time_to_eps {
            Some(t) => out.push_str(&format!("\"time_to_eps\": {t:e}, ")),
            None => out.push_str("\"time_to_eps\": null, "),
        }
        match c.iters_to_eps {
            Some(n) => out.push_str(&format!("\"iters_to_eps\": {n}, ")),
            None => out.push_str("\"iters_to_eps\": null, "),
        }
        out.push_str(&format!("\"min_participation\": {:e}", c.min_participation));
        out.push('}');
        out.push_str(if i + 1 < cells.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> GridSpec {
        GridSpec {
            schemes: vec![Scheme::Hadamard],
            algorithms: vec![Algorithm::Gd],
            scenarios: vec![Scenario::builtin("crash-rejoin").unwrap()],
            n: 32,
            p: 4,
            m: 8,
            k: 6,
            beta: 2.0,
            iters: 8,
            seed: 7,
            lambda: 0.05,
            policy: KPolicy::Static,
        }
    }

    #[test]
    fn grid_runs_and_serializes() {
        let cells = run_grid(&tiny_spec()).unwrap();
        assert_eq!(cells.len(), 1);
        let cell = &cells[0];
        assert_eq!(cell.out.trace.len(), 8);
        assert_eq!(cell.stem(), "hadamard__gd__crash-rejoin");
        let s = canonical_trace(cell);
        assert!(s.starts_with("# scheme=hadamard"));
        // Static runs must serialize exactly as before the controller
        // landed: header + records + w, no rounds section.
        assert_eq!(s.lines().count(), 1 + 8 + 1);
    }

    #[test]
    fn adaptive_cells_pin_their_round_decisions() {
        let mut spec = tiny_spec();
        spec.policy = KPolicy::Adaptive(Default::default());
        let cells = run_grid(&spec).unwrap();
        let cell = &cells[0];
        assert_eq!(cell.out.controller, "adaptive");
        assert_eq!(cell.out.rounds.len(), 8);
        let s = canonical_trace(cell);
        assert!(s.contains("# controller=adaptive rounds=8"));
        assert_eq!(s.lines().count(), 1 + 8 + 1 + 1 + 8, "records + w + rounds section");
        let again = canonical_trace(&run_grid(&spec).unwrap()[0]);
        assert_eq!(s, again, "adaptive grid must be bit-deterministic");
    }

    #[test]
    fn grid_json_is_schema_tagged_and_parseable() {
        let cells = run_grid(&tiny_spec()).unwrap();
        let rows: Vec<CellSummary> = cells.iter().map(|c| summarize_cell(c, 0.5)).collect();
        assert_eq!(rows[0].policy, "static");
        assert_eq!(rows[0].rounds, 8);
        assert_eq!(rows[0].k_min, 6);
        assert_eq!(rows[0].k_max, 6);
        assert!(rows[0].mean_round_secs > 0.0);
        assert!(rows[0].p99_round_secs >= rows[0].mean_round_secs);
        let text = grid_json(&tiny_spec(), 0.5, &rows);
        let root = crate::bench::json::parse(&text).unwrap();
        let obj = root.as_object().unwrap();
        let schema = crate::bench::json::get(obj, "schema").unwrap().as_str().unwrap();
        assert_eq!(schema, GRID_SCHEMA);
        let cells_v = crate::bench::json::get(obj, "cells").unwrap().as_array().unwrap();
        assert_eq!(cells_v.len(), 1);
        let row = cells_v[0].as_object().unwrap();
        assert_eq!(
            crate::bench::json::get(row, "scheme").unwrap().as_str().unwrap(),
            "hadamard"
        );
        // Determinism: the pinned-seed report is byte-stable.
        let rows2: Vec<CellSummary> =
            run_grid(&tiny_spec()).unwrap().iter().map(|c| summarize_cell(c, 0.5)).collect();
        assert_eq!(text, grid_json(&tiny_spec(), 0.5, &rows2));
    }

    #[test]
    fn async_algorithms_rejected() {
        let mut spec = tiny_spec();
        spec.algorithms = vec![Algorithm::AsyncGd];
        assert!(run_grid(&spec).is_err());
    }
}
