//! Asynchronous baselines (paper §5 comparison schemes, Figures 10–13).
//!
//! Parameter-server-style asynchrony: each worker loops
//! fetch-compute-push independently; the master applies updates as they
//! arrive, with whatever staleness the delays induce. Simulated with a
//! virtual-time event queue over the same [`crate::delay::DelayModel`]s
//! as the synchronous engines, so coded-vs-async comparisons share the
//! exact same straggler process.
//!
//! The paper's point (Figs. 12–13): under persistent stragglers the
//! async update frequencies become wildly non-uniform — slow nodes
//! contribute stale, rare updates, degrading convergence — whereas the
//! encoded scheme simply never waits for them.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::delay::{sanitize_delay, DelayModel};
use crate::linalg::Mat;
use crate::metrics::{IterRecord, Participation, Trace};

/// Ordered f64 key for the event queue. Total order (`f64::total_cmp`),
/// so a pathological delay can never panic the heap's internal
/// comparisons — the same boundary rule as the cluster engines' arrival
/// sort (delays additionally pass through [`sanitize_delay`] before
/// entering the queue, mapping NaN to +∞).
#[derive(PartialEq)]
struct Time(f64);
impl Eq for Time {}
impl PartialOrd for Time {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Time {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Config for the async gradient-descent baseline.
#[derive(Clone, Debug)]
pub struct AsyncGdConfig {
    /// Step size per update (async steps are per-worker partial steps).
    pub step: f64,
    /// ℓ₂ regularizer weight.
    pub lambda: f64,
    /// Total worker updates to apply (comparable budget: iterations × k).
    pub updates: usize,
    /// Seconds of compute per shard row (same constant as SimCluster).
    pub secs_per_unit: f64,
    /// Record a trace point every this many updates.
    pub record_every: usize,
}

/// Async data-parallel gradient descent over uncoded partitions.
///
/// `shards[i] = (X_i, y_i)`; the update applied on arrival of worker i's
/// gradient (computed at the stale iterate it fetched) is
/// `w ← w − step·(m/n)·X_iᵀ(X_i·w_stale − y_i) − step·λ·w`.
/// Called by the `driver::AsyncGd` solver.
pub(crate) fn async_gd_loop(
    shards: &[(Mat, Vec<f64>)],
    delay: &mut dyn DelayModel,
    n: usize,
    p: usize,
    cfg: &AsyncGdConfig,
    label: &str,
    eval: &super::EvalFn,
) -> super::gd::RunOutput {
    let m = shards.len();
    assert!(m > 0 && delay.workers() == m);
    let mut w = vec![0.0; p];
    // Each worker's in-flight computation: (finish_time, worker, w_stale)
    let mut queue: BinaryHeap<(Reverse<Time>, usize)> = BinaryHeap::new();
    let mut stale: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut clock;
    for i in 0..m {
        // sanitize: NaN → +∞ (the worker never completes — starvation,
        // not a heap panic; crash windows are rejected at the driver)
        let dur =
            shards[i].0.rows() as f64 * cfg.secs_per_unit + sanitize_delay(delay.sample(i, 0));
        queue.push((Reverse(Time(dur)), i));
        stale.push(w.clone());
    }
    let mut trace = Trace::new(label);
    let mut participation = Participation::new(m);
    for upd in 0..cfg.updates {
        let (Reverse(Time(t)), i) = queue.pop().expect("queue nonempty");
        clock = t;
        // gradient at the stale iterate
        let (xi, yi) = &shards[i];
        let mut resid = xi.matvec(&stale[i]);
        for (r, y) in resid.iter_mut().zip(yi) {
            *r -= y;
        }
        let mut g = xi.matvec_t(&resid);
        crate::linalg::scale(m as f64 / n as f64, &mut g);
        crate::linalg::axpy(cfg.lambda, &stale[i], &mut g);
        crate::linalg::axpy(-cfg.step, &g, &mut w);
        participation.record(&[i]);
        // worker fetches the fresh iterate and starts over
        stale[i] = w.clone();
        let dur =
            xi.rows() as f64 * cfg.secs_per_unit + sanitize_delay(delay.sample(i, upd + 1));
        queue.push((Reverse(Time(clock + dur)), i));
        if upd % cfg.record_every == 0 || upd + 1 == cfg.updates {
            let (objective, test_metric) = eval(&w);
            trace.push(IterRecord {
                iter: upd,
                time: clock,
                objective,
                test_metric,
                k_used: 1,
            });
        }
    }
    super::gd::RunOutput { trace, w, participation }
}

/// Config for the async BCD baseline (model parallelism).
#[derive(Clone, Debug)]
pub struct AsyncBcdConfig {
    pub step: f64,
    pub lambda: f64,
    pub updates: usize,
    pub secs_per_unit: f64,
    pub record_every: usize,
}

/// Async block coordinate descent: worker i owns uncoded column block
/// `A_i = X_{:,Bi}` and coordinates `w_i`; on each completion it applies
/// `w_i ← w_i − step·(A_iᵀ∇φ(u_stale) + 2λw_i)` against the aggregate it
/// fetched before computing (staleness grows with its delay).
/// Called by the `driver::AsyncBcd` solver.
pub(crate) fn async_bcd_loop(
    blocks: &[Mat],
    grad_phi: &dyn Fn(&[f64]) -> Vec<f64>,
    n: usize,
    cfg: &AsyncBcdConfig,
    delay: &mut dyn DelayModel,
    label: &str,
    eval_w_blocks: &dyn Fn(&[Vec<f64>]) -> (f64, f64),
) -> (Trace, Vec<Vec<f64>>, Participation) {
    let m = blocks.len();
    assert_eq!(delay.workers(), m);
    let mut v: Vec<Vec<f64>> = blocks.iter().map(|b| vec![0.0; b.cols()]).collect();
    // master-side aggregate u_total = Σ A_i v_i
    let mut u_total = vec![0.0; n];
    // worker i's snapshot of u_total − A_i v_i taken at fetch time
    let mut fetched: Vec<Vec<f64>> = vec![vec![0.0; n]; m];
    let mut queue: BinaryHeap<(Reverse<Time>, usize)> = BinaryHeap::new();
    let mut clock;
    for i in 0..m {
        let dur = (blocks[i].rows() * blocks[i].cols()) as f64 / 1000.0 * cfg.secs_per_unit
            + sanitize_delay(delay.sample(i, 0));
        queue.push((Reverse(Time(dur)), i));
    }
    let mut trace = Trace::new(label);
    let mut participation = Participation::new(m);
    for upd in 0..cfg.updates {
        let (Reverse(Time(t)), i) = queue.pop().expect("queue nonempty");
        clock = t;
        // gradient of block i at (stale z̃ fetched earlier, current v_i)
        let mut xw = blocks[i].matvec(&v[i]);
        crate::linalg::axpy(1.0, &fetched[i], &mut xw);
        let gphi = grad_phi(&xw);
        let mut grad = blocks[i].matvec_t(&gphi);
        crate::linalg::axpy(2.0 * cfg.lambda, &v[i], &mut grad);
        // apply to owned block; update aggregate with the delta
        let old_contrib = blocks[i].matvec(&v[i]);
        crate::linalg::axpy(-cfg.step, &grad, &mut v[i]);
        let new_contrib = blocks[i].matvec(&v[i]);
        for ((tot, o), nw) in u_total.iter_mut().zip(&old_contrib).zip(&new_contrib) {
            *tot += nw - o;
        }
        participation.record(&[i]);
        // fetch fresh aggregate-minus-own and restart
        let mut z = u_total.clone();
        let own = blocks[i].matvec(&v[i]);
        for (zv, o) in z.iter_mut().zip(&own) {
            *zv -= o;
        }
        fetched[i] = z;
        let dur = (blocks[i].rows() * blocks[i].cols()) as f64 / 1000.0 * cfg.secs_per_unit
            + sanitize_delay(delay.sample(i, upd + 1));
        queue.push((Reverse(Time(clock + dur)), i));
        if upd % cfg.record_every == 0 || upd + 1 == cfg.updates {
            let (objective, test_metric) = eval_w_blocks(&v);
            trace.push(IterRecord { iter: upd, time: clock, objective, test_metric, k_used: 1 });
        }
    }
    (trace, v, participation)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_linear;
    use crate::delay::{BackgroundTasksDelay, NoDelay};
    use crate::encoding::partition_bounds;
    use crate::objectives::{QuadObjective, RidgeProblem};

    fn uncoded_shards(x: &Mat, y: &[f64], m: usize) -> Vec<(Mat, Vec<f64>)> {
        let bounds = partition_bounds(x.rows(), m);
        bounds
            .windows(2)
            .map(|w| (x.row_block(w[0], w[1]), y[w[0]..w[1]].to_vec()))
            .collect()
    }

    #[test]
    fn async_gd_converges_without_delays() {
        let (x, y, _) = gaussian_linear(64, 8, 0.2, 3);
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
        let f_star = prob.objective(&prob.solve_exact());
        let shards = uncoded_shards(&x, &y, 4);
        let mut delay = NoDelay::new(4);
        let cfg = AsyncGdConfig {
            step: 0.3 / prob.smoothness(),
            lambda: 0.05,
            updates: 3000,
            secs_per_unit: 1e-4,
            record_every: 100,
        };
        let out = async_gd_loop(&shards, &mut delay, 64, 8, &cfg, "async", &|w| {
            (prob.objective(w), 0.0)
        });
        let sub = (out.trace.final_objective() - f_star) / f_star;
        assert!(sub < 5e-3, "subopt {sub}");
    }

    #[test]
    fn async_participation_skewed_under_background_tasks() {
        // Figure 13's phenomenon: power-law background load → power-law
        // update frequencies.
        let (x, y, _) = gaussian_linear(64, 8, 0.2, 5);
        let shards = uncoded_shards(&x, &y, 16);
        let mut delay = BackgroundTasksDelay::new(16, 1.5, 50, 0.05, 7);
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
        let cfg = AsyncGdConfig {
            step: 0.1 / prob.smoothness(),
            lambda: 0.05,
            updates: 2000,
            secs_per_unit: 1e-4,
            record_every: 500,
        };
        let out = async_gd_loop(&shards, &mut delay, 64, 8, &cfg, "async-bg", &|w| {
            (prob.objective(w), 0.0)
        });
        assert!(
            out.participation.imbalance() > 0.3,
            "imbalance {}",
            out.participation.imbalance()
        );
    }

    #[test]
    fn async_bcd_decreases_objective() {
        let (x, y, _) = gaussian_linear(40, 12, 0.1, 9);
        let bounds = partition_bounds(12, 4);
        let blocks: Vec<Mat> = bounds
            .windows(2)
            .map(|w| {
                let idx: Vec<usize> = (w[0]..w[1]).collect();
                x.select_cols(&idx)
            })
            .collect();
        let yc = y.clone();
        let n = 40;
        let grad_phi = move |u: &[f64]| -> Vec<f64> {
            u.iter().zip(&yc).map(|(ui, yi)| (ui - yi) / n as f64).collect()
        };
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
        let f0 = prob.objective(&[0.0; 12]);
        let step = 0.5 * 40.0 / x.gram_spectral_norm(60, 4);
        let cfg = AsyncBcdConfig {
            step,
            lambda: 0.0,
            updates: 800,
            secs_per_unit: 1e-4,
            record_every: 100,
        };
        let mut delay = NoDelay::new(4);
        let eval = |v: &[Vec<f64>]| -> (f64, f64) {
            // uncoded: w is the concatenation of blocks
            let w: Vec<f64> = v.iter().flatten().copied().collect();
            (prob.objective(&w), 0.0)
        };
        let (trace, _, _) = async_bcd_loop(&blocks, &grad_phi, 40, &cfg, &mut delay, "abcd", &eval);
        assert!(trace.final_objective() < 0.2 * f0, "{} vs {f0}", trace.final_objective());
    }
}
