//! Encoded L-BFGS (paper §2.1 "Limited-memory-BFGS", §3.3, Theorem 4).
//!
//! The two modifications vs. textbook L-BFGS, both from the paper:
//!
//! 1. **Overlap curvature pairs** — the Hessian-difference vector is
//!    formed only from gradient components common to two consecutive
//!    iterations: `r_t = m/(2n|A_t∩A_{t−1}|)·Σ_{i∈A_t∩A_{t−1}}
//!    (∇f_i(w_t) − ∇f_i(w_{t−1}))` — comparing *different* worker sets
//!    would alias the encoding difference into spurious curvature.
//! 2. **Exact line search over D_t** — each worker returns `‖S̄_iXd‖²`;
//!    the master waits for the fastest k (a set D_t generally ≠ A_t) and
//!    steps `α = −ρ·dᵀg̃ / dᵀX̃_Dᵀ X̃_D d` (eq. 3), ρ < 1 a back-off.
//!
//! Each outer iteration costs two gather rounds (gradient + line search).

use std::collections::BTreeMap;

use super::gd::RunOutput;
use super::{EvalFn, GradAssembler, RoundCtl, KIND_GRADIENT, KIND_LINESEARCH};
use crate::cluster::{Gather, Task};
use crate::linalg::{axpy, dot, scale, sub};
use crate::metrics::{IterRecord, Participation, Trace};

/// Configuration for the encoded-L-BFGS master loop (driven by
/// `driver::Lbfgs`).
#[derive(Clone, Debug)]
pub struct LbfgsConfig {
    pub k: usize,
    pub iters: usize,
    /// ℓ₂ regularizer weight (`h(w) = ‖w‖²/2` with weight λ; the paper
    /// requires a quadratic regularizer for L-BFGS).
    pub lambda: f64,
    /// Memory length σ.
    pub memory: usize,
    /// Line-search back-off ρ ∈ (0, 1).
    pub rho: f64,
    pub w0: Option<Vec<f64>>,
}

impl Default for LbfgsConfig {
    fn default() -> Self {
        LbfgsConfig { k: 1, iters: 100, lambda: 0.0, memory: 10, rho: 0.9, w0: None }
    }
}

/// Curvature pair (u_j, r_j, 1/(r_jᵀu_j)).
struct Pair {
    u: Vec<f64>,
    r: Vec<f64>,
    rho: f64,
}

/// Two-loop recursion: d = −B·g with B built from the pair history.
fn two_loop(pairs: &[Pair], g: &[f64]) -> Vec<f64> {
    let mut q = g.to_vec();
    let mut alphas = Vec::with_capacity(pairs.len());
    for p in pairs.iter().rev() {
        let a = p.rho * dot(&p.u, &q);
        axpy(-a, &p.r, &mut q);
        alphas.push(a);
    }
    // Initial scaling γ = uᵀr / rᵀr from the newest pair.
    if let Some(p) = pairs.last() {
        let gamma = dot(&p.u, &p.r) / dot(&p.r, &p.r).max(1e-300);
        scale(gamma, &mut q);
    }
    for (p, &a) in pairs.iter().zip(alphas.iter().rev()) {
        let b = p.rho * dot(&p.r, &q);
        axpy(a - b, &p.u, &mut q);
    }
    scale(-1.0, &mut q);
    q
}

/// Encoded L-BFGS master loop on a gathered cluster. Called by the
/// `driver::Lbfgs` solver.
///
/// Both of an iteration's gather rounds (gradient and line search) go
/// through `ctl`, so an adaptive wait-for-k policy observes and adjusts
/// at round granularity — twice per outer iteration.
pub(crate) fn lbfgs_loop(
    cluster: &mut dyn Gather,
    assembler: &GradAssembler,
    cfg: &LbfgsConfig,
    ctl: &mut RoundCtl<'_>,
    label: &str,
    eval: &EvalFn,
) -> RunOutput {
    let m = cluster.workers();
    assert!(cfg.k >= 1 && cfg.k <= m);
    assert!(cfg.rho > 0.0 && cfg.rho < 1.0, "ρ must be in (0,1)");
    let p_dim = assembler.p;
    let mut w = cfg.w0.clone().unwrap_or_else(|| vec![0.0; p_dim]);
    let mut trace = Trace::new(label);
    let mut participation = Participation::new(m);
    let mut pairs: Vec<Pair> = Vec::new();
    // Previous round's per-worker raw partial gradients r_i (the paper's
    // ∇f_i up to the factor 2), and the previous iterate.
    let mut prev_partials: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    let mut w_prev: Vec<f64> = w.clone();

    for t in 0..cfg.iters {
        // ---- Round 1: gradients over A_t.
        let rr = ctl.gather(cluster, &mut |_| Task {
            iter: 2 * t,
            kind: KIND_GRADIENT,
            payload: w.clone(),
            aux: vec![],
        });
        participation.record(&rr.active_set());
        let mut g = assembler.assemble(&rr.responses);
        axpy(cfg.lambda, &w, &mut g);

        // ---- Curvature pair from the overlap A_t ∩ A_{t−1}.
        if t > 0 {
            let mut overlap_sum = vec![0.0; p_dim];
            let mut overlap = 0usize;
            for resp in &rr.responses {
                if let Some(prev) = prev_partials.get(&resp.worker) {
                    let diff = sub(&resp.payload, prev);
                    axpy(1.0, &diff, &mut overlap_sum);
                    overlap += 1;
                }
            }
            if overlap > 0 {
                // r_t = m/(n·|overlap|)·Σ (r_i(t) − r_i(t−1)) + λ·u_t
                let mut r = overlap_sum;
                scale(m as f64 / (assembler.n as f64 * overlap as f64), &mut r);
                let u = sub(&w, &w_prev);
                axpy(cfg.lambda, &u, &mut r);
                let ru = dot(&r, &u);
                // Curvature (secant) condition — guaranteed by Lemma 3
                // when the overlap matrix is full rank, checked here for
                // the η < ½+1/(2β) regime the paper warns about.
                if ru > 1e-12 * dot(&u, &u) {
                    pairs.push(Pair { u, rho: 1.0 / ru, r });
                    if pairs.len() > cfg.memory {
                        pairs.remove(0);
                    }
                }
            }
        }
        prev_partials = rr.responses.iter().map(|r| (r.worker, r.payload.clone())).collect();
        w_prev = w.clone();

        // ---- Descent direction.
        let d = if pairs.is_empty() {
            let mut d = g.clone();
            scale(-1.0, &mut d);
            d
        } else {
            two_loop(&pairs, &g)
        };

        // ---- Round 2: exact line search over D_t (eq. 3).
        let ls = ctl.gather(cluster, &mut |_| Task {
            iter: 2 * t + 1,
            kind: KIND_LINESEARCH,
            payload: d.clone(),
            aux: vec![],
        });
        let quad = assembler.assemble_quadform(&ls.responses) + cfg.lambda * dot(&d, &d);
        let dg = dot(&d, &g);
        let alpha = if quad > 1e-300 { -cfg.rho * dg / quad } else { 0.0 };
        // Descent safety: if the two-loop direction lost descent (can
        // happen transiently under adversarial erasures), fall back.
        let alpha = if alpha.is_finite() && alpha > 0.0 { alpha } else { 0.0 };
        axpy(alpha, &d, &mut w);

        let (objective, test_metric) = eval(&w);
        trace.push(IterRecord {
            iter: t,
            time: cluster.clock(),
            objective,
            test_metric,
            k_used: rr.responses.len(),
        });
    }
    RunOutput { trace, w, participation }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::SimCluster;
    use crate::config::Scheme;
    use crate::coordinator::build_data_parallel;
    use crate::data::synth::gaussian_linear;
    use crate::delay::{AdversarialDelay, MixtureDelay, NoDelay};
    use crate::objectives::{QuadObjective, RidgeProblem};

    fn lb_cfg(k: usize, iters: usize, lambda: f64) -> LbfgsConfig {
        LbfgsConfig { k, iters, lambda, memory: 10, rho: 0.9, w0: None }
    }

    #[test]
    fn two_loop_identity_memory_empty() {
        let d = two_loop(&[], &[1.0, -2.0]);
        assert_eq!(d, vec![-1.0, 2.0]);
    }

    #[test]
    fn two_loop_matches_exact_inverse_for_quadratic() {
        // For f = ½wᵀAw with enough exact pairs, B ≈ A⁻¹ along the
        // explored subspace: B·(A·u) must return ≈ u.
        let a = crate::linalg::Mat::from_vec(2, 2, vec![2.0, 0.0, 0.0, 0.5]);
        let pairs: Vec<Pair> = [(1.0, 0.0), (0.0, 1.0)]
            .iter()
            .map(|&(x, y)| {
                let u = vec![x, y];
                let r = a.matvec(&u);
                let rho = 1.0 / dot(&r, &u);
                Pair { u, r, rho }
            })
            .collect();
        let g = a.matvec(&[3.0, -4.0]); // = A·w for w=(3,−4)
        let d = two_loop(&pairs, &g);
        // d = −B·A·w ≈ −w
        crate::testutil::assert_allclose(&d, &[-3.0, 4.0], 1e-9, "newton step");
    }

    #[test]
    fn converges_fast_with_full_gather() {
        let (x, y, _) = gaussian_linear(96, 12, 0.3, 3);
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
        let f_star = prob.objective(&prob.solve_exact());
        let dp = build_data_parallel(&x, &y, Scheme::Hadamard, 8, 2.0, 3).unwrap();
        let asm = dp.assembler.clone();
        let mut cluster = SimCluster::new(dp.workers, Box::new(NoDelay::new(8)));
        let out = lbfgs_loop(
            &mut cluster,
            &asm,
            &lb_cfg(8, 60, 0.05),
            &mut RoundCtl::fixed(8),
            "lbfgs",
            &|w| (prob.objective(w), 0.0),
        );
        let sub = (out.trace.final_objective() - f_star) / f_star;
        assert!(sub < 1e-8, "subopt={sub}");
    }

    #[test]
    fn lbfgs_beats_gd_iteration_count() {
        let (x, y, _) = gaussian_linear(128, 16, 0.2, 5);
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
        let f_star = prob.objective(&prob.solve_exact());
        let target = 1.001 * f_star;
        // L-BFGS run
        let dp = build_data_parallel(&x, &y, Scheme::Hadamard, 8, 2.0, 5).unwrap();
        let asm = dp.assembler.clone();
        let mut cluster = SimCluster::new(dp.workers, Box::new(NoDelay::new(8)));
        let out_l = lbfgs_loop(
            &mut cluster,
            &asm,
            &lb_cfg(8, 80, 0.05),
            &mut RoundCtl::fixed(8),
            "l",
            &|w| (prob.objective(w), 0.0),
        );
        // GD run, same budget
        let dp2 = build_data_parallel(&x, &y, Scheme::Hadamard, 8, 2.0, 5).unwrap();
        let asm2 = dp2.assembler.clone();
        let mut cluster2 = SimCluster::new(dp2.workers, Box::new(NoDelay::new(8)));
        let step = 1.0 / prob.smoothness();
        let cfg = crate::coordinator::GdConfig { k: 8, step, iters: 80, lambda: 0.05, w0: None };
        let out_g = crate::coordinator::gd::gd_loop(
            &mut cluster2,
            &asm2,
            &cfg,
            &mut RoundCtl::fixed(8),
            "g",
            &|w| (prob.objective(w), 0.0),
        );
        let it_l = out_l.trace.records.iter().position(|r| r.objective <= target);
        let it_g = out_g.trace.records.iter().position(|r| r.objective <= target);
        assert!(it_l.is_some(), "L-BFGS never hit target");
        match (it_l, it_g) {
            (Some(l), Some(g)) => assert!(l < g, "L-BFGS {l} iters !< GD {g}"),
            (Some(_), None) => {} // GD never converged in budget: fine
            _ => unreachable!(),
        }
    }

    #[test]
    fn stable_under_bimodal_stragglers_where_uncoded_fails() {
        // The Figure-7 phenomenon: for small η uncoded L-BFGS can diverge
        // or stall; Hadamard-coded converges stably.
        let (x, y, _) = gaussian_linear(128, 20, 0.5, 7);
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
        let f_star = prob.objective(&prob.solve_exact());
        let mut subopts = std::collections::BTreeMap::new();
        for scheme in [Scheme::Hadamard, Scheme::Uncoded] {
            let dp = build_data_parallel(&x, &y, scheme, 16, 2.0, 9).unwrap();
            let asm = dp.assembler.clone();
            let delay = MixtureDelay::paper_bimodal(16, 11);
            let mut cluster = SimCluster::new(dp.workers, Box::new(delay));
            let out = lbfgs_loop(
                &mut cluster,
                &asm,
                &lb_cfg(6, 50, 0.05),
                &mut RoundCtl::fixed(6),
                "x",
                &|w| (prob.objective(w), 0.0),
            );
            subopts.insert(
                format!("{scheme:?}"),
                (out.trace.final_objective() - f_star) / f_star,
            );
        }
        assert!(
            subopts["Hadamard"] < 0.05,
            "hadamard subopt {}",
            subopts["Hadamard"]
        );
        assert!(
            subopts["Hadamard"] < subopts["Uncoded"],
            "coded {} !< uncoded {}",
            subopts["Hadamard"],
            subopts["Uncoded"]
        );
    }

    #[test]
    fn hessian_pairs_only_from_overlap() {
        // Adversarial alternating pattern: A_t ∩ A_{t−1} can be small;
        // the run must remain stable (no NaN, no blow-up).
        let (x, y, _) = gaussian_linear(64, 8, 0.3, 13);
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
        let dp = build_data_parallel(&x, &y, Scheme::Haar, 8, 2.0, 13).unwrap();
        let asm = dp.assembler.clone();
        let delay = AdversarialDelay::rotating(8, 0.5, 1e6);
        let mut cluster = SimCluster::new(dp.workers, Box::new(delay));
        let out = lbfgs_loop(
            &mut cluster,
            &asm,
            &lb_cfg(4, 60, 0.05),
            &mut RoundCtl::fixed(4),
            "rot",
            &|w| (prob.objective(w), 0.0),
        );
        assert!(out.trace.final_objective().is_finite());
        assert!(out.trace.bounded_by(1.2));
    }
}
