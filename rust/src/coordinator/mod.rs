//! The encoded distributed optimization coordinator — the paper's system
//! contribution.
//!
//! Data-parallel algorithms (encoded objective
//! `f̃(w) = 1/(2n)·‖S(Xw−y)‖² + λh(w)`, Algorithms 1–2):
//! - [`gd`]    — encoded gradient descent (Theorem 2),
//! - [`lbfgs`] — encoded L-BFGS with overlap curvature pairs and exact
//!   line search over the fastest-k set D_t (Theorem 4),
//! - [`prox`]  — encoded proximal gradient / ISTA (Theorem 5).
//!
//! Model-parallel:
//! - [`bcd`]   — encoded block coordinate descent (Algorithms 3–4,
//!   Theorem 6).
//!
//! Baselines:
//! - uncoded / replication — via [`GradAssembler`] over identity
//!   encodings and [`crate::encoding::ReplicationMap`],
//! - [`asynchronous`] — parameter-server-style async gradient descent and
//!   async BCD (the Figures 10–13 comparison).
//!
//! Most callers should not wire these pieces by hand: the
//! [`crate::driver`] module owns the problem → encoding → cluster →
//! solve → evaluate pipeline behind the `Experiment` builder, and its
//! docs state the normalization convention (`S̄ᵀS̄ = I` Parseval shards,
//! `m/k` partial-sum rescaling) that this module implements.

pub mod asynchronous;
pub mod bcd;
pub mod gd;
pub mod lbfgs;
pub mod mf;
pub mod prox;
pub mod schedule;

pub use gd::{GdConfig, RunOutput};
pub use lbfgs::LbfgsConfig;
pub use prox::ProxConfig;

use crate::cluster::{Gather, RoundResult, Task, WorkerNode};
use crate::config::Scheme;
use crate::encoding::{EncodingOp, ReplicationMap};
use crate::linalg::{Mat, Precision, PrecisionMat};
use crate::metrics::RoundStats;
use anyhow::Result;

/// Task kinds understood by [`QuadWorker`].
pub const KIND_GRADIENT: u32 = 0;
pub const KIND_LINESEARCH: u32 = 1;
/// Task kind understood by BCD workers.
pub const KIND_BCD_STEP: u32 = 2;

/// Data-parallel worker: stores its encoded shard `(S̄_iX, S̄_iy)` and
/// serves gradient / line-search requests.
///
/// When a PJRT runtime handle is attached (see [`crate::runtime`]), the
/// gradient hot path executes the AOT-compiled JAX/Pallas artifact;
/// otherwise it runs the native rust kernel. Both compute
/// `r_i = (S̄_iX)ᵀ(S̄_iX·w − S̄_iy)`.
///
/// The shard matrix is stored at a [`Precision`]: `F64` by default
/// (bit-determinism contract), or `F32` storage with f64 accumulation
/// (half the shard memory traffic, ≤ 1e-5 tolerance vs the f64 referee
/// — see [`crate::linalg::precision`]). Targets `S̄_iy` always stay f64.
pub struct QuadWorker {
    /// Encoded shard S̄_iX (rows_i × p) at its storage precision.
    pub sx: PrecisionMat,
    /// Encoded targets S̄_i y.
    pub sy: Vec<f64>,
    /// Optional PJRT executor for the gradient kernel.
    // When absent (the default and the CI path) the same kernels run in-process,
    // bit-for-bit, so no unsafe reaches the trace path.
    // lint:allow(zone-containment) — optional accelerator handle, not hot-loop unsafe
    pub pjrt: Option<crate::runtime::GradExecutor>,
    /// Residual scratch buffer (hot-path allocation avoidance; see
    /// EXPERIMENTS.md §Perf iteration 5).
    resid: Vec<f64>,
}

impl QuadWorker {
    pub fn new(sx: Mat, sy: Vec<f64>) -> Self {
        QuadWorker::with_precision(PrecisionMat::F64(sx), sy)
    }

    /// Box a shard already stored at its target precision.
    pub fn with_precision(sx: PrecisionMat, sy: Vec<f64>) -> Self {
        assert_eq!(sx.rows(), sy.len());
        let rows = sx.rows();
        QuadWorker { sx, sy, pjrt: None, resid: vec![0.0; rows] }
    }

    /// Native gradient kernel: r = S̄Xᵀ(S̄X·w − S̄y), residual computed
    /// into the reusable scratch buffer by the fused (and chunk-parallel,
    /// see `linalg::par`) `matvec_sub` kernel — bit-identical to the
    /// sequential dot-minus-y sweep at any thread count.
    fn native_gradient(&mut self, w: &[f64]) -> Vec<f64> {
        self.sx.matvec_sub(w, &self.sy, &mut self.resid);
        self.sx.matvec_t(&self.resid)
    }
}

impl WorkerNode for QuadWorker {
    fn process(&mut self, task: &Task) -> Vec<f64> {
        match task.kind {
            KIND_GRADIENT => {
                if let Some(exec) = &mut self.pjrt {
                    if let Ok(g) = exec.gradient(&task.payload) {
                        return g;
                    }
                    // artifact shape mismatch → native fallback
                }
                self.native_gradient(&task.payload)
            }
            KIND_LINESEARCH => {
                let xd = self.sx.matvec(&task.payload);
                vec![crate::linalg::dot(&xd, &xd)]
            }
            other => panic!("QuadWorker: unknown task kind {other}"),
        }
    }

    fn cost(&self) -> f64 {
        // relative compute ∝ shard flops
        (self.sx.rows().max(1)) as f64
    }
}

/// Master-side bookkeeping to turn k worker responses into an unbiased
/// gradient estimate, uniform across uncoded / replication / coded
/// schemes.
#[derive(Clone, Debug)]
pub struct GradAssembler {
    /// Original data rows n (gradient normalization).
    pub n: usize,
    /// Model dimension p.
    pub p: usize,
    /// worker → partition map (identity for coded schemes).
    pub map: ReplicationMap,
}

impl GradAssembler {
    /// Worker → response index, built once per round. The chosen-worker
    /// loops below would otherwise rescan the response list per chosen
    /// worker — O(k²) payload lookups for a k-response round.
    fn index_responses(&self, responses: &[crate::cluster::Response]) -> Vec<Option<usize>> {
        let mut by_worker: Vec<Option<usize>> = vec![None; self.map.workers()];
        for (i, r) in responses.iter().enumerate() {
            if by_worker[r.worker].is_none() {
                by_worker[r.worker] = Some(i);
            }
        }
        by_worker
    }

    /// Combine responses (arrival order) into `(m_eff/|distinct|)·(1/n)·Σ r`.
    pub fn assemble(&self, responses: &[crate::cluster::Response]) -> Vec<f64> {
        let order: Vec<usize> = responses.iter().map(|r| r.worker).collect();
        let chosen = self.map.resolve(&order);
        let by_worker = self.index_responses(responses);
        let mut g = vec![0.0; self.p];
        for &(_, w) in &chosen {
            let resp = &responses[by_worker[w].unwrap()];
            debug_assert_eq!(resp.payload.len(), self.p, "gradient payload length");
            crate::linalg::axpy(1.0, &resp.payload, &mut g);
        }
        let scale = self.map.partitions() as f64 / (chosen.len().max(1) as f64 * self.n as f64);
        crate::linalg::scale(scale, &mut g);
        g
    }

    /// Combine line-search responses `‖S̄_iX·d‖²` into the quadratic form
    /// estimate `dᵀ(XᵀX/n)d ≈ (m_eff/|distinct|)·(1/n)·Σ ‖·‖²`.
    pub fn assemble_quadform(&self, responses: &[crate::cluster::Response]) -> f64 {
        let order: Vec<usize> = responses.iter().map(|r| r.worker).collect();
        let chosen = self.map.resolve(&order);
        let by_worker = self.index_responses(responses);
        let mut q = 0.0;
        for &(_, w) in &chosen {
            q += responses[by_worker[w].unwrap()].payload[0];
        }
        q * self.map.partitions() as f64 / (chosen.len().max(1) as f64 * self.n as f64)
    }
}

/// Fully-assembled data-parallel problem: encoded worker boxes plus the
/// assembler metadata.
pub struct DataParallel {
    pub workers: Vec<Box<dyn WorkerNode>>,
    pub assembler: GradAssembler,
    pub scheme: Scheme,
    /// Achieved redundancy.
    pub beta: f64,
    /// Workers whose shard shape matched an AOT artifact and got a PJRT
    /// executor attached (0 when built without a runtime index).
    pub pjrt_attached: usize,
}

/// Build data-parallel workers for (X, y) under a scheme.
///
/// - Coded schemes: worker i stores `(S̄_iX, S̄_iy)` with `S̄ = S/√β`.
/// - Uncoded: S = I row-partitioned.
/// - Replication: `⌊β⌋`-fold duplication of the m/⌊β⌋ uncoded partitions.
pub fn build_data_parallel(
    x: &Mat,
    y: &[f64],
    scheme: Scheme,
    m: usize,
    beta: f64,
    seed: u64,
) -> Result<DataParallel> {
    build_data_parallel_with_runtime(x, y, scheme, m, beta, seed, Precision::F64, None)
}

/// Parseval-normalize encoded blocks and box them into [`QuadWorker`]s,
/// attaching PJRT executors where the artifact index matches. The ONE
/// assembly path shared by the in-memory and streamed builders — the
/// sharded-vs-in-memory bit-identity contract rides on both going
/// through identical code from the encoded blocks onward.
fn assemble_coded_workers(
    sx_blocks: Vec<Mat>,
    sy_blocks: Vec<Vec<f64>>,
    norm: f64,
    precision: Precision,
    runtime: Option<&crate::runtime::ArtifactIndex>,
) -> (Vec<Box<dyn WorkerNode>>, usize) {
    let mut pjrt_attached = 0;
    let workers: Vec<Box<dyn WorkerNode>> = sx_blocks
        .into_iter()
        .zip(sy_blocks)
        .map(|(mut sx, mut sy)| {
            // Normalize in f64, THEN demote: the stored f32 values are
            // the rounding of the exact normalized shard, not a product
            // of rounded factors.
            sx.scale_inplace(norm);
            crate::linalg::scale(norm, &mut sy);
            let mut worker = QuadWorker::with_precision(PrecisionMat::demote(sx, precision), sy);
            if let Some(idx) = runtime {
                // The AOT artifacts take f64 shard buffers; f32-storage
                // workers always run the native widening kernels.
                if let PrecisionMat::F64(m) = &worker.sx {
                    worker.pjrt = crate::runtime::GradExecutor::from_index(idx, m, &worker.sy);
                    pjrt_attached += usize::from(worker.pjrt.is_some());
                }
            }
            Box::new(worker) as Box<dyn WorkerNode>
        })
        .collect();
    (workers, pjrt_attached)
}

/// Duplicate per-partition shards onto their replica holders (see
/// [`ReplicationMap`]) — shared by the in-memory and streamed
/// replication builders.
fn assemble_replicated_workers(
    shards: &[(Mat, Vec<f64>)],
    map: &ReplicationMap,
    m: usize,
    precision: Precision,
    runtime: Option<&crate::runtime::ArtifactIndex>,
) -> (Vec<Box<dyn WorkerNode>>, usize) {
    let mut pjrt_attached = 0;
    let workers: Vec<Box<dyn WorkerNode>> = (0..m)
        .map(|w| {
            let p = map.partition_of(w);
            let sx = PrecisionMat::demote(shards[p].0.clone(), precision);
            let mut worker = QuadWorker::with_precision(sx, shards[p].1.clone());
            if let Some(idx) = runtime {
                if let PrecisionMat::F64(mat) = &worker.sx {
                    worker.pjrt = crate::runtime::GradExecutor::from_index(idx, mat, &worker.sy);
                    pjrt_attached += usize::from(worker.pjrt.is_some());
                }
            }
            Box::new(worker) as Box<dyn WorkerNode>
        })
        .collect();
    (workers, pjrt_attached)
}

/// [`build_data_parallel`] with an optional AOT artifact index: workers
/// whose shard shape matches a compiled `quad_grad` artifact execute
/// their gradient hot path on PJRT (lazy per-thread compilation); the
/// rest use the native kernel.
///
/// `precision` selects the shard storage mode: [`Precision::F64`]
/// (default everywhere else) keeps the bit-determinism contract;
/// [`Precision::F32`] stores each worker's `S̄_iX` in single precision
/// (accumulation stays f64) and disables the PJRT attach for those
/// workers, since the AOT artifacts expect f64 buffers.
#[allow(clippy::too_many_arguments)]
pub fn build_data_parallel_with_runtime(
    x: &Mat,
    y: &[f64],
    scheme: Scheme,
    m: usize,
    beta: f64,
    seed: u64,
    precision: Precision,
    runtime: Option<&crate::runtime::ArtifactIndex>,
) -> Result<DataParallel> {
    let n = x.rows();
    anyhow::ensure!(y.len() == n, "X/y mismatch");
    match scheme {
        Scheme::Replication => {
            let r = beta.round() as usize;
            anyhow::ensure!(r >= 1 && m % r == 0, "replication needs r|m (r={r}, m={m})");
            let map = ReplicationMap::new(m, r);
            let parts = map.partitions();
            let enc = EncodingOp::identity(n, parts);
            // partition p's shard, duplicated to each holder (identity
            // blocks are O(rows) CSR slices produced on demand)
            let shards: Vec<(Mat, Vec<f64>)> = (0..parts)
                .map(|p| {
                    let block = enc.row_block(p);
                    (block.encode_mat(x), block.matvec(y))
                })
                .collect();
            let (workers, pjrt_attached) =
                assemble_replicated_workers(&shards, &map, m, precision, runtime);
            Ok(DataParallel {
                workers,
                assembler: GradAssembler { n, p: x.cols(), map },
                scheme,
                beta: r as f64,
                pjrt_attached,
            })
        }
        _ => {
            let enc = EncodingOp::build(scheme, n, m, beta, seed)?;
            let norm = 1.0 / enc.beta.sqrt();
            // Structure-aware encode: FWHT / CSR full-S paths where the
            // scheme has them, per-use regenerated dense blocks as the
            // fallback — no dense row of S is ever stored.
            let sx_blocks = enc.encode_data(x);
            let sy_blocks = enc.encode_vec(y);
            let (workers, pjrt_attached) =
                assemble_coded_workers(sx_blocks, sy_blocks, norm, precision, runtime);
            Ok(DataParallel {
                workers,
                assembler: GradAssembler { n, p: x.cols(), map: ReplicationMap::new(m, 1) },
                scheme,
                beta: enc.beta,
                pjrt_attached,
            })
        }
    }
}

/// [`build_data_parallel_with_runtime`] over a streamed
/// [`BlockSource`](crate::data::shard::BlockSource): encoded worker
/// shards are assembled block-by-block via
/// [`crate::encoding::stream::encode_data_streamed`], so the input
/// dataset is never materialized as one `Mat` — peak resident data is
/// one source block plus the per-worker shards being built.
///
/// Bit-identity contract: given a source streaming the same rows as an
/// in-memory `(X, y)`, the workers (and therefore every trace computed
/// through them) are **bit-identical** to
/// [`build_data_parallel_with_runtime`] on that `(X, y)` — the
/// streaming encoders continue the exact floating-point fold of the
/// dense kernels (see `encoding::stream`), and everything after the
/// encode (normalization, worker construction, PJRT attach) is the
/// same code.
pub fn build_data_parallel_streamed(
    src: &dyn crate::data::shard::BlockSource,
    scheme: Scheme,
    m: usize,
    beta: f64,
    seed: u64,
    precision: Precision,
    runtime: Option<&crate::runtime::ArtifactIndex>,
) -> Result<DataParallel> {
    use crate::data::shard::assemble_targets;
    use crate::encoding::stream::{encode_data_streamed, encode_vec_streamed};
    let n = src.rows();
    anyhow::ensure!(
        src.has_targets(),
        "data-parallel workers need targets y; the sharded dataset has none"
    );
    match scheme {
        Scheme::Replication => {
            let r = beta.round() as usize;
            anyhow::ensure!(r >= 1 && m % r == 0, "replication needs r|m (r={r}, m={m})");
            let map = ReplicationMap::new(m, r);
            let parts = map.partitions();
            let enc = EncodingOp::identity(n, parts);
            let sx = encode_data_streamed(&enc, src)?;
            let y = assemble_targets(src)?;
            let shards: Vec<(Mat, Vec<f64>)> = sx
                .into_iter()
                .enumerate()
                .map(|(p, sxp)| (sxp, enc.row_block(p).matvec(&y)))
                .collect();
            let (workers, pjrt_attached) =
                assemble_replicated_workers(&shards, &map, m, precision, runtime);
            Ok(DataParallel {
                workers,
                assembler: GradAssembler { n, p: src.cols(), map },
                scheme,
                beta: r as f64,
                pjrt_attached,
            })
        }
        _ => {
            let enc = EncodingOp::build(scheme, n, m, beta, seed)?;
            let norm = 1.0 / enc.beta.sqrt();
            let sx_blocks = encode_data_streamed(&enc, src)?;
            let sy_blocks = encode_vec_streamed(&enc, src)?;
            let (workers, pjrt_attached) =
                assemble_coded_workers(sx_blocks, sy_blocks, norm, precision, runtime);
            Ok(DataParallel {
                workers,
                assembler: GradAssembler { n, p: src.cols(), map: ReplicationMap::new(m, 1) },
                scheme,
                beta: enc.beta,
                pjrt_attached,
            })
        }
    }
}

/// Evaluation callback: maps the current iterate to
/// `(original objective, test metric)` for the trace.
pub type EvalFn<'a> = dyn Fn(&[f64]) -> (f64, f64) + 'a;

/// Per-round wait-for-k policy driving a solver loop's gather calls.
///
/// The solver loops ([`gd`], [`lbfgs`], [`prox`], [`bcd`]) never call
/// [`Gather::round`] directly anymore: every gather goes through
/// [`RoundCtl::gather`], which records a [`RoundStats`] observation and
/// — under an adaptive policy — asks the policy for the next round's k.
/// The coordinator layer stays below `control` in the module DAG: the
/// policy arrives as an opaque `FnMut(&RoundStats) -> usize` closure
/// (built by `driver` from a `control::Controller`), so nothing here
/// imports upward.
///
/// With a fixed policy the behavior (including the hard `k ≤ live`
/// panic) is bit-identical to the pre-controller loops; an adaptive
/// policy switches gathers to [`Gather::round_clamped`], since its
/// request precedes this round's crash observations.
pub struct RoundCtl<'a> {
    k: usize,
    policy: Option<&'a mut dyn FnMut(&RoundStats) -> usize>,
    round: usize,
    rounds: Vec<RoundStats>,
}

impl<'a> RoundCtl<'a> {
    /// Static wait-for-k: every round requests exactly `k`.
    pub fn fixed(k: usize) -> Self {
        RoundCtl { k, policy: None, round: 0, rounds: Vec::new() }
    }

    /// Adaptive wait-for-k: start at `k0`, and after each round feed the
    /// recorded [`RoundStats`] to `policy`, whose return value is the
    /// next round's k. The policy owns all bounds (erasure floor, m);
    /// the engine only clamps down to the live count.
    pub fn adaptive(k0: usize, policy: &'a mut dyn FnMut(&RoundStats) -> usize) -> Self {
        RoundCtl { k: k0, policy: Some(policy), round: 0, rounds: Vec::new() }
    }

    /// The k the next gather will request.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Run one gather round under the current policy and record it.
    pub fn gather(
        &mut self,
        cluster: &mut dyn Gather,
        task_for: &mut dyn FnMut(usize) -> Task,
    ) -> RoundResult {
        let rr = match self.policy {
            None => cluster.round(self.k, task_for),
            Some(_) => cluster.round_clamped(self.k, task_for),
        };
        let stats = RoundStats {
            round: self.round,
            k_requested: self.k,
            k_effective: rr.responses.len(),
            live: rr.live,
            elapsed: rr.elapsed,
            arrivals: rr.responses.iter().map(|r| r.arrival).collect(),
        };
        if let Some(policy) = self.policy.as_mut() {
            self.k = policy(&stats);
        }
        self.rounds.push(stats);
        self.round += 1;
        rr
    }

    /// The recorded per-round observations, in round order.
    pub fn rounds(&self) -> &[RoundStats] {
        &self.rounds
    }

    /// Consume the controller, yielding its recorded rounds.
    pub fn into_rounds(self) -> Vec<RoundStats> {
        self.rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Gather, SimCluster};
    use crate::data::synth::gaussian_linear;
    use crate::delay::NoDelay;
    use crate::objectives::{QuadObjective, RidgeProblem};

    fn grad_task(iter: usize, w: &[f64]) -> Task {
        Task { iter, kind: KIND_GRADIENT, payload: w.to_vec(), aux: vec![] }
    }

    #[test]
    fn full_gather_matches_exact_gradient_for_tight_frames() {
        // k = m with a Parseval frame ⇒ assembled gradient == (1/n)Xᵀ(Xw−y)
        let (x, y, _) = gaussian_linear(32, 6, 0.3, 5);
        for scheme in [Scheme::Hadamard, Scheme::Haar, Scheme::Uncoded] {
            let dp = build_data_parallel(&x, &y, scheme, 4, 2.0, 7).unwrap();
            let asm = dp.assembler.clone();
            let mut cluster = SimCluster::new(dp.workers, Box::new(NoDelay::new(4)));
            let w: Vec<f64> = (0..6).map(|i| 0.2 * i as f64 - 0.5).collect();
            let rr = cluster.round(4, &mut |_| grad_task(0, &w));
            let g = asm.assemble(&rr.responses);
            // compare against the λ=0 ridge gradient
            let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
            let g_exact = prob.gradient(&w);
            let err = crate::testutil::rel_err(&g, &g_exact);
            assert!(err < 1e-9, "{scheme:?}: rel err {err}");
        }
    }

    #[test]
    fn partial_gather_is_close_for_coded_far_for_uncoded() {
        let (x, y, _) = gaussian_linear(64, 8, 0.2, 9);
        let w: Vec<f64> = (0..8).map(|i| 0.1 * i as f64).collect();
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
        let g_exact = prob.gradient(&w);
        let mut errs = std::collections::BTreeMap::new();
        for scheme in [Scheme::Hadamard, Scheme::Uncoded] {
            let dp = build_data_parallel(&x, &y, scheme, 8, 2.0, 3).unwrap();
            let asm = dp.assembler.clone();
            let mut cluster = SimCluster::new(dp.workers, Box::new(NoDelay::new(8)));
            let rr = cluster.round(6, &mut |_| grad_task(0, &w));
            let g = asm.assemble(&rr.responses);
            errs.insert(format!("{scheme:?}"), crate::testutil::rel_err(&g, &g_exact));
        }
        let coded = errs["Hadamard"];
        let uncoded = errs["Uncoded"];
        assert!(coded < uncoded, "coded {coded} !< uncoded {uncoded}");
    }

    #[test]
    fn replication_dedups_and_scales() {
        let (x, y, _) = gaussian_linear(24, 4, 0.1, 11);
        let dp = build_data_parallel(&x, &y, Scheme::Replication, 8, 2.0, 1).unwrap();
        let asm = dp.assembler.clone();
        let mut cluster = SimCluster::new(dp.workers, Box::new(NoDelay::new(8)));
        let w = vec![0.1, -0.2, 0.3, 0.0];
        // all respond: both copies of each partition arrive; gradient must
        // still equal the exact one (duplicates dropped, not double-counted)
        let rr = cluster.round(8, &mut |_| grad_task(0, &w));
        let g = asm.assemble(&rr.responses);
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
        let err = crate::testutil::rel_err(&g, &prob.gradient(&w));
        assert!(err < 1e-9, "rel err {err}");
    }

    #[test]
    fn linesearch_quadform_matches_exact() {
        let (x, y, _) = gaussian_linear(32, 5, 0.2, 13);
        let dp = build_data_parallel(&x, &y, Scheme::Hadamard, 4, 2.0, 5).unwrap();
        let asm = dp.assembler.clone();
        let mut cluster = SimCluster::new(dp.workers, Box::new(NoDelay::new(4)));
        let d = vec![0.3, -0.1, 0.5, 0.2, -0.4];
        let rr = cluster.round(4, &mut |_| Task {
            iter: 0,
            kind: KIND_LINESEARCH,
            payload: d.clone(),
            aux: vec![],
        });
        let q = asm.assemble_quadform(&rr.responses);
        let xd = x.matvec(&d);
        let exact = crate::linalg::dot(&xd, &xd) / 32.0;
        assert!((q - exact).abs() < 1e-9 * exact.max(1.0), "{q} vs {exact}");
    }

    #[test]
    fn round_ctl_records_and_adapts() {
        let (x, y, _) = gaussian_linear(32, 6, 0.3, 5);
        let dp = build_data_parallel(&x, &y, Scheme::Uncoded, 4, 2.0, 7).unwrap();
        let mut cluster = SimCluster::new(dp.workers, Box::new(NoDelay::new(4)));
        let w = vec![0.0; 6];
        // toy policy: request one fewer than delivered, never below 2
        let mut policy = |s: &RoundStats| s.k_effective.saturating_sub(1).max(2);
        let mut ctl = RoundCtl::adaptive(4, &mut policy);
        let r0 = ctl.gather(&mut cluster, &mut |_| grad_task(0, &w));
        assert_eq!(r0.responses.len(), 4);
        assert_eq!(ctl.k(), 3, "policy shrank k after round 0");
        let r1 = ctl.gather(&mut cluster, &mut |_| grad_task(1, &w));
        assert_eq!(r1.responses.len(), 3);
        assert_eq!(ctl.k(), 2);
        let rounds = ctl.into_rounds();
        assert_eq!(rounds.len(), 2);
        assert_eq!(rounds[0].k_requested, 4);
        assert_eq!(rounds[1].k_requested, 3);
        assert_eq!(rounds[1].k_effective, 3);
        assert_eq!(rounds[1].live, 4);
        assert_eq!(rounds[1].arrivals.len(), 3);
    }

    #[test]
    fn round_ctl_fixed_records_without_adapting() {
        let (x, y, _) = gaussian_linear(32, 6, 0.3, 5);
        let dp = build_data_parallel(&x, &y, Scheme::Uncoded, 4, 2.0, 7).unwrap();
        let mut cluster = SimCluster::new(dp.workers, Box::new(NoDelay::new(4)));
        let w = vec![0.0; 6];
        let mut ctl = RoundCtl::fixed(3);
        for t in 0..3 {
            let rr = ctl.gather(&mut cluster, &mut |_| grad_task(t, &w));
            assert_eq!(rr.responses.len(), 3);
            assert_eq!(ctl.k(), 3);
        }
        assert_eq!(ctl.rounds().len(), 3);
        assert!(ctl.rounds().iter().all(|s| s.k_requested == 3 && s.k_effective == 3));
    }

    #[test]
    fn worker_cost_scales_with_rows() {
        let (x, y, _) = gaussian_linear(30, 4, 0.1, 15);
        let dp = build_data_parallel(&x, &y, Scheme::Gaussian, 3, 2.0, 1).unwrap();
        // Gaussian β=2 → 60 rows over 3 workers = 20 each
        for w in &dp.workers {
            assert_eq!(w.cost(), 20.0);
        }
    }
}
