//! Encoded gradient descent (paper §2.1 "Gradient descent", Theorem 2).
//!
//! Master loop per Algorithm 1: broadcast `w_t`, wait for the fastest
//! `k` gradient updates, interrupt the rest, assemble the descent
//! direction from the partial sum, take a fixed-step update. With a
//! BRIP encoding the iterates converge deterministically to a
//! neighborhood of the true optimum for *arbitrary* straggler patterns.

use super::{EvalFn, GradAssembler, RoundCtl, KIND_GRADIENT};
use crate::cluster::{Gather, Task};
use crate::metrics::{IterRecord, Participation, Trace};

/// Configuration for the encoded-GD master loop (driven by
/// `driver::Gd`).
#[derive(Clone, Debug)]
pub struct GdConfig {
    /// Wait-for-k.
    pub k: usize,
    /// Step size α.
    pub step: f64,
    /// Outer iterations T.
    pub iters: usize,
    /// Smooth ℓ₂ regularizer weight: adds `λ·w` to the gradient
    /// (`h(w) = ‖w‖²/2`). Use 0 for plain least squares.
    pub lambda: f64,
    /// Initial iterate (defaults to 0).
    pub w0: Option<Vec<f64>>,
}

/// Solver-core outcome: the trace plus final iterate and participation.
///
/// This is what the algorithm loops return; `driver::Experiment::run`
/// wraps it into the richer `driver::RunOutput`, which additionally
/// reports `pjrt_attached` and the achieved redundancy β. Code outside
/// the driver should consume the driver type.
pub struct RunOutput {
    pub trace: Trace,
    pub w: Vec<f64>,
    pub participation: Participation,
}

/// Encoded gradient-descent master loop on a gathered cluster.
///
/// `eval` maps the iterate to (original objective, test metric) for the
/// trace — convergence is reported on the ORIGINAL problem, as in the
/// paper's theorems. Every gather goes through `ctl`, which records the
/// per-round arrivals and — under an adaptive policy — moves k between
/// rounds (`cfg.k` is only the starting point the caller seeded the
/// controller with). Called by the `driver::Gd` solver.
pub(crate) fn gd_loop(
    cluster: &mut dyn Gather,
    assembler: &GradAssembler,
    cfg: &GdConfig,
    ctl: &mut RoundCtl<'_>,
    label: &str,
    eval: &EvalFn,
) -> RunOutput {
    let m = cluster.workers();
    assert!(cfg.k >= 1 && cfg.k <= m, "k out of range");
    let mut w = cfg.w0.clone().unwrap_or_else(|| vec![0.0; assembler.p]);
    assert_eq!(w.len(), assembler.p);
    let mut trace = Trace::new(label);
    let mut participation = Participation::new(m);
    for t in 0..cfg.iters {
        let rr = ctl.gather(cluster, &mut |_| Task {
            iter: t,
            kind: KIND_GRADIENT,
            payload: w.clone(),
            aux: vec![],
        });
        participation.record(&rr.active_set());
        let mut g = assembler.assemble(&rr.responses);
        crate::linalg::axpy(cfg.lambda, &w, &mut g);
        crate::linalg::axpy(-cfg.step, &g, &mut w);
        let (objective, test_metric) = eval(&w);
        trace.push(IterRecord {
            iter: t,
            time: cluster.clock(),
            objective,
            test_metric,
            k_used: rr.responses.len(),
        });
    }
    RunOutput { trace, w, participation }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::SimCluster;
    use crate::config::Scheme;
    use crate::coordinator::build_data_parallel;
    use crate::data::synth::gaussian_linear;
    use crate::delay::{AdversarialDelay, NoDelay};
    use crate::objectives::{QuadObjective, RidgeProblem};

    fn setup(
        n: usize,
        p: usize,
        scheme: Scheme,
        m: usize,
        seed: u64,
    ) -> (RidgeProblem, GradAssembler, SimCluster) {
        let (x, y, _) = gaussian_linear(n, p, 0.3, seed);
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
        let dp = build_data_parallel(&x, &y, scheme, m, 2.0, seed).unwrap();
        let asm = dp.assembler.clone();
        let cluster = SimCluster::new(dp.workers, Box::new(NoDelay::new(m)));
        (prob, asm, cluster)
    }

    fn gd_cfg(k: usize, step: f64, iters: usize) -> GdConfig {
        GdConfig { k, step, iters, lambda: 0.05, w0: None }
    }

    #[test]
    fn converges_to_exact_solution_with_full_gather() {
        let (prob, asm, mut cluster) = setup(64, 8, Scheme::Hadamard, 8, 3);
        let step = 1.0 / prob.smoothness();
        let f_star = prob.objective(&prob.solve_exact());
        let out = gd_loop(
            &mut cluster,
            &asm,
            &gd_cfg(8, step, 400),
            &mut RoundCtl::fixed(8),
            "gd",
            &|w| (prob.objective(w), 0.0),
        );
        let f_final = out.trace.final_objective();
        assert!(
            (f_final - f_star) / f_star < 1e-6,
            "f_final={f_final}, f*={f_star}"
        );
    }

    #[test]
    fn coded_converges_under_adversarial_stragglers() {
        // Theorem 2's claim: arbitrary A_t patterns. Fix two nodes as
        // permanent stragglers; encoded GD still reaches a near-optimal
        // neighborhood.
        let (x, y, _) = gaussian_linear(64, 8, 0.3, 5);
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
        let dp = build_data_parallel(&x, &y, Scheme::Hadamard, 8, 2.0, 5).unwrap();
        let asm = dp.assembler.clone();
        let delay = AdversarialDelay::new(8, vec![0, 3], 1e6);
        let mut cluster = SimCluster::new(dp.workers, Box::new(delay));
        let step = 0.5 / prob.smoothness();
        let f_star = prob.objective(&prob.solve_exact());
        let out = gd_loop(
            &mut cluster,
            &asm,
            &gd_cfg(6, step, 600),
            &mut RoundCtl::fixed(6),
            "gd-adv",
            &|w| (prob.objective(w), 0.0),
        );
        let f_final = out.trace.final_objective();
        // κ-neighborhood, not exact: allow a generous approximation band
        assert!(
            f_final < 1.25 * f_star,
            "f_final={f_final} vs f*={f_star}"
        );
        // stragglers never participated
        assert_eq!(out.participation.fraction(0), 0.0);
        assert_eq!(out.participation.fraction(3), 0.0);
    }

    #[test]
    fn uncoded_partial_gather_is_biased_away_from_optimum() {
        // With S = I and k < m, entire data blocks are silently dropped:
        // the fixed point solves a subsampled problem. With i.i.d. data
        // any subset is nearly representative, so build a HETEROGENEOUS
        // design where block b carries most of the signal for the
        // features ≡ b (mod m): dropping blocks then loses information
        // the uncoded scheme cannot recover, while the encoding spreads
        // every feature's signal over all workers.
        let m = 8;
        let (n, p) = (96, 10);
        let mut rng = crate::rng::Pcg64::new(7);
        let rows_per_block = n / m;
        let x = crate::linalg::Mat::from_fn(n, p, |r, c| {
            let block = r / rows_per_block;
            let strong = c % m == block;
            let z = crate::rng::Normal::sample_standard(&mut rng);
            if strong {
                2.0 * z
            } else {
                0.05 * z
            }
        });
        let w_true: Vec<f64> = (0..p).map(|i| 1.0 + 0.1 * i as f64).collect();
        let mut y = x.matvec(&w_true);
        for v in y.iter_mut() {
            *v += 0.1 * crate::rng::Normal::sample_standard(&mut rng);
        }
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
        let f_star = prob.objective(&prob.solve_exact());
        let step = 0.5 / prob.smoothness();
        let mut finals = std::collections::BTreeMap::new();
        for scheme in [Scheme::Uncoded, Scheme::Haar] {
            let dp = build_data_parallel(&x, &y, scheme, 8, 2.0, 11).unwrap();
            let asm = dp.assembler.clone();
            let delay = AdversarialDelay::new(8, vec![1, 6], 1e6);
            let mut cluster = SimCluster::new(dp.workers, Box::new(delay));
            let out = gd_loop(
                &mut cluster,
                &asm,
                &gd_cfg(6, step, 500),
                &mut RoundCtl::fixed(6),
                "x",
                &|w| (prob.objective(w), 0.0),
            );
            finals.insert(format!("{scheme:?}"), out.trace.final_objective());
        }
        let coded = (finals["Haar"] - f_star) / f_star;
        let uncoded = (finals["Uncoded"] - f_star) / f_star;
        assert!(
            coded < uncoded,
            "coded subopt {coded} !< uncoded subopt {uncoded}"
        );
    }

    #[test]
    fn objective_stays_bounded() {
        // Theorem-5-style sanity: no divergence along the run.
        let (prob, asm, mut cluster) = setup(48, 6, Scheme::Steiner, 6, 13);
        let step = 0.8 / prob.smoothness();
        let out = gd_loop(
            &mut cluster,
            &asm,
            &gd_cfg(4, step, 200),
            &mut RoundCtl::fixed(4),
            "gd",
            &|w| (prob.objective(w), 0.0),
        );
        assert!(out.trace.bounded_by(1.05));
    }

    #[test]
    fn trace_records_k_and_time_monotone() {
        let (prob, asm, mut cluster) = setup(32, 4, Scheme::Gaussian, 4, 17);
        let out = gd_loop(
            &mut cluster,
            &asm,
            &gd_cfg(3, 0.01, 10),
            &mut RoundCtl::fixed(3),
            "gd",
            &|w| (prob.objective(w), 0.0),
        );
        assert_eq!(out.trace.len(), 10);
        for rec in &out.trace.records {
            assert_eq!(rec.k_used, 3);
        }
        for pair in out.trace.records.windows(2) {
            assert!(pair[1].time > pair[0].time);
        }
    }
}
