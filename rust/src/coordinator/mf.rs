//! Distributed matrix-factorization inner solver (paper §5.2).
//!
//! ALS decomposes the MF objective into per-row regularized least
//! squares (eq. 13). The paper solves instances under n = 500 locally
//! (`numpy.linalg.solve`) and larger ones with distributed encoded
//! L-BFGS over the straggling cluster. [`DistributedMfSolver`]
//! implements that hybrid and accumulates the simulated distributed
//! time, which is what the Tables-2/3 "runtime" columns report.

use crate::config::Scheme;
use crate::delay::DelayModel;
// The matfac coordinator launches nested per-block Experiments through the driver.
// lint:allow(layer-order) — deliberate inversion, confined to this subsolver
use crate::driver::{Experiment, Lbfgs, Problem};
use crate::objectives::matfac::{LocalCholesky, SubSolver, Subproblem};
use crate::objectives::QuadObjective;

/// Hybrid local/distributed subproblem solver.
pub struct DistributedMfSolver<F: FnMut(usize) -> Box<dyn DelayModel>> {
    pub scheme: Scheme,
    pub m: usize,
    pub k: usize,
    /// Subproblems with fewer rows than this go to the local solver.
    pub threshold: usize,
    /// L-BFGS iterations per subproblem.
    pub inner_iters: usize,
    /// Builds a fresh delay model per distributed solve (takes a
    /// counter so delays vary across subproblems).
    pub delay_factory: F,
    /// Simulated seconds per shard row of compute.
    pub secs_per_unit: f64,
    /// Accumulated simulated distributed time.
    pub sim_time: f64,
    /// (distributed, local) solve counts.
    pub counts: (usize, usize),
    local: LocalCholesky,
    solve_counter: usize,
}

impl<F: FnMut(usize) -> Box<dyn DelayModel>> DistributedMfSolver<F> {
    pub fn new(scheme: Scheme, m: usize, k: usize, threshold: usize, delay_factory: F) -> Self {
        DistributedMfSolver {
            scheme,
            m,
            k,
            threshold,
            inner_iters: 12,
            delay_factory,
            secs_per_unit: 1e-4,
            sim_time: 0.0,
            counts: (0, 0),
            local: LocalCholesky,
            solve_counter: 0,
        }
    }
}

impl<F: FnMut(usize) -> Box<dyn DelayModel>> SubSolver for DistributedMfSolver<F> {
    fn solve(&mut self, sub: &Subproblem) -> Vec<f64> {
        if sub.a.rows() < self.threshold {
            self.counts.1 += 1;
            return self.local.solve(sub);
        }
        self.counts.0 += 1;
        self.solve_counter += 1;
        let n = sub.a.rows();
        // eq-13 uses unnormalized ‖Aw−b‖² + λ‖w‖²; our ridge convention is
        // 1/(2n)‖·‖² + (λ/2)‖·‖² → rescale.
        let lam = 2.0 * sub.lambda / n as f64;
        let (k, beta) = match self.scheme {
            Scheme::Uncoded => (self.k, 1.0),
            _ => (self.k, 2.0),
        };
        let delay = (self.delay_factory)(self.solve_counter);
        let prob = crate::objectives::RidgeProblem::new(sub.a.clone(), sub.b.clone(), lam);
        let out = Experiment::new(Problem::least_squares(&sub.a, &sub.b))
            .scheme(self.scheme)
            .workers(self.m)
            .wait_for(k)
            .redundancy(beta)
            .seed(17)
            .timing(self.secs_per_unit, 1e-4)
            .delay_model(delay)
            .label("mf-sub")
            .eval(|w| (prob.objective(w), 0.0))
            .run(Lbfgs::new().iters(self.inner_iters).lambda(lam).memory(8).rho(0.9))
            .expect("mf inner solve");
        self.sim_time += out.trace.total_time();
        out.w
    }
}

/// One complete MF experiment (the unit of the paper's Figures 8–9 and
/// Tables 2–3): generate MovieLens-like ratings, run `epochs` ALS
/// epochs with the hybrid distributed solver, return
/// (train RMSE, test RMSE, simulated distributed seconds).
#[derive(Clone, Copy, Debug)]
pub struct MfExperimentCfg {
    pub users: usize,
    pub movies: usize,
    pub dim: usize,
    pub ratings_per_user: usize,
    pub lambda: f64,
    pub epochs: usize,
    pub m: usize,
    pub k: usize,
    pub scheme: Scheme,
    pub threshold: usize,
    pub seed: u64,
}

pub fn mf_experiment(cfg: &MfExperimentCfg) -> (f64, f64, f64) {
    let ds = crate::data::movielens::generate(
        cfg.users,
        cfg.movies,
        cfg.dim,
        cfg.ratings_per_user,
        0.3,
        cfg.seed,
    );
    let mut mf = crate::objectives::matfac::MatFacProblem::new(
        &ds.train,
        cfg.users,
        cfg.movies,
        cfg.dim,
        cfg.lambda,
        ds.global_mean,
        cfg.seed ^ 0x5eed,
    );
    let m = cfg.m;
    let mut solver = DistributedMfSolver::new(cfg.scheme, m, cfg.k, cfg.threshold, move |c| {
        // the paper's §5.2 setup: exp(10 ms) per-task latency
        Box::new(crate::delay::ExponentialDelay::new(m, 0.010, c as u64))
    });
    for _ in 0..cfg.epochs {
        mf.als_epoch(&mut solver);
    }
    (mf.rmse(&ds.train), mf.rmse(&ds.test), solver.sim_time)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::movielens;
    use crate::delay::ExponentialDelay;
    use crate::objectives::matfac::MatFacProblem;

    #[test]
    fn hybrid_solver_improves_rmse_and_tracks_time() {
        let ds = movielens::generate(40, 60, 4, 20, 0.2, 3);
        let mut mf = MatFacProblem::new(&ds.train, 40, 60, 4, 1.0, ds.global_mean, 5);
        let before = mf.rmse(&ds.test);
        let mut solver = DistributedMfSolver::new(Scheme::Hadamard, 4, 3, 25, |c| {
            Box::new(ExponentialDelay::new(4, 0.01, c as u64))
        });
        for _ in 0..3 {
            mf.als_epoch(&mut solver);
        }
        assert!(mf.rmse(&ds.test) < before);
        assert!(solver.counts.0 > 0, "no distributed solves happened");
        assert!(solver.counts.1 > 0, "no local solves happened");
        assert!(solver.sim_time > 0.0);
    }
}
