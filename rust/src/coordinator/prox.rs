//! Encoded proximal gradient / ISTA (paper §2.1 "Proximal gradient",
//! Theorem 5) — the LASSO workhorse (§5.4).
//!
//! Same wait-for-k gather as gradient descent, but the master applies
//! `w_{t+1} = prox_{αλ‖·‖₁}(w_t − α·ĝ_t)` where ĝ_t is the assembled
//! encoded gradient of the smooth part.

use super::{EvalFn, GradAssembler, RoundCtl, KIND_GRADIENT};
use crate::cluster::{Gather, Task};
use crate::linalg::soft_threshold;
use crate::metrics::{IterRecord, Participation, Trace};

/// Configuration for the encoded proximal-gradient master loop
/// (driven by `driver::Prox`).
#[derive(Clone, Debug)]
pub struct ProxConfig {
    pub k: usize,
    /// Step size α < 1/M.
    pub step: f64,
    pub iters: usize,
    /// ℓ₁ weight λ.
    pub lambda: f64,
    pub w0: Option<Vec<f64>>,
}

pub use super::gd::RunOutput;

/// Encoded proximal-gradient (ISTA) master loop on a gathered cluster.
/// Called by the `driver::Prox` solver.
pub(crate) fn prox_loop(
    cluster: &mut dyn Gather,
    assembler: &GradAssembler,
    cfg: &ProxConfig,
    ctl: &mut RoundCtl<'_>,
    label: &str,
    eval: &EvalFn,
) -> RunOutput {
    let m = cluster.workers();
    assert!(cfg.k >= 1 && cfg.k <= m);
    let mut w = cfg.w0.clone().unwrap_or_else(|| vec![0.0; assembler.p]);
    let mut trace = Trace::new(label);
    let mut participation = Participation::new(m);
    let tau = cfg.step * cfg.lambda;
    for t in 0..cfg.iters {
        let rr = ctl.gather(cluster, &mut |_| Task {
            iter: t,
            kind: KIND_GRADIENT,
            payload: w.clone(),
            aux: vec![],
        });
        participation.record(&rr.active_set());
        let g = assembler.assemble(&rr.responses);
        for i in 0..w.len() {
            w[i] = soft_threshold(w[i] - cfg.step * g[i], tau);
        }
        let (objective, test_metric) = eval(&w);
        trace.push(IterRecord {
            iter: t,
            time: cluster.clock(),
            objective,
            test_metric,
            k_used: rr.responses.len(),
        });
    }
    RunOutput { trace, w, participation }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::SimCluster;
    use crate::config::Scheme;
    use crate::coordinator::build_data_parallel;
    use crate::data::synth::sparse_recovery;
    use crate::delay::{AdversarialDelay, NoDelay};
    use crate::metrics::f1_support;
    use crate::objectives::LassoProblem;

    #[test]
    fn matches_centralized_ista_with_full_gather() {
        let (x, y, _) = sparse_recovery(64, 24, 4, 0.1, 3);
        let prob = LassoProblem::new(x.clone(), y.clone(), 0.05);
        let alpha = prob.default_step();
        let dp = build_data_parallel(&x, &y, Scheme::Hadamard, 4, 2.0, 5).unwrap();
        let asm = dp.assembler.clone();
        let mut cluster = SimCluster::new(dp.workers, Box::new(NoDelay::new(4)));
        let cfg = ProxConfig { k: 4, step: alpha, iters: 80, lambda: 0.05, w0: None };
        let out = prox_loop(&mut cluster, &asm, &cfg, &mut RoundCtl::fixed(4), "prox", &|w| {
            (prob.objective(w), 0.0)
        });
        let w_ref = prob.solve_ista(80);
        let err = crate::testutil::rel_err(&out.w, &w_ref);
        assert!(err < 1e-6, "rel err {err}");
    }

    #[test]
    fn recovers_support_under_adversarial_stragglers() {
        let (x, y, w_star) = sparse_recovery(160, 48, 6, 0.1, 7);
        let prob = LassoProblem::new(x.clone(), y.clone(), 0.08);
        let alpha = prob.default_step();
        let dp = build_data_parallel(&x, &y, Scheme::Steiner, 8, 2.0, 9).unwrap();
        let asm = dp.assembler.clone();
        let delay = AdversarialDelay::new(8, vec![2, 5], 1e6);
        let mut cluster = SimCluster::new(dp.workers, Box::new(delay));
        let cfg = ProxConfig { k: 6, step: alpha, iters: 250, lambda: 0.08, w0: None };
        let out = prox_loop(&mut cluster, &asm, &cfg, &mut RoundCtl::fixed(6), "prox-adv", &|w| {
            (prob.objective(w), 0.0)
        });
        let (_, _, f1) = f1_support(&w_star, &out.w, 1e-2);
        assert!(f1 > 0.8, "f1={f1}");
    }

    #[test]
    fn per_step_increase_bounded_theorem5() {
        // Theorem 5 part 2: f(w_{t+1}) ≤ κ·f(w_t) with κ = (1+7ε)/(1−3ε).
        // Empirically the encoded run must never blow up a step by more
        // than a small constant factor.
        let (x, y, _) = sparse_recovery(96, 32, 5, 0.2, 11);
        let prob = LassoProblem::new(x.clone(), y.clone(), 0.05);
        let alpha = prob.default_step();
        let dp = build_data_parallel(&x, &y, Scheme::Haar, 8, 2.0, 13).unwrap();
        let asm = dp.assembler.clone();
        let delay = AdversarialDelay::rotating(8, 0.25, 1e6);
        let mut cluster = SimCluster::new(dp.workers, Box::new(delay));
        let cfg = ProxConfig { k: 6, step: alpha, iters: 120, lambda: 0.05, w0: None };
        let out = prox_loop(&mut cluster, &asm, &cfg, &mut RoundCtl::fixed(6), "prox", &|w| {
            (prob.objective(w), 0.0)
        });
        for pair in out.trace.records.windows(2) {
            assert!(
                pair[1].objective <= 1.6 * pair[0].objective + 1e-12,
                "step blow-up: {} → {}",
                pair[0].objective,
                pair[1].objective
            );
        }
    }

    #[test]
    fn iterates_stay_sparse() {
        let (x, y, _) = sparse_recovery(80, 40, 4, 0.1, 13);
        let prob = LassoProblem::new(x.clone(), y.clone(), 0.2);
        let alpha = prob.default_step();
        let dp = build_data_parallel(&x, &y, Scheme::Hadamard, 4, 2.0, 15).unwrap();
        let asm = dp.assembler.clone();
        let mut cluster = SimCluster::new(dp.workers, Box::new(NoDelay::new(4)));
        let cfg = ProxConfig { k: 3, step: alpha, iters: 150, lambda: 0.2, w0: None };
        let out = prox_loop(&mut cluster, &asm, &cfg, &mut RoundCtl::fixed(3), "prox", &|w| {
            (prob.objective(w), 0.0)
        });
        let nnz = out.w.iter().filter(|&&v| v != 0.0).count();
        assert!(nnz < 40, "soft-thresholding must zero out coordinates (nnz={nnz})");
        assert!(nnz >= 1);
    }
}
