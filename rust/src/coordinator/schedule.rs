//! Wait-for-k scheduling policies.
//!
//! The paper (§3.3) proposes choosing k_t adaptively for L-BFGS:
//! `k_t = min{ k : |A_t(k) ∩ A_{t−1}| > m/β }` — wait for however many
//! responses it takes until the overlap with the previous round's active
//! set is large enough for the curvature-pair matrix `Š_t` to be full
//! rank (condition (7)).

/// Static policy: always wait for the same k.
#[derive(Clone, Copy, Debug)]
pub struct FixedK(pub usize);

/// Adaptive overlap policy (paper §3.3).
#[derive(Clone, Debug)]
pub struct AdaptiveOverlapK {
    /// Minimum overlap target: strictly more than m/β responders shared
    /// with the previous round.
    pub min_overlap: usize,
    /// Floor/ceiling on k.
    pub k_min: usize,
    pub k_max: usize,
}

impl AdaptiveOverlapK {
    pub fn new(m: usize, beta: f64, k_min: usize) -> Self {
        let min_overlap = (m as f64 / beta).floor() as usize + 1;
        AdaptiveOverlapK { min_overlap, k_min, k_max: m }
    }

    /// Given this round's arrival order (fastest first) and the previous
    /// active set, the smallest k satisfying the overlap condition.
    /// Falls back to `k_max` when the condition is unattainable.
    pub fn pick_k(&self, arrival_order: &[usize], prev_active: &[usize]) -> usize {
        let prev: std::collections::BTreeSet<usize> = prev_active.iter().copied().collect();
        let mut overlap = 0usize;
        for (idx, w) in arrival_order.iter().enumerate() {
            if prev.contains(w) {
                overlap += 1;
            }
            let k = idx + 1;
            if k >= self.k_min && overlap >= self.min_overlap {
                return k.min(self.k_max);
            }
        }
        self.k_max.min(arrival_order.len())
    }
}

/// Worst-case η for deterministic overlap (paper §3.3): when columns of X
/// are independent, condition (7) holds if η ≥ ½ + 1/(2β).
pub fn worst_case_eta(beta: f64) -> f64 {
    0.5 + 1.0 / (2.0 * beta)
}

/// Expected-case η under i.i.d. delays: η ≥ 1/√β.
pub fn expected_case_eta(beta: f64) -> f64 {
    1.0 / beta.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_waits_until_overlap() {
        // m=8, β=2 → need overlap > 4, i.e. ≥ 5 shared responders.
        let pol = AdaptiveOverlapK::new(8, 2.0, 2);
        assert_eq!(pol.min_overlap, 5);
        let prev = vec![0, 1, 2, 3, 4];
        // arrivals: three non-members first, then members
        let arrivals = vec![5, 6, 7, 0, 1, 2, 3, 4];
        // need 5 members: k = 8
        assert_eq!(pol.pick_k(&arrivals, &prev), 8);
        // members arrive first: k = 5
        let arrivals2 = vec![0, 1, 2, 3, 4, 5, 6, 7];
        assert_eq!(pol.pick_k(&arrivals2, &prev), 5);
    }

    #[test]
    fn adaptive_respects_k_min() {
        let pol = AdaptiveOverlapK { min_overlap: 1, k_min: 3, k_max: 6 };
        let prev = vec![0];
        let arrivals = vec![0, 1, 2, 3, 4, 5];
        assert_eq!(pol.pick_k(&arrivals, &prev), 3);
    }

    #[test]
    fn adaptive_falls_back_to_kmax() {
        let pol = AdaptiveOverlapK::new(4, 2.0, 1); // need ≥ 3 overlap
        let prev = vec![0];
        let arrivals = vec![1, 2, 3, 0];
        assert_eq!(pol.pick_k(&arrivals, &prev), 4);
    }

    #[test]
    fn eta_thresholds() {
        assert!((worst_case_eta(2.0) - 0.75).abs() < 1e-12);
        assert!((expected_case_eta(4.0) - 0.5).abs() < 1e-12);
        assert!(expected_case_eta(2.0) < worst_case_eta(2.0));
    }
}
