//! Encoded block coordinate descent under model parallelism
//! (paper §2.2, Algorithms 3–4, Theorem 6).
//!
//! The model dimension p is lifted to βp redundant coordinates via
//! `w = S̄ᵀv`; worker i owns the coordinate block `v_i` and the column
//! block `A_i = X·S̄_iᵀ`. Each round the master sends worker i its
//! aggregate `z̃_i = Σ_{j≠i} u_j` (`u_j = A_j·v_j`) plus the accept flag
//! for the worker's pending step; the worker answers with its refreshed
//! `u_i` (and `v_i`, used master-side for evaluation only). Stragglers'
//! updates are erased: the master reuses `u_{i,t−1}` (Algorithm 4 line
//! 7) and tells the worker to discard the never-accepted step — this is
//! what keeps parameter values consistent across machines.
//!
//! Because the lift preserves the problem geometry (`g̃` minimized at
//! `S̄ᵀv* = w*`, Lemma 15), encoded BCD converges to the *exact*
//! optimum, unlike the data-parallel algorithms' κ-approximation.

use super::gd::RunOutput;
use super::{RoundCtl, KIND_BCD_STEP};
use crate::cluster::{Gather, Task, WorkerNode};
use crate::config::Scheme;
use crate::encoding::{Encoder, EncodingOp, SMatrix};
use crate::linalg::{Csr, Mat};
use crate::metrics::{IterRecord, Participation, Trace};
use anyhow::Result;

/// How the master maps the lifted iterate `v = (v_1, …, v_m)` back to
/// `w = S̄ᵀv` — the per-iteration reconstruction the trace evaluation
/// and the final iterate go through: the structured full-generator
/// `S̄ᵀ·concat(v)` via [`Encoder::apply_t`] — one FWHT / CSR pass for
/// structured schemes, per-use regenerated blocks for the dense
/// ensembles. No dense row of S̄ is stored across iterations.
#[derive(Clone, Debug)]
pub struct Reconstruction {
    /// The (unnormalized) lazy operator; its row blocks partition the
    /// lifted coordinates in worker order, so concatenating `vᵢ`
    /// matches its row order.
    pub op: EncodingOp,
    /// Parseval normalization 1/√β applied after the transpose.
    pub norm: f64,
}

impl Reconstruction {
    /// Per-worker coordinate-block sizes `b_i`.
    pub fn block_sizes(&self) -> Vec<usize> {
        (0..self.op.workers()).map(|i| self.op.block_rows(i)).collect()
    }

    /// Model dimension p.
    pub fn dim(&self) -> usize {
        self.op.n
    }

    /// Parseval-normalized dense blocks `S̄_i` — materialized on demand
    /// (spectrum analysis / debugging); the master loop itself never
    /// holds them. Goes through the block visitor so a dense-ensemble
    /// generator (Paley) builds its frame once, not once per block.
    pub fn sbar_blocks(&self) -> Vec<SMatrix> {
        let mut out = Vec::with_capacity(self.op.workers());
        self.op
            .for_each_row_block(&mut |_i, b| {
                let mut dense = b.to_dense();
                dense.scale_inplace(self.norm);
                out.push(SMatrix::Dense(dense));
                Ok(())
            })
            .expect("in-memory block visit cannot fail");
        out
    }

    /// `w = S̄ᵀv` from the per-worker blocks.
    ///
    /// Per-use generation applies here too: structured schemes run one
    /// FWHT/CSR pass; the dense ensembles regenerate their blocks for
    /// this call and drop them (Paley: one frame build per iteration —
    /// the price of never storing dense rows across iterations, bounded
    /// by the construction's size guard and by BCD's modest lifted
    /// dimension βp).
    pub fn reconstruct(&self, v: &[Vec<f64>]) -> Vec<f64> {
        let flat = v.concat();
        let mut w = self.op.apply_t(&flat);
        crate::linalg::scale(self.norm, &mut w);
        w
    }
}

/// Per-coordinate-block worker state.
pub struct BcdWorker {
    /// Column block A_i = X·S̄_iᵀ (n × b_i).
    pub a: Mat,
    /// Owned coordinate block v_i.
    pub v: Vec<f64>,
    /// Pending step d_i and the round it was computed in (−1 = none).
    pending: Vec<f64>,
    pending_round: i64,
    /// Step size α.
    pub step: f64,
    /// Lifted ℓ₂ regularizer weight: adds 2λv_i to the block gradient
    /// (λ‖v‖² is block-separable; λ‖S̄ᵀv‖² would not be).
    pub lambda: f64,
    /// ∇φ: maps the n-vector Xw to the n-vector ∇φ(Xw).
    pub grad_phi: Box<dyn Fn(&[f64]) -> Vec<f64> + Send>,
}

impl BcdWorker {
    pub fn new(
        a: Mat,
        step: f64,
        lambda: f64,
        grad_phi: Box<dyn Fn(&[f64]) -> Vec<f64> + Send>,
    ) -> Self {
        let b = a.cols();
        BcdWorker {
            a,
            v: vec![0.0; b],
            pending: vec![0.0; b],
            pending_round: -1,
            step,
            lambda,
            grad_phi,
        }
    }
}

impl WorkerNode for BcdWorker {
    fn process(&mut self, task: &Task) -> Vec<f64> {
        assert_eq!(task.kind, KIND_BCD_STEP);
        let accept_round = task.aux[0] as i64;
        // Apply the pending step iff the master accepted the round that
        // produced it (lines 4–8 of Algorithm 3).
        if self.pending_round >= 0 && accept_round == self.pending_round {
            crate::linalg::axpy(1.0, &self.pending, &mut self.v);
        }
        let z_tilde = &task.payload;
        // Block gradient ∇_i g̃(v) = A_iᵀ∇φ(A_i·v_i + z̃_i) + 2λv_i.
        let mut xw = self.a.matvec(&self.v);
        crate::linalg::axpy(1.0, z_tilde, &mut xw);
        let gphi = (self.grad_phi)(&xw);
        let mut grad = self.a.matvec_t(&gphi);
        crate::linalg::axpy(2.0 * self.lambda, &self.v, &mut grad);
        // d_{i,t} = −α∇_i g̃ (to be applied next round if accepted)
        self.pending = grad.iter().map(|g| -self.step * g).collect();
        self.pending_round = task.iter as i64;
        // u_i = A_i·v_i at the CURRENT v (one-round staleness by design)
        let mut out = self.a.matvec(&self.v);
        out.extend_from_slice(&self.v);
        out
    }

    fn cost(&self) -> f64 {
        (self.a.rows() * self.a.cols()).max(1) as f64 / 1000.0
    }
}

/// Assembled model-parallel problem.
pub struct ModelParallel {
    pub workers: Vec<Box<dyn WorkerNode>>,
    /// Structured w = S̄ᵀv reconstruction for the master loop. Dense
    /// normalized blocks are NOT materialized here — callers that need
    /// them (spectrum analysis, debugging) ask
    /// [`Reconstruction::sbar_blocks`], which builds them on demand.
    pub recon: Reconstruction,
    /// Data rows n and model dim p.
    pub n: usize,
    pub p: usize,
    /// Achieved redundancy.
    pub beta: f64,
}

/// Build model-parallel workers for a generic smooth φ over `X·w`.
///
/// `x` is the n×p data (dense here; the sparse-input case densifies the
/// per-worker column blocks `X·S̄_iᵀ`, which are small: n × βp/m).
pub fn build_model_parallel(
    x: &Mat,
    scheme: Scheme,
    m: usize,
    beta: f64,
    step: f64,
    lambda: f64,
    seed: u64,
    grad_phi: impl Fn() -> Box<dyn Fn(&[f64]) -> Vec<f64> + Send>,
) -> Result<ModelParallel> {
    let p = x.cols();
    let enc = EncodingOp::build(scheme, p, m, beta, seed)?;
    let norm = 1.0 / enc.beta.sqrt();
    let xt = x.transpose(); // p × n
    // A_i = X·S̄_iᵀ = (S̄_i·Xᵀ)ᵀ, encoded through the structured full-S
    // path (FWHT / CSR) where the scheme has one; dense ensembles
    // regenerate one block at a time.
    let si_xt_blocks = enc.encode_data(&xt); // b_i × n each
    let mut workers: Vec<Box<dyn WorkerNode>> = Vec::with_capacity(m);
    for mut si_xt in si_xt_blocks {
        si_xt.scale_inplace(norm);
        let a = si_xt.transpose(); // n × b_i
        workers.push(Box::new(BcdWorker::new(a, step, lambda, grad_phi())));
    }
    let beta_achieved = enc.beta;
    let recon = Reconstruction { op: enc, norm };
    Ok(ModelParallel { workers, recon, n: x.rows(), p, beta: beta_achieved })
}

/// Dense copy of a sparse data matrix (helper for logistic model
/// parallelism over CSR docs).
pub fn csr_to_dense(z: &Csr) -> Mat {
    z.to_dense()
}

/// Configuration for the encoded-BCD master loop (driven by
/// `driver::Bcd`).
#[derive(Clone, Debug)]
pub struct BcdConfig {
    pub k: usize,
    pub iters: usize,
}

/// Encoded BCD master loop. `eval` receives the reconstructed
/// `w_t = S̄ᵀv_t` (master-visible state). Called by the `driver::Bcd`
/// solver with a [`Reconstruction`].
pub(crate) fn bcd_loop(
    cluster: &mut dyn Gather,
    recon: &Reconstruction,
    n: usize,
    p: usize,
    cfg: &BcdConfig,
    ctl: &mut RoundCtl<'_>,
    label: &str,
    eval: &super::EvalFn,
) -> RunOutput {
    let m = cluster.workers();
    assert!(cfg.k >= 1 && cfg.k <= m);
    let block_sizes = recon.block_sizes();
    assert_eq!(block_sizes.len(), m);
    // Master state: per-worker u_i (n) and v_i snapshots, accept rounds.
    let mut u: Vec<Vec<f64>> = (0..m).map(|_| vec![0.0; n]).collect();
    let mut v: Vec<Vec<f64>> = block_sizes.iter().map(|&b| vec![0.0; b]).collect();
    let mut last_accept: Vec<f64> = vec![-1.0; m];
    let mut total_u = vec![0.0; n];
    let mut trace = Trace::new(label);
    let mut participation = Participation::new(m);

    for t in 0..cfg.iters {
        let rr = {
            let total_ref = &total_u;
            let u_ref = &u;
            let accept_ref = &last_accept;
            ctl.gather(cluster, &mut |i| {
                let mut z_tilde = total_ref.clone();
                for (z, ui) in z_tilde.iter_mut().zip(&u_ref[i]) {
                    *z -= ui;
                }
                Task { iter: t, kind: KIND_BCD_STEP, payload: z_tilde, aux: vec![accept_ref[i]] }
            })
        };
        participation.record(&rr.active_set());
        for resp in &rr.responses {
            let i = resp.worker;
            let (u_new, v_new) = resp.payload.split_at(n);
            // total_u update: subtract old, add new
            for ((tot, old), new) in total_u.iter_mut().zip(&u[i]).zip(u_new) {
                *tot += new - old;
            }
            u[i].copy_from_slice(u_new);
            v[i].copy_from_slice(v_new);
            last_accept[i] = t as f64;
        }
        // Reconstruct w = S̄ᵀv for evaluation (structured apply_t on the
        // fast path: one FWHT / CSR pass instead of m block products).
        let w = recon.reconstruct(&v);
        debug_assert_eq!(w.len(), p);
        let (objective, test_metric) = eval(&w);
        trace.push(IterRecord {
            iter: t,
            time: cluster.clock(),
            objective,
            test_metric,
            k_used: rr.responses.len(),
        });
    }
    // final w
    let w = recon.reconstruct(&v);
    RunOutput { trace, w, participation }
}

/// Replication-equivalent operating point for model-parallel BCD.
///
/// The paper's replication baseline holds each of P = m/r coordinate
/// partitions on r nodes and uses the fastest copy, waiting for k
/// *physical* responses. Since replicas are deterministic clones, this
/// is equivalent to P logical workers with fastest-of-r delays
/// ([`crate::delay::MinOfR`]) waited for `E[#distinct partitions among
/// the first k of m physical arrivals]` — hypergeometric coverage:
/// `P·(1 − C(m−r,k)/C(m,k))`, rounded.
pub fn replication_equivalent(m: usize, r: usize, k: usize) -> (usize, usize) {
    assert!(r >= 1 && m % r == 0 && k <= m);
    let p = m / r;
    // P(a given partition has no copy among the first k) =
    // C(m−r, k)/C(m, k) = Π_{j=0..r−1} (m−k−j)/(m−j)
    let mut miss = 1.0f64;
    for j in 0..r {
        miss *= ((m - k) as f64 - j as f64).max(0.0) / (m - j) as f64;
    }
    let k_logical = ((p as f64) * (1.0 - miss)).round() as usize;
    (p, k_logical.clamp(1, p))
}

/// Convenience: grad_phi factory for least squares
/// `φ(u) = 1/(2n)·‖u − y‖²` (∇φ = (u−y)/n).
pub fn quadratic_phi(y: Vec<f64>) -> impl Fn() -> Box<dyn Fn(&[f64]) -> Vec<f64> + Send> {
    move || {
        let y = y.clone();
        Box::new(move |u: &[f64]| {
            let n = u.len() as f64;
            u.iter().zip(&y).map(|(ui, yi)| (ui - yi) / n).collect()
        })
    }
}

/// grad_phi factory for logistic loss over label-scaled rows:
/// `φ(u) = 1/n·Σ log(1+e^{−uᵢ})` (∇φᵢ = −σ(−uᵢ)/n).
pub fn logistic_phi() -> impl Fn() -> Box<dyn Fn(&[f64]) -> Vec<f64> + Send> {
    || {
        Box::new(|u: &[f64]| {
            let n = u.len() as f64;
            u.iter().map(|&ui| -crate::objectives::logistic::sigmoid(-ui) / n).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::SimCluster;
    use crate::data::rcv1like;
    use crate::data::synth::gaussian_linear;
    use crate::delay::{AdversarialDelay, NoDelay};
    use crate::objectives::LogisticProblem;

    #[test]
    fn bcd_least_squares_reaches_exact_optimum_full_gather() {
        // Model-parallel encoded BCD on ½‖Xw−y‖²/n: exact convergence
        // (Theorem 6 — the lift preserves the optimum).
        let (x, y, _) = gaussian_linear(48, 12, 0.1, 3);
        let m = 4;
        let step = 0.8 * 48.0 / x.gram_spectral_norm(60, 1); // α < n/λmax ≈ 1/L
        let mp = build_model_parallel(
            &x,
            Scheme::Hadamard,
            m,
            2.0,
            step,
            0.0,
            5,
            quadratic_phi(y.clone()),
        )
        .unwrap();
        let recon = mp.recon;
        let mut cluster = SimCluster::new(mp.workers, Box::new(NoDelay::new(m)));
        let prob = crate::objectives::RidgeProblem::new(x.clone(), y.clone(), 0.0);
        use crate::objectives::QuadObjective;
        let f_star = prob.objective(&prob.solve_exact());
        let cfg = BcdConfig { k: m, iters: 400 };
        let out = bcd_loop(
            &mut cluster,
            &recon,
            48,
            12,
            &cfg,
            &mut RoundCtl::fixed(m),
            "bcd",
            &|w| (prob.objective(w), 0.0),
        );
        let f_final = out.trace.final_objective();
        assert!(
            (f_final - f_star) / f_star.max(1e-12) < 1e-3,
            "f_final={f_final} f*={f_star}"
        );
    }

    #[test]
    fn bcd_converges_with_stragglers() {
        let (x, y, _) = gaussian_linear(40, 16, 0.1, 7);
        let m = 8;
        let step = 0.8 * 40.0 / x.gram_spectral_norm(60, 2);
        let mp = build_model_parallel(
            &x,
            Scheme::Haar,
            m,
            2.0,
            step,
            0.0,
            9,
            quadratic_phi(y.clone()),
        )
        .unwrap();
        let recon = mp.recon;
        let delay = AdversarialDelay::new(m, vec![1, 4], 1e6);
        let mut cluster = SimCluster::new(mp.workers, Box::new(delay));
        let prob = crate::objectives::RidgeProblem::new(x.clone(), y.clone(), 0.0);
        use crate::objectives::QuadObjective;
        let f_star = prob.objective(&prob.solve_exact());
        let f0 = prob.objective(&[0.0; 16]);
        let cfg = BcdConfig { k: 6, iters: 600 };
        let out = bcd_loop(
            &mut cluster,
            &recon,
            40,
            16,
            &cfg,
            &mut RoundCtl::fixed(6),
            "bcd-adv",
            &|w| (prob.objective(w), 0.0),
        );
        let f_final = out.trace.final_objective();
        // Fixed stragglers freeze 2 of 8 lifted blocks; redundancy must
        // still recover most of the gap to optimal.
        assert!(
            f_final - f_star < 0.1 * (f0 - f_star),
            "f_final={f_final} f*={f_star} f0={f0}"
        );
    }

    #[test]
    fn bcd_monotone_descent_full_gather() {
        let (x, y, _) = gaussian_linear(30, 8, 0.2, 11);
        let m = 4;
        let step = 0.5 * 30.0 / x.gram_spectral_norm(60, 3);
        let mp = build_model_parallel(
            &x,
            Scheme::Gaussian,
            m,
            2.0,
            step,
            0.0,
            11,
            quadratic_phi(y.clone()),
        )
        .unwrap();
        let recon = mp.recon;
        let mut cluster = SimCluster::new(mp.workers, Box::new(NoDelay::new(m)));
        let prob = crate::objectives::RidgeProblem::new(x, y, 0.0);
        use crate::objectives::QuadObjective;
        let cfg = BcdConfig { k: m, iters: 100 };
        let out = bcd_loop(
            &mut cluster,
            &recon,
            30,
            8,
            &cfg,
            &mut RoundCtl::fixed(m),
            "bcd",
            &|w| (prob.objective(w), 0.0),
        );
        // allow the tiny one-round-staleness transient at t=0→1
        for pair in out.trace.records.windows(2).skip(1) {
            assert!(
                pair[1].objective <= pair[0].objective + 1e-9,
                "ascent: {} → {}",
                pair[0].objective,
                pair[1].objective
            );
        }
    }

    #[test]
    fn bcd_logistic_learns() {
        let ds = rcv1like::generate(120, 24, 5, 0.05, 13);
        let x = csr_to_dense(&ds.train);
        let n_train = ds.train.rows();
        let prob = LogisticProblem::new(ds.train.clone(), 0.0);
        let m = 6;
        let step = 2.0; // logistic φ is 1/(4n)-smooth per unit ‖X‖²; generous but stable here
        let mp = build_model_parallel(&x, Scheme::Steiner, m, 2.0, step, 1e-4, 15, logistic_phi())
            .unwrap();
        let recon = mp.recon;
        let mut cluster = SimCluster::new(mp.workers, Box::new(NoDelay::new(m)));
        let f0 = prob.objective(&[0.0; 24]);
        let cfg = BcdConfig { k: 4, iters: 150 };
        let out = bcd_loop(
            &mut cluster,
            &recon,
            n_train,
            24,
            &cfg,
            &mut RoundCtl::fixed(4),
            "bcd-log",
            &|w| (prob.objective(w), prob.error_rate(w, &ds.test)),
        );
        assert!(
            out.trace.final_objective() < 0.7 * f0,
            "objective {} vs f0 {f0}",
            out.trace.final_objective()
        );
        assert!(out.trace.final_test_metric() < 0.4);
    }

    #[test]
    fn replication_equivalent_coverage() {
        // m=128, r=2, k=64 (the paper's Fig-10 point): P=64 logical,
        // miss = (64·63)/(128·127) ≈ 0.248 → k_logical ≈ 48.
        let (p, k) = replication_equivalent(128, 2, 64);
        assert_eq!(p, 64);
        assert_eq!(k, 48);
        // full wait covers everything
        assert_eq!(replication_equivalent(8, 2, 8), (4, 4));
        // r=1 degenerates to identity
        assert_eq!(replication_equivalent(8, 1, 5), (8, 5));
    }

    #[test]
    fn pending_step_discarded_when_interrupted_midcompute() {
        // Unit-level: a worker whose pending round is never accepted must
        // not apply the step.
        let a = Mat::eye(3);
        let mut w = BcdWorker::new(a, 0.1, 0.0, Box::new(|u: &[f64]| u.to_vec()));
        let t0 =
            Task { iter: 0, kind: KIND_BCD_STEP, payload: vec![1.0, 1.0, 1.0], aux: vec![-1.0] };
        let _ = w.process(&t0); // computes pending for round 0
        let v_before = w.v.clone();
        // master says: last accepted round = −1 (round 0 was erased)
        let t1 =
            Task { iter: 1, kind: KIND_BCD_STEP, payload: vec![1.0, 1.0, 1.0], aux: vec![-1.0] };
        let _ = w.process(&t1);
        assert_eq!(w.v, v_before, "discarded step must not mutate v");
        // now accept round 1: the round-1 pending applies at round 2
        let t2 =
            Task { iter: 2, kind: KIND_BCD_STEP, payload: vec![1.0, 1.0, 1.0], aux: vec![1.0] };
        let _ = w.process(&t2);
        assert_ne!(w.v, v_before, "accepted step must apply");
    }
}
