//! Symmetric eigenvalue computation (cyclic Jacobi).
//!
//! Needed for the BRIP spectrum analysis of `S_Aᵀ S_A` (Definition 1,
//! Figures 5–6) and for the theory-checkpoint tests on L-BFGS Hessian
//! estimates. Jacobi is O(n³) per sweep but rock-solid for symmetric
//! matrices up to the n ≈ 500 sizes the spectrum figures use.

use super::mat::Mat;

/// Full symmetric eigendecomposition A = V·diag(λ)·Vᵀ.
///
/// Returns eigenvalues ascending and the matrix V whose *columns* are the
/// corresponding orthonormal eigenvectors. Cyclic Jacobi with accumulated
/// rotations.
pub fn symmetric_eigen(a: &Mat) -> (Vec<f64>, Mat) {
    symmetric_eigen_tol(a, 1e-12, 64)
}

/// [`symmetric_eigen`] with explicit tolerance / sweep limit.
pub fn symmetric_eigen_tol(a: &Mat, tol: f64, max_sweeps: usize) -> (Vec<f64>, Mat) {
    let n = a.rows();
    let mut m = prepared(a);
    let mut v = Mat::eye(n);
    let fro = m.fro_norm().max(1e-300);
    for _ in 0..max_sweeps {
        if offdiag_norm(&m) <= tol * fro {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let (c, s) = rotation(&m, p, q);
                apply_rotation(&mut m, p, q, c, s);
                // accumulate V ← V·J(p,q,θ)
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    // Sort eigenpairs ascending by eigenvalue.
    let mut order: Vec<usize> = (0..n).collect();
    let diag: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    // total_cmp: NaN-total order; the sort is stable, so equal
    // eigenvalues keep their index order as before.
    order.sort_by(|&i, &j| diag[i].total_cmp(&diag[j]));
    let eigs: Vec<f64> = order.iter().map(|&i| diag[i]).collect();
    let mut vs = Mat::zeros(n, n);
    for (new_col, &old_col) in order.iter().enumerate() {
        for r in 0..n {
            vs[(r, new_col)] = v[(r, old_col)];
        }
    }
    (eigs, vs)
}

fn prepared(a: &Mat) -> Mat {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigendecomposition needs a square matrix");
    let mut m = a.clone();
    // Symmetrize defensively (input may carry fp asymmetry).
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
    m
}

fn offdiag_norm(m: &Mat) -> f64 {
    let n = m.rows();
    let mut off = 0.0;
    for i in 0..n {
        for j in i + 1..n {
            off += m[(i, j)] * m[(i, j)];
        }
    }
    off.sqrt()
}

fn rotation(m: &Mat, p: usize, q: usize) -> (f64, f64) {
    let apq = m[(p, q)];
    let app = m[(p, p)];
    let aqq = m[(q, q)];
    let theta = (aqq - app) / (2.0 * apq);
    let t = if theta >= 0.0 {
        1.0 / (theta + (1.0 + theta * theta).sqrt())
    } else {
        1.0 / (theta - (1.0 + theta * theta).sqrt())
    };
    let c = 1.0 / (1.0 + t * t).sqrt();
    (c, t * c)
}

fn apply_rotation(m: &mut Mat, p: usize, q: usize, c: f64, s: f64) {
    let n = m.rows();
    for k in 0..n {
        let mkp = m[(k, p)];
        let mkq = m[(k, q)];
        m[(k, p)] = c * mkp - s * mkq;
        m[(k, q)] = s * mkp + c * mkq;
    }
    for k in 0..n {
        let mpk = m[(p, k)];
        let mqk = m[(q, k)];
        m[(p, k)] = c * mpk - s * mqk;
        m[(q, k)] = s * mpk + c * mqk;
    }
}

/// All eigenvalues of a symmetric matrix, ascending.
///
/// Cyclic Jacobi rotations until off-diagonal mass is below `tol` relative
/// to the Frobenius norm (default 1e-12 via [`symmetric_eigenvalues`]).
pub fn symmetric_eigenvalues_tol(a: &Mat, tol: f64, max_sweeps: usize) -> Vec<f64> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "eigenvalues need a square matrix");
    let mut m = a.clone();
    // Symmetrize defensively (input may carry fp asymmetry).
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
    let fro = m.fro_norm().max(1e-300);
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= tol * fro {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                // stable tan computation
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Apply rotation J(p,q,θ)ᵀ · M · J(p,q,θ)
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
            }
        }
    }
    let mut eigs: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    eigs.sort_by(|a, b| a.total_cmp(b));
    eigs
}

/// All eigenvalues, ascending, with default tolerance.
pub fn symmetric_eigenvalues(a: &Mat) -> Vec<f64> {
    symmetric_eigenvalues_tol(a, 1e-12, 64)
}

/// Extreme eigenvalues (λ_min, λ_max) of a symmetric matrix.
pub fn extreme_eigenvalues(a: &Mat) -> (f64, f64) {
    let e = symmetric_eigenvalues(a);
    (e[0], *e.last().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix() {
        let a = Mat::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let e = symmetric_eigenvalues(&a);
        assert!((e[0] - 1.0).abs() < 1e-10);
        assert!((e[1] - 2.0).abs() < 1e-10);
        assert!((e[2] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → eigenvalues 1, 3
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = symmetric_eigenvalues(&a);
        assert!((e[0] - 1.0).abs() < 1e-10 && (e[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn trace_and_det_preserved() {
        // random symmetric 8×8; sum of eigenvalues = trace
        let mut rng = crate::rng::Pcg64::new(3);
        let n = 8;
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.next_f64() - 0.5;
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        let e = symmetric_eigenvalues(&a);
        let trace: f64 = (0..n).map(|i| a[(i, i)]).sum();
        assert!((e.iter().sum::<f64>() - trace).abs() < 1e-9);
    }

    #[test]
    fn gram_eigenvalues_nonnegative() {
        let mut rng = crate::rng::Pcg64::new(5);
        let a = Mat::from_fn(12, 6, |_, _| rng.next_f64() - 0.5);
        let e = symmetric_eigenvalues(&a.gram());
        assert!(e.iter().all(|&x| x > -1e-10), "e={e:?}");
    }

    #[test]
    fn orthogonal_frame_gram_is_identity_spectrum() {
        // Hadamard rows scaled to unit norm form a tight frame; the Gram of
        // the full matrix has all eigenvalues equal to β = rows/cols... here
        // square → all 1.
        let n = 8;
        let h = Mat::from_fn(n, n, |i, j| {
            crate::linalg::fwht::hadamard_entry(i, j) / (n as f64).sqrt()
        });
        let e = symmetric_eigenvalues(&h.gram());
        for v in e {
            assert!((v - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn eigen_reconstructs_matrix() {
        let mut rng = crate::rng::Pcg64::new(17);
        let n = 10;
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.next_f64() - 0.5;
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        let (eigs, v) = symmetric_eigen(&a);
        // A·V = V·diag(λ)
        for col in 0..n {
            let vc: Vec<f64> = (0..n).map(|r| v[(r, col)]).collect();
            let av = a.matvec(&vc);
            for r in 0..n {
                assert!((av[r] - eigs[col] * vc[r]).abs() < 1e-8, "col {col}");
            }
        }
        // V orthonormal
        let vtv = v.gram();
        for i in 0..n {
            for j in 0..n {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((vtv[(i, j)] - expect).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn eigen_values_match_eigenvalue_only_path() {
        let a = Mat::from_vec(3, 3, vec![2.0, -1.0, 0.0, -1.0, 2.0, -1.0, 0.0, -1.0, 2.0]);
        let (e1, _) = symmetric_eigen(&a);
        let e2 = symmetric_eigenvalues(&a);
        for (a, b) in e1.iter().zip(&e2) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn matches_power_iteration_top_eigenvalue() {
        let mut rng = crate::rng::Pcg64::new(7);
        let a = Mat::from_fn(20, 10, |_, _| rng.next_f64() - 0.5);
        let top_jacobi = *symmetric_eigenvalues(&a.gram()).last().unwrap();
        let top_power = a.gram_spectral_norm(500, 11);
        assert!((top_jacobi - top_power).abs() / top_jacobi < 1e-6);
    }
}
