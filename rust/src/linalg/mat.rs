//! Dense row-major matrix.

use super::{axpy, dot};

/// Dense `rows × cols` matrix, row-major `Vec<f64>` storage.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a generator f(row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        Mat { rows, cols, data }
    }

    /// Stack row-blocks vertically: `[M_i]_{i∈A}` in the paper's notation.
    pub fn vstack(blocks: &[&Mat]) -> Self {
        assert!(!blocks.is_empty());
        let cols = blocks[0].cols;
        assert!(blocks.iter().all(|b| b.cols == cols), "column mismatch in vstack");
        let rows = blocks.iter().map(|b| b.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for b in blocks {
            data.extend_from_slice(&b.data);
        }
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Raw row-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Copy a contiguous row range `[r0, r1)` into a new matrix.
    pub fn row_block(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Copy selected columns into a new matrix (used for column-subsampled
    /// Haar / Hadamard encodings).
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (jj, &j) in idx.iter().enumerate() {
                dst[jj] = src[j];
            }
        }
        out
    }

    /// y = A·x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec dim mismatch");
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
        y
    }

    /// y = Aᵀ·x (no explicit transpose).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "matvec_t dim mismatch");
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            axpy(x[i], self.row(i), &mut y);
        }
        y
    }

    /// C = A·B.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        // ikj loop order: stream B rows, accumulate into C rows.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let crow = out.row_mut(i);
                axpy(a, brow, crow);
            }
        }
        out
    }

    /// Aᵀ as a new matrix.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Gram matrix AᵀA (symmetric, computed without forming Aᵀ).
    pub fn gram(&self) -> Mat {
        let n = self.cols;
        let mut g = Mat::zeros(n, n);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                let grow = g.row_mut(i);
                for j in i..n {
                    grow[j] += ri * row[j];
                }
            }
        }
        // Mirror the upper triangle.
        for i in 0..n {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        dot(&self.data, &self.data).sqrt()
    }

    /// Scale every entry in place.
    pub fn scale_inplace(&mut self, alpha: f64) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// Largest eigenvalue of AᵀA estimated by power iteration — the
    /// smoothness constant `M` of quadratic losses.
    pub fn gram_spectral_norm(&self, iters: usize, seed: u64) -> f64 {
        let mut rng = crate::rng::Pcg64::new(seed);
        let mut v: Vec<f64> = (0..self.cols).map(|_| rng.next_f64() - 0.5).collect();
        let mut lambda = 0.0;
        for _ in 0..iters {
            let av = self.matvec(&v);
            let mut atav = self.matvec_t(&av);
            let n = super::norm2(&atav);
            if n == 0.0 {
                return 0.0;
            }
            super::scale(1.0 / n, &mut atav);
            lambda = n;
            v = atav;
        }
        lambda
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Mat {
        Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn matvec_matches_manual() {
        let a = small();
        assert_eq!(a.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
    }

    #[test]
    fn matvec_t_is_transpose_matvec() {
        let a = small();
        let at = a.transpose();
        let x = vec![0.5, -1.5];
        assert_eq!(a.matvec_t(&x), at.matvec(&x));
    }

    #[test]
    fn matmul_identity() {
        let a = small();
        let i3 = Mat::eye(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matmul_matches_manual() {
        let a = small();
        let b = Mat::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_vec(2, 2, vec![4.0, 5.0, 10.0, 11.0]));
    }

    #[test]
    fn gram_matches_explicit_transpose_product() {
        let a = small();
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        for i in 0..3 {
            for j in 0..3 {
                assert!((g[(i, j)] - g2[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn vstack_stacks() {
        let a = small();
        let b = small();
        let s = Mat::vstack(&[&a, &b]);
        assert_eq!(s.rows(), 4);
        assert_eq!(s.row(2), a.row(0));
    }

    #[test]
    fn row_block_and_select_cols() {
        let a = small();
        let b = a.row_block(1, 2);
        assert_eq!(b.as_slice(), &[4.0, 5.0, 6.0]);
        let c = a.select_cols(&[2, 0]);
        assert_eq!(c.as_slice(), &[3.0, 1.0, 6.0, 4.0]);
    }

    #[test]
    fn spectral_norm_of_identity_like() {
        let a = Mat::eye(4);
        let s = a.gram_spectral_norm(50, 1);
        assert!((s - 1.0).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn spectral_norm_matches_known() {
        // A = diag(3, 1) → ‖AᵀA‖ = 9.
        let a = Mat::from_vec(2, 2, vec![3.0, 0.0, 0.0, 1.0]);
        let s = a.gram_spectral_norm(100, 2);
        assert!((s - 9.0).abs() < 1e-6, "s={s}");
    }
}
