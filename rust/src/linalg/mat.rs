//! Dense row-major matrix.
//!
//! The kernels (`matvec` / `matvec_t` / `matmul` / `gram`) are
//! cache-blocked and parallelized over the deterministic chunk pool in
//! [`super::par`]. Every kernel keeps the *naive per-element accumulation
//! order* (ascending `k` / row index), so results are bit-identical to
//! the single-threaded reference at any thread count — the chunking only
//! partitions independent output elements, never a floating-point sum.
//! The same rule governs the AVX2 paths ([`super::simd`]): `matvec` /
//! `matvec_sub` process four rows per vector register (lane = row, each
//! lane running the scalar ascending-`k` chain), while `matvec_t`,
//! `gram`, and `matmul` vectorize their elementwise inner sweeps through
//! [`super::axpy`] — so SIMD on/off is bit-identical too. The
//! pre-existing naive kernels are preserved in [`reference`] as the
//! equivalence referee and the denominator of the `coded-opt bench`
//! speedup gate.

// The dispatcher contract (bit-identical to scalar at any width) is what keeps
// reaching into the simd zone legal for this kernel family.
// lint:allow(zone-containment) — dispatched SIMD fast path, bit-identical to scalar
use super::{axpy, dot, par, simd};

/// k-tile length for [`Mat::matmul`]: a `KB × cols` panel of the right
/// operand stays cache-hot while it is reused across a chunk's rows.
const KB: usize = 64;

/// Dense `rows × cols` matrix, row-major `Vec<f64>` storage.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a generator f(row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        Mat { rows, cols, data }
    }

    /// Stack row-blocks vertically: `[M_i]_{i∈A}` in the paper's notation.
    pub fn vstack(blocks: &[&Mat]) -> Self {
        assert!(!blocks.is_empty());
        let cols = blocks[0].cols;
        assert!(blocks.iter().all(|b| b.cols == cols), "column mismatch in vstack");
        let rows = blocks.iter().map(|b| b.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for b in blocks {
            data.extend_from_slice(&b.data);
        }
        Mat { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Raw row-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Copy a contiguous row range `[r0, r1)` into a new matrix.
    pub fn row_block(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Copy a contiguous column range `[c0, c1)` into a new matrix — a
    /// straight per-row memcpy, with no index indirection. Use this for
    /// blocked column partitioning ([`select_cols`](Self::select_cols)
    /// handles arbitrary column subsets).
    pub fn col_block(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.cols);
        let width = c1 - c0;
        let mut data = Vec::with_capacity(self.rows * width);
        for i in 0..self.rows {
            data.extend_from_slice(&self.data[i * self.cols + c0..i * self.cols + c1]);
        }
        Mat { rows: self.rows, cols: width, data }
    }

    /// Copy selected columns into a new matrix (used for column-subsampled
    /// Haar / Hadamard encodings and BCD column sampling): one gather pass
    /// per row appended straight into the output buffer — no zero-fill and
    /// no per-element destination indexing.
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        let mut data = Vec::with_capacity(self.rows * idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            data.extend(idx.iter().map(|&j| src[j]));
        }
        Mat { rows: self.rows, cols: idx.len(), data }
    }

    /// y = A·x.
    ///
    /// Output rows are independent, so the kernel parallelizes over
    /// fixed row chunks, and within a chunk processes rows four at a
    /// time through [`simd::dot4`] (lane = row; each lane is the same
    /// ascending-`k` `dot` chain as the reference, so the quad path is
    /// bit-identical whether the SIMD dispatch lands on AVX2 or the
    /// scalar fallback) — bit-identical at any thread count.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec dim mismatch");
        let mut y = vec![0.0; self.rows];
        let data = &self.data;
        let cols = self.cols;
        par::par_chunks_mut(&mut y, par::CHUNK, cols, |ci, yc| {
            let r0 = ci * par::CHUNK;
            let mut q = 0;
            while q + 4 <= yc.len() {
                let base = (r0 + q) * cols;
                let quad = simd::dot4(
                    &data[base..base + cols],
                    &data[base + cols..base + 2 * cols],
                    &data[base + 2 * cols..base + 3 * cols],
                    &data[base + 3 * cols..base + 4 * cols],
                    x,
                );
                yc[q..q + 4].copy_from_slice(&quad);
                q += 4;
            }
            for (dy, i) in yc[q..].iter_mut().zip(r0 + q..) {
                *dy = dot(&data[i * cols..(i + 1) * cols], x);
            }
        });
        y
    }

    /// out = A·x − b, the fused residual kernel of the worker gradient
    /// hot path. Same chunking, quad-row SIMD grouping, and per-element
    /// order as [`matvec`](Self::matvec); the `− b[i]` lands after each
    /// row's dot exactly like the scalar sweep.
    pub fn matvec_sub(&self, x: &[f64], b: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "matvec_sub dim mismatch");
        assert_eq!(b.len(), self.rows, "matvec_sub rhs mismatch");
        assert_eq!(out.len(), self.rows, "matvec_sub out mismatch");
        let data = &self.data;
        let cols = self.cols;
        par::par_chunks_mut(out, par::CHUNK, cols, |ci, oc| {
            let r0 = ci * par::CHUNK;
            let mut q = 0;
            while q + 4 <= oc.len() {
                let i = r0 + q;
                let base = i * cols;
                let quad = simd::dot4(
                    &data[base..base + cols],
                    &data[base + cols..base + 2 * cols],
                    &data[base + 2 * cols..base + 3 * cols],
                    &data[base + 3 * cols..base + 4 * cols],
                    x,
                );
                for l in 0..4 {
                    oc[q + l] = quad[l] - b[i + l];
                }
                q += 4;
            }
            for (dy, i) in oc[q..].iter_mut().zip(r0 + q..) {
                *dy = dot(&data[i * cols..(i + 1) * cols], x) - b[i];
            }
        });
    }

    /// y = Aᵀ·x (no explicit transpose).
    ///
    /// Parallelized over fixed *column* chunks: each `y[j]` accumulates
    /// its contributions in ascending row order — exactly the reference
    /// `axpy` sweep's per-element order — so the result is bit-identical
    /// to the sequential kernel at any thread count, and each pass
    /// streams only its column stripe of A. The stripe update IS an
    /// [`axpy`], which carries the SIMD lane path.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "matvec_t dim mismatch");
        let mut y = vec![0.0; self.cols];
        let data = &self.data;
        let cols = self.cols;
        par::par_chunks_mut(&mut y, par::CHUNK, self.rows, |ci, yc| {
            let j0 = ci * par::CHUNK;
            for (i, &xi) in x.iter().enumerate() {
                let stripe = &data[i * cols + j0..i * cols + j0 + yc.len()];
                axpy(xi, stripe, yc);
            }
        });
        y
    }

    /// C = A·B.
    ///
    /// Cache-blocked ikj: parallel over fixed row chunks of C (disjoint
    /// output), k-tiled so a `KB × cols` panel of B stays hot across the
    /// chunk's rows. Tiles advance in ascending k, so each `C[i][j]`
    /// accumulates in exactly the reference ikj order — bit-identical at
    /// any thread count.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        let bcols = other.cols;
        let kdim = self.cols;
        if bcols == 0 || kdim == 0 {
            return out;
        }
        let a = &self.data;
        let b = &other.data;
        par::par_chunks_mut(out.as_mut_slice(), par::CHUNK * bcols, kdim, |ci, cchunk| {
            let i0 = ci * par::CHUNK;
            let mut k0 = 0;
            while k0 < kdim {
                let k1 = (k0 + KB).min(kdim);
                for (di, crow) in cchunk.chunks_mut(bcols).enumerate() {
                    let arow = &a[(i0 + di) * kdim..(i0 + di + 1) * kdim];
                    for (off, &aik) in arow[k0..k1].iter().enumerate() {
                        // same zero-skip as the reference kernel (also
                        // keeps −0.0 outputs bit-stable)
                        if aik == 0.0 {
                            continue;
                        }
                        let k = k0 + off;
                        axpy(aik, &b[k * bcols..(k + 1) * bcols], crow);
                    }
                }
                k0 = k1;
            }
        });
        out
    }

    /// Aᵀ as a new matrix.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Gram matrix AᵀA (symmetric, computed without forming Aᵀ).
    ///
    /// Parallel over fixed row chunks of G (disjoint upper-triangle
    /// output); each chunk streams the data rows in ascending order, so
    /// every `G[i][j]` accumulates in exactly the reference order —
    /// bit-identical at any thread count. Chunking re-streams A once per
    /// G-row chunk, which only pays off when the chunks actually run on
    /// parallel threads — the single-thread / small-work case takes a
    /// one-pass sweep instead (same per-element order, same bits).
    pub fn gram(&self) -> Mat {
        let n = self.cols;
        let mut g = Mat::zeros(n, n);
        if n == 0 {
            return g;
        }
        let rows = self.rows;
        let work = rows / 2 + 1;
        if par::threads() <= 1 || (n * n).saturating_mul(work) < par::PAR_THRESHOLD {
            for r in 0..rows {
                let row = &self.data[r * n..(r + 1) * n];
                for (i, &ri) in row.iter().enumerate() {
                    if ri == 0.0 {
                        continue;
                    }
                    // the suffix update is an axpy: G[i][i..] += ri·row[i..]
                    // (same per-element order; carries the SIMD lane path)
                    let grow = &mut g.data[i * n..(i + 1) * n];
                    axpy(ri, &row[i..], &mut grow[i..]);
                }
            }
        } else {
            let data = &self.data;
            par::par_chunks_mut(g.as_mut_slice(), par::CHUNK * n, work, |ci, gchunk| {
                let i0 = ci * par::CHUNK;
                for r in 0..rows {
                    let row = &data[r * n..(r + 1) * n];
                    for (di, grow) in gchunk.chunks_mut(n).enumerate() {
                        let i = i0 + di;
                        let ri = row[i];
                        if ri == 0.0 {
                            continue;
                        }
                        axpy(ri, &row[i..], &mut grow[i..]);
                    }
                }
            });
        }
        // Mirror the upper triangle.
        for i in 0..n {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        dot(&self.data, &self.data).sqrt()
    }

    /// Scale every entry in place.
    pub fn scale_inplace(&mut self, alpha: f64) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// Largest eigenvalue of AᵀA estimated by power iteration — the
    /// smoothness constant `M` of quadratic losses.
    pub fn gram_spectral_norm(&self, iters: usize, seed: u64) -> f64 {
        let mut rng = crate::rng::Pcg64::new(seed);
        let mut v: Vec<f64> = (0..self.cols).map(|_| rng.next_f64() - 0.5).collect();
        let mut lambda = 0.0;
        for _ in 0..iters {
            let av = self.matvec(&v);
            let mut atav = self.matvec_t(&av);
            let n = super::norm2(&atav);
            if n == 0.0 {
                return 0.0;
            }
            super::scale(1.0 / n, &mut atav);
            lambda = n;
            v = atav;
        }
        lambda
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// The pre-blocking naive kernels, kept verbatim as the referee: the
/// kernel-equivalence property tests pin the blocked/parallel kernels
/// bit-identical to these, and `coded-opt bench` times them as the
/// denominator of its speedup gate.
pub mod reference {
    use super::Mat;
    use crate::linalg::{axpy, dot};

    /// Naive y = A·x (row sweep of dots).
    pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), a.cols(), "matvec dim mismatch");
        let mut y = vec![0.0; a.rows()];
        for (i, dy) in y.iter_mut().enumerate() {
            *dy = dot(a.row(i), x);
        }
        y
    }

    /// Naive y = Aᵀ·x (axpy sweep over rows).
    pub fn matvec_t(a: &Mat, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), a.rows(), "matvec_t dim mismatch");
        let mut y = vec![0.0; a.cols()];
        for (i, &xi) in x.iter().enumerate() {
            axpy(xi, a.row(i), &mut y);
        }
        y
    }

    /// Naive ikj C = A·B.
    pub fn matmul(a: &Mat, other: &Mat) -> Mat {
        assert_eq!(a.cols(), other.rows(), "matmul dim mismatch");
        let mut out = Mat::zeros(a.rows(), other.cols());
        for i in 0..a.rows() {
            for k in 0..a.cols() {
                let aik = a[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                axpy(aik, other.row(k), out.row_mut(i));
            }
        }
        out
    }

    /// Naive upper-triangle G = AᵀA.
    pub fn gram(a: &Mat) -> Mat {
        let n = a.cols();
        let mut g = Mat::zeros(n, n);
        for r in 0..a.rows() {
            let row = a.row(r);
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                let grow = g.row_mut(i);
                for j in i..n {
                    grow[j] += ri * row[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g[(i, j)] = g[(j, i)];
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Mat {
        Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn matvec_matches_manual() {
        let a = small();
        assert_eq!(a.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
    }

    #[test]
    fn matvec_t_is_transpose_matvec() {
        let a = small();
        let at = a.transpose();
        let x = vec![0.5, -1.5];
        assert_eq!(a.matvec_t(&x), at.matvec(&x));
    }

    #[test]
    fn matvec_sub_fuses_residual() {
        let a = small();
        let x = vec![1.0, -1.0, 2.0];
        let b = vec![0.5, -0.5];
        let mut out = vec![0.0; 2];
        a.matvec_sub(&x, &b, &mut out);
        let want: Vec<f64> = a.matvec(&x).iter().zip(&b).map(|(v, bi)| v - bi).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn matmul_identity() {
        let a = small();
        let i3 = Mat::eye(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matmul_matches_manual() {
        let a = small();
        let b = Mat::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_vec(2, 2, vec![4.0, 5.0, 10.0, 11.0]));
    }

    #[test]
    fn gram_matches_explicit_transpose_product() {
        let a = small();
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        for i in 0..3 {
            for j in 0..3 {
                assert!((g[(i, j)] - g2[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn blocked_kernels_bit_equal_reference_beyond_one_chunk() {
        // Sizes past CHUNK and KB so the tiled/parallel paths engage.
        let mut rng = crate::rng::Pcg64::new(9);
        let a = Mat::from_fn(150, 130, |_, _| rng.next_f64() - 0.5);
        let b = Mat::from_fn(130, 70, |_, _| rng.next_f64() - 0.5);
        let x: Vec<f64> = (0..130).map(|_| rng.next_f64() - 0.5).collect();
        let xt: Vec<f64> = (0..150).map(|_| rng.next_f64() - 0.5).collect();
        assert_eq!(a.matvec(&x), reference::matvec(&a, &x));
        assert_eq!(a.matvec_t(&xt), reference::matvec_t(&a, &xt));
        assert_eq!(a.matmul(&b), reference::matmul(&a, &b));
        assert_eq!(a.gram(), reference::gram(&a));
    }

    #[test]
    fn vstack_stacks() {
        let a = small();
        let b = small();
        let s = Mat::vstack(&[&a, &b]);
        assert_eq!(s.rows(), 4);
        assert_eq!(s.row(2), a.row(0));
    }

    #[test]
    fn row_block_and_select_cols() {
        let a = small();
        let b = a.row_block(1, 2);
        assert_eq!(b.as_slice(), &[4.0, 5.0, 6.0]);
        let c = a.select_cols(&[2, 0]);
        assert_eq!(c.as_slice(), &[3.0, 1.0, 6.0, 4.0]);
    }

    #[test]
    fn col_block_matches_select_cols() {
        let a = small();
        let b = a.col_block(1, 3);
        assert_eq!(b.as_slice(), &[2.0, 3.0, 5.0, 6.0]);
        let idx: Vec<usize> = (1..3).collect();
        assert_eq!(b, a.select_cols(&idx));
        assert_eq!(a.col_block(2, 2).rows(), 2);
        assert_eq!(a.col_block(2, 2).cols(), 0);
    }

    #[test]
    fn spectral_norm_of_identity_like() {
        let a = Mat::eye(4);
        let s = a.gram_spectral_norm(50, 1);
        assert!((s - 1.0).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn spectral_norm_matches_known() {
        // A = diag(3, 1) → ‖AᵀA‖ = 9.
        let a = Mat::from_vec(2, 2, vec![3.0, 0.0, 0.0, 1.0]);
        let s = a.gram_spectral_norm(100, 2);
        assert!((s - 9.0).abs() < 1e-6, "s={s}");
    }
}
