//! Compressed sparse row (CSR) matrix.
//!
//! Used for the rcv1-like tf-idf document matrices (§5.3) and for the
//! sparse encoding matrices (Steiner ETF blocks, subsampled Haar), where
//! the paper's efficient-encoding scheme (§4.2.1) relies on workers
//! touching only the non-zero column support `B_I(S)`.

use super::mat::Mat;
use super::par;

/// CSR sparse matrix with f64 values.
#[derive(Clone, Debug)]
pub struct Csr {
    rows: usize,
    cols: usize,
    /// Row pointer array, length rows+1.
    indptr: Vec<usize>,
    /// Column indices, length nnz.
    indices: Vec<usize>,
    /// Values, length nnz.
    values: Vec<f64>,
}

impl Csr {
    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(rows: usize, cols: usize, triplets: &[(usize, usize, f64)]) -> Self {
        let mut sorted: Vec<(usize, usize, f64)> = triplets.to_vec();
        sorted.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices: Vec<usize> = Vec::with_capacity(sorted.len());
        let mut values: Vec<f64> = Vec::with_capacity(sorted.len());
        let mut last: Option<(usize, usize)> = None;
        for &(r, c, v) in &sorted {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds");
            if last == Some((r, c)) {
                // duplicate (r, c): sum values
                *values.last_mut().unwrap() += v;
                continue;
            }
            indices.push(c);
            values.push(v);
            indptr[r + 1] = indices.len();
            last = Some((r, c));
        }
        // prefix-fill rows with no entries
        for r in 1..=rows {
            indptr[r] = indptr[r].max(indptr[r - 1]);
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// Dense → CSR, dropping exact zeros.
    pub fn from_dense(m: &Mat) -> Self {
        let mut triplets = Vec::new();
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    triplets.push((i, j, v));
                }
            }
        }
        Csr::from_triplets(m.rows(), m.cols(), &triplets)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Non-zeros of row i as (col, value) pairs.
    pub fn row_iter(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        self.indices[lo..hi].iter().copied().zip(self.values[lo..hi].iter().copied())
    }

    /// Non-zeros of row i with column ≥ `col0`, in ascending column
    /// order — binary-searched start (columns are sorted within a row),
    /// so a consumer sweeping ascending column ranges (the streamed
    /// block encoder) skips straight to its range instead of rescanning
    /// the row prefix per block.
    pub fn row_iter_from(&self, i: usize, col0: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        let start = lo + self.indices[lo..hi].partition_point(|&c| c < col0);
        self.indices[start..hi].iter().copied().zip(self.values[start..hi].iter().copied())
    }

    /// y = A·x.
    ///
    /// Output rows are independent, so the kernel parallelizes over
    /// fixed row chunks (each `y[i]` accumulated in the same ascending
    /// non-zero order as the sequential sweep — bit-identical at any
    /// thread count), and within a chunk runs four row products per
    /// vector register through [`par`]-independent
    /// [`crate::linalg::simd::csr_dot4`] lanes (each lane keeps its
    /// row's ascending order, so SIMD on/off is bit-identical too).
    /// The inline/parallel decision keys on the average row fill, never
    /// on the thread count.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "csr matvec dim mismatch");
        let mut y = vec![0.0; self.rows];
        let fill = self.nnz() / self.rows.max(1);
        let row = |i: usize| {
            let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
            (&self.values[lo..hi], &self.indices[lo..hi])
        };
        par::par_chunks_mut(&mut y, par::CHUNK, fill, |ci, yc| {
            let r0 = ci * par::CHUNK;
            let mut q = 0;
            while q + 4 <= yc.len() {
                let i = r0 + q;
                let (v0, c0) = row(i);
                let (v1, c1) = row(i + 1);
                let (v2, c2) = row(i + 2);
                let (v3, c3) = row(i + 3);
                // lint:allow(zone-containment) — dispatched SIMD row products, bit-identical
                let quad = crate::linalg::simd::csr_dot4([v0, v1, v2, v3], [c0, c1, c2, c3], x);
                yc[q..q + 4].copy_from_slice(&quad);
                q += 4;
            }
            for (dy, i) in yc[q..].iter_mut().zip(r0 + q..) {
                let mut acc = 0.0;
                for idx in self.indptr[i]..self.indptr[i + 1] {
                    acc += self.values[idx] * x[self.indices[idx]];
                }
                *dy = acc;
            }
        });
        y
    }

    /// y = Aᵀ·x.
    ///
    /// The transpose-scatter is a genuine reduction (many rows write the
    /// same output column), so large inputs run a fixed-chunk tree
    /// reduction ([`par::tree_reduce`]): per-row-chunk partials, combined
    /// pairwise in ascending chunk order. Bit-identical at any thread
    /// count; differs from the strict sequential row sweep only by the
    /// deterministic tree summation order (≤ rounding — callers that
    /// compare against dense references use a 1e-12 band).
    ///
    /// Eligibility depends only on the matrix shape, never the thread
    /// count: small-nnz inputs (the per-worker shard sizes) keep the
    /// sequential sweep, and so do *wide* sparse matrices where the
    /// `nchunks × cols` dense partials would dwarf the `O(nnz)` useful
    /// work (e.g. log-fill Haar generators at large n — the partial
    /// buffers would be orders of magnitude larger than the input).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "csr matvec_t dim mismatch");
        let nchunks = self.rows.div_ceil(par::CHUNK).max(1);
        let partial_cost = nchunks.saturating_mul(self.cols);
        if self.nnz() < par::PAR_THRESHOLD
            || nchunks <= 1
            || partial_cost / 4 > self.nnz()
        {
            let mut y = vec![0.0; self.cols];
            self.scatter_rows(0, self.rows, x, &mut y);
            return y;
        }
        let fill = self.nnz() / self.rows.max(1);
        par::tree_reduce(nchunks, self.cols, fill, |ci, slot| {
            let r0 = ci * par::CHUNK;
            let r1 = (r0 + par::CHUNK).min(self.rows);
            self.scatter_rows(r0, r1, x, slot);
        })
    }

    /// Sequential transpose-scatter of rows `[r0, r1)` into `y`.
    fn scatter_rows(&self, r0: usize, r1: usize, x: &[f64], y: &mut [f64]) {
        for i in r0..r1 {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for idx in self.indptr[i]..self.indptr[i + 1] {
                y[self.indices[idx]] += self.values[idx] * xi;
            }
        }
    }

    /// Contiguous row block [r0, r1) as a new CSR (worker shard extraction).
    pub fn row_block(&self, r0: usize, r1: usize) -> Csr {
        assert!(r0 <= r1 && r1 <= self.rows);
        let lo = self.indptr[r0];
        let hi = self.indptr[r1];
        let indptr: Vec<usize> = self.indptr[r0..=r1].iter().map(|p| p - lo).collect();
        Csr {
            rows: r1 - r0,
            cols: self.cols,
            indptr,
            indices: self.indices[lo..hi].to_vec(),
            values: self.values[lo..hi].to_vec(),
        }
    }

    /// Column support of the matrix: sorted distinct non-zero columns —
    /// the paper's `B_I(S)` (§4.2.1).
    pub fn col_support(&self) -> Vec<usize> {
        let mut cols = self.indices.clone();
        cols.sort_unstable();
        cols.dedup();
        cols
    }

    /// Select columns (re-indexing to 0..idx.len()); cols absent from idx
    /// are dropped. `idx` must be sorted & distinct.
    pub fn select_cols(&self, idx: &[usize]) -> Csr {
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]));
        let mut remap = vec![usize::MAX; self.cols];
        for (new, &old) in idx.iter().enumerate() {
            remap[old] = new;
        }
        let mut triplets = Vec::new();
        for i in 0..self.rows {
            for (c, v) in self.row_iter(i) {
                if remap[c] != usize::MAX {
                    triplets.push((i, remap[c], v));
                }
            }
        }
        Csr::from_triplets(self.rows, idx.len(), &triplets)
    }

    /// Densify (tests / small blocks only).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for (j, v) in self.row_iter(i) {
                m[(i, j)] += v;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> Csr {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        Csr::from_triplets(3, 3, &[(0, 0, 1.0), (0, 2, 2.0), (2, 0, 3.0), (2, 1, 4.0)])
    }

    #[test]
    fn row_iter_from_starts_at_the_column_bound() {
        let a = example();
        let all: Vec<(usize, f64)> = a.row_iter(0).collect();
        assert_eq!(all, vec![(0, 1.0), (2, 2.0)]);
        assert_eq!(a.row_iter_from(0, 0).collect::<Vec<_>>(), all, "col0=0 = full row");
        assert_eq!(a.row_iter_from(0, 1).collect::<Vec<_>>(), vec![(2, 2.0)]);
        assert_eq!(a.row_iter_from(0, 3).count(), 0, "past the last column");
        assert_eq!(a.row_iter_from(1, 0).count(), 0, "empty row");
        assert_eq!(a.row_iter_from(2, 1).collect::<Vec<_>>(), vec![(1, 4.0)]);
    }

    #[test]
    fn roundtrip_dense() {
        let a = example();
        let d = a.to_dense();
        let b = Csr::from_dense(&d);
        assert_eq!(b.to_dense(), d);
        assert_eq!(b.nnz(), 4);
    }

    #[test]
    fn matvec_matches_dense() {
        let a = example();
        let x = vec![1.0, -1.0, 0.5];
        assert_eq!(a.matvec(&x), a.to_dense().matvec(&x));
    }

    #[test]
    fn matvec_t_matches_dense() {
        let a = example();
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(a.matvec_t(&x), a.to_dense().matvec_t(&x));
    }

    #[test]
    fn empty_rows_are_fine() {
        let a = example();
        assert_eq!(a.row_iter(1).count(), 0);
        assert_eq!(a.matvec(&[1.0, 1.0, 1.0])[1], 0.0);
    }

    #[test]
    fn duplicates_sum() {
        let a = Csr::from_triplets(1, 2, &[(0, 1, 2.0), (0, 1, 3.0)]);
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.to_dense()[(0, 1)], 5.0);
    }

    #[test]
    fn row_block_extracts_shard() {
        let a = example();
        let b = a.row_block(1, 3);
        assert_eq!(b.rows(), 2);
        assert_eq!(b.to_dense().as_slice(), &[0.0, 0.0, 0.0, 3.0, 4.0, 0.0]);
    }

    #[test]
    fn col_support_sorted_distinct() {
        let a = example();
        assert_eq!(a.col_support(), vec![0, 1, 2]);
        let b = a.row_block(0, 1);
        assert_eq!(b.col_support(), vec![0, 2]);
    }

    #[test]
    fn select_cols_compacts() {
        let a = example();
        let b = a.select_cols(&[0, 2]);
        assert_eq!(b.cols(), 2);
        assert_eq!(b.to_dense().as_slice(), &[1.0, 2.0, 0.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn sparse_times_dense_consistency_large() {
        // random-ish structured matrix, compare sparse vs dense paths
        let mut trips = Vec::new();
        for i in 0..40 {
            for j in 0..30 {
                if (i * 7 + j * 13) % 11 == 0 {
                    trips.push((i, j, ((i + 1) * (j + 2)) as f64 * 0.01));
                }
            }
        }
        let a = Csr::from_triplets(40, 30, &trips);
        let x: Vec<f64> = (0..30).map(|i| (i as f64 * 0.37).sin()).collect();
        let ys = a.matvec(&x);
        let yd = a.to_dense().matvec(&x);
        for (s, d) in ys.iter().zip(&yd) {
            assert!((s - d).abs() < 1e-12);
        }
    }
}
