//! Cholesky factorization and SPD solves.
//!
//! Used for (a) the matrix-factorization inner subproblems — each user /
//! movie update is a small regularized least-squares solve, matching the
//! paper's use of `numpy.linalg.solve` for instances with n < 500 — and
//! (b) closed-form ridge solutions used as ground truth in tests.

use super::mat::Mat;

/// Lower-triangular Cholesky factor L with A = L·Lᵀ.
///
/// Returns `None` if A is not (numerically) positive definite.
pub fn cholesky_factor(a: &Mat) -> Option<Mat> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky needs a square matrix");
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve A·x = b for SPD A via Cholesky. Returns `None` if not SPD.
pub fn cholesky_solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky_factor(a)?;
    let n = a.rows();
    assert_eq!(b.len(), n);
    // Forward substitution: L·y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[(i, k)] * y[k];
        }
        y[i] = sum / l[(i, i)];
    }
    // Back substitution: Lᵀ·x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[(k, i)] * x[k];
        }
        x[i] = sum / l[(i, i)];
    }
    Some(x)
}

/// Solve the regularized least-squares problem
/// `min_w ‖A·w − b‖² + λ‖w‖²` via the normal equations
/// `(AᵀA + λI)·w = Aᵀb`. This is the MF inner solver.
pub fn ridge_solve(a: &Mat, b: &[f64], lambda: f64) -> Vec<f64> {
    let mut g = a.gram();
    for i in 0..g.rows() {
        g[(i, i)] += lambda;
    }
    let atb = a.matvec_t(b);
    cholesky_solve(&g, &atb).expect("ridge normal equations are SPD for λ>0")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factor_reconstructs() {
        let a = Mat::from_vec(3, 3, vec![4.0, 2.0, 0.0, 2.0, 5.0, 1.0, 0.0, 1.0, 3.0]);
        let l = cholesky_factor(&a).unwrap();
        let llt = l.matmul(&l.transpose());
        for i in 0..3 {
            for j in 0..3 {
                assert!((llt[(i, j)] - a[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn solve_matches_manual() {
        let a = Mat::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let x = cholesky_solve(&a, &[1.0, 2.0]).unwrap();
        // residual check
        let r = a.matvec(&x);
        assert!((r[0] - 1.0).abs() < 1e-12 && (r[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn non_spd_rejected() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky_factor(&a).is_none());
    }

    #[test]
    fn ridge_solve_matches_gradient_zero() {
        // gradient of the ridge objective at the solution must vanish:
        // 2Aᵀ(Aw−b) + 2λw = 0
        let a = Mat::from_vec(4, 2, vec![1.0, 0.5, 0.0, 1.0, 2.0, -1.0, 1.0, 1.0]);
        let b = [1.0, -1.0, 0.5, 2.0];
        let lambda = 0.3;
        let w = ridge_solve(&a, &b, lambda);
        let resid = crate::linalg::sub(&a.matvec(&w), &b);
        let mut grad = a.matvec_t(&resid);
        crate::linalg::axpy(lambda, &w, &mut grad);
        assert!(crate::linalg::norm2(&grad) < 1e-10, "grad={grad:?}");
    }

    #[test]
    fn ridge_zero_matrix_gives_zero() {
        let a = Mat::zeros(3, 2);
        let w = ridge_solve(&a, &[1.0, 1.0, 1.0], 1.0);
        assert!(crate::linalg::norm2(&w) < 1e-15);
    }
}
