//! Fast Walsh–Hadamard transform.
//!
//! The paper's §4.2.2 encodes with a column-subsampled (real, ±1/√n)
//! Hadamard matrix applied through FWHT — O(n log n) instead of O(n²).

/// In-place, unnormalized FWHT. `x.len()` must be a power of two.
///
/// After the call, `x = H·x` where `H` is the ±1 Sylvester-Hadamard
/// matrix of order `x.len()`.
///
/// Each layer's butterflies `(a, b) ← (a + b, a − b)` are elementwise
/// over a block's two halves, so wide layers (`h ≥ 4`) run the AVX2
/// lane kernel ([`crate::linalg::simd::butterfly`]) — per-pair
/// operation order unchanged, so the transform is bit-identical with
/// SIMD on or off.
pub fn fwht(x: &mut [f64]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    let mut h = 1;
    while h < n {
        let step = h * 2;
        if h >= 4 {
            for block in (0..n).step_by(step) {
                let (lo, hi) = x[block..block + step].split_at_mut(h);
                // lint:allow(zone-containment) — dispatched SIMD butterfly, bit-identical
                crate::linalg::simd::butterfly(lo, hi);
            }
        } else {
            for block in (0..n).step_by(step) {
                for i in block..block + h {
                    let a = x[i];
                    let b = x[i + h];
                    x[i] = a + b;
                    x[i + h] = a - b;
                }
            }
        }
        h = step;
    }
}

/// In-place orthonormal FWHT: `x = (1/√n)·H·x`, so the transform is its
/// own inverse.
pub fn fwht_normalized(x: &mut [f64]) {
    let n = x.len();
    fwht(x);
    let s = 1.0 / (n as f64).sqrt();
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// Entry (i, j) of the ±1 Sylvester-Hadamard matrix of order n
/// (n a power of two): (−1)^{popcount(i & j)}.
#[inline]
pub fn hadamard_entry(i: usize, j: usize) -> f64 {
    if (i & j).count_ones() % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn fwht_matches_explicit_matrix() {
        let n = 8;
        let h = Mat::from_fn(n, n, |i, j| hadamard_entry(i, j));
        let x: Vec<f64> = (0..n).map(|i| (i as f64 + 1.0) * 0.5).collect();
        let expect = h.matvec(&x);
        let mut got = x.clone();
        fwht(&mut got);
        for (g, e) in got.iter().zip(&expect) {
            assert!((g - e).abs() < 1e-12);
        }
    }

    #[test]
    fn normalized_fwht_is_involution() {
        let x: Vec<f64> = (0..16).map(|i| (i as f64 * 0.7).cos()).collect();
        let mut y = x.clone();
        fwht_normalized(&mut y);
        fwht_normalized(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn normalized_fwht_preserves_norm() {
        let x: Vec<f64> = (0..32).map(|i| (i as f64).sin()).collect();
        let n0 = crate::linalg::norm2(&x);
        let mut y = x;
        fwht_normalized(&mut y);
        assert!((crate::linalg::norm2(&y) - n0).abs() < 1e-12);
    }

    #[test]
    fn hadamard_rows_orthogonal() {
        let n = 16;
        for i in 0..n {
            for j in 0..n {
                let d: f64 = (0..n).map(|k| hadamard_entry(i, k) * hadamard_entry(j, k)).sum();
                if i == j {
                    assert_eq!(d, n as f64);
                } else {
                    assert_eq!(d, 0.0);
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_panics() {
        let mut x = vec![1.0; 6];
        fwht(&mut x);
    }
}
