//! Deterministic chunked parallelism for the compute kernels.
//!
//! Dependency-free (std-only) worker scheduling with one hard contract:
//! **results are bit-identical at any thread count**, including 1. The
//! golden-trace suite (`rust/tests/golden_traces.rs`) is the referee —
//! CI runs it at 1 and 8 threads and the fixtures must not move.
//!
//! Two primitives deliver that contract:
//!
//! - [`par_chunks_mut`] — split a mutable output buffer into *fixed-size*
//!   chunks and hand each chunk to exactly one worker. Chunk geometry
//!   depends only on the buffer length and the chunk size, never on the
//!   thread count, and every output element is written by a single chunk,
//!   so the result cannot depend on scheduling. This covers every kernel
//!   whose output elements are independent (`matvec` rows, `matvec_t`
//!   columns, `matmul` row blocks, `gram` row blocks).
//! - [`tree_reduce`] — for genuine reductions (e.g. the sparse CSR
//!   transpose-scatter, where output elements receive contributions from
//!   many rows): evaluate per-chunk partials in parallel, then combine
//!   them in a *fixed pairwise binary tree over chunk index*
//!   `((p0+p1)+(p2+p3))+…`. The tree shape depends only on the chunk
//!   count, so the floating-point summation order — and therefore the
//!   bits — are the same at every thread count. (The tree order differs
//!   from a strict sequential sweep by ordinary rounding; callers
//!   document the ≤1e-12 contract where they use it.)
//!
//! Work below [`PAR_THRESHOLD`] element·work units runs inline — the
//! solver loops issue many small kernel calls per round and must not pay
//! thread wake-ups for them. The eligibility test depends only on the
//! problem size, never on the thread count, so it cannot break the
//! determinism contract.
//!
//! The thread count resolves, in priority order: [`set_threads`] (the
//! `Experiment::threads` knob), the `CODED_OPT_THREADS` environment
//! variable, then `std::thread::available_parallelism()`, capped at
//! [`MAX_THREADS`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Hard cap on worker threads.
pub const MAX_THREADS: usize = 16;

/// Fixed chunk length (rows / columns) used by the dense kernels. Chunk
/// geometry must never depend on the thread count — this constant is the
/// determinism anchor.
pub const CHUNK: usize = 64;

/// Minimum `out.len() × work_per_item` before a kernel goes parallel
/// (≈ flops). Workers are scoped threads spawned per call — simple and
/// safe, but spawn+join costs tens of microseconds — so the threshold
/// sits around half a millisecond of sequential work (~1M flops): below
/// it the spawn overhead would rival the parallel win, above it the
/// overhead amortizes to a few percent. The cutoff depends only on
/// problem size, never on the thread count, so it cannot perturb the
/// determinism contract.
pub const PAR_THRESHOLD: usize = 1 << 20;

/// 0 = unresolved; resolved lazily on first use.
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide worker-thread count (clamped to
/// `1..=MAX_THREADS`). Results are bit-identical at any setting; this
/// knob only trades wall-clock for cores.
pub fn set_threads(n: usize) {
    THREADS.store(n.clamp(1, MAX_THREADS), Ordering::Relaxed);
}

/// The resolved worker-thread count.
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let resolved = std::env::var("CODED_OPT_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .clamp(1, MAX_THREADS);
    THREADS.store(resolved, Ordering::Relaxed);
    resolved
}

/// Run `body(chunk_index, chunk)` over fixed-size chunks of `out`,
/// in parallel when the work is large enough.
///
/// `chunk` is the chunk length in elements (the last chunk may be
/// shorter); `work_per_item` is the approximate cost of producing one
/// output element, used only for the inline-vs-parallel decision. Each
/// chunk is processed by exactly one thread, so as long as `body` writes
/// only through the chunk it was handed (it cannot do otherwise — the
/// chunks are disjoint `&mut` slices) the result is independent of the
/// thread count.
pub fn par_chunks_mut<T, F>(out: &mut [T], chunk: usize, work_per_item: usize, body: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk length must be positive");
    let nchunks = out.len().div_ceil(chunk);
    let nthreads = threads().min(nchunks);
    if nthreads <= 1 || out.len().saturating_mul(work_per_item) < PAR_THRESHOLD {
        for (ci, c) in out.chunks_mut(chunk).enumerate() {
            body(ci, c);
        }
        return;
    }
    // Work-stealing over a shared chunk iterator: assignment of chunks to
    // threads is racy, but each chunk runs exactly once on exactly one
    // thread, so output bits are schedule-independent. (`worker` is
    // declared before `scope` so the spawned threads' borrows of it
    // outlive `'scope`.)
    let queue = Mutex::new(out.chunks_mut(chunk).enumerate());
    let worker = || loop {
        let job = queue.lock().unwrap().next();
        match job {
            Some((ci, c)) => body(ci, c),
            None => break,
        }
    };
    std::thread::scope(|scope| {
        for _ in 1..nthreads {
            scope.spawn(&worker);
        }
        worker();
    });
}

/// Deterministic fixed-chunk tree reduction into a `len`-vector.
///
/// `eval(ci, slot)` must write chunk `ci`'s partial result (a full
/// `len`-vector) into `slot`; partials are evaluated in parallel
/// (`work_per_item` gates inlining exactly like [`par_chunks_mut`]) and
/// then pairwise-combined in a fixed binary tree over the chunk index:
/// stride-1 pairs first (`p0+=p1`, `p2+=p3`, …), then stride 2, and so
/// on. The tree shape depends only on `nchunks`, so the summation order
/// is identical at every thread count.
pub fn tree_reduce<F>(nchunks: usize, len: usize, work_per_item: usize, eval: F) -> Vec<f64>
where
    F: Fn(usize, &mut [f64]) + Sync,
{
    assert!(nchunks >= 1, "tree_reduce needs at least one chunk");
    if len == 0 {
        return Vec::new();
    }
    let mut partials = vec![0.0f64; nchunks * len];
    par_chunks_mut(&mut partials, len, work_per_item, eval);
    let mut stride = 1;
    while stride < nchunks {
        let mut i = 0;
        while i + stride < nchunks {
            let (head, tail) = partials.split_at_mut((i + stride) * len);
            let dst = &mut head[i * len..(i + 1) * len];
            for (d, s) in dst.iter_mut().zip(&tail[..len]) {
                *d += s;
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    partials.truncate(len);
    partials
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that mutate the process-global thread knob
    /// (cargo runs the unit tests of this binary concurrently).
    static KNOB: Mutex<()> = Mutex::new(());

    #[test]
    fn chunks_cover_output_exactly_once() {
        let mut out = vec![0u32; 1000];
        par_chunks_mut(&mut out, 64, PAR_THRESHOLD, |_, c| {
            for v in c.iter_mut() {
                *v += 1; // every element must be touched exactly once
            }
        });
        assert!(out.iter().all(|&v| v == 1));
    }

    #[test]
    fn chunk_indices_map_to_offsets() {
        let mut out = vec![0usize; 300];
        par_chunks_mut(&mut out, 64, PAR_THRESHOLD, |ci, c| {
            for (k, v) in c.iter_mut().enumerate() {
                *v = ci * 64 + k;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn results_identical_across_thread_counts() {
        let _guard = KNOB.lock().unwrap();
        let eval = |ci: usize, slot: &mut [f64]| {
            for (k, v) in slot.iter_mut().enumerate() {
                *v = ((ci * 31 + k) as f64 * 0.37).sin();
            }
        };
        let before = threads();
        set_threads(1);
        let a = tree_reduce(13, 17, PAR_THRESHOLD, eval);
        set_threads(8);
        let b = tree_reduce(13, 17, PAR_THRESHOLD, eval);
        set_threads(before);
        assert_eq!(a, b, "tree reduction must be thread-count invariant");
    }

    #[test]
    fn tree_reduce_matches_pairwise_hand_sum() {
        // 3 chunks of scalars: tree = (p0 + p1) + p2.
        let got = tree_reduce(3, 1, usize::MAX, |ci, slot| slot[0] = [1.0, 2.0, 4.0][ci]);
        assert_eq!(got, vec![7.0]);
    }

    #[test]
    fn single_chunk_is_identity() {
        let got = tree_reduce(1, 4, 0, |_, slot| slot.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]));
        assert_eq!(got, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn empty_output_is_fine() {
        let mut out: Vec<f64> = Vec::new();
        par_chunks_mut(&mut out, 8, 1, |_, _| panic!("no chunks expected"));
        assert!(tree_reduce(4, 0, 1, |_, _| ()).is_empty());
    }

    #[test]
    fn set_threads_clamps() {
        let _guard = KNOB.lock().unwrap();
        let before = threads();
        set_threads(0);
        assert_eq!(threads(), 1);
        set_threads(10_000);
        assert_eq!(threads(), MAX_THREADS);
        set_threads(before);
    }
}
