//! Mixed-precision storage: f32 shards, f64 accumulation.
//!
//! The paper's encoded workers are memory-bandwidth-bound at the shipped
//! shard shapes — every gradient round streams the whole `S·X` block.
//! Storing the block in `f32` halves the streamed bytes while every
//! arithmetic accumulation stays in `f64`: each stored element is
//! widened *exactly* (`f64::from(f32)` is lossless) before the same
//! ascending mul/add chain the f64 kernels use.
//!
//! # Tolerance contract
//!
//! `f32` storage is **not** bit-pinned. The determinism contract splits:
//!
//! - For a *fixed* precision mode, results remain bit-identical at any
//!   thread count and with SIMD on or off (the lane kernels in
//!   [`super::simd`] replay the scalar widening chain per output).
//! - Across *modes* (`F32` vs `F64`), the one-time demotion rounds each
//!   stored element to the nearest `f32`, so results differ by the input
//!   rounding only: `rust/tests/kernel_equivalence.rs` pins the f32 path
//!   within `1e-5` relative error of the f64 referee on unit-scale data.
//!
//! Golden traces are recorded under [`Precision::F64`] (the default);
//! `F32` runs are perf/memory experiments, not trace-conformant runs.

// lint:allow(zone-containment) — dispatched SIMD fast path, bit-identical to scalar
use super::{par, simd, Mat};

/// Data-plane storage precision for worker shards.
///
/// `F64` is the default and the only mode the golden-trace suite
/// records. `F32` stores shard payloads in single precision (half the
/// memory traffic) while accumulating in `f64`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Full double-precision storage (bit-determinism contract applies).
    F64,
    /// Single-precision storage, double-precision accumulation
    /// (≤ 1e-5 relative tolerance vs the f64 referee; not bit-pinned).
    F32,
}

impl Precision {
    /// Parse a CLI / config spelling. Accepts `f64` / `f32`
    /// (case-insensitive).
    pub fn parse(s: &str) -> Option<Precision> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f64" => Some(Precision::F64),
            "f32" => Some(Precision::F32),
            _ => None,
        }
    }

    /// Canonical name (`"f64"` / `"f32"`).
    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

/// Scalar f32-storage dot with f64 accumulation: the canonical widening
/// sweep every SIMD f32 lane replays (`acc += widen(a[k])·x[k]`,
/// ascending `k`, one rounding per op).
#[inline]
pub(crate) fn dot_widen(a: &[f32], x: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), x.len());
    let mut acc = 0.0;
    for (&ai, xi) in a.iter().zip(x) {
        acc += f64::from(ai) * xi;
    }
    acc
}

/// Dense row-major matrix with `f32` storage and `f64` kernel
/// accumulation. Mirrors the [`Mat`] hot-path kernels (`matvec` /
/// `matvec_sub` / `matvec_t`) with the same chunking, quad-row SIMD
/// grouping, and per-element accumulation order.
#[derive(Clone, Debug, PartialEq)]
pub struct MatF32 {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl MatF32 {
    /// Demote an f64 matrix: each element rounds to nearest `f32` once.
    pub fn from_mat(m: &Mat) -> Self {
        MatF32 {
            rows: m.rows(),
            cols: m.cols(),
            data: m.as_slice().iter().map(|&v| v as f32).collect(),
        }
    }

    /// Wrap an existing row-major `f32` buffer (shard read path).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        MatF32 { rows, cols, data }
    }

    /// Widen back to an f64 [`Mat`] (exact).
    pub fn to_mat(&self) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.data.iter().map(|&v| f64::from(v)).collect())
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Raw row-major buffer.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Storage footprint in bytes — half of the equivalent [`Mat`].
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// y = A·x with f64 accumulation. Same row-chunk parallelism and
    /// quad-row SIMD grouping as [`Mat::matvec`]; bit-identical at any
    /// thread count and across the SIMD toggle *for this storage mode*.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec dim mismatch");
        let mut y = vec![0.0; self.rows];
        let data = &self.data;
        let cols = self.cols;
        par::par_chunks_mut(&mut y, par::CHUNK, cols, |ci, yc| {
            let r0 = ci * par::CHUNK;
            let mut q = 0;
            while q + 4 <= yc.len() {
                let base = (r0 + q) * cols;
                let quad = simd::dot4_f32(
                    &data[base..base + cols],
                    &data[base + cols..base + 2 * cols],
                    &data[base + 2 * cols..base + 3 * cols],
                    &data[base + 3 * cols..base + 4 * cols],
                    x,
                );
                yc[q..q + 4].copy_from_slice(&quad);
                q += 4;
            }
            for (dy, i) in yc[q..].iter_mut().zip(r0 + q..) {
                *dy = dot_widen(&data[i * cols..(i + 1) * cols], x);
            }
        });
        y
    }

    /// out = A·x − b, the fused residual kernel (f32-storage twin of
    /// [`Mat::matvec_sub`]): the `− b[i]` lands after each row's dot.
    pub fn matvec_sub(&self, x: &[f64], b: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "matvec_sub dim mismatch");
        assert_eq!(b.len(), self.rows, "matvec_sub rhs mismatch");
        assert_eq!(out.len(), self.rows, "matvec_sub out mismatch");
        let data = &self.data;
        let cols = self.cols;
        par::par_chunks_mut(out, par::CHUNK, cols, |ci, oc| {
            let r0 = ci * par::CHUNK;
            let mut q = 0;
            while q + 4 <= oc.len() {
                let i = r0 + q;
                let base = i * cols;
                let quad = simd::dot4_f32(
                    &data[base..base + cols],
                    &data[base + cols..base + 2 * cols],
                    &data[base + 2 * cols..base + 3 * cols],
                    &data[base + 3 * cols..base + 4 * cols],
                    x,
                );
                for l in 0..4 {
                    oc[q + l] = quad[l] - b[i + l];
                }
                q += 4;
            }
            for (dy, i) in oc[q..].iter_mut().zip(r0 + q..) {
                *dy = dot_widen(&data[i * cols..(i + 1) * cols], x) - b[i];
            }
        });
    }

    /// y = Aᵀ·x with f64 accumulation. Column-stripe chunks exactly as
    /// [`Mat::matvec_t`]; the stripe update is a widening axpy
    /// ([`simd::axpy_widen`]), ascending row order per output element.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "matvec_t dim mismatch");
        let mut y = vec![0.0; self.cols];
        let data = &self.data;
        let cols = self.cols;
        par::par_chunks_mut(&mut y, par::CHUNK, self.rows, |ci, yc| {
            let j0 = ci * par::CHUNK;
            for (i, &xi) in x.iter().enumerate() {
                let stripe = &data[i * cols + j0..i * cols + j0 + yc.len()];
                simd::axpy_widen(xi, stripe, yc);
            }
        });
        y
    }
}

/// A worker shard matrix in either storage precision, presenting the
/// hot-path kernel surface (`matvec` / `matvec_sub` / `matvec_t`)
/// uniformly so the coordinator never branches per call.
#[derive(Clone, Debug, PartialEq)]
pub enum PrecisionMat {
    F64(Mat),
    F32(MatF32),
}

impl PrecisionMat {
    /// Store `m` at the requested precision (one demotion pass for
    /// `F32`, a move for `F64`).
    pub fn demote(m: Mat, p: Precision) -> Self {
        match p {
            Precision::F64 => PrecisionMat::F64(m),
            Precision::F32 => PrecisionMat::F32(MatF32::from_mat(&m)),
        }
    }

    /// The storage precision of this shard.
    pub fn precision(&self) -> Precision {
        match self {
            PrecisionMat::F64(_) => Precision::F64,
            PrecisionMat::F32(_) => Precision::F32,
        }
    }

    /// Borrow the f64 matrix, if this shard is stored in f64 (the
    /// PJRT executor path needs the raw f64 buffer).
    pub fn as_f64(&self) -> Option<&Mat> {
        match self {
            PrecisionMat::F64(m) => Some(m),
            PrecisionMat::F32(_) => None,
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            PrecisionMat::F64(m) => m.rows(),
            PrecisionMat::F32(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            PrecisionMat::F64(m) => m.cols(),
            PrecisionMat::F32(m) => m.cols(),
        }
    }

    /// Storage footprint in bytes.
    pub fn bytes(&self) -> usize {
        match self {
            PrecisionMat::F64(m) => m.as_slice().len() * std::mem::size_of::<f64>(),
            PrecisionMat::F32(m) => m.bytes(),
        }
    }

    /// y = A·x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        match self {
            PrecisionMat::F64(m) => m.matvec(x),
            PrecisionMat::F32(m) => m.matvec(x),
        }
    }

    /// out = A·x − b.
    pub fn matvec_sub(&self, x: &[f64], b: &[f64], out: &mut [f64]) {
        match self {
            PrecisionMat::F64(m) => m.matvec_sub(x, b, out),
            PrecisionMat::F32(m) => m.matvec_sub(x, b, out),
        }
    }

    /// y = Aᵀ·x.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        match self {
            PrecisionMat::F64(m) => m.matvec_t(x),
            PrecisionMat::F32(m) => m.matvec_t(x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randm(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = crate::rng::Pcg64::new(seed);
        Mat::from_fn(rows, cols, |_, _| rng.next_f64() - 0.5)
    }

    fn randv(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::rng::Pcg64::new(seed);
        (0..n).map(|_| rng.next_f64() - 0.5).collect()
    }

    #[test]
    fn parse_and_name_roundtrip() {
        assert_eq!(Precision::parse("f64"), Some(Precision::F64));
        assert_eq!(Precision::parse("F32"), Some(Precision::F32));
        assert_eq!(Precision::parse(" f32 "), Some(Precision::F32));
        assert_eq!(Precision::parse("f16"), None);
        assert_eq!(Precision::F32.name(), "f32");
        assert_eq!(Precision::parse(Precision::F64.name()), Some(Precision::F64));
    }

    #[test]
    fn widening_roundtrip_is_exact() {
        // f64::from(v as f32) is the identity on values that fit f32
        // exactly; to_mat()/from_mat over such values round-trips.
        let m = Mat::from_fn(7, 5, |i, j| (i as f64) * 0.5 - (j as f64) * 0.25);
        let f = MatF32::from_mat(&m);
        assert_eq!(f.to_mat(), m);
        assert_eq!(f.bytes() * 2, m.as_slice().len() * std::mem::size_of::<f64>());
    }

    #[test]
    fn f32_kernels_within_tolerance_of_f64() {
        // Sizes past one quad and with remainder rows/cols.
        let m = randm(70, 33, 11);
        let f = MatF32::from_mat(&m);
        let x = randv(33, 12);
        let xt = randv(70, 13);
        let b = randv(70, 14);

        let tol = |got: &[f64], want: &[f64]| {
            for (g, w) in got.iter().zip(want) {
                assert!((g - w).abs() <= 1e-5 * w.abs().max(1.0), "got {g}, want {w}");
            }
        };
        tol(&f.matvec(&x), &m.matvec(&x));
        tol(&f.matvec_t(&xt), &m.matvec_t(&xt));
        let mut got = vec![0.0; 70];
        let mut want = vec![0.0; 70];
        f.matvec_sub(&x, &b, &mut got);
        m.matvec_sub(&x, &b, &mut want);
        tol(&got, &want);
    }

    #[test]
    fn f32_matvec_matches_widened_mat_exactly() {
        // The f32 kernels accumulate in f64, so they agree bit-for-bit
        // with the f64 kernels applied to the widened copy.
        let m = randm(41, 19, 21);
        let f = MatF32::from_mat(&m);
        let wide = f.to_mat();
        let x = randv(19, 22);
        assert_eq!(f.matvec(&x), wide.matvec(&x));
        let xt = randv(41, 23);
        assert_eq!(f.matvec_t(&xt), wide.matvec_t(&xt));
    }

    #[test]
    fn precision_mat_dispatches() {
        let m = randm(10, 6, 31);
        let x = randv(6, 32);
        let p64 = PrecisionMat::demote(m.clone(), Precision::F64);
        let p32 = PrecisionMat::demote(m.clone(), Precision::F32);
        assert_eq!(p64.precision(), Precision::F64);
        assert_eq!(p32.precision(), Precision::F32);
        assert_eq!(p64.matvec(&x), m.matvec(&x));
        assert!(p64.as_f64().is_some());
        assert!(p32.as_f64().is_none());
        assert_eq!(p64.rows(), 10);
        assert_eq!(p32.cols(), 6);
        assert_eq!(p32.bytes() * 2, p64.bytes());
    }
}
