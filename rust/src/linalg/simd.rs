//! Runtime-dispatched AVX2 SIMD kernels for the data-plane hot paths.
//!
//! # Determinism contract (why SIMD cannot move a golden trace)
//!
//! Every kernel in this module vectorizes across **independent output
//! elements** — four matvec rows side by side (lane = row), four axpy /
//! butterfly elements side by side (lane = element) — and **never
//! across a reduction axis**. Each lane runs the exact scalar
//! accumulation: ascending-`k` sweep, separate multiply and add
//! instructions (`_mm256_mul_pd` + `_mm256_add_pd`, never FMA — fused
//! single rounding would change bits), no horizontal add anywhere. A
//! lane's float operation sequence is therefore *identical* to the
//! scalar kernel's for that output element, so the SIMD path is
//! bit-identical to the scalar path on every input, at every size, at
//! any thread count — flipping `CODED_OPT_SIMD` cannot move a golden
//! trace, and `rust/tests/kernel_equivalence.rs` pins exactly that.
//!
//! # Dispatch
//!
//! Resolved once per process and cached: `CODED_OPT_SIMD=0` forces the
//! scalar path, `CODED_OPT_SIMD=1` (or unset) uses AVX2 when the CPU
//! reports it at runtime (`is_x86_64_feature_detected!`); non-x86_64
//! targets always take the scalar path. Tests and the bench harness
//! override in-process with [`set_forced`]. The f32-storage variants
//! ([`dot4_f32`], [`axpy_widen`]) widen each stored `f32` to `f64`
//! exactly (`vcvtps2pd` — lossless) before the same mul/add sequence,
//! so they too are bit-identical to their scalar twins in
//! [`super::precision`].
//!
//! `unsafe` here is confined to `#[target_feature(enable = "avx2")]`
//! functions and their guarded call sites; the `safety-comment` lint
//! rule allowlists exactly this file (outside `runtime/`) and requires
//! every block to name its CPU-feature guard.

use std::sync::atomic::{AtomicU8, Ordering};

const UNRESOLVED: u8 = 0;
const OFF: u8 = 1;
const ON: u8 = 2;

/// Cached dispatch state. Relaxed ordering suffices: the resolved value
/// is a pure function of the environment + CPU, so racing resolvers
/// store the same byte.
static STATE: AtomicU8 = AtomicU8::new(UNRESOLVED);

/// Does this CPU support the AVX2 path at all?
pub fn detected() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_64_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Is the SIMD path active for this process?
///
/// First call resolves `CODED_OPT_SIMD` (`0` = force scalar, `1` = SIMD
/// where supported; unset = auto-detect) and caches the answer.
#[inline]
pub fn active() -> bool {
    match STATE.load(Ordering::Relaxed) {
        ON => true,
        OFF => false,
        _ => {
            let on = resolve();
            STATE.store(if on { ON } else { OFF }, Ordering::Relaxed);
            on
        }
    }
}

fn resolve() -> bool {
    match std::env::var("CODED_OPT_SIMD") {
        Ok(v) if v.trim() == "0" => false,
        _ => detected(),
    }
}

/// In-process override mirroring [`super::par::set_threads`]:
/// `Some(true)` forces SIMD on (still requires hardware support — on a
/// non-AVX2 CPU the scalar path is kept, which is bit-identical
/// anyway), `Some(false)` forces scalar, `None` re-resolves from the
/// environment on next use. Used by the equivalence tests and the
/// SIMD-vs-scalar bench pairs.
pub fn set_forced(on: Option<bool>) {
    let s = match on {
        Some(true) => {
            if detected() {
                ON
            } else {
                OFF
            }
        }
        Some(false) => OFF,
        None => UNRESOLVED,
    };
    STATE.store(s, Ordering::Relaxed);
}

/// Comma-separated list of the detected CPU vector features relevant to
/// this module — recorded in the bench report (`features` field) so
/// cross-runner baseline diffs are explainable.
pub fn cpu_features() -> String {
    #[cfg(target_arch = "x86_64")]
    {
        let probes = [
            ("sse2", std::arch::is_x86_64_feature_detected!("sse2")),
            ("sse4.2", std::arch::is_x86_64_feature_detected!("sse4.2")),
            ("avx", std::arch::is_x86_64_feature_detected!("avx")),
            ("avx2", std::arch::is_x86_64_feature_detected!("avx2")),
            ("fma", std::arch::is_x86_64_feature_detected!("fma")),
            ("avx512f", std::arch::is_x86_64_feature_detected!("avx512f")),
        ];
        let hits: Vec<&str> =
            probes.iter().filter(|(_, have)| *have).map(|(name, _)| *name).collect();
        hits.join(",")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        String::new()
    }
}

/// Four dot products at once: `[a0·x, a1·x, a2·x, a3·x]`, lane = row.
///
/// Each lane accumulates `acc += a[k]·x[k]` in ascending `k` from a
/// zero start — the exact [`super::dot`] sequence — so the result is
/// bit-identical to four scalar `dot` calls. This breaks the serial
/// add-latency chain that bounds a single scalar dot (~4 cycles per
/// element) by running four independent chains in one vector register.
#[inline]
pub fn dot4(a0: &[f64], a1: &[f64], a2: &[f64], a3: &[f64], x: &[f64]) -> [f64; 4] {
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: `active()` is true only when AVX2 was detected on
        // this CPU (both the env resolution and `set_forced(Some(true))`
        // re-check `detected()`), satisfying `dot4_avx2`'s guard.
        return unsafe { dot4_avx2(a0, a1, a2, a3, x) };
    }
    [super::dot(a0, x), super::dot(a1, x), super::dot(a2, x), super::dot(a3, x)]
}

/// [`dot4`] over f32 row storage with f64 accumulation: each element is
/// widened exactly before the same mul/add sequence — bit-identical to
/// the scalar widening sweep in [`super::precision`].
#[inline]
pub fn dot4_f32(a0: &[f32], a1: &[f32], a2: &[f32], a3: &[f32], x: &[f64]) -> [f64; 4] {
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: `active()` implies AVX2 was detected at runtime,
        // satisfying `dot4_f32_avx2`'s target-feature guard.
        return unsafe { dot4_f32_avx2(a0, a1, a2, a3, x) };
    }
    [
        super::precision::dot_widen(a0, x),
        super::precision::dot_widen(a1, x),
        super::precision::dot_widen(a2, x),
        super::precision::dot_widen(a3, x),
    ]
}

/// y ← y + αx. Lane = element; per-element operation order is exactly
/// the scalar sweep's (`y[j] + α·x[j]`, one rounding per op), so the
/// vector path is bit-identical. [`super::axpy`] routes here; the
/// matvec_t stripe sweep, the gram row update, and matmul's k-panels
/// all inherit the SIMD path through it.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if y.len() >= 4 && active() {
        // SAFETY: `active()` implies AVX2 was detected at runtime,
        // satisfying `axpy_avx2`'s target-feature guard.
        unsafe { axpy_avx2(alpha, x, y) };
        return;
    }
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// y ← y + α·widen(x) over f32 storage: the f32 matvec_t stripe kernel.
/// Widening is exact, mul/add separate — bit-identical to the scalar
/// widening sweep.
#[inline]
pub fn axpy_widen(alpha: f64, x: &[f32], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if y.len() >= 4 && active() {
        // SAFETY: `active()` implies AVX2 was detected at runtime,
        // satisfying `axpy_widen_avx2`'s target-feature guard.
        unsafe { axpy_widen_avx2(alpha, x, y) };
        return;
    }
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * f64::from(xi);
    }
}

/// One FWHT butterfly layer half: `(a, b) ← (a + b, a − b)` elementwise
/// over two equal-length halves of a block. Lane = element; per-pair
/// operation order is the scalar butterfly's, so the result is
/// bit-identical. [`crate::linalg::fwht::fwht`] calls this per block.
#[inline]
pub fn butterfly(a: &mut [f64], b: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if a.len() >= 4 && active() {
        // SAFETY: `active()` implies AVX2 was detected at runtime,
        // satisfying `butterfly_avx2`'s target-feature guard.
        unsafe { butterfly_avx2(a, b) };
        return;
    }
    for (ai, bi) in a.iter_mut().zip(b.iter_mut()) {
        let s = *ai + *bi;
        let d = *ai - *bi;
        *ai = s;
        *bi = d;
    }
}

/// Four CSR row products at once: lane `l` accumulates
/// `acc += v[l][k]·x[ix[l][k]]` in ascending `k` — the sequential CSR
/// row sweep — lockstep over the rows' common-length prefix, then
/// scalar per-lane tails that *continue* each lane's chain. Every
/// lane's operation sequence is exactly the scalar row sweep's, so the
/// result is bit-identical to four scalar rows.
#[inline]
pub fn csr_dot4(v: [&[f64]; 4], ix: [&[usize]; 4], x: &[f64]) -> [f64; 4] {
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: `active()` implies AVX2 was detected at runtime,
        // satisfying `csr_dot4_avx2`'s target-feature guard.
        return unsafe { csr_dot4_avx2(v, ix, x) };
    }
    let mut out = [0.0f64; 4];
    for l in 0..4 {
        let mut acc = 0.0;
        for (val, &c) in v[l].iter().zip(ix[l]) {
            acc += val * x[c];
        }
        out[l] = acc;
    }
    out
}

// ---------------------------------------------------------------------
// AVX2 bodies. Callers must hold the guard stated on each function; the
// safe wrappers above establish it via `active()`.
// ---------------------------------------------------------------------

// SAFETY: caller must ensure the CPU supports AVX2 (checked by the safe
// wrapper via `active()`); all memory access below is bounds-asserted.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot4_avx2(a0: &[f64], a1: &[f64], a2: &[f64], a3: &[f64], x: &[f64]) -> [f64; 4] {
    use std::arch::x86_64::*;
    let n = x.len();
    assert!(a0.len() == n && a1.len() == n && a2.len() == n && a3.len() == n);
    let (p0, p1, p2, p3, px) = (a0.as_ptr(), a1.as_ptr(), a2.as_ptr(), a3.as_ptr(), x.as_ptr());
    let mut acc = _mm256_setzero_pd();
    for k in 0..n {
        // SAFETY: k < n and every slice has length n (asserted above),
        // so each `add(k)` read is in bounds.
        let rows = unsafe { _mm256_set_pd(*p3.add(k), *p2.add(k), *p1.add(k), *p0.add(k)) };
        // SAFETY: k < n = x.len().
        let xk = unsafe { _mm256_set1_pd(*px.add(k)) };
        acc = _mm256_add_pd(acc, _mm256_mul_pd(rows, xk));
    }
    let mut out = [0.0f64; 4];
    // SAFETY: `out` holds exactly four f64s — one full 256-bit store.
    unsafe { _mm256_storeu_pd(out.as_mut_ptr(), acc) };
    out
}

// SAFETY: caller must ensure the CPU supports AVX2 (checked by the safe
// wrapper via `active()`); all memory access below is bounds-asserted.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot4_f32_avx2(a0: &[f32], a1: &[f32], a2: &[f32], a3: &[f32], x: &[f64]) -> [f64; 4] {
    use std::arch::x86_64::*;
    let n = x.len();
    assert!(a0.len() == n && a1.len() == n && a2.len() == n && a3.len() == n);
    let mut acc = _mm256_setzero_pd();
    for k in 0..n {
        // Exact f32→f64 widening per lane, then the scalar mul/add.
        let rows = _mm256_set_pd(
            f64::from(a3[k]),
            f64::from(a2[k]),
            f64::from(a1[k]),
            f64::from(a0[k]),
        );
        let xk = _mm256_set1_pd(x[k]);
        acc = _mm256_add_pd(acc, _mm256_mul_pd(rows, xk));
    }
    let mut out = [0.0f64; 4];
    // SAFETY: `out` holds exactly four f64s — one full 256-bit store.
    unsafe { _mm256_storeu_pd(out.as_mut_ptr(), acc) };
    out
}

// SAFETY: caller must ensure the CPU supports AVX2 (checked by the safe
// wrapper via `active()`); all memory access below is bounds-asserted.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(alpha: f64, x: &[f64], y: &mut [f64]) {
    use std::arch::x86_64::*;
    let n = y.len();
    assert!(x.len() == n);
    let va = _mm256_set1_pd(alpha);
    let (px, py) = (x.as_ptr(), y.as_mut_ptr());
    let mut k = 0;
    while k + 4 <= n {
        // SAFETY: k + 4 ≤ n, so the 4-wide load/store stays in bounds
        // of both length-n slices.
        unsafe {
            let vx = _mm256_loadu_pd(px.add(k));
            let vy = _mm256_loadu_pd(py.add(k));
            _mm256_storeu_pd(py.add(k), _mm256_add_pd(vy, _mm256_mul_pd(va, vx)));
        }
        k += 4;
    }
    while k < n {
        y[k] += alpha * x[k];
        k += 1;
    }
}

// SAFETY: caller must ensure the CPU supports AVX2 (checked by the safe
// wrapper via `active()`); all memory access below is bounds-asserted.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_widen_avx2(alpha: f64, x: &[f32], y: &mut [f64]) {
    use std::arch::x86_64::*;
    let n = y.len();
    assert!(x.len() == n);
    let va = _mm256_set1_pd(alpha);
    let (px, py) = (x.as_ptr(), y.as_mut_ptr());
    let mut k = 0;
    while k + 4 <= n {
        // SAFETY: k + 4 ≤ n: the 128-bit f32 load reads x[k..k+4], the
        // 256-bit f64 load/store covers y[k..k+4] — both in bounds.
        unsafe {
            let vx = _mm256_cvtps_pd(_mm_loadu_ps(px.add(k)));
            let vy = _mm256_loadu_pd(py.add(k));
            _mm256_storeu_pd(py.add(k), _mm256_add_pd(vy, _mm256_mul_pd(va, vx)));
        }
        k += 4;
    }
    while k < n {
        y[k] += alpha * f64::from(x[k]);
        k += 1;
    }
}

// SAFETY: caller must ensure the CPU supports AVX2 (checked by the safe
// wrapper via `active()`); all memory access below is bounds-asserted.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn butterfly_avx2(a: &mut [f64], b: &mut [f64]) {
    use std::arch::x86_64::*;
    let n = a.len();
    assert!(b.len() == n);
    let (pa, pb) = (a.as_mut_ptr(), b.as_mut_ptr());
    let mut k = 0;
    while k + 4 <= n {
        // SAFETY: k + 4 ≤ n, so each 4-wide load/store stays in bounds
        // of both length-n halves (disjoint slices by construction).
        unsafe {
            let va = _mm256_loadu_pd(pa.add(k));
            let vb = _mm256_loadu_pd(pb.add(k));
            _mm256_storeu_pd(pa.add(k), _mm256_add_pd(va, vb));
            _mm256_storeu_pd(pb.add(k), _mm256_sub_pd(va, vb));
        }
        k += 4;
    }
    while k < n {
        let s = a[k] + b[k];
        let d = a[k] - b[k];
        a[k] = s;
        b[k] = d;
        k += 1;
    }
}

// SAFETY: caller must ensure the CPU supports AVX2 (checked by the safe
// wrapper via `active()`); memory access uses bounds-checked indexing.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn csr_dot4_avx2(v: [&[f64]; 4], ix: [&[usize]; 4], x: &[f64]) -> [f64; 4] {
    use std::arch::x86_64::*;
    for l in 0..4 {
        assert_eq!(v[l].len(), ix[l].len());
    }
    let common =
        v[0].len().min(v[1].len()).min(v[2].len()).min(v[3].len());
    let mut acc = _mm256_setzero_pd();
    for k in 0..common {
        // Bounds-checked gathers: CSR guarantees indices < cols, and a
        // violation should panic exactly like the scalar path.
        let vals = _mm256_set_pd(v[3][k], v[2][k], v[1][k], v[0][k]);
        let xs = _mm256_set_pd(x[ix[3][k]], x[ix[2][k]], x[ix[1][k]], x[ix[0][k]]);
        acc = _mm256_add_pd(acc, _mm256_mul_pd(vals, xs));
    }
    let mut out = [0.0f64; 4];
    // SAFETY: `out` holds exactly four f64s — one full 256-bit store.
    unsafe { _mm256_storeu_pd(out.as_mut_ptr(), acc) };
    // Scalar tails continue each lane's ascending chain past the
    // common prefix — same order the sequential row sweep would use.
    for l in 0..4 {
        let mut a = out[l];
        for k in common..v[l].len() {
            a += v[l][k] * x[ix[l][k]];
        }
        out[l] = a;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// Serializes tests that flip the process-wide dispatch knob.
    static KNOB: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn randv(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| rng.next_f64() - 0.5).collect()
    }

    /// Run `f` with SIMD forced on and off, returning (on, off).
    fn both<T>(mut f: impl FnMut() -> T) -> (T, T) {
        let _g = KNOB.lock().unwrap();
        set_forced(Some(true));
        let on = f();
        set_forced(Some(false));
        let off = f();
        set_forced(None);
        (on, off)
    }

    #[test]
    fn dot4_bit_equal_across_toggle_and_vs_dot() {
        for n in [0usize, 1, 3, 4, 5, 7, 8, 13, 64, 65, 127] {
            let a: Vec<Vec<f64>> = (0..4).map(|i| randv(n, 10 + i)).collect();
            let x = randv(n, 99);
            let (on, off) = both(|| dot4(&a[0], &a[1], &a[2], &a[3], &x));
            assert_eq!(on, off, "n={n}");
            for l in 0..4 {
                assert_eq!(on[l], crate::linalg::dot(&a[l], &x), "n={n} lane {l}");
            }
        }
    }

    #[test]
    fn axpy_bit_equal_across_toggle() {
        for n in [0usize, 1, 2, 3, 4, 5, 6, 7, 8, 9, 31, 64, 130] {
            let x = randv(n, 3);
            let y0 = randv(n, 4);
            let (on, off) = both(|| {
                let mut y = y0.clone();
                axpy(0.37, &x, &mut y);
                y
            });
            assert_eq!(on, off, "n={n}");
        }
    }

    #[test]
    fn butterfly_bit_equal_across_toggle() {
        for n in [1usize, 2, 3, 4, 5, 7, 8, 16, 33] {
            let a0 = randv(n, 5);
            let b0 = randv(n, 6);
            let (on, off) = both(|| {
                let (mut a, mut b) = (a0.clone(), b0.clone());
                butterfly(&mut a, &mut b);
                (a, b)
            });
            assert_eq!(on, off, "n={n}");
        }
    }

    #[test]
    fn csr_dot4_handles_ragged_rows() {
        // Rows of different lengths exercise the common-prefix + tail
        // split on the AVX2 path.
        let lens = [0usize, 3, 7, 5];
        let x = randv(40, 8);
        let rows: Vec<(Vec<f64>, Vec<usize>)> = lens
            .iter()
            .enumerate()
            .map(|(l, &len)| {
                let vals = randv(len, 20 + l as u64);
                let idxs: Vec<usize> = (0..len).map(|k| (k * 7 + l) % 40).collect();
                (vals, idxs)
            })
            .collect();
        let (on, off) = both(|| {
            csr_dot4(
                [&rows[0].0, &rows[1].0, &rows[2].0, &rows[3].0],
                [&rows[0].1, &rows[1].1, &rows[2].1, &rows[3].1],
                &x,
            )
        });
        assert_eq!(on, off);
        for l in 0..4 {
            let want: f64 =
                rows[l].0.iter().zip(&rows[l].1).fold(0.0, |acc, (v, &c)| acc + v * x[c]);
            assert_eq!(off[l], want, "lane {l}");
        }
    }

    #[test]
    fn forced_on_requires_detection() {
        let _g = KNOB.lock().unwrap();
        set_forced(Some(true));
        assert_eq!(active(), detected());
        set_forced(None);
    }

    #[test]
    fn cpu_features_lists_avx2_when_detected() {
        let feats = cpu_features();
        assert_eq!(feats.contains("avx2"), detected(), "{feats}");
    }
}
