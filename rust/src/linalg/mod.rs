//! Dense and sparse linear-algebra substrate.
//!
//! The entire optimizer stack (encoding, objectives, coordinator math,
//! spectrum analysis for Figures 5–6) runs on these primitives. Built from
//! scratch for the offline environment; `f64` accumulation everywhere on
//! the rust side (the AOT JAX/Pallas artifacts compute in `f32` and are
//! validated against these reference ops in integration tests). Two
//! orthogonal data-plane knobs sit below the kernels:
//!
//! - [`simd`] — runtime-dispatched AVX2 lane kernels
//!   (`CODED_OPT_SIMD=0|1`), bit-identical to the scalar paths by
//!   construction (lanes are independent outputs, never a reduction).
//! - [`precision`] — optional f32 *storage* with f64 accumulation
//!   ([`MatF32`] / [`PrecisionMat`], [`Precision::F32`]), halving shard
//!   memory bandwidth at a documented ≤ 1e-5 tolerance vs f64.

pub mod chol;
pub mod eig;
pub mod fwht;
pub mod mat;
pub mod par;
pub mod precision;
pub mod simd;
pub mod sparse;

pub use chol::{cholesky_factor, cholesky_solve};
pub use eig::{symmetric_eigen, symmetric_eigenvalues};
pub use fwht::{fwht, fwht_normalized};
pub use mat::Mat;
pub use precision::{MatF32, Precision, PrecisionMat};
pub use sparse::Csr;

/// Dot product.
///
/// Kept as the naive strict-order sweep: a 4-way-unrolled multi-
/// accumulator variant was tried during the perf pass and REGRESSED the
/// gather-round p50 by ~18% at the shipped shard shapes (bounds-check +
/// register pressure beat the ILP win at p ≤ 128) — see EXPERIMENTS.md
/// §Perf iteration 6. The zipped form accumulates in exactly the same
/// order (parallel kernels depend on that for bit-identity).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Euclidean norm ‖x‖₂.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// y ← y + αx.
///
/// Routed through [`simd::axpy`]: the AVX2 lane kernel when the SIMD
/// path is active, the scalar sweep otherwise — bit-identical either
/// way (lane = element; per-element op order is the scalar sweep's).
/// `matvec_t` stripes, the `gram` row update, and `matmul`'s k-panels
/// all inherit the SIMD path through this one entry point.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    simd::axpy(alpha, x, y);
}

/// Elementwise x ← αx.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// z = x − y.
pub fn sub(x: &[f64], y: &[f64]) -> Vec<f64> {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a - b).collect()
}

/// z = x + y.
pub fn add(x: &[f64], y: &[f64]) -> Vec<f64> {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a + b).collect()
}

/// Soft-thresholding operator: sign(x)·max(|x|−τ, 0), the prox of τ‖·‖₁.
#[inline]
pub fn soft_threshold(x: f64, tau: f64) -> f64 {
    if x > tau {
        x - tau
    } else if x < -tau {
        x + tau
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, -1.0], &mut y);
        assert_eq!(y, vec![7.0, -1.0]);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn add_sub_roundtrip() {
        let x = vec![1.0, -2.0, 3.5];
        let y = vec![0.5, 0.5, 0.5];
        assert_eq!(add(&sub(&x, &y), &y), x);
    }
}
