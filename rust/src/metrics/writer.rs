//! Output writers: CSV files and aligned console tables (the bench
//! harness prints rows matching the paper's tables).

use std::io::Write;
use std::path::Path;

use super::trace::Trace;

/// Write one or more traces to a CSV file with columns
/// `label,iter,time,objective,test_metric,k_used`.
pub fn write_csv(path: &Path, traces: &[&Trace]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "label,iter,time,objective,test_metric,k_used")?;
    for t in traces {
        for r in &t.records {
            writeln!(
                f,
                "{},{},{:.6},{:.8e},{:.6},{}",
                t.label, r.iter, r.time, r.objective, r.test_metric, r.k_used
            )?;
        }
    }
    Ok(())
}

/// Fixed-width console table builder.
pub struct TableWriter {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TableWriter {
    pub fn new(headers: &[&str]) -> Self {
        TableWriter {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column alignment.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$}  ", cell, w = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::trace::IterRecord;

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Trace::new("hadamard");
        t.push(IterRecord { iter: 0, time: 0.1, objective: 1.0, test_metric: 0.9, k_used: 4 });
        t.push(IterRecord { iter: 1, time: 0.2, objective: 0.5, test_metric: 0.8, k_used: 4 });
        let dir = std::env::temp_dir().join("coded_opt_test_csv");
        let path = dir.join("trace.csv");
        write_csv(&path, &[&t]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("label,iter"));
        assert!(lines[1].starts_with("hadamard,0,"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_renders_aligned() {
        let mut tw = TableWriter::new(&["scheme", "rmse"]);
        tw.row(&["hadamard".into(), "0.874".into()]);
        tw.row(&["uncoded".into(), "0.898".into()]);
        let s = tw.render();
        assert!(s.contains("scheme"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_width() {
        let mut tw = TableWriter::new(&["a", "b"]);
        tw.row(&["only-one".into()]);
    }
}
