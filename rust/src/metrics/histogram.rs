//! Streaming histogram / summary statistics for latency measurements.

/// Online summary with exact percentiles (stores samples; fine for the
/// 10³–10⁶ samples our benches produce).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            // lint:allow(no-silent-nan) — documented empty-histogram sentinel
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
    }

    /// Exact percentile via nearest-rank (q in [0,1]).
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            // lint:allow(no-silent-nan) — documented empty-histogram sentinel
            return f64::NAN;
        }
        self.ensure_sorted();
        let idx = ((q * self.samples.len() as f64).ceil() as usize).clamp(1, self.samples.len());
        self.samples[idx - 1]
    }

    pub fn min(&mut self) -> f64 {
        self.percentile(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.percentile(1.0)
    }

    /// "mean ± std [p50 p95 p99]" for bench output lines.
    pub fn summary(&mut self) -> String {
        format!(
            "mean={:.6} std={:.6} p50={:.6} p95={:.6} p99={:.6} n={}",
            self.mean(),
            self.std(),
            self.percentile(0.50),
            self.percentile(0.95),
            self.percentile(0.99),
            self.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known() {
        let mut h = Histogram::new();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            h.record(v);
        }
        assert!((h.mean() - 5.0).abs() < 1e-12);
        // sample std of that classic dataset is sqrt(32/7)
        assert!((h.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let mut h = Histogram::new();
        for v in 1..=100 {
            h.record(v as f64);
        }
        assert_eq!(h.percentile(0.5), 50.0);
        assert_eq!(h.percentile(0.95), 95.0);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 100.0);
    }

    #[test]
    fn empty_is_nan() {
        let mut h = Histogram::new();
        assert!(h.mean().is_nan());
        assert!(h.percentile(0.5).is_nan());
    }

    #[test]
    fn single_sample() {
        let mut h = Histogram::new();
        h.record(3.0);
        assert_eq!(h.percentile(0.5), 3.0);
        assert_eq!(h.std(), 0.0);
    }
}
