//! Metrics: convergence traces, histograms, participation counters,
//! and table/CSV writers used by the benchmark harness.

pub mod histogram;
pub mod trace;
pub mod writer;

pub use histogram::Histogram;
pub use trace::{IterRecord, Trace};
pub use writer::{write_csv, TableWriter};

/// Per-node participation statistics — the empirical probability of the
/// event {i ∈ A_t} plotted in the paper's Figures 12–13.
#[derive(Clone, Debug)]
pub struct Participation {
    counts: Vec<usize>,
    iterations: usize,
}

impl Participation {
    pub fn new(m: usize) -> Self {
        Participation { counts: vec![0; m], iterations: 0 }
    }

    /// Record the active set A_t of one iteration.
    pub fn record(&mut self, active: &[usize]) {
        self.iterations += 1;
        for &i in active {
            self.counts[i] += 1;
        }
    }

    /// Fraction of iterations node i participated in.
    pub fn fraction(&self, i: usize) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            self.counts[i] as f64 / self.iterations as f64
        }
    }

    pub fn fractions(&self) -> Vec<f64> {
        (0..self.counts.len()).map(|i| self.fraction(i)).collect()
    }

    pub fn iterations(&self) -> usize {
        self.iterations
    }

    /// Coefficient of variation across nodes — 0 for perfectly uniform
    /// participation; large for the skewed async profile of Fig. 13.
    pub fn imbalance(&self) -> f64 {
        let f = self.fractions();
        let mean = f.iter().sum::<f64>() / f.len() as f64;
        if mean == 0.0 {
            return 0.0;
        }
        let var = f.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / f.len() as f64;
        var.sqrt() / mean
    }
}

/// Precision / recall / F1 of support recovery — the paper's LASSO
/// sparsity metric (§5.4).
pub fn f1_support(w_true: &[f64], w_hat: &[f64], tol: f64) -> (f64, f64, f64) {
    assert_eq!(w_true.len(), w_hat.len());
    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut fn_ = 0usize;
    for (t, h) in w_true.iter().zip(w_hat) {
        let t_nz = t.abs() > tol;
        let h_nz = h.abs() > tol;
        match (t_nz, h_nz) {
            (true, true) => tp += 1,
            (false, true) => fp += 1,
            (true, false) => fn_ += 1,
            (false, false) => {}
        }
    }
    let p = if tp + fp == 0 { 0.0 } else { tp as f64 / (tp + fp) as f64 };
    let r = if tp + fn_ == 0 { 0.0 } else { tp as f64 / (tp + fn_) as f64 };
    let f1 = if p + r == 0.0 { 0.0 } else { 2.0 * p * r / (p + r) };
    (p, r, f1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn participation_fractions() {
        let mut p = Participation::new(3);
        p.record(&[0, 1]);
        p.record(&[0]);
        p.record(&[0, 2]);
        assert!((p.fraction(0) - 1.0).abs() < 1e-12);
        assert!((p.fraction(1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((p.fraction(2) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(p.iterations(), 3);
    }

    #[test]
    fn imbalance_zero_for_uniform() {
        let mut p = Participation::new(4);
        for _ in 0..10 {
            p.record(&[0, 1, 2, 3]);
        }
        assert!(p.imbalance() < 1e-12);
    }

    #[test]
    fn imbalance_positive_for_skew() {
        let mut p = Participation::new(2);
        for _ in 0..10 {
            p.record(&[0]);
        }
        assert!(p.imbalance() > 0.5);
    }

    #[test]
    fn f1_perfect_recovery() {
        let w = vec![0.0, 1.0, 0.0, -2.0];
        let (p, r, f1) = f1_support(&w, &w, 1e-9);
        assert_eq!((p, r, f1), (1.0, 1.0, 1.0));
    }

    #[test]
    fn f1_partial() {
        let wt = vec![1.0, 1.0, 0.0, 0.0];
        let wh = vec![1.0, 0.0, 1.0, 0.0];
        let (p, r, f1) = f1_support(&wt, &wh, 1e-9);
        assert!((p - 0.5).abs() < 1e-12);
        assert!((r - 0.5).abs() < 1e-12);
        assert!((f1 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_empty_prediction() {
        let wt = vec![1.0, 0.0];
        let wh = vec![0.0, 0.0];
        let (p, r, f1) = f1_support(&wt, &wh, 1e-9);
        assert_eq!((p, r, f1), (0.0, 0.0, 0.0));
    }
}
