//! Metrics: convergence traces, histograms, participation counters,
//! and table/CSV writers used by the benchmark harness.

pub mod histogram;
pub mod trace;
pub mod writer;

pub use histogram::Histogram;
pub use trace::{IterRecord, Trace};
pub use writer::{write_csv, TableWriter};

/// One cluster round as the wait-for-k control plane saw it: what k was
/// asked for, what the engine could actually deliver, and the winners'
/// arrival times. This is the *only* input a
/// [`Controller`](../control/trait.Controller.html) may base its next-k
/// decision on (see `crate::control`) — everything here is derived from
/// recorded arrivals, so a controller-enabled run replays bit-identically
/// from a delay tape.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundStats {
    /// Cluster round index (for L-BFGS this counts both the gradient and
    /// the line-search round of each iteration).
    pub round: usize,
    /// The k the coordinator asked the engine for this round (already
    /// clamped to the controller's hard bounds).
    pub k_requested: usize,
    /// The k the engine delivered — `min(k_requested, live)` under an
    /// adaptive policy, exactly `k_requested` under a static one.
    pub k_effective: usize,
    /// Non-crashed workers at dispatch time.
    pub live: usize,
    /// Virtual seconds from round start to the slowest winner.
    pub elapsed: f64,
    /// Winner arrival times in arrival order (ascending; ties broken by
    /// worker index) — the per-round arrival "histogram" raw data.
    pub arrivals: Vec<f64>,
}

impl RoundStats {
    /// The winners' arrival times as a [`Histogram`] (exact percentiles).
    pub fn arrival_histogram(&self) -> Histogram {
        let mut h = Histogram::new();
        for &a in &self.arrivals {
            h.record(a);
        }
        h
    }

    /// Gap between the slowest and second-slowest winner — the marginal
    /// price paid for the last unit of k this round (0 when k < 2).
    pub fn tail_gap(&self) -> f64 {
        match self.arrivals.len() {
            0 | 1 => 0.0,
            n => self.arrivals[n - 1] - self.arrivals[n - 2],
        }
    }
}

/// Per-node participation statistics — the empirical probability of the
/// event {i ∈ A_t} plotted in the paper's Figures 12–13.
#[derive(Clone, Debug)]
pub struct Participation {
    counts: Vec<usize>,
    iterations: usize,
}

impl Participation {
    pub fn new(m: usize) -> Self {
        Participation { counts: vec![0; m], iterations: 0 }
    }

    /// Record the active set A_t of one iteration.
    pub fn record(&mut self, active: &[usize]) {
        self.iterations += 1;
        for &i in active {
            self.counts[i] += 1;
        }
    }

    /// Fraction of iterations node i participated in.
    pub fn fraction(&self, i: usize) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            self.counts[i] as f64 / self.iterations as f64
        }
    }

    pub fn fractions(&self) -> Vec<f64> {
        (0..self.counts.len()).map(|i| self.fraction(i)).collect()
    }

    pub fn iterations(&self) -> usize {
        self.iterations
    }

    /// Coefficient of variation across nodes — 0 for perfectly uniform
    /// participation; large for the skewed async profile of Fig. 13.
    pub fn imbalance(&self) -> f64 {
        let f = self.fractions();
        let mean = f.iter().sum::<f64>() / f.len() as f64;
        if mean == 0.0 {
            return 0.0;
        }
        let var = f.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / f.len() as f64;
        var.sqrt() / mean
    }
}

/// Precision / recall / F1 of support recovery — the paper's LASSO
/// sparsity metric (§5.4).
pub fn f1_support(w_true: &[f64], w_hat: &[f64], tol: f64) -> (f64, f64, f64) {
    assert_eq!(w_true.len(), w_hat.len());
    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut fn_ = 0usize;
    for (t, h) in w_true.iter().zip(w_hat) {
        let t_nz = t.abs() > tol;
        let h_nz = h.abs() > tol;
        match (t_nz, h_nz) {
            (true, true) => tp += 1,
            (false, true) => fp += 1,
            (true, false) => fn_ += 1,
            (false, false) => {}
        }
    }
    let p = if tp + fp == 0 { 0.0 } else { tp as f64 / (tp + fp) as f64 };
    let r = if tp + fn_ == 0 { 0.0 } else { tp as f64 / (tp + fn_) as f64 };
    let f1 = if p + r == 0.0 { 0.0 } else { 2.0 * p * r / (p + r) };
    (p, r, f1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn participation_fractions() {
        let mut p = Participation::new(3);
        p.record(&[0, 1]);
        p.record(&[0]);
        p.record(&[0, 2]);
        assert!((p.fraction(0) - 1.0).abs() < 1e-12);
        assert!((p.fraction(1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((p.fraction(2) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(p.iterations(), 3);
    }

    #[test]
    fn imbalance_zero_for_uniform() {
        let mut p = Participation::new(4);
        for _ in 0..10 {
            p.record(&[0, 1, 2, 3]);
        }
        assert!(p.imbalance() < 1e-12);
    }

    #[test]
    fn imbalance_positive_for_skew() {
        let mut p = Participation::new(2);
        for _ in 0..10 {
            p.record(&[0]);
        }
        assert!(p.imbalance() > 0.5);
    }

    #[test]
    fn f1_perfect_recovery() {
        let w = vec![0.0, 1.0, 0.0, -2.0];
        let (p, r, f1) = f1_support(&w, &w, 1e-9);
        assert_eq!((p, r, f1), (1.0, 1.0, 1.0));
    }

    #[test]
    fn f1_partial() {
        let wt = vec![1.0, 1.0, 0.0, 0.0];
        let wh = vec![1.0, 0.0, 1.0, 0.0];
        let (p, r, f1) = f1_support(&wt, &wh, 1e-9);
        assert!((p - 0.5).abs() < 1e-12);
        assert!((r - 0.5).abs() < 1e-12);
        assert!((f1 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn round_stats_tail_gap_and_histogram() {
        let s = RoundStats {
            round: 0,
            k_requested: 3,
            k_effective: 3,
            live: 4,
            elapsed: 0.9,
            arrivals: vec![0.1, 0.2, 0.9],
        };
        assert!((s.tail_gap() - 0.7).abs() < 1e-12);
        let mut h = s.arrival_histogram();
        assert_eq!(h.len(), 3);
        assert_eq!(h.max(), 0.9);
        let empty = RoundStats { arrivals: vec![], k_effective: 0, ..s.clone() };
        assert_eq!(empty.tail_gap(), 0.0);
        let one = RoundStats { arrivals: vec![0.5], k_effective: 1, ..s };
        assert_eq!(one.tail_gap(), 0.0);
    }

    #[test]
    fn f1_empty_prediction() {
        let wt = vec![1.0, 0.0];
        let wh = vec![0.0, 0.0];
        let (p, r, f1) = f1_support(&wt, &wh, 1e-9);
        assert_eq!((p, r, f1), (0.0, 0.0, 0.0));
    }
}
