//! Convergence traces: one record per outer iteration.

/// A single iteration record.
#[derive(Clone, Debug, PartialEq)]
pub struct IterRecord {
    /// Outer iteration index t.
    pub iter: usize,
    /// Simulated wall-clock at the end of the iteration (seconds).
    pub time: f64,
    /// Objective value f(w_t) on the ORIGINAL (uncoded) problem — the
    /// paper reports convergence in terms of the original objective.
    pub objective: f64,
    /// Optional generalization metric (test RMSE / error / F1).
    pub test_metric: f64,
    /// |A_t| actually waited for.
    pub k_used: usize,
}

/// Trace of a full optimization run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub records: Vec<IterRecord>,
    pub label: String,
}

impl Trace {
    pub fn new(label: &str) -> Self {
        Trace { records: Vec::new(), label: label.to_string() }
    }

    pub fn push(&mut self, rec: IterRecord) {
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn final_objective(&self) -> f64 {
        // lint:allow(no-silent-nan) — documented empty-trace sentinel
        self.records.last().map(|r| r.objective).unwrap_or(f64::NAN)
    }

    pub fn final_test_metric(&self) -> f64 {
        // lint:allow(no-silent-nan) — documented empty-trace sentinel
        self.records.last().map(|r| r.test_metric).unwrap_or(f64::NAN)
    }

    pub fn total_time(&self) -> f64 {
        self.records.last().map(|r| r.time).unwrap_or(0.0)
    }

    /// First time the objective drops at/below `target`; None if never.
    pub fn time_to_objective(&self, target: f64) -> Option<f64> {
        self.records.iter().find(|r| r.objective <= target).map(|r| r.time)
    }

    /// Last record with time ≤ t (state of the run at wall/sim time t).
    pub fn at_time(&self, t: f64) -> Option<&IterRecord> {
        self.records.iter().take_while(|r| r.time <= t).last()
    }

    /// Objective at time t (NaN before the first record).
    pub fn objective_at_time(&self, t: f64) -> f64 {
        // lint:allow(no-silent-nan) — documented before-first-record sentinel
        self.at_time(t).map(|r| r.objective).unwrap_or(f64::NAN)
    }

    /// Test metric at time t (NaN before the first record).
    pub fn test_metric_at_time(&self, t: f64) -> f64 {
        // lint:allow(no-silent-nan) — documented before-first-record sentinel
        self.at_time(t).map(|r| r.test_metric).unwrap_or(f64::NAN)
    }

    /// Running mean of objective values up to each t — the quantity the
    /// paper's Theorems 2/5 bound for the general convex case.
    pub fn running_mean_objective(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.records.len());
        let mut acc = 0.0;
        for (i, r) in self.records.iter().enumerate() {
            acc += r.objective;
            out.push(acc / (i + 1) as f64);
        }
        out
    }

    /// Is the objective sequence non-divergent (bounded by c·f(w_0))?
    pub fn bounded_by(&self, c: f64) -> bool {
        if self.records.is_empty() {
            return true;
        }
        let f0 = self.records[0].objective;
        self.records.iter().all(|r| r.objective <= c * f0 + 1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(objs: &[f64]) -> Trace {
        let mut t = Trace::new("test");
        for (i, &o) in objs.iter().enumerate() {
            t.push(IterRecord {
                iter: i,
                time: i as f64 * 0.5,
                objective: o,
                test_metric: 0.0,
                k_used: 4,
            });
        }
        t
    }

    #[test]
    fn final_and_total() {
        let t = mk(&[10.0, 5.0, 2.0]);
        assert_eq!(t.final_objective(), 2.0);
        assert_eq!(t.total_time(), 1.0);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn time_to_objective() {
        let t = mk(&[10.0, 5.0, 2.0]);
        assert_eq!(t.time_to_objective(5.0), Some(0.5));
        assert_eq!(t.time_to_objective(1.0), None);
    }

    #[test]
    fn at_time_queries() {
        let t = mk(&[10.0, 5.0, 2.0]); // times 0.0, 0.5, 1.0
        assert_eq!(t.objective_at_time(0.6), 5.0);
        assert_eq!(t.objective_at_time(10.0), 2.0);
        assert!(t.objective_at_time(-0.1).is_nan());
    }

    #[test]
    fn running_mean() {
        let t = mk(&[4.0, 2.0, 0.0]);
        assert_eq!(t.running_mean_objective(), vec![4.0, 3.0, 2.0]);
    }

    #[test]
    fn bounded_by_checks_divergence() {
        assert!(mk(&[1.0, 0.9, 0.5]).bounded_by(1.0));
        assert!(!mk(&[1.0, 3.0]).bounded_by(2.0));
        assert!(mk(&[]).bounded_by(1.0));
    }
}
