//! Mini property-testing framework (offline `proptest` stand-in).
//!
//! Usage (`no_run`: doctest binaries don't inherit the xla rpath
//! rustflags; the same pattern is exercised by unit tests below):
//! ```no_run
//! use coded_opt::testutil::{Gen, PropRunner};
//! PropRunner::new("k_le_m", 0xC0DE).cases(100).run(
//!     |g| {
//!         let m = g.usize_in(1, 64);
//!         let k = g.usize_in(1, m);
//!         (m, k)
//!     },
//!     |&(m, k)| {
//!         if k <= m { Ok(()) } else { Err(format!("k={k} > m={m}")) }
//!     },
//! );
//! ```

use crate::rng::Pcg64;

/// Value generator handed to the case-builder closure.
pub struct Gen {
    rng: Pcg64,
    /// Size budget in [0,1]; shrinking replays with smaller budgets.
    size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Gen { rng: Pcg64::new(seed), size }
    }

    /// Uniform usize in [lo, hi] (inclusive), scaled down when shrinking.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64) * self.size).round() as usize;
        lo + if scaled == 0 { 0 } else { self.rng.gen_range(scaled + 1) }
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        crate::rng::Normal::sample_standard(&mut self.rng)
    }

    /// Vec of f64 in [lo, hi) with length in [min_len, max_len].
    pub fn vec_f64(&mut self, min_len: usize, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Bernoulli(p).
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.gen_bool(p)
    }

    /// Random subset of {0..n} of exactly size k.
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        crate::rng::sample_without_replacement(&mut self.rng, n, k)
    }

    /// Access the raw RNG for bespoke generation.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Failure report from a property run.
#[derive(Debug)]
pub struct PropError {
    pub property: String,
    pub seed: u64,
    pub message: String,
}

impl std::fmt::Display for PropError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property '{}' failed (replay seed {:#x}): {}",
            self.property, self.seed, self.message
        )
    }
}

/// Drives a property over many seeded cases with greedy size-shrinking.
pub struct PropRunner {
    name: String,
    seed: u64,
    cases: usize,
}

impl PropRunner {
    pub fn new(name: &str, seed: u64) -> Self {
        PropRunner { name: name.to_string(), seed, cases: 64 }
    }

    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Run `property` over `cases` inputs built by `build`. On failure,
    /// retries the same case seed at smaller generator sizes to find a
    /// smaller counterexample, then panics with the report.
    pub fn run<T: std::fmt::Debug>(
        &self,
        mut build: impl FnMut(&mut Gen) -> T,
        mut property: impl FnMut(&T) -> Result<(), String>,
    ) {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
            let mut gen = Gen::new(case_seed, 1.0);
            let value = build(&mut gen);
            if let Err(msg) = property(&value) {
                // Greedy shrink: replay the same seed with smaller budgets.
                let mut best: (f64, String, String) = (1.0, msg, format!("{value:?}"));
                for &size in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                    let mut g = Gen::new(case_seed, size);
                    let v = build(&mut g);
                    if let Err(m) = property(&v) {
                        best = (size, m, format!("{v:?}"));
                    }
                }
                let err = PropError {
                    property: self.name.clone(),
                    seed: case_seed,
                    message: format!(
                        "{} [shrunk size={}] counterexample: {}",
                        best.1, best.0, best.2
                    ),
                };
                panic!("{err}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        PropRunner::new("sum_commutes", 1).cases(50).run(
            |g| (g.f64_in(-10.0, 10.0), g.f64_in(-10.0, 10.0)),
            |&(a, b)| {
                if (a + b - (b + a)).abs() < 1e-15 {
                    Ok(())
                } else {
                    Err("non-commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "always_fails")]
    fn failing_property_panics_with_name() {
        PropRunner::new("always_fails", 2).cases(3).run(
            |g| g.usize_in(0, 100),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn subset_has_exact_size() {
        PropRunner::new("subset_size", 3).cases(50).run(
            |g| {
                let n = g.usize_in(1, 40);
                let k = g.usize_in(0, n);
                (n, k, g.subset(n, k))
            },
            |(n, k, s)| {
                if s.len() != *k {
                    return Err(format!("len {} != k {k}", s.len()));
                }
                if s.iter().any(|&i| i >= *n) {
                    return Err("out of range".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shrinking_reduces_size() {
        // Property that fails for vectors longer than 3; the shrunk
        // counterexample reported should be small. We can't easily capture
        // the panic message here, so just verify the mechanism doesn't
        // crash on a passing run with small budgets.
        let mut g = Gen::new(42, 0.01);
        let v = g.vec_f64(0, 1000, 0.0, 1.0);
        assert!(v.len() <= 10);
    }
}
