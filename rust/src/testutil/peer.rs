//! A deliberately misbehaving socket-engine worker for fault-injection
//! tests.
//!
//! Each [`MisbehavingPeer`] binds an ephemeral localhost port, accepts
//! exactly one master session on a background thread, and then
//! misbehaves in one scripted way ([`PeerMode`]). The conformance suite
//! points a [`SocketCluster`](crate::cluster::SocketCluster) at it and
//! asserts that every mode surfaces as a *crash-erasure* — the peer is
//! interrupted out of the active set, the `k ≤ live` invariant holds,
//! and its stale bytes never reach an assembler — rather than a hang or
//! panic.

use std::io::Write as _;
use std::net::TcpListener;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::cluster::wire::{read_msg, write_msg, write_msg_with_version, Msg, WIRE_VERSION};

/// The scripted fault a [`MisbehavingPeer`] commits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerMode {
    /// Answer the first task with the first 10 bytes of a valid result
    /// frame — EOF lands mid-header, a torn frame.
    TornFrame,
    /// Answer with a full header but only half the promised body, then
    /// close — a truncated payload.
    TruncatedResult,
    /// Answer with a well-formed result echoing the *wrong* iteration
    /// (`iter + 1`) — a stale/confused payload the master must drop.
    WrongIterEcho,
    /// Open the session with a `Hello` stamped `WIRE_VERSION + 1` —
    /// the handshake must refuse cleanly.
    WrongVersionHello,
    /// Accept the task and never reply — the master's read timeout, not
    /// a hang, must end the round.
    Stall,
}

/// One scripted-fault worker session on an ephemeral localhost port.
pub struct MisbehavingPeer {
    addr: String,
    handle: Option<JoinHandle<()>>,
}

impl MisbehavingPeer {
    /// Bind `127.0.0.1:0` and serve one master session in `mode`,
    /// advertising a `rows × cols` partition in the `Hello`.
    pub fn spawn(mode: PeerMode, rows: u64, cols: u64) -> Result<Self> {
        let listener =
            TcpListener::bind("127.0.0.1:0").context("binding misbehaving peer")?;
        let addr = listener.local_addr()?.to_string();
        let handle = std::thread::spawn(move || {
            // A refused/failed session is the point of this peer; errors
            // here only mean the master already gave up on us.
            let _ = serve_once(&listener, mode, rows, cols);
        });
        Ok(MisbehavingPeer { addr, handle: Some(handle) })
    }

    /// The address to hand the master, e.g. in a `--worker-addrs` slot.
    pub fn addr(&self) -> &str {
        &self.addr
    }
}

impl Drop for MisbehavingPeer {
    fn drop(&mut self) {
        // The serving thread exits on its own in every mode (the master
        // disconnecting unblocks any pending I/O); joining keeps test
        // teardown deterministic.
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_once(listener: &TcpListener, mode: PeerMode, rows: u64, cols: u64) -> Result<()> {
    let (mut stream, _) = listener.accept()?;
    stream.set_nodelay(true).ok();
    if mode == PeerMode::WrongVersionHello {
        write_msg_with_version(
            &mut stream,
            &Msg::Hello { rows, cols },
            WIRE_VERSION + 1,
        )?;
        stream.flush()?;
        // Hold the connection open until the master hangs up: the test
        // asserts the *master* refuses, not that we disconnected first.
        let _ = read_msg(&mut stream);
        return Ok(());
    }
    write_msg(&mut stream, &Msg::Hello { rows, cols })?;
    stream.flush()?;
    loop {
        let task = match read_msg(&mut stream) {
            Ok(Msg::Task { iter, .. }) => iter,
            // Shutdown or disconnect: session over.
            _ => return Ok(()),
        };
        match mode {
            PeerMode::TornFrame => {
                let frame = result_frame(task, cols);
                stream.write_all(&frame[..10])?;
                stream.flush()?;
                return Ok(()); // close: EOF mid-header on the master side
            }
            PeerMode::TruncatedResult => {
                let frame = result_frame(task, cols);
                stream.write_all(&frame[..frame.len() / 2])?;
                stream.flush()?;
                return Ok(()); // close: EOF mid-body
            }
            PeerMode::WrongIterEcho => {
                write_msg(
                    &mut stream,
                    &Msg::Result { iter: task + 1, payload: vec![0.0; cols as usize] },
                )?;
                stream.flush()?;
                // keep answering wrongly until the master hangs up
            }
            PeerMode::Stall => {
                // Never reply; block until the master's timeout closes
                // the connection (the next read returns Err/EOF).
                let _ = read_msg(&mut stream);
                return Ok(());
            }
            PeerMode::WrongVersionHello => unreachable!("handled before the loop"),
        }
    }
}

/// A well-formed `Result` frame for `iter` with a `cols`-sized payload —
/// the byte source the torn/truncated modes cut short.
fn result_frame(iter: u64, cols: u64) -> Vec<u8> {
    let mut frame = Vec::new();
    write_msg(&mut frame, &Msg::Result { iter, payload: vec![0.5; cols as usize] })
        .expect("Vec write cannot fail");
    frame
}
