//! Test utilities, including a miniature property-testing framework.
//!
//! The offline environment has no `proptest` crate, so [`prop`] provides
//! the subset we need: seeded value generators, a `run` driver that
//! executes a property over many random cases, and greedy shrinking for
//! failures so that counterexamples are small and readable.

pub mod peer;
pub mod prop;

pub use peer::{MisbehavingPeer, PeerMode};
pub use prop::{Gen, PropError, PropRunner};

/// Assert two f64 slices are elementwise close.
pub fn assert_allclose(a: &[f64], b: &[f64], atol: f64, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= atol + 1e-9 * y.abs().max(x.abs()),
            "{ctx}: index {i}: {x} vs {y} (atol={atol})"
        );
    }
}

/// Relative error ‖a−b‖/max(‖b‖, eps).
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    let diff = crate::linalg::sub(a, b);
    crate::linalg::norm2(&diff) / crate::linalg::norm2(b).max(1e-12)
}
