//! Command-line argument parsing for the launcher (no clap offline).
//!
//! Grammar: `coded-opt <subcommand> [--key value | --key=value | --flag]*`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(tok) = it.next() {
            let Some(stripped) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument '{tok}'");
            };
            if stripped.is_empty() {
                bail!("bare '--' not supported");
            }
            if let Some(eq) = stripped.find('=') {
                let (k, v) = stripped.split_at(eq);
                args.options.insert(k.to_string(), v[1..].to_string());
            } else if let Some(next) = it.peek() {
                if next.starts_with("--") {
                    args.flags.push(stripped.to_string());
                } else {
                    args.options.insert(stripped.to_string(), it.next().unwrap());
                }
            } else {
                args.flags.push(stripped.to_string());
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| {
                anyhow::anyhow!("--{key} expects an integer, got '{v}'")
            })?)),
        }
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| {
                anyhow::anyhow!("--{key} expects a number, got '{v}'")
            })?)),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["run", "--config", "exp.toml", "--k=12", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("config"), Some("exp.toml"));
        assert_eq!(a.get_usize("k").unwrap(), Some(12));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["bench", "--fast"]);
        assert!(a.has_flag("fast"));
    }

    #[test]
    fn negative_number_value() {
        let a = parse(&["run", "--offset=-1.5"]);
        assert_eq!(a.get_f64("offset").unwrap(), Some(-1.5));
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--k", "3"]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_usize("k").unwrap(), Some(3));
    }

    #[test]
    fn bad_int_errors() {
        let a = parse(&["run", "--k", "abc"]);
        assert!(a.get_usize("k").is_err());
    }

    #[test]
    fn positional_after_subcommand_rejected() {
        assert!(Args::parse(["run".to_string(), "oops".to_string()]).is_err());
    }
}
