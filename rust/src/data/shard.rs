//! Out-of-core row-shard storage: the versioned on-disk format behind
//! the paper's §4.2 "efficient mechanisms for encoding large-scale
//! data" at dataset sizes that never fit one memory image.
//!
//! A *sharded dataset* is a directory containing
//! - `manifest.json` — schema `coded-opt/shard-v1`: global shape
//!   (`rows`, `cols`), targets flag, X payload [`Dtype`] (absent field
//!   = `f64`, so version-1 manifests parse unchanged), and one entry
//!   per shard file (name, starting row, row count, payload checksum);
//! - `shard-NNNNN.bin` — consecutive row blocks of the design matrix
//!   `X` (row-major little-endian, element width per the dtype) plus,
//!   when targets are present, the matching slice of `y` (always f64).
//!
//! ## Shard file layout (versions 1 and 2)
//!
//! ```text
//! offset  size          field
//! 0       4             magic  b"CSHD"
//! 4       4             u32 LE version (1 = f64 X payload, 2 = flagged)
//! 8       8             u64 LE row0   (global row of the first row)
//! 16      8             u64 LE rows   (rows in this shard)
//! 24      8             u64 LE cols
//! 32      1             v1: has_targets (0 / 1)
//!                       v2: flags — bit 0 has_targets, bit 1 f32 X
//! 33      rows·cols·w   X block, row-major LE (w = 8 f64, 4 f32)
//! …       rows·8        y block, f64 LE (present iff has_targets)
//! ```
//!
//! An f64 dataset is written as version-1 files byte-for-byte, so every
//! pre-dtype reader and fixture keeps working; only `f32` storage emits
//! version-2 files. The read path always widens X to an f64 [`Mat`] —
//! storage precision is a disk/bandwidth knob, not an arithmetic one
//! (see [`crate::linalg::precision`] for the tolerance contract).
//!
//! [`ShardWriter`] splits any row stream into fixed-size shards;
//! [`ShardStream`] / [`ShardedSource`] read them back one block at a
//! time. The [`BlockSource`] trait is the streaming contract the
//! encode layer ([`crate::encoding::stream`]) and the driver's sharded
//! data path consume: blocks arrive in ascending row order, and a
//! source can be iterated any number of times (the FWHT encode path
//! makes one pass per column panel). A consumer of this interface
//! holds at most one block of the *input* at a time — the interface has
//! no whole-matrix accessor — so whatever it builds (encoded worker
//! partitions, streamed statistics) is assembled without the `n × p`
//! input ever existing in memory.

use std::fs;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

// lint:allow(zone-containment) — shares bench's dependency-free JSON writer; no timing flows
use crate::bench::json;
use crate::linalg::Mat;
use anyhow::{ensure, Context, Result};

/// Manifest schema tag. Unchanged across shard-file versions: version 2
/// only *adds* an optional `dtype` field, so every v1 document is a
/// valid v2 document.
pub const SHARD_SCHEMA: &str = "coded-opt/shard-v1";

/// Highest binary shard-file version this build writes/reads. Readers
/// accept `1..=SHARD_VERSION`; writers emit 1 for f64 payloads (byte
/// compatibility) and 2 for f32.
pub const SHARD_VERSION: u32 = 2;

const MAGIC: &[u8; 4] = b"CSHD";
const MANIFEST_FILE: &str = "manifest.json";

/// Flags byte (header offset 32) of a version-2 shard file.
const FLAG_TARGETS: u8 = 0b01;
const FLAG_F32: u8 = 0b10;

/// On-disk element type of the X payload (`y` is always f64).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 8-byte LE doubles — the version-1 format, bit-exact round trip.
    F64,
    /// 4-byte LE floats — half the payload; each element is the
    /// nearest-f32 rounding of the written value, widened exactly on
    /// read.
    F32,
}

impl Dtype {
    /// Canonical name (`"f64"` / `"f32"`).
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F64 => "f64",
            Dtype::F32 => "f32",
        }
    }

    /// Parse a manifest / CLI spelling.
    pub fn parse(s: &str) -> Option<Dtype> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f64" => Some(Dtype::F64),
            "f32" => Some(Dtype::F32),
            _ => None,
        }
    }

    /// Stored bytes per X element.
    pub fn width(self) -> usize {
        match self {
            Dtype::F64 => 8,
            Dtype::F32 => 4,
        }
    }
}

/// A re-iterable source of contiguous row blocks of `(X, y)`.
///
/// The streaming contract every out-of-core consumer relies on:
/// - blocks cover rows `0..rows()` exactly once, in ascending order;
/// - each callback sees one block of at most [`max_block_rows`] rows
///   (`x.cols() == cols()`, `y.len() == x.rows()` when
///   [`has_targets`], else `y` is empty);
/// - the source can be re-iterated (multi-pass encodes).
///
/// [`max_block_rows`]: BlockSource::max_block_rows
/// [`has_targets`]: BlockSource::has_targets
pub trait BlockSource {
    /// Total data rows n.
    fn rows(&self) -> usize;

    /// Data columns p.
    fn cols(&self) -> usize;

    /// Whether blocks carry a target slice `y`.
    fn has_targets(&self) -> bool;

    /// Upper bound on the rows of any yielded block — the resident-set
    /// bound of the streaming pipeline.
    fn max_block_rows(&self) -> usize;

    /// Stream the blocks in ascending row order:
    /// `f(row0, x_block, y_block)`.
    fn for_each_block(
        &self,
        f: &mut dyn FnMut(usize, &Mat, &[f64]) -> Result<()>,
    ) -> Result<()>;
}

/// Assemble the full target vector `y` from a source (n floats — the
/// one full-length buffer the streaming pipeline keeps; it is O(n),
/// never O(n·p)).
pub fn assemble_targets(src: &dyn BlockSource) -> Result<Vec<f64>> {
    ensure!(src.has_targets(), "data source has no target vector y");
    let mut y = Vec::with_capacity(src.rows());
    src.for_each_block(&mut |row0, _x, yb| {
        ensure!(row0 == y.len(), "target blocks out of order");
        y.extend_from_slice(yb);
        Ok(())
    })?;
    ensure!(y.len() == src.rows(), "target stream short: {} of {}", y.len(), src.rows());
    Ok(y)
}

/// In-memory [`BlockSource`]: view an existing `(X, y)` as a stream of
/// `block_rows`-row blocks. The equivalence referee for the sharded
/// path (same blocks, no files) and the bench harness's source.
pub struct MatSource<'a> {
    x: &'a Mat,
    y: Option<&'a [f64]>,
    block_rows: usize,
}

impl<'a> MatSource<'a> {
    pub fn new(x: &'a Mat, y: Option<&'a [f64]>, block_rows: usize) -> Self {
        assert!(block_rows >= 1, "block_rows must be ≥ 1");
        if let Some(y) = y {
            assert_eq!(y.len(), x.rows(), "X/y row mismatch");
        }
        MatSource { x, y, block_rows }
    }
}

impl BlockSource for MatSource<'_> {
    fn rows(&self) -> usize {
        self.x.rows()
    }

    fn cols(&self) -> usize {
        self.x.cols()
    }

    fn has_targets(&self) -> bool {
        self.y.is_some()
    }

    fn max_block_rows(&self) -> usize {
        self.block_rows.min(self.x.rows().max(1))
    }

    fn for_each_block(
        &self,
        f: &mut dyn FnMut(usize, &Mat, &[f64]) -> Result<()>,
    ) -> Result<()> {
        let n = self.x.rows();
        let mut r0 = 0;
        while r0 < n {
            let r1 = (r0 + self.block_rows).min(n);
            let xb = self.x.row_block(r0, r1);
            let yb: &[f64] = match self.y {
                Some(y) => &y[r0..r1],
                None => &[],
            };
            f(r0, &xb, yb)?;
            r0 = r1;
        }
        Ok(())
    }
}

/// One shard file's manifest entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardMeta {
    /// File name relative to the dataset directory.
    pub file: String,
    /// Global row of the shard's first row.
    pub row0: usize,
    /// Rows in this shard.
    pub rows: usize,
    /// FNV-1a 64 checksum of the payload bytes (X then y).
    pub checksum: u64,
}

/// The dataset manifest (`manifest.json`, schema `coded-opt/shard-v1`).
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub rows: usize,
    pub cols: usize,
    pub has_targets: bool,
    /// X payload storage type. Absent in pre-dtype manifests, which
    /// parse as [`Dtype::F64`].
    pub dtype: Dtype,
    /// The writer's shard-row target: every shard has exactly this many
    /// rows except possibly the last.
    pub shard_rows: usize,
    pub shards: Vec<ShardMeta>,
}

impl Manifest {
    /// Serialize to the `coded-opt/shard-v1` JSON document.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"schema\": \"{SHARD_SCHEMA}\",\n"));
        out.push_str(&format!("  \"version\": {},\n", SHARD_VERSION));
        out.push_str(&format!("  \"rows\": {},\n", self.rows));
        out.push_str(&format!("  \"cols\": {},\n", self.cols));
        out.push_str(&format!("  \"has_targets\": {},\n", self.has_targets));
        out.push_str(&format!("  \"dtype\": \"{}\",\n", self.dtype.name()));
        out.push_str(&format!("  \"shard_rows\": {},\n", self.shard_rows));
        out.push_str("  \"shards\": [\n");
        for (i, s) in self.shards.iter().enumerate() {
            // checksum as a hex string: the minimal JSON parser reads
            // numbers as f64, which cannot hold a full 64-bit hash.
            out.push_str(&format!(
                "    {{\"file\": \"{}\", \"row0\": {}, \"rows\": {}, \
                 \"checksum\": \"{:016x}\"}}{}",
                json::escape(&s.file),
                s.row0,
                s.rows,
                s.checksum,
                if i + 1 < self.shards.len() { ",\n" } else { "\n" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Parse and structurally validate a manifest document.
    pub fn parse_json(text: &str) -> Result<Manifest> {
        let root = json::parse(text)?;
        let obj = root.as_object().context("shard manifest: root must be an object")?;
        let schema = json::get(obj, "schema")
            .and_then(|v| v.as_str())
            .context("shard manifest: missing schema")?;
        ensure!(
            schema == SHARD_SCHEMA,
            "shard manifest: unknown schema '{schema}' (want {SHARD_SCHEMA})"
        );
        let version = json::get(obj, "version")
            .and_then(|v| v.as_f64())
            .context("shard manifest: missing version")? as u32;
        ensure!(
            (1..=SHARD_VERSION).contains(&version),
            "shard manifest: unsupported version {version} (this build reads 1..={SHARD_VERSION})"
        );
        let num = |key: &str| -> Result<usize> {
            Ok(json::get(obj, key)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("shard manifest: missing {key}"))? as usize)
        };
        let rows = num("rows")?;
        let cols = num("cols")?;
        let shard_rows = num("shard_rows")?;
        let has_targets = json::get(obj, "has_targets")
            .and_then(|v| v.as_bool())
            .context("shard manifest: missing has_targets")?;
        let dtype = match json::get(obj, "dtype").and_then(|v| v.as_str()) {
            // pre-dtype (version 1) manifests omit the field
            None => Dtype::F64,
            Some(s) => Dtype::parse(s)
                .with_context(|| format!("shard manifest: unknown dtype '{s}'"))?,
        };
        let shards_v = json::get(obj, "shards")
            .and_then(|v| v.as_array())
            .context("shard manifest: missing shards array")?;
        let mut shards = Vec::with_capacity(shards_v.len());
        for v in shards_v {
            let e = v.as_object().context("shard entry must be an object")?;
            let file = json::get(e, "file")
                .and_then(|v| v.as_str())
                .context("shard entry: missing file")?
                .to_string();
            ensure!(
                !file.contains('/') && !file.contains(".."),
                "shard entry: file name '{file}' must be a plain name inside the dataset dir"
            );
            let fld = |key: &str| -> Result<f64> {
                json::get(e, key)
                    .and_then(|v| v.as_f64())
                    .with_context(|| format!("shard entry: missing {key}"))
            };
            let checksum_hex = json::get(e, "checksum")
                .and_then(|v| v.as_str())
                .context("shard entry: missing checksum")?;
            let checksum = u64::from_str_radix(checksum_hex, 16)
                .with_context(|| format!("shard entry: bad checksum '{checksum_hex}'"))?;
            shards.push(ShardMeta {
                file,
                row0: fld("row0")? as usize,
                rows: fld("rows")? as usize,
                checksum,
            });
        }
        let m = Manifest { rows, cols, has_targets, dtype, shard_rows, shards };
        m.validate()?;
        Ok(m)
    }

    /// Structural invariants: shards tile `0..rows` contiguously in
    /// order, each at most `shard_rows` rows.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.cols >= 1, "shard manifest: cols must be ≥ 1");
        ensure!(self.shard_rows >= 1, "shard manifest: shard_rows must be ≥ 1");
        let mut next = 0usize;
        for (i, s) in self.shards.iter().enumerate() {
            ensure!(
                s.row0 == next,
                "shard manifest: shard #{i} starts at row {} (expected {next})",
                s.row0
            );
            ensure!(s.rows >= 1, "shard manifest: shard #{i} is empty");
            ensure!(
                s.rows <= self.shard_rows,
                "shard manifest: shard #{i} has {} rows > shard_rows {}",
                s.rows,
                self.shard_rows
            );
            next += s.rows;
        }
        ensure!(
            next == self.rows,
            "shard manifest: shards cover {next} rows, dataset declares {}",
            self.rows
        );
        Ok(())
    }
}

/// FNV-1a 64-bit over a byte stream (manifest payload checksums; fast,
/// dependency-free, and good enough to catch truncation / corruption —
/// not a cryptographic integrity guarantee).
fn fnv1a64(acc: u64, bytes: &[u8]) -> u64 {
    let mut h = acc;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Seed value for [`fnv1a64`] (the standard offset basis).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

fn f64s_to_le_bytes(vals: &[f64], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(vals.len() * 8);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Demote to nearest-f32 and serialize — the `Dtype::F32` X payload.
fn f64s_to_f32_le_bytes(vals: &[f64], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(vals.len() * 4);
    for &v in vals {
        out.extend_from_slice(&(v as f32).to_le_bytes());
    }
}

fn le_bytes_to_f64s(bytes: &[u8], out: &mut Vec<f64>) {
    debug_assert_eq!(bytes.len() % 8, 0);
    out.clear();
    out.reserve(bytes.len() / 8);
    for c in bytes.chunks_exact(8) {
        out.push(f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]));
    }
}

/// Widen an f32 LE payload to f64 values (exact).
fn f32_le_bytes_to_f64s(bytes: &[u8], out: &mut Vec<f64>) {
    debug_assert_eq!(bytes.len() % 4, 0);
    out.clear();
    out.reserve(bytes.len() / 4);
    for c in bytes.chunks_exact(4) {
        out.push(f64::from(f32::from_le_bytes([c[0], c[1], c[2], c[3]])));
    }
}

/// Streaming shard writer: feed it row blocks of any size (in order);
/// it re-chunks them into `shard_rows`-row shard files and produces the
/// manifest. Peak resident data: one shard buffer.
pub struct ShardWriter {
    dir: PathBuf,
    cols: usize,
    shard_rows: usize,
    has_targets: bool,
    dtype: Dtype,
    /// Buffered rows not yet flushed (≤ shard_rows · cols values).
    xbuf: Vec<f64>,
    ybuf: Vec<f64>,
    rows_written: usize,
    shards: Vec<ShardMeta>,
    finished: bool,
}

impl ShardWriter {
    /// Create a writer into `dir` (created if missing; an existing
    /// manifest there is an error — shard sets are immutable).
    pub fn create(
        dir: impl AsRef<Path>,
        cols: usize,
        shard_rows: usize,
        has_targets: bool,
    ) -> Result<ShardWriter> {
        ensure!(cols >= 1, "shard writer: cols must be ≥ 1");
        ensure!(shard_rows >= 1, "shard writer: shard_rows must be ≥ 1");
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating shard dir {}", dir.display()))?;
        let manifest = dir.join(MANIFEST_FILE);
        ensure!(
            !manifest.exists(),
            "shard dir {} already holds a dataset (shard sets are immutable; \
             write to a fresh directory)",
            dir.display()
        );
        Ok(ShardWriter {
            dir,
            cols,
            shard_rows,
            has_targets,
            dtype: Dtype::F64,
            xbuf: Vec::new(),
            ybuf: Vec::new(),
            rows_written: 0,
            shards: Vec::new(),
            finished: false,
        })
    }

    /// X payload storage type (default [`Dtype::F64`] — the version-1
    /// byte format). [`Dtype::F32`] emits version-2 files with each X
    /// element rounded to nearest f32; targets stay f64 either way.
    pub fn with_dtype(mut self, dtype: Dtype) -> Self {
        self.dtype = dtype;
        self
    }

    /// Append a row block (and its target slice when the writer was
    /// created with targets).
    pub fn append(&mut self, x: &Mat, y: &[f64]) -> Result<()> {
        ensure!(!self.finished, "shard writer already finished");
        ensure!(
            x.cols() == self.cols,
            "shard writer: block has {} cols, want {}",
            x.cols(),
            self.cols
        );
        if self.has_targets {
            ensure!(y.len() == x.rows(), "shard writer: y block length mismatch");
        } else {
            ensure!(y.is_empty(), "shard writer: unexpected targets (created without)");
        }
        self.xbuf.extend_from_slice(x.as_slice());
        self.ybuf.extend_from_slice(y);
        while self.xbuf.len() >= self.shard_rows * self.cols {
            self.flush_shard(self.shard_rows)?;
        }
        Ok(())
    }

    /// Flush the first `rows` buffered rows into the next shard file.
    fn flush_shard(&mut self, rows: usize) -> Result<()> {
        let nvals = rows * self.cols;
        let file = format!("shard-{:05}.bin", self.shards.len());
        let path = self.dir.join(&file);
        let f = fs::File::create(&path)
            .with_context(|| format!("creating shard file {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        // f64 payloads stay version-1 files byte-for-byte; only f32
        // storage needs the version-2 flags byte.
        let (version, flags) = match self.dtype {
            Dtype::F64 => (1u32, u8::from(self.has_targets)),
            Dtype::F32 => (2u32, u8::from(self.has_targets) | FLAG_F32),
        };
        w.write_all(&version.to_le_bytes())?;
        w.write_all(&(self.rows_written as u64).to_le_bytes())?;
        w.write_all(&(rows as u64).to_le_bytes())?;
        w.write_all(&(self.cols as u64).to_le_bytes())?;
        w.write_all(&[flags])?;
        let mut bytes = Vec::new();
        match self.dtype {
            Dtype::F64 => f64s_to_le_bytes(&self.xbuf[..nvals], &mut bytes),
            Dtype::F32 => f64s_to_f32_le_bytes(&self.xbuf[..nvals], &mut bytes),
        }
        let mut checksum = fnv1a64(FNV_OFFSET, &bytes);
        w.write_all(&bytes)?;
        if self.has_targets {
            f64s_to_le_bytes(&self.ybuf[..rows], &mut bytes);
            checksum = fnv1a64(checksum, &bytes);
            w.write_all(&bytes)?;
        }
        w.flush()?;
        self.xbuf.drain(..nvals);
        if self.has_targets {
            self.ybuf.drain(..rows);
        }
        self.shards.push(ShardMeta { file, row0: self.rows_written, rows, checksum });
        self.rows_written += rows;
        Ok(())
    }

    /// Flush the tail shard, write `manifest.json`, and return the
    /// manifest.
    pub fn finish(mut self) -> Result<Manifest> {
        ensure!(!self.finished, "shard writer already finished");
        let tail_rows = self.xbuf.len() / self.cols;
        if tail_rows > 0 {
            self.flush_shard(tail_rows)?;
        }
        ensure!(self.rows_written > 0, "shard writer: no rows appended");
        self.finished = true;
        let manifest = Manifest {
            rows: self.rows_written,
            cols: self.cols,
            has_targets: self.has_targets,
            dtype: self.dtype,
            shard_rows: self.shard_rows,
            shards: std::mem::take(&mut self.shards),
        };
        manifest.validate()?;
        fs::write(self.dir.join(MANIFEST_FILE), manifest.to_json())
            .with_context(|| format!("writing manifest in {}", self.dir.display()))?;
        Ok(manifest)
    }
}

/// Shard an in-memory dataset: the general writer entry point
/// (`coded-opt shard` uses the fully streaming generator in
/// [`super::synth`] instead where one exists).
pub fn shard_dataset(
    x: &Mat,
    y: Option<&[f64]>,
    dir: impl AsRef<Path>,
    shard_rows: usize,
) -> Result<Manifest> {
    shard_dataset_dtype(x, y, dir, shard_rows, Dtype::F64)
}

/// [`shard_dataset`] with an explicit X payload [`Dtype`]
/// (`coded-opt shard --dtype f32` lands here).
pub fn shard_dataset_dtype(
    x: &Mat,
    y: Option<&[f64]>,
    dir: impl AsRef<Path>,
    shard_rows: usize,
    dtype: Dtype,
) -> Result<Manifest> {
    let mut w =
        ShardWriter::create(&dir, x.cols(), shard_rows, y.is_some())?.with_dtype(dtype);
    // Feed in shard-sized blocks so the writer buffer stays small.
    let src = MatSource::new(x, y, shard_rows);
    src.for_each_block(&mut |_r0, xb, yb| w.append(xb, yb))?;
    w.finish()
}

/// One decoded block from a [`ShardStream`].
pub struct ShardBlock {
    /// Global row of the block's first row.
    pub row0: usize,
    pub x: Mat,
    /// Empty when the dataset has no targets.
    pub y: Vec<f64>,
}

/// Sequential reader over a sharded dataset: yields one [`ShardBlock`]
/// per shard file, verifying headers and checksums against the
/// manifest. Construct via [`ShardedSource::stream`].
pub struct ShardStream<'a> {
    source: &'a ShardedSource,
    next: usize,
}

impl Iterator for ShardStream<'_> {
    type Item = Result<ShardBlock>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.next >= self.source.manifest.shards.len() {
            return None;
        }
        let i = self.next;
        self.next += 1;
        Some(self.source.read_shard(i))
    }
}

/// An opened sharded dataset: the manifest plus the directory, usable
/// as a re-iterable [`BlockSource`]. Opening reads ONLY the manifest;
/// shard payloads are read one block at a time during streaming, so
/// peak resident data is one shard, not the dataset.
#[derive(Clone, Debug)]
pub struct ShardedSource {
    dir: PathBuf,
    manifest: Manifest,
}

impl ShardedSource {
    /// Open a dataset directory (reads + validates `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<ShardedSource> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join(MANIFEST_FILE);
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading shard manifest {}", path.display()))?;
        // (map_err, not with_context: the inner error is already an
        // anyhow::Error, and Context is only for std errors / options)
        let manifest = Manifest::parse_json(&text)
            .map_err(|e| e.context(format!("parsing shard manifest {}", path.display())))?;
        Ok(ShardedSource { dir, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Iterate the shards once, in row order.
    pub fn stream(&self) -> ShardStream<'_> {
        ShardStream { source: self, next: 0 }
    }

    /// Read + verify shard `i`.
    fn read_shard(&self, i: usize) -> Result<ShardBlock> {
        let meta = &self.manifest.shards[i];
        let path = self.dir.join(&meta.file);
        let f = fs::File::open(&path)
            .with_context(|| format!("opening shard {}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut head = [0u8; 33];
        r.read_exact(&mut head)
            .with_context(|| format!("reading shard header {}", path.display()))?;
        ensure!(&head[0..4] == MAGIC, "shard {}: bad magic", meta.file);
        let version = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
        ensure!(
            (1..=SHARD_VERSION).contains(&version),
            "shard {}: unsupported version {version} (this build reads 1..={SHARD_VERSION})",
            meta.file
        );
        let rd_u64 = |o: usize| {
            u64::from_le_bytes([
                head[o],
                head[o + 1],
                head[o + 2],
                head[o + 3],
                head[o + 4],
                head[o + 5],
                head[o + 6],
                head[o + 7],
            ]) as usize
        };
        let (row0, rows, cols) = (rd_u64(8), rd_u64(16), rd_u64(24));
        let flags = head[32];
        let (has_targets, dtype) = if version == 1 {
            ensure!(flags <= 1, "shard {}: bad has_targets byte {flags}", meta.file);
            (flags != 0, Dtype::F64)
        } else {
            ensure!(
                flags & !(FLAG_TARGETS | FLAG_F32) == 0,
                "shard {}: unknown flag bits {flags:#04x}",
                meta.file
            );
            (
                flags & FLAG_TARGETS != 0,
                if flags & FLAG_F32 != 0 { Dtype::F32 } else { Dtype::F64 },
            )
        };
        ensure!(
            dtype == self.manifest.dtype,
            "shard {}: payload dtype {} disagrees with manifest {}",
            meta.file,
            dtype.name(),
            self.manifest.dtype.name()
        );
        ensure!(
            row0 == meta.row0 && rows == meta.rows,
            "shard {}: header rows [{row0}, {row0}+{rows}) disagree with manifest \
             [{}, {}+{})",
            meta.file,
            meta.row0,
            meta.row0,
            meta.rows
        );
        ensure!(
            cols == self.manifest.cols && has_targets == self.manifest.has_targets,
            "shard {}: header shape disagrees with manifest",
            meta.file
        );
        let mut bytes = vec![0u8; rows * cols * dtype.width()];
        r.read_exact(&mut bytes)
            .with_context(|| format!("reading shard payload {}", path.display()))?;
        let mut checksum = fnv1a64(FNV_OFFSET, &bytes);
        let mut xvals = Vec::new();
        match dtype {
            Dtype::F64 => le_bytes_to_f64s(&bytes, &mut xvals),
            Dtype::F32 => f32_le_bytes_to_f64s(&bytes, &mut xvals),
        }
        let x = Mat::from_vec(rows, cols, xvals);
        let mut y = Vec::new();
        if has_targets {
            let mut ybytes = vec![0u8; rows * 8];
            r.read_exact(&mut ybytes)
                .with_context(|| format!("reading shard targets {}", path.display()))?;
            checksum = fnv1a64(checksum, &ybytes);
            le_bytes_to_f64s(&ybytes, &mut y);
        }
        let mut tail = [0u8; 1];
        ensure!(
            r.read(&mut tail)? == 0,
            "shard {}: trailing bytes after declared payload",
            meta.file
        );
        ensure!(
            checksum == meta.checksum,
            "shard {}: checksum mismatch (file corrupt or manifest stale)",
            meta.file
        );
        Ok(ShardBlock { row0, x, y })
    }

    /// Load the entire dataset into memory (tests / small datasets /
    /// explicit opt-out of streaming). NOT used by the streaming encode
    /// or driver paths — those consume [`BlockSource`] blocks.
    pub fn load_dense(&self) -> Result<(Mat, Option<Vec<f64>>)> {
        // lint:allow(eager-buffer) — load_dense IS the documented whole-matrix escape hatch
        let mut x = Mat::zeros(self.manifest.rows, self.manifest.cols);
        let mut y =
            if self.manifest.has_targets { Some(vec![0.0; self.manifest.rows]) } else { None };
        for block in self.stream() {
            let b = block?;
            for r in 0..b.x.rows() {
                x.row_mut(b.row0 + r).copy_from_slice(b.x.row(r));
            }
            if let Some(y) = y.as_mut() {
                y[b.row0..b.row0 + b.y.len()].copy_from_slice(&b.y);
            }
        }
        Ok((x, y))
    }

    /// Largest eigenvalue of `XᵀX` by streamed power iteration — the
    /// smoothness-constant estimate for step-size defaults on sharded
    /// runs (`Σ_b X_bᵀ(X_b·v)` per iteration; O(p + block) memory).
    /// Matches [`Mat::gram_spectral_norm`] to power-iteration accuracy,
    /// not bit-for-bit (the fold crosses block boundaries).
    pub fn gram_spectral_norm(&self, iters: usize, seed: u64) -> Result<f64> {
        let p = self.manifest.cols;
        let mut rng = crate::rng::Pcg64::new(seed);
        let mut v: Vec<f64> = (0..p).map(|_| rng.next_f64() - 0.5).collect();
        let mut lambda = 0.0;
        for _ in 0..iters {
            let mut atav = vec![0.0; p];
            for block in self.stream() {
                let b = block?;
                let u = b.x.matvec(&v);
                let part = b.x.matvec_t(&u);
                crate::linalg::axpy(1.0, &part, &mut atav);
            }
            let norm = crate::linalg::norm2(&atav);
            if norm == 0.0 {
                return Ok(0.0);
            }
            crate::linalg::scale(1.0 / norm, &mut atav);
            lambda = norm;
            v = atav;
        }
        Ok(lambda)
    }

    /// `1/(2n)·‖Xw − y‖²` computed in one streaming pass — the
    /// least-squares data term of ridge / LASSO objectives for sharded
    /// runs, without materializing `X`. Accumulates residual energy in
    /// ascending row order (one sequential fold).
    pub fn half_mse(&self, w: &[f64]) -> Result<f64> {
        ensure!(self.manifest.has_targets, "dataset has no targets: cannot evaluate");
        ensure!(w.len() == self.manifest.cols, "iterate length mismatch");
        let mut acc = 0.0;
        for block in self.stream() {
            let b = block?;
            let pred = b.x.matvec(w);
            for (p, yi) in pred.iter().zip(&b.y) {
                let r = p - yi;
                acc += r * r;
            }
        }
        Ok(acc / (2.0 * self.manifest.rows as f64))
    }
}

impl BlockSource for ShardedSource {
    fn rows(&self) -> usize {
        self.manifest.rows
    }

    fn cols(&self) -> usize {
        self.manifest.cols
    }

    fn has_targets(&self) -> bool {
        self.manifest.has_targets
    }

    fn max_block_rows(&self) -> usize {
        self.manifest.shard_rows
    }

    fn for_each_block(
        &self,
        f: &mut dyn FnMut(usize, &Mat, &[f64]) -> Result<()>,
    ) -> Result<()> {
        for block in self.stream() {
            let b = block?;
            f(b.row0, &b.x, &b.y)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_linear;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("coded-opt-shard-test-{name}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn write_stream_roundtrip_is_bit_identical() {
        let (x, y, _) = gaussian_linear(53, 7, 0.3, 11);
        let dir = tmpdir("roundtrip");
        let manifest = shard_dataset(&x, Some(&y), &dir, 8).unwrap();
        assert_eq!(manifest.rows, 53);
        assert_eq!(manifest.cols, 7);
        assert_eq!(manifest.shards.len(), 7, "⌈53/8⌉ shards");
        assert_eq!(manifest.shards.last().unwrap().rows, 5, "tail shard");
        let src = ShardedSource::open(&dir).unwrap();
        assert_eq!(src.manifest(), &manifest);
        let (x2, y2) = src.load_dense().unwrap();
        assert_eq!(x.as_slice(), x2.as_slice(), "X bits must survive the disk trip");
        assert_eq!(y, y2.unwrap(), "y bits must survive the disk trip");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn f32_dataset_roundtrips_at_f32_fidelity() {
        let (x, y, _) = gaussian_linear(37, 6, 0.3, 17);
        let dir = tmpdir("f32-roundtrip");
        let manifest = shard_dataset_dtype(&x, Some(&y), &dir, 8, Dtype::F32).unwrap();
        assert_eq!(manifest.dtype, Dtype::F32);
        let src = ShardedSource::open(&dir).unwrap();
        assert_eq!(src.manifest().dtype, Dtype::F32);
        let (x2, y2) = src.load_dense().unwrap();
        // X comes back as the exact widening of its nearest-f32 rounding…
        for (orig, got) in x.as_slice().iter().zip(x2.as_slice()) {
            assert_eq!(*got, f64::from(*orig as f32));
        }
        // …while y (always f64 on disk) round-trips bit-exactly.
        assert_eq!(y, y2.unwrap());
        // The f32 payload really is half-width on disk: header 33 bytes
        // + rows·cols·4 (X) + rows·8 (y).
        let s0 = &manifest.shards[0];
        let len = fs::metadata(dir.join(&s0.file)).unwrap().len() as usize;
        assert_eq!(len, 33 + s0.rows * 6 * 4 + s0.rows * 8);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn dtype_absent_in_manifest_means_f64() {
        let (x, y, _) = gaussian_linear(12, 3, 0.2, 19);
        let dir = tmpdir("dtype-absent");
        shard_dataset(&x, Some(&y), &dir, 6).unwrap();
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path).unwrap();
        // Strip the dtype line, emulating a pre-dtype (version 1)
        // manifest; the dataset must still open and read as f64.
        let stripped: String =
            text.lines().filter(|l| !l.contains("\"dtype\"")).collect::<Vec<_>>().join("\n");
        assert_ne!(stripped, text, "fixture must actually drop the field");
        fs::write(&path, stripped).unwrap();
        let src = ShardedSource::open(&dir).unwrap();
        assert_eq!(src.manifest().dtype, Dtype::F64);
        let (x2, _) = src.load_dense().unwrap();
        assert_eq!(x.as_slice(), x2.as_slice());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn blocks_are_bounded_and_ordered() {
        let (x, y, _) = gaussian_linear(40, 3, 0.1, 3);
        let dir = tmpdir("bounded");
        shard_dataset(&x, Some(&y), &dir, 16).unwrap();
        let src = ShardedSource::open(&dir).unwrap();
        let mut next = 0;
        src.for_each_block(&mut |row0, xb, yb| {
            assert_eq!(row0, next, "ascending contiguous blocks");
            assert!(xb.rows() <= src.max_block_rows(), "resident set bounded by shard size");
            assert_eq!(yb.len(), xb.rows());
            next += xb.rows();
            Ok(())
        })
        .unwrap();
        assert_eq!(next, 40);
        // multi-pass: a second full iteration sees the same rows
        let mut passes = 0;
        src.for_each_block(&mut |_, xb, _| {
            passes += xb.rows();
            Ok(())
        })
        .unwrap();
        assert_eq!(passes, 40);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corruption_is_detected() {
        let (x, y, _) = gaussian_linear(20, 4, 0.1, 5);
        let dir = tmpdir("corrupt");
        let manifest = shard_dataset(&x, Some(&y), &dir, 8).unwrap();
        let victim = dir.join(&manifest.shards[1].file);
        let mut bytes = fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        fs::write(&victim, &bytes).unwrap();
        let src = ShardedSource::open(&dir).unwrap();
        let err = src.load_dense().unwrap_err();
        assert!(err.to_string().contains("checksum"), "got: {err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_json_roundtrip_and_validation() {
        let m = Manifest {
            rows: 10,
            cols: 3,
            has_targets: true,
            dtype: Dtype::F64,
            shard_rows: 6,
            shards: vec![
                ShardMeta { file: "shard-00000.bin".into(), row0: 0, rows: 6, checksum: 1 },
                ShardMeta { file: "shard-00001.bin".into(), row0: 6, rows: 4, checksum: 2 },
            ],
        };
        let back = Manifest::parse_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        // non-contiguous shards rejected
        let mut bad = m.clone();
        bad.shards[1].row0 = 7;
        assert!(bad.validate().is_err());
        // wrong total rejected
        let mut bad = m.clone();
        bad.rows = 11;
        assert!(bad.validate().is_err());
        // path traversal rejected
        let evil = m.to_json().replace("shard-00001.bin", "../evil.bin");
        assert!(Manifest::parse_json(&evil).is_err());
    }

    #[test]
    fn writer_rechunks_arbitrary_append_sizes() {
        let (x, y, _) = gaussian_linear(30, 5, 0.2, 7);
        let dir = tmpdir("rechunk");
        let mut w = ShardWriter::create(&dir, 5, 12, true).unwrap();
        // feed blocks of irregular sizes: 1, 2, 3, … rows
        let mut r0 = 0;
        let mut step = 1;
        while r0 < 30 {
            let r1 = (r0 + step).min(30);
            w.append(&x.row_block(r0, r1), &y[r0..r1]).unwrap();
            r0 = r1;
            step += 1;
        }
        let manifest = w.finish().unwrap();
        assert_eq!(manifest.shards.len(), 3, "30 rows at 12/shard → 12+12+6");
        let (x2, y2) = ShardedSource::open(&dir).unwrap().load_dense().unwrap();
        assert_eq!(x.as_slice(), x2.as_slice());
        assert_eq!(y, y2.unwrap());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn existing_dataset_is_not_overwritten() {
        let (x, y, _) = gaussian_linear(10, 2, 0.1, 9);
        let dir = tmpdir("immutable");
        shard_dataset(&x, Some(&y), &dir, 4).unwrap();
        let err = shard_dataset(&x, Some(&y), &dir, 4).unwrap_err();
        assert!(err.to_string().contains("immutable"), "got: {err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn half_mse_matches_in_memory() {
        let (x, y, _) = gaussian_linear(25, 4, 0.3, 13);
        let dir = tmpdir("mse");
        shard_dataset(&x, Some(&y), &dir, 8).unwrap();
        let src = ShardedSource::open(&dir).unwrap();
        let w = vec![0.3, -0.1, 0.2, 0.5];
        let pred = x.matvec(&w);
        let exact: f64 =
            pred.iter().zip(&y).map(|(p, yi)| (p - yi) * (p - yi)).sum::<f64>() / 50.0;
        let got = src.half_mse(&w).unwrap();
        assert!((got - exact).abs() <= 1e-12 * exact.abs().max(1.0), "{got} vs {exact}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn mat_source_streams_without_targets() {
        let (x, _, _) = gaussian_linear(9, 2, 0.1, 1);
        let src = MatSource::new(&x, None, 4);
        assert!(!src.has_targets());
        assert!(assemble_targets(&src).is_err());
        let mut rows = 0;
        src.for_each_block(&mut |_, xb, yb| {
            assert!(yb.is_empty());
            rows += xb.rows();
            Ok(())
        })
        .unwrap();
        assert_eq!(rows, 9);
    }
}
