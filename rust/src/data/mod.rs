//! Synthetic workload generators mirroring the paper's datasets.
//!
//! - [`synth`] — the §5.1 ridge ensemble (i.i.d. Gaussian design, linear
//!   model + noise) and the §5.4 sparse-recovery LASSO ensemble.
//! - [`movielens`] — MovieLens-like low-rank ratings with user/movie/
//!   global biases (the real MovieLens-1M is not redistributable in this
//!   offline environment; DESIGN.md §5 documents the substitution).
//! - [`rcv1like`] — rcv1.binary-like sparse two-class documents with
//!   power-law feature frequencies.
//! - [`shard`] — the out-of-core row-shard format (versioned binary
//!   shards + JSON manifest) and the [`shard::BlockSource`] streaming
//!   contract consumed by [`crate::encoding::stream`] and the driver's
//!   sharded data path.

pub mod movielens;
pub mod rcv1like;
pub mod shard;
pub mod synth;

pub use shard::{BlockSource, Dtype, Manifest, MatSource, ShardStream, ShardWriter, ShardedSource};
