//! rcv1.binary-like synthetic sparse document classification data
//! (substitute for Reuters RCV1, unavailable offline — DESIGN.md §5).
//!
//! Matches the structural properties the paper's §5.3 experiment relies
//! on: high-dimensional sparse tf-idf-like features with power-law
//! frequencies, binary labels from a sparse ground-truth separator, and
//! rows stored as `zᵢ = yᵢ·xᵢ` (label-scaled), which is the form the
//! logistic objective consumes.

use crate::linalg::Csr;
use crate::rng::{Normal, Pareto, Pcg64};
use crate::rng::dist::Distribution;

/// Generated document dataset. Rows of `train`/`test` are `zᵢ = yᵢxᵢ`.
pub struct DocsData {
    pub train: Csr,
    pub test: Csr,
    /// Sparse ground-truth separator.
    pub w_true: Vec<f64>,
    pub n_features: usize,
}

/// Generate `n_docs` documents over `n_features` features with about
/// `nnz_per_doc` non-zeros each; `label_noise` is the fraction of labels
/// flipped. 1/7 of documents are held out (mirroring the paper's
/// 100 000 of ~700 000).
pub fn generate(
    n_docs: usize,
    n_features: usize,
    nnz_per_doc: usize,
    label_noise: f64,
    seed: u64,
) -> DocsData {
    let mut rng = Pcg64::with_stream(seed, 0xdc5);
    // Power-law feature popularity.
    let pareto = Pareto::new(1.0, 1.1);
    let weights: Vec<f64> = (0..n_features).map(|_| pareto.sample(&mut rng)).collect();
    let total: f64 = weights.iter().sum();
    let mut cum = vec![0.0; n_features];
    let mut acc = 0.0;
    for i in 0..n_features {
        acc += weights[i] / total;
        cum[i] = acc;
    }
    let sample_feature = |rng: &mut Pcg64| -> usize {
        let u = rng.next_f64();
        cum.partition_point(|&c| c < u).min(n_features - 1)
    };
    // Sparse ground truth on ~10% of features.
    let support =
        crate::rng::sample_without_replacement(&mut rng, n_features, (n_features / 10).max(1));
    let coef = Normal::new(0.0, 1.0);
    let mut w_true = vec![0.0; n_features];
    for &f in &support {
        w_true[f] = coef.sample(&mut rng);
    }

    let tfidf = Normal::new(0.5, 0.2);
    let mut triplets_train: Vec<(usize, usize, f64)> = Vec::new();
    let mut triplets_test: Vec<(usize, usize, f64)> = Vec::new();
    let n_test = n_docs / 7;
    let mut train_row = 0usize;
    let mut test_row = 0usize;
    for doc in 0..n_docs {
        // sample distinct features for this doc
        let mut feats: Vec<usize> = Vec::with_capacity(nnz_per_doc);
        let mut guard = 0;
        while feats.len() < nnz_per_doc.min(n_features) && guard < 50 * nnz_per_doc {
            guard += 1;
            let f = sample_feature(&mut rng);
            if !feats.contains(&f) {
                feats.push(f);
            }
        }
        let vals: Vec<f64> = feats.iter().map(|_| tfidf.sample(&mut rng).abs() + 0.05).collect();
        // label from the ground truth separator (+ noise)
        let margin: f64 = feats.iter().zip(&vals).map(|(&f, &v)| v * w_true[f]).sum();
        let mut label = if margin >= 0.0 { 1.0 } else { -1.0 };
        if rng.gen_bool(label_noise) {
            label = -label;
        }
        let is_test = doc < n_test;
        let row = if is_test { &mut test_row } else { &mut train_row };
        for (&f, &v) in feats.iter().zip(&vals) {
            let z = label * v;
            if is_test {
                triplets_test.push((*row, f, z));
            } else {
                triplets_train.push((*row, f, z));
            }
        }
        *row += 1;
    }
    DocsData {
        train: Csr::from_triplets(train_row, n_features, &triplets_train),
        test: Csr::from_triplets(test_row, n_features, &triplets_test),
        w_true,
        n_features,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_split() {
        let ds = generate(70, 40, 6, 0.05, 1);
        assert_eq!(ds.test.rows(), 10);
        assert_eq!(ds.train.rows(), 60);
        assert_eq!(ds.train.cols(), 40);
    }

    #[test]
    fn rows_are_sparse() {
        let ds = generate(50, 200, 8, 0.05, 2);
        for i in 0..ds.train.rows() {
            let nnz = ds.train.row_iter(i).count();
            assert!(nnz <= 8, "row {i} has {nnz} non-zeros");
            assert!(nnz >= 1);
        }
    }

    #[test]
    fn ground_truth_separates_train_data() {
        // with zero label noise, zᵢᵀw_true ≥ 0 for every row
        let ds = generate(40, 30, 5, 0.0, 3);
        let margins = ds.train.matvec(&ds.w_true);
        assert!(margins.iter().all(|&m| m >= 0.0));
    }

    #[test]
    fn label_noise_flips_some() {
        let ds = generate(200, 30, 5, 0.3, 4);
        let margins = ds.train.matvec(&ds.w_true);
        let violated = margins.iter().filter(|&&m| m < 0.0).count();
        assert!(violated > 10, "expected flipped labels, got {violated}");
    }

    #[test]
    fn feature_popularity_skewed() {
        let ds = generate(300, 100, 6, 0.05, 5);
        let mut counts = vec![0usize; 100];
        for i in 0..ds.train.rows() {
            for (f, _) in ds.train.row_iter(i) {
                counts[f] += 1;
            }
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top10: usize = counts[..10].iter().sum();
        let total: usize = counts.iter().sum();
        assert!(top10 as f64 > 0.3 * total as f64);
    }
}
