//! MovieLens-like synthetic ratings (substitute for MovieLens-1M, which
//! is not available offline — see DESIGN.md §5).
//!
//! Generative model matching the structure the paper's MF objective
//! (eq. 12) assumes: `R_ij = clip(x_iᵀy_j + u_i + v_j + b + ε, 1, 5)`
//! with low-rank user/movie factors, per-user/movie biases, and a
//! popularity power law on which movies get rated. 80/20 split as in the
//! paper.

use crate::objectives::matfac::Rating;
use crate::rng::{Normal, Pareto, Pcg64};
use crate::rng::dist::Distribution;

/// A generated ratings dataset.
pub struct RatingsData {
    pub train: Vec<Rating>,
    pub test: Vec<Rating>,
    pub n_users: usize,
    pub n_movies: usize,
    /// True latent rank used to generate.
    pub rank: usize,
    /// Global mean rating (use as the fixed bias b).
    pub global_mean: f64,
}

/// Generate ratings: each user rates ~`ratings_per_user` movies chosen
/// by a popularity power law; rating = biased low-rank model + N(0, σ²),
/// clipped to [1, 5].
pub fn generate(
    n_users: usize,
    n_movies: usize,
    rank: usize,
    ratings_per_user: usize,
    sigma: f64,
    seed: u64,
) -> RatingsData {
    let mut rng = Pcg64::with_stream(seed, 0x30f1);
    let factor = Normal::new(0.0, (1.0 / rank as f64).sqrt());
    let bias = Normal::new(0.0, 0.3);
    let noise = Normal::new(0.0, sigma);
    let xu: Vec<Vec<f64>> = (0..n_users)
        .map(|_| (0..rank).map(|_| factor.sample(&mut rng)).collect())
        .collect();
    let ym: Vec<Vec<f64>> = (0..n_movies)
        .map(|_| (0..rank).map(|_| factor.sample(&mut rng)).collect())
        .collect();
    let ub: Vec<f64> = (0..n_users).map(|_| bias.sample(&mut rng)).collect();
    let vb: Vec<f64> = (0..n_movies).map(|_| bias.sample(&mut rng)).collect();
    let b = 3.0;

    // Movie popularity: Pareto weights → sampling distribution.
    let pareto = Pareto::new(1.0, 1.2);
    let mut weights: Vec<f64> = (0..n_movies).map(|_| pareto.sample(&mut rng)).collect();
    let total: f64 = weights.iter().sum();
    for w in weights.iter_mut() {
        *w /= total;
    }
    // cumulative for sampling
    let mut cum = vec![0.0; n_movies];
    let mut acc = 0.0;
    for (i, w) in weights.iter().enumerate() {
        acc += w;
        cum[i] = acc;
    }
    let sample_movie = |rng: &mut Pcg64| -> usize {
        let u = rng.next_f64();
        cum.partition_point(|&c| c < u).min(n_movies - 1)
    };

    let mut all = Vec::new();
    for user in 0..n_users {
        let mut seen = vec![false; n_movies];
        let target = ratings_per_user.min(n_movies);
        let mut count = 0;
        let mut attempts = 0;
        while count < target && attempts < 50 * target {
            attempts += 1;
            let movie = sample_movie(&mut rng);
            if seen[movie] {
                continue;
            }
            seen[movie] = true;
            let mean = crate::linalg::dot(&xu[user], &ym[movie]) + ub[user] + vb[movie] + b;
            let value = (mean + noise.sample(&mut rng)).clamp(1.0, 5.0);
            all.push(Rating { user, movie, value });
            count += 1;
        }
    }
    // 80/20 split
    crate::rng::shuffle(&mut rng, &mut all);
    let n_test = all.len() / 5;
    let test = all[..n_test].to_vec();
    let train = all[n_test..].to_vec();
    let global_mean = if train.is_empty() {
        3.0
    } else {
        train.iter().map(|r| r.value).sum::<f64>() / train.len() as f64
    };
    RatingsData { train, test, n_users, n_movies, rank, global_mean }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_split() {
        let ds = generate(50, 30, 5, 10, 0.2, 1);
        let total = ds.train.len() + ds.test.len();
        assert_eq!(total, 50 * 10);
        assert_eq!(ds.test.len(), total / 5);
    }

    #[test]
    fn ratings_in_range_and_valid_ids() {
        let ds = generate(20, 15, 3, 8, 0.5, 2);
        for r in ds.train.iter().chain(&ds.test) {
            assert!((1.0..=5.0).contains(&r.value));
            assert!(r.user < 20 && r.movie < 15);
        }
    }

    #[test]
    fn no_duplicate_user_movie_pairs() {
        let ds = generate(10, 20, 3, 10, 0.2, 3);
        let mut pairs: Vec<(usize, usize)> = ds
            .train
            .iter()
            .chain(&ds.test)
            .map(|r| (r.user, r.movie))
            .collect();
        let before = pairs.len();
        pairs.sort_unstable();
        pairs.dedup();
        assert_eq!(pairs.len(), before);
    }

    #[test]
    fn popularity_is_skewed() {
        let ds = generate(100, 50, 3, 10, 0.2, 4);
        let mut counts = vec![0usize; 50];
        for r in ds.train.iter().chain(&ds.test) {
            counts[r.movie] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top5: usize = counts[..5].iter().sum();
        let total: usize = counts.iter().sum();
        assert!(top5 as f64 > 0.2 * total as f64, "top5={top5} of {total}");
    }

    #[test]
    fn global_mean_near_three() {
        let ds = generate(50, 40, 4, 10, 0.3, 5);
        assert!((ds.global_mean - 3.0).abs() < 0.5, "mean={}", ds.global_mean);
    }
}
