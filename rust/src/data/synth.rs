//! Dense synthetic ensembles (paper §5.1 and §5.4).

use crate::linalg::Mat;
use crate::rng::{Normal, Pcg64};
use crate::rng::dist::Distribution;

/// §5.1 ridge ensemble: `X ~ N(0,1)^{n×p}`, `w* ~ N(0,1)^p`,
/// `y = Xw* + σ·z`. Returns (X, y, w*).
pub fn gaussian_linear(n: usize, p: usize, sigma: f64, seed: u64) -> (Mat, Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::with_stream(seed, 0xda7a);
    let x = Mat::from_fn(n, p, |_, _| Normal::sample_standard(&mut rng));
    let w_star: Vec<f64> = (0..p).map(|_| Normal::sample_standard(&mut rng)).collect();
    let mut y = x.matvec(&w_star);
    let noise = Normal::new(0.0, sigma);
    for v in y.iter_mut() {
        *v += noise.sample(&mut rng);
    }
    (x, y, w_star)
}

/// §5.4 LASSO sparse-recovery ensemble: `X ~ N(0,1)^{n×p}`, `w*` has
/// `nnz` non-zeros drawn N(0, 4) at random coordinates,
/// `y = Xw* + σ·z`. Returns (X, y, w*).
pub fn sparse_recovery(
    n: usize,
    p: usize,
    nnz: usize,
    sigma: f64,
    seed: u64,
) -> (Mat, Vec<f64>, Vec<f64>) {
    assert!(nnz <= p);
    let mut rng = Pcg64::with_stream(seed, 0x5a55);
    let x = Mat::from_fn(n, p, |_, _| Normal::sample_standard(&mut rng));
    let support = crate::rng::sample_without_replacement(&mut rng, p, nnz);
    let coef = Normal::new(0.0, 2.0); // N(0, 4) per the paper
    let mut w_star = vec![0.0; p];
    for &i in &support {
        w_star[i] = coef.sample(&mut rng);
    }
    let mut y = x.matvec(&w_star);
    let noise = Normal::new(0.0, sigma);
    for v in y.iter_mut() {
        *v += noise.sample(&mut rng);
    }
    (x, y, w_star)
}

/// [`gaussian_linear`] streamed straight to a shard directory,
/// **bit-identical** to the in-memory ensemble without ever holding the
/// full `X`. Returns the manifest and `w*`.
///
/// The in-memory generator draws one PRNG stream in the order
/// `X` (row-major) → `w*` → per-row noise. Streaming replays exactly
/// that order with two cursors over the same stream:
/// - pass 1 advances a throwaway cursor through the `n·p` design draws
///   (one shard buffer at a time), then draws `w*` — leaving the cursor
///   parked exactly where the noise draws begin;
/// - pass 2 re-draws the design rows shard-by-shard from a fresh
///   cursor, computes `y = X_shard·w*` with the same per-row dot, and
///   adds noise from the parked pass-1 cursor.
///
/// Peak resident data: one `shard_rows × p` block plus `w*`.
pub fn gaussian_linear_shard_to(
    dir: impl AsRef<std::path::Path>,
    n: usize,
    p: usize,
    sigma: f64,
    seed: u64,
    shard_rows: usize,
) -> anyhow::Result<(crate::data::shard::Manifest, Vec<f64>)> {
    gaussian_linear_shard_to_dtype(dir, n, p, sigma, seed, shard_rows, crate::data::Dtype::F64)
}

/// [`gaussian_linear_shard_to`] with an explicit X payload dtype.
/// Generation is identical (the PRNG stream and `y` are f64 regardless);
/// only the on-disk X width changes, so an f32 dataset holds exactly the
/// nearest-f32 rounding of the f64 dataset with the same seed.
pub fn gaussian_linear_shard_to_dtype(
    dir: impl AsRef<std::path::Path>,
    n: usize,
    p: usize,
    sigma: f64,
    seed: u64,
    shard_rows: usize,
    dtype: crate::data::Dtype,
) -> anyhow::Result<(crate::data::shard::Manifest, Vec<f64>)> {
    use crate::data::shard::ShardWriter;
    anyhow::ensure!(n > 0 && p > 0, "n and p must be positive");
    // Pass 1: advance past the n·p design draws, then take w*.
    let mut rng_noise = Pcg64::with_stream(seed, 0xda7a);
    for _ in 0..n * p {
        let _ = Normal::sample_standard(&mut rng_noise);
    }
    let w_star: Vec<f64> = (0..p).map(|_| Normal::sample_standard(&mut rng_noise)).collect();
    // rng_noise is now parked at the first noise draw.
    let mut rng_x = Pcg64::with_stream(seed, 0xda7a);
    let noise = Normal::new(0.0, sigma);
    let mut writer = ShardWriter::create(dir, p, shard_rows, true)?.with_dtype(dtype);
    let mut r0 = 0;
    while r0 < n {
        let rows = shard_rows.min(n - r0);
        let xb = Mat::from_fn(rows, p, |_, _| Normal::sample_standard(&mut rng_x));
        let mut yb = xb.matvec(&w_star);
        for v in yb.iter_mut() {
            *v += noise.sample(&mut rng_noise);
        }
        writer.append(&xb, &yb)?;
        r0 += rows;
    }
    Ok((writer.finish()?, w_star))
}

/// Random train/test row split: returns (train_idx, test_idx) with
/// `test_frac` of rows held out.
pub fn split_rows(n: usize, test_frac: f64, seed: u64) -> (Vec<usize>, Vec<usize>) {
    let n_test = ((n as f64) * test_frac).round() as usize;
    let mut rng = Pcg64::with_stream(seed, 0x59e1);
    let mut idx: Vec<usize> = (0..n).collect();
    crate::rng::shuffle(&mut rng, &mut idx);
    let test = idx[..n_test].to_vec();
    let train = idx[n_test..].to_vec();
    (train, test)
}

/// Extract the given rows of (X, y).
pub fn take_rows(x: &Mat, y: &[f64], idx: &[usize]) -> (Mat, Vec<f64>) {
    let mut xm = Mat::zeros(idx.len(), x.cols());
    let mut ym = Vec::with_capacity(idx.len());
    for (r, &i) in idx.iter().enumerate() {
        xm.row_mut(r).copy_from_slice(x.row(i));
        ym.push(y[i]);
    }
    (xm, ym)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_linear_shapes_and_noise() {
        let (x, y, w) = gaussian_linear(50, 10, 0.0, 1);
        assert_eq!(x.rows(), 50);
        assert_eq!(x.cols(), 10);
        assert_eq!(y.len(), 50);
        assert_eq!(w.len(), 10);
        // noiseless: y = Xw exactly
        let y2 = x.matvec(&w);
        crate::testutil::assert_allclose(&y, &y2, 1e-12, "noiseless");
    }

    #[test]
    fn sparse_recovery_support_size() {
        let (_, _, w) = sparse_recovery(20, 100, 7, 1.0, 2);
        let nnz = w.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nnz, 7);
    }

    #[test]
    fn split_rows_partitions() {
        let (train, test) = split_rows(100, 0.2, 3);
        assert_eq!(test.len(), 20);
        assert_eq!(train.len(), 80);
        let mut all: Vec<usize> = train.iter().chain(&test).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn take_rows_extracts() {
        let (x, y, _) = gaussian_linear(10, 3, 0.1, 4);
        let (xs, ys) = take_rows(&x, &y, &[2, 5]);
        assert_eq!(xs.rows(), 2);
        assert_eq!(xs.row(0), x.row(2));
        assert_eq!(ys[1], y[5]);
    }

    #[test]
    fn streamed_generation_is_bit_identical_to_in_memory() {
        let (n, p, sigma, seed) = (37, 5, 0.4, 21);
        let (x, y, w) = gaussian_linear(n, p, sigma, seed);
        let dir = std::env::temp_dir()
            .join(format!("coded-opt-synth-stream-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (manifest, w2) = gaussian_linear_shard_to(&dir, n, p, sigma, seed, 8).unwrap();
        assert_eq!(manifest.rows, n);
        assert_eq!(w, w2, "w* must replay bit-identically");
        let (x2, y2) =
            crate::data::shard::ShardedSource::open(&dir).unwrap().load_dense().unwrap();
        assert_eq!(x.as_slice(), x2.as_slice(), "streamed X bits");
        assert_eq!(y, y2.unwrap(), "streamed y bits");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn deterministic_given_seed() {
        let (x1, y1, _) = gaussian_linear(5, 2, 0.5, 9);
        let (x2, y2, _) = gaussian_linear(5, 2, 0.5, 9);
        assert_eq!(x1.as_slice(), x2.as_slice());
        assert_eq!(y1, y2);
    }
}
