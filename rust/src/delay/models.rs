//! Concrete delay models.

use super::DelayModel;
use crate::rng::{Exponential, GaussianMixture, Pareto, Pcg64};
use crate::rng::dist::Distribution;

/// Zero injected delay.
pub struct NoDelay {
    m: usize,
}

impl NoDelay {
    pub fn new(m: usize) -> Self {
        NoDelay { m }
    }
}

impl DelayModel for NoDelay {
    fn sample(&mut self, _worker: usize, _iter: usize) -> f64 {
        0.0
    }
    fn workers(&self) -> usize {
        self.m
    }
}

/// Same constant delay everywhere (useful in tests: makes arrival order
/// deterministic up to tie-breaking).
pub struct ConstantDelay {
    m: usize,
    secs: f64,
}

impl ConstantDelay {
    pub fn new(m: usize, secs: f64) -> Self {
        ConstantDelay { m, secs }
    }
}

impl DelayModel for ConstantDelay {
    fn sample(&mut self, _worker: usize, _iter: usize) -> f64 {
        self.secs
    }
    fn workers(&self) -> usize {
        self.m
    }
}

/// i.i.d. exponential latency per (worker, iteration) — the MovieLens
/// experiment's `Δ ~ exp(mean 10 ms)` (§5.2).
pub struct ExponentialDelay {
    m: usize,
    dist: Exponential,
    rng: Pcg64,
}

impl ExponentialDelay {
    pub fn new(m: usize, mean_secs: f64, seed: u64) -> Self {
        ExponentialDelay {
            m,
            dist: Exponential::with_mean(mean_secs),
            rng: Pcg64::with_stream(seed, 0xe4b),
        }
    }
}

impl DelayModel for ExponentialDelay {
    fn sample(&mut self, _worker: usize, _iter: usize) -> f64 {
        self.dist.sample(&mut self.rng)
    }
    fn workers(&self) -> usize {
        self.m
    }
}

/// i.i.d. Gaussian-mixture latency, clipped at 0 (delays cannot be
/// negative). Covers the paper's bimodal (§5.3) and trimodal (§5.4)
/// communication-delay experiments.
pub struct MixtureDelay {
    m: usize,
    dist: GaussianMixture,
    rng: Pcg64,
}

impl MixtureDelay {
    pub fn new(m: usize, dist: GaussianMixture, seed: u64) -> Self {
        MixtureDelay { m, dist, rng: Pcg64::with_stream(seed, 0x617) }
    }

    /// §5.3: 0.5·N(0.5s, 0.2²) + 0.5·N(20s, 5²).
    pub fn paper_bimodal(m: usize, seed: u64) -> Self {
        Self::new(m, GaussianMixture::paper_bimodal(), seed)
    }

    /// §5.4: 0.8·N(0.2, 0.1²) + 0.1·N(0.6, 0.2²) + 0.1·N(1.0, 0.4²).
    pub fn paper_trimodal(m: usize, seed: u64) -> Self {
        Self::new(m, GaussianMixture::paper_trimodal(), seed)
    }
}

impl DelayModel for MixtureDelay {
    fn sample(&mut self, _worker: usize, _iter: usize) -> f64 {
        self.dist.sample(&mut self.rng).max(0.0)
    }
    fn workers(&self) -> usize {
        self.m
    }
}

/// Power-law background load (§5.3): at construction each machine draws a
/// number of dummy background tasks from a Pareto(α) law capped at `cap`;
/// the tasks persist for the whole run, slowing every iteration of that
/// machine proportionally. This produces the *persistent* straggler
/// profile of Figures 12–13 (same machines are always slow).
pub struct BackgroundTasksDelay {
    tasks: Vec<usize>,
    task_secs: f64,
    rng: Pcg64,
}

impl BackgroundTasksDelay {
    pub fn new(m: usize, alpha: f64, cap: usize, task_secs: f64, seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, 0xb69);
        let pareto = Pareto::new(1.0, alpha);
        let tasks = (0..m)
            .map(|_| {
                // numbers of tasks ∈ {0, 1, …, cap}: Pareto ≥ 1 shifted
                let t = pareto.sample(&mut rng).floor() as usize - 1;
                t.min(cap)
            })
            .collect();
        BackgroundTasksDelay { tasks, task_secs, rng }
    }

    /// Background tasks per node (diagnostics / Fig. 12 reproduction).
    pub fn task_counts(&self) -> &[usize] {
        &self.tasks
    }
}

impl DelayModel for BackgroundTasksDelay {
    fn sample(&mut self, worker: usize, _iter: usize) -> f64 {
        // Each background task steals a CPU share (persistent,
        // multiplicative jitter) plus an exponential per-iteration
        // scheduling-noise term — so machines with similar load trade
        // places across iterations (the fractional participation bands
        // of the paper's Figure 12) while heavily-loaded machines stay
        // clearly slow.
        let jitter = 1.0 + 0.05 * (self.rng.next_f64() - 0.5);
        let noise = -(1.0 - self.rng.next_f64()).max(1e-300).ln() * 1.5 * self.task_secs;
        self.tasks[worker] as f64 * self.task_secs * jitter + noise
    }
    fn workers(&self) -> usize {
        self.tasks.len()
    }
}

/// Adversarial: a fixed subset of nodes is delayed by `slow_secs` every
/// iteration. Used by the deterministic-convergence tests — the paper's
/// guarantees hold for *arbitrary* straggler patterns, including this
/// worst case where the same nodes never respond in time.
pub struct AdversarialDelay {
    m: usize,
    slow: Vec<bool>,
    slow_secs: f64,
}

impl AdversarialDelay {
    pub fn new(m: usize, slow_workers: Vec<usize>, slow_secs: f64) -> Self {
        let mut slow = vec![false; m];
        for w in slow_workers {
            slow[w] = true;
        }
        AdversarialDelay { m, slow, slow_secs }
    }

    /// Rotating adversary: delays a different window of ⌈fraction·m⌉
    /// workers each iteration (worst case for replication).
    pub fn rotating(m: usize, fraction: f64, slow_secs: f64) -> RotatingAdversary {
        RotatingAdversary { m, n_slow: ((m as f64) * fraction).ceil() as usize, slow_secs }
    }
}

impl DelayModel for AdversarialDelay {
    fn sample(&mut self, worker: usize, _iter: usize) -> f64 {
        if self.slow[worker] {
            self.slow_secs
        } else {
            0.0
        }
    }
    fn workers(&self) -> usize {
        self.m
    }
}

/// See [`AdversarialDelay::rotating`].
pub struct RotatingAdversary {
    m: usize,
    n_slow: usize,
    slow_secs: f64,
}

impl DelayModel for RotatingAdversary {
    fn sample(&mut self, worker: usize, iter: usize) -> f64 {
        let start = (iter * self.n_slow) % self.m;
        let in_window = (0..self.n_slow).any(|o| (start + o) % self.m == worker);
        if in_window {
            self.slow_secs
        } else {
            0.0
        }
    }
    fn workers(&self) -> usize {
        self.m
    }
}

/// Fastest-of-r wrapper: each logical worker's delay is the minimum of
/// `r` independent draws from the inner model. Used to model the
/// replication baseline under model parallelism: a partition held by r
/// replicas responds as fast as its fastest copy (see
/// `coordinator::bcd::replication_equivalent` for the wait-for-k
/// mapping).
pub struct MinOfR<D: DelayModel> {
    inner: D,
    r: usize,
    m_logical: usize,
}

impl<D: DelayModel> MinOfR<D> {
    /// `inner` must be sized for `r × m_logical` physical workers.
    pub fn new(inner: D, r: usize) -> Self {
        assert!(r >= 1);
        let m_logical = inner.workers() / r;
        assert_eq!(inner.workers(), r * m_logical, "inner model must cover r·P workers");
        MinOfR { inner, r, m_logical }
    }
}

impl<D: DelayModel> DelayModel for MinOfR<D> {
    fn sample(&mut self, worker: usize, iter: usize) -> f64 {
        (0..self.r)
            .map(|c| self.inner.sample(worker + c * self.m_logical, iter))
            .fold(f64::INFINITY, f64::min)
    }
    fn workers(&self) -> usize {
        self.m_logical
    }
}

/// Replay a recorded delay trace: `trace[t][i]` seconds; iterations past
/// the end wrap around.
pub struct TraceDelay {
    trace: Vec<Vec<f64>>,
}

impl TraceDelay {
    pub fn new(trace: Vec<Vec<f64>>) -> Self {
        assert!(!trace.is_empty());
        let m = trace[0].len();
        assert!(trace.iter().all(|r| r.len() == m), "ragged trace");
        TraceDelay { trace }
    }
}

impl DelayModel for TraceDelay {
    fn sample(&mut self, worker: usize, iter: usize) -> f64 {
        self.trace[iter % self.trace.len()][worker]
    }
    fn workers(&self) -> usize {
        self.trace[0].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_mean_matches() {
        let mut d = ExponentialDelay::new(4, 0.01, 7);
        let n = 50_000;
        let mean: f64 = (0..n).map(|t| d.sample(t % 4, t)).sum::<f64>() / n as f64;
        assert!((mean - 0.01).abs() < 5e-4, "mean={mean}");
    }

    #[test]
    fn mixture_never_negative() {
        let mut d = MixtureDelay::paper_bimodal(4, 9);
        assert!((0..10_000).all(|t| d.sample(t % 4, t) >= 0.0));
    }

    #[test]
    fn background_tasks_persistent_per_node() {
        let mut d = BackgroundTasksDelay::new(16, 1.5, 50, 0.05, 11);
        assert!(d.task_counts().iter().all(|&t| t <= 50));
        // heavily loaded nodes are consistently slower than idle ones
        // (averaged over iterations; per-iteration noise can reorder
        // near-equal loads but not a ≥10-task gap)
        let counts = d.task_counts().to_vec();
        if let (Some(&hi), Some(&lo)) = (
            counts.iter().filter(|&&c| c >= 10).min(),
            counts.iter().filter(|&&c| c <= 1).max(),
        ) {
            let hi_w = counts.iter().position(|&c| c == hi).unwrap();
            let lo_w = counts.iter().position(|&c| c == lo).unwrap();
            let mean = |d: &mut BackgroundTasksDelay, w: usize| -> f64 {
                (0..200).map(|t| d.sample(w, t)).sum::<f64>() / 200.0
            };
            assert!(mean(&mut d, hi_w) > mean(&mut d, lo_w));
        }
    }

    #[test]
    fn background_tasks_power_law_is_skewed() {
        let d = BackgroundTasksDelay::new(128, 1.5, 50, 0.05, 13);
        let zero_ish = d.task_counts().iter().filter(|&&t| t == 0).count();
        let heavy = d.task_counts().iter().filter(|&&t| t >= 10).count();
        // majority of machines nearly idle, a heavy tail loaded
        assert!(zero_ish > 50, "zero={zero_ish}");
        assert!(heavy >= 2, "heavy={heavy}");
    }

    #[test]
    fn adversarial_fixed_set() {
        let mut d = AdversarialDelay::new(4, vec![1, 3], 5.0);
        for t in 0..10 {
            assert_eq!(d.sample(0, t), 0.0);
            assert_eq!(d.sample(1, t), 5.0);
            assert_eq!(d.sample(2, t), 0.0);
            assert_eq!(d.sample(3, t), 5.0);
        }
    }

    #[test]
    fn rotating_adversary_moves() {
        let mut d = AdversarialDelay::rotating(4, 0.5, 5.0);
        let slow_at = |d: &mut RotatingAdversary, t: usize| -> Vec<usize> {
            (0..4).filter(|&w| d.sample(w, t) > 0.0).collect()
        };
        let s0 = slow_at(&mut d, 0);
        let s1 = slow_at(&mut d, 1);
        assert_eq!(s0.len(), 2);
        assert_eq!(s1.len(), 2);
        assert_ne!(s0, s1);
    }

    #[test]
    fn min_of_r_takes_fastest_copy() {
        // 4 physical workers (2 logical × r=2); physical 0&2 are copies of
        // logical 0, physical 1&3 of logical 1.
        let inner = TraceDelay::new(vec![vec![5.0, 1.0, 2.0, 7.0]]);
        let mut d = MinOfR::new(inner, 2);
        assert_eq!(d.workers(), 2);
        assert_eq!(d.sample(0, 0), 2.0); // min(5, 2)
        assert_eq!(d.sample(1, 0), 1.0); // min(1, 7)
    }

    #[test]
    fn trace_replays_and_wraps() {
        let mut d = TraceDelay::new(vec![vec![0.1, 0.2], vec![0.3, 0.4]]);
        assert_eq!(d.sample(1, 0), 0.2);
        assert_eq!(d.sample(0, 1), 0.3);
        assert_eq!(d.sample(0, 2), 0.1); // wrap
        assert_eq!(d.workers(), 2);
    }
}
