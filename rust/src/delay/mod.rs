//! Straggler delay models (paper §5 experimental setups).
//!
//! A [`DelayModel`] answers "how many extra seconds does worker `i` take
//! in iteration `t`?". The paper's experiments use:
//! - exponential per-task latency, mean 10 ms (MovieLens, §5.2);
//! - a bimodal Gaussian mixture — half the nodes ~0.5 s, half ~20 s
//!   (logistic regression, §5.3);
//! - a trimodal mixture (LASSO, §5.4);
//! - a power-law number of background tasks per machine, capped at 50
//!   (logistic regression, §5.3) — *persistent* per-node slowdown;
//! - adversarial patterns (used by the deterministic-convergence tests:
//!   the theory holds for arbitrary A_t sequences).
//!
//! Composable *transforms* over these models — time-varying phases,
//! rack-correlated slowdowns, crash/rejoin windows, record/replay — live
//! in [`crate::scenario`]. A [`CRASHED`] (infinite) delay marks a worker
//! as dead for the round; both cluster engines map it onto the paper's
//! stragglers-as-erasures semantics.

pub mod models;

pub use models::{
    AdversarialDelay, BackgroundTasksDelay, ConstantDelay, ExponentialDelay, MinOfR,
    MixtureDelay, NoDelay, TraceDelay,
};

use crate::config::DelaySpec;
use crate::rng::Pcg64;

/// Sentinel delay meaning "this worker is crashed for the round": an
/// unbounded delay, so the wait-for-k gather erases the worker exactly
/// like any other straggler. `SimCluster` gives crashed workers an
/// infinite arrival time; `ThreadCluster` never dispatches to them.
pub const CRASHED: f64 = f64::INFINITY;

/// Whether a sampled delay marks the worker as crashed.
pub fn is_crashed(delay: f64) -> bool {
    delay.is_infinite()
}

/// Normalize a sampled delay at the cluster boundary: NaN (e.g. a
/// hand-edited replay tape, or a future transform composing `0·∞`)
/// becomes [`CRASHED`] — an unusable sample is an erasure, which the
/// wait-for-k gather already handles deterministically — and negative
/// delays clamp to 0 (time travel would reorder arrivals below the
/// compute floor). Finite non-negative samples and `+∞` pass through
/// unchanged. Both engines call this on every sample, so a NaN can
/// never reach `SimCluster`'s arrival sort (which additionally uses the
/// total order `f64::total_cmp`, not a panicking `partial_cmp`).
pub fn sanitize_delay(delay: f64) -> f64 {
    if delay.is_nan() {
        return CRASHED;
    }
    delay.max(0.0)
}

/// Extra latency injected on top of a worker's compute time.
pub trait DelayModel: Send {
    /// Delay in seconds for worker `i` at iteration `t`.
    fn sample(&mut self, worker: usize, iter: usize) -> f64;

    /// Number of workers this model was configured for.
    fn workers(&self) -> usize;
}

/// Build a delay model from an experiment's [`DelaySpec`].
pub fn from_spec(spec: &DelaySpec, m: usize, seed: u64) -> Box<dyn DelayModel> {
    match spec {
        DelaySpec::None => Box::new(NoDelay::new(m)),
        DelaySpec::Exponential { mean } => Box::new(ExponentialDelay::new(m, *mean, seed)),
        DelaySpec::Bimodal => Box::new(MixtureDelay::paper_bimodal(m, seed)),
        DelaySpec::Trimodal => Box::new(MixtureDelay::paper_trimodal(m, seed)),
        DelaySpec::BackgroundTasks { alpha, cap, task_secs } => {
            Box::new(BackgroundTasksDelay::new(m, *alpha, *cap, *task_secs, seed))
        }
        DelaySpec::Adversarial { slow_fraction, slow_secs } => {
            let n_slow = ((m as f64) * slow_fraction).round() as usize;
            let mut rng = Pcg64::with_stream(seed, 0xadfe);
            let slow = crate::rng::sample_without_replacement(&mut rng, m, n_slow.min(m));
            Box::new(AdversarialDelay::new(m, slow, *slow_secs))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_spec_dispatch() {
        let m = 8;
        for (spec, lo, hi) in [
            (DelaySpec::None, 0.0, 0.0),
            (DelaySpec::Exponential { mean: 0.01 }, 0.0, f64::INFINITY),
            (DelaySpec::Bimodal, 0.0, f64::INFINITY),
        ] {
            let mut d = from_spec(&spec, m, 1);
            assert_eq!(d.workers(), m);
            for w in 0..m {
                let v = d.sample(w, 0);
                assert!(v >= lo && v <= hi);
            }
        }
    }

    #[test]
    fn adversarial_spec_marks_fraction() {
        let mut d = from_spec(
            &DelaySpec::Adversarial { slow_fraction: 0.5, slow_secs: 9.0 },
            8,
            3,
        );
        let slow = (0..8).filter(|&w| d.sample(w, 0) > 8.0).count();
        assert_eq!(slow, 4);
    }
}
