//! The [`Solver`] trait and its six implementations — the algorithm
//! layer of the [`Experiment`](super::Experiment) driver.
//!
//! Data-parallel (encoded objective, Algorithms 1–2): [`Gd`], [`Lbfgs`],
//! [`Prox`]. Model-parallel (Algorithms 3–4): [`Bcd`]. Parameter-server
//! baselines (the Figures 10–13 comparison): [`AsyncGd`], [`AsyncBcd`].
//!
//! Each solver carries only its *algorithmic* hyper-parameters (step
//! size, iteration budget, regularizer weight, …); everything about the
//! distributed substrate — scheme, `m`, wait-for-`k`, redundancy,
//! delays, engine, runtime — lives on the `Experiment` and is delivered
//! through the [`Ctx`] wiring context.

use super::Ctx;
use crate::coordinator::asynchronous::{
    async_bcd_loop, async_gd_loop, AsyncBcdConfig, AsyncGdConfig,
};
use crate::coordinator::bcd::{bcd_loop, BcdConfig};
use crate::coordinator::gd::{gd_loop, GdConfig, RunOutput as CoreOutput};
use crate::coordinator::lbfgs::{lbfgs_loop, LbfgsConfig};
use crate::coordinator::prox::{prox_loop, ProxConfig};
use anyhow::Result;

/// An optimization algorithm runnable through
/// [`Experiment::run`](super::Experiment::run).
pub trait Solver {
    /// Short name, used as the default trace label.
    fn name(&self) -> &'static str;

    /// Execute against the experiment's wiring context.
    fn solve(&self, ctx: &mut Ctx<'_, '_>) -> Result<CoreOutput>;
}

impl<S: Solver + ?Sized> Solver for &S {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn solve(&self, ctx: &mut Ctx<'_, '_>) -> Result<CoreOutput> {
        (**self).solve(ctx)
    }
}

/// Encoded gradient descent (Theorem 2).
#[derive(Clone, Copy, Debug)]
pub struct Gd {
    step: f64,
    lambda: f64,
    iters: usize,
}

impl Gd {
    /// Fixed step size α (typically `1/M` for an `M`-smooth objective).
    pub fn with_step(step: f64) -> Self {
        Gd { step, lambda: 0.0, iters: 100 }
    }

    /// Smooth ℓ₂ regularizer weight (`h(w) = ‖w‖²/2`). Default 0.
    pub fn lambda(mut self, lambda: f64) -> Self {
        self.lambda = lambda;
        self
    }

    /// Outer iterations T. Default 100.
    pub fn iters(mut self, iters: usize) -> Self {
        self.iters = iters;
        self
    }
}

impl Solver for Gd {
    fn name(&self) -> &'static str {
        "gd"
    }

    fn solve(&self, ctx: &mut Ctx<'_, '_>) -> Result<CoreOutput> {
        let (mut cluster, assembler) = ctx.data_parallel()?;
        let cfg = GdConfig {
            k: ctx.k(),
            step: self.step,
            iters: self.iters,
            lambda: self.lambda,
            w0: ctx.w0(),
        };
        Ok(ctx.run_rounds(|ctl, label, eval| {
            gd_loop(cluster.as_mut(), &assembler, &cfg, ctl, label, eval)
        }))
    }
}

/// Encoded L-BFGS with overlap curvature pairs and exact line search
/// over the fastest-k set (Theorem 4).
#[derive(Clone, Copy, Debug)]
pub struct Lbfgs {
    lambda: f64,
    iters: usize,
    memory: usize,
    rho: f64,
}

impl Default for Lbfgs {
    fn default() -> Self {
        Lbfgs { lambda: 0.0, iters: 100, memory: 10, rho: 0.9 }
    }
}

impl Lbfgs {
    pub fn new() -> Self {
        Self::default()
    }

    /// ℓ₂ regularizer weight (the paper requires a quadratic regularizer
    /// for L-BFGS). Default 0.
    pub fn lambda(mut self, lambda: f64) -> Self {
        self.lambda = lambda;
        self
    }

    /// Outer iterations T (two gather rounds each). Default 100.
    pub fn iters(mut self, iters: usize) -> Self {
        self.iters = iters;
        self
    }

    /// Memory length σ. Default 10.
    pub fn memory(mut self, memory: usize) -> Self {
        self.memory = memory;
        self
    }

    /// Line-search back-off ρ ∈ (0, 1). Default 0.9.
    pub fn rho(mut self, rho: f64) -> Self {
        self.rho = rho;
        self
    }
}

impl Solver for Lbfgs {
    fn name(&self) -> &'static str {
        "lbfgs"
    }

    fn solve(&self, ctx: &mut Ctx<'_, '_>) -> Result<CoreOutput> {
        let (mut cluster, assembler) = ctx.data_parallel()?;
        let cfg = LbfgsConfig {
            k: ctx.k(),
            iters: self.iters,
            lambda: self.lambda,
            memory: self.memory,
            rho: self.rho,
            w0: ctx.w0(),
        };
        Ok(ctx.run_rounds(|ctl, label, eval| {
            lbfgs_loop(cluster.as_mut(), &assembler, &cfg, ctl, label, eval)
        }))
    }
}

/// Encoded proximal gradient / ISTA (Theorem 5) — the LASSO workhorse.
#[derive(Clone, Copy, Debug)]
pub struct Prox {
    step: f64,
    lambda: f64,
    iters: usize,
}

impl Prox {
    /// Step size α < 1/M.
    pub fn with_step(step: f64) -> Self {
        Prox { step, lambda: 0.0, iters: 100 }
    }

    /// ℓ₁ weight λ. Default 0.
    pub fn lambda(mut self, lambda: f64) -> Self {
        self.lambda = lambda;
        self
    }

    /// Outer iterations T. Default 100.
    pub fn iters(mut self, iters: usize) -> Self {
        self.iters = iters;
        self
    }
}

impl Solver for Prox {
    fn name(&self) -> &'static str {
        "prox"
    }

    fn solve(&self, ctx: &mut Ctx<'_, '_>) -> Result<CoreOutput> {
        let (mut cluster, assembler) = ctx.data_parallel()?;
        let cfg = ProxConfig {
            k: ctx.k(),
            step: self.step,
            iters: self.iters,
            lambda: self.lambda,
            w0: ctx.w0(),
        };
        Ok(ctx.run_rounds(|ctl, label, eval| {
            prox_loop(cluster.as_mut(), &assembler, &cfg, ctl, label, eval)
        }))
    }
}

/// Encoded block coordinate descent under model parallelism
/// (Algorithms 3–4, Theorem 6).
#[derive(Clone, Copy, Debug)]
pub struct Bcd {
    step: f64,
    lambda: f64,
    iters: usize,
}

impl Bcd {
    /// Per-block step size α.
    pub fn with_step(step: f64) -> Self {
        Bcd { step, lambda: 0.0, iters: 100 }
    }

    /// Lifted ℓ₂ regularizer weight on `v` (block-separable `λ‖v‖²`).
    /// Default 0.
    pub fn lambda(mut self, lambda: f64) -> Self {
        self.lambda = lambda;
        self
    }

    /// Outer iterations T. Default 100.
    pub fn iters(mut self, iters: usize) -> Self {
        self.iters = iters;
        self
    }
}

impl Solver for Bcd {
    fn name(&self) -> &'static str {
        "bcd"
    }

    fn solve(&self, ctx: &mut Ctx<'_, '_>) -> Result<CoreOutput> {
        ctx.reject_w0("Bcd")?;
        let parts = ctx.model_parallel(self.step, self.lambda)?;
        let mut cluster = parts.cluster;
        let cfg = BcdConfig { k: ctx.k(), iters: self.iters };
        Ok(ctx.run_rounds(|ctl, label, eval| {
            bcd_loop(cluster.as_mut(), &parts.recon, parts.n, parts.p, &cfg, ctl, label, eval)
        }))
    }
}

/// Asynchronous parameter-server gradient descent over uncoded row
/// shards (the Figures 10–13 baseline). Ignores `scheme` / `wait_for` /
/// `runtime`: asynchrony has no rounds and no encoding.
#[derive(Clone, Copy, Debug)]
pub struct AsyncGd {
    step: f64,
    lambda: f64,
    updates: usize,
    record_every: usize,
}

impl AsyncGd {
    /// Per-update step size.
    pub fn with_step(step: f64) -> Self {
        AsyncGd { step, lambda: 0.0, updates: 1000, record_every: 100 }
    }

    /// ℓ₂ regularizer weight. Default 0.
    pub fn lambda(mut self, lambda: f64) -> Self {
        self.lambda = lambda;
        self
    }

    /// Total worker updates to apply (comparable budget: iterations × k).
    /// Default 1000.
    pub fn updates(mut self, updates: usize) -> Self {
        self.updates = updates;
        self
    }

    /// Trace-point stride in updates. Default 100.
    pub fn record_every(mut self, record_every: usize) -> Self {
        self.record_every = record_every;
        self
    }
}

impl Solver for AsyncGd {
    fn name(&self) -> &'static str {
        "async-gd"
    }

    fn solve(&self, ctx: &mut Ctx<'_, '_>) -> Result<CoreOutput> {
        ctx.reject_w0("AsyncGd")?;
        ctx.require_sim_engine("AsyncGd")?;
        ctx.reject_unsupported_scenario("AsyncGd")?;
        ctx.require_static_policy("AsyncGd")?;
        ctx.beta = 1.0;
        let shards = ctx.uncoded_row_shards()?;
        let mut delay = ctx.delay_model()?;
        let cfg = AsyncGdConfig {
            step: self.step,
            lambda: self.lambda,
            updates: self.updates,
            secs_per_unit: ctx.secs_per_unit(),
            record_every: self.record_every,
        };
        Ok(async_gd_loop(
            &shards,
            delay.as_mut(),
            ctx.n(),
            ctx.p(),
            &cfg,
            ctx.label(),
            ctx.eval_fn(),
        ))
    }
}

/// Asynchronous block coordinate descent over uncoded column blocks.
/// The evaluation callback receives the concatenated coordinate blocks
/// as `w`, like every other solver.
#[derive(Clone, Copy, Debug)]
pub struct AsyncBcd {
    step: f64,
    lambda: f64,
    updates: usize,
    record_every: usize,
}

impl AsyncBcd {
    /// Per-update step size.
    pub fn with_step(step: f64) -> Self {
        AsyncBcd { step, lambda: 0.0, updates: 1000, record_every: 100 }
    }

    /// Block regularizer weight. Default 0.
    pub fn lambda(mut self, lambda: f64) -> Self {
        self.lambda = lambda;
        self
    }

    /// Total block updates to apply. Default 1000.
    pub fn updates(mut self, updates: usize) -> Self {
        self.updates = updates;
        self
    }

    /// Trace-point stride in updates. Default 100.
    pub fn record_every(mut self, record_every: usize) -> Self {
        self.record_every = record_every;
        self
    }
}

impl Solver for AsyncBcd {
    fn name(&self) -> &'static str {
        "async-bcd"
    }

    fn solve(&self, ctx: &mut Ctx<'_, '_>) -> Result<CoreOutput> {
        ctx.reject_w0("AsyncBcd")?;
        ctx.require_sim_engine("AsyncBcd")?;
        ctx.reject_unsupported_scenario("AsyncBcd")?;
        ctx.require_static_policy("AsyncBcd")?;
        ctx.beta = 1.0;
        let blocks = ctx.uncoded_col_blocks()?;
        let phi = ctx.grad_phi()?;
        let mut delay = ctx.delay_model()?;
        let cfg = AsyncBcdConfig {
            step: self.step,
            lambda: self.lambda,
            updates: self.updates,
            secs_per_unit: ctx.secs_per_unit(),
            record_every: self.record_every,
        };
        let eval = ctx.eval_fn();
        let eval_blocks = |v: &[Vec<f64>]| -> (f64, f64) {
            let w: Vec<f64> = v.iter().flatten().copied().collect();
            eval(&w)
        };
        let (trace, v, participation) = async_bcd_loop(
            &blocks,
            &*phi,
            ctx.n(),
            &cfg,
            delay.as_mut(),
            ctx.label(),
            &eval_blocks,
        );
        let w: Vec<f64> = v.iter().flatten().copied().collect();
        Ok(CoreOutput { trace, w, participation })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_linear;
    use crate::driver::{Experiment, Problem};
    use crate::objectives::{QuadObjective, RidgeProblem};

    #[test]
    fn gd_through_driver_descends() {
        let (x, y, _) = gaussian_linear(48, 6, 0.3, 3);
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .workers(4)
            .wait_for(4)
            .eval(|w| (prob.objective(w), 0.0))
            .run(Gd::with_step(1.0 / prob.smoothness()).lambda(0.05).iters(50))
            .unwrap();
        let f0 = prob.objective(&[0.0; 6]);
        assert!(out.trace.final_objective() < 0.5 * f0);
        assert_eq!(out.trace.len(), 50);
        assert_eq!(out.w.len(), 6);
        assert_eq!(out.pjrt_attached, 0);
        assert!((out.beta - 2.0).abs() < 0.5, "hadamard β ≈ 2, got {}", out.beta);
    }

    #[test]
    fn bcd_through_driver_descends() {
        let (x, y, _) = gaussian_linear(40, 8, 0.2, 5);
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
        let step = 0.5 * 40.0 / x.gram_spectral_norm(60, 3);
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .workers(4)
            .wait_for(4)
            .eval(|w| (prob.objective(w), 0.0))
            .run(Bcd::with_step(step).iters(80))
            .unwrap();
        let f0 = prob.objective(&[0.0; 8]);
        assert!(out.trace.final_objective() < 0.5 * f0);
        assert_eq!(out.w.len(), 8, "BCD returns the reconstructed w, not v");
    }

    #[test]
    fn async_solvers_reject_crash_scenarios() {
        // A crashed worker would starve forever on the async event queue
        // (it never re-samples after being scheduled at +inf), so crash
        // scenarios must be rejected loudly, not silently misrun.
        let (x, y, _) = gaussian_linear(30, 6, 0.2, 11);
        let sc = crate::scenario::Scenario::builtin("crash-rejoin").unwrap();
        let exp = Experiment::new(Problem::least_squares(&x, &y))
            .workers(3)
            .scenario(&sc);
        let err = exp.run(AsyncGd::with_step(0.01).updates(50)).unwrap_err();
        assert!(err.to_string().contains("crash"), "got: {err}");
        let err = exp.run(AsyncBcd::with_step(0.01).updates(50)).unwrap_err();
        assert!(err.to_string().contains("crash"), "got: {err}");
        // non-uniform compute speeds are applied by the cluster engines,
        // which async solvers never build — also rejected, not dropped
        let hetero = crate::scenario::Scenario::builtin("hetero-speed").unwrap();
        let err = Experiment::new(Problem::least_squares(&x, &y))
            .workers(3)
            .scenario(&hetero)
            .run(AsyncGd::with_step(0.01).updates(50))
            .unwrap_err();
        assert!(err.to_string().contains("speed"), "got: {err}");
        // crash-free, uniform-speed scenarios are fine
        let ok = Experiment::new(Problem::least_squares(&x, &y))
            .workers(3)
            .scenario(&crate::scenario::Scenario::builtin("rack-correlated").unwrap())
            .run(AsyncGd::with_step(0.01).updates(50));
        assert!(ok.is_ok(), "{:?}", ok.err().map(|e| e.to_string()));
    }

    #[test]
    fn async_bcd_eval_sees_concatenated_w() {
        let (x, y, _) = gaussian_linear(30, 6, 0.2, 7);
        let prob = RidgeProblem::new(x.clone(), y.clone(), 0.0);
        let step = 0.5 * 30.0 / x.gram_spectral_norm(60, 4);
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .workers(3)
            .timing(1e-4, 1e-3)
            .eval(|w| {
                assert_eq!(w.len(), 6);
                (prob.objective(w), 0.0)
            })
            .run(AsyncBcd::with_step(step).updates(400).record_every(50))
            .unwrap();
        let f0 = prob.objective(&[0.0; 6]);
        assert!(out.trace.final_objective() < 0.5 * f0);
        assert_eq!(out.w.len(), 6);
    }
}
