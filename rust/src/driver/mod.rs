//! # Experiment driver — the one entry point for every solver
//!
//! The paper's central claim is that *oblivious* encoding composes with
//! many first-order methods: gradient descent, L-BFGS, proximal
//! gradient, block coordinate descent, and the asynchronous baselines
//! all share the same problem → encoding → cluster → solve → evaluate
//! pipeline. [`Experiment`] owns that wiring once, so benches, examples,
//! tests, and the launcher describe *what* to run, not how to plumb it:
//!
//! ```no_run
//! use coded_opt::config::Scheme;
//! use coded_opt::data::synth::gaussian_linear;
//! use coded_opt::delay::MixtureDelay;
//! use coded_opt::driver::{Experiment, Gd, Problem};
//! use coded_opt::objectives::{QuadObjective, RidgeProblem};
//!
//! # fn main() -> anyhow::Result<()> {
//! let (x, y, _) = gaussian_linear(512, 64, 0.5, 42);
//! let prob = RidgeProblem::new(x.clone(), y.clone(), 0.05);
//! let out = Experiment::new(Problem::least_squares(&x, &y))
//!     .scheme(Scheme::Hadamard)
//!     .workers(8)
//!     .wait_for(6)
//!     .delay(|m| Box::new(MixtureDelay::paper_bimodal(m, 7)))
//!     .eval(|w| (prob.objective(w), 0.0))
//!     .run(Gd::with_step(1.0 / prob.smoothness()).lambda(0.05).iters(200))?;
//! println!("f(w_T) = {:.6}", out.trace.final_objective());
//! # Ok(())
//! # }
//! ```
//!
//! A [`Solver`] is any of [`Gd`], [`Lbfgs`], [`Prox`] (data parallelism),
//! [`Bcd`] (model parallelism), or the [`AsyncGd`] / [`AsyncBcd`]
//! parameter-server baselines; all six run through the same builder and
//! return the same [`RunOutput`].
//!
//! ## Normalization convention
//!
//! Encoding constructions produce `SᵀS = β·I` (unit-norm tight frames).
//! The driver hands each worker the *Parseval-normalized* block
//! `S̄_i = S_i/√β`, so `S̄ᵀS̄ = I` and the encoded objective equals the
//! original objective exactly when all `m` workers respond — including
//! the regularizer weighting (the paper's §4.1 optimality-preservation
//! argument). When only `k` of `m` respond, the assembled partial sums
//! are rescaled by `m/k`, which is unbiased under random active sets
//! `A_t`; the BRIP condition (Definition 1) bounds the worst case under
//! adversarial ones. Convergence is always *evaluated* on the ORIGINAL
//! objective, which is why [`Experiment::eval`] receives the plain
//! iterate `w` (for model parallelism: the reconstruction `w = S̄ᵀv`).
//!
//! ## Straggler injection, engines, and the AOT runtime
//!
//! - [`Experiment::delay`] installs a straggler [`DelayModel`] factory
//!   (called with the worker count `m` once per run, keeping repeated
//!   runs of one experiment statistically independent but reproducible).
//! - [`Experiment::scenario`] installs a named [`Scenario`] — a base
//!   delay spec plus composable transforms (time-varying phases,
//!   rack-correlated slowdowns, crash/rejoin windows, per-worker delay
//!   scaling) and a per-worker compute [`SpeedProfile`] — on either
//!   engine. See [`crate::scenario`] for the DSL.
//! - [`Experiment::engine`] picks the virtual-clock [`SimCluster`]
//!   (deterministic; drives all paper figures) or the OS-thread
//!   [`ThreadCluster`] (wall-clock, real interrupts).
//! - [`Experiment::runtime`] attaches an AOT artifact index; workers
//!   whose shard shape matches a compiled `quad_grad` module execute
//!   their gradient hot path on PJRT, and [`RunOutput::pjrt_attached`]
//!   reports how many did.

pub mod solvers;

pub use solvers::{AsyncBcd, AsyncGd, Bcd, Gd, Lbfgs, Prox, Solver};

use std::cell::RefCell;

use crate::cluster::{Gather, SimCluster, SocketCluster, ThreadCluster, WorkerNode};
use crate::config::{DelaySpec, Scheme};
use crate::control::{Controller, KPolicy};
use crate::coordinator::bcd::{build_model_parallel, logistic_phi, quadratic_phi};
use crate::coordinator::{
    build_data_parallel_streamed, build_data_parallel_with_runtime, EvalFn, GradAssembler,
    RoundCtl,
};
use crate::data::shard::{BlockSource, ShardedSource};
use crate::delay::{from_spec, DelayModel, NoDelay};
use crate::encoding::{partition_bounds, EncodingOp, ReplicationMap};
use crate::linalg::{Mat, Precision};
use crate::metrics::{Participation, RoundStats, Trace};
// A missing index leaves the trace-identical in-process kernel path untouched.
// lint:allow(zone-containment) — setup-time artifact discovery, not hot-loop unsafe
use crate::runtime::ArtifactIndex;
use crate::scenario::{Scenario, SpeedProfile};
use anyhow::Result;

/// Loss over the linear predictor `u = Xw` — the φ of the paper's
/// composite objective `f(w) = φ(Xw) + λh(w)`.
#[derive(Clone, Copy, Debug)]
pub enum Loss<'a> {
    /// Least squares: `φ(u) = 1/(2n)·‖u − y‖²`.
    Quadratic { y: &'a [f64] },
    /// Logistic loss over label-scaled rows:
    /// `φ(u) = 1/n·Σ log(1 + e^{−uᵢ})`.
    Logistic,
}

/// The optimization problem an [`Experiment`] distributes: the data
/// matrix plus the loss over its linear predictor.
#[derive(Clone, Copy, Debug)]
pub struct Problem<'a> {
    x: &'a Mat,
    loss: Loss<'a>,
}

impl<'a> Problem<'a> {
    /// Least-squares problem on `(X, y)` — ridge / LASSO / quadratic BCD.
    pub fn least_squares(x: &'a Mat, y: &'a [f64]) -> Self {
        assert_eq!(x.rows(), y.len(), "X/y row mismatch");
        Problem { x, loss: Loss::Quadratic { y } }
    }

    /// Logistic-regression problem on label-scaled rows (model-parallel
    /// BCD and the async baseline; the labels are folded into `X`).
    pub fn logistic(x: &'a Mat) -> Self {
        Problem { x, loss: Loss::Logistic }
    }

    pub fn x(&self) -> &'a Mat {
        self.x
    }

    pub fn loss(&self) -> Loss<'a> {
        self.loss
    }
}

/// Where an [`Experiment`] reads its dataset from.
///
/// - [`DataSource::InMemory`] — a borrowed [`Problem`] (the historical
///   path; every solver supported).
/// - [`DataSource::Sharded`] — an out-of-core
///   [`ShardedSource`]: the encoded worker shards are
///   assembled block-by-block from disk
///   ([`crate::encoding::stream`]) and the input matrix is never
///   materialized as one `Mat`. Sharded datasets carry targets and are
///   least-squares problems; they drive the data-parallel solvers
///   ([`Gd`] / [`Lbfgs`] / [`Prox`]) and the [`AsyncGd`] baseline.
///   [`Bcd`] / [`AsyncBcd`] need *column* access (model parallelism)
///   and reject a sharded source with a loud error.
///
/// Bit-identity: a sharded run produces traces bit-identical to the
/// same experiment run from the equivalent in-memory dataset (same
/// seed / scheme / solver) — pinned by `rust/tests/shard_pipeline.rs`.
pub enum DataSource<'a> {
    InMemory(Problem<'a>),
    Sharded(ShardedSource),
}

/// Cluster engine selection.
#[derive(Clone, Debug)]
pub enum Engine {
    /// Deterministic virtual-clock simulation ([`SimCluster`]).
    Sim,
    /// Real OS threads with wall-clock interrupts ([`ThreadCluster`]).
    /// Injected delays are multiplied by `delay_scale` (scale the
    /// paper's 20-second stragglers down to test-friendly milliseconds).
    Threads { delay_scale: f64 },
    /// Multi-process TCP engine ([`SocketCluster`]): `addrs[i]` is the
    /// listen address of the `coded-opt worker` process holding encoded
    /// partition `i` (the `worker-NNN` directory written by
    /// `coded-opt encode`). Virtual-clock like [`Engine::Sim`] —
    /// injected delays are enforced by the master's winner selection,
    /// never wall clock — so the same experiment on `Sim` and `Socket`
    /// produces bit-identical traces. Data-parallel solvers only
    /// (gd / lbfgs / prox).
    Socket { addrs: Vec<String> },
}

/// How the experiment sources its straggler delays.
enum DelayChoice<'a> {
    /// No injected delay.
    None,
    /// Factory called with the worker count `m` once per run.
    Factory(Box<dyn Fn(usize) -> Box<dyn DelayModel> + 'a>),
    /// A pre-built model, usable for exactly one run.
    Once(RefCell<Option<Box<dyn DelayModel>>>),
    /// Config-driven spec, instantiated with (m, seed) per run.
    Spec(DelaySpec, u64),
    /// A named scenario (base spec + transform stack), instantiated with
    /// (m, experiment seed) per run.
    Scenario(Scenario),
}

/// Unified result of an [`Experiment::run`]: the convergence trace on
/// the original objective, the final iterate, per-node participation,
/// and how many workers executed on the PJRT runtime.
#[derive(Clone, Debug)]
pub struct RunOutput {
    pub trace: Trace,
    /// Final iterate `w_T` (model parallelism: reconstructed `S̄ᵀv_T`).
    pub w: Vec<f64>,
    pub participation: Participation,
    /// Workers whose shard matched an AOT artifact and ran on PJRT
    /// (0 without [`Experiment::runtime`], and for model-parallel/async
    /// solvers, which have no AOT kernel).
    pub pjrt_attached: usize,
    /// Achieved redundancy β (1.0 for uncoded/async runs; constructions
    /// round to feasible sizes so this can differ from the request).
    pub beta: f64,
    /// Per-gather-round record — requested/effective k, live-worker
    /// count, and the arrival times the k-controller's next decision
    /// was derived from. One entry per gather round (L-BFGS takes two
    /// per outer iteration); empty for the async baselines, which have
    /// no rounds.
    pub rounds: Vec<RoundStats>,
    /// Name of the k-policy that steered the run (`"static"` unless
    /// [`Experiment::controller`] installed another).
    pub controller: String,
}

/// Builder-style driver for one encoded-optimization experiment.
///
/// See the [module docs](self) for the full picture; construction starts
/// from a [`Problem`] and every knob has a paper-faithful default:
/// Hadamard scheme, `m = 8`, `k = m`, `β = 2`, seed 42, no injected
/// delay, virtual-clock engine with the [`SimCluster`] default timing.
pub struct Experiment<'a> {
    source: DataSource<'a>,
    scheme: Scheme,
    m: usize,
    k: Option<usize>,
    beta: f64,
    seed: u64,
    label: String,
    secs_per_unit: f64,
    master_overhead: f64,
    engine: Engine,
    /// Whether `timing()` was explicitly configured (rejected loudly
    /// under `Engine::Threads`, which measures wall-clock).
    timing_set: bool,
    /// Worker shard storage precision (data-parallel solvers only).
    precision: Precision,
    runtime: Option<&'a ArtifactIndex>,
    delay: DelayChoice<'a>,
    /// Per-worker compute-speed multipliers, resolved with `m` at
    /// cluster-build time.
    speeds: SpeedProfile,
    /// Extra seed mixed into the speed-profile resolution (set by
    /// [`Experiment::scenario`] so the scenario seed also moves the
    /// slow-worker set).
    speed_seed: u64,
    /// Compute-kernel worker threads ([`crate::linalg::par`]); None
    /// keeps the process-wide setting.
    threads: Option<usize>,
    /// Wait-for-k runtime controller policy ([`crate::control`]).
    policy: KPolicy,
    #[allow(clippy::type_complexity)]
    eval: Option<Box<dyn Fn(&[f64]) -> (f64, f64) + 'a>>,
    w0: Option<Vec<f64>>,
}

impl<'a> Experiment<'a> {
    pub fn new(problem: Problem<'a>) -> Self {
        Self::data_source(DataSource::InMemory(problem))
    }

    /// Construct from any [`DataSource`] — the in-memory [`Problem`]
    /// path ([`Experiment::new`] is sugar for it) or an out-of-core
    /// [`ShardedSource`] whose worker shards are encoded
    /// block-by-block from disk.
    pub fn data_source(source: DataSource<'a>) -> Self {
        Experiment {
            source,
            scheme: Scheme::Hadamard,
            m: 8,
            k: None,
            beta: 2.0,
            seed: 42,
            label: String::new(),
            // SimCluster's defaults, so driver runs are bit-identical to
            // hand-wired `SimCluster::new(..)` runs.
            secs_per_unit: 0.01,
            master_overhead: 0.001,
            engine: Engine::Sim,
            timing_set: false,
            precision: Precision::F64,
            runtime: None,
            delay: DelayChoice::None,
            speeds: SpeedProfile::Uniform,
            speed_seed: 0,
            threads: None,
            policy: KPolicy::Static,
            eval: None,
            w0: None,
        }
    }

    /// Sugar for [`Experiment::data_source`] with a sharded dataset.
    pub fn sharded(source: ShardedSource) -> Self {
        Self::data_source(DataSource::Sharded(source))
    }

    /// Encoding scheme (paper §4). Default: Hadamard.
    pub fn scheme(mut self, scheme: Scheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Worker count `m`. Default: 8.
    pub fn workers(mut self, m: usize) -> Self {
        self.m = m;
        self
    }

    /// Wait-for-`k`: responses gathered per round before the rest are
    /// interrupted. Default: `m` (full gather).
    pub fn wait_for(mut self, k: usize) -> Self {
        self.k = Some(k);
        self
    }

    /// Wait-for-k runtime controller policy ([`crate::control`]).
    /// Default: [`KPolicy::Static`] — the classic fixed-k gather with
    /// strict semantics (`k > live` panics). An adaptive policy starts
    /// from [`wait_for`](Self::wait_for)'s k, routes every gather
    /// through the live-clamped round path, and moves k between rounds
    /// within `[erasure_floor(m, β), m]`; the per-round decisions and
    /// arrivals land in [`RunOutput::rounds`]. Synchronous wait-for-k
    /// solvers only — the async baselines reject a non-static policy.
    pub fn controller(mut self, policy: KPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Redundancy factor `β ≥ 1`. Default: 2.
    pub fn redundancy(mut self, beta: f64) -> Self {
        self.beta = beta;
        self
    }

    /// Encoding / data seed. Default: 42.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Trace label. Default: the solver's name.
    pub fn label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    /// Install a straggler-delay factory; it receives the worker count
    /// `m` and is invoked once per [`run`](Self::run).
    pub fn delay<F>(mut self, factory: F) -> Self
    where
        F: Fn(usize) -> Box<dyn DelayModel> + 'a,
    {
        self.delay = DelayChoice::Factory(Box::new(factory));
        self
    }

    /// Install a pre-built delay model. Supports exactly one
    /// [`run`](Self::run); use [`delay`](Self::delay) for reusable
    /// experiments.
    pub fn delay_model(mut self, model: Box<dyn DelayModel>) -> Self {
        self.delay = DelayChoice::Once(RefCell::new(Some(model)));
        self
    }

    /// Install a config-driven delay spec, instantiated with `(m, seed)`
    /// per run.
    pub fn delay_spec(mut self, spec: DelaySpec, seed: u64) -> Self {
        self.delay = DelayChoice::Spec(spec, seed);
        self
    }

    /// Install a straggler [`Scenario`]: its delay stack replaces any
    /// previous delay choice, and its [`SpeedProfile`] is installed as
    /// the cluster's per-worker compute speeds. Reusable across runs
    /// (rebuilt with `(m, seed)` each time).
    pub fn scenario(mut self, scenario: &Scenario) -> Self {
        self.speeds = scenario.speeds.clone();
        self.speed_seed = scenario.seed;
        self.delay = DelayChoice::Scenario(scenario.clone());
        self
    }

    /// Per-worker compute-speed multipliers without a full scenario.
    pub fn speeds(mut self, profile: SpeedProfile) -> Self {
        self.speeds = profile;
        self
    }

    /// Simulated seconds per unit of worker cost and master per-round
    /// overhead ([`SimCluster`] timing). Defaults: 0.01 / 0.001.
    /// [`Engine::Sim`] only — [`Engine::Threads`] measures wall-clock,
    /// so combining the two is rejected at run time.
    pub fn timing(mut self, secs_per_unit: f64, master_overhead: f64) -> Self {
        self.secs_per_unit = secs_per_unit;
        self.master_overhead = master_overhead;
        self.timing_set = true;
        self
    }

    /// Cluster engine. Default: [`Engine::Sim`].
    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    /// Compute-kernel worker threads for the deterministic chunk pool
    /// ([`crate::linalg::par`]). The setting is **process-global**
    /// (applied at [`run`](Self::run) time via
    /// [`par::set_threads`](crate::linalg::par::set_threads)); results
    /// are bit-identical at any value — the knob only trades wall-clock
    /// for cores. Default: the `CODED_OPT_THREADS` environment variable,
    /// then `available_parallelism`.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// Worker shard storage precision for the data-parallel solvers.
    /// Default: [`Precision::F64`] (the bit-determinism contract and
    /// golden traces assume it). [`Precision::F32`] stores each worker's
    /// `S̄_iX` in single precision with f64 accumulation — half the
    /// shard memory at a documented ≤ 1e-5 tolerance vs the f64 run
    /// (see [`crate::linalg::precision`]). In-process engines only;
    /// socket workers load f64 partitions from their own disks.
    pub fn precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    /// Attach the AOT artifact index: matching shards execute their
    /// gradient hot path on PJRT ([`RunOutput::pjrt_attached`] reports
    /// how many).
    pub fn runtime(mut self, index: &'a ArtifactIndex) -> Self {
        self.runtime = Some(index);
        self
    }

    /// Evaluation callback mapping the iterate to
    /// `(original objective, test metric)` for the trace. Default:
    /// `(0.0, 0.0)` (timing-only runs).
    pub fn eval<F>(mut self, eval: F) -> Self
    where
        F: Fn(&[f64]) -> (f64, f64) + 'a,
    {
        self.eval = Some(Box::new(eval));
        self
    }

    /// Initial iterate (defaults to 0). Supported by the data-parallel
    /// solvers (`Gd`/`Lbfgs`/`Prox`); `Bcd` and the async baselines
    /// always start from 0 and reject a warm start with an error.
    pub fn w0(mut self, w0: Vec<f64>) -> Self {
        self.w0 = Some(w0);
        self
    }

    /// Effective wait-for-`k` (defaults to `m`).
    pub fn effective_k(&self) -> usize {
        self.k.unwrap_or(self.m)
    }

    fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.m >= 1, "workers must be ≥ 1");
        let k = self.effective_k();
        anyhow::ensure!(
            k >= 1 && k <= self.m,
            "k must satisfy 1 ≤ k ≤ m (k={k}, m={})",
            self.m
        );
        anyhow::ensure!(self.beta >= 1.0, "redundancy β must be ≥ 1 (got {})", self.beta);
        Ok(())
    }

    /// Run a solver through the wired pipeline.
    pub fn run(&self, solver: impl Solver) -> Result<RunOutput> {
        self.validate()?;
        if let Some(n) = self.threads {
            crate::linalg::par::set_threads(n);
        }
        let label =
            if self.label.is_empty() { solver.name().to_string() } else { self.label.clone() };
        let mut ctx = Ctx {
            exp: self,
            label,
            pjrt_attached: 0,
            beta: 1.0,
            rounds: Vec::new(),
            controller: "static",
        };
        let core = solver.solve(&mut ctx)?;
        Ok(RunOutput {
            trace: core.trace,
            w: core.w,
            participation: core.participation,
            pjrt_attached: ctx.pjrt_attached,
            beta: ctx.beta,
            rounds: ctx.rounds,
            controller: ctx.controller.to_string(),
        })
    }

    /// Escape hatch for harnesses that drive gather rounds manually
    /// (microbenches, invariant tests): the fully wired data-parallel
    /// cluster + assembler, without running a solver.
    pub fn assemble_data_parallel(&self) -> Result<DataParallelParts> {
        self.validate()?;
        let mut ctx = Ctx {
            exp: self,
            label: self.label.clone(),
            pjrt_attached: 0,
            beta: 1.0,
            rounds: Vec::new(),
            controller: "static",
        };
        let (cluster, assembler) = ctx.data_parallel()?;
        Ok(DataParallelParts {
            cluster,
            assembler,
            pjrt_attached: ctx.pjrt_attached,
            beta: ctx.beta,
        })
    }

}

/// Wired data-parallel pipeline pieces (see
/// [`Experiment::assemble_data_parallel`]).
pub struct DataParallelParts {
    pub cluster: Box<dyn Gather>,
    pub assembler: GradAssembler,
    pub pjrt_attached: usize,
    pub beta: f64,
}

/// Wired model-parallel pipeline pieces, produced by
/// [`Ctx::model_parallel`] for the [`Bcd`] solver (and any custom
/// model-parallel [`Solver`] implementation).
pub struct ModelParallelParts {
    pub cluster: Box<dyn Gather>,
    /// Structured `w = S̄ᵀv` reconstruction (the master-loop hot path);
    /// `recon.sbar_blocks()` materializes the normalized dense blocks on
    /// demand for spectrum/debug use.
    pub recon: crate::coordinator::bcd::Reconstruction,
    /// Data rows n and model dimension p.
    pub n: usize,
    pub p: usize,
    pub beta: f64,
}

fn zero_eval(_w: &[f64]) -> (f64, f64) {
    (0.0, 0.0)
}

/// The wiring context a [`Solver`] sees: accessors for the experiment's
/// knobs plus on-demand builders for each parallelism mode. Solvers call
/// only what they need; the driver records what was built
/// (`pjrt_attached`, achieved β) for the [`RunOutput`].
pub struct Ctx<'e, 'a> {
    exp: &'e Experiment<'a>,
    label: String,
    pub(crate) pjrt_attached: usize,
    pub(crate) beta: f64,
    /// Per-round controller records, filled by [`Ctx::run_rounds`].
    pub(crate) rounds: Vec<RoundStats>,
    /// Name of the controller that steered the run.
    pub(crate) controller: &'static str,
}

impl<'e, 'a> Ctx<'e, 'a> {
    pub fn k(&self) -> usize {
        self.exp.effective_k()
    }

    pub fn workers(&self) -> usize {
        self.exp.m
    }

    pub fn seed(&self) -> u64 {
        self.exp.seed
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn w0(&self) -> Option<Vec<f64>> {
        self.exp.w0.clone()
    }

    /// Data rows n.
    pub fn n(&self) -> usize {
        match &self.exp.source {
            DataSource::InMemory(prob) => prob.x.rows(),
            DataSource::Sharded(src) => src.rows(),
        }
    }

    /// Model dimension p.
    pub fn p(&self) -> usize {
        match &self.exp.source {
            DataSource::InMemory(prob) => prob.x.cols(),
            DataSource::Sharded(src) => src.cols(),
        }
    }

    pub fn secs_per_unit(&self) -> f64 {
        self.exp.secs_per_unit
    }

    /// The experiment's evaluation callback (`(0, 0)` when unset).
    pub fn eval_fn(&self) -> &EvalFn<'_> {
        match &self.exp.eval {
            Some(f) => &**f,
            None => &zero_eval,
        }
    }

    /// The experiment's wait-for-k controller policy.
    pub fn policy(&self) -> &KPolicy {
        &self.exp.policy
    }

    /// Build the experiment's k-controller and drive a solver loop with
    /// it: `run` receives the wired [`RoundCtl`] plus the trace label
    /// and evaluation callback. A static policy uses the strict
    /// fixed-k gather (bit-identical to the pre-controller loops); an
    /// adaptive policy seeds the controller with `k` and the ACHIEVED β
    /// (call after [`data_parallel`](Self::data_parallel) /
    /// [`model_parallel`](Self::model_parallel)), then routes every
    /// round through the live-clamped gather. The per-round records
    /// land in [`RunOutput::rounds`] either way.
    pub fn run_rounds<R>(
        &mut self,
        run: impl FnOnce(&mut RoundCtl<'_>, &str, &EvalFn<'_>) -> R,
    ) -> R {
        let mut controller = self.exp.policy.build(self.exp.effective_k(), self.exp.m, self.beta);
        self.controller = controller.name();
        let (out, rounds) = if self.exp.policy.is_static() {
            let mut ctl = RoundCtl::fixed(self.exp.effective_k());
            let out = run(&mut ctl, &self.label, self.eval_fn());
            (out, ctl.into_rounds())
        } else {
            let k0 = controller.initial_k();
            let mut policy = |s: &RoundStats| controller.observe(s);
            let mut ctl = RoundCtl::adaptive(k0, &mut policy);
            let out = run(&mut ctl, &self.label, self.eval_fn());
            (out, ctl.into_rounds())
        };
        self.rounds = rounds;
        out
    }

    /// Guard for the async baselines, which have no gather rounds for a
    /// k-controller to steer.
    pub fn require_static_policy(&self, who: &str) -> Result<()> {
        anyhow::ensure!(
            self.exp.policy.is_static(),
            "{who} has no gather rounds for a k-controller to steer; adaptive \
             k-policies need the wait-for-k solvers (gd / lbfgs / prox / bcd)"
        );
        Ok(())
    }

    /// Instantiate the experiment's straggler delay model.
    pub fn delay_model(&self) -> Result<Box<dyn DelayModel>> {
        let model = match &self.exp.delay {
            DelayChoice::None => Box::new(NoDelay::new(self.exp.m)) as Box<dyn DelayModel>,
            DelayChoice::Factory(f) => f(self.exp.m),
            DelayChoice::Once(cell) => cell.borrow_mut().take().ok_or_else(|| {
                anyhow::anyhow!(
                    "Experiment::delay_model supports a single run; \
                     use Experiment::delay(factory) for repeated runs"
                )
            })?,
            DelayChoice::Spec(spec, seed) => from_spec(spec, self.exp.m, *seed),
            DelayChoice::Scenario(sc) => sc.build_delay(self.exp.m, self.exp.seed)?,
        };
        anyhow::ensure!(
            model.workers() == self.exp.m,
            "delay model sized for {} workers, experiment has m={}",
            model.workers(),
            self.exp.m
        );
        Ok(model)
    }

    /// Guard for solvers whose algorithm state always starts at 0
    /// (BCD's lifted `v`, the async baselines): a configured warm start
    /// would be silently ignored, so reject it loudly instead.
    pub fn reject_w0(&self, who: &str) -> Result<()> {
        anyhow::ensure!(
            self.exp.w0.is_none(),
            "{who} always starts from 0 and does not support Experiment::w0"
        );
        Ok(())
    }

    /// Guard for the event-queue async solvers, which have no cluster
    /// and therefore cannot honor [`Engine::Threads`] or
    /// [`Engine::Socket`].
    pub fn require_sim_engine(&self, who: &str) -> Result<()> {
        match &self.exp.engine {
            Engine::Sim => Ok(()),
            Engine::Threads { .. } => anyhow::bail!(
                "{who} simulates asynchrony on a virtual-time event queue \
                 and does not support Engine::Threads"
            ),
            Engine::Socket { .. } => anyhow::bail!(
                "{who} simulates asynchrony on a virtual-time event queue \
                 and does not support Engine::Socket"
            ),
        }
    }

    /// Guard for the event-queue async solvers against scenario features
    /// only the cluster engines implement. Crash windows: the wait-for-k
    /// engines re-sample a crashed worker every round so it rejoins when
    /// the window closes, but the async event queue schedules the
    /// worker's next completion at +∞ the first time it samples inside
    /// the window — the worker starves forever instead of rejoining.
    /// Speed profiles: per-worker compute speeds are applied by
    /// `Ctx::cluster`, which the async solvers never build — a non-trivial
    /// profile would be silently dropped, misrepresenting the scenario.
    pub fn reject_unsupported_scenario(&self, who: &str) -> Result<()> {
        if let DelayChoice::Scenario(sc) = &self.exp.delay {
            anyhow::ensure!(
                !sc.has_crash(),
                "scenario '{}' has a crash window, which {who} cannot honor: a \
                 crashed worker would starve forever on the async event queue \
                 instead of rejoining; run crash scenarios on the wait-for-k \
                 solvers (gd / lbfgs / prox / bcd)",
                sc.name
            );
        }
        anyhow::ensure!(
            self.exp.speeds == SpeedProfile::Uniform,
            "{who} has no cluster, so per-worker compute speeds would be \
             silently ignored; speed profiles need the wait-for-k solvers \
             (gd / lbfgs / prox / bcd)"
        );
        Ok(())
    }

    /// The in-memory problem, or a loud error naming the solver when
    /// the experiment reads from a sharded source.
    fn require_in_memory(&self, who: &str) -> Result<&'e Problem<'a>> {
        let exp: &'e Experiment<'a> = self.exp;
        match &exp.source {
            DataSource::InMemory(prob) => Ok(prob),
            DataSource::Sharded(_) => anyhow::bail!(
                "{who} needs column access to the data matrix, which a sharded \
                 (row-streamed) source cannot provide; load the dataset in \
                 memory (Experiment::new) for this solver"
            ),
        }
    }

    fn require_y(&self, prob: &Problem<'a>, who: &str) -> Result<&'a [f64]> {
        match prob.loss {
            Loss::Quadratic { y } => Ok(y),
            Loss::Logistic => anyhow::bail!(
                "{who} need a least-squares problem (Problem::least_squares); \
                 logistic regression runs model-parallel (Bcd / AsyncBcd)"
            ),
        }
    }

    fn cluster(&self, workers: Vec<Box<dyn WorkerNode>>) -> Result<Box<dyn Gather>> {
        let delay = self.delay_model()?;
        let speeds = self
            .exp
            .speeds
            .resolve(self.exp.m, self.exp.seed ^ self.exp.speed_seed.wrapping_mul(0x9e37_79b9))?;
        Ok(match &self.exp.engine {
            Engine::Sim => Box::new(
                SimCluster::new(workers, delay)
                    .with_timing(self.exp.secs_per_unit, self.exp.master_overhead)
                    .with_speeds(speeds),
            ),
            Engine::Threads { delay_scale } => {
                anyhow::ensure!(
                    !self.exp.timing_set,
                    "Experiment::timing configures the virtual clock and is \
                     ignored by Engine::Threads (wall-clock); drop one of the two"
                );
                Box::new(
                    ThreadCluster::new(workers, delay)
                        .with_delay_scale(*delay_scale)
                        .with_speeds(speeds),
                )
            }
            Engine::Socket { .. } => anyhow::bail!(
                "this pipeline builds its workers in-process, but Engine::Socket \
                 workers hold pre-encoded partitions on their own disks; only the \
                 data-parallel solvers (gd / lbfgs / prox) run on the socket engine"
            ),
        })
    }

    /// Build the encoded data-parallel pipeline: worker shards
    /// `(S̄_iX, S̄_iy)` behind a gathered cluster, plus the master-side
    /// assembler. A sharded source streams its blocks through
    /// [`build_data_parallel_streamed`] — the input matrix is never
    /// materialized, and the resulting workers are bit-identical to the
    /// in-memory build of the same rows.
    pub fn data_parallel(&mut self) -> Result<(Box<dyn Gather>, GradAssembler)> {
        if let Engine::Socket { addrs } = &self.exp.engine {
            let addrs = addrs.clone();
            return self.data_parallel_socket(&addrs);
        }
        let exp = self.exp;
        let dp = match &exp.source {
            DataSource::InMemory(prob) => {
                let y = self.require_y(prob, "data-parallel solvers")?;
                build_data_parallel_with_runtime(
                    prob.x,
                    y,
                    exp.scheme,
                    exp.m,
                    exp.beta,
                    exp.seed,
                    exp.precision,
                    exp.runtime,
                )?
            }
            DataSource::Sharded(src) => build_data_parallel_streamed(
                src,
                exp.scheme,
                exp.m,
                exp.beta,
                exp.seed,
                exp.precision,
                exp.runtime,
            )?,
        };
        self.pjrt_attached = dp.pjrt_attached;
        self.beta = dp.beta;
        let assembler = dp.assembler.clone();
        Ok((self.cluster(dp.workers)?, assembler))
    }

    /// The data-parallel pipeline on [`Engine::Socket`]: the encoded
    /// worker shards already live on the remote workers' disks
    /// (written by `coded-opt encode`), so the master builds only the
    /// delay model, the assembler, and the TCP connections — then
    /// checks that each worker reports the partition shape the
    /// encoding predicts for its index, catching shuffled
    /// `--worker-addrs` before any gradient crosses the wire.
    fn data_parallel_socket(&mut self, addrs: &[String]) -> Result<(Box<dyn Gather>, GradAssembler)> {
        let exp = self.exp;
        anyhow::ensure!(
            exp.scheme != Scheme::Replication,
            "Engine::Socket workers load partitions written by `coded-opt encode`, \
             which has no replication layout; use a coded scheme (hadamard / \
             gaussian / paley) or the uncoded baseline"
        );
        anyhow::ensure!(
            addrs.len() == exp.m,
            "Engine::Socket got {} worker address(es) but the experiment has m={} \
             workers; pass one address per encoded partition",
            addrs.len(),
            exp.m
        );
        anyhow::ensure!(
            exp.precision == Precision::F64,
            "Engine::Socket workers load f64 partitions written by `coded-opt \
             encode`; Precision::F32 shard storage is in-process only \
             (Sim / Threads engines)"
        );
        match &exp.source {
            DataSource::InMemory(prob) => {
                self.require_y(prob, "socket-engine data-parallel solvers")?;
            }
            DataSource::Sharded(src) => anyhow::ensure!(
                src.has_targets(),
                "data-parallel workers need targets y; the sharded dataset has none"
            ),
        }
        let (n, p) = (self.n(), self.p());
        // Same lazy lowering `coded-opt encode` ran when it wrote the
        // partitions: predicts each worker's row count and the achieved
        // redundancy without touching the data.
        let enc = EncodingOp::build(exp.scheme, n, exp.m, exp.beta, exp.seed)?;
        let expected_rows: Vec<u64> =
            (0..exp.m).map(|w| enc.block_rows(w) as u64).collect();
        let delay = self.delay_model()?;
        let speeds = self
            .exp
            .speeds
            .resolve(exp.m, exp.seed ^ exp.speed_seed.wrapping_mul(0x9e37_79b9))?;
        let cluster = SocketCluster::connect(addrs, delay)?
            .with_timing(exp.secs_per_unit, exp.master_overhead)
            .with_speeds(speeds);
        cluster.verify_partitions(&expected_rows, p as u64)?;
        self.pjrt_attached = 0;
        self.beta = enc.beta;
        let assembler = GradAssembler { n, p, map: ReplicationMap::new(exp.m, 1) };
        Ok((Box::new(cluster), assembler))
    }

    /// Build the encoded model-parallel pipeline: per-worker column
    /// blocks `A_i = X·S̄_iᵀ` with the loss's `∇φ` baked in.
    /// Model parallelism partitions *columns*, which a row-streamed
    /// sharded source cannot serve — rejected with a loud error.
    pub fn model_parallel(&mut self, step: f64, lambda: f64) -> Result<ModelParallelParts> {
        let exp = self.exp;
        let prob = self.require_in_memory("model-parallel BCD")?;
        let mp = match prob.loss {
            Loss::Quadratic { y } => build_model_parallel(
                prob.x,
                exp.scheme,
                exp.m,
                exp.beta,
                step,
                lambda,
                exp.seed,
                quadratic_phi(y.to_vec()),
            )?,
            Loss::Logistic => build_model_parallel(
                prob.x,
                exp.scheme,
                exp.m,
                exp.beta,
                step,
                lambda,
                exp.seed,
                logistic_phi(),
            )?,
        };
        self.beta = mp.beta;
        let (n, p) = (mp.n, mp.p);
        Ok(ModelParallelParts {
            cluster: self.cluster(mp.workers)?,
            recon: mp.recon,
            n,
            p,
            beta: mp.beta,
        })
    }

    /// Uncoded row shards `(X_i, y_i)` for the async data-parallel
    /// baseline. A sharded source assembles each partition from its
    /// streamed blocks (partition boundaries are row ranges, so each
    /// shard lands in exactly the partitions it overlaps) — bit-identical
    /// rows to the in-memory `row_block` slicing.
    pub fn uncoded_row_shards(&self) -> Result<Vec<(Mat, Vec<f64>)>> {
        match &self.exp.source {
            DataSource::InMemory(prob) => {
                let y = self.require_y(prob, "async gradient descent")?;
                let x = prob.x;
                let bounds = partition_bounds(x.rows(), self.exp.m);
                Ok(bounds
                    .windows(2)
                    .map(|w| (x.row_block(w[0], w[1]), y[w[0]..w[1]].to_vec()))
                    .collect())
            }
            DataSource::Sharded(src) => {
                anyhow::ensure!(
                    src.has_targets(),
                    "async gradient descent needs targets y; the sharded dataset has none"
                );
                let bounds = partition_bounds(src.rows(), self.exp.m);
                let mut parts: Vec<(Mat, Vec<f64>)> = bounds
                    .windows(2)
                    .map(|w| (Mat::zeros(w[1] - w[0], src.cols()), vec![0.0; w[1] - w[0]]))
                    .collect();
                src.for_each_block(&mut |row0, xb, yb| {
                    for r in 0..xb.rows() {
                        let g = row0 + r; // global row → partition index
                        let pi = bounds.partition_point(|&b| b <= g) - 1;
                        let local = g - bounds[pi];
                        parts[pi].0.row_mut(local).copy_from_slice(xb.row(r));
                        parts[pi].1[local] = yb[r];
                    }
                    Ok(())
                })?;
                Ok(parts)
            }
        }
    }

    /// Uncoded column blocks `X_{:,B_i}` for the async model-parallel
    /// baseline — contiguous ranges, so each block is a straight per-row
    /// memcpy with no index buffer. Column access ⇒ in-memory only.
    pub fn uncoded_col_blocks(&self) -> Result<Vec<Mat>> {
        let x = self.require_in_memory("async BCD")?.x;
        let bounds = partition_bounds(x.cols(), self.exp.m);
        Ok(bounds.windows(2).map(|w| x.col_block(w[0], w[1])).collect())
    }

    /// `∇φ` of the problem's loss as a callable over the n-vector `Xw` —
    /// the same factories the BCD workers are built from, so the coded
    /// and async paths can never drift apart on the gradient formula.
    pub fn grad_phi(&self) -> Result<Box<dyn Fn(&[f64]) -> Vec<f64> + Send>> {
        let prob = self.require_in_memory("model-parallel solvers")?;
        Ok(match prob.loss {
            Loss::Quadratic { y } => quadratic_phi(y.to_vec())(),
            Loss::Logistic => logistic_phi()(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::gaussian_linear;
    use crate::delay::ConstantDelay;

    #[test]
    fn defaults_and_validation() {
        let (x, y, _) = gaussian_linear(32, 4, 0.2, 1);
        let exp = Experiment::new(Problem::least_squares(&x, &y));
        assert_eq!(exp.effective_k(), 8, "k defaults to m");
        assert!(exp.validate().is_ok());
        let bad = Experiment::new(Problem::least_squares(&x, &y)).workers(4).wait_for(5);
        assert!(bad.validate().is_err(), "k > m must be rejected");
        let bad = Experiment::new(Problem::least_squares(&x, &y)).redundancy(0.5);
        assert!(bad.validate().is_err(), "β < 1 must be rejected");
    }

    #[test]
    fn label_defaults_to_solver_name() {
        let (x, y, _) = gaussian_linear(32, 4, 0.2, 3);
        let exp = Experiment::new(Problem::least_squares(&x, &y)).workers(4).wait_for(4);
        let out = exp.run(Gd::with_step(0.01).iters(3)).unwrap();
        assert_eq!(out.trace.label, "gd");
        let out = exp.label("custom").run(Gd::with_step(0.01).iters(3)).unwrap();
        assert_eq!(out.trace.label, "custom");
    }

    #[test]
    fn factory_delay_supports_repeated_runs() {
        let (x, y, _) = gaussian_linear(32, 4, 0.2, 5);
        let exp = Experiment::new(Problem::least_squares(&x, &y))
            .workers(4)
            .wait_for(3)
            .delay(|m| Box::new(ConstantDelay::new(m, 0.5)));
        let a = exp.run(Gd::with_step(0.01).iters(4)).unwrap();
        let b = exp.run(Gd::with_step(0.01).iters(4)).unwrap();
        assert_eq!(a.w, b.w, "identical wiring must reproduce bit-identically");
        assert_eq!(a.trace.len(), 4);
    }

    #[test]
    fn one_shot_delay_model_errors_on_reuse() {
        let (x, y, _) = gaussian_linear(32, 4, 0.2, 7);
        let exp = Experiment::new(Problem::least_squares(&x, &y))
            .workers(4)
            .delay_model(Box::new(ConstantDelay::new(4, 0.1)));
        assert!(exp.run(Gd::with_step(0.01).iters(2)).is_ok());
        let err = exp.run(Gd::with_step(0.01).iters(2)).unwrap_err();
        assert!(err.to_string().contains("single run"), "got: {err}");
    }

    #[test]
    fn logistic_problem_rejected_by_data_parallel_solvers() {
        let (x, _, _) = gaussian_linear(32, 4, 0.2, 9);
        let exp = Experiment::new(Problem::logistic(&x)).workers(4);
        assert!(exp.run(Gd::with_step(0.01).iters(2)).is_err());
        assert!(exp.run(Lbfgs::new().iters(2)).is_err());
        assert!(exp.run(Prox::with_step(0.01).iters(2)).is_err());
    }

    #[test]
    fn scenario_is_reusable_and_deterministic() {
        let (x, y, _) = gaussian_linear(64, 8, 0.2, 2);
        let sc = crate::scenario::Scenario::builtin("crash-rejoin").unwrap();
        let exp = Experiment::new(Problem::least_squares(&x, &y))
            .workers(8)
            .wait_for(6)
            .scenario(&sc);
        let a = exp.run(Gd::with_step(0.01).iters(20)).unwrap();
        let b = exp.run(Gd::with_step(0.01).iters(20)).unwrap();
        assert_eq!(a.w, b.w, "scenario runs must be bit-identical");
        assert_eq!(a.trace.len(), 20);
        assert!(a.trace.records.iter().all(|r| r.k_used == 6));
        assert!(a.trace.total_time().is_finite());
    }

    #[test]
    fn adaptive_controller_is_deterministic_and_bounded() {
        let (x, y, _) = gaussian_linear(64, 8, 0.2, 2);
        let sc = crate::scenario::Scenario::builtin("crash-rejoin").unwrap();
        let exp = Experiment::new(Problem::least_squares(&x, &y))
            .workers(8)
            .wait_for(6)
            .scenario(&sc)
            .controller(KPolicy::Adaptive(Default::default()));
        let a = exp.run(Gd::with_step(0.01).iters(20)).unwrap();
        let b = exp.run(Gd::with_step(0.01).iters(20)).unwrap();
        assert_eq!(a.w, b.w, "controller-enabled runs must be bit-identical");
        assert_eq!(a.controller, "adaptive");
        assert_eq!(a.rounds.len(), 20);
        let floor = crate::control::erasure_floor(8, a.beta);
        for r in &a.rounds {
            assert!(
                r.k_requested >= floor,
                "round {}: k {} < floor {floor}",
                r.round,
                r.k_requested
            );
            assert!(r.k_requested <= 8);
            assert_eq!(r.k_effective, r.k_requested.min(r.live));
            assert_eq!(r.arrivals.len(), r.k_effective);
        }
        // The crash window shrinks live below m; the controller must
        // have been held to it rather than panicking the strict gather.
        assert!(a.rounds.iter().any(|r| r.live < 8), "crash window never seen");
    }

    #[test]
    fn static_runs_record_rounds_too() {
        let (x, y, _) = gaussian_linear(32, 4, 0.2, 5);
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .workers(4)
            .wait_for(3)
            .run(Gd::with_step(0.01).iters(6))
            .unwrap();
        assert_eq!(out.controller, "static");
        assert_eq!(out.rounds.len(), 6);
        assert!(out.rounds.iter().all(|r| r.k_requested == 3 && r.k_effective == 3));
    }

    #[test]
    fn async_solvers_reject_adaptive_policy() {
        let (x, y, _) = gaussian_linear(30, 6, 0.2, 11);
        let exp = Experiment::new(Problem::least_squares(&x, &y))
            .workers(3)
            .controller(KPolicy::Adaptive(Default::default()));
        let err = exp.run(AsyncGd::with_step(0.01).updates(50)).unwrap_err();
        assert!(err.to_string().contains("k-controller"), "got: {err}");
        let err = exp.run(AsyncBcd::with_step(0.01).updates(50)).unwrap_err();
        assert!(err.to_string().contains("k-controller"), "got: {err}");
    }

    #[test]
    fn speed_profile_excludes_slow_worker() {
        use crate::scenario::SpeedProfile;
        let (x, y, _) = gaussian_linear(32, 4, 0.2, 3);
        let out = Experiment::new(Problem::least_squares(&x, &y))
            .workers(4)
            .wait_for(3)
            .speeds(SpeedProfile::PerWorker(vec![1.0, 1.0, 1.0, 50.0]))
            .run(Gd::with_step(0.01).iters(10))
            .unwrap();
        assert_eq!(
            out.participation.fraction(3),
            0.0,
            "a 50× slower worker can never make the fastest-3 set"
        );
    }

    #[test]
    fn assemble_data_parallel_reports_parts() {
        let (x, y, _) = gaussian_linear(32, 4, 0.2, 11);
        let parts = Experiment::new(Problem::least_squares(&x, &y))
            .workers(4)
            .assemble_data_parallel()
            .unwrap();
        assert_eq!(parts.cluster.workers(), 4);
        assert_eq!(parts.assembler.p, 4);
        assert_eq!(parts.pjrt_attached, 0, "no runtime attached");
        assert!(parts.beta >= 1.0);
    }
}
