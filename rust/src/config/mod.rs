//! Experiment configuration.
//!
//! A TOML-subset parser ([`toml`]) plus the typed [`ExperimentConfig`]
//! consumed by the launcher (`coded-opt run --config exp.toml`). No serde
//! in the offline environment, so decoding is explicit.

pub mod toml;

pub use toml::{TomlDoc, TomlValue};

use anyhow::{bail, Context, Result};

/// Which optimization algorithm drives the experiment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Encoded gradient descent (data parallelism, Thm 2).
    Gd,
    /// Encoded L-BFGS with overlap curvature pairs (Thm 4).
    Lbfgs,
    /// Encoded proximal gradient / ISTA (Thm 5).
    ProxGradient,
    /// Encoded block coordinate descent (model parallelism, Thm 6).
    Bcd,
    /// Asynchronous parameter-server GD baseline (Figs. 10–13).
    AsyncGd,
    /// Asynchronous BCD baseline (Figs. 10–13).
    AsyncBcd,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "gd" | "gradient_descent" => Algorithm::Gd,
            "lbfgs" | "l-bfgs" => Algorithm::Lbfgs,
            "prox" | "proximal_gradient" | "ista" => Algorithm::ProxGradient,
            "bcd" | "coordinate_descent" => Algorithm::Bcd,
            "async_gd" | "async-gd" | "async" => Algorithm::AsyncGd,
            "async_bcd" | "async-bcd" => Algorithm::AsyncBcd,
            other => bail!("unknown algorithm '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Gd => "gd",
            Algorithm::Lbfgs => "lbfgs",
            Algorithm::ProxGradient => "prox",
            Algorithm::Bcd => "bcd",
            Algorithm::AsyncGd => "async_gd",
            Algorithm::AsyncBcd => "async_bcd",
        }
    }

    /// The synchronous wait-for-k algorithms (everything the scenario
    /// grid can sweep).
    pub fn synchronous() -> &'static [Algorithm] {
        &[Algorithm::Gd, Algorithm::Lbfgs, Algorithm::ProxGradient, Algorithm::Bcd]
    }
}

/// Encoding scheme selector (paper §4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// S = I: classic uncoded partitioning.
    Uncoded,
    /// β-fold block replication with fastest-copy deduplication.
    Replication,
    /// i.i.d. N(0, 1/√(βn)) dense encoding.
    Gaussian,
    /// Paley conference-matrix ETF.
    Paley,
    /// Column-subsampled Hadamard (FWHT fast path).
    Hadamard,
    /// Steiner ETF from (2,2,v)-Steiner systems (sparse).
    Steiner,
    /// Column-subsampled Haar wavelet matrix (sparse).
    Haar,
}

impl Scheme {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "uncoded" | "identity" => Scheme::Uncoded,
            "replication" | "rep" => Scheme::Replication,
            "gaussian" | "iid" => Scheme::Gaussian,
            "paley" => Scheme::Paley,
            "hadamard" | "fwht" => Scheme::Hadamard,
            "steiner" => Scheme::Steiner,
            "haar" => Scheme::Haar,
            other => bail!("unknown scheme '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Uncoded => "uncoded",
            Scheme::Replication => "replication",
            Scheme::Gaussian => "gaussian",
            Scheme::Paley => "paley",
            Scheme::Hadamard => "hadamard",
            Scheme::Steiner => "steiner",
            Scheme::Haar => "haar",
        }
    }

    /// All schemes the paper benchmarks against each other.
    pub fn all() -> &'static [Scheme] {
        &[
            Scheme::Uncoded,
            Scheme::Replication,
            Scheme::Gaussian,
            Scheme::Paley,
            Scheme::Hadamard,
            Scheme::Steiner,
            Scheme::Haar,
        ]
    }
}

/// Delay model selector (paper §5 experiment setups).
#[derive(Clone, Debug, PartialEq)]
pub enum DelaySpec {
    /// No injected delay.
    None,
    /// Exponential with given mean (seconds).
    Exponential { mean: f64 },
    /// The §5.3 bimodal Gaussian mixture.
    Bimodal,
    /// The §5.4 trimodal Gaussian mixture.
    Trimodal,
    /// Power-law number of background tasks (§5.3), capped.
    BackgroundTasks { alpha: f64, cap: usize, task_secs: f64 },
    /// Adversarial: a fixed set of nodes is always slowest.
    Adversarial { slow_fraction: f64, slow_secs: f64 },
}

impl DelaySpec {
    pub fn parse(doc: &TomlDoc, section: &str) -> Result<Self> {
        let kind = doc.get_str(section, "kind").unwrap_or("none");
        Ok(match kind {
            "none" => DelaySpec::None,
            "exponential" => DelaySpec::Exponential {
                mean: doc.get_f64(section, "mean").unwrap_or(0.01),
            },
            "bimodal" => DelaySpec::Bimodal,
            "trimodal" => DelaySpec::Trimodal,
            "background" => DelaySpec::BackgroundTasks {
                alpha: doc.get_f64(section, "alpha").unwrap_or(1.5),
                cap: doc.get_i64(section, "cap").unwrap_or(50) as usize,
                task_secs: doc.get_f64(section, "task_secs").unwrap_or(0.05),
            },
            "adversarial" => DelaySpec::Adversarial {
                slow_fraction: doc.get_f64(section, "slow_fraction").unwrap_or(0.25),
                slow_secs: doc.get_f64(section, "slow_secs").unwrap_or(10.0),
            },
            other => bail!("unknown delay kind '{other}'"),
        })
    }
}

/// Full experiment configuration for the launcher.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub algorithm: Algorithm,
    pub scheme: Scheme,
    /// Worker count m.
    pub workers: usize,
    /// Wait-for-k (k ≤ m).
    pub k: usize,
    /// Redundancy factor β ≥ 1.
    pub beta: f64,
    pub iterations: usize,
    pub seed: u64,
    /// Problem dims (rows n, cols p).
    pub n: usize,
    pub p: usize,
    /// Regularization λ.
    pub lambda: f64,
    /// Step size (0 → algorithm default).
    pub step_size: f64,
    /// L-BFGS memory σ.
    pub lbfgs_memory: usize,
    pub delay: DelaySpec,
    /// Full straggler scenario ([`crate::scenario::Scenario`], parsed
    /// from `[scenario.*]` sections). When set, the launcher installs it
    /// instead of the plain `delay` spec.
    pub scenario: Option<crate::scenario::Scenario>,
    /// Use the PJRT runtime (AOT artifacts) for worker compute when the
    /// shard shape matches a compiled artifact; fall back to native rust
    /// kernels otherwise.
    pub use_pjrt: bool,
    /// Wait-for-k runtime policy ([`crate::control::KPolicy`], parsed
    /// from `k_policy = "static" | "adaptive[:opts]"`). Static keeps
    /// the legacy fixed-k gather bit-for-bit; adaptive retunes k
    /// between rounds within the erasure-floor bounds.
    pub k_policy: crate::control::KPolicy,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            algorithm: Algorithm::Gd,
            scheme: Scheme::Hadamard,
            workers: 8,
            k: 6,
            beta: 2.0,
            iterations: 100,
            seed: 42,
            n: 512,
            p: 128,
            lambda: 0.05,
            step_size: 0.0,
            lbfgs_memory: 10,
            delay: DelaySpec::Exponential { mean: 0.001 },
            scenario: None,
            use_pjrt: false,
            k_policy: crate::control::KPolicy::Static,
        }
    }
}

impl ExperimentConfig {
    /// Decode from a parsed TOML document. Missing keys keep defaults.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        let s = "experiment";
        if let Some(v) = doc.get_str(s, "name") {
            cfg.name = v.to_string();
        }
        if let Some(v) = doc.get_str(s, "algorithm") {
            cfg.algorithm = Algorithm::parse(v)?;
        }
        if let Some(v) = doc.get_str(s, "scheme") {
            cfg.scheme = Scheme::parse(v)?;
        }
        if let Some(v) = doc.get_i64(s, "workers") {
            cfg.workers = v as usize;
        }
        if let Some(v) = doc.get_i64(s, "k") {
            cfg.k = v as usize;
        }
        if let Some(v) = doc.get_f64(s, "beta") {
            cfg.beta = v;
        }
        if let Some(v) = doc.get_i64(s, "iterations") {
            cfg.iterations = v as usize;
        }
        if let Some(v) = doc.get_i64(s, "seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_i64(s, "n") {
            cfg.n = v as usize;
        }
        if let Some(v) = doc.get_i64(s, "p") {
            cfg.p = v as usize;
        }
        if let Some(v) = doc.get_f64(s, "lambda") {
            cfg.lambda = v;
        }
        if let Some(v) = doc.get_f64(s, "step_size") {
            cfg.step_size = v;
        }
        if let Some(v) = doc.get_i64(s, "lbfgs_memory") {
            cfg.lbfgs_memory = v as usize;
        }
        if let Some(v) = doc.get_bool(s, "use_pjrt") {
            cfg.use_pjrt = v;
        }
        if let Some(v) = doc.get_str(s, "k_policy") {
            cfg.k_policy = crate::control::KPolicy::parse(v)?;
        }
        if doc.has_section("delay") {
            cfg.delay = DelaySpec::parse(doc, "delay")?;
        }
        // Any scenario.* section means the user wants a scenario —
        // Scenario::from_doc errors loudly if the [scenario] header is
        // missing (the flat parser creates no parent tables), instead of
        // silently dropping the adversarial part of the experiment.
        if doc.has_section("scenario")
            || doc.sections().iter().any(|s| s.starts_with("scenario."))
        {
            cfg.scenario = Some(crate::scenario::Scenario::from_doc(doc)?);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        let doc = TomlDoc::parse(&text)?;
        Self::from_doc(&doc)
    }

    /// Invariant checks shared by launcher and tests.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("workers must be ≥ 1");
        }
        if self.k == 0 || self.k > self.workers {
            bail!("k must satisfy 1 ≤ k ≤ m (k={}, m={})", self.k, self.workers);
        }
        if self.beta < 1.0 {
            bail!("redundancy β must be ≥ 1 (got {})", self.beta);
        }
        Ok(())
    }

    /// Whether the strict BRIP condition of Definition 1 (η ≥ 1/β) can
    /// hold for this operating point. The paper notes the algorithms often
    /// work below this threshold (e.g. Fig. 7 runs k=12, m=32, β=2), so
    /// this is advisory — the launcher logs a warning, never rejects.
    pub fn brip_feasible(&self) -> bool {
        match self.scheme {
            Scheme::Uncoded | Scheme::Replication => true,
            _ => self.eta() * self.beta >= 1.0 - 1e-9,
        }
    }

    /// η = k/m, the fraction of nodes waited for.
    pub fn eta(&self) -> f64 {
        self.k as f64 / self.workers as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_full_doc() {
        let text = r#"
[experiment]
name = "ridge-fig7"
algorithm = "lbfgs"
scheme = "hadamard"
workers = 32
k = 12
beta = 2.0
iterations = 50
n = 1024
p = 1500
lambda = 0.05
k_policy = "adaptive:widen=3.0"

[delay]
kind = "bimodal"
"#;
        let doc = TomlDoc::parse(text).unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.name, "ridge-fig7");
        assert_eq!(cfg.algorithm, Algorithm::Lbfgs);
        assert_eq!(cfg.scheme, Scheme::Hadamard);
        assert_eq!(cfg.workers, 32);
        assert_eq!(cfg.k, 12);
        assert_eq!(cfg.delay, DelaySpec::Bimodal);
        assert_eq!(cfg.k_policy.name(), "adaptive");
        assert!((cfg.eta() - 0.375).abs() < 1e-12);
    }

    #[test]
    fn bad_k_policy_rejected() {
        let text = "[experiment]\nk_policy = \"sometimes\"\n";
        let doc = TomlDoc::parse(text).unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn k_greater_than_m_rejected() {
        let mut cfg = ExperimentConfig::default();
        cfg.k = cfg.workers + 1;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn brip_feasibility_is_advisory() {
        let mut cfg = ExperimentConfig::default();
        cfg.workers = 10;
        cfg.k = 2;
        cfg.beta = 2.0; // η·β = 0.4 < 1
        cfg.validate().unwrap(); // still valid to run…
        assert!(!cfg.brip_feasible()); // …but flagged
        cfg.k = 5; // η·β = 1.0
        assert!(cfg.brip_feasible());
    }

    #[test]
    fn uncoded_always_brip_feasible() {
        let mut cfg = ExperimentConfig::default();
        cfg.scheme = Scheme::Uncoded;
        cfg.workers = 10;
        cfg.k = 2;
        cfg.beta = 1.0;
        cfg.validate().unwrap();
        assert!(cfg.brip_feasible());
    }

    #[test]
    fn scenario_section_parses_into_config() {
        let text = r#"
[experiment]
name = "sc-run"

[scenario]
name = "one-crash"

[scenario.t0]
transform = "crash"
workers = "0"
start = 2
end = 4
"#;
        let doc = TomlDoc::parse(text).unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        let sc = cfg.scenario.expect("scenario parsed");
        assert_eq!(sc.name, "one-crash");
        assert_eq!(sc.transforms.len(), 1);
        // configs without a [scenario] section keep None
        let plain = TomlDoc::parse("[experiment]\nname = \"x\"\n").unwrap();
        assert!(ExperimentConfig::from_doc(&plain).unwrap().scenario.is_none());
        // an orphan [scenario.t0] without the [scenario] header is a loud
        // error, not a silently dropped adversary
        let orphan = TomlDoc::parse(
            "[experiment]\nname = \"x\"\n[scenario.t0]\ntransform = \"crash\"\nworkers = \"0\"\n",
        )
        .unwrap();
        assert!(ExperimentConfig::from_doc(&orphan).is_err());
    }

    #[test]
    fn algorithm_names_roundtrip() {
        for a in [
            Algorithm::Gd,
            Algorithm::Lbfgs,
            Algorithm::ProxGradient,
            Algorithm::Bcd,
            Algorithm::AsyncGd,
            Algorithm::AsyncBcd,
        ] {
            assert_eq!(Algorithm::parse(a.name()).unwrap(), a);
        }
        assert_eq!(Algorithm::synchronous().len(), 4);
    }

    #[test]
    fn algorithm_and_scheme_parsing() {
        assert_eq!(Algorithm::parse("L-BFGS").unwrap(), Algorithm::Lbfgs);
        assert_eq!(Scheme::parse("STEINER").unwrap(), Scheme::Steiner);
        assert_eq!(Algorithm::parse("async_gd").unwrap(), Algorithm::AsyncGd);
        assert_eq!(Algorithm::parse("async-bcd").unwrap(), Algorithm::AsyncBcd);
        assert!(Algorithm::parse("sgd?").is_err());
        assert!(Scheme::parse("fourier??").is_err());
    }
}
