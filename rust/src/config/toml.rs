//! Minimal TOML-subset parser.
//!
//! Supports exactly what experiment configs need:
//! `[section]` headers, `key = value` pairs with string / integer / float /
//! boolean values, `#` comments, and blank lines. No arrays, no nested
//! tables, no multi-line strings — configs stay flat by design.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

/// A parsed document: section → key → value.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = TomlDoc::default();
        let mut current = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(stripped) = line.strip_prefix('[') {
                let Some(name) = stripped.strip_suffix(']') else {
                    bail!("line {}: malformed section header '{raw}'", lineno + 1);
                };
                current = name.trim().to_string();
                if current.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
            };
            let key = line[..eq].trim().to_string();
            let val_str = line[eq + 1..].trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let value = parse_value(val_str)
                .ok_or_else(|| {
                    anyhow::anyhow!("line {}: cannot parse value '{val_str}'", lineno + 1)
                })?;
            doc.sections.entry(current.clone()).or_default().insert(key, value);
        }
        Ok(doc)
    }

    pub fn has_section(&self, section: &str) -> bool {
        self.sections.contains_key(section)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key)? {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn get_i64(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key)? {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`beta = 2`).
    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key)? {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            TomlValue::Bool(v) => Some(*v),
            _ => None,
        }
    }

    /// All section names, in order.
    pub fn sections(&self) -> Vec<String> {
        self.sections.keys().cloned().collect()
    }

    /// Keys of a section (for diagnostics).
    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|m| m.keys().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }
}

/// Remove a trailing `#` comment, respecting double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<TomlValue> {
    if let Some(stripped) = s.strip_prefix('"') {
        let inner = stripped.strip_suffix('"')?;
        return Some(TomlValue::Str(inner.to_string()));
    }
    match s {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Some(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Some(TomlValue::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
# top comment
[a]
s = "hello"   # trailing comment
i = 42
f = 2.5
neg = -3
b = true

[b]
x = 1
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("a", "s"), Some("hello"));
        assert_eq!(doc.get_i64("a", "i"), Some(42));
        assert_eq!(doc.get_f64("a", "f"), Some(2.5));
        assert_eq!(doc.get_i64("a", "neg"), Some(-3));
        assert_eq!(doc.get_bool("a", "b"), Some(true));
        assert_eq!(doc.get_i64("b", "x"), Some(1));
        assert!(doc.has_section("b"));
        assert!(!doc.has_section("c"));
    }

    #[test]
    fn int_readable_as_float() {
        let doc = TomlDoc::parse("[s]\nbeta = 2\n").unwrap();
        assert_eq!(doc.get_f64("s", "beta"), Some(2.0));
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = TomlDoc::parse("[s]\nname = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_str("s", "name"), Some("a#b"));
    }

    #[test]
    fn malformed_lines_error() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("[s]\nnovalue\n").is_err());
        assert!(TomlDoc::parse("[s]\nk = what\n").is_err());
        assert!(TomlDoc::parse("[]\n").is_err());
        assert!(TomlDoc::parse("[s]\n = 3\n").is_err());
    }

    #[test]
    fn wrong_type_returns_none() {
        let doc = TomlDoc::parse("[s]\nk = 3\n").unwrap();
        assert_eq!(doc.get_str("s", "k"), None);
        assert_eq!(doc.get_bool("s", "k"), None);
    }

    #[test]
    fn last_duplicate_wins() {
        let doc = TomlDoc::parse("[s]\nk = 1\nk = 2\n").unwrap();
        assert_eq!(doc.get_i64("s", "k"), Some(2));
    }
}
