//! Benchmark harness (criterion stand-in for the offline environment).
//!
//! Used by the `rust/benches/*` binaries (declared with `harness = false`)
//! to produce stable timing summaries and the paper-table output rows,
//! and by the `coded-opt bench` subcommand to emit the machine-readable
//! `BENCH_*.json` reports that CI's perf job gates on.
//!
//! ## `BENCH_*.json` schema (`coded-opt/bench-v1`)
//!
//! ```json
//! {
//!   "schema": "coded-opt/bench-v1",
//!   "threads": 8,
//!   "features": "cpu=sse2,avx,avx2; simd=on; precision=f64",
//!   "entries": [
//!     {
//!       "name": "encode_hadamard_1024x512",
//!       "mean_secs": 1.2e-3, "p50_secs": 1.1e-3, "p95_secs": 1.9e-3,
//!       "iters": 30,
//!       "baseline_mean_secs": 9.8e-3,
//!       "speedup": 8.2
//!     }
//!   ]
//! }
//! ```
//!
//! Entries that measure a fast kernel against its in-process naive
//! reference carry `baseline_mean_secs`/`speedup`; plain entries omit
//! them. The CI regression gate ([`BenchReport::compare`]) only ever
//! compares **speedup ratios** — fast kernel vs. the reference kernel
//! timed in the same process — because those are machine-independent,
//! unlike absolute seconds. Future PRs should extend this schema (new
//! entry names) rather than invent a new format.
//!
//! `features` is an informational free-form descriptor of the machine
//! and data-plane configuration the report was produced under (detected
//! CPU SIMD features, whether the AVX2 kernels were active, storage
//! precision). It is never gated on — `simd_*` / `f32_*` paired entries
//! carry that information where it matters, as speedup ratios measured
//! with both variants in the same process (e.g. `simd_matvec_1024x512`
//! times the AVX2 kernel against the forced-scalar kernel, and
//! `f32_matvec_1024x512` times f32-storage matvec against f64). Parsers
//! treat a missing `features` field as empty for backward compatibility
//! with pre-SIMD reports.

use std::time::Instant;

use crate::metrics::Histogram;
use anyhow::{bail, Context, Result};

/// Timing statistics from [`run_bench`].
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub iters: usize,
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} mean {:>12} p50 {:>12} p95 {:>12} ({} iters)",
            self.name,
            fmt_secs(self.mean_secs),
            fmt_secs(self.p50_secs),
            fmt_secs(self.p95_secs),
            self.iters
        )
    }
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Time a closure `iters` times after `warmup` runs; returns stats.
pub fn run_bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut h = Histogram::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        h.record(t0.elapsed().as_secs_f64());
    }
    let stats = BenchStats {
        name: name.to_string(),
        mean_secs: h.mean(),
        p50_secs: h.percentile(0.5),
        p95_secs: h.percentile(0.95),
        iters,
    };
    println!("{stats}");
    stats
}

/// Header banner for a bench binary; prints which paper artifact it
/// regenerates.
pub fn banner(fig: &str, desc: &str) {
    println!("================================================================");
    println!("  coded-opt bench — {fig}");
    println!("  {desc}");
    println!("================================================================");
}

/// One row of a [`BenchReport`].
#[derive(Clone, Debug)]
pub struct BenchEntry {
    pub name: String,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub iters: usize,
    /// The in-process naive-reference timing (speedup denominator) for
    /// paired fast-vs-reference measurements; `None` for plain timings.
    pub baseline_mean_secs: Option<f64>,
}

impl BenchEntry {
    /// Speedup of the fast kernel over its in-process reference.
    pub fn speedup(&self) -> Option<f64> {
        self.baseline_mean_secs.map(|b| b / self.mean_secs.max(1e-12))
    }
}

/// Machine-readable bench report (schema `coded-opt/bench-v1`).
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub threads: usize,
    /// Free-form machine/configuration descriptor (CPU SIMD features,
    /// active SIMD mode, precision). Informational only — never gated.
    pub features: String,
    pub entries: Vec<BenchEntry>,
}

/// Schema tag written into / required from every report.
pub const BENCH_SCHEMA: &str = "coded-opt/bench-v1";

impl BenchReport {
    pub fn new(threads: usize) -> Self {
        BenchReport { threads, features: String::new(), entries: Vec::new() }
    }

    /// Attach the machine/configuration descriptor (see module docs).
    pub fn with_features(mut self, features: impl Into<String>) -> Self {
        self.features = features.into();
        self
    }

    /// Record a plain timing.
    pub fn push(&mut self, stats: &BenchStats) {
        self.entries.push(BenchEntry {
            name: stats.name.clone(),
            mean_secs: stats.mean_secs,
            p50_secs: stats.p50_secs,
            p95_secs: stats.p95_secs,
            iters: stats.iters,
            baseline_mean_secs: None,
        });
    }

    /// Record a paired fast-vs-reference timing under `name`.
    pub fn push_pair(&mut self, name: &str, fast: &BenchStats, reference: &BenchStats) {
        self.entries.push(BenchEntry {
            name: name.to_string(),
            mean_secs: fast.mean_secs,
            p50_secs: fast.p50_secs,
            p95_secs: fast.p95_secs,
            iters: fast.iters,
            baseline_mean_secs: Some(reference.mean_secs),
        });
    }

    pub fn entry(&self, name: &str) -> Option<&BenchEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Serialize to the `coded-opt/bench-v1` JSON document.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"schema\": \"{BENCH_SCHEMA}\",\n"));
        out.push_str(&format!("  \"threads\": {},\n", self.threads));
        out.push_str(&format!("  \"features\": \"{}\",\n", json::escape(&self.features)));
        out.push_str("  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            out.push_str("    {");
            out.push_str(&format!("\"name\": \"{}\", ", json::escape(&e.name)));
            out.push_str(&format!("\"mean_secs\": {:e}, ", e.mean_secs));
            out.push_str(&format!("\"p50_secs\": {:e}, ", e.p50_secs));
            out.push_str(&format!("\"p95_secs\": {:e}, ", e.p95_secs));
            out.push_str(&format!("\"iters\": {}", e.iters));
            if let Some(b) = e.baseline_mean_secs {
                out.push_str(&format!(", \"baseline_mean_secs\": {b:e}"));
                out.push_str(&format!(", \"speedup\": {:.3}", e.speedup().unwrap()));
            }
            out.push('}');
            out.push_str(if i + 1 < self.entries.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Parse a `coded-opt/bench-v1` document.
    pub fn parse_json(text: &str) -> Result<BenchReport> {
        let root = json::parse(text)?;
        let obj = root.as_object().context("bench report: root must be an object")?;
        let schema = json::get(obj, "schema")
            .and_then(|v| v.as_str())
            .context("bench report: missing schema")?;
        if schema != BENCH_SCHEMA {
            bail!("bench report: unknown schema '{schema}' (want {BENCH_SCHEMA})");
        }
        let threads = json::get(obj, "threads").and_then(|v| v.as_f64()).unwrap_or(1.0) as usize;
        // Absent in pre-SIMD reports (still schema bench-v1): default empty.
        let features = json::get(obj, "features")
            .and_then(|v| v.as_str())
            .unwrap_or_default()
            .to_string();
        let entries_v = json::get(obj, "entries")
            .and_then(|v| v.as_array())
            .context("bench report: missing entries array")?;
        let mut entries = Vec::with_capacity(entries_v.len());
        for v in entries_v {
            let e = v.as_object().context("bench entry must be an object")?;
            let name = json::get(e, "name")
                .and_then(|v| v.as_str())
                .context("bench entry: missing name")?
                .to_string();
            let num = |key: &str| -> f64 {
                json::get(e, key).and_then(|v| v.as_f64()).unwrap_or(0.0)
            };
            entries.push(BenchEntry {
                name,
                mean_secs: num("mean_secs"),
                p50_secs: num("p50_secs"),
                p95_secs: num("p95_secs"),
                iters: num("iters") as usize,
                baseline_mean_secs: json::get(e, "baseline_mean_secs").and_then(|v| v.as_f64()),
            });
        }
        Ok(BenchReport { threads, features, entries })
    }

    /// Regression gate: every baseline entry that records a speedup must
    /// be matched by a measured entry whose speedup is at least
    /// `(1 − tolerance) ×` the baseline's. Returns the list of
    /// regressions (empty = pass). Only dimensionless speedups are
    /// gated — absolute seconds vary with the runner hardware.
    pub fn compare(&self, baseline: &BenchReport, tolerance: f64) -> Vec<String> {
        let mut regressions = Vec::new();
        for base in &baseline.entries {
            let Some(base_speedup) = base.speedup() else { continue };
            let floor = base_speedup * (1.0 - tolerance);
            match self.entry(&base.name).and_then(|e| e.speedup()) {
                None => regressions.push(format!(
                    "{}: baseline records a {base_speedup:.2}x speedup but the \
                     measured report has no such paired entry",
                    base.name
                )),
                Some(got) if got < floor => regressions.push(format!(
                    "{}: speedup {got:.2}x < floor {floor:.2}x \
                     (baseline {base_speedup:.2}x, tolerance {tolerance})",
                    base.name
                )),
                Some(_) => {}
            }
        }
        regressions
    }
}

/// Minimal JSON subset parser (objects / arrays / strings / numbers /
/// bool / null) — no serde offline. Shared by the bench schema here and
/// the shard manifests in [`crate::data::shard`]; extend it rather than
/// growing a second parser.
pub mod json {
    use anyhow::{bail, Result};

    #[derive(Clone, Debug)]
    pub enum Value {
        Object(Vec<(String, Value)>),
        Array(Vec<Value>),
        Str(String),
        Num(f64),
        Bool(bool),
        Null,
    }

    impl Value {
        pub fn as_object(&self) -> Option<&[(String, Value)]> {
            match self {
                Value::Object(kv) => Some(kv),
                _ => None,
            }
        }

        pub fn as_array(&self) -> Option<&[Value]> {
            match self {
                Value::Array(a) => Some(a),
                _ => None,
            }
        }

        pub fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }

        pub fn as_f64(&self) -> Option<f64> {
            match self {
                Value::Num(x) => Some(*x),
                _ => None,
            }
        }

        pub fn as_bool(&self) -> Option<bool> {
            match self {
                Value::Bool(b) => Some(*b),
                _ => None,
            }
        }
    }

    pub fn get<'a>(obj: &'a [(String, Value)], key: &str) -> Option<&'a Value> {
        obj.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// Escape a string for embedding in a JSON document (quotes,
    /// backslashes, and the control characters the parser understands).
    pub fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                c => out.push(c),
            }
        }
        out
    }

    pub fn parse(text: &str) -> Result<Value> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters at byte {pos}");
        }
        Ok(v)
    }

    fn skip_ws(b: &[u8], pos: &mut usize) {
        while *pos < b.len() && (b[*pos] as char).is_ascii_whitespace() {
            *pos += 1;
        }
    }

    fn expect(b: &[u8], pos: &mut usize, ch: u8) -> Result<()> {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != ch {
            bail!("expected '{}' at byte {pos}", ch as char);
        }
        *pos += 1;
        Ok(())
    }

    fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value> {
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b'{') => {
                *pos += 1;
                let mut kv = Vec::new();
                skip_ws(b, pos);
                if b.get(*pos) == Some(&b'}') {
                    *pos += 1;
                    return Ok(Value::Object(kv));
                }
                loop {
                    skip_ws(b, pos);
                    let key = parse_string(b, pos)?;
                    expect(b, pos, b':')?;
                    let val = parse_value(b, pos)?;
                    kv.push((key, val));
                    skip_ws(b, pos);
                    match b.get(*pos) {
                        Some(b',') => *pos += 1,
                        Some(b'}') => {
                            *pos += 1;
                            return Ok(Value::Object(kv));
                        }
                        _ => bail!("expected ',' or '}}' at byte {pos}"),
                    }
                }
            }
            Some(b'[') => {
                *pos += 1;
                let mut arr = Vec::new();
                skip_ws(b, pos);
                if b.get(*pos) == Some(&b']') {
                    *pos += 1;
                    return Ok(Value::Array(arr));
                }
                loop {
                    arr.push(parse_value(b, pos)?);
                    skip_ws(b, pos);
                    match b.get(*pos) {
                        Some(b',') => *pos += 1,
                        Some(b']') => {
                            *pos += 1;
                            return Ok(Value::Array(arr));
                        }
                        _ => bail!("expected ',' or ']' at byte {pos}"),
                    }
                }
            }
            Some(b'"') => Ok(Value::Str(parse_string(b, pos)?)),
            Some(b't') => keyword(b, pos, "true", Value::Bool(true)),
            Some(b'f') => keyword(b, pos, "false", Value::Bool(false)),
            Some(b'n') => keyword(b, pos, "null", Value::Null),
            Some(_) => {
                let start = *pos;
                while *pos < b.len()
                    && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
                {
                    *pos += 1;
                }
                let s = std::str::from_utf8(&b[start..*pos])?;
                Ok(Value::Num(s.parse::<f64>().map_err(|_| {
                    anyhow::anyhow!("bad number '{s}' at byte {start}")
                })?))
            }
            None => bail!("unexpected end of input"),
        }
    }

    fn keyword(b: &[u8], pos: &mut usize, word: &str, value: Value) -> Result<Value> {
        if b[*pos..].starts_with(word.as_bytes()) {
            *pos += word.len();
            Ok(value)
        } else {
            bail!("bad literal at byte {pos}")
        }
    }

    fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
        if b.get(*pos) != Some(&b'"') {
            bail!("expected string at byte {pos}");
        }
        *pos += 1;
        let mut out: Vec<u8> = Vec::new();
        while *pos < b.len() {
            match b[*pos] {
                b'"' => {
                    *pos += 1;
                    return Ok(String::from_utf8(out)?);
                }
                b'\\' => {
                    *pos += 1;
                    match b.get(*pos) {
                        Some(b'"') => out.push(b'"'),
                        Some(b'\\') => out.push(b'\\'),
                        Some(b'n') => out.push(b'\n'),
                        Some(b't') => out.push(b'\t'),
                        other => bail!("unsupported escape {other:?}"),
                    }
                    *pos += 1;
                }
                c => {
                    // multi-byte UTF-8 passes through byte-wise
                    out.push(c);
                    *pos += 1;
                }
            }
        }
        bail!("unterminated string")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_counts() {
        let mut count = 0usize;
        let stats = run_bench("noop", 2, 5, || {
            count += 1;
        });
        assert_eq!(count, 7);
        assert_eq!(stats.iters, 5);
        assert!(stats.mean_secs >= 0.0);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }

    fn stats(name: &str, mean: f64) -> BenchStats {
        BenchStats {
            name: name.to_string(),
            mean_secs: mean,
            p50_secs: mean,
            p95_secs: mean * 1.2,
            iters: 10,
        }
    }

    #[test]
    fn report_json_roundtrip() {
        let mut r = BenchReport::new(8).with_features("cpu=avx2; simd=on; precision=f64");
        r.push(&stats("fwht_8192", 1e-4));
        r.push(&stats("tricky \"name\" with \\ and n=8", 1e-4));
        r.push_pair("gram_512", &stats("gram fast", 1e-3), &stats("gram naive", 4e-3));
        let text = r.to_json();
        let back = BenchReport::parse_json(&text).unwrap();
        assert_eq!(back.threads, 8);
        assert_eq!(back.features, "cpu=avx2; simd=on; precision=f64");
        assert_eq!(back.entries.len(), 3);
        // Pre-SIMD documents omit `features`; parse must tolerate that.
        let old = BenchReport::parse_json(
            "{\"schema\": \"coded-opt/bench-v1\", \"threads\": 2, \"entries\": []}",
        )
        .unwrap();
        assert!(old.features.is_empty());
        assert!(back.entry("fwht_8192").unwrap().speedup().is_none());
        assert!(back.entry("tricky \"name\" with \\ and n=8").is_some(), "escaped roundtrip");
        let g = back.entry("gram_512").unwrap();
        assert!((g.speedup().unwrap() - 4.0).abs() < 1e-6, "{:?}", g.speedup());
    }

    #[test]
    fn compare_gates_on_speedup_ratios_only() {
        let mut baseline = BenchReport::new(4);
        baseline.push_pair("gram_512", &stats("f", 1e-3), &stats("n", 4e-3)); // 4x
        baseline.push(&stats("fwht_8192", 1e-4)); // informational, never gated
        // Same speedup on a 10x slower machine: passes.
        let mut slow = BenchReport::new(4);
        slow.push_pair("gram_512", &stats("f", 1e-2), &stats("n", 4e-2));
        assert!(slow.compare(&baseline, 0.25).is_empty());
        // Speedup collapsed to 2x (< 4x·0.75): fails.
        let mut bad = BenchReport::new(4);
        bad.push_pair("gram_512", &stats("f", 2e-3), &stats("n", 4e-3));
        let regressions = bad.compare(&baseline, 0.25);
        assert_eq!(regressions.len(), 1, "{regressions:?}");
        // A missing paired entry is a failure, not a silent pass.
        let empty = BenchReport::new(4);
        assert_eq!(empty.compare(&baseline, 0.25).len(), 1);
    }

    #[test]
    fn parse_json_rejects_garbage() {
        assert!(BenchReport::parse_json("{}").is_err());
        assert!(BenchReport::parse_json("not json").is_err());
        assert!(BenchReport::parse_json(
            "{\"schema\": \"other/v9\", \"entries\": []}"
        )
        .is_err());
    }
}
