//! Benchmark harness (criterion stand-in for the offline environment).
//!
//! Used by the `rust/benches/*` binaries (declared with `harness = false`)
//! to produce stable timing summaries and the paper-table output rows.

use std::time::Instant;

use crate::metrics::Histogram;

/// Timing statistics from [`run_bench`].
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub iters: usize,
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} mean {:>12} p50 {:>12} p95 {:>12} ({} iters)",
            self.name,
            fmt_secs(self.mean_secs),
            fmt_secs(self.p50_secs),
            fmt_secs(self.p95_secs),
            self.iters
        )
    }
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Time a closure `iters` times after `warmup` runs; returns stats.
pub fn run_bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut h = Histogram::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        h.record(t0.elapsed().as_secs_f64());
    }
    let stats = BenchStats {
        name: name.to_string(),
        mean_secs: h.mean(),
        p50_secs: h.percentile(0.5),
        p95_secs: h.percentile(0.95),
        iters,
    };
    println!("{stats}");
    stats
}

/// Header banner for a bench binary; prints which paper artifact it
/// regenerates.
pub fn banner(fig: &str, desc: &str) {
    println!("================================================================");
    println!("  coded-opt bench — {fig}");
    println!("  {desc}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_counts() {
        let mut count = 0usize;
        let stats = run_bench("noop", 2, 5, || {
            count += 1;
        });
        assert_eq!(count, 7);
        assert_eq!(stats.iters, 5);
        assert!(stats.mean_secs >= 0.0);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
