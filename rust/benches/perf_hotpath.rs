//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf).
//!
//! Times the components that dominate an encoded-optimization round:
//! worker gradient kernels (native vs PJRT), gather-round dispatch
//! overhead, gradient assembly, FWHT encoding, and encoding construction.
//!
//!     cargo bench --bench perf_hotpath

use coded_opt::bench::{banner, run_bench};
use coded_opt::cluster::{Gather, Task};
use coded_opt::config::Scheme;
use coded_opt::coordinator::KIND_GRADIENT;
use coded_opt::data::synth::gaussian_linear;
use coded_opt::driver::{Experiment, Problem};
use coded_opt::linalg::fwht::fwht;
use coded_opt::linalg::Mat;
use coded_opt::rng::Pcg64;
use coded_opt::runtime::{ArtifactIndex, GradExecutor};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("perf", "hot-path microbenchmarks (native kernel, PJRT, gather, FWHT)");
    let mut rng = Pcg64::new(1);

    // ---- native worker gradient kernel, shipped shapes
    for &(rows, cols) in &[(128usize, 64usize), (512, 128)] {
        let sx = Mat::from_fn(rows, cols, |_, _| rng.next_f64() - 0.5);
        let sy: Vec<f64> = (0..rows).map(|_| rng.next_f64() - 0.5).collect();
        let w: Vec<f64> = (0..cols).map(|_| rng.next_f64() - 0.5).collect();
        run_bench(&format!("native quad_grad {rows}x{cols}"), 20, 200, || {
            let mut resid = sx.matvec(&w);
            for (r, y) in resid.iter_mut().zip(&sy) {
                *r -= y;
            }
            std::hint::black_box(sx.matvec_t(&resid));
        });
    }

    // ---- PJRT worker gradient kernel (AOT pallas artifact)
    let idx = ArtifactIndex::load(Path::new("artifacts"))?;
    if idx.is_empty() {
        println!("(skipping PJRT benches: run `make artifacts`)");
    } else {
        for &(rows, cols) in &[(128usize, 64usize), (512, 128)] {
            let sx = Mat::from_fn(rows, cols, |_, _| rng.next_f64() - 0.5);
            let sy: Vec<f64> = (0..rows).map(|_| rng.next_f64() - 0.5).collect();
            let w: Vec<f64> = (0..cols).map(|_| rng.next_f64() - 0.5).collect();
            if let Some(mut exec) = GradExecutor::from_index(&idx, &sx, &sy) {
                exec.gradient(&w)?; // compile once outside the timer
                run_bench(&format!("PJRT  quad_grad {rows}x{cols}"), 20, 200, || {
                    std::hint::black_box(exec.gradient(&w).unwrap());
                });
            }
        }
    }

    // ---- full gather round (m=8 sim cluster, no delays): coordinator
    //      dispatch + worker compute + assembly, wired by the Experiment
    //      driver's escape hatch for round-level harnesses
    {
        let (x, y, _) = gaussian_linear(512, 64, 0.3, 5);
        let mut parts = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(Scheme::Hadamard)
            .workers(8)
            .wait_for(6)
            .redundancy(2.0)
            .seed(5)
            .assemble_data_parallel()?;
        let w: Vec<f64> = (0..64).map(|_| rng.next_f64() - 0.5).collect();
        let mut iter = 0usize;
        run_bench("gather round m=8 (512x64, hadamard)", 10, 100, || {
            let rr = parts.cluster.round(6, &mut |_| Task {
                iter,
                kind: KIND_GRADIENT,
                payload: w.clone(),
                aux: vec![],
            });
            iter += 1;
            std::hint::black_box(parts.assembler.assemble(&rr.responses));
        });
    }

    // ---- FWHT encoding throughput
    for nn in [1024usize, 8192] {
        let mut buf: Vec<f64> = (0..nn).map(|i| (i as f64 * 0.37).sin()).collect();
        run_bench(&format!("FWHT n={nn}"), 20, 200, || {
            fwht(&mut buf);
        });
    }

    // ---- encoding construction (amortized once per experiment)
    run_bench("build hadamard encoding 1024x512 m=16", 2, 10, || {
        std::hint::black_box(
            coded_opt::encoding::EncodingOp::build(Scheme::Hadamard, 512, 16, 2.0, 3).unwrap(),
        );
    });
    run_bench("build steiner  encoding n=496 m=16", 2, 10, || {
        std::hint::black_box(
            coded_opt::encoding::EncodingOp::build(Scheme::Steiner, 496, 16, 2.0, 3).unwrap(),
        );
    });
    Ok(())
}
