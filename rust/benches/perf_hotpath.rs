//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf).
//!
//! Times the components that dominate an encoded-optimization round:
//! worker gradient kernels (native vs PJRT), gather-round dispatch
//! overhead, gradient assembly, FWHT encoding, and encoding construction.
//!
//!     cargo bench --bench perf_hotpath

use coded_opt::bench::{banner, run_bench};
use coded_opt::cluster::{Gather, SimCluster, Task};
use coded_opt::config::Scheme;
use coded_opt::coordinator::{build_data_parallel, KIND_GRADIENT};
use coded_opt::data::synth::gaussian_linear;
use coded_opt::delay::NoDelay;
use coded_opt::linalg::fwht::fwht;
use coded_opt::linalg::Mat;
use coded_opt::rng::Pcg64;
use coded_opt::runtime::{ArtifactIndex, GradExecutor};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    banner("perf", "hot-path microbenchmarks (native kernel, PJRT, gather, FWHT)");
    let mut rng = Pcg64::new(1);

    // ---- native worker gradient kernel, shipped shapes
    for &(rows, cols) in &[(128usize, 64usize), (512, 128)] {
        let sx = Mat::from_fn(rows, cols, |_, _| rng.next_f64() - 0.5);
        let sy: Vec<f64> = (0..rows).map(|_| rng.next_f64() - 0.5).collect();
        let w: Vec<f64> = (0..cols).map(|_| rng.next_f64() - 0.5).collect();
        run_bench(&format!("native quad_grad {rows}x{cols}"), 20, 200, || {
            let mut resid = sx.matvec(&w);
            for (r, y) in resid.iter_mut().zip(&sy) {
                *r -= y;
            }
            std::hint::black_box(sx.matvec_t(&resid));
        });
    }

    // ---- PJRT worker gradient kernel (AOT pallas artifact)
    let idx = ArtifactIndex::load(Path::new("artifacts"))?;
    if idx.is_empty() {
        println!("(skipping PJRT benches: run `make artifacts`)");
    } else {
        for &(rows, cols) in &[(128usize, 64usize), (512, 128)] {
            let sx = Mat::from_fn(rows, cols, |_, _| rng.next_f64() - 0.5);
            let sy: Vec<f64> = (0..rows).map(|_| rng.next_f64() - 0.5).collect();
            let w: Vec<f64> = (0..cols).map(|_| rng.next_f64() - 0.5).collect();
            if let Some(mut exec) = GradExecutor::from_index(&idx, &sx, &sy) {
                exec.gradient(&w)?; // compile once outside the timer
                run_bench(&format!("PJRT  quad_grad {rows}x{cols}"), 20, 200, || {
                    std::hint::black_box(exec.gradient(&w).unwrap());
                });
            }
        }
    }

    // ---- full gather round (m=8 sim cluster, no delays): coordinator
    //      dispatch + worker compute + assembly
    {
        let (x, y, _) = gaussian_linear(512, 64, 0.3, 5);
        let dp = build_data_parallel(&x, &y, Scheme::Hadamard, 8, 2.0, 5)?;
        let asm = dp.assembler.clone();
        let mut cluster = SimCluster::new(dp.workers, Box::new(NoDelay::new(8)));
        let w: Vec<f64> = (0..64).map(|_| rng.next_f64() - 0.5).collect();
        let mut iter = 0usize;
        run_bench("gather round m=8 (512x64, hadamard)", 10, 100, || {
            let rr = cluster.round(6, &mut |_| Task {
                iter,
                kind: KIND_GRADIENT,
                payload: w.clone(),
                aux: vec![],
            });
            iter += 1;
            std::hint::black_box(asm.assemble(&rr.responses));
        });
    }

    // ---- FWHT encoding throughput
    for nn in [1024usize, 8192] {
        let mut buf: Vec<f64> = (0..nn).map(|i| (i as f64 * 0.37).sin()).collect();
        run_bench(&format!("FWHT n={nn}"), 20, 200, || {
            fwht(&mut buf);
        });
    }

    // ---- encoding construction (amortized once per experiment)
    run_bench("build hadamard encoding 1024x512 m=16", 2, 10, || {
        std::hint::black_box(
            coded_opt::encoding::Encoding::build(Scheme::Hadamard, 512, 16, 2.0, 3).unwrap(),
        );
    });
    run_bench("build steiner  encoding n=496 m=16", 2, 10, || {
        std::hint::black_box(
            coded_opt::encoding::Encoding::build(Scheme::Steiner, 496, 16, 2.0, 3).unwrap(),
        );
    });
    Ok(())
}
