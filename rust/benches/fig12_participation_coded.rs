//! Figure 12: fraction of iterations each worker participates in
//! (empirical P{i ∈ A_t}) for Steiner-encoded BCD with k = 0.625·m under
//! power-law background tasks.
//!
//!     cargo bench --bench fig12_participation_coded

use coded_opt::bench::banner;
use coded_opt::cluster::SimCluster;
use coded_opt::config::Scheme;
use coded_opt::coordinator::bcd::{build_model_parallel, logistic_phi, run_bcd, BcdConfig};
use coded_opt::data::rcv1like;
use coded_opt::delay::BackgroundTasksDelay;
use coded_opt::objectives::LogisticProblem;

fn main() -> anyhow::Result<()> {
    banner("Figure 12", "per-node participation, Steiner-coded BCD (k=0.625m)");
    let (docs, feats, nnz) = (500usize, 192usize, 10usize);
    let (m, k) = (16usize, 10usize);
    let ds = rcv1like::generate(docs, feats, nnz, 0.05, 77);
    let x = ds.train.to_dense();
    let n_train = ds.train.rows();
    let prob = LogisticProblem::new(ds.train.clone(), 1e-4);
    let step = 1.0 / prob.smoothness() / 4.0;
    let mp = build_model_parallel(&x, Scheme::Steiner, m, 2.0, step, 1e-4, 13, logistic_phi())?;
    let sbar = mp.sbar;
    let bg = BackgroundTasksDelay::new(m, 1.5, 50, 0.05, 31);
    let tasks: Vec<usize> = bg.task_counts().to_vec();
    let mut cluster = SimCluster::new(mp.workers, Box::new(bg)).with_timing(1e-4, 1e-3);
    let cfg = BcdConfig { k, iters: 300 };
    let out = run_bcd(&mut cluster, &sbar, n_train, feats, &cfg, "steiner", &|_| (0.0, 0.0));
    println!("\nnode  bg-tasks  participation fraction");
    for i in 0..m {
        let frac = out.participation.fraction(i);
        let bar = "#".repeat((40.0 * frac).round() as usize);
        println!("{i:>4}  {:>8}  {frac:>6.3} |{bar}", tasks[i]);
    }
    println!("\ntarget E[participation] = k/m = {:.3}", k as f64 / m as f64);
    println!("imbalance (cv) = {:.3}", out.participation.imbalance());
    println!("\nPaper shape (Fig. 12): lightly-loaded nodes participate in nearly every");
    println!("iteration; heavily-loaded nodes are (harmlessly) erased — but every node");
    println!("that does participate contributes a FRESH update.");
    Ok(())
}
