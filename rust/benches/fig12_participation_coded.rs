//! Figure 12: fraction of iterations each worker participates in
//! (empirical P{i ∈ A_t}) for Steiner-encoded BCD with k = 0.625·m under
//! power-law background tasks — one
//! [`Experiment`](coded_opt::driver::Experiment) run.
//!
//!     cargo bench --bench fig12_participation_coded

use coded_opt::bench::banner;
use coded_opt::config::Scheme;
use coded_opt::data::rcv1like;
use coded_opt::delay::BackgroundTasksDelay;
use coded_opt::driver::{Bcd, Experiment, Problem};
use coded_opt::objectives::LogisticProblem;

fn main() -> anyhow::Result<()> {
    banner("Figure 12", "per-node participation, Steiner-coded BCD (k=0.625m)");
    let (docs, feats, nnz) = (500usize, 192usize, 10usize);
    let (m, k) = (16usize, 10usize);
    let ds = rcv1like::generate(docs, feats, nnz, 0.05, 77);
    let x = ds.train.to_dense();
    let prob = LogisticProblem::new(ds.train.clone(), 1e-4);
    let step = 1.0 / prob.smoothness() / 4.0;
    // One delay model: read the per-node background-task counts for the
    // printout, then hand the same instance to the (single) run.
    let bg = BackgroundTasksDelay::new(m, 1.5, 50, 0.05, 31);
    let tasks: Vec<usize> = bg.task_counts().to_vec();
    let out = Experiment::new(Problem::logistic(&x))
        .scheme(Scheme::Steiner)
        .workers(m)
        .wait_for(k)
        .redundancy(2.0)
        .seed(13)
        .delay_model(Box::new(bg))
        .timing(1e-4, 1e-3)
        .label("steiner")
        .run(Bcd::with_step(step).lambda(1e-4).iters(300))?;
    println!("\nnode  bg-tasks  participation fraction");
    for i in 0..m {
        let frac = out.participation.fraction(i);
        let bar = "#".repeat((40.0 * frac).round() as usize);
        println!("{i:>4}  {:>8}  {frac:>6.3} |{bar}", tasks[i]);
    }
    println!("\ntarget E[participation] = k/m = {:.3}", k as f64 / m as f64);
    println!("imbalance (cv) = {:.3}", out.participation.imbalance());
    println!("\nPaper shape (Fig. 12): lightly-loaded nodes participate in nearly every");
    println!("iteration; heavily-loaded nodes are (harmlessly) erased — but every node");
    println!("that does participate contributes a FRESH update.");
    Ok(())
}
