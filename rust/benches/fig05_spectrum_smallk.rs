//! Figure 5: sample spectrum of S_AᵀS_A for various constructions with
//! SMALL k (η well below 1). Regenerates the eigenvalue histograms the
//! paper plots, as ASCII series + summary table.
//!
//!     cargo bench --bench fig05_spectrum_smallk

use coded_opt::bench::banner;
use coded_opt::config::Scheme;
use coded_opt::encoding::{EncodingOp, SubsetSpectrum};
use coded_opt::metrics::TableWriter;

fn main() -> anyhow::Result<()> {
    banner("Figure 5", "spectrum of subset Grams, small k (η = 0.375)");
    let (n, m, beta, k) = (120usize, 16usize, 2.0, 6usize);
    let mut table =
        TableWriter::new(&["scheme", "n", "k/m", "β", "λmin", "λmax", "ε", "bulk@1"]);
    for scheme in [
        Scheme::Gaussian,
        Scheme::Paley,
        Scheme::Hadamard,
        Scheme::Steiner,
        Scheme::Haar,
    ] {
        let enc = EncodingOp::build(scheme, n, m, beta, 5)?;
        let mut an = SubsetSpectrum::new(&enc, 11);
        let stats = an.analyze(k, 16);
        table.row(&stats.summary_row());
        // ASCII histogram over [0, 2.5] — the figure's x-axis
        let hist = stats.histogram(0.0, 2.5, 25);
        let max = *hist.iter().max().unwrap() as f64;
        let bars: String = hist
            .iter()
            .map(|&c| {
                let lvl = (8.0 * c as f64 / max.max(1.0)).round() as usize;
                [' ', '.', ':', '-', '=', '+', '*', '#', '@'][lvl.min(8)]
            })
            .collect();
        println!("{:<10} |{}| λ∈[0,2.5]", scheme.name(), bars);
    }
    println!();
    table.print();
    println!("\nPaper shape: ETF spectra (paley/hadamard/steiner) concentrate harder than");
    println!("gaussian; at η < 1−1/β no flat plateau is guaranteed (Prop. 8 premise fails).");
    Ok(())
}
