//! Figure 8: matrix-factorization test RMSE for m ∈ {8, 24} with the
//! server waiting for k = m/8 and k = m/2 responses, across schemes.
//! "Perfect" = k = m.
//!
//!     cargo bench --bench fig08_mf_rmse

use coded_opt::bench::banner;
use coded_opt::config::Scheme;
use coded_opt::coordinator::mf::{mf_experiment, MfExperimentCfg};
use coded_opt::metrics::TableWriter;

fn main() -> anyhow::Result<()> {
    banner("Figure 8", "MF test RMSE: m ∈ {8,24}, k ∈ {m/8, m/2}, all schemes");
    let schemes = [
        Scheme::Uncoded,
        Scheme::Replication,
        Scheme::Gaussian,
        Scheme::Paley,
        Scheme::Hadamard,
    ];
    for m in [8usize, 24] {
        for k in [m / 8, m / 2] {
            let mut table = TableWriter::new(&["scheme", "test RMSE", "Δ vs perfect"]);
            // "perfect" reference: k = m uncoded
            let perfect = mf_experiment(&MfExperimentCfg {
                users: 80,
                movies: 240,
                dim: 8,
                ratings_per_user: 40,
                lambda: 2.0,
                epochs: 3,
                m,
                k: m,
                scheme: Scheme::Uncoded,
                threshold: 40,
                seed: 7,
            });
            for scheme in schemes {
                let (_, test, _) = mf_experiment(&MfExperimentCfg {
                    users: 80,
                    movies: 240,
                    dim: 8,
                    ratings_per_user: 40,
                    lambda: 2.0,
                    epochs: 3,
                    m,
                    k,
                    scheme,
                    threshold: 40,
                    seed: 7,
                });
                table.row(&[
                    scheme.name().into(),
                    format!("{test:.4}"),
                    format!("{:+.4}", test - perfect.1),
                ]);
            }
            println!("\n--- m={m}, k={k}   (perfect k=m test RMSE: {:.4}) ---", perfect.1);
            table.print();
        }
    }
    println!("\nPaper shape (Fig. 8): coded schemes are most robust at small k —");
    println!("uncoded degrades hardest at k=m/8, ETFs stay closest to 'perfect'.");
    Ok(())
}
