//! Table 3: full MovieLens-style MF results, m = 24 nodes,
//! k ∈ {3, 12}: train/test RMSE and runtime per scheme.
//!
//!     cargo bench --bench tab03_mf_m24

use coded_opt::bench::banner;
use coded_opt::config::Scheme;
use coded_opt::coordinator::mf::{mf_experiment, MfExperimentCfg};
use coded_opt::metrics::TableWriter;

fn main() -> anyhow::Result<()> {
    banner("Table 3", "MF full results, m = 24 (train RMSE / test RMSE / runtime)");
    let schemes = [
        Scheme::Uncoded,
        Scheme::Replication,
        Scheme::Gaussian,
        Scheme::Paley,
        Scheme::Hadamard,
    ];
    let base = MfExperimentCfg {
        users: 80,
        movies: 240,
        dim: 8,
        ratings_per_user: 40,
        lambda: 2.0,
        epochs: 3,
        m: 24,
        k: 24,
        scheme: Scheme::Uncoded,
        threshold: 40,
        seed: 7,
    };
    for k in [3usize, 12] {
        let mut table =
            TableWriter::new(&["", "uncoded", "replication", "gaussian", "paley", "hadamard"]);
        let mut train_row = vec!["train RMSE".to_string()];
        let mut test_row = vec!["test RMSE".to_string()];
        let mut time_row = vec!["runtime".to_string()];
        for scheme in schemes {
            let (train, test, time) = mf_experiment(&MfExperimentCfg { k, scheme, ..base });
            train_row.push(format!("{train:.3}"));
            test_row.push(format!("{test:.3}"));
            time_row.push(format!("{time:.1}s"));
        }
        println!("\n--- m = 24, k = {k} ---");
        table.row(&train_row);
        table.row(&test_row);
        table.row(&time_row);
        table.print();
    }
    let (train, test, time) = mf_experiment(&base);
    println!(
        "\nfull-batch reference (uncoded, k=m=24): train {train:.3} / test {test:.3} / {time:.1}s"
    );
    println!("\nPaper shape (Table 3): same ordering as Table 2 at larger m — coded");
    println!("schemes closest to full-batch RMSE at small k.");
    Ok(())
}
