//! Figure 9: MF total runtime with m = 8 and m = 24 nodes for different
//! values of k, under a fixed iteration budget per scheme.
//!
//!     cargo bench --bench fig09_mf_runtime

use coded_opt::bench::banner;
use coded_opt::config::Scheme;
use coded_opt::coordinator::mf::{mf_experiment, MfExperimentCfg};
use coded_opt::metrics::TableWriter;

fn main() -> anyhow::Result<()> {
    banner("Figure 9", "MF total (simulated) runtime vs k, fixed epochs");
    for m in [8usize, 24] {
        let ks: Vec<usize> = match m {
            8 => vec![1, 4, 6, 8],
            _ => vec![3, 12, 18, 24],
        };
        let mut table = TableWriter::new(&["k", "uncoded", "replication", "paley", "hadamard"]);
        for k in ks {
            let mut row = vec![format!("{k}")];
            for scheme in
                [Scheme::Uncoded, Scheme::Replication, Scheme::Paley, Scheme::Hadamard]
            {
                let (_, _, time) = mf_experiment(&MfExperimentCfg {
                    users: 80,
                    movies: 240,
                    dim: 8,
                    ratings_per_user: 40,
                    lambda: 2.0,
                    epochs: 2,
                    m,
                    k,
                    scheme,
                    threshold: 40,
                    seed: 7,
                });
                row.push(format!("{time:.1}s"));
            }
            table.row(&row);
        }
        println!("\n--- m = {m} ---");
        table.print();
    }
    println!("\nPaper shape (Fig. 9): runtime increases with k (more stragglers waited");
    println!("for); coded runtimes are comparable to uncoded at the same k — the");
    println!("encoding overhead is amortized (paper §5.2).");
    Ok(())
}
