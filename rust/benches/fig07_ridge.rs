//! Figure 7: ridge regression with distributed encoded L-BFGS.
//! Left panel — objective evolution for uncoded / replication / Hadamard
//! at the paper's k=12, m=32 operating point (persistent stragglers).
//! Right panel — total runtime vs η for a fixed iteration budget.
//!
//!     cargo bench --bench fig07_ridge

use coded_opt::bench::banner;
use coded_opt::config::Scheme;
use coded_opt::data::synth::gaussian_linear;
use coded_opt::delay::BackgroundTasksDelay;
use coded_opt::driver::{Experiment, Lbfgs, Problem};
use coded_opt::metrics::TableWriter;
use coded_opt::objectives::{QuadObjective, RidgeProblem};

const SECS_PER_UNIT: f64 = 2e-4;

fn main() -> anyhow::Result<()> {
    banner("Figure 7", "ridge L-BFGS: convergence (left) and runtime vs η (right)");
    // paper: (n,p)=(4096,6000), m=32, k=12, λ=0.05, β=2 — scaled 4×
    let (n, p, m, k) = (1024usize, 256usize, 32usize, 12usize);
    let lambda = 0.05;
    let (x, y, _) = gaussian_linear(n, p, 0.5, 99);
    let prob = RidgeProblem::new(x.clone(), y.clone(), lambda);
    let f_star = prob.objective(&prob.solve_exact());
    println!("n={n} p={p} m={m} k={k} λ={lambda} β=2   f*={f_star:.6}\n");

    // One experiment template per scheme; persistent background-load
    // stragglers — the regime where fixed-k uncoded permanently drops
    // the same blocks.
    let run = |scheme: Scheme, k_run: usize, with_eval: bool| {
        let mut exp = Experiment::new(Problem::least_squares(&x, &y))
            .scheme(scheme)
            .workers(m)
            .wait_for(k_run)
            .redundancy(2.0)
            .seed(5)
            .delay(|m| Box::new(BackgroundTasksDelay::new(m, 1.5, 50, 0.2, 77)))
            .timing(SECS_PER_UNIT, 1e-3)
            .label(scheme.name());
        if with_eval {
            exp = exp.eval(|w| (prob.objective(w), 0.0));
        }
        exp.run(Lbfgs::new().iters(40).lambda(lambda))
    };

    // ---- Left: evolution of (f−f*)/f* per iteration
    println!("LEFT: relative suboptimality vs iteration");
    println!("{:<6} {:>12} {:>12} {:>12}", "iter", "uncoded", "replication", "hadamard");
    let mut traces = Vec::new();
    for scheme in [Scheme::Uncoded, Scheme::Replication, Scheme::Hadamard] {
        traces.push(run(scheme, k, true)?.trace);
    }
    for i in (0..40).step_by(4) {
        print!("{:<6}", i);
        for t in &traces {
            print!(" {:>12.3e}", (t.records[i].objective - f_star) / f_star);
        }
        println!();
    }
    println!("\nfinal suboptimality:");
    for t in &traces {
        println!("  {:<12} {:.3e}", t.label, (t.final_objective() - f_star) / f_star);
    }

    // ---- Right: runtime vs η for the same iteration count
    println!("\nRIGHT: simulated runtime (s) for 40 iterations vs η = k/m");
    let mut table = TableWriter::new(&["η", "k", "uncoded", "replication", "hadamard"]);
    for k_sweep in [8usize, 12, 16, 20, 24, 28, 32] {
        let mut row = vec![format!("{:.3}", k_sweep as f64 / m as f64), format!("{k_sweep}")];
        for scheme in [Scheme::Uncoded, Scheme::Replication, Scheme::Hadamard] {
            let out = run(scheme, k_sweep, false)?;
            row.push(format!("{:.1}", out.trace.total_time()));
        }
        table.row(&row);
    }
    table.print();
    println!("\nPaper shape: runtime grows steeply as η→1 (waiting for stragglers);");
    println!("k=12 cuts runtime ~40% vs k=32 while hadamard keeps converging stably.");
    Ok(())
}
