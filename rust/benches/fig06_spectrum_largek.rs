//! Figure 6: sample spectrum of S_AᵀS_A for moderate redundancy and
//! LARGE k. The key visual: ETF constructions pin a Prop-8 plateau of
//! eigenvalues at exactly 1 while the Gaussian ensemble spreads.
//!
//!     cargo bench --bench fig06_spectrum_largek

use coded_opt::bench::banner;
use coded_opt::config::Scheme;
use coded_opt::encoding::{EncodingOp, SubsetSpectrum};
use coded_opt::metrics::TableWriter;

fn main() -> anyhow::Result<()> {
    banner("Figure 6", "spectrum of subset Grams, large k (η = 0.75)");
    let (n, m, beta, k) = (120usize, 16usize, 2.0, 12usize);
    let mut table =
        TableWriter::new(&["scheme", "n", "k/m", "β", "λmin", "λmax", "ε", "bulk@1"]);
    let mut bulk = std::collections::BTreeMap::new();
    for scheme in [
        Scheme::Gaussian,
        Scheme::Paley,
        Scheme::Hadamard,
        Scheme::Steiner,
        Scheme::Haar,
    ] {
        let enc = EncodingOp::build(scheme, n, m, beta, 5)?;
        let mut an = SubsetSpectrum::new(&enc, 11);
        let stats = an.analyze(k, 16);
        bulk.insert(scheme.name(), stats.bulk_at_one);
        table.row(&stats.summary_row());
        let hist = stats.histogram(0.0, 2.0, 25);
        let max = *hist.iter().max().unwrap() as f64;
        let bars: String = hist
            .iter()
            .map(|&c| {
                let lvl = (8.0 * c as f64 / max.max(1.0)).round() as usize;
                [' ', '.', ':', '-', '=', '+', '*', '#', '@'][lvl.min(8)]
            })
            .collect();
        println!("{:<10} |{}| λ∈[0,2.0]", scheme.name(), bars);
    }
    println!();
    table.print();
    // The paper's headline comparison for this figure:
    let etf_bulk = bulk["paley"].max(bulk["hadamard"]).max(bulk["steiner"]);
    println!(
        "\nETF plateau fraction ≥ {:.0}% vs gaussian {:.0}% — who wins: ETFs, as in the paper.",
        100.0 * etf_bulk,
        100.0 * bulk["gaussian"]
    );
    Ok(())
}
