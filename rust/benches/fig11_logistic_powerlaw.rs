//! Figure 11: logistic regression (encoded BCD) — train/test error over
//! time when the number of background tasks per machine follows a power
//! law (α = 1.5, capped at 50); k/m = 0.625 (the paper's k=80, m=128).
//! Every run — coded, uncoded, async — goes through the same
//! [`Experiment`](coded_opt::driver::Experiment).
//!
//!     cargo bench --bench fig11_logistic_powerlaw

use coded_opt::bench::banner;
use coded_opt::config::Scheme;
use coded_opt::data::rcv1like;
use coded_opt::delay::BackgroundTasksDelay;
use coded_opt::driver::{AsyncBcd, Bcd, Experiment, Problem};
use coded_opt::metrics::Trace;
use coded_opt::objectives::LogisticProblem;

const SECS_PER_UNIT: f64 = 1e-4;

fn main() -> anyhow::Result<()> {
    banner("Figure 11", "logistic BCD, power-law background tasks: error vs time");
    let (docs, feats, nnz) = (700usize, 256usize, 12usize);
    let (m, k) = (16usize, 10usize); // k/m = 0.625 = paper's 80/128
    let ds = rcv1like::generate(docs, feats, nnz, 0.05, 77);
    let x = ds.train.to_dense();
    let prob = LogisticProblem::new(ds.train.clone(), 1e-4);
    let step = 1.0 / prob.smoothness() / 4.0;
    let iters = 400;

    let mut traces: Vec<Trace> = Vec::new();
    let sync_runs: Vec<(&str, Scheme, usize, f64)> = vec![
        ("steiner k<m", Scheme::Steiner, k, 2.0),
        ("haar k<m", Scheme::Haar, k, 2.0),
        ("uncoded k<m", Scheme::Uncoded, k, 1.0),
        ("uncoded k=m", Scheme::Uncoded, m, 1.0),
    ];
    for (label, scheme, k_run, beta) in sync_runs {
        let out = Experiment::new(Problem::logistic(&x))
            .scheme(scheme)
            .workers(m)
            .wait_for(k_run)
            .redundancy(beta)
            .seed(13)
            .delay(|m| Box::new(BackgroundTasksDelay::new(m, 1.5, 50, 0.05, 31)))
            .timing(SECS_PER_UNIT, 1e-3)
            .label(label)
            .eval(|w| (prob.objective(w), prob.error_rate(w, &ds.test)))
            .run(Bcd::with_step(step).lambda(1e-4).iters(iters))?;
        traces.push(out.trace);
    }
    // async under the same persistent background load, same wall budget
    {
        let budget = traces.iter().map(|t| t.total_time()).fold(0.0, f64::max);
        let out = Experiment::new(Problem::logistic(&x))
            .workers(m)
            .delay(|m| Box::new(BackgroundTasksDelay::new(m, 1.5, 50, 0.05, 31)))
            .timing(SECS_PER_UNIT, 1e-3)
            .label("async")
            .eval(|w| (prob.objective(w), prob.error_rate(w, &ds.test)))
            .run(AsyncBcd::with_step(step).lambda(1e-4).updates(40_000).record_every(200))?;
        let mut trace = out.trace;
        trace.records.retain(|r| r.time <= budget);
        traces.push(trace);
    }

    let t_max = traces
        .iter()
        .filter(|t| t.label != "uncoded k=m")
        .map(|t| t.total_time())
        .fold(0.0, f64::max);
    println!("\ntrain objective / test error at time t:");
    print!("{:<10}", "time(s)");
    for t in &traces {
        print!(" {:>20}", t.label);
    }
    println!();
    for i in 1..=8 {
        let cp = t_max * i as f64 / 8.0;
        print!("{:<10.1}", cp);
        for t in &traces {
            print!(
                " {:>12.4}/{:>6.3}",
                t.objective_at_time(cp),
                t.test_metric_at_time(cp)
            );
        }
        println!();
    }
    println!("\nfinal state per run:");
    for t in &traces {
        println!(
            "  {:<14} obj {:.4}  test err {:.3}  total sim time {:.0}s",
            t.label,
            t.final_objective(),
            t.final_test_metric(),
            t.total_time()
        );
    }
    println!("\nPaper shape (Fig. 11): under PERSISTENT power-law load the same machines");
    println!("straggle forever: uncoded k<m permanently freezes their blocks (stalls");
    println!("above the encoded runs), uncoded k=m pays their latency every round, and");
    println!("the encoded schemes sidestep both.");
    println!("\nHONEST DIVERGENCE NOTE: in this scaled simulator the async baseline is");
    println!("more competitive on raw objective than in the paper's 128-node EC2 runs —");
    println!("block-separable staleness is benign at m=16 with a convex objective. The");
    println!("paper's async pathologies (Fig. 13 participation skew, no deterministic");
    println!("guarantee, divergence risk at aggressive steps) are reproduced in");
    println!("fig13_participation_async and the theory checkpoints.");
    Ok(())
}
