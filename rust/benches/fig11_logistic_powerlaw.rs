//! Figure 11: logistic regression (encoded BCD) — train/test error over
//! time when the number of background tasks per machine follows a power
//! law (α = 1.5, capped at 50); k/m = 0.625 (the paper's k=80, m=128).
//!
//!     cargo bench --bench fig11_logistic_powerlaw

use coded_opt::bench::banner;
use coded_opt::cluster::SimCluster;
use coded_opt::config::Scheme;
use coded_opt::coordinator::asynchronous::{run_async_bcd, AsyncBcdConfig};
use coded_opt::coordinator::bcd::{build_model_parallel, logistic_phi, run_bcd, BcdConfig};
use coded_opt::data::rcv1like;
use coded_opt::delay::BackgroundTasksDelay;
use coded_opt::encoding::partition_bounds;
use coded_opt::metrics::Trace;
use coded_opt::objectives::LogisticProblem;

const SECS_PER_UNIT: f64 = 1e-4;

fn main() -> anyhow::Result<()> {
    banner("Figure 11", "logistic BCD, power-law background tasks: error vs time");
    let (docs, feats, nnz) = (700usize, 256usize, 12usize);
    let (m, k) = (16usize, 10usize); // k/m = 0.625 = paper's 80/128
    let ds = rcv1like::generate(docs, feats, nnz, 0.05, 77);
    let x = ds.train.to_dense();
    let n_train = ds.train.rows();
    let prob = LogisticProblem::new(ds.train.clone(), 1e-4);
    let step = 1.0 / prob.smoothness() / 4.0;
    let iters = 400;

    let mut traces: Vec<Trace> = Vec::new();
    let sync_runs: Vec<(&str, Scheme, usize, f64)> = vec![
        ("steiner k<m", Scheme::Steiner, k, 2.0),
        ("haar k<m", Scheme::Haar, k, 2.0),
        ("uncoded k<m", Scheme::Uncoded, k, 1.0),
        ("uncoded k=m", Scheme::Uncoded, m, 1.0),
    ];
    for (label, scheme, k_run, beta) in sync_runs {
        let mp = build_model_parallel(&x, scheme, m, beta, step, 1e-4, 13, logistic_phi())?;
        let sbar = mp.sbar;
        let delay = BackgroundTasksDelay::new(m, 1.5, 50, 0.05, 31);
        let mut cluster =
            SimCluster::new(mp.workers, Box::new(delay)).with_timing(SECS_PER_UNIT, 1e-3);
        let cfg = BcdConfig { k: k_run, iters };
        let out = run_bcd(&mut cluster, &sbar, n_train, feats, &cfg, label, &|w| {
            (prob.objective(w), prob.error_rate(w, &ds.test))
        });
        traces.push(out.trace);
    }
    // async under the same persistent background load, same wall budget
    {
        let bounds = partition_bounds(feats, m);
        let blocks: Vec<coded_opt::linalg::Mat> = bounds
            .windows(2)
            .map(|w| x.select_cols(&(w[0]..w[1]).collect::<Vec<_>>()))
            .collect();
        let grad_phi = |u: &[f64]| -> Vec<f64> {
            let n = u.len() as f64;
            u.iter().map(|&ui| -coded_opt::objectives::logistic::sigmoid(-ui) / n).collect()
        };
        let mut delay = BackgroundTasksDelay::new(m, 1.5, 50, 0.05, 31);
        let budget = traces.iter().map(|t| t.total_time()).fold(0.0, f64::max);
        let cfg = AsyncBcdConfig {
            step,
            lambda: 1e-4,
            updates: 40_000,
            secs_per_unit: SECS_PER_UNIT,
            record_every: 200,
        };
        let eval = |v: &[Vec<f64>]| -> (f64, f64) {
            let w: Vec<f64> = v.iter().flatten().copied().collect();
            (prob.objective(&w), prob.error_rate(&w, &ds.test))
        };
        let (mut trace, _, _) =
            run_async_bcd(&blocks, &grad_phi, n_train, &cfg, &mut delay, "async", &eval);
        trace.records.retain(|r| r.time <= budget);
        traces.push(trace);
    }

    let t_max = traces
        .iter()
        .filter(|t| t.label != "uncoded k=m")
        .map(|t| t.total_time())
        .fold(0.0, f64::max);
    println!("\ntrain objective / test error at time t:");
    print!("{:<10}", "time(s)");
    for t in &traces {
        print!(" {:>20}", t.label);
    }
    println!();
    for i in 1..=8 {
        let cp = t_max * i as f64 / 8.0;
        print!("{:<10.1}", cp);
        for t in &traces {
            print!(
                " {:>12.4}/{:>6.3}",
                t.objective_at_time(cp),
                t.test_metric_at_time(cp)
            );
        }
        println!();
    }
    println!("\nfinal state per run:");
    for t in &traces {
        println!(
            "  {:<14} obj {:.4}  test err {:.3}  total sim time {:.0}s",
            t.label,
            t.final_objective(),
            t.final_test_metric(),
            t.total_time()
        );
    }
    println!("\nPaper shape (Fig. 11): under PERSISTENT power-law load the same machines");
    println!("straggle forever: uncoded k<m permanently freezes their blocks (stalls");
    println!("above the encoded runs), uncoded k=m pays their latency every round, and");
    println!("the encoded schemes sidestep both.");
    println!("\nHONEST DIVERGENCE NOTE: in this scaled simulator the async baseline is");
    println!("more competitive on raw objective than in the paper's 128-node EC2 runs —");
    println!("block-separable staleness is benign at m=16 with a convex objective. The");
    println!("paper's async pathologies (Fig. 13 participation skew, no deterministic");
    println!("guarantee, divergence risk at aggressive steps) are reproduced in");
    println!("fig13_participation_async and the theory checkpoints.");
    Ok(())
}
